"""Paper Fig. 7: zero-cancellation accuracy — C = A · A^{-1}.

The Ozaki scheme computes the high mantissa digits exactly (digit-block
by digit-block), so the off-diagonal cancellation beats plain FP64.
"""
import jax.numpy as jnp
import numpy as np

from repro.core.ozaki import OzakiConfig, dgemm_f64, ozaki_matmul
from repro.core.xmath import dd_matmul_np, rel_error_vs_dd

from .common import emit, time_fn


def run(n: int = 96):
    rng = np.random.default_rng(1)
    a_np = rng.standard_normal((n, n))
    ainv = np.linalg.inv(a_np)
    a, b = jnp.asarray(a_np), jnp.asarray(ainv)
    hi, lo = dd_matmul_np(a_np, ainv)

    def err(c):
        return float(np.mean(rel_error_vs_dd(np.asarray(c), hi, lo)))

    for s in (9, 11, 13):
        cfg = OzakiConfig(num_splits=s)
        us = time_fn(lambda c=cfg: ozaki_matmul(a, b, c))
        emit(f"fig7/INT8x{s}", us, f"mean_rel_err={err(ozaki_matmul(a, b, cfg)):.3e}")
    emit("fig7/DGEMM", time_fn(dgemm_f64, a, b),
         f"mean_rel_err={err(dgemm_f64(a, b)):.3e}")


if __name__ == "__main__":
    import argparse

    import jax

    from .common import CSV_HEADER, add_plan_args, configure_from_args

    jax.config.update("jax_enable_x64", True)
    ap = argparse.ArgumentParser()
    add_plan_args(ap)
    configure_from_args(ap.parse_args())
    print(CSV_HEADER)
    run()
