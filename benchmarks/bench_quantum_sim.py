"""Paper Fig. 10 + Table 3: brickwork random-unitary circuit simulation.

StateVec simulation where every d-qubit gate application is a
matmul-(2^{N-d}, 2^d, 2^d) — computed by cuBLAS-ZGEMM in the paper, here
by (a) complex128 einsum (the ZGEMM stand-in) and (b) the Ozaki scheme
on int8 with automatic split selection INT8-AUTO(T).

Reported per config: wall time, speed-up ratio, relative error of the
|00..0> amplitude vs the double-double oracle, and split-slice memory —
the Table 3 columns.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.auto_split import auto_num_splits_complex
from repro.core.ozaki import OzakiConfig, ozaki_matmul_complex

from .common import emit


def haar_unitary(rng, dim):
    z = rng.standard_normal((dim, dim)) + 1j * rng.standard_normal((dim, dim))
    q, r = np.linalg.qr(z)
    return q * (np.diag(r) / np.abs(np.diag(r)))


def apply_gate(state, u, qubits, n, engine, mode, threshold):
    """state: (2^n,) complex; u acts on ``qubits`` (contiguous block)."""
    d = len(qubits)
    lo = qubits[0]
    # reshape so the gate axes are in the middle: (pre, 2^d, post)
    state = state.reshape(2 ** (n - lo - d), 2 ** d, 2 ** lo)
    mat = state.transpose(1, 0, 2).reshape(2 ** d, -1)
    if engine == "zgemm":
        out = jnp.asarray(u) @ jnp.asarray(mat)
        splits = 0
    else:
        a, b = jnp.asarray(u), jnp.asarray(mat)
        splits = auto_num_splits_complex(a, b, w=7,
                                         threshold_bits=threshold)
        out = ozaki_matmul_complex(a, b, OzakiConfig(num_splits=splits))
    out = np.asarray(out).reshape(2 ** d, 2 ** (n - lo - d), 2 ** lo)
    return out.transpose(1, 0, 2).reshape(-1), splits


def simulate(n_qubits, d, layers, engine, threshold=0.0, seed=0):
    rng = np.random.default_rng(seed)
    state = np.zeros(2 ** n_qubits, np.complex128)
    state[0] = 1.0
    used_splits = []
    t0 = time.perf_counter()
    for layer in range(layers):
        offset = (layer % 2) * (d // 2)
        q = offset
        while q + d <= n_qubits:
            u = haar_unitary(rng, 2 ** d)
            state, s = apply_gate(state, u, list(range(q, q + d)),
                                  n_qubits, engine, "auto", threshold)
            used_splits.append(s)
            q += d
    dt = time.perf_counter() - t0
    return state, dt, used_splits


def run(n_qubits: int = 10, d: int = 4, layers: int = 4):
    # reference amplitude in double-double-ish precision via complex256?
    # numpy lacks complex256 portably; run the zgemm engine in f64 and a
    # shadow in extended precision via two independent seeds sanity.
    ref, t_ref, _ = simulate(n_qubits, d, layers, "zgemm")
    emit(f"fig10/ZGEMM/N={n_qubits},d={d}", t_ref * 1e6, "speedup=1.00x")
    for threshold, label in ((0.0, "T=0"), (1.0, "T=1")):
        state, dt, splits = simulate(n_qubits, d, layers, "ozaki",
                                     threshold)
        err = abs(state[0].real - ref[0].real) / max(abs(ref[0].real),
                                                     1e-300)
        mem_mb = np.mean(splits) * (2 ** d) ** 2 * 4 / 1e6  # 4 real mats
        emit(f"fig10/INT8-AUTO({label})/N={n_qubits},d={d}", dt * 1e6,
             f"speedup={t_ref / dt:.2f}x;modes=INT8x{int(np.mean(splits))};"
             f"rel_err_amp={err:.2e};slice_mem_mb={mem_mb:.3f}")
    # norm preservation (unitarity) as an accuracy cross-check
    norm = float(np.linalg.norm(state))
    emit("table3/norm_preservation", 0.0, f"|psi|={norm:.15f}")

    # Host wall-clock is NOT the paper's metric (no IMMU on this host).
    # Modeled v5e ratio vs the FP16-MMU ozBLAS equivalent (Mukunoki et
    # al.), same mantissa space, at a TARGET-RANGE k (the paper's
    # 2^11..2^20; the toy gates here are k=2^d where FP16's accumulator
    # headroom hides its disadvantage — Sec. 3.2 is about large k).
    from repro.core.analytic import FP16_FP32, INT8_INT32
    from repro.launch.mesh import PEAK_BF16_FLOPS, PEAK_INT8_OPS
    space = 53 + 8
    for k in (2 ** 12, 2 ** 16):
        g_int8 = INT8_INT32.num_gemms(k, space)
        g_fp16 = FP16_FP32.num_gemms(k, space)
        ratio = (g_fp16 / PEAK_BF16_FLOPS) / (g_int8 / PEAK_INT8_OPS)
        emit(f"fig10/model_v5e_int8_vs_fp16mmu/k={k}", 0.0,
             f"speedup={ratio:.2f}x;int8_gemms={g_int8};"
             f"fp16_gemms={g_fp16}")


if __name__ == "__main__":
    import argparse

    import jax

    from .common import CSV_HEADER, add_plan_args, configure_from_args

    jax.config.update("jax_enable_x64", True)
    ap = argparse.ArgumentParser()
    add_plan_args(ap)
    configure_from_args(ap.parse_args())
    print(CSV_HEADER)
    run()
