"""Ozaki Scheme I vs Scheme II: the residue-system crossover.

Scheme II (arXiv:2504.08009, ``core.modular``) replaces the
``s(s+1)/2`` slice-pair int8 GEMMs with ``ell`` residue GEMMs, ``ell``
growing *linearly* in the mantissa budget. This benchmark pins the
claim three ways:

  * **modeled** — at the s=7-matched accuracy target and tall k, the
    planner's Scheme II plan issues strictly fewer int8 GEMMs than
    Scheme I's full-pair schedule (15 vs 28 at k=4096; asserted), and
    ``core.accuracy.resolve_accuracy`` arbitrates the same way (the
    cross-scheme cost model picks ``ozaki2_fp64`` there and
    ``ozaki_fp64`` at a loose-target small-k point; both asserted);
  * **measured** — wall-clock of both schemes at matched
    ``target_error`` (CPU interpret-mode rankings are indicative only;
    the deployable number is the GEMM count), each row carrying the
    executed ``PipelinePlan``;
  * **proved** — each scheme's measured ``scaled_error`` against a
    double-double reference stays under its own guaranteed bound, and
    the two results agree within the sum of the bounds (the matched-
    accuracy contract the cost model trades on).

The measured comparison is persisted as versioned
``BENCH_scheme2.json`` (same artifact family as PR 6/7's
``BENCH_streaming.json`` / ``BENCH_collective.json``).
"""
import numpy as np

import jax.numpy as jnp

from repro.core.accuracy import (error_bound, resolve_accuracy,
                                 scaled_error, truncation_eta)
from repro.core.modular import (ModularConfig, modular_error_bound,
                                ozaki2_matmul, resolve_modular)
from repro.core.ozaki import OzakiConfig, ozaki_matmul
from repro.core.splitting import slice_width
from repro.core.tuning import hbm_pass_model
from repro.core.xmath import dd_matmul_np

from .common import emit, phi_matrix, plan_gemm, time_fn, write_bench_json


def _matched_target(k: int, s: int) -> float:
    """Scheme I's own guaranteed truncation bound at (k, s): the
    accuracy contract both schemes are sized for."""
    return k * truncation_eta(s, slice_width(k, fuse_terms=s))


def run(quick: bool = False):
    rng = np.random.default_rng(11)
    rows = []

    # --- modeled GEMM-count win at tall k (the ISSUE acceptance pin):
    # at the s=7-matched target and k=4096 the planner's Scheme II plan
    # must issue strictly fewer int8 GEMMs than Scheme I full-pair.
    k_tall, s_match = 4096, 7
    tgt_tall = _matched_target(k_tall, s_match)
    plan1 = plan_gemm(512, 512, k_tall, scheme="ozaki_fp64",
                      target_error=tgt_tall)
    plan2 = plan_gemm(512, 512, k_tall, scheme="ozaki2_fp64",
                      target_error=tgt_tall)
    assert plan2.num_gemms < plan1.num_gemms, (plan2, plan1)
    choice = resolve_accuracy(k_tall, 10, target_error=tgt_tall,
                              schemes=("ozaki_fp64", "ozaki2_fp64"),
                              m=512, n=512)
    assert choice.scheme == "ozaki2_fp64", choice
    emit(f"scheme2/model/tallk/k={k_tall}", 0.0,
         f"target={tgt_tall:.3g};gemms_scheme1={plan1.num_gemms};"
         f"gemms_scheme2={plan2.num_gemms};winner={choice.scheme}",
         plan=plan2)
    rows.append({"name": "model_tallk", "k": k_tall,
                 "target_error": tgt_tall,
                 "gemms_scheme1": plan1.num_gemms,
                 "gemms_scheme2": plan2.num_gemms,
                 "arbitration": choice.scheme,
                 "costs": [list(c) for c in choice.costs]})

    # --- and the arbitration flips back at a loose-target small-k
    # point: few kept pairs beat the CRT's fixed modulus floor.
    choice_1 = resolve_accuracy(256, 9, target_error=1e-2,
                                schemes=("ozaki_fp64", "ozaki2_fp64"),
                                m=256, n=256)
    assert choice_1.scheme == "ozaki_fp64", choice_1
    emit("scheme2/model/smallk/k=256", 0.0,
         f"target=1e-2;winner={choice_1.scheme};"
         f"costs={dict(choice_1.costs)}")
    rows.append({"name": "model_smallk", "k": 256, "target_error": 1e-2,
                 "arbitration": choice_1.scheme,
                 "costs": [list(c) for c in choice_1.costs]})

    # --- measured matched-target comparison (CPU indicative): both
    # schemes sized for the same contract, errors proved under bound.
    shapes = ([(16, 16, 1024)] if quick
              else [(48, 48, 256), (32, 32, 2048)])
    for m, n, k in shapes:
        tgt = _matched_target(k, s_match)
        a = jnp.asarray(phi_matrix(rng, m, k, 1.0))
        b = jnp.asarray(phi_matrix(rng, k, n, 1.0))
        a_np, b_np = np.asarray(a), np.asarray(b)
        hi, lo = dd_matmul_np(a_np, b_np)

        s1, _ = resolve_accuracy(k, 26, target_error=tgt)
        cfg1 = OzakiConfig(num_splits=s1, backend="xla")
        us1 = time_fn(lambda: ozaki_matmul(a, b, cfg1))
        c1 = np.asarray(ozaki_matmul(a, b, cfg1))
        err1 = scaled_error(c1, hi, a_np, b_np, ref_lo=lo)
        bound1 = error_bound(s1, cfg1.width_for(k), k)
        assert err1 <= bound1, (err1, bound1)

        cfg2 = ModularConfig(target_error=tgt, backend="xla")
        point = cfg2.point(k)
        us2 = time_fn(lambda: ozaki2_matmul(a, b, cfg2))
        c2 = np.asarray(ozaki2_matmul(a, b, cfg2))
        err2 = scaled_error(c2, hi, a_np, b_np, ref_lo=lo)
        bound2 = modular_error_bound(point.beta, k, point.moduli)
        assert err2 <= bound2, (err2, bound2)

        # matched-accuracy contract: the schemes agree within the sum
        # of their guaranteed bounds on the same normalization
        cross = scaled_error(c1, c2, a_np, b_np)
        assert cross <= bound1 + bound2, (cross, bound1, bound2)

        g1 = cfg1.num_gemms
        g2 = len(point.moduli)
        emit(f"scheme2/measured/m={m}/n={n}/k={k}", us2,
             f"target={tgt:.3g};scheme1_us={us1:.1f};"
             f"gemms_scheme1={g1};gemms_scheme2={g2};"
             f"err_scheme1={err1:.3g};err_scheme2={err2:.3g}",
             plan=cfg2.plan(k))
        rows.append({"name": "measured", "m": m, "n": n, "k": k,
                     "target_error": tgt, "us_scheme1": us1,
                     "us_scheme2": us2, "gemms_scheme1": g1,
                     "gemms_scheme2": g2, "beta": point.beta,
                     "scaled_error_scheme1": err1,
                     "scaled_error_scheme2": err2,
                     "bound_scheme1": bound1, "bound_scheme2": bound2})

    # --- accuracy dial: the ozaki2-fp64xL modulus count vs error, the
    # Scheme II analogue of Fig. 6's splits-vs-error sweep.
    m, n, k = (16, 16, 96) if quick else (32, 32, 96)
    a = jnp.asarray(phi_matrix(rng, m, k, 1.0))
    b = jnp.asarray(phi_matrix(rng, k, n, 1.0))
    a_np, b_np = np.asarray(a), np.asarray(b)
    hi, lo = dd_matmul_np(a_np, b_np)
    for ell in (8, 14, 20):
        point = resolve_modular(k, num_moduli=ell)
        cfg = ModularConfig(num_moduli=ell)
        c = np.asarray(ozaki2_matmul(a, b, cfg))
        err = scaled_error(c, hi, a_np, b_np, ref_lo=lo)
        bound = modular_error_bound(point.beta, k, point.moduli)
        assert err <= bound, (ell, err, bound)
        emit(f"scheme2/dial/L={ell}/k={k}", 0.0,
             f"beta={point.beta};scaled_error={err:.3g};"
             f"bound={bound:.3g}")
        rows.append({"name": "dial", "num_moduli": ell, "k": k,
                     "beta": point.beta, "scaled_error": err,
                     "bound": bound})

    # --- fused-CRT epilogue (ISSUE 9): bitwise parity + wall-clock vs
    # the stage-fused route, and the modeled HBM-pass table — the
    # epilogue fusion must claim strictly fewer passes (it removes the
    # 2*ell int32 residue-product round-trips), which is its whole
    # reason to exist.
    m, n, k = (16, 16, 96) if quick else (32, 32, 256)
    a = jnp.asarray(phi_matrix(rng, m, k, 1.0))
    b = jnp.asarray(phi_matrix(rng, k, n, 1.0))
    cfg_st = ModularConfig(backend="pallas_fused")
    cfg_epi = ModularConfig(backend="pallas_fused", fuse_epilogue=True)
    point = cfg_epi.point(k)
    s2, ell = point.num_splits, len(point.moduli)
    us_st = time_fn(lambda: ozaki2_matmul(a, b, cfg_st))
    us_epi = time_fn(lambda: ozaki2_matmul(a, b, cfg_epi))
    c_st = np.asarray(ozaki2_matmul(a, b, cfg_st))
    c_epi = np.asarray(ozaki2_matmul(a, b, cfg_epi))
    assert np.array_equal(c_st, c_epi), "fused-CRT parity must be bitwise"
    passes = {fusion: hbm_pass_model(s2, fusion=fusion,
                                     scheme="ozaki2_fp64", num_moduli=ell)
              for fusion in ("none", "stages", "epilogue")}
    assert (passes["epilogue"]["total"] < passes["stages"]["total"]
            < passes["none"]["total"]), passes
    assert (passes["stages"]["total"] - passes["epilogue"]["total"]
            == 2 * ell), passes
    emit(f"scheme2/fused_crt/m={m}/n={n}/k={k}", us_epi,
         f"stages_us={us_st:.1f};ell={ell};"
         f"passes_none={passes['none']['total']};"
         f"passes_stages={passes['stages']['total']};"
         f"passes_epilogue={passes['epilogue']['total']}",
         plan=cfg_epi.plan(k))
    rows.append({"name": "fused_crt", "m": m, "n": n, "k": k,
                 "num_moduli": ell, "num_splits": s2,
                 "us_stages": us_st, "us_epilogue": us_epi,
                 "bitwise_equal": True,
                 "hbm_passes": {f: p["total"]
                                for f, p in passes.items()},
                 "hbm_pass_table": passes})

    import jax

    from repro.kernels.ops import INTERPRET
    write_bench_json("BENCH_scheme2.json", rows,
                     device_kind=jax.devices()[0].device_kind,
                     interpret=INTERPRET)


if __name__ == "__main__":
    import argparse

    import jax

    from .common import CSV_HEADER, add_plan_args, configure_from_args

    jax.config.update("jax_enable_x64", True)
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny shapes (CI smoke run)")
    add_plan_args(ap)
    args = ap.parse_args()
    configure_from_args(args)
    print(CSV_HEADER)
    run(quick=args.quick)
