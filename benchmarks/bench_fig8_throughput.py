"""Paper Fig. 5/8/9: throughput, unit comparison, and time breakdown.

CPU wall-clock comparisons are indicative only; the deployable numbers
are the TPU v5e roofline models (int8 394 TOPS vs bf16 197 TFLOPS — the
same 2x unit advantage the paper exploits on Tensor Cores; Fig. 5
analogue) and the dry-run roofline table (EXPERIMENTS.md §Roofline).
NVML power (Fig. 8 middle/bottom) is host-specific: reported as the
analytic energy ratio = ops ratio x (pJ/int8-MAC / pJ/bf16-FMA) ~ 0.25,
flagged as a hardware adaptation in DESIGN.md §2.
"""
import jax.numpy as jnp
import numpy as np

from repro.core.analytic import INT8_INT32, DGEMM_MANTISSA_SPACE
from repro.core.ozaki import OzakiConfig, dgemm_f64, ozaki_matmul
from repro.core.splitting import split_int
from repro.launch.mesh import PEAK_BF16_FLOPS, PEAK_INT8_OPS

from .common import emit, phi_matrix, time_fn, write_bench_json


def run(n: int | None = None, quick: bool = False):
    rng = np.random.default_rng(2)
    if n is None:
        n = 64 if quick else 256     # quick sets the default; -n wins
    a = jnp.asarray(phi_matrix(rng, n, n, 1.0))
    b = jnp.asarray(phi_matrix(rng, n, n, 1.0))
    flop = 2.0 * n ** 3
    bench_rows = []

    # --- Fig. 5 analogue: unit throughput ratio on the target hardware
    emit("fig5/tpu_v5e_unit_ratio", 0.0,
         f"int8_over_bf16={PEAK_INT8_OPS / PEAK_BF16_FLOPS:.1f}x")

    # --- Fig. 8 top: wall-clock throughput (CPU indicative)
    for s in (9,) if quick else (9, 11, 13):
        cfg = OzakiConfig(num_splits=s)
        us = time_fn(lambda c=cfg: ozaki_matmul(a, b, c))
        emit(f"fig8/INT8x{s}/n={n}", us, f"gflops={flop / us / 1e3:.2f}")
        bench_rows.append({"name": f"INT8x{s}", "n": n, "num_splits": s,
                           "us_per_call": us,
                           "gflops": flop / us / 1e3})
    us = time_fn(dgemm_f64, a, b)
    emit(f"fig8/DGEMM/n={n}", us, f"gflops={flop / us / 1e3:.2f}")
    bench_rows.append({"name": "DGEMM", "n": n, "us_per_call": us,
                       "gflops": flop / us / 1e3})

    # --- Fig. 8 analytic: modeled TPU step time of INT8x9 vs bf16 GEMM
    s = 9
    gemms = s * (s + 1) // 2
    t_int8 = gemms * flop / PEAK_INT8_OPS
    t_bf16 = flop / PEAK_BF16_FLOPS
    emit("fig8/model_v5e_int8x9_vs_bf16", 0.0,
         f"slowdown_vs_bf16={t_int8 / t_bf16:.1f}x;"
         f"note=TPU_has_no_fp64_alternative")
    emit("fig8/power_model", 0.0,
         "energy_ratio_int8x9_vs_fp64_emulation=n/a_on_host;"
         "analytic=0.25pJ_per_MAC_ratio")

    # --- Fig. 9: time breakdown (split / GEMM / accumulate)
    cfg = OzakiConfig(num_splits=9)
    w = cfg.width_for(n)
    t_split = time_fn(lambda: split_int(a, 9, w))
    t_total = time_fn(lambda: ozaki_matmul(a, b, cfg))
    from repro.core.executors import gemm_xla as _gemm_xla
    sa = split_int(a, 9, w)
    sb = split_int(jnp.asarray(b).T, 9, w)
    t_one_gemm = time_fn(lambda: _gemm_xla(sa.slices[0], sb.slices[0]))
    t_gemms = t_one_gemm * cfg.num_gemms
    t_accum = max(t_total - 2 * t_split - t_gemms, 0.0)
    emit("fig9/split(1,2)", 2 * t_split,
         f"frac={2 * t_split / t_total:.2f}")
    emit("fig9/int8_gemm(6)", t_gemms, f"frac={t_gemms / t_total:.2f}")
    emit("fig9/accumulate(7)", t_accum, f"frac={t_accum / t_total:.2f}")
    bench_rows.append({"name": "fig9_breakdown", "n": n,
                       "us_split": 2 * t_split, "us_gemms": t_gemms,
                       "us_accum": t_accum, "us_total": t_total})

    # persist the measured throughput table as a versioned CI artifact
    # (same family as BENCH_streaming.json / BENCH_scheme2.json)
    import jax

    from repro.kernels.ops import INTERPRET
    write_bench_json("BENCH_throughput.json", bench_rows,
                     device_kind=jax.devices()[0].device_kind,
                     interpret=INTERPRET)


if __name__ == "__main__":
    import argparse

    import jax

    from .common import CSV_HEADER, add_plan_args, configure_from_args

    jax.config.update("jax_enable_x64", True)
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small n, one split count (CI smoke run)")
    ap.add_argument("-n", type=int, default=None,
                    help="matrix size (overrides the --quick default)")
    add_plan_args(ap)
    args = ap.parse_args()
    configure_from_args(args)
    print(CSV_HEADER)
    run(n=args.n, quick=args.quick)
