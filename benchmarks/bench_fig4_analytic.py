"""Paper Fig. 4: BPS / #splits / memory / #GEMMs vs k, per MMU type.

Closed forms from ``repro.core.analytic`` — exact reproduction of all
four panels, emitted as CSV for the table in EXPERIMENTS.md.
"""
from repro.core.analytic import ALL_MMUS, DGEMM_MANTISSA_SPACE

from .common import emit


def run():
    ks = [2 ** e for e in range(11, 21, 3)]
    for mmu in ALL_MMUS:
        for k in ks:
            bps = mmu.bps(k)
            s = mmu.num_splits(k, DGEMM_MANTISSA_SPACE)
            mem = mmu.slice_bytes_per_element(k, DGEMM_MANTISSA_SPACE)
            g = mmu.num_gemms(k, DGEMM_MANTISSA_SPACE)
            emit(f"fig4/{mmu.name}/k={k}", 0.0,
                 f"bps={bps};splits={s};bytes_per_elem={mem};gemms={g}")
    # headline claims (asserted in tests): INT8 memory saving vs FP16
    for k in ks:
        fp16 = ALL_MMUS[0].slice_bytes_per_element(k, DGEMM_MANTISSA_SPACE)
        int8 = ALL_MMUS[2].slice_bytes_per_element(k, DGEMM_MANTISSA_SPACE)
        emit(f"fig4/int8_mem_saving/k={k}", 0.0,
             f"saving={1 - int8 / fp16:.2%}")


if __name__ == "__main__":
    import argparse

    import jax

    from .common import CSV_HEADER, add_plan_args, configure_from_args

    jax.config.update("jax_enable_x64", True)
    ap = argparse.ArgumentParser()
    add_plan_args(ap)
    configure_from_args(ap.parse_args())
    print(CSV_HEADER)
    run()
