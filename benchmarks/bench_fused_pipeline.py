"""Fused-pipeline benchmark: pallas_fused (stage-, epilogue-, and
streaming-fused) vs xla Ozaki, modeled HBM passes, and the measured
autotuner.

The paper's Fig. 9 shows the split and accumulation stages — not the int8
GEMMs — dominating the memory-bound cost of the scheme. The fused
pipelines attack exactly those: a one-pass SplitInt kernel (s slices per
HBM read), a fused scaled-accumulation kernel (convert + scale +
compensated add in one VMEM pass), and — one step further — the
epilogue-fused GEMM that accumulates the scaled partial sums inside the
GEMM grid so the int32 slice products never round-trip to HBM at all.
This benchmark reports

  * wall-clock of the three modes (CPU interpret mode — indicative only;
    the kernels lower to Mosaic unchanged on TPU), each row carrying the
    executed ``PipelinePlan`` in the ``plan`` CSV column,
  * the modeled HBM round-trips per stage (``core.tuning.hbm_pass_model``)
    — the deployable claim: the epilogue mode drops each accumulation
    group from 3 passes (read P + read/write C) to 2 (read/write C only),
    on top of the fused path's s-pass -> 1-pass split; the streaming mode
    then zeroes the ``slices`` line item entirely (slice extraction runs
    inside the GEMM grid, int8 slices never touch HBM) — the measured
    mode comparison is persisted as versioned ``BENCH_streaming.json``,
  * the batched broadcast-weights case through ``ozaki_matmul_batched``
    AND the stacked-weights batch on the batch-grid epilogue kernel
    (which keeps ``fuse_epilogue=True`` — the lifted PR 2 limitation),
  * the measured autotuner vs the analytic plan per shape (ISSUE 3
    acceptance: the analytic plan is always candidate #0, so the tuned
    plan is never slower up to timer noise — the emitted speedup is
    >= ~1.0x by construction).

Flags (also via ``benchmarks.run``): ``--plan-cache PATH`` persists and
reuses tuned plans; ``--autotune`` tunes cache misses for the pipeline
rows too. The epilogue-vs-stages pass reduction is asserted (ISSUE 2).
"""
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.configs.ozimmu_gemm import BATCHED_CONFIG, CONFIG
from repro.core.autotune import autotune_plan
from repro.core.ozaki import OzakiConfig, ozaki_matmul, ozaki_matmul_batched
from repro.core.tuning import (apply_pipeline_plan, hbm_pass_model,
                               select_plan)

from .common import (CONTEXT, emit, phi_matrix, plan_gemm, time_fn,
                     write_bench_json)


def run(n: int = 128, num_splits: int | None = None, quick: bool = False):
    rng = np.random.default_rng(7)
    num_splits = CONFIG.num_splits if num_splits is None else num_splits
    if quick:
        n, num_splits = 64, 5
    a = jnp.asarray(phi_matrix(rng, n, n, 1.0))
    b = jnp.asarray(phi_matrix(rng, n, n, 1.0))

    tile = (select_plan(n, n, n, num_splits=num_splits) if CONFIG.autotune
            else None)
    cfgs = {
        "xla": OzakiConfig(num_splits=num_splits, backend="xla"),
        CONFIG.backend: OzakiConfig(num_splits=num_splits,
                                    backend=CONFIG.backend, tile=tile),
        "pallas_fused_epilogue": OzakiConfig(num_splits=num_splits,
                                             backend="pallas_fused",
                                             fuse_epilogue=True, tile=tile),
        "pallas_fused_streaming": OzakiConfig(num_splits=num_splits,
                                              backend="pallas_fused",
                                              streaming=True, tile=tile),
    }
    outs = {}
    bench_rows = []
    for name, cfg in cfgs.items():
        if cfg.backend != "xla" and (CONTEXT.plan_cache is not None or
                                     CONTEXT.autotune):
            # resolve through the run's plan context (cache + autotune),
            # but PIN this row's fusion mode afterwards: the cache key is
            # fusion-agnostic (fusion is result-invariant and part of the
            # search space), and these rows exist to compare the modes
            want_epilogue, want_streaming = cfg.fuse_epilogue, cfg.streaming
            cfg = apply_pipeline_plan(cfg, plan_gemm(
                n, n, n, backend=cfg.backend, accum="f64",
                num_splits=num_splits, fuse_epilogue=want_epilogue,
                streaming=want_streaming))
            cfg = dataclasses.replace(cfg, fuse_epilogue=want_epilogue,
                                      streaming=want_streaming)
            cfgs[name] = cfg
        us = time_fn(lambda c=cfg: ozaki_matmul(a, b, c))
        outs[name] = np.asarray(ozaki_matmul(a, b, cfgs[name]))
        plan = cfg.plan()
        passes = hbm_pass_model(num_splits, fusion=plan.fusion)
        emit(f"fused_pipeline/{name}/n={n}", us,
             f"hbm_passes_split={passes['split']};"
             f"hbm_passes_slices={passes['slices']};"
             f"hbm_passes_accum={passes['accum']};"
             f"hbm_passes_total={passes['total']}", plan=plan)
        bench_rows.append({"name": name, "n": n,
                           "num_splits": num_splits, "us_per_call": us,
                           "fusion": plan.fusion, "hbm_passes": passes})
    bitwise = all(np.array_equal(outs["xla"], c) for c in outs.values())
    px = hbm_pass_model(num_splits, fused=False)
    pf = hbm_pass_model(num_splits, fused=True)
    pe = hbm_pass_model(num_splits, fused=True, fuse_epilogue=True)
    pst = hbm_pass_model(num_splits, fusion="streaming")
    # ISSUE 2 acceptance: epilogue fusion models strictly fewer passes
    # than the PR 1 stage-fused pipeline (which beat the XLA path).
    # ISSUE 6 acceptance: with the slice-stack traffic charged (the
    # ``slices`` line item the model used to hide), streaming — whose
    # slices never touch HBM — models strictly fewer again.
    assert pst["total"] < pe["total"] < pf["total"] < px["total"], \
        (pst, pe, pf, px)
    assert pst["slices"] == 0 and pe["slices"] > 0, (pst, pe)
    emit("fused_pipeline/parity", 0.0,
         f"bitwise_equal={bitwise};"
         f"pass_reduction_fused={px['total'] / pf['total']:.2f}x;"
         f"pass_reduction_epilogue={px['total'] / pe['total']:.2f}x;"
         f"pass_reduction_streaming={px['total'] / pst['total']:.2f}x")
    # persist the measured mode comparison as a versioned CI artifact
    from repro.kernels.ops import INTERPRET
    import jax
    write_bench_json("BENCH_streaming.json", bench_rows,
                     device_kind=jax.devices()[0].device_kind,
                     interpret=INTERPRET, bitwise_equal_xla=bool(bitwise))

    # batched serving case (BATCHED_CONFIG shape, CPU-scaled): the
    # (B, m, k) @ (k, n) broadcast-weights route of ozaki_matmul_batched.
    scale = 16 if quick else 4
    bsz = max(2, BATCHED_CONFIG.batch // scale)
    m = max(8, BATCHED_CONFIG.m // scale)
    ab = jnp.asarray(
        np.stack([phi_matrix(rng, m, n, 1.0) for _ in range(bsz)]))
    cfg = OzakiConfig(num_splits=BATCHED_CONFIG.num_splits,
                      backend=BATCHED_CONFIG.backend)
    us = time_fn(lambda: ozaki_matmul_batched(ab, b, cfg))
    emit(f"fused_pipeline/batched/b={bsz}/m={m}/n={n}", us,
         f"broadcast_weights=1;gflops="
         f"{2.0 * bsz * m * n * n / us / 1e3:.2f}",
         plan=cfg.plan(batch_layout="rows"))

    # stacked-weights batch on the batch-grid epilogue kernel: the plan
    # KEEPS fuse_epilogue (no stage-fused downgrade) — 2 modeled passes
    # per accumulation group instead of 3, per batch row.
    bs = 2 if quick else 4
    ms, ks, ns = (16, 48, 24) if quick else (24, 96, 32)
    ag = jnp.asarray(
        np.stack([phi_matrix(rng, ms, ks, 1.0) for _ in range(bs)]))
    bg = jnp.asarray(
        np.stack([phi_matrix(rng, ks, ns, 1.0) for _ in range(bs)]))
    cfg_g = OzakiConfig(num_splits=num_splits, backend="pallas_fused",
                        fuse_epilogue=True)
    plan_g = cfg_g.plan(batch_layout="grid")
    assert plan_g.fusion == "epilogue", plan_g     # limitation lifted
    us = time_fn(lambda: ozaki_matmul_batched(ag, bg, cfg_g))
    pg = hbm_pass_model(num_splits, fused=True, fuse_epilogue=True,
                        batch=bs, batch_layout="grid")
    ps = hbm_pass_model(num_splits, fused=True, batch=bs,
                        batch_layout="grid")
    emit(f"fused_pipeline/batched_grid_epilogue/b={bs}/m={ms}/k={ks}", us,
         f"stacked_weights=1;fusion={plan_g.fusion};"
         f"hbm_passes_total={pg['total']};stages_would_be={ps['total']}",
         plan=plan_g)

    # fast mode on the epilogue pipeline: the truncated pair list becomes
    # a SHORTER pair-grid dimension in the epilogue kernel (never a
    # mask), cutting slice GEMMs while staying bitwise equal to the xla
    # pipeline under the same policy.
    cfg_fast = OzakiConfig(num_splits=num_splits, backend="pallas_fused",
                           fuse_epilogue=True, pair_policy="diagonal")
    us = time_fn(lambda: ozaki_matmul(a, b, cfg_fast))
    c_fast = np.asarray(ozaki_matmul(a, b, cfg_fast))
    c_fast_xla = np.asarray(ozaki_matmul(
        a, b, OzakiConfig(num_splits=num_splits, pair_policy="diagonal")))
    assert np.array_equal(c_fast, c_fast_xla)
    assert cfg_fast.num_gemms < cfgs["xla"].num_gemms
    emit(f"fused_pipeline/fast_mode/n={n}", us,
         f"policy=diagonal;gemms={cfg_fast.num_gemms};"
         f"gemms_full={cfgs['xla'].num_gemms};"
         f"bitwise_equal_xla_same_policy=True", plan=cfg_fast.plan())

    # measured autotuner vs the analytic plan (ISSUE 3 acceptance table):
    # candidate #0 IS the analytic plan, so best <= analytic up to noise.
    shapes = [(n, n, n)] if quick else [(64, 64, 128), (96, 48, 96),
                                        (n, n, n)]
    for mm, nn, kk in shapes:
        # cache=None: always measure, so the analytic-vs-tuned comparison
        # is reported even when earlier rows already cached this shape
        rep = autotune_plan(mm, nn, kk, accum="f64", num_splits=num_splits,
                            cache=None, max_candidates=4 if quick else 6,
                            iters=2 if quick else 3)
        if CONTEXT.plan_cache is not None:
            CONTEXT.plan_cache.put(rep.key, rep.best,
                                   measured_us=rep.best_us)
            CONTEXT.plan_cache.save()
        emit(f"fused_pipeline/autotune/m={mm}/n={nn}/k={kk}", rep.best_us,
             f"analytic_us={rep.analytic_us:.1f};"
             f"speedup_vs_analytic={rep.analytic_us / rep.best_us:.2f}x;"
             f"candidates={len(rep.measurements)}", plan=rep.best)


if __name__ == "__main__":
    import argparse

    import jax

    from .common import CSV_HEADER, add_plan_args, configure_from_args

    jax.config.update("jax_enable_x64", True)
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny shapes, few splits (CI smoke run)")
    add_plan_args(ap)
    args = ap.parse_args()
    configure_from_args(args)
    print(CSV_HEADER)
    run(quick=args.quick)
