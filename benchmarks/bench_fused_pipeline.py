"""Fused-pipeline benchmark: pallas_fused (stage- and epilogue-fused) vs
xla Ozaki, plus modeled HBM passes.

The paper's Fig. 9 shows the split and accumulation stages — not the int8
GEMMs — dominating the memory-bound cost of the scheme. The fused
pipelines attack exactly those: a one-pass SplitInt kernel (s slices per
HBM read), a fused scaled-accumulation kernel (convert + scale +
compensated add in one VMEM pass), and — one step further — the
epilogue-fused GEMM that accumulates the scaled partial sums inside the
GEMM grid so the int32 slice products never round-trip to HBM at all.
This benchmark reports

  * wall-clock of the three modes (CPU interpret mode — indicative only;
    the kernels lower to Mosaic unchanged on TPU),
  * the modeled HBM round-trips per stage (``core.tuning.hbm_pass_model``)
    — the deployable claim: the epilogue mode drops each accumulation
    group from 3 passes (read P + read/write C) to 2 (read/write C only),
    on top of the fused path's s-pass -> 1-pass split,
  * the batched broadcast-weights case through ``ozaki_matmul_batched``.

The epilogue-vs-stages pass reduction is asserted (ISSUE 2 acceptance).
"""
import jax.numpy as jnp
import numpy as np

from repro.configs.ozimmu_gemm import BATCHED_CONFIG, CONFIG
from repro.core.ozaki import OzakiConfig, ozaki_matmul, ozaki_matmul_batched
from repro.core.tuning import hbm_pass_model, select_plan

from .common import emit, phi_matrix, time_fn


def run(n: int = 128, num_splits: int | None = None, quick: bool = False):
    rng = np.random.default_rng(7)
    num_splits = CONFIG.num_splits if num_splits is None else num_splits
    if quick:
        n, num_splits = 64, 5
    a = jnp.asarray(phi_matrix(rng, n, n, 1.0))
    b = jnp.asarray(phi_matrix(rng, n, n, 1.0))

    plan = (select_plan(n, n, n, num_splits=num_splits) if CONFIG.autotune
            else None)
    cfgs = {
        "xla": OzakiConfig(num_splits=num_splits, backend="xla"),
        CONFIG.backend: OzakiConfig(num_splits=num_splits,
                                    backend=CONFIG.backend, tile=plan),
        "pallas_fused_epilogue": OzakiConfig(num_splits=num_splits,
                                             backend="pallas_fused",
                                             fuse_epilogue=True, tile=plan),
    }
    outs = {}
    for name, cfg in cfgs.items():
        us = time_fn(lambda c=cfg: ozaki_matmul(a, b, c))
        outs[name] = np.asarray(ozaki_matmul(a, b, cfgs[name]))
        passes = hbm_pass_model(num_splits, fused=(cfg.backend ==
                                                   "pallas_fused"),
                                fuse_epilogue=cfg.fuse_epilogue)
        emit(f"fused_pipeline/{name}/n={n}", us,
             f"hbm_passes_split={passes['split']};"
             f"hbm_passes_accum={passes['accum']};"
             f"hbm_passes_total={passes['total']}")
    bitwise = all(np.array_equal(outs["xla"], c) for c in outs.values())
    px = hbm_pass_model(num_splits, fused=False)
    pf = hbm_pass_model(num_splits, fused=True)
    pe = hbm_pass_model(num_splits, fused=True, fuse_epilogue=True)
    # ISSUE 2 acceptance: epilogue fusion models strictly fewer passes
    # than the PR 1 stage-fused pipeline (which beat the XLA path).
    assert pe["total"] < pf["total"] < px["total"], (pe, pf, px)
    emit("fused_pipeline/parity", 0.0,
         f"bitwise_equal={bitwise};"
         f"pass_reduction_fused={px['total'] / pf['total']:.2f}x;"
         f"pass_reduction_epilogue={px['total'] / pe['total']:.2f}x")

    # batched serving case (BATCHED_CONFIG shape, CPU-scaled): the
    # (B, m, k) @ (k, n) broadcast-weights route of ozaki_matmul_batched.
    scale = 16 if quick else 4
    bsz = max(2, BATCHED_CONFIG.batch // scale)
    m = max(8, BATCHED_CONFIG.m // scale)
    ab = jnp.asarray(
        np.stack([phi_matrix(rng, m, n, 1.0) for _ in range(bsz)]))
    cfg = OzakiConfig(num_splits=BATCHED_CONFIG.num_splits,
                      backend=BATCHED_CONFIG.backend)
    us = time_fn(lambda: ozaki_matmul_batched(ab, b, cfg))
    emit(f"fused_pipeline/batched/b={bsz}/m={m}/n={n}", us,
         f"broadcast_weights=1;gflops="
         f"{2.0 * bsz * m * n * n / us / 1e3:.2f}")


if __name__ == "__main__":
    import argparse

    import jax

    jax.config.update("jax_enable_x64", True)
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny shapes, few splits (CI smoke run)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=args.quick)
