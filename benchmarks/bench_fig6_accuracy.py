"""Paper Fig. 6: accuracy vs exponent-distribution width phi.

INT8x{9,11,13} + DGEMM + naive-FP32, errors vs the double-double oracle
(Eq. 7), for phi in {0.1, 1, 2, 4}. CPU x64 provides the real-FP64 DGEMM
the paper compares against (TPU itself has no FP64 — DESIGN.md §2).
"""
import jax.numpy as jnp
import numpy as np

from repro.core.ozaki import (OzakiConfig, dgemm_f64, gemm_fp32_pass,
                              ozaki_matmul)
from repro.core.xmath import dd_matmul_np, rel_error_vs_dd

from .common import emit, phi_matrix, time_fn


def run(n: int = 96, k: int = 192):
    rng = np.random.default_rng(0)
    for phi in (0.1, 1.0, 2.0, 4.0):
        a = jnp.asarray(phi_matrix(rng, n, k, phi))
        b = jnp.asarray(phi_matrix(rng, k, n, phi))
        hi, lo = dd_matmul_np(np.asarray(a), np.asarray(b))

        def err(c):
            return float(np.mean(rel_error_vs_dd(np.asarray(c), hi, lo)))

        for s in (9, 11, 13):
            cfg = OzakiConfig(num_splits=s)
            us = time_fn(lambda aa=a, bb=b, c=cfg: ozaki_matmul(aa, bb, c))
            emit(f"fig6/INT8x{s}/phi={phi}", us,
                 f"mean_rel_err={err(ozaki_matmul(a, b, cfg)):.3e}")
        emit(f"fig6/DGEMM/phi={phi}", time_fn(dgemm_f64, a, b),
             f"mean_rel_err={err(dgemm_f64(a, b)):.3e}")
        emit(f"fig6/FP32/phi={phi}", time_fn(gemm_fp32_pass, a, b),
             f"mean_rel_err={err(gemm_fp32_pass(a, b)):.3e}")


if __name__ == "__main__":
    import argparse

    import jax

    from .common import CSV_HEADER, add_plan_args, configure_from_args

    jax.config.update("jax_enable_x64", True)
    ap = argparse.ArgumentParser()
    add_plan_args(ap)
    configure_from_args(ap.parse_args())
    print(CSV_HEADER)
    run()
