"""Paper Fig. 6: accuracy vs exponent-distribution width phi — plus the
fast-mode error-vs-pairs sweep (accuracy-adaptive planning).

INT8x{9,11,13} + DGEMM + naive-FP32, errors vs the double-double oracle
(Eq. 7), for phi in {0.1, 1, 2, 4}. CPU x64 provides the real-FP64 DGEMM
the paper compares against (TPU itself has no FP64 — DESIGN.md §2).

``run_fast`` reproduces the follow-up literature's accuracy/throughput
trade-off (arXiv:2409.13313 fast mode; arXiv:2506.11277 bounds): at a
fixed s it sweeps the kept-pair budget, emitting for every row the
modeled GEMM work, the guaranteed error bound, and the MEASURED scaled
error — and asserts the bound holds, so the CSV is a proof artifact.
``--fast-sweep`` runs only that sweep (the nightly CI job uploads its
CSV alongside the tuned plans).
"""
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.accuracy import (accum_floor, pair_budget_for, scaled_error,
                                 truncation_eta)
from repro.core.ozaki import (OzakiConfig, dgemm_f64, gemm_fp32_pass,
                              ozaki_matmul, resolve_accuracy_config)
from repro.core.tuning import diagonal_groups
from repro.core.xmath import dd_matmul_np, rel_error_vs_dd

from .common import emit, phi_matrix, time_fn


def run(n: int = 96, k: int = 192):
    rng = np.random.default_rng(0)
    for phi in (0.1, 1.0, 2.0, 4.0):
        a = jnp.asarray(phi_matrix(rng, n, k, phi))
        b = jnp.asarray(phi_matrix(rng, k, n, phi))
        hi, lo = dd_matmul_np(np.asarray(a), np.asarray(b))

        def err(c):
            return float(np.mean(rel_error_vs_dd(np.asarray(c), hi, lo)))

        for s in (9, 11, 13):
            cfg = OzakiConfig(num_splits=s)
            us = time_fn(lambda aa=a, bb=b, c=cfg: ozaki_matmul(aa, bb, c))
            emit(f"fig6/INT8x{s}/phi={phi}", us,
                 f"mean_rel_err={err(ozaki_matmul(a, b, cfg)):.3e}")
        emit(f"fig6/DGEMM/phi={phi}", time_fn(dgemm_f64, a, b),
             f"mean_rel_err={err(dgemm_f64(a, b)):.3e}")
        emit(f"fig6/FP32/phi={phi}", time_fn(gemm_fp32_pass, a, b),
             f"mean_rel_err={err(gemm_fp32_pass(a, b)):.3e}")
    run_fast(n=n, k=k)


def run_fast(n: int = 96, k: int = 192, num_splits: int = 9,
             quick: bool = False):
    """Error-vs-pairs sweep at fixed s, plus target-driven resolution rows.

    Every row's ``bound_ok`` field is asserted: the measured scaled error
    ``max |C - C_dd| / 2^{ea+eb}`` must meet the guaranteed bound
    ``k * eta + accum_floor`` of its pair budget (and, for the
    target-driven rows, the configured ``target_error`` plus the floor).
    """
    if quick:
        n, k, num_splits = 48, 96, 5
    rng = np.random.default_rng(4)
    a_np = phi_matrix(rng, n, k, 1.0)
    b_np = phi_matrix(rng, k, n, 1.0)
    a, b = jnp.asarray(a_np), jnp.asarray(b_np)
    hi, lo = dd_matmul_np(a_np, b_np)
    s = num_splits
    cfg0 = OzakiConfig(num_splits=s)
    w = cfg0.width_for(k)
    full_gemms = cfg0.num_gemms

    def one(policy: str):
        cfg = dataclasses.replace(cfg0, pair_policy=policy)
        us = time_fn(lambda: ozaki_matmul(a, b, cfg))
        c = np.asarray(ozaki_matmul(a, b, cfg))
        eta_k = k * truncation_eta(s, w, pair_policy=policy)
        floor = accum_floor(s, k, pair_policy=policy)
        serr = scaled_error(c, hi, a_np, b_np, ref_lo=lo)
        gemms = cfg.num_gemms
        ok = serr <= eta_k + floor
        assert ok, (policy, serr, eta_k, floor)
        emit(f"fig6fast/INT8x{s}/pairs={gemms}", us,
             f"policy={policy};gemms={gemms};gemms_full={full_gemms};"
             f"modeled_gemm_flops={2.0 * n * n * k * gemms:.3e};"
             f"eta_bound={eta_k:.3e};accum_floor={floor:.3e};"
             f"scaled_err={serr:.3e};bound_ok={ok}",
             plan=cfg.plan())
        return gemms

    # whole-diagonal budgets: the natural error-vs-work ladder
    budgets, seen = ["full"], 0
    for _, pairs in diagonal_groups(s)[:-1]:
        seen += len(pairs)
        budgets.append(f"budget:{seen}")
    trimmed = [one(p) for p in reversed(budgets)]
    assert trimmed[-1] == full_gemms and min(trimmed) < full_gemms

    # target-driven rows: the planner picks the budget, the CSV proves it
    # (targets sit above the configured s ceiling's guaranteed bound, so
    # ``serr <= target + floor`` is a theorem, not an observation)
    for tgt in (1e-4, 1e-6) if quick else (1e-4, 1e-8, 1e-12):
        cfg = OzakiConfig(num_splits=s, target_error=tgt, fast_mode=True)
        res = resolve_accuracy_config(cfg, k)
        us = time_fn(lambda: ozaki_matmul(a, b, cfg))
        c = np.asarray(ozaki_matmul(a, b, cfg))
        floor = accum_floor(res.num_splits, k, pair_policy=res.pair_policy)
        serr = scaled_error(c, hi, a_np, b_np, ref_lo=lo)
        ok = serr <= tgt + floor
        assert ok, (tgt, serr, floor)
        emit(f"fig6fast/target={tgt}", us,
             f"resolved_splits={res.num_splits};policy={res.pair_policy};"
             f"gemms={res.num_gemms};gemms_full_s{s}={full_gemms};"
             f"accum_floor={floor:.3e};scaled_err={serr:.3e};bound_ok={ok}",
             plan=res.plan())
    # fast-mode pair budget meets the bound on the Pallas pair GRID too
    # (the truncated pair list is a grid dimension, not a mask): bitwise
    # equal to the xla pipeline under the same policy.
    policy = pair_budget_for(1e-8, s, w, k)
    cfg_x = dataclasses.replace(cfg0, pair_policy=policy)
    cfg_e = dataclasses.replace(cfg_x, backend="pallas_fused",
                                fuse_epilogue=True)
    bitwise = np.array_equal(np.asarray(ozaki_matmul(a, b, cfg_e)),
                             np.asarray(ozaki_matmul(a, b, cfg_x)))
    assert bitwise
    emit(f"fig6fast/grid_parity/{policy}", 0.0,
         f"epilogue_bitwise_equal_xla={bitwise}", plan=cfg_e.plan())


if __name__ == "__main__":
    import argparse

    import jax

    from .common import CSV_HEADER, add_plan_args, configure_from_args

    jax.config.update("jax_enable_x64", True)
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast-sweep", action="store_true",
                    help="run only the fast-mode error-vs-pairs sweep "
                         "(accuracy CSV artifact)")
    ap.add_argument("--quick", action="store_true",
                    help="tiny shapes (CI smoke)")
    add_plan_args(ap)
    args = ap.parse_args()
    configure_from_args(args)
    print(CSV_HEADER)
    if args.fast_sweep:
        run_fast(quick=args.quick)
    else:
        run()
