"""End-to-end serving decode latency: ozaki_fp64 + pallas_fused vs bf16.

The serving claim of the Ozaki scheme is that FP64-accurate projections
can ride the int8 MXU path at deployment time. This benchmark drives the
REAL serving engine (continuous batching, slot admission, jitted batched
decode) through full request lifecycles and reports per-tick decode
latency for

  * ``bf16``                       — the TPU-native baseline policy,
  * ``ozaki_fp64 + pallas_fused``  — the paper's path on the stage-fused
                                     kernel pipeline,
  * ``ozaki_fp64 + epilogue``      — the epilogue-fused GEMM+accumulate
                                     pipeline (int32 products stay in
                                     VMEM).

Every dense projection in the decode step is a ``(slots, 1, k) @ (k, n)``
broadcast-weights matmul, i.e. ``ozaki_matmul_batched``'s rows layout —
one set of slice GEMMs per projection for the whole batch. CPU interpret
mode makes the absolute numbers indicative only (the kernels lower to
Mosaic unchanged on TPU); the per-tick latency RATIO and the engine
overhead split are the portable signal.
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_model
from repro.serving.engine import Request, ServingEngine

from .common import CONTEXT, emit

VARIANTS = {
    "bf16": dict(matmul_precision="bf16"),
    "ozaki_fused": dict(matmul_precision="ozaki_fp64",
                        ozaki_backend="pallas_fused"),
    "ozaki_epilogue": dict(matmul_precision="ozaki_fp64",
                           ozaki_backend="pallas_fused",
                           ozaki_fuse_epilogue=True),
}


def _drive(cfg, params, overrides, *, num_slots: int, new_tokens: int,
           prompts) -> dict:
    # the run-wide plan context reaches the engine: pre-warmed (and, with
    # --autotune, measured) projection plans apply to every decode tick
    engine = ServingEngine(cfg, params, num_slots=num_slots, max_len=64,
                           plan_cache=CONTEXT.plan_cache,
                           autotune_plans=CONTEXT.autotune, **overrides)
    for rid, prompt in enumerate(prompts):
        engine.submit(Request(rid=rid, prompt=prompt,
                              max_new_tokens=new_tokens))
    engine.step()                       # admission + warmup (jit compile)
    ticks = []
    while engine.waiting or any(r is not None for r in engine.slot_req):
        t0 = time.perf_counter()
        engine.step()
        ticks.append((time.perf_counter() - t0) * 1e6)
        if len(ticks) > 10_000:
            raise TimeoutError("engine did not drain")
    done = sorted(engine.finished, key=lambda r: r.rid)
    return {"tick_us": float(np.median(ticks)) if ticks else 0.0,
            "ticks": len(ticks),
            "tokens": [r.generated for r in done]}


def run(arch: str = "llama3.2-3b", quick: bool = False):
    cfg = get_config(arch).reduced()
    new_tokens = 4 if quick else 8
    num_slots = 2
    rng = np.random.default_rng(11)
    params, _ = init_model(cfg, jax.random.key(0))
    prompts = [rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
               for _ in range(3)]           # 3 requests, 2 slots: one queues
    results = {}
    for name, overrides in VARIANTS.items():
        if quick and name == "ozaki_fused":
            continue                        # CI smoke: baseline + epilogue
        r = _drive(cfg, params, overrides, num_slots=num_slots,
                   new_tokens=new_tokens, prompts=prompts)
        results[name] = r
        emit(f"serve_latency/{name}/slots={num_slots}", r["tick_us"],
             f"decode_ticks={r['ticks']};new_tokens={new_tokens}")
    if "bf16" in results:
        base = results["bf16"]["tick_us"] or 1.0
        for name, r in results.items():
            if name == "bf16":
                continue
            emit(f"serve_latency/{name}_vs_bf16", 0.0,
                 f"tick_ratio={r['tick_us'] / base:.2f}x")
    return results


if __name__ == "__main__":
    import argparse

    from .common import CSV_HEADER, add_plan_args, configure_from_args

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer tokens/variants (CI smoke run)")
    add_plan_args(ap)
    args = ap.parse_args()
    configure_from_args(args)
    print(CSV_HEADER)
    run(quick=args.quick)
