"""Benchmark aggregator: one section per paper figure/table.

``PYTHONPATH=src python -m benchmarks.run`` prints
``name,us_per_call,derived,plan,policy`` CSV rows for every benchmark;
section mapping lives in DESIGN.md §5 and EXPERIMENTS.md.

``--policy SPEC`` pins a run-wide ``repro.api.MatmulPolicy`` (one front
door for backend/fusion/splits/target/fast-mode; a spec naming
``|cache=PATH`` / ``|autotune`` maps onto the same machinery as the
dedicated flags below) and the resolved spec string is recorded in the
``policy`` column of every row. ``--plan-cache PATH`` routes every
planned GEMM through a persistent ``core.autotune.PlanCache`` and
``--autotune`` measures candidates on misses — the chosen plan lands in
the ``plan`` CSV column of each row it applies to, so perf numbers are
reproducible from the row alone. The flags reach every registered
benchmark through ``common.CONTEXT``.
"""
import argparse

import jax

jax.config.update("jax_enable_x64", True)   # FP64 oracle + DGEMM baseline


def main(argv=None) -> None:
    from . import common

    ap = argparse.ArgumentParser(description=__doc__)
    common.add_plan_args(ap)
    args = ap.parse_args(argv)
    common.configure_from_args(args)

    print(common.CSV_HEADER)
    from . import (bench_distributed, bench_fig4_analytic,
                   bench_fig6_accuracy, bench_fig7_zerocancel,
                   bench_fig8_throughput, bench_fused_pipeline,
                   bench_quantum_sim, bench_scheme2, bench_serve_latency)
    bench_fig4_analytic.run()
    bench_fig6_accuracy.run()
    bench_fig7_zerocancel.run()
    bench_fig8_throughput.run()
    bench_fused_pipeline.run()
    bench_quantum_sim.run()
    bench_scheme2.run()
    bench_serve_latency.run()
    bench_distributed.run()
    if common.CONTEXT.plan_cache is not None:
        common.CONTEXT.plan_cache.save()


if __name__ == "__main__":
    main()
