"""Benchmark aggregator: one section per paper figure/table.

``PYTHONPATH=src python -m benchmarks.run`` prints
``name,us_per_call,derived`` CSV rows for every benchmark; section
mapping lives in DESIGN.md §5 and EXPERIMENTS.md.
"""
import jax

jax.config.update("jax_enable_x64", True)   # FP64 oracle + DGEMM baseline


def main() -> None:
    print("name,us_per_call,derived")
    from . import (bench_fig4_analytic, bench_fig6_accuracy,
                   bench_fig7_zerocancel, bench_fig8_throughput,
                   bench_fused_pipeline, bench_quantum_sim,
                   bench_serve_latency)
    bench_fig4_analytic.run()
    bench_fig6_accuracy.run()
    bench_fig7_zerocancel.run()
    bench_fig8_throughput.run()
    bench_fused_pipeline.run()
    bench_quantum_sim.run()
    bench_serve_latency.run()


if __name__ == "__main__":
    main()
