"""Shared benchmark utilities: timing, CSV emission, input generators,
and the policy/plan-cache/autotune context every registered benchmark
runs in.

CSV schema: ``name,us_per_call,derived,plan,policy`` — ``plan`` is the
chosen ``PipelinePlan`` as JSON (CSV-quoted; empty for rows that plan
nothing) and ``policy`` is the run's resolved ``MatmulPolicy`` spec
string (empty when the run pinned no policy), so any perf row can be
reproduced from its exact launch parameters AND its precision operating
point.

``benchmarks.run`` (and each benchmark's ``__main__``) parses
``--policy SPEC`` / ``--plan-cache PATH`` / ``--autotune`` into the
module-level ``CONTEXT``; a ``--policy`` naming a cache path or
``|autotune`` maps onto the same machinery as the dedicated flags.
Benchmarks call ``plan_gemm`` to resolve plans through it, so the same
flags reach every registered benchmark without threading arguments.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import TYPE_CHECKING, Optional

import jax
import numpy as np

if TYPE_CHECKING:                      # deferred: repro imports stay lazy
    from repro.api import MatmulPolicy
    from repro.core.autotune import PlanCache

ROWS = []

CSV_HEADER = "name,us_per_call,derived,plan,policy"


@dataclasses.dataclass
class BenchContext:
    """Plan/policy resolution shared by all benchmarks in one run."""

    plan_cache: Optional["PlanCache"] = None    # core.autotune.PlanCache
    autotune: bool = False
    policy: Optional["MatmulPolicy"] = None     # repro.api.MatmulPolicy


CONTEXT = BenchContext()


def configure(plan_cache_path: Optional[str] = None,
              autotune: bool = False,
              policy: Optional[str] = None) -> BenchContext:
    """Install the run-wide context (--policy/--plan-cache/--autotune).

    A ``--policy`` spec naming a plan cache (``|cache=PATH``) or
    ``|autotune`` feeds the SAME plan-cache/autotune machinery as the
    dedicated flags (the dedicated flags win when both are given).
    """
    from repro.core.autotune import PlanCache
    pol = None
    if policy is not None:
        from repro.api import MatmulPolicy
        pol = MatmulPolicy.of(policy)
        plan_cache_path = plan_cache_path or pol.plan_cache
        autotune = autotune or pol.autotune
    CONTEXT.policy = pol
    CONTEXT.plan_cache = (PlanCache.load(plan_cache_path)
                          if plan_cache_path else None)
    CONTEXT.autotune = autotune
    return CONTEXT


def add_plan_args(ap) -> None:
    """The shared --policy/--plan-cache/--autotune argparse surface."""
    ap.add_argument("--policy", metavar="SPEC", default=None,
                    help="matmul policy spec (repro.api.MatmulPolicy, "
                         "e.g. 'ozaki-fp64@1e-25:fast/pallas_fused"
                         "+epilogue|cache=plans.json|autotune') applied "
                         "to every planned GEMM and recorded per CSV row")
    ap.add_argument("--plan-cache", metavar="PATH", default=None,
                    help="persistent PlanCache JSON consulted (and, with "
                         "--autotune, populated) for every planned GEMM")
    ap.add_argument("--autotune", action="store_true",
                    help="measure candidate plans on plan-cache misses "
                         "instead of using the analytic plan")


def configure_from_args(args) -> BenchContext:
    return configure(plan_cache_path=args.plan_cache,
                     autotune=args.autotune,
                     policy=getattr(args, "policy", None))


def policy_spec() -> str:
    """The run's resolved policy spec string ("" without --policy)."""
    return CONTEXT.policy.spec() if CONTEXT.policy is not None else ""


def plan_gemm(m: int, n: int, k: int, **kwargs):
    """Resolve a PipelinePlan through the run's plan context.

    Analytic when no cache/autotune is configured; cache hits return
    without re-tuning; misses autotune when --autotune was passed (the
    winner is persisted to the cache file immediately). A run-wide
    --policy seeds the planner's precision knobs (backend, fusion,
    splits, target, fast mode, pair policy) — explicit kwargs win.
    """
    from repro.core.tuning import select_pipeline_plan
    pol = CONTEXT.policy
    if pol is not None and pol.scheme == "ozaki2_fp64":
        kwargs.setdefault("scheme", "ozaki2_fp64")
        kwargs.setdefault("backend", pol.backend)
        kwargs.setdefault("accum", "f64")
        if pol.num_splits is not None:        # the xL modulus-count dial
            kwargs.setdefault("num_moduli", pol.num_splits)
        if pol.target_error is not None:
            kwargs.setdefault("target_error", pol.target_error)
    elif pol is not None and pol.scheme == "ozaki_fp64":
        kwargs.setdefault("backend", pol.backend)
        kwargs.setdefault("fuse_epilogue", pol.fuse_epilogue)
        kwargs.setdefault("streaming", pol.streaming)
        if pol.num_splits is not None:
            kwargs.setdefault("num_splits", pol.num_splits)
        if pol.target_error is not None:
            kwargs.setdefault("target_error", pol.target_error)
        if pol.fast_mode:
            kwargs.setdefault("fast_mode", True)
        if pol.pair_policy != "full":
            kwargs.setdefault("pair_policy", pol.pair_policy)
        if pol.shard_axis is not None:
            kwargs.setdefault("shard_axis", pol.shard_axis)
        if pol.comm != "f64":
            kwargs.setdefault("comm", pol.comm)
    return select_pipeline_plan(m, n, k, cache=CONTEXT.plan_cache,
                                autotune=CONTEXT.autotune, **kwargs)


def _csv_field(s: str) -> str:
    if any(ch in s for ch in ",\"\n"):
        return '"' + s.replace('"', '""') + '"'
    return s


def plan_json(plan) -> str:
    return json.dumps(plan.to_dict(), sort_keys=True) if plan else ""


def emit(name: str, us_per_call: float, derived: str = "", plan=None):
    pj = plan_json(plan)
    spec = policy_spec()
    ROWS.append((name, us_per_call, derived, pj, spec))
    print(f"{name},{us_per_call:.1f},{derived},{_csv_field(pj)},"
          f"{_csv_field(spec)}", flush=True)


# versioned measured-run persistence (the BENCH_*.json CI artifacts):
# like the plan cache, the wire format carries a version plus the two
# facts a consumer needs to trust a number — the device it ran on and
# whether the kernels ran in Pallas interpret mode (CPU emulation
# timings rank, they don't predict hardware).
BENCH_JSON_VERSION = 1


def write_bench_json(path: str, rows: list, **meta) -> str:
    """Persist measured benchmark rows as versioned JSON.

    ``rows`` is a list of JSON-ready dicts; ``meta`` keys (e.g.
    ``device_kind=...``, ``interpret=...``) ride at the top level next
    to ``version``.
    """
    payload = {"version": BENCH_JSON_VERSION, **meta, "rows": rows}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    return path


def time_fn(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time in microseconds (results block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def phi_matrix(rng, m, k, phi) -> np.ndarray:
    """Paper Eq. (6) input generator."""
    return (rng.uniform(-0.5, 0.5, (m, k))
            * np.exp(phi * rng.standard_normal((m, k))))
