"""Shared benchmark utilities: timing, CSV emission, input generators,
and the plan-cache/autotune context every registered benchmark runs in.

CSV schema: ``name,us_per_call,derived,plan`` — ``plan`` is the chosen
``PipelinePlan`` as JSON (CSV-quoted; empty for rows that plan nothing),
so any perf row can be reproduced from its exact launch parameters.

``benchmarks.run`` (and each benchmark's ``__main__``) parses
``--plan-cache PATH`` / ``--autotune`` into the module-level ``CONTEXT``;
benchmarks call ``plan_gemm`` to resolve plans through it, so the same
flags reach every registered benchmark without threading arguments.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import TYPE_CHECKING, Optional

import jax
import numpy as np

if TYPE_CHECKING:                      # deferred: repro imports stay lazy
    from repro.core.autotune import PlanCache

ROWS = []

CSV_HEADER = "name,us_per_call,derived,plan"


@dataclasses.dataclass
class BenchContext:
    """Plan resolution policy shared by all benchmarks in one run."""

    plan_cache: Optional["PlanCache"] = None    # core.autotune.PlanCache
    autotune: bool = False


CONTEXT = BenchContext()


def configure(plan_cache_path: Optional[str] = None,
              autotune: bool = False) -> BenchContext:
    """Install the run-wide plan context (from --plan-cache/--autotune)."""
    from repro.core.autotune import PlanCache
    CONTEXT.plan_cache = (PlanCache.load(plan_cache_path)
                          if plan_cache_path else None)
    CONTEXT.autotune = autotune
    return CONTEXT


def add_plan_args(ap) -> None:
    """The shared --plan-cache/--autotune argparse surface."""
    ap.add_argument("--plan-cache", metavar="PATH", default=None,
                    help="persistent PlanCache JSON consulted (and, with "
                         "--autotune, populated) for every planned GEMM")
    ap.add_argument("--autotune", action="store_true",
                    help="measure candidate plans on plan-cache misses "
                         "instead of using the analytic plan")


def configure_from_args(args) -> BenchContext:
    return configure(plan_cache_path=args.plan_cache,
                     autotune=args.autotune)


def plan_gemm(m: int, n: int, k: int, **kwargs):
    """Resolve a PipelinePlan through the run's plan context.

    Analytic when no cache/autotune is configured; cache hits return
    without re-tuning; misses autotune when --autotune was passed (the
    winner is persisted to the cache file immediately).
    """
    from repro.core.tuning import select_pipeline_plan
    return select_pipeline_plan(m, n, k, cache=CONTEXT.plan_cache,
                                autotune=CONTEXT.autotune, **kwargs)


def _csv_field(s: str) -> str:
    if any(ch in s for ch in ",\"\n"):
        return '"' + s.replace('"', '""') + '"'
    return s


def plan_json(plan) -> str:
    return json.dumps(plan.to_dict(), sort_keys=True) if plan else ""


def emit(name: str, us_per_call: float, derived: str = "", plan=None):
    pj = plan_json(plan)
    ROWS.append((name, us_per_call, derived, pj))
    print(f"{name},{us_per_call:.1f},{derived},{_csv_field(pj)}", flush=True)


def time_fn(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time in microseconds (results block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def phi_matrix(rng, m, k, phi) -> np.ndarray:
    """Paper Eq. (6) input generator."""
    return (rng.uniform(-0.5, 0.5, (m, k))
            * np.exp(phi * rng.standard_normal((m, k))))
