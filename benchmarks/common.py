"""Shared benchmark utilities: timing, CSV emission, input generators."""
from __future__ import annotations

import time

import jax
import numpy as np

ROWS = []


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def time_fn(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time in microseconds (results block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def phi_matrix(rng, m, k, phi) -> np.ndarray:
    """Paper Eq. (6) input generator."""
    return (rng.uniform(-0.5, 0.5, (m, k))
            * np.exp(phi * rng.standard_normal((m, k))))
