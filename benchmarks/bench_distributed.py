"""Distributed int8-slice transport benchmark: modeled link bytes per
device (``core.tuning.comm_bytes_model``) for every schedule x layout,
plus measured wall-clock of the collective schedules when the process
actually has a mesh (>= 2 devices — the CI smoke runs this module under
``--xla_force_host_platform_device_count=8``).

The headline claim (ISSUE 7 acceptance, asserted below): on a tall-k
k-sharded GEMM at the paper's s=9, shipping exact int32 anti-diagonal
partials instead of letting GSPMD all-gather the f64 operands moves
**>= 6x fewer bytes per device** (psum schedule; reduce-scatter doubles
the win again by leaving C column-sharded). The m/n-shard SliceWire
gather is also tabled — honestly: at s bytes/element it only beats the
8-byte f64 gather for s < 8, i.e. ``target_error``-reduced split counts.

Every measured row is verified bitwise against the single-device
reference before timing (a perf row for a wrong result is worthless).
Rows persist to ``BENCH_distributed.json`` via ``common.write_bench_json``
next to the CSV stream.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ozaki import OzakiConfig, ozaki_matmul
from repro.core.tuning import comm_bytes_model

from .common import emit, phi_matrix, plan_gemm, time_fn, write_bench_json

# the k-shard collective schedules x the transport they use
_KSHARD_ROWS = [("f64", "psum"),            # GSPMD operand-gather baseline
                ("int8", "psum"),
                ("int8", "overlap"),
                ("int8", "reduce_scatter"),
                ("int8", "rs_stream")]


def _model_table(m, n, k, s, world, bench_rows):
    """Emit the comm-bytes columns for one shape; returns f64/int8 ratios."""
    totals = {}
    for comm, sched in _KSHARD_ROWS:
        c = comm_bytes_model(m, n, k, num_splits=s, world=world,
                             layout="kshard", comm=comm, schedule=sched)
        totals[(comm, sched)] = c["total"]
        emit(f"distributed/model/kshard/{comm}/{sched}/"
             f"m={m}/n={n}/k={k}/s={s}/world={world}", 0.0,
             f"comm_bytes_total={c['total']:.0f};"
             f"comm_bytes_operands={c['operands']:.0f};"
             f"comm_bytes_partials={c['partials']:.0f};"
             f"comm_bytes_exponents={c['exponents']:.0f}")
        bench_rows.append({"kind": "model", "layout": "kshard",
                           "comm": comm, "schedule": sched, "m": m, "n": n,
                           "k": k, "num_splits": s, "world": world,
                           "comm_bytes": c})
    for comm, sched in (("f64", "allgather"), ("int8", "allgather")):
        c = comm_bytes_model(m, n, k, num_splits=s, world=world,
                             layout="mnshard", comm=comm, schedule=sched)
        emit(f"distributed/model/mnshard/{comm}/m={m}/n={n}/k={k}/s={s}",
             0.0, f"comm_bytes_total={c['total']:.0f};"
                  f"comm_bytes_slices={c['slices']:.0f};"
                  f"comm_bytes_operands={c['operands']:.0f}")
        bench_rows.append({"kind": "model", "layout": "mnshard",
                           "comm": comm, "schedule": sched, "m": m, "n": n,
                           "k": k, "num_splits": s, "world": world,
                           "comm_bytes": c})
    base = totals[("f64", "psum")]
    return {sched: base / totals[("int8", sched)]
            for _, sched in _KSHARD_ROWS[1:]}


def _ratios_for(m, n, k, s, world):
    """f64-baseline/int8 byte ratios per k-shard schedule (no emission)."""
    def total(comm, sched):
        return comm_bytes_model(m, n, k, num_splits=s, world=world,
                                layout="kshard", comm=comm,
                                schedule=sched)["total"]
    base = total("f64", "psum")
    return {sched: base / total("int8", sched)
            for _, sched in _KSHARD_ROWS[1:]}


def run(quick: bool = False):
    world = 8
    s = 9
    shapes = [(64, 64, 2048)] if quick else [(256, 256, 8192),
                                             (128, 128, 4096),
                                             (512, 64, 2048)]
    bench_rows = []
    for m, n, k in shapes:
        _model_table(m, n, k, s, world, bench_rows)
    # ISSUE 7 acceptance: >= 6x fewer bytes for int8 vs the f64 operand
    # gather at s=9 on the canonical tall-k shape (model-only, so it runs
    # in quick mode too), asserted AND printed. The (512, 64, 2048) row
    # above shows the flip side: squat shapes with big m*n amortize worse.
    ratios = _model_table(256, 256, 8192, s, world, bench_rows) \
        if (256, 256, 8192) not in shapes else \
        _ratios_for(256, 256, 8192, s, world)
    assert ratios["psum"] >= 6.0, ratios
    assert ratios["reduce_scatter"] >= 6.0, ratios
    emit("distributed/model/int8_vs_f64", 0.0,
         f"ratio_psum={ratios['psum']:.2f}x;"
         f"ratio_overlap={ratios['overlap']:.2f}x;"
         f"ratio_reduce_scatter={ratios['reduce_scatter']:.2f}x;"
         f"ratio_rs_stream={ratios['rs_stream']:.2f}x;"
         f"acceptance_ge_6x=True")
    bench_rows.append({"kind": "acceptance", "num_splits": s,
                       "world": world, "int8_vs_f64_ratios": ratios})

    # honest mnshard crossover: the SliceWire gather wins only for s < 8
    for sw, wins in ((5, True), (9, False)):
        f64 = comm_bytes_model(256, 256, 4096, num_splits=sw, world=world,
                               layout="mnshard", comm="f64")
        i8 = comm_bytes_model(256, 256, 4096, num_splits=sw, world=world,
                              layout="mnshard", comm="int8",
                              schedule="allgather")
        assert (i8["total"] < f64["total"]) == wins
        emit(f"distributed/model/mnshard_crossover/s={sw}", 0.0,
             f"int8_bytes={i8['total']:.0f};f64_bytes={f64['total']:.0f};"
             f"int8_wins={wins}")

    # measured schedules — only meaningful with a real mesh in-process
    # (the CI smoke runs this module under 8 forced host devices; the
    # aggregator's single-device run skips cleanly)
    if jax.device_count() < 2:
        emit("distributed/measured/skipped", 0.0,
             f"device_count={jax.device_count()};need>=2")
        write_bench_json("BENCH_distributed.json", bench_rows,
                         device_kind=jax.devices()[0].device_kind,
                         device_count=jax.device_count(),
                         int8_vs_f64_ratios=ratios)
        return

    from repro.launch.mesh import make_mesh_compat
    from repro.parallel.ozaki_shard import (distributed_ozaki_matmul,
                                            ozaki_matmul_kshard_auto,
                                            ozaki_matmul_mnshard)
    mworld = jax.device_count()
    mesh = make_mesh_compat((1, mworld), ("data", "model"))
    rng = np.random.default_rng(13)
    mm, nn, kk = (32, 32, 512) if quick else (64, 64, 2048)
    sm = 5 if quick else s
    a = jnp.asarray(phi_matrix(rng, mm, kk, 1.0))
    b = jnp.asarray(phi_matrix(rng, kk, nn, 0.0))
    cfg = OzakiConfig(num_splits=sm)
    ref = np.asarray(ozaki_matmul(a, b, cfg))
    plan = plan_gemm(mm, nn, kk, num_splits=sm, accum="f64", backend="xla",
                     shard_axis="model", comm="int8")

    # GSPMD f64-operand baseline (what comm="f64" costs end to end)
    us = time_fn(lambda: ozaki_matmul_kshard_auto(a, b, mesh, cfg,
                                                  axis="model"))
    emit(f"distributed/measured/kshard/f64/gspmd/k={kk}", us,
         f"world={mworld}", plan=None)
    bench_rows.append({"kind": "measured", "layout": "kshard",
                       "comm": "f64", "schedule": "gspmd", "k": kk,
                       "num_splits": sm, "world": mworld,
                       "us_per_call": us})
    for sched in ("psum", "overlap", "reduce_scatter", "rs_stream"):
        got = np.asarray(distributed_ozaki_matmul(a, b, mesh, cfg,
                                                  schedule=sched))
        assert np.array_equal(got, ref), f"{sched} not bitwise"
        us = time_fn(lambda sc=sched: distributed_ozaki_matmul(
            a, b, mesh, cfg, schedule=sc))
        emit(f"distributed/measured/kshard/int8/{sched}/k={kk}", us,
             f"world={mworld};bitwise_equal_single_device=True", plan=plan)
        bench_rows.append({"kind": "measured", "layout": "kshard",
                           "comm": "int8", "schedule": sched, "k": kk,
                           "num_splits": sm, "world": mworld,
                           "us_per_call": us, "bitwise": True})
    for sched in ("allgather", "overlap"):
        got = np.asarray(ozaki_matmul_mnshard(a, b, mesh, cfg,
                                              schedule=sched))
        assert np.array_equal(got, ref), f"mnshard/{sched} not bitwise"
        us = time_fn(lambda sc=sched: ozaki_matmul_mnshard(
            a, b, mesh, cfg, schedule=sc))
        emit(f"distributed/measured/mnshard/int8/{sched}/k={kk}", us,
             f"world={mworld};bitwise_equal_single_device=True", plan=plan)
        bench_rows.append({"kind": "measured", "layout": "mnshard",
                           "comm": "int8", "schedule": sched, "k": kk,
                           "num_splits": sm, "world": mworld,
                           "us_per_call": us, "bitwise": True})

    write_bench_json("BENCH_distributed.json", bench_rows,
                     device_kind=jax.devices()[0].device_kind,
                     device_count=jax.device_count(),
                     int8_vs_f64_ratios=ratios)


if __name__ == "__main__":
    import argparse

    from .common import CSV_HEADER, add_plan_args, configure_from_args

    jax.config.update("jax_enable_x64", True)
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny shapes, few splits (CI smoke run)")
    add_plan_args(ap)
    args = ap.parse_args()
    configure_from_args(args)
    print(CSV_HEADER)
    run(quick=args.quick)
