"""Test session config.

x64 is enabled for the FP64 oracle paths (the paper targets DGEMM).
NOTE: do NOT set XLA_FLAGS device-count here — smoke tests must see one
device; multi-device behaviour is tested through subprocesses
(tests/util.py) and the dry-run launcher sets its own flag.
"""
import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _fresh_downgrade_warn_latch():
    """Per-test fresh-process semantics for the fuse_epilogue downgrade
    warn-once latch: without the reset, the first test that trips the
    warning latches module state and every later test sees silence."""
    from repro.core.tuning import reset_downgrade_warnings
    reset_downgrade_warnings()
    yield
    reset_downgrade_warnings()
