"""Test session config.

x64 is enabled for the FP64 oracle paths (the paper targets DGEMM).
NOTE: do NOT set XLA_FLAGS device-count here — smoke tests must see one
device; multi-device behaviour is tested through subprocesses
(tests/util.py) and the dry-run launcher sets its own flag.
"""
import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _fresh_warn_once_latches():
    """Per-test fresh-process semantics for EVERY warn-once latch (the
    fuse_epilogue downgrade warning, the ArchConfig ozaki_* deprecation
    warning, and any future ``core.warn_once`` consumer): without the
    reset, the first test that trips a warning latches module state and
    every later test sees silence."""
    from repro.core.warn_once import reset_all_warn_latches
    reset_all_warn_latches()
    yield
    reset_all_warn_latches()
