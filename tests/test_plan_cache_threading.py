"""``api._PLAN_CACHE_MEMO`` regression tests (ISSUE 6 satellite).

The per-path PlanCache memo used to be an unbounded plain dict mutated
with no lock: a serving process cycling through many per-model cache
paths grew it forever, and two threads racing the check-then-insert
could interleave. The memo is now an LRU bounded at
``_PLAN_CACHE_MEMO_MAX`` entries, mutated only under
``_PLAN_CACHE_LOCK``.
"""
import json
import threading

import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core.autotune import PlanCache, plan_cache_key
from repro.core.tuning import select_pipeline_plan


def _seed_cache_file(path, m, n, k, num_splits):
    cache = PlanCache(path)
    cache.put(plan_cache_key(m, n, k, accum="f64"),
              select_pipeline_plan(m, n, k, accum="f64",
                                   num_splits=num_splits))
    cache.save()
    return str(path)


def test_plan_cache_memo_is_bounded(tmp_path):
    api._PLAN_CACHE_MEMO.clear()
    paths = [str(tmp_path / f"plans_{i}.json") for i in range(40)]
    for p in paths:
        api._load_plan_cache(p)          # missing files memoize as empty
    assert len(api._PLAN_CACHE_MEMO) <= api._PLAN_CACHE_MEMO_MAX
    # LRU: the most recently used paths are the survivors
    assert paths[-1] in api._PLAN_CACHE_MEMO
    assert paths[0] not in api._PLAN_CACHE_MEMO
    # a hit refreshes recency instead of reloading
    survivor = next(iter(api._PLAN_CACHE_MEMO))
    hit = api._load_plan_cache(survivor)
    assert api._load_plan_cache(survivor) is hit


def test_plan_cache_memo_reloads_on_file_change(tmp_path):
    """The mtime guard survives the LRU rewrite: a rewritten file must be
    re-read, an untouched one must stay memoized."""
    api._PLAN_CACHE_MEMO.clear()
    path = _seed_cache_file(tmp_path / "plans.json", 8, 16, 32, 5)
    first = api._load_plan_cache(path)
    assert api._load_plan_cache(path) is first
    data = json.loads(open(path).read())
    import os
    with open(path, "w") as f:
        json.dump(data, f)
    os.utime(path, ns=(1, 1))            # force a distinct mtime_ns
    second = api._load_plan_cache(path)
    assert second is not first


def test_matmul_two_threads_distinct_cache_paths(tmp_path, rng):
    """Two threads hammering ``repro.matmul`` under policies naming
    DISTINCT plan-cache paths: no race in the memo, every result bitwise
    equal to the single-threaded uncached run."""
    api._PLAN_CACHE_MEMO.clear()
    m, n, k = 16, 16, 48
    path_a = _seed_cache_file(tmp_path / "a.json", m, n, k, 5)
    path_b = _seed_cache_file(tmp_path / "b.json", m, n, k, 5)
    a = jnp.asarray(rng.standard_normal((m, k)))
    b = jnp.asarray(rng.standard_normal((k, n)))
    ref = np.asarray(api.matmul(a, b, "ozaki-fp64x5"))
    errors = []
    barrier = threading.Barrier(2)

    def worker(path):
        try:
            barrier.wait(timeout=30)
            for _ in range(8):
                got = api.matmul(a, b, f"ozaki-fp64x5|cache={path}")
                np.testing.assert_array_equal(np.asarray(got), ref)
        except Exception as e:                   # surfaced to the main thread
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(p,))
               for p in (path_a, path_b)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not errors, errors
    assert not any(t.is_alive() for t in threads)
    assert len(api._PLAN_CACHE_MEMO) <= api._PLAN_CACHE_MEMO_MAX


def test_load_plan_cache_concurrent_churn(tmp_path):
    """Many threads loading MANY distinct paths concurrently: the bound
    holds and no insert is lost mid-eviction (the original dict raced
    check-then-insert)."""
    api._PLAN_CACHE_MEMO.clear()
    errors = []
    barrier = threading.Barrier(4)

    def worker(tid):
        try:
            barrier.wait(timeout=30)
            for i in range(25):
                api._load_plan_cache(str(tmp_path / f"c{tid}_{i}.json"))
        except Exception as e:
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(t,)) for t in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert not errors, errors
    assert len(api._PLAN_CACHE_MEMO) <= api._PLAN_CACHE_MEMO_MAX
