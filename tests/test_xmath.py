"""Error-free transform exactness — verified against exact rationals."""
from fractions import Fraction

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis "
                           "(pip install -r requirements-test.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.xmath import (DW, dd_matmul_f64, dd_matmul_np, df32_from_f64,
                              df32_to_f64, dw_add, dw_mul, dw_to_single,
                              fast_two_sum, rel_error_vs_dd, two_prod,
                              two_sum, veltkamp_split)

# XLA:CPU flushes subnormals to zero -> keep magnitudes in normal range
finite = st.floats(allow_nan=False, allow_infinity=False,
                   allow_subnormal=False,
                   min_value=-1e30, max_value=1e30).filter(
                       lambda x: x == 0.0 or abs(x) > 1e-200)


@given(finite, finite)
@settings(max_examples=300, deadline=None)
def test_two_sum_exact(a, b):
    s, e = (np.asarray(x) for x in two_sum(jnp.float64(a), jnp.float64(b)))
    assert Fraction(float(s)) + Fraction(float(e)) == \
        Fraction(a) + Fraction(b)


prod_floats = st.floats(allow_nan=False, allow_infinity=False,
                        allow_subnormal=False, min_value=-1e100,
                        max_value=1e100).filter(
                            lambda x: x == 0.0 or abs(x) > 1e-120)


@given(prod_floats, prod_floats)
@settings(max_examples=300, deadline=None)
def test_two_prod_exact(a, b):
    p, e = (np.asarray(x) for x in two_prod(jnp.float64(a), jnp.float64(b)))
    if np.isfinite(p) and np.isfinite(e):
        assert Fraction(float(p)) + Fraction(float(e)) == \
            Fraction(a) * Fraction(b)


@given(finite)
@settings(max_examples=200, deadline=None)
def test_veltkamp_split_exact(a):
    hi, lo = (np.asarray(x) for x in veltkamp_split(jnp.float64(a)))
    assert float(hi) + float(lo) == a
    # halves fit in 26/27 bits -> their product is exact in f64
    assert float(np.float64(hi) * np.float64(hi)) == float(hi) ** 2


@given(finite, finite)
@settings(max_examples=200, deadline=None)
def test_fast_two_sum_when_ordered(a, b):
    if abs(a) < abs(b):
        a, b = b, a
    s, e = (np.asarray(x) for x in
            fast_two_sum(jnp.float64(a), jnp.float64(b)))
    assert Fraction(float(s)) + Fraction(float(e)) == \
        Fraction(a) + Fraction(b)


@given(finite, finite, finite, finite)
@settings(max_examples=100, deadline=None)
def test_dw_add_high_accuracy(a_hi, a_lo, b_hi, b_lo):
    # normalize into VALID double-word pairs first (|lo| <= ulp(hi)/2)
    ah, al = two_sum(jnp.float64(a_hi), jnp.float64(a_lo * 1e-18))
    bh, bl = two_sum(jnp.float64(b_hi), jnp.float64(b_lo * 1e-18))
    a = DW(ah, al)
    b = DW(bh, bl)
    out = dw_add(a, b)
    exact = (Fraction(float(a.hi)) + Fraction(float(a.lo))
             + Fraction(float(b.hi)) + Fraction(float(b.lo)))
    got = Fraction(float(out.hi)) + Fraction(float(out.lo))
    if exact != 0:
        rel = abs((got - exact) / exact)
        assert rel < Fraction(1, 2 ** 100)


def test_df32_roundtrip():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.uniform(-1, 1, 128))
    dw = df32_from_f64(x)
    back = np.asarray(df32_to_f64(dw))
    # 48-bit mantissa: relative error < 2^-47
    np.testing.assert_allclose(back, np.asarray(x), rtol=2 ** -47)


def test_dd_matmul_agrees_with_np_oracle(rng):
    a = jnp.asarray(rng.uniform(-1, 1, (17, 23)))
    b = jnp.asarray(rng.uniform(-1, 1, (23, 9)))
    dw = dd_matmul_f64(a, b)
    hi, lo = dd_matmul_np(np.asarray(a), np.asarray(b))
    # both are valid dd oracles; XLA vs numpy rounding paths may differ
    # in the last ulp of the compensated term
    np.testing.assert_allclose(np.asarray(dw.hi), hi, rtol=0, atol=5e-16)
    np.testing.assert_allclose(np.asarray(dw.hi) + np.asarray(dw.lo),
                               hi + lo, rtol=0, atol=5e-16)


def test_dd_matmul_beats_plain_f64(rng):
    # cancellation-heavy case: dd must be closer to the exact value.
    # numpy oracle: XLA:CPU contracts mul+add into FMA inside scans,
    # which perturbs Dekker's two_prod there (the jax dd path is still
    # <= plain-f64 error; the np oracle is the reference used by all
    # accuracy benchmarks).
    an_ = rng.uniform(-1, 1, (8, 64))
    bn_ = rng.uniform(-1, 1, (64, 8))
    hi_, lo_ = dd_matmul_np(an_, bn_)
    import collections
    dw = collections.namedtuple('R', 'hi lo')(hi_, lo_)
    a, b = jnp.asarray(an_), jnp.asarray(bn_)
    exact = np.zeros((8, 8), object)
    an, bn = np.asarray(a), np.asarray(b)
    for i in range(8):
        for j in range(8):
            exact[i, j] = sum(Fraction(an[i, t]) * Fraction(bn[t, j])
                              for t in range(64))
    dd_err = plain_err = 0.0
    plain = an @ bn
    for i in range(8):
        for j in range(8):
            got = Fraction(float(dw.hi[i, j])) + Fraction(float(dw.lo[i, j]))
            dd_err = max(dd_err, abs(float(got - exact[i, j])))
            plain_err = max(plain_err,
                            abs(float(Fraction(plain[i, j])
                                      - exact[i, j])))
    assert dd_err <= plain_err
    assert dd_err < 1e-20


def test_rel_error_vs_dd_zero_safe():
    c = np.array([[1.0, 0.0]])
    err = rel_error_vs_dd(c, np.array([[1.0, 0.0]]), np.zeros((1, 2)))
    assert np.all(err == 0)
