"""Multi-device behaviour (8 virtual CPU devices via subprocess)."""
import jax
import pytest

from util import run_multidevice


def test_distributed_ozaki_bitwise_reproducible():
    out = run_multidevice("""
import jax
jax.config.update('jax_enable_x64', True)
import numpy as np, jax.numpy as jnp
from repro.core.ozaki import OzakiConfig
from repro.launch.mesh import make_mesh_compat
from repro.parallel.ozaki_shard import distributed_ozaki_matmul
rng = np.random.default_rng(0)
a = jnp.asarray(rng.uniform(-0.5, 0.5, (64, 256))
                * np.exp(rng.standard_normal((64, 256))))
b = jnp.asarray(rng.uniform(-0.5, 0.5, (256, 48)))
cfg = OzakiConfig(num_splits=11)
outs = []
for shape in ((2, 4), (4, 2), (1, 8)):
    mesh = make_mesh_compat(shape, ('data', 'model'))
    outs.append(np.asarray(distributed_ozaki_matmul(a, b, mesh, cfg)))
assert np.array_equal(outs[0], outs[1]), 'mesh 2x4 vs 4x2'
assert np.array_equal(outs[0], outs[2]), 'mesh 2x4 vs 1x8'
ref = np.asarray(a) @ np.asarray(b)
err = np.abs(outs[0] - ref).max() / np.abs(ref).max()
assert err < 1e-14, err
# overlap schedule identical (int32 psum exactness)
o2 = np.asarray(distributed_ozaki_matmul(
    a, b, make_mesh_compat((2, 4), ('data', 'model')),
    cfg, schedule='overlap'))
assert np.array_equal(outs[0], o2)
print('OK')
""")
    assert "OK" in out


def test_distributed_ozaki_m_sharded_and_df32():
    out = run_multidevice("""
import jax
jax.config.update('jax_enable_x64', True)
import numpy as np, jax.numpy as jnp
from repro.core.ozaki import OzakiConfig
from repro.core.xmath import df32_to_f64
from repro.launch.mesh import make_mesh_compat
from repro.parallel.ozaki_shard import distributed_ozaki_matmul
rng = np.random.default_rng(1)
a = jnp.asarray(rng.uniform(-0.5, 0.5, (64, 128)))
b = jnp.asarray(rng.uniform(-0.5, 0.5, (128, 32)))
mesh = make_mesh_compat((2, 4), ('data', 'model'))
c = np.asarray(distributed_ozaki_matmul(a, b, mesh,
               OzakiConfig(num_splits=9), m_axis='data'))
ref = np.asarray(a) @ np.asarray(b)
assert np.abs(c - ref).max() < 1e-13
a32, b32 = a.astype(jnp.float32), b.astype(jnp.float32)
dw = distributed_ozaki_matmul(a32, b32, mesh,
                              OzakiConfig(num_splits=9, accum='df32'),
                              m_axis='data')
c32 = np.asarray(df32_to_f64(dw))
# oracle must use the SAME f32-rounded inputs (their rounding is ~1e-8;
# the scheme reproduces their exact product to df32 precision)
ref32 = np.asarray(a32, np.float64) @ np.asarray(b32, np.float64)
assert np.abs(c32 - ref32).max() < 1e-11, np.abs(c32 - ref32).max()
print('OK')
""")
    assert "OK" in out


def test_distributed_batched_kshard_pallas_fused_parity():
    """k-sharded batched API: pallas_fused (epilogue) == xla == unsharded.

    int32 slice-product reductions are exact and the accumulation runs on
    the reduced (replicated) products, so the sharded result is bitwise
    equal to the single-device pipeline for every backend.
    """
    out = run_multidevice("""
import jax
jax.config.update('jax_enable_x64', True)
import numpy as np, jax.numpy as jnp
from repro.core.ozaki import OzakiConfig, ozaki_matmul_batched
from repro.launch.mesh import make_mesh_compat
from repro.parallel.ozaki_shard import ozaki_matmul_kshard_auto
rng = np.random.default_rng(3)
a = jnp.asarray(rng.uniform(-0.5, 0.5, (3, 16, 64))
                * np.exp(rng.standard_normal((3, 16, 64))))
w = jnp.asarray(rng.uniform(-0.5, 0.5, (64, 24)))
bb = jnp.asarray(rng.uniform(-0.5, 0.5, (3, 64, 24)))
mesh = make_mesh_compat((1, 8), ('data', 'model'))
ref = np.einsum('bmk,kn->bmn', np.asarray(a), np.asarray(w))
un = np.asarray(ozaki_matmul_batched(a, w, OzakiConfig(num_splits=9)))
un3 = np.asarray(ozaki_matmul_batched(a, bb, OzakiConfig(num_splits=9)))
for backend, epi in (('xla', False), ('pallas_fused', True)):
    cfg = OzakiConfig(num_splits=9, backend=backend, fuse_epilogue=epi)
    sh = np.asarray(ozaki_matmul_kshard_auto(a, w, mesh, cfg, axis='model'))
    assert np.array_equal(sh, un), backend + ' broadcast'
    sh3 = np.asarray(ozaki_matmul_kshard_auto(a, bb, mesh, cfg,
                                              axis='model'))
    assert np.array_equal(sh3, un3), backend + ' stacked'
err = np.abs(un - ref).max() / np.abs(ref).max()
assert err < 1e-14, err
print('OK')
""")
    assert "OK" in out


def test_layers_shard_axis_wiring():
    """ArchConfig.ozaki_shard_axis k-shards the 2-D policy matmul through
    the registered shard mesh without changing a single bit; 3-D model
    projections must pass through untouched (see ``_matmul_ozaki``)."""
    out = run_multidevice("""
import jax, numpy as np, jax.numpy as jnp
from repro.api import MatmulPolicy
from repro.launch.mesh import make_mesh_compat
from repro.models.layers import _matmul_ozaki
from repro.parallel.ozaki_shard import use_shard_mesh
rng = np.random.default_rng(5)
x = jnp.asarray(rng.standard_normal((4, 1, 64)), jnp.float32)
w = jnp.asarray(rng.standard_normal((64, 16)), jnp.float32)
x2 = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)    # plain 2-D
mesh = make_mesh_compat((1, 8), ('data', 'model'))
pol = MatmulPolicy.parse('ozaki-fp64x9/pallas_fused+epilogue')
shp = MatmulPolicy.parse('ozaki-fp64x9/pallas_fused+epilogue|shard=model')
ref = np.asarray(_matmul_ozaki(x, w, pol))
ref2 = np.asarray(_matmul_ozaki(x2, w, pol))
with use_shard_mesh(mesh):
    # 2-D: constraints applied (eager + jit), bitwise identical
    f2 = jax.jit(lambda x, w: _matmul_ozaki(x, w, shp))
    assert np.array_equal(np.asarray(f2(x2, w)), ref2)
    assert np.array_equal(np.asarray(_matmul_ozaki(x2, w, shp)), ref2)
    # 3-D model projections: shard_axis is a structural no-op
    assert np.array_equal(np.asarray(_matmul_ozaki(x, w, shp)), ref)
# absent mesh: silent no-op
assert np.array_equal(np.asarray(_matmul_ozaki(x2, w, shp)), ref2)
# the public facade applies the same 2-D constraints under the mesh
import repro
fa = jnp.asarray(np.float64(np.asarray(x2)))
fw = jnp.asarray(np.float64(np.asarray(w)))
fref = np.asarray(repro.matmul(fa, fw, precision='ozaki-fp64x9'))
with use_shard_mesh(mesh):
    fsh = np.asarray(repro.matmul(fa, fw,
                                  precision='ozaki-fp64x9|shard=model'))
assert np.array_equal(fsh, fref)
print('OK')
""")
    assert "OK" in out


def test_sharded_train_step_matches_single_device():
    out = run_multidevice("""
import jax, numpy as np, jax.numpy as jnp
from repro.configs import get_config
from repro.launch.mesh import make_local_mesh
from repro.parallel.sharding import make_plan
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import init_training, make_train_step, train_step
from repro.train.optimizer import adamw_init
from repro.data.pipeline import make_data

cfg = get_config('llama3.2-3b').reduced()
oc = OptimizerConfig(peak_lr=1e-3, warmup_steps=1, total_steps=10)
data = make_data(cfg, 32, 8)
batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}

# single device reference
from repro.models import init_model
params, axes = init_model(cfg, jax.random.key(0))
p1, o1, m1 = train_step(cfg, oc, params, adamw_init(params), batch)

# 4x2 mesh sharded
mesh = make_local_mesh(data=4, model=2)
plan = make_plan(cfg, axes, mesh, kind='train')
step = make_train_step(cfg, oc, plan)
params2, _, opt2 = init_training(cfg, jax.random.key(0), plan)
p2, o2, m2 = step(params2, opt2, batch)
for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=5e-3, atol=5e-4)
assert abs(float(m1['loss']) - float(m2['loss'])) < 1e-2
print('OK')
""", timeout=900)
    assert "OK" in out


def test_int8_gradient_compression_with_error_feedback():
    out = run_multidevice("""
import jax, numpy as np, jax.numpy as jnp
from functools import partial
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_mesh_compat
from repro.parallel.compression import (compress_psum, init_ef_state)

mesh = make_mesh_compat((8,), ('data',))
rng = np.random.default_rng(0)
g_all = jnp.asarray(rng.standard_normal((8, 256)), jnp.float32)

def one_round(gs, res):
    def local(g, r):
        avg, ef = compress_psum({'g': g[0]}, init_ef_state({'g': g[0]})._replace(residual={'g': r[0]}), 'data')
        return avg['g'][None], ef.residual['g'][None]
    return shard_map(local, mesh=mesh, in_specs=(P('data'), P('data')),
                     out_specs=(P('data'), P('data')))(gs, res)

res = jnp.zeros_like(g_all)
exact = np.asarray(jnp.mean(g_all, axis=0))
# EF: accumulated compressed sum over T rounds of the SAME grad converges
acc = np.zeros(256)
for t in range(20):
    avg, res = one_round(g_all, res)
    acc += np.asarray(avg[0])
err = np.abs(acc / 20 - exact).max() / (np.abs(exact).max() + 1e-9)
assert err < 2e-3, err
print('OK')
""")
    assert "OK" in out


def test_int8_transport_parity_matrix():
    """The tentpole acceptance matrix: every int8-slice collective
    schedule x layout x backend is BITWISE identical to the single-device
    reference, fast-mode and df32 rows included (int32 collectives are
    associative; the mnshard gather ships the exact split the reference
    computes)."""
    out = run_multidevice("""
import jax
jax.config.update('jax_enable_x64', True)
import numpy as np, jax.numpy as jnp
from repro.core.ozaki import OzakiConfig, ozaki_matmul, ozaki_matmul_batched
from repro.core.xmath import df32_to_f64
from repro.launch.mesh import make_mesh_compat
from repro.parallel.ozaki_shard import (distributed_ozaki_matmul,
                                        distributed_ozaki_matmul_batched,
                                        ozaki_matmul_mnshard)
rng = np.random.default_rng(7)
m, k, n = 32, 256, 48
a = jnp.asarray(rng.uniform(-0.5, 0.5, (m, k))
                * np.exp(rng.standard_normal((m, k))))
b = jnp.asarray(rng.uniform(-0.5, 0.5, (k, n)))
mesh = make_mesh_compat((1, 8), ('data', 'model'))
cfg = OzakiConfig(num_splits=6)
ref = np.asarray(ozaki_matmul(a, b, cfg))
# k-shard: all four collective schedules
for sched in ('psum', 'overlap', 'reduce_scatter', 'rs_stream'):
    got = np.asarray(distributed_ozaki_matmul(a, b, mesh, cfg,
                                              schedule=sched))
    assert np.array_equal(got, ref), f'kshard/{sched}'
# fast-mode row: resolve_accuracy_config must match the reference driver
cfg_f = OzakiConfig(num_splits=6, fast_mode=True)
ref_f = np.asarray(ozaki_matmul(a, b, cfg_f))
got_f = np.asarray(distributed_ozaki_matmul(a, b, mesh, cfg_f,
                                            schedule='overlap'))
assert np.array_equal(got_f, ref_f), 'kshard fast-mode'
# m/n-shard: SliceWire gather, both schedules, xla + pallas backends
for backend in ('xla', 'pallas'):
    cfg_b = OzakiConfig(num_splits=6, backend=backend)
    ref_b = np.asarray(ozaki_matmul(a, b, cfg_b))
    for sched in ('allgather', 'overlap'):
        got = np.asarray(ozaki_matmul_mnshard(a, b, mesh, cfg_b,
                                              schedule=sched))
        assert np.array_equal(got, ref_b), f'mnshard/{sched}/{backend}'
# 2-D (k x batch) mesh composition, broadcast weights
mesh2 = make_mesh_compat((2, 4), ('data', 'model'))
ab = jnp.asarray(rng.uniform(-0.5, 0.5, (4, m, k)))
refb = np.asarray(ozaki_matmul_batched(ab, b, cfg))
for sched in ('psum', 'reduce_scatter'):
    got = np.asarray(distributed_ozaki_matmul_batched(
        ab, b, mesh2, cfg, axis='model', batch_axis='data',
        schedule=sched))
    assert np.array_equal(got, refb), f'batched2d/{sched}'
# df32 row (TPU-deployable accumulator)
cfg_d = OzakiConfig(num_splits=4, accum='df32')
a32 = a.astype(jnp.float32).astype(jnp.float64)
b32 = b.astype(jnp.float32).astype(jnp.float64)
ref_d = np.asarray(ozaki_matmul(a32, b32, cfg_d))
got_d = np.asarray(df32_to_f64(distributed_ozaki_matmul(
    a32, b32, mesh, cfg_d, schedule='psum')))
assert np.array_equal(got_d, ref_d), 'kshard df32'
print('OK')
""", timeout=900)
    assert "OK" in out


def test_int8_transport_facade_and_auto_routing():
    """``comm=int8`` end to end: the policy spec routes ``repro.matmul``
    and ``ozaki_matmul_kshard_auto`` onto the explicit int8-slice
    schedules, bitwise-equal to the unsharded facade; schedules that the
    transport cannot serve (df32 auto, streaming mnshard) fall back /
    refuse loudly."""
    out = run_multidevice("""
import dataclasses
import jax
jax.config.update('jax_enable_x64', True)
import numpy as np, jax.numpy as jnp
import repro
from repro.api import MatmulPolicy
from repro.core.ozaki import OzakiConfig, ozaki_matmul, ozaki_matmul_batched
from repro.launch.mesh import make_mesh_compat
from repro.parallel.ozaki_shard import (ozaki_matmul_kshard_auto,
                                        ozaki_matmul_mnshard, use_shard_mesh)
rng = np.random.default_rng(11)
a = jnp.asarray(rng.standard_normal((32, 256)))
b = jnp.asarray(rng.standard_normal((256, 48)))
mesh = make_mesh_compat((1, 8), ('data', 'model'))
pol = MatmulPolicy.parse('ozaki-fp64x6|shard=model|comm=int8')
ref = np.asarray(repro.matmul(a, b, MatmulPolicy.parse('ozaki-fp64x6')))
with use_shard_mesh(mesh):
    got = np.asarray(repro.matmul(a, b, pol))
assert np.array_equal(got, ref), 'facade comm=int8'
# kshard_auto: comm=int8 re-routes 2-D and 3-D-broadcast onto the
# explicit schedules, still bitwise vs the unsharded reference
cfg = OzakiConfig(num_splits=6, comm='int8')
assert np.array_equal(
    np.asarray(ozaki_matmul_kshard_auto(a, b, mesh, cfg, axis='model')),
    np.asarray(ozaki_matmul(a, b, OzakiConfig(num_splits=6))))
ab = jnp.asarray(rng.standard_normal((3, 32, 256)))
assert np.array_equal(
    np.asarray(ozaki_matmul_kshard_auto(ab, b, mesh, cfg, axis='model')),
    np.asarray(ozaki_matmul_batched(ab, b, OzakiConfig(num_splits=6))))
# stacked 3-D weights stay on the GSPMD fallback (still runs, correct)
bb = jnp.asarray(rng.standard_normal((3, 256, 48)))
got3 = np.asarray(ozaki_matmul_kshard_auto(ab, bb, mesh, cfg,
                                           axis='model'))
ref3 = np.asarray(ozaki_matmul_batched(ab, bb, OzakiConfig(num_splits=6)))
assert np.array_equal(got3, ref3), 'stacked GSPMD fallback'
# mnshard refuses schedules it cannot serve losslessly
cfg_s = OzakiConfig(num_splits=6, backend='pallas_fused', streaming=True)
try:
    ozaki_matmul_mnshard(a, b, mesh, cfg_s)
    raise SystemExit('streaming mnshard must refuse')
except ValueError as e:
    assert 'streaming' in str(e)
try:
    ozaki_matmul_mnshard(a, b, mesh, OzakiConfig(num_splits=6,
                                                 accum='df32'))
    raise SystemExit('df32 mnshard must refuse')
except ValueError as e:
    assert 'f64' in str(e)
print('OK')
""", timeout=900)
    assert "OK" in out


def test_scheme2_int8_transport_parity_matrix():
    """Scheme II residue-wire transport: every k-shard schedule x
    backend (fused-CRT epilogue included) and the ResidueWire mnshard
    gather are BITWISE identical to the single-device reference, across
    mesh shapes — and the ``ozaki2-fp64|shard=model|comm=int8`` policy
    spec routes the facade onto the same schedules."""
    out = run_multidevice("""
import jax
jax.config.update('jax_enable_x64', True)
import numpy as np, jax.numpy as jnp
import repro
from repro.api import MatmulPolicy
from repro.core.modular import ModularConfig, ozaki2_matmul
from repro.launch.mesh import make_mesh_compat
from repro.parallel.ozaki_shard import (distributed_ozaki2_matmul,
                                        ozaki2_matmul_mnshard,
                                        use_shard_mesh)
rng = np.random.default_rng(13)
m, k, n = 16, 256, 24
a = jnp.asarray(rng.uniform(-0.5, 0.5, (m, k))
                * np.exp(rng.standard_normal((m, k))))
b = jnp.asarray(rng.uniform(-0.5, 0.5, (k, n)))
mesh = make_mesh_compat((1, 8), ('data', 'model'))
for cfg in (ModularConfig(),
            ModularConfig(backend='pallas_fused', fuse_epilogue=True)):
    ref = np.asarray(ozaki2_matmul(a, b, cfg))
    tag = cfg.backend + ('+epi' if cfg.fuse_epilogue else '')
    for sched in ('psum', 'reduce_scatter'):
        got = np.asarray(distributed_ozaki2_matmul(
            a, b, mesh, cfg, axis='model', schedule=sched))
        assert np.array_equal(got, ref), f'kshard/{sched}/{tag}'
    got = np.asarray(ozaki2_matmul_mnshard(a, b, mesh, cfg, axis='model'))
    assert np.array_equal(got, ref), f'mnshard/{tag}'
# mesh-shape elasticity: 4-way k-shard reproduces the same bits
mesh2 = make_mesh_compat((2, 4), ('data', 'model'))
cfg = ModularConfig()
ref = np.asarray(ozaki2_matmul(a, b, cfg))
got = np.asarray(distributed_ozaki2_matmul(a, b, mesh2, cfg,
                                           axis='model'))
assert np.array_equal(got, ref), 'kshard 4-way'
# facade: the policy spec routes onto the explicit residue schedules
pol = MatmulPolicy.parse('ozaki2-fp64|shard=model|comm=int8')
ref_f = np.asarray(repro.matmul(a, b, MatmulPolicy.parse('ozaki2-fp64')))
with use_shard_mesh(mesh):
    got_f = np.asarray(repro.matmul(a, b, pol))
assert np.array_equal(got_f, ref_f), 'facade ozaki2 comm=int8'
# schedule validation refuses loudly
try:
    distributed_ozaki2_matmul(a, b, mesh, cfg, schedule='overlap')
    raise SystemExit('unknown schedule must refuse')
except ValueError as e:
    assert 'schedule' in str(e)
print('OK')
""", timeout=900)
    assert "OK" in out


@pytest.mark.xfail(jax.__version__ == "0.4.37", strict=True,
                   reason="with_sharding_constraint on Ozaki operands "
                          "inside _scan_decoder produces wrong logits on "
                          "the pinned jax CPU SPMD stack (ROADMAP 'Known "
                          "limitation (PR 2)'); layers.py therefore only "
                          "constrains 2-D projections. Strict: an XPASS "
                          "after a jax upgrade flags that the 3-D model "
                          "paths can be re-enabled.")
def test_scan_decoder_sharding_constraint_pinned_failure():
    """Pinned repro of the in-scan sharding-constraint miscompilation:
    constrain the 3-D in-scan projections (exactly what layers.py
    refuses to do) and compare logits to the unsharded reference —
    observed max diff ~3.2 on reduced-llama, pure-XLA backend."""
    out = run_multidevice("""
import dataclasses
import jax, numpy as np, jax.numpy as jnp
from repro.configs import get_config
from repro.models import init_model, forward_train
from repro.launch.mesh import make_mesh_compat
from repro.parallel.ozaki_shard import (constrain_batched_kshard,
                                        use_shard_mesh)
import repro.models.layers as L

cfg = dataclasses.replace(get_config('llama3.2-3b').reduced(),
                          matmul_precision='ozaki_fp64', ozaki_splits=7)
params, _ = init_model(cfg, jax.random.key(0))
rng = np.random.default_rng(0)
batch = {'tokens': jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 16)))}
ref, _ = forward_train(cfg, params, batch)

orig = L._matmul_ozaki
def patched(x, w, policy):
    if x.ndim == 3:
        x, w = constrain_batched_kshard(x, w, 'model')
    return orig(x, w, policy)
L._matmul_ozaki = patched
mesh = make_mesh_compat((1, 8), ('data', 'model'))
with use_shard_mesh(mesh):
    sh, _ = jax.jit(lambda p, b: forward_train(cfg, p, b))(params, batch)
diff = float(jnp.max(jnp.abs(sh - ref)))
print('max diff:', diff)
assert diff < 1e-3, f'logits diverge under in-scan constraints: {diff}'
print('OK')
""", timeout=900)
    assert "OK" in out
