"""The while-aware HLO analyzer — the §Roofline measurement tool itself
must be trustworthy, so validate it against known-cost programs."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze, parse_computations


def test_scan_flops_multiplied_by_trip_count():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    comp = jax.jit(f).lower(x, w).compile()
    st = analyze(comp.as_text())
    expected = 2 * 128 ** 3 * 10
    assert abs(st.total_flops / expected - 1.0) < 1e-6
    # XLA's own analysis counts the body once (the reason this module
    # exists) — document the discrepancy. Older jax returns a one-element
    # list of properties dicts, newer a dict.
    ca = comp.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    assert ca["flops"] < 0.2 * expected


def test_nested_scan_multiplies():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=4)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=3)
        return out

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    st = analyze(jax.jit(f).lower(x, w).compile().as_text())
    assert abs(st.total_flops / (2 * 64 ** 3 * 12) - 1.0) < 1e-6


def test_dtype_split_counts_int8_separately():
    def f(a, b):
        return jax.lax.dot_general(
            a, b, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)

    a = jax.ShapeDtypeStruct((64, 64), jnp.int8)
    b = jax.ShapeDtypeStruct((64, 64), jnp.int8)
    st = analyze(jax.jit(f).lower(a, b).compile().as_text())
    assert st.int_flops == st.total_flops > 0


def test_parse_computations_finds_entry():
    def f(x):
        return x * 2.0

    hlo = jax.jit(f).lower(
        jax.ShapeDtypeStruct((8,), jnp.float32)).compile().as_text()
    comps, entry = parse_computations(hlo)
    assert entry is not None and entry in comps


def test_hbm_model_fusion_merging():
    """A softmax chain must be charged ~once, not once per op."""
    def f(x):
        return jax.nn.softmax(jnp.tanh(x) * 2.0 + 1.0, axis=-1)

    x = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    st = analyze(jax.jit(f).lower(x).compile().as_text())
    nbytes = 512 * 512 * 4
    # read x once + write out once, plus small reduction temps: the
    # merged model must land within 4x of the ideal 2 passes (the naive
    # per-op model measures ~10x)
    assert st.hbm_bytes <= 4 * 2 * nbytes, st.hbm_bytes / nbytes
