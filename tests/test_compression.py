"""parallel.compression: the SliceWire/ResidueWire transports (lossless)
and the EF-SGD int8 gradient compressor (lossy, error-bounded).

Single-device properties; the mesh behaviour lives in test_distributed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)


# ----------------------------------------------------------------------------
# SliceWire: pack/unpack are exact transposes; byte model matches reality
# ----------------------------------------------------------------------------

def _split(rows=12, k=40, s=7):
    from repro.core.splitting import slice_width, split_int
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((rows, k))
                    * np.exp(rng.integers(-10, 10, (rows, 1))))
    return split_int(x, s, slice_width(k)), x


def test_slice_wire_round_trip_exact():
    from repro.parallel.compression import pack_slices, unpack_slices
    sr, _ = _split()
    wire = pack_slices(sr)
    assert wire.slices.dtype == jnp.int8
    assert wire.slices.shape == (12, 7, 40)       # sharded dim leads
    back = unpack_slices(wire)
    assert np.array_equal(np.asarray(back.slices), np.asarray(sr.slices))
    assert np.array_equal(np.asarray(back.exp), np.asarray(sr.exp))
    assert back.w == sr.w


def test_slice_wire_byte_model_matches_arrays():
    from repro.parallel.compression import (pack_slices, slice_wire_bytes,
                                            wire_nbytes)
    sr, _ = _split(rows=12, k=40, s=7)
    wire = pack_slices(sr)
    assert wire_nbytes(wire) == slice_wire_bytes(12, 40, 7)
    # the headline economics: s bytes/element (+exp) vs 8 for f64
    assert slice_wire_bytes(12, 40, 7) < 8 * 12 * 40


def test_slice_wire_reconstructs_operand():
    """Lossless transport: the unpacked SplitResult reconstructs to the
    bitwise-identical value the un-wired split reconstructs to (the wire
    round-trip is pure transposes — zero arithmetic)."""
    from repro.core.splitting import reconstruct
    from repro.parallel.compression import pack_slices, unpack_slices
    sr, x = _split()
    back = unpack_slices(pack_slices(sr))
    assert np.array_equal(np.asarray(reconstruct(back)),
                          np.asarray(reconstruct(sr)))
    # and the kept part carries the top s*w mantissa bits of x
    rel = np.abs(np.asarray(reconstruct(sr)) - np.asarray(x))
    exp = np.asarray(sr.exp)
    assert (rel <= np.ldexp(1.0, exp - sr.w * 7 + 1)[:, None]).all()


# ----------------------------------------------------------------------------
# ResidueWire: the Scheme II sibling — same wire discipline, ell planes
# ----------------------------------------------------------------------------

def _residues(rows=12, k=40, s=5, ell=6):
    from repro.core.modular import residues_from_slices, usable_moduli
    from repro.core.splitting import split_int
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((rows, k))
                    * np.exp(rng.integers(-10, 10, (rows, 1))))
    sr = split_int(x, s, 7)
    moduli = usable_moduli(k)[:ell]
    return residues_from_slices(sr.slices, 7, moduli), sr.exp, moduli


def test_residue_wire_round_trip_exact():
    from repro.parallel.compression import pack_residues, unpack_residues
    res, exp, moduli = _residues()
    wire = pack_residues(res, exp, moduli)
    assert wire.residues.dtype == jnp.int8
    assert wire.residues.shape == (12, 6, 40)     # sharded dim leads
    assert wire.moduli == tuple(moduli)           # static metadata
    back, back_exp = unpack_residues(wire)
    assert np.array_equal(np.asarray(back), np.asarray(res))
    assert np.array_equal(np.asarray(back_exp), np.asarray(exp))


def test_residue_wire_byte_model_matches_arrays():
    from repro.parallel.compression import (pack_residues,
                                            residue_wire_bytes,
                                            slice_wire_bytes, wire_nbytes)
    res, exp, moduli = _residues(rows=12, k=40, s=5, ell=6)
    wire = pack_residues(res, exp, moduli)
    assert wire_nbytes(wire) == residue_wire_bytes(12, 40, 6)
    # the headline economics: ell bytes/element (+exp) vs 8 for f64
    assert residue_wire_bytes(12, 40, 6) < 8 * 12 * 40
    # cross-wire arbitration: the residue wire beats the slice wire
    # exactly when ell < s (the comm_bytes_model honesty rule)
    assert residue_wire_bytes(12, 40, 4) < slice_wire_bytes(12, 40, 5)
    assert residue_wire_bytes(12, 40, 6) > slice_wire_bytes(12, 40, 5)


def test_residue_wire_reconstruction_exact():
    """Wire-round-tripped residues feed the CRT pipeline to the bitwise-
    identical product: the transport is pure transposes, so the Garner
    digits — and hence the f64 reconstruction — cannot move a bit."""
    from repro.core.modular import (ModularConfig, center_mod, crt_digits,
                                    crt_value, ozaki2_matmul,
                                    residues_from_slices, usable_moduli)
    from repro.core.splitting import split_int
    from repro.parallel.compression import pack_residues, unpack_residues
    rng = np.random.default_rng(2)
    m, k, n = 8, 96, 10
    a = jnp.asarray(rng.standard_normal((m, k))
                    * np.exp(rng.integers(-8, 8, (m, 1))))
    b = jnp.asarray(rng.standard_normal((k, n)))
    cfg = ModularConfig()
    plan = cfg.plan(k)
    moduli = usable_moduli(k)[:plan.num_moduli]
    sa = split_int(a, plan.num_splits, cfg.w)
    sb = split_int(b.T, plan.num_splits, cfg.w)
    ra = residues_from_slices(sa.slices, cfg.w, moduli)
    rb = residues_from_slices(sb.slices, cfg.w, moduli)
    rb_wire, exp = unpack_residues(pack_residues(rb, sb.exp, moduli))
    from repro.core.executors import gemm_xla
    p = gemm_xla(ra, rb_wire)
    digits = crt_digits(center_mod(p, moduli), moduli)
    e_base = (sa.exp[:, None].astype(jnp.int32) +
              exp[None, :].astype(jnp.int32))
    c = crt_value(digits, moduli, plan.beta, e_base)
    assert np.array_equal(np.asarray(c),
                          np.asarray(ozaki2_matmul(a, b, cfg)))


def test_residue_wire_round_trip_property():
    pytest.importorskip("hypothesis",
                        reason="property tests need hypothesis")
    from hypothesis import given, settings, strategies as st
    from repro.core.modular import usable_moduli
    from repro.parallel.compression import (pack_residues,
                                            residue_wire_bytes,
                                            unpack_residues, wire_nbytes)

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2 ** 31), rows=st.integers(1, 9),
           k=st.integers(1, 33), ell=st.integers(1, 8))
    def prop(seed, rows, k, ell):
        moduli = usable_moduli(max(k, 1))[:ell]
        rng = np.random.default_rng(seed)
        halves = (np.asarray(moduli, np.int64)[:, None, None] - 1) // 2
        res = jnp.asarray(
            rng.integers(-halves, halves + 1, (len(moduli), rows, k)),
            jnp.int8)
        exp = jnp.asarray(rng.integers(-50, 50, (rows,)), jnp.int32)
        wire = pack_residues(res, exp, moduli)
        back, back_exp = unpack_residues(wire)
        assert np.array_equal(np.asarray(back), np.asarray(res))
        assert np.array_equal(np.asarray(back_exp), np.asarray(exp))
        assert wire_nbytes(wire) == residue_wire_bytes(rows, k,
                                                       len(moduli))

    prop()


# ----------------------------------------------------------------------------
# int8 quantizer: deterministic round-trip error bound
# ----------------------------------------------------------------------------

def test_quantize_dequantize_error_bound():
    from repro.parallel.compression import dequantize_int8, quantize_int8
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((64, 33)), jnp.float32)
    q, scale = quantize_int8(x)
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(dequantize_int8(q, scale)) - np.asarray(x))
    # round-to-nearest against a per-tensor scale: |err| <= scale/2
    assert err.max() <= float(scale) / 2 + 1e-12
    # zeros stay exactly zero (scale has the +eps guard, q = 0)
    qz, sz = quantize_int8(jnp.zeros((4, 4), jnp.float32))
    assert np.array_equal(np.asarray(qz), np.zeros((4, 4)))
    assert np.array_equal(np.asarray(dequantize_int8(qz, sz)),
                          np.zeros((4, 4)))


# ----------------------------------------------------------------------------
# EF-SGD: the residual stays bounded (error feedback does not accumulate)
# ----------------------------------------------------------------------------

def test_ef_residual_stays_bounded():
    """Per-round quantization error is <= scale/2 elementwise and the
    residual is exactly (input - quantized), so over T rounds with fresh
    gradients the residual never grows beyond one quantization step of
    the current round — the EF-SGD boundedness that makes the compressed
    sum converge (mesh-level convergence is covered in
    test_distributed)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_mesh_compat
    from repro.parallel.compression import (EFState, compress_psum,
                                            init_ef_state)
    mesh = make_mesh_compat((1,), ("data",))      # axis of size 1: exact psum
    rng = np.random.default_rng(4)

    def one_round(g, r):
        def local(g, r):
            avg, ef = compress_psum({"g": g}, EFState({"g": r}), "data")
            return avg["g"], ef.residual["g"]
        return shard_map(local, mesh=mesh, in_specs=(P(), P()),
                         out_specs=(P(), P()), check_rep=False)(g, r)

    g0 = jnp.asarray(rng.standard_normal(256), jnp.float32)
    res = jnp.asarray(init_ef_state({"g": g0}).residual["g"])
    for t in range(30):
        g = jnp.asarray(rng.standard_normal(256), jnp.float32) * (1 + t % 3)
        prev = res
        avg, res = one_round(g, res)
        # the quantizer's scale is max|g + prev_res| / 127; round-to-
        # nearest leaves at most half a step behind as the new residual
        bound = float(jnp.max(jnp.abs(g + prev))) / 127.0 / 2
        assert float(jnp.max(jnp.abs(res))) <= bound + 1e-6, t
