"""Ozaki-scheme GEMM accuracy and scheduling equivalences (paper Alg. 3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ozaki import (OzakiConfig, dgemm_f64, gemm_fp32_pass,
                              ozaki_matmul, ozaki_matmul_complex,
                              ozaki_matmul_dw)
from repro.core.xmath import (DW, dd_matmul_np, df32_from_f64, df32_to_f64,
                              rel_error_vs_dd)


def _phi_matrix(rng, m, k, phi):
    """Paper Eq. (6): uniform(-0.5,0.5) * exp(phi * normal)."""
    return jnp.asarray(rng.uniform(-0.5, 0.5, (m, k))
                       * np.exp(phi * rng.standard_normal((m, k))))


def _max_rel_err_vs_dd(c, a, b):
    hi, lo = dd_matmul_np(np.asarray(a), np.asarray(b))
    return float(np.max(rel_error_vs_dd(np.asarray(c), hi, lo)))


@pytest.mark.parametrize("phi,s,tol", [
    (0.1, 9, 1e-15), (1.0, 11, 1e-14), (2.0, 13, 1e-13)])
def test_accuracy_vs_exponent_range(rng, phi, s, tol):
    """Fig. 6: enough splits keep INT8xX at/below DGEMM error."""
    a = _phi_matrix(rng, 24, 96, phi)
    b = _phi_matrix(rng, 96, 16, phi).T.T
    c = ozaki_matmul(a, jnp.asarray(b), OzakiConfig(num_splits=s))
    assert _max_rel_err_vs_dd(c, a, b) < tol


def test_few_splits_wide_exponents_degrades(rng):
    """Fig. 6's other half: wide phi + few splits loses accuracy."""
    a = _phi_matrix(rng, 16, 64, 4.0)
    b = _phi_matrix(rng, 64, 16, 4.0)
    err3 = _max_rel_err_vs_dd(
        ozaki_matmul(a, b, OzakiConfig(num_splits=3)), a, b)
    err13 = _max_rel_err_vs_dd(
        ozaki_matmul(a, b, OzakiConfig(num_splits=13)), a, b)
    assert err13 < err3 * 1e-3


def test_zero_cancellation_beats_dgemm(rng):
    """Fig. 7: C = A @ A^-1 — Ozaki beats plain FP64 on cancellation."""
    n = 48
    a_np = rng.standard_normal((n, n))
    ainv = np.linalg.inv(a_np)
    a, b = jnp.asarray(a_np), jnp.asarray(ainv)
    err_oz = _max_rel_err_vs_dd(
        ozaki_matmul(a, b, OzakiConfig(num_splits=11)), a, b)
    err_dg = _max_rel_err_vs_dd(dgemm_f64(a, b), a, b)
    assert err_oz < err_dg


def test_schedules_agree(rng):
    a = _phi_matrix(rng, 16, 128, 1.0)
    b = _phi_matrix(rng, 128, 12, 1.0)
    base = np.asarray(ozaki_matmul(
        a, b, OzakiConfig(num_splits=9, fuse_diagonals=False)))
    fused = np.asarray(ozaki_matmul(
        a, b, OzakiConfig(num_splits=9, fuse_diagonals=True)))
    cat = np.asarray(ozaki_matmul(
        a, b, OzakiConfig(num_splits=9, concat_k=True)))
    # fused sums the same int32 products exactly -> tiny f64 path diffs
    np.testing.assert_allclose(fused, base, rtol=1e-15)
    np.testing.assert_array_equal(fused, cat)   # identical group order


def test_full_pairs_at_least_as_accurate(rng):
    a = _phi_matrix(rng, 12, 64, 1.0)
    b = _phi_matrix(rng, 64, 12, 1.0)
    tri = _max_rel_err_vs_dd(ozaki_matmul(
        a, b, OzakiConfig(num_splits=7, full_pairs=False)), a, b)
    full = _max_rel_err_vs_dd(ozaki_matmul(
        a, b, OzakiConfig(num_splits=7, full_pairs=True)), a, b)
    assert full <= tri * 1.01 + 1e-18


def test_pallas_backend_bitwise_equals_xla(rng):
    a = _phi_matrix(rng, 32, 256, 1.0)
    b = _phi_matrix(rng, 256, 24, 1.0)
    x = np.asarray(ozaki_matmul(a, b, OzakiConfig(num_splits=9,
                                                  backend="xla")))
    p = np.asarray(ozaki_matmul(a, b, OzakiConfig(num_splits=9,
                                                  backend="pallas",
                                                  interpret=True)))
    np.testing.assert_array_equal(x, p)


def test_df32_accumulation_path(rng):
    a = _phi_matrix(rng, 16, 96, 0.5)
    b = _phi_matrix(rng, 96, 16, 0.5)
    c = ozaki_matmul(a, b, OzakiConfig(num_splits=9, accum="df32"))
    # df32 carries 48 bits -> ~1e-13 relative accuracy
    assert _max_rel_err_vs_dd(c, a, b) < 1e-12


def test_dw_native_path(rng):
    """TPU-native entry: df32 in, df32 out, no f64 in the hot path."""
    a = _phi_matrix(rng, 16, 64, 0.5)
    b = _phi_matrix(rng, 64, 8, 0.5)
    out = ozaki_matmul_dw(df32_from_f64(a), df32_from_f64(jnp.asarray(b).T),
                          OzakiConfig(num_splits=9, accum="df32"))
    c = np.asarray(df32_to_f64(out))
    assert _max_rel_err_vs_dd(c, a, b) < 1e-12


@pytest.mark.parametrize("algo", ["4mul", "3mul"])
def test_complex_gemm(rng, algo):
    n = 24
    a = jnp.asarray(rng.uniform(-0.5, 0.5, (n, n))
                    + 1j * rng.uniform(-0.5, 0.5, (n, n)))
    b = jnp.asarray(rng.uniform(-0.5, 0.5, (n, n))
                    + 1j * rng.uniform(-0.5, 0.5, (n, n)))
    c = np.asarray(ozaki_matmul_complex(a, b, OzakiConfig(num_splits=10),
                                        algo=algo))
    ref = np.asarray(a) @ np.asarray(b)
    np.testing.assert_allclose(c, ref, rtol=1e-13, atol=1e-14)


def test_better_than_fp32(rng):
    a = _phi_matrix(rng, 16, 64, 1.0)
    b = _phi_matrix(rng, 64, 16, 1.0)
    err_oz = _max_rel_err_vs_dd(
        ozaki_matmul(a, b, OzakiConfig(num_splits=9)), a, b)
    err_32 = _max_rel_err_vs_dd(gemm_fp32_pass(a, b), a, b)
    assert err_oz < err_32 * 1e-6


def test_gemm_count_formula():
    cfg = OzakiConfig(num_splits=9)
    assert cfg.num_gemms == 45                       # s(s+1)/2
    assert OzakiConfig(num_splits=9, full_pairs=True).num_gemms == 81
