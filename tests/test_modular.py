"""Ozaki Scheme II (``core.modular``): residue-system GEMM emulation.

Deterministic coverage (hypothesis-randomized counterparts live in
``test_modular_props.py``):

* residue extraction and balanced-CRT reconstruction are EXACT against
  a python-int reference (including negatives, zero rows, all-zero
  columns — the ``test_splitting`` edge-case mirror);
* ``resolve_modular`` knob priority (beta > target_error > pinned
  num_moduli dial > 70-bit DGEMM default) and its refusal to accept a
  modulus count the CRT range cannot live in;
* end-to-end ``scaled_error <= modular_error_bound`` and Scheme I/II
  parity at matched targets across the backend/batch matrix (the
  Pallas backends bitwise-equal to XLA);
* the cross-scheme cost model: the pinned GEMM-count win at tall k
  (15 residue GEMMs vs 28 slice pairs at the s=7-matched target),
  arbitration resolving to DIFFERENT families at pinned points, the
  autotuner enumerating candidates from both families, and the plan
  cache keeping the schemes' entries distinct.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.accuracy import (plan_meets_target, resolve_accuracy,
                                 scaled_error, scheme_costs,
                                 truncation_eta)
from repro.core.autotune import (PLAN_CACHE_VERSION, PlanCache, PlanKey,
                                 candidate_plans, plan_cache_key)
from repro.core.modular import (MAX_BETA, ModularConfig, center_mod,
                                crt_digits, crt_value, min_beta_for,
                                modular_error_bound, modular_eta,
                                modular_plan, ozaki2_matmul,
                                ozaki2_matmul_batched, residues_from_slices,
                                resolve_modular, select_moduli,
                                usable_moduli)
from repro.core.ozaki import OzakiConfig, ozaki_matmul
from repro.core.splitting import slice_width, split_int
from repro.core.tuning import PipelinePlan, select_pipeline_plan
from repro.core.xmath import dd_matmul_np


def _phi(rng, m, k, phi=1.0):
    return jnp.asarray(rng.uniform(-0.5, 0.5, (m, k))
                       * np.exp(phi * rng.standard_normal((m, k))))


def _matched_target(k, s):
    return k * truncation_eta(s, slice_width(k, fuse_terms=s))


# ----------------------------------------------------------------------------
# Moduli selection
# ----------------------------------------------------------------------------

def test_usable_moduli_overflow_guard():
    for k in (1, 96, 4096, 10 ** 6):
        pool = usable_moduli(k)
        assert pool, k
        for m in pool:
            assert m % 2 == 1 and m <= 251
            assert k * ((m - 1) // 2) ** 2 <= 2 ** 31 - 1
        assert list(pool) == sorted(pool, reverse=True)
    # tighter k admits fewer primes
    assert len(usable_moduli(10 ** 6)) < len(usable_moduli(96))


def test_select_moduli_minimal_covering_prefix():
    k, beta = 96, 70
    moduli = select_moduli(k, beta)
    prod = 1
    for m in moduli:
        prod *= m
    assert prod > 2 * k * 4 ** beta              # range covered
    shorter = 1
    for m in moduli[:-1]:
        shorter *= m
    assert shorter <= 2 * k * 4 ** beta          # and minimal
    assert moduli == usable_moduli(k)[:len(moduli)]   # always a prefix


def test_select_moduli_pool_exhausted_raises():
    with pytest.raises(ValueError, match="pool exhausted"):
        select_moduli(10 ** 6, MAX_BETA)


# ----------------------------------------------------------------------------
# Residues + CRT: exactness against python ints
# ----------------------------------------------------------------------------

def _int_matrix_cases():
    rng = np.random.default_rng(3)
    dense = rng.integers(-2 ** 40, 2 ** 40, (4, 6))
    zero_row = dense.copy()
    zero_row[1] = 0                               # all-zero row
    zero_col = dense.copy()
    zero_col[:, 2] = 0                            # all-zero column
    negative = -np.abs(dense)                     # all-negative values
    return [dense, zero_row, zero_col, negative,
            np.zeros((3, 5), np.int64)]


@pytest.mark.parametrize("x_int", _int_matrix_cases(),
                         ids=["dense", "zero_row", "zero_col",
                              "negative", "all_zero"])
def test_residues_from_slices_match_python_ints(x_int):
    # slice-build the integers the way the pipeline does (w=7 digits,
    # most significant first), then check every centered residue
    w, s = 7, 8
    moduli = usable_moduli(64)[:12]
    digits = []
    rem = np.asarray(x_int, object)
    for p in range(s - 1, -1, -1):                # least significant first
        centered = ((rem + 2 ** (w - 1)) % 2 ** w) - 2 ** (w - 1)
        digits.append(centered.astype(np.int8))
        rem = (rem - centered) >> w
    assert np.all(rem == 0)                       # s*w bits suffice
    slices = jnp.asarray(np.stack(digits[::-1]))
    res = residues_from_slices(slices, w, moduli)
    assert res.dtype == jnp.int8
    for j, m in enumerate(moduli):
        want = np.asarray(x_int, object) % m
        want = np.where(want > (m - 1) // 2, want - m, want)
        np.testing.assert_array_equal(np.asarray(res[j], object), want)


def test_center_mod_range_and_congruence():
    moduli = (251, 13, 3)
    x = jnp.asarray(np.random.default_rng(0).integers(
        -2 ** 20, 2 ** 20, (3, 5, 7)), jnp.int32)
    c = center_mod(x, moduli)
    for j, m in enumerate(moduli):
        cj = np.asarray(c[j], np.int64)
        assert np.all(np.abs(cj) <= (m - 1) // 2)
        np.testing.assert_array_equal(cj % m, np.asarray(x[j], np.int64) % m)


def test_crt_roundtrip_exact_python_ints():
    # random X with |X| < M/2: residues -> balanced digits -> X, exactly
    k, beta = 64, 49
    moduli = select_moduli(k, beta)
    big = 1
    for m in moduli:
        big *= m
    rng = np.random.default_rng(5)
    xs = np.concatenate([
        rng.integers(-10 ** 9, 10 ** 9, 64),
        np.asarray([0, 1, -1, big // 2 - 1, -(big // 2 - 1)], object)])
    res = np.stack([[int(x) % m for x in xs] for m in moduli])
    res = jnp.asarray(np.where(
        res > (np.asarray(moduli)[:, None] - 1) // 2,
        res - np.asarray(moduli)[:, None], res).astype(np.int32))
    digits = crt_digits(res, moduli)
    # reconstruct as python ints from the balanced digits
    prefix = [1]
    for m in moduli[:-1]:
        prefix.append(prefix[-1] * m)
    got = [sum(int(np.asarray(d)[i]) * q
               for d, q in zip(digits, prefix)) for i in range(len(xs))]
    assert got == [int(x) for x in xs]
    for d, m in zip(digits, moduli):
        assert np.all(np.abs(np.asarray(d)) <= (m - 1) // 2)


def test_crt_value_scaling():
    # one modulus, digit v: the FP64 value is ldexp(v * 4^-beta, e_base)
    moduli = select_moduli(4, 3)
    digits = crt_digits(jnp.asarray(np.full((len(moduli), 2, 2), 5,
                                            np.int32)), moduli)
    out = crt_value(digits, moduli, 3, jnp.full((2, 2), 8, jnp.int32))
    np.testing.assert_allclose(np.asarray(out), 5.0 * 4.0 ** -3 * 2 ** 8)


# ----------------------------------------------------------------------------
# resolve_modular: knob priority
# ----------------------------------------------------------------------------

def test_resolve_modular_default_is_dgemm_space():
    p = resolve_modular(96)
    assert (p.beta, p.num_splits) == (70, 10)    # ceil(70/7)*7
    assert p.moduli == select_moduli(96, 70)


def test_resolve_modular_beta_rounds_up_to_slice_multiple():
    p = resolve_modular(96, beta=50)
    assert (p.beta, p.num_splits) == (56, 8)
    with pytest.raises(ValueError, match="MAX_BETA"):
        resolve_modular(96, beta=MAX_BETA + 1)


def test_resolve_modular_target_sizes_beta():
    k = 1024
    p = resolve_modular(k, target_error=1e-10)
    assert k * modular_eta(p.beta) <= 1e-10
    assert p.beta == -(-min_beta_for(1e-10, k) // 7) * 7
    with pytest.raises(ValueError):
        resolve_modular(k, target_error=-1.0)


def test_resolve_modular_pinned_moduli_is_accuracy_dial():
    k = 96
    p8 = resolve_modular(k, num_moduli=8)
    p14 = resolve_modular(k, num_moduli=14)
    assert len(p8.moduli) == 8 and len(p14.moduli) == 14
    assert p8.beta < p14.beta                    # more moduli, more bits
    prod = 1
    for m in p14.moduli:
        prod *= m
    assert prod > 2 * k * 4 ** p14.beta          # still reconstructs


def test_resolve_modular_insufficient_moduli_raises():
    # fewer moduli than the CRT needs is wraparound, never accepted
    k = 96
    need = len(select_moduli(k, 70))
    with pytest.raises(ValueError, match="cannot reconstruct"):
        resolve_modular(k, beta=70, num_moduli=need - 1)
    with pytest.raises(ValueError, match="exceeds"):
        resolve_modular(k, num_moduli=10 ** 4)
    # extra moduli beyond the minimum are fine (headroom, not error)
    p = resolve_modular(k, beta=70, num_moduli=need + 2)
    assert len(p.moduli) == need + 2


# ----------------------------------------------------------------------------
# End-to-end accuracy: bound proved, Scheme I parity at matched targets
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(16, 16, 96), (8, 24, 64),
                                   (17, 13, 51), (1, 5, 3)])
def test_bound_holds_2d(rng, shape):
    m, n, k = shape
    a, b = _phi(rng, m, k), _phi(rng, k, n)
    cfg = ModularConfig()
    point = cfg.point(k)
    c = np.asarray(ozaki2_matmul(a, b, cfg))
    hi, lo = dd_matmul_np(np.asarray(a), np.asarray(b))
    err = scaled_error(c, hi, np.asarray(a), np.asarray(b), ref_lo=lo)
    assert err <= modular_error_bound(point.beta, k, point.moduli)


def test_parity_with_scheme1_at_matched_target(rng):
    # the cost model's premise: at one target the families agree within
    # the sum of their guaranteed bounds, across targets
    m, n, k = 24, 16, 96
    a, b = _phi(rng, m, k), _phi(rng, k, n)
    a_np, b_np = np.asarray(a), np.asarray(b)
    for s in (3, 5, 7):
        tgt = _matched_target(k, s)
        c1 = np.asarray(ozaki_matmul(a, b, OzakiConfig(num_splits=s)))
        cfg2 = ModularConfig(target_error=tgt)
        point = cfg2.point(k)
        c2 = np.asarray(ozaki2_matmul(a, b, cfg2))
        from repro.core.accuracy import error_bound
        bound1 = error_bound(s, OzakiConfig(num_splits=s).width_for(k), k)
        bound2 = modular_error_bound(point.beta, k, point.moduli)
        cross = scaled_error(c1, c2, a_np, b_np)
        assert cross <= bound1 + bound2, (s, cross)


def test_backends_bitwise_equal_xla(rng):
    m, n, k = 16, 24, 96
    a, b = _phi(rng, m, k), _phi(rng, k, n)
    ref = np.asarray(ozaki2_matmul(a, b, ModularConfig(backend="xla")))
    for backend in ("pallas", "pallas_fused"):
        got = np.asarray(ozaki2_matmul(a, b, ModularConfig(
            backend=backend, interpret=True)))
        np.testing.assert_array_equal(got, ref, err_msg=backend)


@pytest.mark.parametrize("case", ["zero_row", "zero_col", "negative",
                                  "all_zero"])
def test_degenerate_inputs_stay_finite_and_bounded(rng, case):
    m, n, k = 8, 8, 48
    a = np.array(_phi(rng, m, k))
    b = np.array(_phi(rng, k, n))
    if case == "zero_row":
        a[2] = 0.0
    elif case == "zero_col":
        b[:, 3] = 0.0
    elif case == "negative":
        a, b = -np.abs(a), -np.abs(b)
    else:
        a = np.zeros_like(a)
    cfg = ModularConfig()
    point = cfg.point(k)
    c = np.asarray(ozaki2_matmul(jnp.asarray(a), jnp.asarray(b), cfg))
    assert np.all(np.isfinite(c))
    hi, lo = dd_matmul_np(a, b)
    err = scaled_error(c, hi, a, b, ref_lo=lo)
    assert err <= modular_error_bound(point.beta, k, point.moduli)
    if case == "zero_row":
        np.testing.assert_array_equal(c[2], 0.0)
    if case == "all_zero":
        np.testing.assert_array_equal(c, 0.0)


def test_batched_stacked_and_broadcast(rng):
    bsz, m, k, n = 3, 8, 64, 12
    a3 = jnp.asarray(np.stack([np.asarray(_phi(rng, m, k))
                               for _ in range(bsz)]))
    b3 = jnp.asarray(np.stack([np.asarray(_phi(rng, k, n))
                               for _ in range(bsz)]))
    cfg = ModularConfig()
    got = np.asarray(ozaki2_matmul_batched(a3, b3, cfg))
    for i in range(bsz):
        ref = np.asarray(ozaki2_matmul(a3[i], b3[i], cfg))
        np.testing.assert_allclose(got[i], ref, rtol=0, atol=np.max(
            np.abs(ref)) * 1e-12)
    # broadcast weights: bitwise equal to the per-item loop (fold-rows)
    got_b = np.asarray(ozaki2_matmul_batched(a3, b3[0], cfg))
    for i in range(bsz):
        np.testing.assert_array_equal(
            got_b[i], np.asarray(ozaki2_matmul(a3[i], b3[0], cfg)))


def test_batched_grad_exact_product_rule(rng):
    a3 = jnp.asarray(np.stack([np.asarray(_phi(rng, 4, 16))
                               for _ in range(2)]))
    b3 = jnp.asarray(np.stack([np.asarray(_phi(rng, 16, 5))
                               for _ in range(2)]))
    cfg = ModularConfig()
    g = jax.grad(lambda a, b: jnp.sum(ozaki2_matmul_batched(a, b, cfg)),
                 argnums=(0, 1))(a3, b3)
    ones = jnp.ones((2, 4, 5), jnp.float64)
    np.testing.assert_allclose(np.asarray(g[0]),
                               np.asarray(jnp.matmul(ones,
                                                     b3.swapaxes(1, 2))))
    np.testing.assert_allclose(np.asarray(g[1]),
                               np.asarray(jnp.matmul(a3.swapaxes(1, 2),
                                                     ones)))


def test_type_and_shape_validation(rng):
    a, b = _phi(rng, 4, 8), _phi(rng, 8, 4)
    with pytest.raises(TypeError, match="float64"):
        ozaki2_matmul(a.astype(jnp.float32), b.astype(jnp.float32))
    with pytest.raises(ValueError, match="2-D"):
        ozaki2_matmul(a[None], b)
    with pytest.raises(ValueError, match="mismatch"):
        ozaki2_matmul(a, _phi(rng, 9, 4))
    with pytest.raises(ValueError, match="batch, m, k"):
        ozaki2_matmul_batched(a, b)


# ----------------------------------------------------------------------------
# Cross-scheme cost model (the ISSUE acceptance pins)
# ----------------------------------------------------------------------------

def test_gemm_count_win_at_tall_k_pinned():
    # s=7-matched target at k=4096: 15 residue GEMMs vs 28 slice pairs
    k = 4096
    tgt = _matched_target(k, 7)
    plan1 = select_pipeline_plan(512, 512, k, accum="f64",
                                 target_error=tgt)
    plan2 = select_pipeline_plan(512, 512, k, accum="f64",
                                 scheme="ozaki2_fp64", target_error=tgt)
    assert plan1.num_gemms == 28
    assert plan2.num_gemms == 15
    assert plan2.scheme == "ozaki2_fp64" and plan2.beta == 49


def test_resolve_accuracy_arbitrates_both_ways():
    both = ("ozaki_fp64", "ozaki2_fp64")
    # tall k, tight matched target: the linear modulus count wins
    tall = resolve_accuracy(4096, 10,
                            target_error=_matched_target(4096, 7),
                            schemes=both, m=512, n=512)
    assert tall.scheme == "ozaki2_fp64"
    assert tall.num_moduli == 15 and tall.beta == 49
    # small k, loose target: few kept pairs beat the CRT modulus floor
    small = resolve_accuracy(256, 9, target_error=1e-2, schemes=both,
                             m=256, n=256)
    assert small.scheme == "ozaki_fp64"
    assert small.gemms == dict(small.costs)["ozaki_fp64"]
    # both candidates' costs are recorded either way
    assert {name for name, _ in tall.costs} == set(both)
    # the legacy tuple contract is untouched without `schemes`
    assert resolve_accuracy(256, 9, target_error=1e-6) == (5, "full")


def test_scheme_costs_matched_without_target():
    # no target: Scheme II is sized for Scheme I's OWN guaranteed bound
    costs = dict(scheme_costs(4096, 7, target_error=None))
    assert costs["ozaki_fp64"] == 28.0
    assert costs["ozaki2_fp64"] < 28.0
    # infeasible Scheme II point costs inf, never raises
    costs_inf = dict(scheme_costs(10 ** 6, 16, target_error=1e-30))
    assert costs_inf["ozaki2_fp64"] == np.inf


def test_candidate_plans_enumerate_both_families():
    tgt = _matched_target(4096, 7)
    # scheme-I base: a Scheme II candidate appears under a target
    cands = candidate_plans(64, 64, 4096, accum="f64", target_error=tgt,
                            max_candidates=None)
    schemes = {c.scheme for c in cands}
    assert schemes == {"ozaki_fp64", "ozaki2_fp64"}
    for c in cands:
        assert plan_meets_target(c, 4096, tgt), c
    # scheme-II base: the Scheme I seed rides along
    cands2 = candidate_plans(64, 64, 4096, accum="f64",
                             scheme="ozaki2_fp64", target_error=tgt,
                             max_candidates=None)
    assert {c.scheme for c in cands2} == {"ozaki_fp64", "ozaki2_fp64"}
    assert cands2[0].scheme == "ozaki2_fp64"     # base plan leads


def test_select_pipeline_plan_rejects_scheme1_knobs_for_scheme2():
    with pytest.raises(ValueError, match="pair schedule"):
        select_pipeline_plan(8, 8, 64, scheme="ozaki2_fp64",
                             fast_mode=True)
    with pytest.raises(ValueError, match="pair schedule"):
        select_pipeline_plan(8, 8, 64, scheme="ozaki2_fp64",
                             pair_policy="diagonal")


def test_modular_plan_reflection():
    plan = modular_plan(96, num_moduli=20)
    assert plan.scheme == "ozaki2_fp64"
    assert plan.num_gemms == 20 and plan.num_moduli == 20
    assert plan.accum == "f64" and plan.pair_policy == "full"
    back = PipelinePlan.from_dict(json.loads(json.dumps(plan.to_dict())))
    assert back == plan                          # wire-format roundtrip


# ----------------------------------------------------------------------------
# Plan cache: scheme-keyed entries, v2 -> v3 version fallback
# ----------------------------------------------------------------------------

def test_plan_cache_keys_scheme_distinct(tmp_path):
    k1 = plan_cache_key(8, 16, 96, accum="f64", device_kind="cpu")
    k2 = plan_cache_key(8, 16, 96, accum="f64", device_kind="cpu",
                        scheme="ozaki2_fp64")
    assert k1 != k2 and "scheme=ozaki2_fp64" in k2.encode()
    cache = PlanCache(tmp_path / "p.json")
    p1 = select_pipeline_plan(8, 16, 96, accum="f64")
    p2 = modular_plan(96)
    cache.put(k1, p1)
    cache.put(k2, p2)
    cache.save()
    back = PlanCache.load(tmp_path / "p.json")
    assert back.get(k1) == p1 and back.get(k2) == p2   # coexist


def test_plan_cache_v2_file_loads_empty(tmp_path):
    # the scheme field bumped PLAN_CACHE_VERSION to 3: a v2 file (no
    # scheme in its keys) degrades to an empty cache, never errors
    assert PLAN_CACHE_VERSION == 3
    path = tmp_path / "p.json"
    cache = PlanCache(path)
    cache.put(PlanKey(m=8, n=16, k=32, dtype="float64",
                      device_kind="cpu"), modular_plan(32))
    cache.save()
    data = json.loads(path.read_text())
    data["version"] = 2
    path.write_text(json.dumps(data))
    with pytest.warns(UserWarning, match="version"):
        back = PlanCache.load(path)
    assert len(back) == 0
    plan = select_pipeline_plan(8, 16, 32, accum="f64", cache=back)
    assert plan == select_pipeline_plan(8, 16, 32, accum="f64")


def test_cached_scheme2_hit_requires_scheme_match():
    cache = PlanCache()
    key2 = plan_cache_key(8, 16, 96, accum="f64", device_kind="cpu",
                          scheme="ozaki2_fp64")
    cache.put(key2, modular_plan(96))
    # scheme-II request hits its own entry
    got = select_pipeline_plan(8, 16, 96, accum="f64",
                               scheme="ozaki2_fp64", cache=cache,
                               device_kind="cpu")
    assert got.scheme == "ozaki2_fp64" and cache.hits == 1
    # a scheme-I request never sees it (distinct key)
    got1 = select_pipeline_plan(8, 16, 96, accum="f64", cache=cache,
                                device_kind="cpu")
    assert got1.scheme == "ozaki_fp64"


def test_target_pinned_hit_accepts_other_family():
    # under a target EITHER family meeting the bound is an acceptable
    # hit (the target is the contract, not the family)
    cache = PlanCache()
    k = 4096
    tgt = _matched_target(k, 7)
    key1 = plan_cache_key(64, 64, k, accum="f64", device_kind="cpu")
    p2 = modular_plan(k, target_error=tgt)
    assert plan_meets_target(p2, k, tgt)
    cache.put(key1, p2)                          # II cached under I's key
    got = select_pipeline_plan(64, 64, k, accum="f64", target_error=tgt,
                               cache=cache, device_kind="cpu")
    assert got == p2 and cache.hits == 1


# ----------------------------------------------------------------------------
# PipelinePlan validation for the new scheme
# ----------------------------------------------------------------------------

def test_pipeline_plan_scheme2_validation():
    good = modular_plan(96)
    assert good.fusion in ("none", "stages")
    # the fused-CRT epilogue is a first-class Scheme II fusion mode now
    epi = dataclasses.replace(good, backend="pallas_fused",
                              fusion="epilogue")
    assert epi.fusion == "epilogue" and epi.num_gemms == good.num_gemms
    with pytest.raises(ValueError):
        dataclasses.replace(good, accum="df32")
    with pytest.raises(ValueError):
        dataclasses.replace(good, fusion="streaming")
    with pytest.raises(ValueError):
        dataclasses.replace(good, pair_policy="diagonal")
    with pytest.raises(ValueError):
        dataclasses.replace(good, beta=0)
    with pytest.raises(ValueError):
        PipelinePlan(scheme="nope")


def test_modular_plan_fuse_epilogue_threading():
    plan = modular_plan(96, backend="pallas_fused", fuse_epilogue=True)
    assert plan.fusion == "epilogue"
    with pytest.raises(ValueError, match="pallas_fused"):
        modular_plan(96, backend="xla", fuse_epilogue=True)
    # select_pipeline_plan's default (pallas_fused + fuse_epilogue=True)
    # now lands on the fused-CRT plan; fuse_epilogue=False keeps stages
    sel = select_pipeline_plan(8, 16, 96, accum="f64",
                               scheme="ozaki2_fp64")
    assert sel.fusion == "epilogue"
    sel2 = select_pipeline_plan(8, 16, 96, accum="f64",
                                scheme="ozaki2_fp64", fuse_epilogue=False)
    assert sel2.fusion == "stages"
    with pytest.raises(ValueError, match="streaming"):
        select_pipeline_plan(8, 16, 96, accum="f64",
                             scheme="ozaki2_fp64", streaming=True)
