"""Property-based tests for Scheme II (hypothesis).

Randomized exponent ranges, shapes, and value signs for the modulus
split -> residue GEMM -> CRT reconstruction pipeline; skipped cleanly
when hypothesis is unavailable (deterministic counterparts of the same
claims run in ``test_modular.py``).
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis "
                           "(pip install -r requirements-test.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.accuracy import scaled_error  # noqa: E402
from repro.core.modular import (ModularConfig, crt_digits,  # noqa: E402
                                modular_error_bound, ozaki2_matmul,
                                residues_from_slices, select_moduli,
                                usable_moduli)
from repro.core.splitting import split_int  # noqa: E402
from repro.core.xmath import dd_matmul_np  # noqa: E402

dims = st.integers(1, 16)
phis = st.floats(0.0, 4.0)      # exponent spread: up to e^{4 sigma}


def _mat(seed, m, k, phi):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(-0.5, 0.5, (m, k))
                       * np.exp(phi * rng.standard_normal((m, k))))


@given(seed=st.integers(0, 2 ** 31), rows=dims, k=dims,
       shift=st.integers(-60, 60))
@settings(max_examples=25, deadline=None)
def test_residues_match_integerization_any_exponent_range(seed, rows, k,
                                                          shift):
    # split_int's integerization at ANY exponent scale: the residues of
    # the slice-built integer match python-int arithmetic exactly
    w, s = 7, 6
    x = _mat(seed, rows, k, 1.0) * 2.0 ** shift
    res = split_int(x, s, w)
    moduli = usable_moduli(max(k, 1))[:10]
    slices = np.asarray(res.slices, np.int64)
    x_int = sum(slices[p].astype(object) * 2 ** ((s - 1 - p) * w)
                for p in range(s))
    got = residues_from_slices(res.slices, w, moduli)
    for j, m in enumerate(moduli):
        want = x_int % m
        want = np.where(want > (m - 1) // 2, want - m, want)
        np.testing.assert_array_equal(np.asarray(got[j], object), want)


@given(seed=st.integers(0, 2 ** 31), n=st.integers(1, 64),
       beta=st.integers(7, 70))
@settings(max_examples=25, deadline=None)
def test_crt_digits_reconstruct_exactly(seed, n, beta):
    k = 32
    moduli = select_moduli(k, min(beta, 56))
    big = 1
    for m in moduli:
        big *= m
    rng = np.random.default_rng(seed)
    lo, hi = -(big // 2), big // 2
    xs = [int(rng.integers(-2 ** 62, 2 ** 62)) % (hi - lo) + lo
          for _ in range(n)]
    res = np.stack([[x % m for x in xs] for m in moduli])
    res = np.where(res > (np.asarray(moduli)[:, None] - 1) // 2,
                   res - np.asarray(moduli)[:, None], res)
    digits = crt_digits(jnp.asarray(res.astype(np.int32)), moduli)
    prefix = [1]
    for m in moduli[:-1]:
        prefix.append(prefix[-1] * m)
    got = [sum(int(np.asarray(d)[i]) * q
               for d, q in zip(digits, prefix)) for i in range(n)]
    assert got == xs


@given(seed=st.integers(0, 2 ** 31), m=dims, k=dims, n=dims, phi=phis,
       negate=st.booleans(), zero_row=st.booleans())
@settings(max_examples=15, deadline=None)
def test_end_to_end_bound_random_exponent_ranges(seed, m, k, n, phi,
                                                 negate, zero_row):
    a = np.array(_mat(seed, m, k, phi))
    b = np.array(_mat(seed + 1, k, n, phi))
    if negate:
        a = -np.abs(a)
    if zero_row:
        a[0] = 0.0
    cfg = ModularConfig()
    point = cfg.point(k)
    c = np.asarray(ozaki2_matmul(jnp.asarray(a), jnp.asarray(b), cfg))
    assert np.all(np.isfinite(c))
    hi, lo = dd_matmul_np(a, b)
    err = scaled_error(c, hi, a, b, ref_lo=lo)
    assert err <= modular_error_bound(point.beta, k, point.moduli)
    if zero_row:
        np.testing.assert_array_equal(c[0], 0.0)
