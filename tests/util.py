"""Helpers for tests that need multiple (host CPU) devices.

jax locks the device count at first init, so multi-device tests run in a
subprocess with XLA_FLAGS set. Scripts print their assertions; a
non-zero exit fails the test with the captured output.
"""
from __future__ import annotations

import os
import subprocess
import sys

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_multidevice(script: str, n_devices: int = 8,
                    timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = REPO_SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout
