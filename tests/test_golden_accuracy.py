"""Golden accuracy-regression pins: max relative error vs ``dgemm_f64``.

Fig. 6 of the paper sweeps the exponent distribution width phi (Eq. 6
inputs) against the split count. These tests pin the measured error of
the current implementation (fixed seed, ~3-4x headroom) for
num_splits in {5, 9, 13}, so a future kernel/accumulation refactor that
silently loses mantissa bits fails loudly instead of drifting.

The pins are against the plain FP64 GEMM (the replacement target), so
at s >= 9 the bound includes dgemm's own rounding (~1e-13 at k = 128) —
the Ozaki result itself is *more* accurate than the reference there
(see test_zero_cancellation_beats_dgemm).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ozaki import OzakiConfig, dgemm_f64, ozaki_matmul

# (num_splits, phi) -> pinned max relative error vs dgemm_f64.
# Measured with seed 42 at (m, k, n) = (32, 128, 24), pins ~4x measured:
#   s=5:  3.3e-08 / 1.1e-05 / 1.7e-04
#   s=9:  4.1e-14 / 3.9e-13 / 2.1e-12
#   s=13: 4.1e-14 / 3.8e-13 / 4.1e-13
GOLDEN = {
    (5, 0.1): 1.5e-07,
    (5, 1.0): 5.0e-05,
    (5, 2.0): 7.0e-04,
    (9, 0.1): 2.0e-13,
    (9, 1.0): 1.5e-12,
    (9, 2.0): 8.0e-12,
    (13, 0.1): 2.0e-13,
    (13, 1.0): 1.5e-12,
    (13, 2.0): 1.6e-12,
}


def _phi_case(phi):
    rng = np.random.default_rng(42)
    a = jnp.asarray(rng.uniform(-0.5, 0.5, (32, 128))
                    * np.exp(phi * rng.standard_normal((32, 128))))
    b = jnp.asarray(rng.uniform(-0.5, 0.5, (128, 24))
                    * np.exp(phi * rng.standard_normal((128, 24))))
    return a, b


def _max_rel_err(c, ref):
    denom = np.where(ref == 0.0, 1.0, np.abs(ref))
    return float(np.max(np.abs(c - ref) / denom))


@pytest.mark.parametrize("num_splits,phi,bound",
                         [(s, p, b) for (s, p), b in sorted(GOLDEN.items())])
def test_golden_max_rel_error(num_splits, phi, bound):
    a, b = _phi_case(phi)
    c = np.asarray(ozaki_matmul(a, b, OzakiConfig(num_splits=num_splits)))
    ref = np.asarray(dgemm_f64(a, b))
    err = _max_rel_err(c, ref)
    assert err <= bound, (num_splits, phi, err, bound)


def test_more_splits_never_worse_by_much():
    """Monotonicity sanity across the pinned split counts (phi = 1)."""
    a, b = _phi_case(1.0)
    ref = np.asarray(dgemm_f64(a, b))
    errs = {s: _max_rel_err(
        np.asarray(ozaki_matmul(a, b, OzakiConfig(num_splits=s))), ref)
        for s in (5, 9, 13)}
    assert errs[9] < errs[5] * 1e-3
    # at s >= 9 both sit at dgemm's own rounding floor
    assert errs[13] < 1e-11
