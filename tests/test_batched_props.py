"""Property-based accuracy tests for the batched Ozaki API (hypothesis).

Randomized shapes/batch sizes/exponent spreads; skipped cleanly when
hypothesis is unavailable (deterministic counterparts of the same claims
run in ``test_batched_api.py``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis "
                           "(pip install -r requirements-test.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.ozaki import (OzakiConfig, ozaki_matmul,  # noqa: E402
                              ozaki_matmul_batched)

dims = st.integers(1, 24)
batches = st.integers(1, 4)
phis = st.floats(0.0, 2.0)


def _stack(seed, bsz, m, k, phi):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(-0.5, 0.5, (bsz, m, k))
                       * np.exp(phi * rng.standard_normal((bsz, m, k))))


@given(seed=st.integers(0, 2 ** 31), m=dims, k=dims, n=dims, phi=phis)
@settings(max_examples=20, deadline=None)
def test_batch_of_one_equals_unbatched(seed, m, k, n, phi):
    cfg = OzakiConfig(num_splits=9)
    a = _stack(seed, 1, m, k, phi)
    b = _stack(seed + 1, 1, k, n, phi)
    got = np.asarray(ozaki_matmul_batched(a, b, cfg))
    np.testing.assert_array_equal(got[0],
                                  np.asarray(ozaki_matmul(a[0], b[0], cfg)))


@given(seed=st.integers(0, 2 ** 31), bsz=batches, m=dims, k=dims, n=dims,
       phi=phis)
@settings(max_examples=20, deadline=None)
def test_broadcast_weights_equals_loop(seed, bsz, m, k, n, phi):
    cfg = OzakiConfig(num_splits=9)
    a = _stack(seed, bsz, m, k, phi)
    w = _stack(seed + 1, 1, k, n, phi)[0]
    got = np.asarray(ozaki_matmul_batched(a, w, cfg))
    want = np.stack([np.asarray(ozaki_matmul(a[i], w, cfg))
                     for i in range(bsz)])
    np.testing.assert_array_equal(got, want)


@given(seed=st.integers(0, 2 ** 31), bsz=batches, m=dims, k=dims, n=dims)
@settings(max_examples=10, deadline=None)
def test_jit_grad_dtypes_survive(seed, bsz, m, k, n):
    cfg = OzakiConfig(num_splits=9)
    a = _stack(seed, bsz, m, k, 0.5)
    w = _stack(seed + 1, 1, k, n, 0.5)[0]
    out = jax.jit(lambda x, y: ozaki_matmul_batched(x, y, cfg))(a, w)
    assert out.dtype == jnp.float64 and out.shape == (bsz, m, n)
    ga, gw = jax.jit(jax.grad(
        lambda x, y: jnp.sum(ozaki_matmul_batched(x, y, cfg)),
        argnums=(0, 1)))(a, w)
    assert ga.dtype == a.dtype and ga.shape == a.shape
    assert gw.dtype == w.dtype and gw.shape == w.shape
    # d/dA sum(A @ w) = broadcast of row sums of w
    np.testing.assert_allclose(
        np.asarray(ga),
        np.broadcast_to(np.asarray(w).sum(axis=1), (bsz, m, k)),
        rtol=1e-12, atol=1e-12)
