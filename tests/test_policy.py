"""The public API façade: ``MatmulPolicy`` spec round-trips, legacy
ArchConfig field conversion, and the ``repro.matmul`` parity matrix.

Acceptance contract (ISSUE 5): ``repro.matmul(a, b, precision=spec)`` is
bitwise-identical to the corresponding legacy entry point for every row
of the backend-parity matrix (xla/pallas/fused/epilogue/batch-grid,
batched and fast-mode included), and legacy ``ozaki_*`` ArchConfig
fields still work, emitting exactly one DeprecationWarning.
"""
import dataclasses
import itertools
import json
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.api import (MatmulPolicy, default_policy, policy_from_legacy_fields,
                       policy_of)
from repro.configs.base import ArchConfig
from repro.core.ozaki import (OzakiConfig, ozaki_matmul,
                              ozaki_matmul_batched, ozaki_matmul_complex,
                              ozaki_matmul_dw)
from repro.core.xmath import DW, df32_from_f64, df32_to_f64


def _phi_matrix(rng, m, k, phi=1.0):
    return jnp.asarray(rng.uniform(-0.5, 0.5, (m, k))
                       * np.exp(phi * rng.standard_normal((m, k))))


def _dense_cfg(**kw):
    return ArchConfig(name="t", family="dense", num_layers=1, d_model=32,
                      num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=64,
                      **kw)


# ----------------------------------------------------------------------------
# Spec parse / format / JSON round-trips
# ----------------------------------------------------------------------------

ROUND_TRIP_SPECS = [
    "bf16",
    "int8-quant",
    "ozaki-fp64",
    "ozaki-fp64x9",
    "ozaki-fp64@1e-25:fast/pallas_fused+epilogue",
    "ozaki-fp64x7:budget:12/pallas|shard=data|cache=plans.json|autotune",
    "ozaki-fp64:diagonal",
    "ozaki-fp64x5@2.5e-09:fast,budget:7/pallas_fused",
    "ozaki-fp64x9|shard=model|comm=int8",
    "ozaki-fp64/pallas_fused+epilogue|shard=model|comm=int8",
    "ozaki2-fp64",
    "ozaki2-fp64x15",
    "ozaki2-fp64/pallas_fused+epilogue",
    "ozaki2-fp64|shard=model|comm=int8",
    "ozaki2-fp64/pallas_fused+epilogue|shard=model|comm=int8",
]


@pytest.mark.parametrize("spec", ROUND_TRIP_SPECS)
def test_spec_round_trip(spec):
    pol = MatmulPolicy.parse(spec)
    assert MatmulPolicy.parse(pol.spec()) == pol
    assert str(pol) == pol.spec()
    # JSON round-trip through plain dicts
    via_json = MatmulPolicy.from_dict(json.loads(json.dumps(pol.to_dict())))
    assert via_json == pol
    assert via_json.spec() == pol.spec()


def test_spec_canonicalizes_aliases():
    """Underscore spellings and the legacy matmul_precision vocabulary
    parse to the same policy as the canonical dashed spec."""
    assert MatmulPolicy.parse("ozaki_fp64") == MatmulPolicy.parse(
        "ozaki-fp64")
    assert MatmulPolicy.parse("int8_quant") == MatmulPolicy.parse(
        "int8-quant")
    assert (MatmulPolicy.parse("ozaki-fp64/pallas-fused").backend
            == "pallas_fused")
    # parse is cached — identical spec strings share one frozen instance
    assert MatmulPolicy.parse("ozaki-fp64x9") is MatmulPolicy.parse(
        "ozaki-fp64x9")


def test_spec_field_mapping():
    pol = MatmulPolicy.parse(
        "ozaki-fp64x7@1e-25:fast,budget:12/pallas_fused+epilogue"
        "|shard=data|cache=/tmp/p.json|autotune")
    assert pol.scheme == "ozaki_fp64"
    assert pol.num_splits == 7
    assert pol.target_error == 1e-25
    assert pol.fast_mode and pol.pair_policy == "budget:12"
    assert pol.backend == "pallas_fused" and pol.fuse_epilogue
    assert pol.shard_axis == "data"
    assert pol.plan_cache == "/tmp/p.json"
    assert pol.autotune


@pytest.mark.parametrize("bad", [
    "",                              # empty
    "nope",                          # unknown scheme
    "bf16x9",                        # splits on a non-ozaki scheme
    "bf16@1e-10",                    # target on a non-ozaki scheme
    "bf16/pallas_fused",             # backend on a non-ozaki scheme
    "ozaki-fp64x0",                  # num_splits < 1
    "ozaki-fp64@abc",                # malformed target
    "ozaki-fp64@-1e-3",              # non-positive target
    "ozaki-fp64:warp",               # unknown mode
    "ozaki-fp64:budget:0",           # non-positive pair budget
    "ozaki-fp64:budget:x",           # malformed pair budget
    "ozaki-fp64:diagonal,budget:3",  # conflicting pair policies
    "ozaki-fp64:full,budget:3",      # conflicting, order-independent
    "ozaki-fp64:budget:3,full",      # conflicting, order-independent
    "ozaki-fp64/cuda",               # unknown backend
    "ozaki-fp64|wat=1",              # unknown option
    "ozaki-fp64|comm=fp8",           # unknown comm mode
    "bf16|comm=int8",                # comm on a non-ozaki scheme
])
def test_malformed_specs_rejected(bad):
    with pytest.raises(ValueError):
        MatmulPolicy.parse(bad)


def test_policy_object_validation_matches_spec_validation():
    """The validation that used to live in OzakiConfig/ArchConfig/serve
    flag handling is centralized on the policy object itself."""
    with pytest.raises(ValueError, match="unknown backend"):
        MatmulPolicy(backend="cuda")
    with pytest.raises(ValueError, match="unknown scheme"):
        MatmulPolicy(scheme="fp8")
    with pytest.raises(ValueError, match="target_error"):
        MatmulPolicy(target_error=0.0)
    with pytest.raises(ValueError, match="pair"):
        MatmulPolicy(pair_policy="budget:-3")
    with pytest.raises(ValueError, match="only applies"):
        MatmulPolicy(scheme="bf16", fuse_epilogue=True)


def test_policy_of_coercion():
    pol = MatmulPolicy.parse("ozaki-fp64x9")
    assert MatmulPolicy.of(pol) is pol
    assert MatmulPolicy.of("ozaki-fp64x9") == pol
    assert MatmulPolicy.of(None) == default_policy()
    with pytest.raises(TypeError):
        MatmulPolicy.of(9)


# ----------------------------------------------------------------------------
# Ambient default (context manager) + plan-cache scoping
# ----------------------------------------------------------------------------

def test_default_matmul_precision_scopes_policy():
    base = default_policy()
    with repro.default_matmul_precision("ozaki-fp64x5") as pol:
        assert default_policy() == pol
        assert pol.num_splits == 5
        with repro.default_matmul_precision("bf16"):
            assert default_policy().scheme == "bf16"
        assert default_policy() == pol           # inner scope restored
    assert default_policy() == base


def test_default_matmul_precision_scopes_plan_cache(tmp_path):
    """A policy naming a cache path subsumes use_plan_cache: the ambient
    core.autotune registry holds the loaded cache for the scope."""
    from repro.core.autotune import active_plan_cache
    path = tmp_path / "plans.json"
    assert active_plan_cache() is None
    with repro.default_matmul_precision(f"ozaki-fp64|cache={path}"):
        cache = active_plan_cache()
        assert cache is not None and cache.path == str(path)
    assert active_plan_cache() is None


# ----------------------------------------------------------------------------
# Legacy ArchConfig field conversion
# ----------------------------------------------------------------------------

def test_legacy_fields_convert_and_warn_exactly_once():
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        cfg = _dense_cfg(matmul_precision="ozaki_fp64",
                         ozaki_backend="pallas_fused",
                         ozaki_fuse_epilogue=True, ozaki_splits=7,
                         ozaki_target_error=1e-8, ozaki_fast_mode=True,
                         ozaki_shard_axis="model")
        # a second legacy config: the one-shot latch keeps it silent
        _dense_cfg(matmul_precision="ozaki_fp64", ozaki_splits=5)
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1
    assert "matmul_policy=" in str(dep[0].message)
    pol = cfg.policy()
    assert pol == MatmulPolicy.parse(
        "ozaki-fp64x7@1e-08:fast/pallas_fused+epilogue|shard=model")
    # the derivation round-trips through the spec the warning suggested
    assert policy_of(dataclasses.replace(cfg, matmul_policy=pol.spec(),
                                         )) == pol


def test_default_legacy_fields_do_not_warn():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        cfg = _dense_cfg()                       # all-default: no warning
        cfg.reduced()                            # asdict round-trip too
    assert cfg.policy().scheme == "bf16"


def test_matmul_policy_field_is_authoritative():
    """matmul_policy back-fills matmul_precision + every legacy ozaki_*
    field, so pre-PR-5 readers see a consistent config — silently."""
    spec = "ozaki-fp64x7@1e-08:fast/pallas_fused+epilogue|shard=model"
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        cfg = _dense_cfg(matmul_policy=spec)
    assert cfg.matmul_precision == "ozaki_fp64"
    assert cfg.ozaki_backend == "pallas_fused"
    assert cfg.ozaki_splits == 7
    assert cfg.ozaki_fuse_epilogue
    assert cfg.ozaki_target_error == 1e-8
    assert cfg.ozaki_fast_mode
    assert cfg.ozaki_shard_axis == "model"
    assert cfg.policy() == MatmulPolicy.parse(spec)
    # asdict/replace round-trips (reduced()) keep the spec authoritative
    red = cfg.reduced()
    assert red.policy() == MatmulPolicy.parse(spec)


def test_policy_from_legacy_fields_drops_ozaki_knobs_for_bf16():
    cfg = _dense_cfg(matmul_precision="bf16", ozaki_splits=5)
    assert policy_from_legacy_fields(cfg) == MatmulPolicy(scheme="bf16")


# ----------------------------------------------------------------------------
# Parity matrix: repro.matmul == the legacy entry points, bitwise
# ----------------------------------------------------------------------------

BACKEND_SPECS = {
    "xla": dict(backend="xla"),
    "pallas": dict(backend="pallas"),
    "pallas_fused": dict(backend="pallas_fused"),
    "pallas_fused+epilogue": dict(backend="pallas_fused",
                                  fuse_epilogue=True),
}


def _spec_for(backend_key: str, prefix: str) -> str:
    return (prefix + "/" + backend_key) if backend_key != "xla" else prefix


@pytest.mark.parametrize("backend", sorted(BACKEND_SPECS))
def test_matmul_parity_unbatched_f64(rng, backend):
    a = _phi_matrix(rng, 24, 96)
    b = _phi_matrix(rng, 96, 16)
    got = repro.matmul(a, b, precision=_spec_for(backend, "ozaki-fp64x9"))
    legacy = ozaki_matmul(a, b, OzakiConfig(num_splits=9,
                                            **BACKEND_SPECS[backend]))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(legacy))


@pytest.mark.parametrize("backend", sorted(BACKEND_SPECS))
def test_matmul_parity_unbatched_f32(rng, backend):
    """2-D f32 dispatch: the TPU-native df32 pipeline, f32 out."""
    a = _phi_matrix(rng, 16, 64, 0.5).astype(jnp.float32)
    b = _phi_matrix(rng, 64, 8, 0.5).astype(jnp.float32)
    got = repro.matmul(a, b, precision=_spec_for(backend, "ozaki-fp64x7"))
    assert got.dtype == jnp.float32
    cfg = OzakiConfig(num_splits=7, accum="df32", **BACKEND_SPECS[backend])
    from repro.core.xmath import dw_to_single
    legacy = dw_to_single(ozaki_matmul_dw(
        DW(a, jnp.zeros_like(a)), DW(b.T, jnp.zeros_like(b.T)), cfg))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(legacy))


@pytest.mark.parametrize("backend,stacked", list(itertools.product(
    sorted(BACKEND_SPECS), [True, False])))
def test_matmul_parity_batched(rng, backend, stacked):
    """3-D dispatch: stacked weights (batch-grid kernels) and broadcast
    weights (rows fold) both route through ozaki_matmul_batched."""
    a = jnp.stack([_phi_matrix(rng, 9, 33) for _ in range(3)])
    b = (jnp.stack([_phi_matrix(rng, 33, 11) for _ in range(3)])
         if stacked else _phi_matrix(rng, 33, 11))
    got = repro.matmul(a, b, precision=_spec_for(backend, "ozaki-fp64x7"))
    legacy = ozaki_matmul_batched(
        a, b, OzakiConfig(num_splits=7, **BACKEND_SPECS[backend]))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(legacy))


@pytest.mark.parametrize("backend", sorted(BACKEND_SPECS))
@pytest.mark.parametrize("mode", ["fast", "diagonal", "budget:7"])
def test_matmul_parity_fast_mode(rng, backend, mode):
    """Fast-mode rows of the acceptance matrix: truncated schedules stay
    bitwise-identical between the façade and the legacy driver."""
    a = _phi_matrix(rng, 24, 96)
    b = _phi_matrix(rng, 96, 16)
    spec = _spec_for(backend, f"ozaki-fp64x9@1e-06:{mode}")
    if mode == "fast":
        cfg = OzakiConfig(num_splits=9, target_error=1e-6, fast_mode=True,
                          **BACKEND_SPECS[backend])
    else:
        cfg = OzakiConfig(num_splits=9, target_error=1e-6,
                          pair_policy=mode, **BACKEND_SPECS[backend])
    got = repro.matmul(a, b, precision=spec)
    legacy = ozaki_matmul(a, b, cfg)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(legacy))


def test_matmul_parity_fast_mode_batch_grid(rng):
    """Fast mode on the batch-grid epilogue kernel through the façade."""
    a = jnp.stack([_phi_matrix(rng, 9, 33) for _ in range(3)])
    b = jnp.stack([_phi_matrix(rng, 33, 11) for _ in range(3)])
    got = repro.matmul(
        a, b,
        precision="ozaki-fp64x7:diagonal/pallas_fused+epilogue")
    legacy = ozaki_matmul_batched(
        a, b, OzakiConfig(num_splits=7, pair_policy="diagonal",
                          backend="pallas_fused", fuse_epilogue=True))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(legacy))


def test_matmul_parity_dw(rng):
    """DW-ness dispatch: natural-orientation operands reach the legacy
    transposed-B entry bitwise (transposition is a permutation)."""
    a = df32_from_f64(_phi_matrix(rng, 16, 64, 0.5))
    b_t = df32_from_f64(_phi_matrix(rng, 8, 64, 0.5))          # (n, k)
    b = DW(b_t.hi.T, b_t.lo.T)                                 # (k, n)
    got = repro.matmul(a, b, precision="ozaki-fp64x9/pallas_fused")
    legacy = ozaki_matmul_dw(a, b_t, OzakiConfig(num_splits=9,
                                                 accum="df32",
                                                 backend="pallas_fused"))
    np.testing.assert_array_equal(np.asarray(df32_to_f64(got)),
                                  np.asarray(df32_to_f64(legacy)))


def test_matmul_parity_complex(rng):
    a = (_phi_matrix(rng, 12, 48) + 1j * _phi_matrix(rng, 12, 48))
    b = (_phi_matrix(rng, 48, 10) + 1j * _phi_matrix(rng, 48, 10))
    got = repro.matmul(a, b, precision="ozaki-fp64x9")
    legacy = ozaki_matmul_complex(a, b, OzakiConfig(num_splits=9))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(legacy))


def test_matmul_parity_scheme2_routes(rng):
    """ISSUE 9: the unified Scheme II facade. The fused-CRT ``+epilogue``
    spec is bitwise-equal to the unfused XLA reference; complex128 and
    float32 operands route to the residue decomposition drivers instead
    of the stale rejections."""
    from repro.core.modular import (ModularConfig, ozaki2_matmul,
                                    ozaki2_matmul_complex,
                                    ozaki2_matmul_df32)
    a, b = _phi_matrix(rng, 12, 96), _phi_matrix(rng, 96, 10)
    ref = ozaki2_matmul(a, b, ModularConfig())
    got = repro.matmul(a, b, precision="ozaki2-fp64/pallas_fused+epilogue")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    # complex128 — 2x2 real block decomposition over residue GEMMs
    ac = _phi_matrix(rng, 12, 48) + 1j * _phi_matrix(rng, 12, 48)
    bc = _phi_matrix(rng, 48, 10) + 1j * _phi_matrix(rng, 48, 10)
    gotc = repro.matmul(ac, bc, precision="ozaki2-fp64")
    legc = ozaki2_matmul_complex(ac, bc, ModularConfig())
    np.testing.assert_array_equal(np.asarray(gotc), np.asarray(legc))
    # float32 — df32 reconstruction target
    a32, b32 = a.astype(jnp.float32), b.astype(jnp.float32)
    got32 = repro.matmul(a32, b32, precision="ozaki2-fp64")
    leg32 = ozaki2_matmul_df32(a32, b32, ModularConfig())
    assert got32.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(got32), np.asarray(leg32))


def test_scheme2_rejection_table_is_current():
    """ISSUE 9 satellite: the rejection table only names knobs Scheme II
    truly lacks — the stale complex/df32 entries (and their 'no complex
    path yet' message) are gone, and what remains points at the
    supported alternative."""
    from repro.api import _OZAKI2_REJECTED
    assert set(_OZAKI2_REJECTED) == {"streaming", "fast_mode",
                                     "pair_policy"}
    assert not any("complex" in why for why in _OZAKI2_REJECTED.values())
    with pytest.raises(ValueError, match="streaming.*\\+epilogue|"
                                         "\\+epilogue.*streaming"):
        MatmulPolicy.parse("ozaki2-fp64/pallas_fused+streaming")
    with pytest.raises(ValueError, match="no pair schedule"):
        MatmulPolicy.parse("ozaki2-fp64:fast")
    with pytest.raises(ValueError, match="no pair schedule"):
        MatmulPolicy.parse("ozaki2-fp64:diagonal")


def test_matmul_bf16_and_int8_schemes(rng):
    a = _phi_matrix(rng, 8, 32).astype(jnp.float32)
    b = _phi_matrix(rng, 32, 8).astype(jnp.float32)
    got = repro.matmul(a, b, precision="bf16")
    ref = jnp.matmul(a.astype(jnp.bfloat16), b.astype(jnp.bfloat16),
                     preferred_element_type=jnp.float32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    from repro.models.layers import _matmul_int8_quant
    got8 = repro.matmul(a, b, precision="int8-quant")
    np.testing.assert_array_equal(np.asarray(got8),
                                  np.asarray(_matmul_int8_quant(a, b)))


def test_matmul_rejects_mixed_and_integer_dtypes(rng):
    """The front door validates operands instead of silently degrading
    an f64 @ f32 call to f32-grade accuracy."""
    a64 = _phi_matrix(rng, 8, 32)
    b32 = _phi_matrix(rng, 32, 8).astype(jnp.float32)
    with pytest.raises(TypeError, match="dtype mismatch"):
        repro.matmul(a64, b32, precision="ozaki-fp64x5")
    with pytest.raises(TypeError, match="float32/float64"):
        repro.matmul(jnp.ones((4, 4), jnp.int32),
                     jnp.ones((4, 4), jnp.int32), precision="ozaki-fp64")


def test_archconfig_pinned_splits_with_auto_spec_warns():
    """ozaki_splits alongside an auto-split spec cannot be back-filled:
    the config must say so instead of silently running a different
    split count than the legacy field reads."""
    with pytest.warns(UserWarning, match="ozaki_splits=13 is ignored"):
        cfg = _dense_cfg(matmul_policy="ozaki-fp64@1e-25",
                         ozaki_splits=13)
    assert cfg.policy().num_splits is None       # the spec wins


def test_matmul_rejects_3d_complex(rng):
    """Batched complex has no pipeline: reject clearly at the front
    door instead of crashing inside the splitting stage."""
    a = jnp.stack([_phi_matrix(rng, 4, 16) + 1j * _phi_matrix(rng, 4, 16)
                   for _ in range(2)])
    b = _phi_matrix(rng, 16, 4) + 1j * _phi_matrix(rng, 16, 4)
    with pytest.raises(ValueError, match="complex operands must be 2-D"):
        repro.matmul(a, b, precision="ozaki-fp64x5")


def test_matmul_shard_axis_no_mesh_is_bitwise_noop(rng):
    """|shard=AXIS| without a registered mesh: constraints are skipped,
    results identical to the unsharded spec (the mesh-active case is
    covered by tests/test_distributed.py)."""
    a = _phi_matrix(rng, 8, 64)
    b = _phi_matrix(rng, 64, 8)
    got = repro.matmul(a, b, precision="ozaki-fp64x7|shard=model")
    base = repro.matmul(a, b, precision="ozaki-fp64x7")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(base))


def test_matmul_rejects_bad_ranks(rng):
    a = _phi_matrix(rng, 8, 32)
    with pytest.raises(ValueError, match="2-D or 3-D"):
        repro.matmul(a.reshape(8, 32, 1, 1)[..., 0, 0].reshape(2, 2, 8, 8),
                     a, precision="ozaki-fp64")
    with pytest.raises(TypeError, match="DW"):
        repro.matmul(DW(a.astype(jnp.float32),
                        jnp.zeros((8, 32), jnp.float32)), a,
                     precision="ozaki-fp64")


# ----------------------------------------------------------------------------
# policy_matmul / engine integration through the one policy object
# ----------------------------------------------------------------------------

def test_policy_matmul_spec_config_matches_legacy_config(rng):
    """A policy-spec ArchConfig and its legacy-field equivalent drive
    policy_matmul to bitwise-identical results."""
    from repro.models.layers import policy_matmul
    x = _phi_matrix(rng, 6, 64, 0.5).astype(jnp.float32)
    w = _phi_matrix(rng, 64, 16, 0.5).astype(jnp.float32)
    new = _dense_cfg(matmul_policy="ozaki-fp64x7/pallas_fused+epilogue")
    old = _dense_cfg(matmul_precision="ozaki_fp64", ozaki_splits=7,
                     ozaki_backend="pallas_fused",
                     ozaki_fuse_epilogue=True)
    np.testing.assert_array_equal(np.asarray(policy_matmul(new, x, w)),
                                  np.asarray(policy_matmul(old, x, w)))


def test_engine_policy_kwarg_equals_legacy_kwargs():
    cfg = _dense_cfg().reduced()
    from repro.serving.engine import ServingEngine
    from repro.models import init_model
    import jax
    params, _ = init_model(cfg, jax.random.key(0))
    e_new = ServingEngine(cfg, params, num_slots=2, max_len=32,
                          policy="ozaki-fp64x5/pallas_fused")
    e_old = ServingEngine(cfg, params, num_slots=2, max_len=32,
                          matmul_precision="ozaki_fp64",
                          ozaki_backend="pallas_fused")
    e_old.cfg = dataclasses.replace(e_old.cfg, ozaki_splits=5)
    assert e_new.cfg.matmul_precision == "ozaki_fp64"
    assert e_new.cfg.ozaki_backend == "pallas_fused"
    assert e_new.cfg.ozaki_splits == 5
    assert e_new.cfg.policy().num_splits == 5
    with pytest.raises(ValueError, match="not both"):
        ServingEngine(cfg, params, num_slots=2, max_len=32,
                      policy="bf16", matmul_precision="bf16")


def test_engine_legacy_kwarg_preserves_spec_only_knobs():
    """A per-knob legacy override on a policy-configured config merges
    into the spec: pair_policy and the auto split count survive."""
    cfg = dataclasses.replace(
        _dense_cfg(matmul_policy="ozaki-fp64@1e-25:budget:12").reduced())
    from repro.serving.engine import ServingEngine
    from repro.models import init_model
    import jax
    params, _ = init_model(cfg, jax.random.key(0))
    eng = ServingEngine(cfg, params, num_slots=2, max_len=32,
                        ozaki_fast_mode=True)
    pol = eng.cfg.policy()
    assert pol.fast_mode                         # the override applied
    assert pol.pair_policy == "budget:12"        # spec-only knob kept
    assert pol.num_splits is None                # auto count kept
    assert pol.target_error == 1e-25


def test_plan_cache_memo_reloads_on_file_change(tmp_path):
    """The per-path cache memo must follow the file: plans persisted
    mid-process (engine pre-warm, --autotune) reach later loads."""
    from repro.api import _load_plan_cache
    from repro.core.autotune import PlanCache, plan_cache_key
    from repro.core.tuning import PipelinePlan
    path = str(tmp_path / "plans.json")
    first = _load_plan_cache(path)               # missing file: empty
    assert len(first) == 0
    writer = PlanCache(path)
    writer.put(plan_cache_key(8, 8, 64, dtype="float32", backend="xla"),
               PipelinePlan(backend="xla"))
    writer.save()
    second = _load_plan_cache(path)
    assert second is not first and len(second) == 1
    assert _load_plan_cache(path) is second      # unchanged file: memo hit


# ----------------------------------------------------------------------------
# Shared warn-once helper
# ----------------------------------------------------------------------------

def test_warn_once_latch_is_resettable():
    from repro.core.warn_once import WarnOnceLatch, reset_all_warn_latches
    latch = WarnOnceLatch("test_latch")
    with pytest.warns(UserWarning, match="hello"):
        assert latch.warn("k", "hello")
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert not latch.warn("k", "hello")      # latched: silent
    reset_all_warn_latches()
    with pytest.warns(UserWarning, match="hello"):
        assert latch.warn("k", "hello")          # fresh state: refires
