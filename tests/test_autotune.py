"""Measured-plan autotuner + persistent plan cache (ISSUE 3).

Covers the PlanCache contract (round-trip, hit/miss accounting, version
mismatch and corrupted-file fallback to analytic planning), the
``select_pipeline_plan`` cache/autotune integration, result-invariance
of the candidate space (a cached/tuned plan is bitwise-equal to the
analytic plan's results), the tiny-candidate-set measurement smoke that
exercises the timing path on every PR, and the serving engine's
startup pre-warm (steady-state serving never tunes on the request
path).
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune as at
from repro.core.autotune import (PLAN_CACHE_VERSION, AutotuneReport,
                                 PlanCache, PlanKey, autotune_plan,
                                 candidate_plans, measure_plan,
                                 plan_cache_key, use_plan_cache)
from repro.core.ozaki import OzakiConfig, ozaki_matmul
from repro.core.tuning import (PipelinePlan, TilePlan, apply_pipeline_plan,
                               select_pipeline_plan)


def _phi(rng, m, k):
    return jnp.asarray(rng.uniform(-0.5, 0.5, (m, k))
                       * np.exp(rng.standard_normal((m, k))))


def _distinct_plan() -> PipelinePlan:
    """A plan no analytic path would produce (sentinel for hit checks)."""
    return PipelinePlan(num_splits=5, backend="pallas_fused",
                        fusion="stages", tile=TilePlan(bm=32, bn=128,
                                                       bk=128))


KEY = PlanKey(m=8, n=16, k=32, batch=1, dtype="float64",
              backend="pallas_fused", device_kind="cpu")


# ----------------------------------------------------------------------------
# PlanCache: persistence contract
# ----------------------------------------------------------------------------

def test_plan_cache_roundtrip(tmp_path):
    path = tmp_path / "plans.json"
    cache = PlanCache(path)
    plan = _distinct_plan()
    cache.put(KEY, plan, measured_us=12.5)
    assert cache.save() == str(path)
    back = PlanCache.load(path)
    assert len(back) == 1 and KEY in back
    assert back.get(KEY) == plan
    assert back.measured_us(KEY) == 12.5
    # the wire format is versioned, structured-key JSON
    data = json.loads(path.read_text())
    assert data["version"] == PLAN_CACHE_VERSION
    (entry,) = data["plans"].values()
    assert entry["key"] == KEY.to_dict()
    assert PipelinePlan.from_dict(entry["plan"]) == plan


def test_plan_cache_hit_miss_accounting(tmp_path):
    cache = PlanCache(tmp_path / "p.json")
    assert cache.get(KEY) is None
    assert (cache.hits, cache.misses) == (0, 1)
    cache.put(KEY, _distinct_plan())
    assert cache.get(KEY) is not None
    assert (cache.hits, cache.misses) == (1, 1)


def test_plan_cache_version_mismatch_falls_back(tmp_path):
    path = tmp_path / "plans.json"
    cache = PlanCache(path)
    cache.put(KEY, _distinct_plan())
    cache.save()
    data = json.loads(path.read_text())
    data["version"] = PLAN_CACHE_VERSION + 1
    path.write_text(json.dumps(data))
    with pytest.warns(UserWarning, match="version"):
        back = PlanCache.load(path)
    assert len(back) == 0
    # planning degrades to analytic, never errors
    plan = select_pipeline_plan(8, 16, 32, cache=back, accum="f64")
    assert plan == select_pipeline_plan(8, 16, 32, accum="f64")


def test_plan_cache_version_stays_3_and_scheme2_plans_degrade(tmp_path):
    """ISSUE 9 satellite: the fused-CRT epilogue route reuses the
    existing ``fusion`` field — no new PlanKey/PipelinePlan identity
    field, so the cache version MUST stay 3 (a bump would orphan every
    cached plan for no schema reason). And a FUTURE-versioned file
    carrying a Scheme II epilogue plan still degrades to empty + the
    analytic default rather than resurrecting a stale schema."""
    assert PLAN_CACHE_VERSION == 3
    path = tmp_path / "plans.json"
    cache = PlanCache(path)
    key = PlanKey(m=8, n=16, k=96, batch=1, dtype="float64",
                  backend="pallas_fused", device_kind="cpu")
    from repro.core.modular import modular_plan
    plan2 = modular_plan(96, backend="pallas_fused", fuse_epilogue=True)
    assert plan2.fusion == "epilogue"       # round-trips under version 3
    cache.put(key, plan2)
    cache.save()
    back = PlanCache.load(path)
    assert back.get(key) == plan2
    data = json.loads(path.read_text())
    data["version"] = PLAN_CACHE_VERSION + 1
    path.write_text(json.dumps(data))
    with pytest.warns(UserWarning, match="version"):
        back = PlanCache.load(path)
    assert len(back) == 0
    plan = select_pipeline_plan(8, 16, 96, cache=back, accum="f64",
                                scheme="ozaki2_fp64")
    assert plan == select_pipeline_plan(8, 16, 96, accum="f64",
                                        scheme="ozaki2_fp64")
    assert plan.fusion == "epilogue"        # the analytic default route


@pytest.mark.parametrize("content", ["{not json", '{"plans": 7}',
                                     '{"version": 1, "plans": '
                                     '{"x": {"plan": {"bogus": 1}}}}'])
def test_plan_cache_corrupted_file_falls_back(tmp_path, content):
    path = tmp_path / "plans.json"
    path.write_text(content)
    with pytest.warns(UserWarning, match="unreadable|version"):
        back = PlanCache.load(path)
    assert len(back) == 0
    plan = select_pipeline_plan(8, 16, 32, cache=back, accum="f64")
    assert plan == select_pipeline_plan(8, 16, 32, accum="f64")


def test_plan_cache_missing_file_is_empty(tmp_path):
    back = PlanCache.load(tmp_path / "nope.json")
    assert len(back) == 0


# ----------------------------------------------------------------------------
# PlanCache v2 metadata: the measurement MODE rides with the plan
# ----------------------------------------------------------------------------

def test_plan_cache_meta_interpret_roundtrip(tmp_path):
    """ISSUE 6 satellite: a PlanKey used to say nothing about HOW the
    winner was ranked — interpret-mode timings silently ranked compiled
    runs. v2 entries persist the measurement mode."""
    path = tmp_path / "plans.json"
    cache = PlanCache(path)
    cache.put(KEY, _distinct_plan(), measured_us=3.0, interpret=True)
    assert cache.meta(KEY) == {"interpret": True}
    cache.save()
    back = PlanCache.load(path)
    assert back.meta(KEY) == {"interpret": True}
    data = json.loads(path.read_text())
    (entry,) = data["plans"].values()
    assert entry["meta"] == {"interpret": True}


def test_interpret_ranked_plan_warns_compiled_consumer_once(tmp_path):
    from repro.core.autotune import warn_if_interpret_ranked

    cache = PlanCache()
    cache.put(KEY, _distinct_plan(), interpret=True)
    with pytest.warns(UserWarning, match="interpret mode"):
        warn_if_interpret_ranked(cache, KEY, interpret=False)
    import warnings
    with warnings.catch_warnings():             # latched: once per key
        warnings.simplefilter("error")
        warn_if_interpret_ranked(cache, KEY, interpret=False)
        # interpret consumers never warn, mode-matched entries never warn
        warn_if_interpret_ranked(cache, KEY, interpret=True)
        hw = PlanCache()
        hw.put(KEY, _distinct_plan(), interpret=False)
        warn_if_interpret_ranked(hw, KEY, interpret=False)
        # absent meta (entry not produced by autotune_plan) stays silent
        bare = PlanCache()
        bare.put(KEY, _distinct_plan())
        warn_if_interpret_ranked(bare, KEY, interpret=False)


def test_autotune_records_measurement_mode(tmp_path):
    """autotune_plan stamps its interpret mode on the persisted winner,
    and a compiled select_pipeline_plan consuming it warns."""
    cache = PlanCache(tmp_path / "plans.json")
    rep = autotune_plan(8, 16, 32, accum="f64", num_splits=5, cache=cache,
                        max_candidates=2, warmup=1, iters=1,
                        interpret=True)
    assert cache.meta(rep.key) == {"interpret": True}
    with pytest.warns(UserWarning, match="interpret mode"):
        got = select_pipeline_plan(8, 16, 32, accum="f64", num_splits=5,
                                   cache=cache, interpret=False,
                                   device_kind=rep.key.device_kind)
    assert got == rep.best                      # warned, still served


# ----------------------------------------------------------------------------
# select_pipeline_plan x cache: hit short-circuits, miss stays analytic
# ----------------------------------------------------------------------------

def test_select_pipeline_plan_cache_hit_returns_cached():
    cache = PlanCache()
    sentinel = _distinct_plan()
    key = plan_cache_key(8, 16, 32, dtype="float64", device_kind="cpu")
    cache.put(key, sentinel)
    got = select_pipeline_plan(8, 16, 32, accum="f64", cache=cache,
                               device_kind="cpu")
    assert got == sentinel                      # NOT the analytic plan
    assert cache.hits == 1


def test_select_pipeline_plan_cache_miss_analytic_not_stored():
    cache = PlanCache()
    got = select_pipeline_plan(8, 16, 32, accum="f64", cache=cache)
    assert got == select_pipeline_plan(8, 16, 32, accum="f64")
    assert len(cache) == 0                      # analytic misses don't pollute
    assert cache.misses == 1


def test_cache_hit_rejected_on_num_splits_mismatch():
    """An explicit num_splits pins the accuracy operating point: a plan
    cached at a different s must NOT substitute for it (the key is
    deliberately fusion/splits-agnostic, so the hit path validates)."""
    import dataclasses

    cache = PlanCache()
    key = plan_cache_key(8, 16, 32, accum="f64", device_kind="cpu")
    cache.put(key, dataclasses.replace(_distinct_plan(), num_splits=5))
    got = select_pipeline_plan(8, 16, 32, accum="f64", num_splits=13,
                               cache=cache, device_kind="cpu")
    assert got.num_splits == 13                 # analytic, not the s=5 hit
    # unpinned callers accept whatever operating point was tuned
    got2 = select_pipeline_plan(8, 16, 32, accum="f64", cache=cache,
                                device_kind="cpu")
    assert got2.num_splits == 5


def test_autotune_honors_analytic_knobs():
    """mantissa_space/mmu/vmem_budget reach the candidate seed: the
    autotuned operating point matches the analytic one for the same
    target (regression: the autotune dispatch used to drop them)."""
    from repro.core.analytic import INT8_INT32
    tight = select_pipeline_plan(256, 256, 2048, accum="f64",
                                 mantissa_space=106,
                                 vmem_budget=2 ** 18)
    cands = candidate_plans(256, 256, 2048, accum="f64",
                            mantissa_space=106, mmu=INT8_INT32,
                            vmem_budget=2 ** 18)
    assert cands[0] == tight
    assert all(c.num_splits == tight.num_splits for c in cands)
    t = cands[0].tile
    assert t.bm * t.bk + t.bn * t.bk + 4 * t.bm * t.bn <= 2 ** 18


def test_plan_key_dtype_defaults_from_accum():
    k64 = plan_cache_key(4, 4, 4, accum="f64", device_kind="x")
    k32 = plan_cache_key(4, 4, 4, accum="df32", device_kind="x")
    assert k64.dtype == "float64" and k32.dtype == "float32"
    assert k64 != k32


def test_plan_key_dtype_spellings_canonicalized(tmp_path):
    """A key built from the ``jnp.float64`` OBJECT and one built from the
    ``"float64"`` string are the same cache entry — before the
    canonicalization they hashed apart and silently missed (and the
    object spelling broke JSON serialization)."""
    import numpy as np

    obj_key = PlanKey(m=8, n=16, k=32, dtype=jnp.float64,
                      device_kind="cpu")
    str_key = PlanKey(m=8, n=16, k=32, dtype="float64", device_kind="cpu")
    np_key = PlanKey(m=8, n=16, k=32, dtype=np.dtype("float64"),
                     device_kind="cpu")
    assert obj_key == str_key == np_key
    assert obj_key.dtype == "float64"           # canonical string stored
    assert hash(obj_key) == hash(str_key)
    cache = PlanCache(tmp_path / "p.json")
    cache.put(obj_key, _distinct_plan())
    assert cache.get(str_key) is not None       # cross-spelling hit
    cache.save()                                 # object spelling is JSON-safe
    back = PlanCache.load(tmp_path / "p.json")
    assert back.get(PlanKey(m=8, n=16, k=32, dtype=jnp.float64,
                            device_kind="cpu")) == _distinct_plan()
    # the select_pipeline_plan entry point accepts either spelling too
    sel_key = plan_cache_key(8, 16, 32, dtype=jnp.float64,
                             device_kind="cpu")
    assert sel_key == str_key


# ----------------------------------------------------------------------------
# Candidate space: analytic seed first, result-invariant by default
# ----------------------------------------------------------------------------

def test_candidates_analytic_first_and_bounded():
    cands = candidate_plans(64, 64, 256, accum="f64", max_candidates=4)
    assert 2 <= len(cands) <= 4
    assert cands[0] == select_pipeline_plan(64, 64, 256, accum="f64")
    assert len(set(cands)) == len(cands)        # deduped
    # result-affecting knobs are frozen across default candidates
    for c in cands:
        assert c.num_splits == cands[0].num_splits
        assert c.fuse_diagonals == cands[0].fuse_diagonals


def test_candidates_num_splits_search_is_opt_in():
    base = candidate_plans(32, 32, 64, accum="f64")
    wide = candidate_plans(32, 32, 64, accum="f64", search_num_splits=2)
    s0 = base[0].num_splits
    assert {c.num_splits for c in base} == {s0}
    assert {c.num_splits for c in wide} == {s0, s0 + 1, s0 + 2}


def test_candidates_never_violate_dw_schedule_guard():
    """search_num_splits used to enumerate df32 plans violating the
    ``(num_splits + 1) * w <= 120`` guard and crash mid-measurement;
    invalid candidates are now filtered up front, so the guard never
    raises during (or after) ``candidate_plans``."""
    from repro.core.tuning import plan_schedule_ok

    # k=32 -> w=7 at every candidate s: s > 16 violates (s+1)*7 <= 120
    cands = candidate_plans(8, 8, 32, accum="df32", search_num_splits=12,
                            max_candidates=None)
    assert all(plan_schedule_ok(c, 32) for c in cands)
    assert max(c.num_splits for c in cands) <= 16
    assert len({c.num_splits for c in cands}) > 1   # search still widens
    # the widest surviving candidate measures without raising — this is
    # the exact call path that crashed before the filter
    widest = max(cands, key=lambda c: c.num_splits)
    assert measure_plan(widest, 8, 8, 32, warmup=1, iters=1) > 0
    # sanity: the filter is the reason (an over-wide plan IS invalid)
    import dataclasses as dc
    assert not plan_schedule_ok(dc.replace(cands[0], num_splits=20), 32)
    # f64 plans have no f32 scale ceiling: nothing is filtered there
    f64 = candidate_plans(8, 8, 32, accum="f64", search_num_splits=12,
                          max_candidates=None)
    s0 = f64[0].num_splits
    assert max(c.num_splits for c in f64) == s0 + 12


def test_candidates_pair_budgets_are_accuracy_checked(rng):
    """With a target, pair-budget candidates appear — every one meeting
    the guaranteed bound (each family judged by its OWN bound: the
    cross-scheme seed is a Scheme II plan), so no measured winner can
    violate the target."""
    from repro.core.accuracy import plan_meets_target

    k = 96
    tgt = 1e-6
    cands = candidate_plans(24, 24, k, accum="f64", target_error=tgt,
                            fast_mode=True, max_candidates=None)
    budgets = [c for c in cands if c.pair_policy.startswith("budget:")]
    assert budgets                               # the space really widened
    for c in cands:
        assert plan_meets_target(c, k, tgt), (c.scheme, c.pair_policy)
    # distinct budgets: the measurement can trade pairs for time
    assert len({c.pair_policy for c in cands}) >= 2


def test_cache_hit_rejected_on_pair_policy_mismatch():
    """A plan cached with the full schedule must not serve a fast-mode
    request (pair_policy is result-affecting, like num_splits)."""
    cache = PlanCache()
    key = plan_cache_key(8, 16, 32, accum="f64", device_kind="cpu")
    full_plan = select_pipeline_plan(8, 16, 32, accum="f64")
    cache.put(key, full_plan)
    got = select_pipeline_plan(8, 16, 32, accum="f64", fast_mode=True,
                               cache=cache, device_kind="cpu")
    assert got.pair_policy == "diagonal"         # resolved, not the hit
    # and the exact-policy request hits
    cache.put(key, got)
    again = select_pipeline_plan(8, 16, 32, accum="f64", fast_mode=True,
                                 cache=cache, device_kind="cpu")
    assert again == got


def test_unpinned_request_never_served_truncated_plan():
    """The inverse direction: a truncated plan cached by a fast-mode run
    (e.g. the serving pre-warm) must NOT be silently served to a caller
    with no accuracy knobs — that would degrade a full-accuracy run."""
    import dataclasses as dc

    cache = PlanCache()
    key = plan_cache_key(8, 16, 32, accum="f64", device_kind="cpu")
    truncated = dc.replace(select_pipeline_plan(8, 16, 32, accum="f64"),
                           pair_policy="budget:5")
    cache.put(key, truncated)
    got = select_pipeline_plan(8, 16, 32, accum="f64", cache=cache,
                               device_kind="cpu")
    assert got.pair_policy == "full"             # analytic, not the hit


def test_target_pinned_hit_accepts_any_point_meeting_target():
    """Under a pinned target the TARGET is the acceptance contract: a
    cached winner with MORE pairs than the minimal resolved budget (or
    the full schedule) still meets it and must hit — rejecting it would
    re-tune on every call forever."""
    cache = PlanCache()
    k = 96
    key = plan_cache_key(24, 24, k, accum="f64", device_kind="cpu")
    full_plan = select_pipeline_plan(24, 24, k, accum="f64")
    cache.put(key, full_plan)                    # full: meets any target
    got = select_pipeline_plan(24, 24, k, accum="f64", target_error=1e-6,
                               fast_mode=True, cache=cache,
                               device_kind="cpu")
    assert got == full_plan and cache.hits == 1
    # but a cached point too coarse for the target is rejected
    import dataclasses as dc
    cache2 = PlanCache()
    cache2.put(key, dc.replace(full_plan, pair_policy="budget:2"))
    got2 = select_pipeline_plan(24, 24, k, accum="f64", target_error=1e-6,
                                fast_mode=True, cache=cache2,
                                device_kind="cpu")
    assert got2.pair_policy != "budget:2"


def test_autotune_target_second_call_is_pure_hit(tmp_path, monkeypatch):
    """Whatever accuracy-checked candidate wins the measurement, the
    next identical target-pinned call must be a pure cache hit (the
    winner's policy may differ from the minimal resolution)."""
    cache = PlanCache(tmp_path / "plans.json")
    rep = autotune_plan(16, 16, 48, accum="f64", target_error=1e-6,
                        fast_mode=True, cache=cache, max_candidates=6,
                        warmup=1, iters=1)
    assert len(cache) == 1

    def boom(*a, **kw):
        raise AssertionError("measured on a target-pinned cache hit")
    monkeypatch.setattr(at, "measure_plan", boom)
    rep2 = autotune_plan(16, 16, 48, accum="f64", target_error=1e-6,
                         fast_mode=True, cache=cache)
    assert rep2.best == rep.best


def test_candidates_all_bitwise_equal_to_analytic(rng):
    """Every default candidate — hence any cached winner — reproduces
    the analytic plan's results bit for bit (ISSUE 3 acceptance)."""
    m, n, k = 24, 16, 96
    a = _phi(rng, m, k)
    b = _phi(rng, k, n)
    cands = candidate_plans(m, n, k, accum="f64", num_splits=5)
    assert len(cands) >= 3
    ref = np.asarray(ozaki_matmul(a, b, apply_pipeline_plan(OzakiConfig(),
                                                            cands[0])))
    for cand in cands[1:]:
        got = np.asarray(ozaki_matmul(a, b,
                                      apply_pipeline_plan(OzakiConfig(),
                                                          cand)))
        np.testing.assert_array_equal(got, ref, err_msg=repr(cand))


def test_cached_plan_bitwise_equal_after_roundtrip(rng, tmp_path):
    """Tune -> persist -> reload -> execute == analytic run, bitwise."""
    m, n, k = 16, 16, 48
    cache = PlanCache(tmp_path / "plans.json")
    autotune_plan(m, n, k, accum="f64", num_splits=5, cache=cache,
                  max_candidates=3, warmup=1, iters=1)
    reloaded = PlanCache.load(tmp_path / "plans.json")
    tuned = select_pipeline_plan(m, n, k, accum="f64", num_splits=5,
                                 cache=reloaded)
    a = _phi(rng, m, k)
    b = _phi(rng, k, n)
    got = np.asarray(ozaki_matmul(a, b, apply_pipeline_plan(OzakiConfig(),
                                                            tuned)))
    ref = np.asarray(ozaki_matmul(a, b, OzakiConfig(
        num_splits=5, backend="pallas_fused", fuse_epilogue=True)))
    np.testing.assert_array_equal(got, ref)


# ----------------------------------------------------------------------------
# Measurement path (tier-1 smoke: <= 4 candidates, runs on every PR)
# ----------------------------------------------------------------------------

def test_autotune_smoke_tiny_candidate_set(tmp_path, monkeypatch):
    m, n, k = 8, 16, 32
    cache = PlanCache(tmp_path / "plans.json")
    rep = autotune_plan(m, n, k, accum="f64", num_splits=5, cache=cache,
                        max_candidates=4, warmup=1, iters=1)
    assert isinstance(rep, AutotuneReport)
    assert 2 <= len(rep.measurements) <= 4
    assert all(us > 0 for _, us in rep.measurements)
    assert rep.best_us == min(us for _, us in rep.measurements)
    assert rep.best in [p for p, _ in rep.measurements]
    # winner persisted under the shared key
    assert (tmp_path / "plans.json").exists()
    assert cache.get(rep.key) == rep.best
    # second call: pure cache hit — measurement must NOT run again
    def boom(*a, **kw):
        raise AssertionError("measured on a cache hit")
    monkeypatch.setattr(at, "measure_plan", boom)
    rep2 = autotune_plan(m, n, k, accum="f64", num_splits=5, cache=cache)
    assert rep2.best == rep.best


def test_select_pipeline_plan_autotune_populates_cache():
    cache = PlanCache()                         # in-memory, no path
    got = select_pipeline_plan(8, 16, 32, accum="f64", num_splits=5,
                               cache=cache, autotune=True)
    assert len(cache) == 1
    key = plan_cache_key(8, 16, 32, accum="f64")
    assert cache.get(key) == got


def test_measure_plan_reports_positive_time():
    plan = select_pipeline_plan(8, 8, 16, accum="f64", num_splits=5)
    us = measure_plan(plan, 8, 8, 16, warmup=1, iters=1)
    assert us > 0


# ----------------------------------------------------------------------------
# Ambient cache registry + the layers trace-time lookup
# ----------------------------------------------------------------------------

def test_use_plan_cache_scoping():
    cache = PlanCache()
    assert at.active_plan_cache() is None
    with use_plan_cache(cache):
        assert at.active_plan_cache() is cache
        with use_plan_cache(None):
            assert at.active_plan_cache() is None
        assert at.active_plan_cache() is cache
    assert at.active_plan_cache() is None


def test_layers_pick_up_ambient_plans_bitwise(rng):
    """policy_matmul under a scoped cache: the cached plan is looked up
    (hit counted) and the result is bit-identical to the uncached run
    (only result-invariant plan fields are applied)."""
    from repro.configs import get_config
    from repro.models.layers import policy_matmul
    import dataclasses

    cfg = dataclasses.replace(get_config("llama3.2-3b").reduced(),
                              matmul_precision="ozaki_fp64",
                              ozaki_backend="pallas_fused",
                              ozaki_splits=5)
    x = jnp.asarray(rng.standard_normal((4, 64)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    base = np.asarray(policy_matmul(cfg, x, w))
    cache = PlanCache()
    key = plan_cache_key(4, 32, 64, batch=1, dtype="float32",
                         backend="pallas_fused")
    cache.put(key, select_pipeline_plan(4, 32, 64, num_splits=5,
                                        fuse_epilogue=True))
    with use_plan_cache(cache):
        got = np.asarray(policy_matmul(cfg, x, w))
    assert cache.hits >= 1                      # the lookup really ran
    np.testing.assert_array_equal(got, base)


# ----------------------------------------------------------------------------
# Serving engine pre-warm: tuned at startup, hits on the request path
# ----------------------------------------------------------------------------

def _tiny_serving_cfg():
    import dataclasses

    from repro.configs import get_config
    return dataclasses.replace(get_config("llama3.2-3b").reduced(),
                               matmul_precision="ozaki_fp64",
                               ozaki_backend="pallas_fused",
                               ozaki_fuse_epilogue=True, ozaki_splits=5)


def test_engine_prewarm_populates_and_persists(tmp_path):
    from repro.models import init_model
    from repro.serving.engine import ServingEngine, ozaki_projection_shapes

    cfg = _tiny_serving_cfg()
    params, _ = init_model(cfg, jax.random.key(0))
    path = tmp_path / "plans.json"
    eng = ServingEngine(cfg, params, num_slots=2, max_len=32,
                        plan_cache=str(path))
    shapes = ozaki_projection_shapes(cfg)
    assert len(shapes) >= 4
    assert len(eng.plan_cache) == len(shapes)
    assert path.exists()                        # persisted at startup
    # every decode projection is a hit now (no tuning on request path)
    for k, n in shapes:
        key = plan_cache_key(1, n, k, batch=2, dtype="float32",
                             backend=cfg.ozaki_backend)
        assert key in eng.plan_cache


def test_engine_prewarm_second_start_hits_without_tuning(tmp_path,
                                                         monkeypatch):
    from repro.models import init_model
    from repro.serving.engine import ServingEngine

    cfg = _tiny_serving_cfg()
    params, _ = init_model(cfg, jax.random.key(0))
    path = tmp_path / "plans.json"
    ServingEngine(cfg, params, num_slots=2, max_len=32,
                  plan_cache=str(path))

    def boom(*a, **kw):
        raise AssertionError("tuned on a warm start")
    monkeypatch.setattr(at, "autotune_plan", boom)
    eng2 = ServingEngine(cfg, params, num_slots=2, max_len=32,
                        plan_cache=str(path), autotune_plans=True)
    assert eng2.plan_cache.hits == len(eng2.plan_cache)
    assert eng2.plan_cache.misses == 0


def test_engine_plan_scope_registers_ambient_cache(tmp_path):
    from repro.models import init_model
    from repro.serving.engine import ServingEngine

    cfg = _tiny_serving_cfg()
    params, _ = init_model(cfg, jax.random.key(0))
    eng = ServingEngine(cfg, params, num_slots=2, max_len=32,
                        plan_cache=str(tmp_path / "p.json"))
    assert at.active_plan_cache() is None
    with eng._plan_scope():
        assert at.active_plan_cache() is eng.plan_cache
    assert at.active_plan_cache() is None


def test_candidate_plans_flip_comm_under_shard_axis():
    """With a shard axis in play the tuner explores both transports; an
    unsharded plan never wastes measurements on comm flips."""
    sharded = candidate_plans(64, 64, 512, accum="f64",
                              shard_axis="model", comm="f64")
    comms = {c.comm for c in sharded}
    assert comms == {"f64", "int8"}
    assert sharded[0].comm == "f64"          # base plan leads
    back = candidate_plans(64, 64, 512, accum="f64",
                           shard_axis="model", comm="int8")
    assert back[0].comm == "int8"
    assert {c.comm for c in back} == {"f64", "int8"}
    unsharded = candidate_plans(64, 64, 512, accum="f64")
    assert {c.comm for c in unsharded} == {"f64"}
