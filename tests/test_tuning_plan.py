"""Planner edge cases: ``select_plan`` block selection, ``PipelinePlan``
construction/validation/serialization, and the config <-> plan round trip.
"""
import json

import pytest

from repro.core.ozaki import OzakiConfig
from repro.core.tuning import (BATCH_LAYOUTS, FUSION_MODES, PipelinePlan,
                               TilePlan, VMEM_BUDGET, apply_pipeline_plan,
                               diagonal_groups, hbm_pass_model, plan_for,
                               select_pipeline_plan, select_plan)
from repro.kernels.launch import LANE, SUBLANE_F32, SUBLANE_I8


# ----------------------------------------------------------------------------
# select_plan edge cases
# ----------------------------------------------------------------------------

def test_select_plan_tiny_k():
    """k=1: blocks floor at their alignment minima, splits stay sane."""
    plan = select_plan(8, 8, 1)
    assert plan.bm == SUBLANE_I8 and plan.bn == LANE and plan.bk == LANE
    assert plan.split_bk == LANE and plan.accum_bn == LANE
    assert plan.num_splits >= 1
    assert plan.concat_k          # short reduction -> one concatenated GEMM


def test_select_plan_k1_batched_disables_concat():
    """A stacked batch disables concat_k even for launch-bound k."""
    assert select_plan(16, 16, 1, batch=1).concat_k
    assert not select_plan(16, 16, 1, batch=4).concat_k


def test_select_plan_non_pow2_mn():
    """Non-pow2 m/n: power-of-two blocks within the aligned extents."""
    plan = select_plan(100, 130, 530)
    for b in (plan.bm, plan.bn, plan.bk, plan.accum_bm, plan.accum_bn):
        assert b & (b - 1) == 0, b
    assert plan.bm <= 128           # align_up(100, 32) = 128
    assert plan.bn <= 256
    assert plan.bm * plan.bk + plan.bn * plan.bk + \
        4 * plan.bm * plan.bn <= VMEM_BUDGET


def test_select_plan_vmem_pressure_shrinks_bk_first():
    tight = select_plan(4096, 4096, 8192, vmem_budget=VMEM_BUDGET // 8)
    default = select_plan(4096, 4096, 8192)
    assert tight.bk <= default.bk
    assert tight.bm * tight.bk + tight.bn * tight.bk + \
        4 * tight.bm * tight.bn <= VMEM_BUDGET // 8


# ----------------------------------------------------------------------------
# PipelinePlan construction / validation
# ----------------------------------------------------------------------------

def test_select_pipeline_plan_layouts():
    none = select_pipeline_plan(64, 64, 256)
    rows = select_pipeline_plan(8, 64, 256, batch=32, broadcast_weights=True)
    grid = select_pipeline_plan(8, 64, 256, batch=32)
    assert none.batch_layout == "none" and none.fusion == "epilogue"
    assert rows.batch_layout == "rows" and rows.fusion == "epilogue"
    # the batch-grid epilogue kernel keeps stacked batches epilogue-fused
    assert grid.batch_layout == "grid" and grid.fusion == "epilogue"
    # rows layout sizes tiles for the folded batch*m row extent
    assert rows.tile.bm >= none.tile.bm or rows.tile.bm == 256


def test_pipeline_plan_validation():
    with pytest.raises(ValueError, match="fusion"):
        PipelinePlan(fusion="bogus")
    with pytest.raises(ValueError, match="batch_layout"):
        PipelinePlan(batch_layout="bogus")
    with pytest.raises(ValueError, match="accum"):
        PipelinePlan(accum="f32")
    with pytest.raises(ValueError, match="pair_policy"):
        PipelinePlan(pair_policy="bogus")
    with pytest.raises(ValueError, match="budget"):
        PipelinePlan(pair_policy="budget:0")
    # epilogue + grid is a VALID plan since the batch-grid epilogue kernel
    plan = PipelinePlan(backend="pallas_fused", fusion="epilogue",
                        batch_layout="grid")
    assert plan.fusion == "epilogue"
    # so is streaming + grid (the batch-grid streaming kernel)
    plan = PipelinePlan(backend="pallas_fused", fusion="streaming",
                        batch_layout="grid")
    assert plan.fusion == "streaming"
    assert set(FUSION_MODES) == {"none", "stages", "epilogue", "streaming"}
    assert set(BATCH_LAYOUTS) == {"none", "rows", "grid"}


def test_plan_for_reflects_config():
    cfg = OzakiConfig(num_splits=11, accum="df32", backend="pallas_fused",
                      fuse_epilogue=True, shard_axis="model",
                      interpret=True)
    plan = plan_for(cfg)
    assert plan.num_splits == 11 and plan.accum == "df32"
    assert plan.fusion == "epilogue" and plan.shard_axis == "model"
    # grid layout keeps epilogue fusion (batch-grid epilogue kernel)
    assert plan_for(cfg, batch_layout="grid").fusion == "epilogue"
    # non-fused backends never fuse
    assert plan_for(OzakiConfig(backend="xla")).fusion == "none"
    assert plan_for(OzakiConfig(backend="pallas",
                                fuse_epilogue=True)).fusion == "none"
    # streaming wins the fusion slot on the fused backend, any layout
    scfg = OzakiConfig(backend="pallas_fused", streaming=True)
    assert plan_for(scfg).fusion == "streaming"
    assert plan_for(scfg, batch_layout="grid").fusion == "streaming"
    assert plan_for(OzakiConfig(backend="pallas",
                                streaming=True)).fusion == "none"


def test_plan_for_keeps_explicit_tile_blocks():
    tile = select_plan(40, 24, 200, num_splits=9)
    cfg = OzakiConfig(num_splits=5, tile=tile)   # schedule from cfg wins
    plan = plan_for(cfg)
    assert plan.tile is tile
    assert plan.num_splits == 5


def test_apply_pipeline_plan_roundtrip():
    plan = select_pipeline_plan(64, 32, 512, accum="df32",
                                shard_axis="model")
    cfg = apply_pipeline_plan(OzakiConfig(), plan)
    assert cfg.backend == "pallas_fused" and cfg.accum == "df32"
    assert cfg.fuse_epilogue and cfg.shard_axis == "model"
    assert cfg.tile == plan.tile
    assert plan_for(cfg) == plan


# ----------------------------------------------------------------------------
# Serialization round trip (deployment plan caches)
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("plan", [
    PipelinePlan(),
    PipelinePlan(pair_policy="diagonal"),
    PipelinePlan(pair_policy="budget:7"),
    select_pipeline_plan(64, 64, 256),
    select_pipeline_plan(64, 64, 256, fast_mode=True),
    select_pipeline_plan(64, 64, 256, target_error=1e-8, fast_mode=True),
    select_pipeline_plan(8, 64, 7, batch=32, broadcast_weights=True,
                         accum="df32", shard_axis="model"),
    select_pipeline_plan(9, 65, 129, batch=3, backend="pallas",
                         fuse_epilogue=False, interpret=False),
    select_pipeline_plan(64, 64, 256, streaming=True),
    select_pipeline_plan(8, 64, 256, batch=32, streaming=True),
])
def test_pipeline_plan_json_roundtrip(plan):
    wire = json.dumps(plan.to_dict())
    back = PipelinePlan.from_dict(json.loads(wire))
    assert back == plan
    assert isinstance(back.tile, TilePlan)


def test_pipeline_plan_from_dict_without_pair_policy():
    """Plans serialized before the pair_policy field (PR 3 caches) load
    with the full schedule — cache files stay forward-compatible."""
    d = PipelinePlan().to_dict()
    d.pop("pair_policy")
    assert PipelinePlan.from_dict(d).pair_policy == "full"


def test_select_pipeline_plan_accuracy_knobs():
    full = select_pipeline_plan(64, 64, 128)
    fast = select_pipeline_plan(64, 64, 128, fast_mode=True)
    assert fast.pair_policy == "diagonal"
    assert fast.num_gemms < full.num_gemms
    targeted = select_pipeline_plan(64, 64, 128, target_error=1e-8,
                                    fast_mode=True)
    assert targeted.num_splits < full.num_splits     # reduced, not raised
    assert targeted.pair_policy.startswith("budget:")
    # apply_pipeline_plan carries the policy into the config and back
    cfg = apply_pipeline_plan(OzakiConfig(), targeted)
    assert cfg.pair_policy == targeted.pair_policy
    assert plan_for(cfg) == targeted


def test_streaming_plan_config_roundtrip():
    """streaming plan <-> OzakiConfig survives apply/plan_for round trip."""
    plan = select_pipeline_plan(64, 32, 512, streaming=True)
    assert plan.fusion == "streaming"
    cfg = apply_pipeline_plan(OzakiConfig(), plan)
    assert cfg.streaming and not cfg.fuse_epilogue
    assert cfg.backend == "pallas_fused"
    assert plan_for(cfg) == plan


def test_diagonal_groups_pair_budget():
    full = diagonal_groups(5)
    assert sum(len(p) for _, p in full) == 15
    cut = diagonal_groups(5, pair_budget=7)
    assert sum(len(p) for _, p in cut) == 7
    # truncation keeps the significance-ascending prefix; the partial
    # diagonal keeps its leading pairs
    assert [t for t, _ in cut] == [0, 1, 2, 3]
    assert cut[-1][1] == full[3][1][:1]
    assert diagonal_groups(5, pair_budget=1) == [(0, [(0, 0)])]


# ----------------------------------------------------------------------------
# HBM pass model: streaming < epilogue < stage-fused < unfused, every s
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("s", [5, 9, 13])
def test_hbm_pass_model_epilogue_strictly_fewer(s):
    unfused = hbm_pass_model(s, fused=False)
    stages = hbm_pass_model(s, fused=True)
    epilogue = hbm_pass_model(s, fused=True, fuse_epilogue=True)
    assert epilogue["total"] < stages["total"] < unfused["total"]
    assert epilogue["split"] == stages["split"] == 1
    assert epilogue["accum"] == 2 * s       # read C + write C per group
    # every mode pays the slice-stack traffic (the line item the model
    # used to hide): s slice writes + one read per kept pair per operand
    kept = s * (s + 1) // 2
    assert unfused["slices"] == stages["slices"] == \
        epilogue["slices"] == s + kept


@pytest.mark.parametrize("s", [5, 9, 13])
@pytest.mark.parametrize("pair_policy", ["full", "diagonal", "budget:6"])
def test_hbm_pass_model_streaming_strictly_fewer(s, pair_policy):
    """ISSUE 6 acceptance: streaming beats EVERY non-streaming mode on
    total passes once the slices line item is charged, and models the
    slice stack as never touching HBM."""
    streaming = hbm_pass_model(s, fusion="streaming",
                               pair_policy=pair_policy)
    assert streaming["slices"] == 0
    for kw in (dict(fused=False), dict(fused=True),
               dict(fused=True, fuse_epilogue=True)):
        other = hbm_pass_model(s, pair_policy=pair_policy, **kw)
        assert streaming["total"] < other["total"], (s, pair_policy, kw)


# regression pins for every (fusion mode, batch layout) combination at
# s=9: per-element counts are layout-invariant (the "rows" fold and the
# batch-grid kernels run the identical per-element pipeline — including
# the batch-grid EPILOGUE and STREAMING kernels, which remove the
# modeled 3-vs-2 passes per group the old stage-fused downgrade cost
# stacked batches), and scale linearly with the batch size. Columns:
# (split, slices, accum, total); streaming re-reads operands per group
# (split=s) but its int8 slice stack never touches HBM (slices=0).
_FUSIONS = {"none": dict(fused=False),
            "stages": dict(fused=True),
            "epilogue": dict(fused=True, fuse_epilogue=True),
            "streaming": dict(fusion="streaming")}
_PINNED_S9 = {"none": (9, 54, 45, 108), "stages": (1, 54, 27, 82),
              "epilogue": (1, 54, 18, 73), "streaming": (9, 0, 18, 27)}


@pytest.mark.parametrize("layout,batch", [("none", 1), ("rows", 1),
                                          ("grid", 1), ("rows", 4),
                                          ("grid", 4)])
@pytest.mark.parametrize("fusion", sorted(_FUSIONS))
def test_hbm_pass_model_matrix_pinned(fusion, layout, batch):
    got = hbm_pass_model(9, batch=batch, batch_layout=layout,
                         **_FUSIONS[fusion])
    split, slices, accum, total = (batch * x for x in _PINNED_S9[fusion])
    assert got == {"split": split, "slices": slices, "residues": 0,
                   "accum": accum,
                   "total": total}, (fusion, layout, batch, got)


# ----------------------------------------------------------------------------
# HBM pass model, Scheme II: the residues line item + the fused-CRT win
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("s,ell", [(7, 15), (9, 15), (9, 21)])
def test_hbm_pass_model_scheme2_epilogue_strictly_fewer(s, ell):
    """ISSUE 9 acceptance: the fused-CRT epilogue is strictly fewer
    modeled passes than every unfused Scheme II mode — the saved traffic
    is exactly the (ell, m, n) int32 residue products' round-trip."""
    kw = dict(scheme="ozaki2_fp64", num_moduli=ell)
    unfused = hbm_pass_model(s, fused=False, **kw)
    stages = hbm_pass_model(s, fused=True, **kw)
    epi = hbm_pass_model(s, fused=True, fuse_epilogue=True, **kw)
    assert epi["total"] < stages["total"] < unfused["total"]
    assert stages["accum"] - epi["accum"] == 2 * ell
    # every mode pays the residue-plane traffic; the slice stack is read
    # once by the extraction, never per pair
    for got in (unfused, stages, epi):
        assert got["residues"] == 2 * ell and got["slices"] == 2 * s


def test_hbm_pass_model_scheme2_pinned():
    # s=9, ell=15 columns: (split, slices, residues, accum, total)
    pins = {"none": (9, 18, 30, 31, 88), "stages": (1, 18, 30, 31, 80),
            "epilogue": (1, 18, 30, 1, 50)}
    for fusion, (split, slices, residues, accum, total) in pins.items():
        got = hbm_pass_model(9, fusion=fusion, scheme="ozaki2_fp64",
                             num_moduli=15)
        assert got == {"split": split, "slices": slices,
                       "residues": residues, "accum": accum,
                       "total": total}, (fusion, got)
    b = hbm_pass_model(9, fusion="epilogue", scheme="ozaki2_fp64",
                       num_moduli=15, batch=4, batch_layout="grid")
    assert b["total"] == 4 * 50


def test_hbm_pass_model_scheme2_validation():
    with pytest.raises(ValueError, match="num_moduli"):
        hbm_pass_model(9, scheme="ozaki2_fp64")
    with pytest.raises(ValueError, match="streaming"):
        hbm_pass_model(9, fusion="streaming", scheme="ozaki2_fp64",
                       num_moduli=15)
    with pytest.raises(ValueError, match="pair"):
        hbm_pass_model(9, scheme="ozaki2_fp64", num_moduli=15,
                       pair_policy="diagonal")
    with pytest.raises(ValueError, match="scheme"):
        hbm_pass_model(9, scheme="bogus")


def test_hbm_pass_model_batched_epilogue_closes_fusion_gap():
    """The batched-epilogue claim in one number: 3 -> 2 passes per
    accumulation group on the stacked-batch path."""
    stages = hbm_pass_model(9, fused=True, batch=4, batch_layout="grid")
    epi = hbm_pass_model(9, fused=True, fuse_epilogue=True, batch=4,
                         batch_layout="grid")
    assert stages["accum"] == 3 * 9 * 4 and epi["accum"] == 2 * 9 * 4


def test_hbm_pass_model_pair_policy():
    """Pair truncation drops whole accumulation groups (fused diagonals)
    or individual pair passes (paper-faithful schedule)."""
    full = hbm_pass_model(9, fused=True, fuse_epilogue=True)
    diag = hbm_pass_model(9, fused=True, fuse_epilogue=True,
                          pair_policy="diagonal")
    assert diag["accum"] == 2 * 8 and full["accum"] == 2 * 9
    unfused_budget = hbm_pass_model(9, fused=False, fuse_diagonals=False,
                                    pair_policy="budget:10")
    assert unfused_budget["accum"] == 10 * 5


def test_hbm_pass_model_validates_batch_layout():
    with pytest.raises(ValueError, match="batch_layout"):
        hbm_pass_model(9, fused=True, batch_layout="bogus")
    with pytest.raises(ValueError, match="batch"):
        hbm_pass_model(9, fused=True, batch=0)
    with pytest.raises(ValueError, match="requires"):
        hbm_pass_model(9, fused=True, batch=2, batch_layout="none")


# ----------------------------------------------------------------------------
# comm_bytes_model: the transport-layer companion to hbm_pass_model
# ----------------------------------------------------------------------------

def test_comm_bytes_model_int8_kshard_wins_6x():
    """Acceptance bar: at the paper's s=9 on a tall-k shape, the int8
    k-shard transport moves >= 6x fewer link bytes per device than the
    GSPMD f64-operand-gather baseline (and reduce-scatter doubles the
    win again by leaving C column-sharded)."""
    from repro.core.tuning import comm_bytes_model
    kw = dict(num_splits=9, world=8, layout="kshard")
    f64 = comm_bytes_model(256, 256, 8192, comm="f64", **kw)
    for sched in ("psum", "overlap"):
        i8 = comm_bytes_model(256, 256, 8192, comm="int8", schedule=sched,
                              **kw)
        assert f64["total"] / i8["total"] >= 6.0, (sched, i8)
        assert i8["operands"] == 0          # no f64 word ever on a link
    rs = comm_bytes_model(256, 256, 8192, comm="int8",
                          schedule="reduce_scatter", **kw)
    assert f64["total"] / rs["total"] >= 12.0
    assert rs["partials"] * 2 == comm_bytes_model(
        256, 256, 8192, comm="int8", schedule="psum", **kw)["partials"]


def test_comm_bytes_model_mnshard_honest_about_s():
    """m/n-shard gathers the slice stack at s bytes/element vs f64's 8:
    the model must show int8 winning for s < 8 and losing for s > 8."""
    from repro.core.tuning import comm_bytes_model
    kw = dict(world=8, layout="mnshard")
    for s, wins in ((5, True), (9, False)):
        f64 = comm_bytes_model(256, 256, 4096, num_splits=s, comm="f64",
                               **kw)
        i8 = comm_bytes_model(256, 256, 4096, num_splits=s, comm="int8",
                              schedule="allgather", **kw)
        assert (i8["total"] < f64["total"]) == wins, (s, i8, f64)


def test_comm_bytes_model_structure():
    from repro.core.tuning import comm_bytes_model
    # world=1: a single device moves nothing
    one = comm_bytes_model(64, 64, 512, num_splits=9, world=1,
                           comm="int8")
    assert one["total"] == 0
    # fast-mode pair truncation drops whole anti-diagonal groups from
    # the partial-product traffic
    full = comm_bytes_model(64, 64, 512, num_splits=9, world=8,
                            comm="int8")
    diag = comm_bytes_model(64, 64, 512, num_splits=9, world=8,
                            comm="int8", pair_policy="diagonal")
    assert diag["partials"] < full["partials"]
    # batch scales the activation-side items; broadcast weights cross once
    b4 = comm_bytes_model(64, 64, 512, num_splits=9, world=8, comm="f64",
                          batch=4)
    b1 = comm_bytes_model(64, 64, 512, num_splits=9, world=8, comm="f64")
    assert b4["operands"] < 4 * b1["operands"]
    with pytest.raises(ValueError, match="layout"):
        comm_bytes_model(8, 8, 8, num_splits=9, world=2, layout="bogus")
    with pytest.raises(ValueError, match="comm"):
        comm_bytes_model(8, 8, 8, num_splits=9, world=2, comm="fp8")
    with pytest.raises(ValueError, match="schedule"):
        comm_bytes_model(8, 8, 8, num_splits=9, world=2, schedule="bogus")
    with pytest.raises(ValueError, match="world"):
        comm_bytes_model(8, 8, 8, num_splits=9, world=0)


def test_comm_bytes_model_scheme2():
    """Scheme II transport: k-shard int8 ships ell int32 residue planes
    (no f64 operand word ever crosses); m/n-shard gathers the packed
    ResidueWire at ell bytes/element vs f64's 8."""
    from repro.core.tuning import comm_bytes_model
    kw = dict(num_splits=9, world=8, scheme="ozaki2_fp64", num_moduli=15)
    f64 = comm_bytes_model(256, 256, 8192, layout="kshard", comm="f64",
                           **kw)
    i8 = comm_bytes_model(256, 256, 8192, layout="kshard", comm="int8",
                          **kw)
    assert i8["operands"] == 0 and i8["partials"] > 0
    assert f64["total"] > i8["total"]      # tall k amortizes the planes
    rs = comm_bytes_model(256, 256, 8192, layout="kshard", comm="int8",
                          schedule="reduce_scatter", **kw)
    assert rs["partials"] * 2 == i8["partials"]
    # mnshard honesty: ell=15 > 8 loses, ell=5 < 8 wins
    for ell, wins in ((5, True), (15, False)):
        g64 = comm_bytes_model(256, 256, 4096, num_splits=9, world=8,
                               layout="mnshard", comm="f64",
                               scheme="ozaki2_fp64", num_moduli=ell)
        gi8 = comm_bytes_model(256, 256, 4096, num_splits=9, world=8,
                               layout="mnshard", comm="int8",
                               scheme="ozaki2_fp64", num_moduli=ell)
        assert (gi8["total"] < g64["total"]) == wins, (ell, gi8, g64)
    with pytest.raises(ValueError, match="num_moduli"):
        comm_bytes_model(8, 8, 8, num_splits=9, world=2,
                         scheme="ozaki2_fp64")


# ----------------------------------------------------------------------------
# PipelinePlan.comm: validation, serialization, config threading
# ----------------------------------------------------------------------------

def test_plan_comm_validation_and_round_trip():
    plan = PipelinePlan(comm="int8", shard_axis="model")
    assert PipelinePlan.from_dict(plan.to_dict()) == plan
    with pytest.raises(ValueError, match="comm"):
        PipelinePlan(comm="fp8")
    # legacy serialized plans (pre-comm) load with the f64 default
    d = plan.to_dict()
    del d["comm"]
    assert PipelinePlan.from_dict(d).comm == "f64"


def test_plan_for_threads_comm():
    cfg = OzakiConfig(shard_axis="model", comm="int8")
    plan = plan_for(cfg)
    assert plan.comm == "int8" and plan.shard_axis == "model"
    back = apply_pipeline_plan(OzakiConfig(), plan)
    assert back.comm == "int8" and back.shard_axis == "model"
    sel = select_pipeline_plan(64, 64, 512, shard_axis="model", comm="int8")
    assert sel.comm == "int8"
