"""Backend-parity matrix: every executor x {f64, df32} x schedule must
agree on shared random cases.

Executors covered: ``pallas`` (MXU GEMM kernel only), ``pallas_fused``
with ``fusion="stages"`` (one-pass split + fused accumulation kernels),
``pallas_fused`` with ``fusion="epilogue"`` (GEMM + accumulation in one
kernel, int32 products never reach HBM), and the batch-grid executor
behind ``ozaki_matmul_batched`` (explicit batch grid dimension).

Contract (ISSUE acceptance): the fused paths match the XLA path to
<= 1 ulp of the f64 reference. The implementation is actually stronger —
every stage of every pipeline runs the same rounding sequence as the
XLA ops (ldexp-exact splitting, exact int32 GEMMs, matching compensated
accumulation), so the paths are asserted bitwise identical, which implies
the 1-ulp bound trivially. The explicit ulp check stays as the documented
contract in case a future backend trades bitwise equality for speed.
"""
import itertools

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ozaki import (OzakiConfig, dgemm_f64, ozaki_matmul,
                              ozaki_matmul_batched, ozaki_matmul_complex,
                              ozaki_matmul_dw)
from repro.core.tuning import select_plan
from repro.core.xmath import df32_from_f64, df32_to_f64

SCHEDULES = {
    "paper": dict(fuse_diagonals=False, concat_k=False),
    "fuse_diagonals": dict(fuse_diagonals=True, concat_k=False),
    "concat_k": dict(fuse_diagonals=True, concat_k=True),
}

# executor selection knobs per parity column (see core.executors)
EXECUTORS = {
    "pallas": dict(backend="pallas"),
    "pallas_fused": dict(backend="pallas_fused"),
    "pallas_fused_epilogue": dict(backend="pallas_fused",
                                  fuse_epilogue=True),
    "pallas_fused_streaming": dict(backend="pallas_fused",
                                   streaming=True),
}


def _phi_matrix(rng, m, k, phi=1.0):
    return jnp.asarray(rng.uniform(-0.5, 0.5, (m, k))
                       * np.exp(phi * rng.standard_normal((m, k))))


def _assert_within_one_ulp_of_ref(c_test, c_base, ref):
    """|c_test - c_base| <= 1 ulp(reference) elementwise."""
    ulp = np.spacing(np.abs(np.asarray(ref)))
    diff = np.abs(np.asarray(c_test) - np.asarray(c_base))
    assert np.all(diff <= ulp), float((diff / ulp).max())


@pytest.mark.parametrize(
    "executor,accum,schedule",
    list(itertools.product(sorted(EXECUTORS), ["f64", "df32"],
                           sorted(SCHEDULES))))
def test_backend_parity_matrix(rng, executor, accum, schedule):
    a = _phi_matrix(rng, 24, 96)
    b = _phi_matrix(rng, 96, 16)
    kw = dict(num_splits=9, accum=accum, **SCHEDULES[schedule])
    base = np.asarray(ozaki_matmul(a, b, OzakiConfig(backend="xla", **kw)))
    got = np.asarray(ozaki_matmul(
        a, b, OzakiConfig(interpret=True, **EXECUTORS[executor], **kw)))
    ref = np.asarray(dgemm_f64(a, b))
    _assert_within_one_ulp_of_ref(got, base, ref)
    # stronger guarantee the current kernels provide: bitwise identity
    np.testing.assert_array_equal(got, base)


@pytest.mark.parametrize("executor,accum", list(itertools.product(
    sorted(EXECUTORS), ["f64", "df32"])))
def test_backend_parity_odd_shapes(rng, executor, accum):
    """Non-pow2 / odd extents exercise every kernel's padding path."""
    a = _phi_matrix(rng, 23, 131)
    b = _phi_matrix(rng, 131, 19)
    kw = dict(num_splits=7, accum=accum)
    base = np.asarray(ozaki_matmul(a, b, OzakiConfig(backend="xla", **kw)))
    got = np.asarray(ozaki_matmul(
        a, b, OzakiConfig(interpret=True, **EXECUTORS[executor], **kw)))
    np.testing.assert_array_equal(got, base)


@pytest.mark.parametrize(
    "executor,accum",
    list(itertools.product(sorted(EXECUTORS), ["f64", "df32"])))
def test_batch_grid_parity(rng, executor, accum):
    """The batch-grid executors (explicit batch grid dim, no vmap) —
    including the batch-grid EPILOGUE kernel — must be bitwise equal to
    the XLA batched pipeline AND to a loop over the unbatched pipeline,
    odd/non-pow2 shapes included."""
    cfg = OzakiConfig(num_splits=7, accum=accum, **EXECUTORS[executor])
    a = jnp.stack([_phi_matrix(rng, 9, 33) for _ in range(3)])
    b = jnp.stack([_phi_matrix(rng, 33, 11) for _ in range(3)])
    got = np.asarray(ozaki_matmul_batched(a, b, cfg))
    base = np.asarray(ozaki_matmul_batched(
        a, b, OzakiConfig(num_splits=7, accum=accum, backend="xla")))
    loop = np.stack([np.asarray(ozaki_matmul(a[i], b[i], cfg))
                     for i in range(3)])
    np.testing.assert_array_equal(got, base)
    np.testing.assert_array_equal(got, loop)


def test_epilogue_keeps_fusion_on_batch_grid(rng):
    """Stacked weights no longer downgrade fuse_epilogue: the plan keeps
    fusion='epilogue' (the batch-grid epilogue kernel) — and is bitwise
    equal to the stage-fused and xla batched pipelines."""
    cfg = OzakiConfig(num_splits=7, backend="pallas_fused",
                      fuse_epilogue=True)
    assert cfg.plan(batch_layout="grid").fusion == "epilogue"
    a = jnp.stack([_phi_matrix(rng, 8, 32) for _ in range(2)])
    b = jnp.stack([_phi_matrix(rng, 32, 8) for _ in range(2)])
    got = np.asarray(ozaki_matmul_batched(a, b, cfg))
    base = np.asarray(ozaki_matmul_batched(a, b, OzakiConfig(num_splits=7)))
    np.testing.assert_array_equal(got, base)


def test_epilogue_batch_grid_env_fallback_warns_once(rng, monkeypatch):
    """REPRO_OZAKI_BATCHED_EPILOGUE=0 restores the stage-fused fallback
    for stacked-weights batches — with ONE warning stating the reason,
    not a silent fusion-mode switch — and stays bitwise. (The warn-once
    latch is reset per test by the conftest fixture via the public
    ``reset_downgrade_warnings`` API — no monkeypatching module
    internals.)"""
    import warnings

    from repro.core import tuning

    monkeypatch.setenv(tuning.BATCHED_EPILOGUE_ENV, "0")
    cfg = OzakiConfig(num_splits=7, backend="pallas_fused",
                      fuse_epilogue=True)
    with pytest.warns(UserWarning, match="fuse_epilogue downgraded"):
        plan = cfg.plan(batch_layout="grid")
    assert plan.fusion == "stages"
    with warnings.catch_warnings():             # second plan: warn ONCE
        warnings.simplefilter("error")
        assert cfg.plan(batch_layout="grid").fusion == "stages"
    # unbatched plans are untouched by the knob
    assert cfg.plan().fusion == "epilogue"
    a = jnp.stack([_phi_matrix(rng, 8, 32) for _ in range(2)])
    b = jnp.stack([_phi_matrix(rng, 32, 8) for _ in range(2)])
    got = np.asarray(ozaki_matmul_batched(a, b, cfg))
    base = np.asarray(ozaki_matmul_batched(a, b, OzakiConfig(num_splits=7)))
    np.testing.assert_array_equal(got, base)


def test_env_fallback_warning_refires_after_reset(rng, monkeypatch):
    """The latch leaking across tests was a bug: a SECOND consumer of the
    downgrade (fresh process, re-configured deployment, the next test)
    must see the warning again once the latch is reset."""
    from repro.core import tuning

    monkeypatch.setenv(tuning.BATCHED_EPILOGUE_ENV, "0")
    cfg = OzakiConfig(num_splits=7, backend="pallas_fused",
                      fuse_epilogue=True)
    with pytest.warns(UserWarning, match="fuse_epilogue downgraded"):
        cfg.plan(batch_layout="grid")
    tuning.reset_downgrade_warnings()
    with pytest.warns(UserWarning, match="fuse_epilogue downgraded"):
        cfg.plan(batch_layout="grid")            # fires again: fresh state


# fast-mode pair policies ride the SAME executor matrix: "full" must stay
# bitwise-identical to the plain xla pipeline, truncated policies bitwise
# equal to xla under the same policy (truncation is a schedule property,
# not a backend property — the Pallas pair grids shrink with it).
PAIR_POLICIES_TESTED = ("full", "diagonal", "budget:7")


@pytest.mark.parametrize("executor", sorted(EXECUTORS))
@pytest.mark.parametrize("policy", PAIR_POLICIES_TESTED)
def test_pair_policy_parity_matrix(rng, executor, policy):
    a = _phi_matrix(rng, 24, 96)
    b = _phi_matrix(rng, 96, 16)
    kw = dict(num_splits=9, pair_policy=policy)
    base = np.asarray(ozaki_matmul(a, b, OzakiConfig(backend="xla", **kw)))
    got = np.asarray(ozaki_matmul(
        a, b, OzakiConfig(interpret=True, **EXECUTORS[executor], **kw)))
    np.testing.assert_array_equal(got, base)
    if policy == "full":
        plain = np.asarray(ozaki_matmul(a, b, OzakiConfig(num_splits=9)))
        np.testing.assert_array_equal(got, plain)


@pytest.mark.parametrize("policy", ["diagonal", "budget:5"])
def test_pair_policy_batch_grid_parity(rng, policy):
    """Truncated pair grids on the batch-grid epilogue kernel: bitwise
    equal to the xla batched pipeline under the same policy."""
    kw = dict(num_splits=7, pair_policy=policy)
    a = jnp.stack([_phi_matrix(rng, 9, 33) for _ in range(3)])
    b = jnp.stack([_phi_matrix(rng, 33, 11) for _ in range(3)])
    got = np.asarray(ozaki_matmul_batched(
        a, b, OzakiConfig(backend="pallas_fused", fuse_epilogue=True, **kw)))
    base = np.asarray(ozaki_matmul_batched(
        a, b, OzakiConfig(backend="xla", **kw)))
    np.testing.assert_array_equal(got, base)


@pytest.mark.parametrize("executor", ["pallas_fused",
                                      "pallas_fused_epilogue",
                                      "pallas_fused_streaming"])
@pytest.mark.parametrize("schedule", sorted(SCHEDULES))
def test_backend_parity_dw_native(rng, schedule, executor):
    """TPU-native df32 entry: fused pipelines == XLA pipeline bitwise."""
    a = df32_from_f64(_phi_matrix(rng, 16, 64, 0.5))
    b_t = df32_from_f64(_phi_matrix(rng, 8, 64, 0.5))
    kw = dict(num_splits=9, accum="df32", **SCHEDULES[schedule])
    base = ozaki_matmul_dw(a, b_t, OzakiConfig(backend="xla", **kw))
    got = ozaki_matmul_dw(a, b_t,
                          OzakiConfig(**EXECUTORS[executor], **kw))
    np.testing.assert_array_equal(np.asarray(df32_to_f64(base)),
                                  np.asarray(df32_to_f64(got)))


def test_parity_with_tuned_plan(rng):
    """A tuning-selected TilePlan must not change results, only launches."""
    a = _phi_matrix(rng, 40, 200)
    b = _phi_matrix(rng, 200, 24)
    plan = select_plan(40, 24, 200, num_splits=9)
    base = np.asarray(ozaki_matmul(a, b, OzakiConfig(num_splits=9)))
    got = np.asarray(ozaki_matmul(
        a, b, OzakiConfig(num_splits=9, backend="pallas_fused", tile=plan,
                          fuse_diagonals=plan.fuse_diagonals,
                          concat_k=plan.concat_k)))
    # tile/schedule changes regroup exact int32 sums only
    ref = np.asarray(dgemm_f64(a, b))
    _assert_within_one_ulp_of_ref(got, base, ref)


# complex pipelines x pair truncation x fused executors: truncation is a
# schedule property, so it must compose with BOTH complex algorithms
# (4-mul paper form, 3-mul Karatsuba) and with every fused executor,
# bitwise against xla under the same knobs.
@pytest.mark.parametrize("executor", ["pallas_fused_epilogue",
                                      "pallas_fused_streaming"])
@pytest.mark.parametrize("algo", ["4mul", "3mul"])
@pytest.mark.parametrize("policy", ["diagonal", "budget:7"])
def test_complex_pair_policy_parity(rng, executor, algo, policy):
    a = _phi_matrix(rng, 12, 48) + 1j * np.asarray(_phi_matrix(rng, 12, 48))
    b = _phi_matrix(rng, 48, 10) + 1j * np.asarray(_phi_matrix(rng, 48, 10))
    kw = dict(num_splits=9, pair_policy=policy)
    base = np.asarray(ozaki_matmul_complex(
        a, b, OzakiConfig(backend="xla", **kw), algo=algo))
    got = np.asarray(ozaki_matmul_complex(
        a, b, OzakiConfig(interpret=True, **EXECUTORS[executor], **kw),
        algo=algo))
    np.testing.assert_array_equal(got, base)


@pytest.mark.parametrize("executor", ["pallas_fused_epilogue",
                                      "pallas_fused_streaming"])
@pytest.mark.parametrize("algo", ["4mul", "3mul"])
def test_complex_fast_mode_parity(rng, executor, algo):
    """fast_mode (accuracy-adaptive truncation) composes with the complex
    pipelines on the fused executors — bitwise vs xla, same knobs."""
    a = _phi_matrix(rng, 12, 48) + 1j * np.asarray(_phi_matrix(rng, 12, 48))
    b = _phi_matrix(rng, 48, 10) + 1j * np.asarray(_phi_matrix(rng, 48, 10))
    kw = dict(num_splits=9, fast_mode=True, target_error=1e-20)
    base = np.asarray(ozaki_matmul_complex(
        a, b, OzakiConfig(backend="xla", **kw), algo=algo))
    got = np.asarray(ozaki_matmul_complex(
        a, b, OzakiConfig(interpret=True, **EXECUTORS[executor], **kw),
        algo=algo))
    np.testing.assert_array_equal(got, base)


def test_unknown_backend_raises(rng):
    a = _phi_matrix(rng, 8, 32)
    b = _phi_matrix(rng, 32, 8)
    with pytest.raises(ValueError, match="unknown backend"):
        ozaki_matmul(a, b, OzakiConfig(backend="cuda"))
