"""Backend-parity matrix: {xla, pallas, pallas_fused} x {f64, df32} x
schedule must agree on shared random cases.

Contract (ISSUE acceptance): the fused path matches the XLA path to
<= 1 ulp of the f64 reference. The implementation is actually stronger —
every stage of the fused pipeline runs the same rounding sequence as the
XLA ops (ldexp-exact splitting, exact int32 GEMMs, matching compensated
accumulation), so the paths are asserted bitwise identical, which implies
the 1-ulp bound trivially. The explicit ulp check stays as the documented
contract in case a future backend trades bitwise equality for speed.
"""
import itertools

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ozaki import (OzakiConfig, dgemm_f64, ozaki_matmul,
                              ozaki_matmul_dw)
from repro.core.tuning import select_plan
from repro.core.xmath import df32_from_f64, df32_to_f64

SCHEDULES = {
    "paper": dict(fuse_diagonals=False, concat_k=False),
    "fuse_diagonals": dict(fuse_diagonals=True, concat_k=False),
    "concat_k": dict(fuse_diagonals=True, concat_k=True),
}


def _phi_matrix(rng, m, k, phi=1.0):
    return jnp.asarray(rng.uniform(-0.5, 0.5, (m, k))
                       * np.exp(phi * rng.standard_normal((m, k))))


def _assert_within_one_ulp_of_ref(c_test, c_base, ref):
    """|c_test - c_base| <= 1 ulp(reference) elementwise."""
    ulp = np.spacing(np.abs(np.asarray(ref)))
    diff = np.abs(np.asarray(c_test) - np.asarray(c_base))
    assert np.all(diff <= ulp), float((diff / ulp).max())


@pytest.mark.parametrize(
    "backend,accum,schedule",
    list(itertools.product(["pallas", "pallas_fused"], ["f64", "df32"],
                           sorted(SCHEDULES))))
def test_backend_parity_matrix(rng, backend, accum, schedule):
    a = _phi_matrix(rng, 24, 96)
    b = _phi_matrix(rng, 96, 16)
    kw = dict(num_splits=9, accum=accum, **SCHEDULES[schedule])
    base = np.asarray(ozaki_matmul(a, b, OzakiConfig(backend="xla", **kw)))
    got = np.asarray(ozaki_matmul(
        a, b, OzakiConfig(backend=backend, interpret=True, **kw)))
    ref = np.asarray(dgemm_f64(a, b))
    _assert_within_one_ulp_of_ref(got, base, ref)
    # stronger guarantee the current kernels provide: bitwise identity
    np.testing.assert_array_equal(got, base)


@pytest.mark.parametrize("schedule", sorted(SCHEDULES))
def test_backend_parity_dw_native(rng, schedule):
    """TPU-native df32 entry: fused pipeline == XLA pipeline bitwise."""
    a = df32_from_f64(_phi_matrix(rng, 16, 64, 0.5))
    b_t = df32_from_f64(_phi_matrix(rng, 8, 64, 0.5))
    kw = dict(num_splits=9, accum="df32", **SCHEDULES[schedule])
    base = ozaki_matmul_dw(a, b_t, OzakiConfig(backend="xla", **kw))
    got = ozaki_matmul_dw(a, b_t, OzakiConfig(backend="pallas_fused", **kw))
    np.testing.assert_array_equal(np.asarray(df32_to_f64(base)),
                                  np.asarray(df32_to_f64(got)))


def test_parity_with_tuned_plan(rng):
    """A tuning-selected TilePlan must not change results, only launches."""
    a = _phi_matrix(rng, 40, 200)
    b = _phi_matrix(rng, 200, 24)
    plan = select_plan(40, 24, 200, num_splits=9)
    base = np.asarray(ozaki_matmul(a, b, OzakiConfig(num_splits=9)))
    got = np.asarray(ozaki_matmul(
        a, b, OzakiConfig(num_splits=9, backend="pallas_fused", tile=plan,
                          fuse_diagonals=plan.fuse_diagonals,
                          concat_k=plan.concat_k)))
    # tile/schedule changes regroup exact int32 sums only
    ref = np.asarray(dgemm_f64(a, b))
    _assert_within_one_ulp_of_ref(got, base, ref)


def test_unknown_backend_raises(rng):
    a = _phi_matrix(rng, 8, 32)
    b = _phi_matrix(rng, 32, 8)
    with pytest.raises(ValueError, match="unknown backend"):
        ozaki_matmul(a, b, OzakiConfig(backend="cuda"))
