"""parallel.collectives: ring primitives and exact integer reductions.

The ring all-gather and the int32 reduce-scatter are load-bearing for the
int8-slice transport (``parallel.ozaki_shard``): the gather must restore
GLOBAL source order for any ring stride, and the scatter must be exactly
the associative integer sum (bitwise == the reference all-gather + sum).
"""
import pytest

from util import run_multidevice


def test_ring_all_gather_matches_lax_all_gather():
    """hop=1 and a non-contiguous hop=3 ring both reproduce
    ``jax.lax.all_gather`` exactly (source-order restore is by actual
    per-step source id, not by position)."""
    out = run_multidevice("""
import jax, numpy as np, jax.numpy as jnp
from functools import partial
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_mesh_compat
from repro.parallel.collectives import ring_all_gather

mesh = make_mesh_compat((8,), ('data',))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((16, 5)), jnp.float32)

def run(hop):
    def local(blk):
        return ring_all_gather(blk, 'data', 8, hop=hop)
    return shard_map(local, mesh=mesh, in_specs=P('data'),
                     out_specs=P(), check_rep=False)(x)

def ref():
    def local(blk):
        g = jax.lax.all_gather(blk, 'data')      # (8, chunk, 5)
        return g.reshape(-1, g.shape[-1])
    return shard_map(local, mesh=mesh, in_specs=P('data'),
                     out_specs=P(), check_rep=False)(x)

r = np.asarray(ref())
assert np.array_equal(r, np.asarray(x))          # sanity: gather restores x
for hop in (1, 3, 5, 7, 9):                      # 9 % 8 == 1: wrapped stride
    got = np.asarray(run(hop))
    assert np.array_equal(got, r), f'hop={hop}'
print('OK')
""")
    assert "OK" in out


def test_ring_all_gather_rejects_degenerate_ring():
    """gcd(hop, axis_size) != 1 never visits every device — the helper
    must refuse instead of silently dropping source blocks."""
    import math

    from repro.parallel.collectives import ring_all_gather

    import jax.numpy as jnp

    for hop in (2, 4, 6):
        with pytest.raises(ValueError, match="does not generate"):
            ring_all_gather(jnp.zeros((2, 2)), "data", 8, hop=hop)
    assert math.gcd(3, 8) == 1  # the hops the mesh test exercises are rings


def test_reduce_scatter_sum_int32_exact():
    """psum_scatter of int32 == all-gather + exact sum, sliced — the
    bitwise contract the reduce_scatter/rs_stream Ozaki schedules rely
    on (associative integer adds, any reduction order)."""
    out = run_multidevice("""
import jax, numpy as np, jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_mesh_compat
from repro.parallel.collectives import psum_exact_int32, reduce_scatter_sum

mesh = make_mesh_compat((8,), ('data',))
rng = np.random.default_rng(1)
# big enough values that float reduction WOULD round: int32 must not
vals = jnp.asarray(rng.integers(-2**24, 2**24, (8, 4, 16)), jnp.int32)

def local_rs(v):
    return reduce_scatter_sum(v[0], 'data', scatter_dim=1)

def local_psum(v):
    return psum_exact_int32(v[0], 'data')

rs = shard_map(local_rs, mesh=mesh, in_specs=P('data', None, None),
               out_specs=P(None, 'data'), check_rep=False)(vals)
tot = shard_map(local_psum, mesh=mesh, in_specs=P('data', None, None),
                out_specs=P(), check_rep=False)(vals)
exact = np.asarray(vals, np.int64).sum(axis=0)
assert np.array_equal(np.asarray(tot, np.int64), exact)
assert np.array_equal(np.asarray(rs, np.int64), exact)
print('OK')
""")
    assert "OK" in out
