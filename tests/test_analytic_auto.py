"""Analytic cost model (Fig. 4 claims) + INT8-AUTO split selection."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.analytic import (ALL_MMUS, DGEMM_MANTISSA_SPACE, FP16_FP32,
                                 INT4_INT32, INT8_INT32, INT12_INT32,
                                 ozaki_flops, ozaki_hp_accum_ops)
from repro.core.auto_split import auto_num_splits
from repro.core.splitting import compute_alpha

TARGET_RANGE = [2 ** e for e in range(11, 21)]


def test_bps_ordering_paper_sec_321():
    """INT8 BPS >= FP16 BPS in the target range; INT4 fixed at 3."""
    for k in TARGET_RANGE:
        assert INT8_INT32.bps(k) >= FP16_FP32.bps(k)
        assert INT4_INT32.bps(k) == 3
        if k < 2 ** 18:
            assert INT8_INT32.bps(k) == 7      # = ell_in, no waste > 1


def test_fewer_splits_than_fp16_sec_322():
    for k in TARGET_RANGE:
        assert INT8_INT32.num_splits(k, DGEMM_MANTISSA_SPACE) <= \
            FP16_FP32.num_splits(k, DGEMM_MANTISSA_SPACE)
        if k <= 2 ** 16:   # beyond, FP16's alpha collapses below INT4's 3
            assert INT4_INT32.num_splits(k, DGEMM_MANTISSA_SPACE) >= \
                FP16_FP32.num_splits(k, DGEMM_MANTISSA_SPACE)


def test_memory_saving_sec_323():
    """Paper: integers save 50-75% of slice working memory vs FP16."""
    for k in TARGET_RANGE:
        fp16 = FP16_FP32.slice_bytes_per_element(k, DGEMM_MANTISSA_SPACE)
        int8 = INT8_INT32.slice_bytes_per_element(k, DGEMM_MANTISSA_SPACE)
        saving = 1 - int8 / fp16
        assert 0.45 <= saving <= 0.85, (k, saving)
        # INT8 is the least-memory IMMU (up to k ~ 2^17; beyond, INT8's
        # alpha drops below ell_in and INT4's fixed 3 bits catch up —
        # visible in the paper's own Fig. 4 bottom-left)
        if k <= 2 ** 17:
            for mmu in (INT4_INT32, INT12_INT32):
                assert int8 <= mmu.slice_bytes_per_element(
                    k, DGEMM_MANTISSA_SPACE)


def test_gemm_count_sec_324():
    for k in TARGET_RANGE:
        s8 = INT8_INT32.num_splits(k, DGEMM_MANTISSA_SPACE)
        assert INT8_INT32.num_gemms(k, DGEMM_MANTISSA_SPACE) == \
            s8 * (s8 + 1) // 2
        # INT4 needs ~6x the operations of INT8 (paper Sec. 3.2.4)
        ratio = INT4_INT32.num_gemms(k, DGEMM_MANTISSA_SPACE) / \
            INT8_INT32.num_gemms(k, DGEMM_MANTISSA_SPACE)
        assert ratio > 2.5


def test_alpha_closed_form_matches_exact():
    """Eq. (4) floor form vs the overflow-exact implementation."""
    for k in TARGET_RANGE:
        assert abs(INT8_INT32.alpha(k) - compute_alpha(k)) <= 1


def test_flops_model():
    assert ozaki_flops(4, 5, 6, 1) == 2 * 4 * 5 * 6
    assert ozaki_flops(4, 5, 6, 9) == 2 * 4 * 5 * 6 * 45
    assert ozaki_hp_accum_ops(4, 5, 9, True) == 4 * 5 * 9
    assert ozaki_hp_accum_ops(4, 5, 9, False) == 4 * 5 * 45


# --------------------------------------------------------------------------
# INT8-AUTO
# --------------------------------------------------------------------------

def _phi(rng, m, k, phi):
    return jnp.asarray(rng.uniform(-0.5, 0.5, (m, k))
                       * np.exp(phi * rng.standard_normal((m, k))))


def test_auto_monotone_in_threshold(rng):
    a = _phi(rng, 16, 64, 1.0)
    b = _phi(rng, 64, 16, 1.0)
    s0 = auto_num_splits(a, b, w=7, threshold_bits=0.0)
    s1 = auto_num_splits(a, b, w=7, threshold_bits=1.0)
    assert s1 <= s0
    assert s0 >= 8      # T=0 keeps all 53 bits: ~ceil((53+phi)/7)


def test_auto_monotone_in_phi(rng):
    narrow = auto_num_splits(_phi(rng, 16, 64, 0.1), _phi(rng, 64, 16, 0.1),
                             w=7, threshold_bits=0.0)
    wide = auto_num_splits(_phi(rng, 16, 64, 4.0), _phi(rng, 64, 16, 4.0),
                           w=7, threshold_bits=0.0)
    assert wide > narrow


def test_auto_t0_gives_exactness(rng):
    """T=0 split count -> error at dd-oracle level (paper Sec. 4.4)."""
    from repro.core.ozaki import OzakiConfig, ozaki_matmul
    from repro.core.xmath import dd_matmul_np, rel_error_vs_dd
    a = _phi(rng, 16, 64, 1.0)
    b = _phi(rng, 64, 12, 1.0)
    s = auto_num_splits(a, b, w=7, threshold_bits=0.0)
    c = ozaki_matmul(a, b, OzakiConfig(num_splits=s))
    hi, lo = dd_matmul_np(np.asarray(a), np.asarray(b))
    assert float(np.max(rel_error_vs_dd(np.asarray(c), hi, lo))) < 1e-15
