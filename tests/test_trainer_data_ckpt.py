"""Trainer math, data determinism, checkpoint roundtrip/restart."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt as ckpt_lib
from repro.configs import get_config
from repro.data.pipeline import TokenSource, DataConfig, make_data, \
    write_corpus
from repro.models import init_model
from repro.train.optimizer import (AdamWState, OptimizerConfig, adamw_init,
                                   adamw_update, cosine_lr,
                                   clip_by_global_norm)
from repro.train.trainer import loss_fn, split_microbatches, train_step

KEY = jax.random.key(0)


# --------------------------------------------------------------------------
# optimizer
# --------------------------------------------------------------------------

def test_adamw_matches_reference(rng):
    oc = OptimizerConfig(peak_lr=1e-2, warmup_steps=0, total_steps=10,
                         weight_decay=0.0, grad_clip_norm=1e9,
                         min_lr_ratio=1.0)
    p = {"w": jnp.asarray(rng.standard_normal(5), jnp.float32)}
    g = {"w": jnp.asarray(rng.standard_normal(5), jnp.float32)}
    st = adamw_init(p)
    new_p, st2, m = adamw_update(g, st, p, oc)
    gw = np.asarray(g["w"])
    mh = (0.1 * gw) / (1 - 0.9)
    vh = (0.05 * gw ** 2) / (1 - 0.95)
    want = np.asarray(p["w"]) - 1e-2 * mh / (np.sqrt(vh) + oc.eps)
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-5)


def test_cosine_schedule_shape():
    oc = OptimizerConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                         min_lr_ratio=0.1)
    lrs = [float(cosine_lr(oc, jnp.int32(s))) for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1)


def test_grad_clip(rng):
    g = {"a": jnp.asarray([3.0, 4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(5.0)
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.6, 0.8],
                               rtol=1e-6)


def test_bf16_moment_update_stays_bf16(rng):
    oc = OptimizerConfig()
    p = {"w": jnp.ones(4, jnp.bfloat16)}
    st = adamw_init(p, moment_dtype=jnp.bfloat16)
    g = {"w": jnp.full(4, 0.5, jnp.bfloat16)}
    new_p, st2, _ = adamw_update(g, st, p, oc)
    assert st2.mu["w"].dtype == jnp.bfloat16
    assert new_p["w"].dtype == jnp.bfloat16


# --------------------------------------------------------------------------
# trainer
# --------------------------------------------------------------------------

def _tiny_setup(rng, steps_cfg=40):
    cfg = get_config("llama3.2-3b").reduced()
    params, _ = init_model(cfg, KEY)
    oc = OptimizerConfig(peak_lr=5e-3, warmup_steps=2,
                         total_steps=steps_cfg)
    data = make_data(cfg, seq_len=32, global_batch=4)
    return cfg, params, oc, data


def test_loss_decreases(rng):
    cfg, params, oc, data = _tiny_setup(rng)
    opt = adamw_init(params)
    losses = []
    for step in range(12):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        params, opt, metrics = train_step(cfg, oc, params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.1, losses


def test_grad_accum_consistent(rng):
    cfg, params, oc, data = _tiny_setup(rng)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    p1, _, m1 = train_step(cfg, oc, params, adamw_init(params), batch)
    micro = {k: jnp.asarray(v) for k, v in
             split_microbatches({k: np.asarray(v) for k, v in
                                 batch.items()}, 2).items()}
    p2, _, m2 = train_step(cfg, oc, params, adamw_init(params), micro,
                           grad_accum=2)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_vision_loss_masks_patches(rng):
    cfg = get_config("internvl2-76b").reduced()
    params, _ = init_model(cfg, KEY)
    b, s = 2, 16
    batch = {"tokens": jnp.asarray(rng.integers(
        0, cfg.vocab_size, (b, s)), jnp.int32),
        "patch_embeds": jnp.asarray(
            rng.standard_normal((b, cfg.num_patches, cfg.d_model)),
            jnp.float32)}
    loss, metrics = loss_fn(cfg, params, batch)
    assert bool(jnp.isfinite(loss))


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------

def test_data_deterministic():
    cfg = get_config("llama3.2-3b").reduced()
    d1 = make_data(cfg, 16, 4, seed=7)
    d2 = make_data(cfg, 16, 4, seed=7)
    b1, b2 = d1.batch_at(5), d2.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], d1.batch_at(6)["tokens"])


def test_data_host_sharding():
    cfg = get_config("llama3.2-3b").reduced()
    d = make_data(cfg, 16, 8, seed=7)
    full = d.batch_at(3)["tokens"]
    h0 = d.batch_at(3, host_index=0, host_count=2)["tokens"]
    h1 = d.batch_at(3, host_index=1, host_count=2)["tokens"]
    np.testing.assert_array_equal(np.concatenate([h0, h1]), full)


def test_memmap_corpus(tmp_path):
    path = str(tmp_path / "corpus.bin")
    write_corpus(path, 10_000, vocab=100)
    cfg = get_config("llama3.2-3b").reduced()
    d = make_data(cfg, 16, 2, memmap_path=path)
    b = d.batch_at(0)["tokens"]
    assert b.shape == (2, 16)
    assert b.max() < cfg.vocab_size
    np.testing.assert_array_equal(
        b, make_data(cfg, 16, 2, memmap_path=path).batch_at(0)["tokens"])


def test_audio_vlm_batches():
    for arch in ("musicgen-medium", "internvl2-76b"):
        cfg = get_config(arch).reduced()
        d = make_data(cfg, 16, 2)
        b = d.batch_at(0)
        if cfg.frontend == "audio":
            assert b["tokens"].shape == (2, 16, cfg.num_codebooks)
        else:
            assert b["patch_embeds"].shape == (2, cfg.num_patches,
                                               cfg.d_model)
            assert b["tokens"].shape == (2, 16 - cfg.num_patches)


# --------------------------------------------------------------------------
# checkpoint
# --------------------------------------------------------------------------

def test_ckpt_roundtrip(tmp_path, rng):
    tree = {"params": {"w": jnp.asarray(rng.standard_normal((4, 3)),
                                        jnp.float32),
                       "b": jnp.asarray(rng.standard_normal(3),
                                        jnp.bfloat16)},
            "step": jnp.int32(7)}
    ckpt_lib.save(str(tmp_path), 7, tree, meta={"data_cursor": 7})
    assert ckpt_lib.latest_step(str(tmp_path)) == 7
    assert ckpt_lib.verify(str(tmp_path), 7)
    out = ckpt_lib.restore(str(tmp_path), 7, jax.eval_shape(lambda: tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ckpt_async_and_gc(tmp_path, rng):
    tree = {"w": jnp.ones((8,))}
    threads = [ckpt_lib.save(str(tmp_path), s, tree, async_write=True,
                             keep_last=2) for s in (1, 2, 3)]
    for t in threads:
        t.join()
    ckpt_lib.save(str(tmp_path), 4, tree, keep_last=2)
    assert ckpt_lib.all_steps(str(tmp_path)) == [3, 4]


def test_ckpt_shape_mismatch_raises(tmp_path):
    ckpt_lib.save(str(tmp_path), 1, {"w": jnp.ones((4,))})
    with pytest.raises(ValueError):
        ckpt_lib.restore(str(tmp_path), 1,
                         {"w": jax.ShapeDtypeStruct((5,), jnp.float32)})


def test_ckpt_missing_key_raises(tmp_path):
    ckpt_lib.save(str(tmp_path), 1, {"w": jnp.ones((4,))})
    with pytest.raises(ValueError):
        ckpt_lib.restore(str(tmp_path), 1,
                         {"w": jax.ShapeDtypeStruct((4,), jnp.float32),
                          "extra": jax.ShapeDtypeStruct((1,), jnp.float32)})
