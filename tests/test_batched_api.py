"""Batched Ozaki GEMM API — deterministic coverage.

(The hypothesis property-test versions of these claims live in
``test_batched_props.py``; this module keeps the guarantees exercised
even where hypothesis is not installed.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ozaki import OzakiConfig, ozaki_matmul, ozaki_matmul_batched


def _phi_stack(rng, bsz, m, k, phi=1.0):
    return jnp.asarray(rng.uniform(-0.5, 0.5, (bsz, m, k))
                       * np.exp(phi * rng.standard_normal((bsz, m, k))))


@pytest.mark.parametrize("backend", ["xla", "pallas_fused"])
def test_batch_of_one_equals_unbatched(rng, backend):
    cfg = OzakiConfig(num_splits=9, backend=backend)
    a = _phi_stack(rng, 1, 16, 64)
    b = _phi_stack(rng, 1, 64, 8)
    batched = np.asarray(ozaki_matmul_batched(a, b, cfg))
    single = np.asarray(ozaki_matmul(a[0], b[0], cfg))
    np.testing.assert_array_equal(batched[0], single)


@pytest.mark.parametrize("backend", ["xla", "pallas_fused"])
def test_broadcast_weights_equals_loop(rng, backend):
    """(B, m, k) @ (k, n) must equal a Python loop over ozaki_matmul."""
    cfg = OzakiConfig(num_splits=9, backend=backend)
    a = _phi_stack(rng, 3, 8, 48)
    w = _phi_stack(rng, 1, 48, 8)[0]
    got = np.asarray(ozaki_matmul_batched(a, w, cfg))
    want = np.stack([np.asarray(ozaki_matmul(a[i], w, cfg))
                     for i in range(3)])
    np.testing.assert_array_equal(got, want)


def test_fully_batched_equals_loop(rng):
    cfg = OzakiConfig(num_splits=9)
    a = _phi_stack(rng, 3, 8, 48)
    b = _phi_stack(rng, 3, 48, 8)
    got = np.asarray(ozaki_matmul_batched(a, b, cfg))
    want = np.stack([np.asarray(ozaki_matmul(a[i], b[i], cfg))
                     for i in range(3)])
    np.testing.assert_array_equal(got, want)


def test_f32_inputs_take_df32_path(rng):
    """f32 operands run the TPU-native pipeline and return f32."""
    cfg = OzakiConfig(num_splits=9, accum="df32")
    a = _phi_stack(rng, 2, 8, 32, 0.5).astype(jnp.float32)
    w = _phi_stack(rng, 1, 32, 8, 0.5)[0].astype(jnp.float32)
    out = ozaki_matmul_batched(a, w, cfg)
    assert out.dtype == jnp.float32
    ref = np.einsum("bmk,kn->bmn", np.asarray(a, np.float64),
                    np.asarray(w, np.float64))
    err = np.abs(np.asarray(out) - ref).max() / np.abs(ref).max()
    assert err < 1e-6, err          # f32 result carries full f32 precision


def test_jit_and_grad(rng):
    """dtypes and gradients survive jax.jit (exact-product JVP)."""
    cfg = OzakiConfig(num_splits=9)
    a = _phi_stack(rng, 2, 8, 32, 0.5)
    w = _phi_stack(rng, 1, 32, 8, 0.5)[0]

    fn = jax.jit(lambda x, y: ozaki_matmul_batched(x, y, cfg))
    out = fn(a, w)
    assert out.dtype == jnp.float64 and out.shape == (2, 8, 8)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ozaki_matmul_batched(a, w, cfg)))

    loss = jax.jit(jax.grad(
        lambda x, y: jnp.sum(ozaki_matmul_batched(x, y, cfg) ** 2),
        argnums=(0, 1)))
    ga, gw = loss(a, w)
    assert ga.shape == a.shape and gw.shape == w.shape
    # exact-product rule: grads equal those of the plain matmul
    ga_ref, gw_ref = jax.grad(
        lambda x, y: jnp.sum(jnp.matmul(x, y) ** 2), argnums=(0, 1))(a, w)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(ga_ref),
                               rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(gw_ref),
                               rtol=1e-12, atol=1e-12)


def test_shape_and_dtype_validation(rng):
    cfg = OzakiConfig()
    a = _phi_stack(rng, 2, 4, 8)
    with pytest.raises(ValueError, match="batch, m, k"):
        ozaki_matmul_batched(a[0], a[0].T, cfg)
    with pytest.raises(ValueError, match="batch mismatch"):
        ozaki_matmul_batched(a, _phi_stack(rng, 3, 8, 4), cfg)
    with pytest.raises(ValueError, match="contraction mismatch"):
        ozaki_matmul_batched(a, _phi_stack(rng, 2, 9, 4), cfg)
    with pytest.raises(TypeError, match="dtype mismatch"):
        ozaki_matmul_batched(a, _phi_stack(rng, 2, 8, 4).astype(jnp.float32),
                             cfg)
