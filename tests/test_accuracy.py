"""Accuracy-adaptive planning + fast-mode pair truncation (ISSUE 4).

Covers the ``core.accuracy`` bound family (brute-force-validated eta,
split/budget selection, per-input spread refinement), the end-to-end
``target_error``/``fast_mode``/``pair_policy`` knobs through
``OzakiConfig`` and the model/serving layers, the golden-pin bound checks
(s in {5, 9, 13}), and the zero-cancellation regression: zero
rows/columns in BOTH operands must flow through every backend — and the
new exponent statistics — without -inf/NaN, under accuracy-adaptive
planning too.
"""
import dataclasses
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.accuracy import (MAX_SPLITS, accum_floor, error_bound,
                                 exponent_spread, input_truncation_eta,
                                 kept_pairs, min_splits_for, pair_budget_for,
                                 required_splits, resolve_accuracy,
                                 scaled_error, truncation_eta)
from repro.core.ozaki import (OzakiConfig, dgemm_f64, ozaki_matmul,
                              ozaki_matmul_batched, resolve_accuracy_config)
from repro.core.splitting import slice_width
from repro.core.tuning import parse_pair_policy
from repro.core.xmath import dd_matmul_np


def _phi(rng, m, k, phi=1.0):
    return (rng.uniform(-0.5, 0.5, (m, k))
            * np.exp(phi * rng.standard_normal((m, k))))


# ----------------------------------------------------------------------------
# The eta bound: brute force, monotonicity, policy ordering
# ----------------------------------------------------------------------------

def _brute_eta(s, w, policy="full", lim=300):
    kept = set(kept_pairs(s, pair_policy=policy))
    r = 2.0 ** -w
    return math.fsum(r ** (p + q)
                     for p in range(lim) for q in range(lim)
                     if (p, q) not in kept)


@pytest.mark.parametrize("s,w,policy", [
    (5, 7, "full"), (5, 7, "diagonal"), (5, 7, "budget:7"),
    (9, 7, "full"), (9, 7, "budget:45"), (2, 3, "budget:1"),
    (1, 7, "full"),
])
def test_truncation_eta_matches_brute_force(s, w, policy):
    got = truncation_eta(s, w, pair_policy=policy)
    want = _brute_eta(s, w, policy)
    assert got == pytest.approx(want, rel=1e-10)


def test_truncation_eta_monotone_in_splits_and_budget():
    etas = [truncation_eta(s, 7) for s in range(1, 14)]
    assert all(a > b for a, b in zip(etas, etas[1:]))
    budgets = [truncation_eta(9, 7, pair_policy=f"budget:{n}")
               for n in range(1, 46)]
    assert all(a > b for a, b in zip(budgets, budgets[1:]))
    # policy ordering: full < diagonal < tiny budget
    assert truncation_eta(9, 7) < truncation_eta(9, 7,
                                                 pair_policy="diagonal")
    assert truncation_eta(9, 7, pair_policy="diagonal") < \
        truncation_eta(9, 7, pair_policy="budget:3")


def test_min_splits_for_meets_and_is_minimal():
    k = 192
    prev = 1
    for tgt in (1e-2, 1e-6, 1e-10, 1e-14):
        s = min_splits_for(tgt, k)
        w = slice_width(k, fuse_terms=s)
        assert k * truncation_eta(s, w) <= tgt
        if s > 1:
            w1 = slice_width(k, fuse_terms=s - 1)
            assert k * truncation_eta(s - 1, w1) > tgt
        assert s >= prev
        prev = s
    with pytest.raises(ValueError, match="target_error"):
        min_splits_for(0.0, k)


def test_pair_budget_for_meets_and_is_minimal():
    k, s = 192, 9
    w = slice_width(k, fuse_terms=s)
    for tgt in (1e-6, 1e-10):
        policy = pair_budget_for(tgt, s, w, k)
        assert policy.startswith("budget:")
        n = int(policy.split(":")[1])
        assert k * truncation_eta(s, w, pair_policy=policy) <= tgt
        assert k * truncation_eta(s, w, pair_policy=f"budget:{n-1}") > tgt
    # no headroom: the target needs every pair of the schedule
    tight = k * truncation_eta(s, w) * 1.5
    assert pair_budget_for(tight, s, w, k) in ("full", "budget:44")


def test_resolve_accuracy_semantics():
    k = 192
    # fast mode without a target drops the last diagonal
    assert resolve_accuracy(k, 9, fast_mode=True) == (9, "diagonal")
    # a target REDUCES s, never raises it
    s, policy = resolve_accuracy(k, 9, target_error=1e-8)
    assert s < 9 and policy == "full"
    s_loose, _ = resolve_accuracy(k, 3, target_error=1e-20)
    assert s_loose == 3                          # ceiling respected
    # explicit policy wins over fast_mode
    assert resolve_accuracy(k, 9, fast_mode=True,
                            pair_policy="budget:5")[1] == "budget:5"
    # idempotent
    s2, p2 = resolve_accuracy(k, 9, target_error=1e-8, fast_mode=True)
    assert resolve_accuracy(k, s2, target_error=1e-8, fast_mode=True,
                            pair_policy=p2) == (s2, p2)


# ----------------------------------------------------------------------------
# Golden-pin shapes: truncated policies meet the computed bound
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("num_splits", [5, 9, 13])
@pytest.mark.parametrize("phi", [0.1, 1.0])
def test_truncated_policies_meet_bound_on_golden_shapes(num_splits, phi):
    rng = np.random.default_rng(42)
    a = _phi(rng, 32, 128, phi)
    b = _phi(rng, 128, 24, phi)
    hi, lo = dd_matmul_np(a, b)
    k = 128
    cfg0 = OzakiConfig(num_splits=num_splits)
    w = cfg0.width_for(k)
    half = max(1, cfg0.num_gemms // 2)
    for policy in ("full", "diagonal", f"budget:{half}"):
        cfg = dataclasses.replace(cfg0, pair_policy=policy)
        c = np.asarray(ozaki_matmul(jnp.asarray(a), jnp.asarray(b), cfg))
        bound = error_bound(num_splits, w, k, pair_policy=policy)
        serr = scaled_error(c, hi, a, b, ref_lo=lo)
        assert serr <= bound, (policy, serr, bound)
        # the bound is informative, not vacuous: truncating to half the
        # pairs must cost accuracy the full schedule does not
    full = scaled_error(np.asarray(ozaki_matmul(jnp.asarray(a),
                                                jnp.asarray(b), cfg0)),
                        hi, a, b, ref_lo=lo)
    trunc = scaled_error(np.asarray(ozaki_matmul(
        jnp.asarray(a), jnp.asarray(b),
        dataclasses.replace(cfg0, pair_policy=f"budget:{half}"))),
        hi, a, b, ref_lo=lo)
    assert trunc >= full


def test_config_target_error_end_to_end():
    """cfg.target_error/fast_mode resolve per shape and the result meets
    target + accumulation floor (a theorem: the target sits above the
    configured ceiling's guaranteed bound)."""
    rng = np.random.default_rng(3)
    k = 128
    a = jnp.asarray(_phi(rng, 24, k))
    b = jnp.asarray(_phi(rng, k, 16))
    hi, lo = dd_matmul_np(np.asarray(a), np.asarray(b))
    for tgt in (1e-4, 1e-8):
        cfg = OzakiConfig(num_splits=9, target_error=tgt, fast_mode=True)
        res = resolve_accuracy_config(cfg, k)
        assert res.num_splits <= 9
        assert res.num_gemms < OzakiConfig(num_splits=9).num_gemms
        c = np.asarray(ozaki_matmul(a, b, cfg))
        floor = accum_floor(res.num_splits, k,
                            pair_policy=res.pair_policy)
        serr = scaled_error(c, hi, np.asarray(a), np.asarray(b), ref_lo=lo)
        assert serr <= tgt + floor, (tgt, serr)
    # no knobs -> the driver keeps the config untouched
    base = OzakiConfig(num_splits=9)
    assert resolve_accuracy_config(base, k) is base


# ----------------------------------------------------------------------------
# Per-input refinement: spreads reduce the required split count
# ----------------------------------------------------------------------------

def test_exponent_spread_basics():
    m = jnp.asarray([[8.0, 1.0, 0.0], [0.0, 0.0, 0.0], [2.0, 2.0, 2.0]])
    spread = np.asarray(exponent_spread(m))
    assert spread[1] == 0                       # all-zero row: finite clamp
    assert spread[2] == 0                       # constant row: no spread
    assert spread[0] == 3                       # 8 vs 1: 3 octaves
    assert np.all(np.isfinite(spread))


def test_required_splits_narrow_spread_needs_fewer():
    rng = np.random.default_rng(0)
    # f32-precision values, zero spread: the informative slice count is
    # small, so exactness (target None) needs far fewer splits than the
    # wide-spread worst case
    narrow = np.sign(rng.standard_normal((32, 64)))
    wide = _phi(rng, 32, 64, 4.0)
    wide_b = _phi(rng, 64, 32, 4.0)
    s_narrow = required_splits(jnp.asarray(narrow),
                               jnp.asarray(narrow.T.copy()),
                               mantissa_bits=24)
    s_wide = required_splits(jnp.asarray(wide), jnp.asarray(wide_b),
                             mantissa_bits=24)
    assert s_narrow < s_wide
    # and the promised accuracy is real: at the chosen s the result is
    # exact up to the accumulation floor
    cfg = OzakiConfig(num_splits=s_narrow)
    a, b = jnp.asarray(narrow), jnp.asarray(narrow.T.copy())
    c = np.asarray(ozaki_matmul(a, b, cfg))
    ref = np.asarray(dgemm_f64(a, b))
    assert np.max(np.abs(c - ref)) <= 1e-10


def test_required_splits_monotone_in_target():
    rng = np.random.default_rng(1)
    a = jnp.asarray(_phi(rng, 16, 48))
    b = jnp.asarray(_phi(rng, 48, 16))
    s_loose = required_splits(a, b, target_error=1e-4)
    s_tight = required_splits(a, b, target_error=1e-12)
    assert s_loose <= s_tight <= MAX_SPLITS


def test_input_truncation_eta_never_exceeds_worst_case():
    for s in (3, 5, 9):
        w = 7
        full_grid = truncation_eta(s, w)
        assert input_truncation_eta(s, w, 4, 4) <= full_grid + 1e-30
        # huge effective slice counts recover (almost) the full bound
        assert input_truncation_eta(s, w, 60, 60) == \
            pytest.approx(full_grid, rel=1e-6)


# ----------------------------------------------------------------------------
# Zero-cancellation regression (satellite): zero rows/cols in BOTH operands
# ----------------------------------------------------------------------------

_ZC_EXECUTORS = {
    "xla": dict(backend="xla"),
    "pallas_fused": dict(backend="pallas_fused"),
    "pallas_fused_epilogue": dict(backend="pallas_fused",
                                  fuse_epilogue=True),
}


@pytest.mark.parametrize("executor", sorted(_ZC_EXECUTORS))
def test_zero_rows_cols_no_nan_and_exact_zeros(rng, executor):
    a = _phi(rng, 12, 32)
    b = _phi(rng, 32, 10)
    a[3, :] = 0.0
    a[:, 7] = 0.0
    b[:, 2] = 0.0
    b[11, :] = 0.0
    aj, bj = jnp.asarray(a), jnp.asarray(b)
    for cfg in (OzakiConfig(num_splits=9, **_ZC_EXECUTORS[executor]),
                OzakiConfig(num_splits=9, target_error=1e-8,
                            fast_mode=True, **_ZC_EXECUTORS[executor])):
        c = np.asarray(ozaki_matmul(aj, bj, cfg))
        assert np.all(np.isfinite(c))
        np.testing.assert_array_equal(c[3, :], 0.0)   # zero row -> zero row
        np.testing.assert_array_equal(c[:, 2], 0.0)   # zero col -> zero col
        ref = np.asarray(dgemm_f64(aj, bj))
        assert np.max(np.abs(c - ref)) <= 1e-4 * np.abs(ref).max()


def test_zero_rows_batched_grid(rng):
    """The batch-grid executors under zero rows + fast mode: finite,
    bitwise-equal to xla (fig7-style zero-cancellation regression)."""
    a = np.stack([_phi(rng, 8, 24) for _ in range(2)])
    b = np.stack([_phi(rng, 24, 6) for _ in range(2)])
    a[0, 2, :] = 0.0
    b[1][:, 3] = 0.0
    cfg = OzakiConfig(num_splits=7, backend="pallas_fused",
                      fuse_epilogue=True, fast_mode=True)
    got = np.asarray(ozaki_matmul_batched(jnp.asarray(a), jnp.asarray(b),
                                          cfg))
    base = np.asarray(ozaki_matmul_batched(
        jnp.asarray(a), jnp.asarray(b),
        OzakiConfig(num_splits=7, backend="xla", fast_mode=True)))
    assert np.all(np.isfinite(got))
    np.testing.assert_array_equal(got, base)
    np.testing.assert_array_equal(got[0, 2, :], 0.0)


def test_zero_cancellation_inverse_with_zero_padding(rng):
    """A @ A^{-1} (paper Fig. 7) embedded in a zero-padded frame — the
    serving-batch shape where padded rows/cols are exactly zero."""
    n = 24
    a_core = rng.standard_normal((n, n))
    ainv = np.linalg.inv(a_core)
    a = np.zeros((n + 4, n + 4))
    b = np.zeros((n + 4, n + 4))
    a[:n, :n] = a_core
    b[:n, :n] = ainv
    cfg = OzakiConfig(num_splits=13, target_error=1e-12)
    c = np.asarray(ozaki_matmul(jnp.asarray(a), jnp.asarray(b), cfg))
    assert np.all(np.isfinite(c))
    np.testing.assert_array_equal(c[n:, :], 0.0)
    np.testing.assert_array_equal(c[:, n:], 0.0)
    # the off-diagonal cancellation stays at the Ozaki quality level
    assert np.max(np.abs(c[:n, :n] - np.eye(n))) <= 1e-10


def test_exponent_spread_all_zero_operands():
    z = jnp.zeros((4, 8))
    assert np.all(np.asarray(exponent_spread(z)) == 0)
    # the spread statistic is finite (no -inf min over an empty set), so
    # selection behaves like a zero-spread input instead of diverging
    assert 1 <= required_splits(z, jnp.zeros((8, 4)),
                                target_error=1e-10) <= MAX_SPLITS
    c = np.asarray(ozaki_matmul(jnp.zeros((4, 8)), jnp.zeros((8, 4)),
                                OzakiConfig(fast_mode=True)))
    np.testing.assert_array_equal(c, 0.0)


# ----------------------------------------------------------------------------
# Model/serving opt-in
# ----------------------------------------------------------------------------

def test_policy_matmul_fast_mode_opt_in(rng):
    import dataclasses as dc

    from repro.configs import get_config
    from repro.models.layers import policy_matmul

    base_cfg = dc.replace(get_config("llama3.2-3b").reduced(),
                          matmul_precision="ozaki_fp64",
                          ozaki_backend="pallas_fused", ozaki_splits=7)
    fast_cfg = dc.replace(base_cfg, ozaki_target_error=1e-6,
                          ozaki_fast_mode=True)
    x = jnp.asarray(rng.standard_normal((4, 64)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    base = np.asarray(policy_matmul(base_cfg, x, w))
    fast = np.asarray(policy_matmul(fast_cfg, x, w))
    assert np.all(np.isfinite(fast))
    # fast mode trades pair products for speed within the target
    np.testing.assert_allclose(fast, base, rtol=1e-4, atol=1e-5)


def test_engine_prewarm_carries_fast_mode_policy(tmp_path):
    import dataclasses as dc

    import jax

    from repro.configs import get_config
    from repro.models import init_model
    from repro.serving.engine import ServingEngine

    cfg = dc.replace(get_config("llama3.2-3b").reduced(),
                     matmul_precision="ozaki_fp64",
                     ozaki_backend="pallas_fused", ozaki_splits=5)
    params, _ = init_model(cfg, jax.random.key(0))
    eng = ServingEngine(cfg, params, num_slots=2, max_len=32,
                        plan_cache=str(tmp_path / "plans.json"),
                        ozaki_fast_mode=True)
    assert eng.cfg.ozaki_fast_mode
    assert len(eng.plan_cache) >= 4
    policies = {plan.pair_policy
                for key in eng.plan_cache.keys()
                for plan in [eng.plan_cache.get(key)]}
    assert policies == {"diagonal"}            # fast mode, no target


# ----------------------------------------------------------------------------
# Plan/schedule plumbing
# ----------------------------------------------------------------------------

def test_parse_pair_policy_vocabulary():
    assert parse_pair_policy("full", 9) is None
    assert parse_pair_policy("diagonal", 9) == 36      # 45 - last 9
    assert parse_pair_policy("diagonal", 1) == 1       # floor at 1 pair
    assert parse_pair_policy("budget:7", 9) == 7
    assert parse_pair_policy("budget:999", 9) == 45    # clamped to total
    for bad in ("bogus", "budget:0", "budget:-3", "budget:x"):
        with pytest.raises(ValueError):
            parse_pair_policy(bad, 9)


def test_num_gemms_reflects_policy():
    full = OzakiConfig(num_splits=9)
    assert full.num_gemms == 45
    assert dataclasses.replace(full, pair_policy="diagonal").num_gemms == 36
    assert dataclasses.replace(full, pair_policy="budget:7").num_gemms == 7
