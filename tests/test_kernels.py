"""Pallas kernel sweeps vs the pure-jnp oracles (interpret=True on CPU).

Integer paths assert exact equality; the df32 accumulation path is exact
too (identical compensated-arithmetic sequence).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.splitting import row_exponents, split_int, split_int_dw
from repro.core.xmath import DW, df32_from_f64
from repro.kernels import ref
from repro.kernels.int8_gemm import (int8_matmul_nt, int8_matmul_nt_batched,
                                     int8_matmul_nt_epilogue_dw,
                                     int8_matmul_nt_epilogue_sw)
from repro.kernels.ozaki_accum import accum_scaled_dw, accum_scaled_sw
from repro.kernels.ozaki_split import fused_split_dw


@pytest.mark.parametrize("m,n,k", [
    (8, 8, 8), (16, 24, 32), (128, 64, 256), (200, 120, 530),
    (256, 256, 512), (33, 7, 129)])
def test_int8_gemm_sweep(rng, m, n, k):
    a = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
    bt = jnp.asarray(rng.integers(-128, 128, (n, k)), jnp.int8)
    got = np.asarray(int8_matmul_nt(a, bt, interpret=True))
    want = np.asarray(ref.int8_matmul_nt_ref(a, bt))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("bm,bn,bk", [(32, 32, 64), (256, 256, 512)])
def test_int8_gemm_block_shapes(rng, bm, bn, bk):
    a = jnp.asarray(rng.integers(-128, 128, (100, 300)), jnp.int8)
    bt = jnp.asarray(rng.integers(-128, 128, (70, 300)), jnp.int8)
    got = np.asarray(int8_matmul_nt(a, bt, bm=bm, bn=bn, bk=bk,
                                    interpret=True))
    np.testing.assert_array_equal(got,
                                  np.asarray(ref.int8_matmul_nt_ref(a, bt)))


@pytest.mark.parametrize("m,k,s,w", [
    (8, 128, 9, 7), (64, 256, 13, 7), (100, 130, 5, 6), (16, 512, 3, 7)])
def test_fused_split_sweep(rng, m, k, s, w):
    x = jnp.asarray(rng.uniform(-0.5, 0.5, (m, k))
                    * np.exp(rng.standard_normal((m, k))))
    dw = df32_from_f64(x)
    exp = row_exponents(dw.hi)
    got = np.asarray(fused_split_dw(dw.hi, dw.lo, exp, num_splits=s, w=w,
                                    interpret=True))
    want = np.asarray(ref.fused_split_dw_ref(dw.hi, dw.lo, exp,
                                             num_splits=s, w=w))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("m,n,scale_pow", [(16, 128, -14), (100, 200, -28),
                                           (256, 256, -42)])
def test_accum_scaled_sweep(rng, m, n, scale_pow):
    p = jnp.asarray(rng.integers(-2 ** 30, 2 ** 30, (m, n)), jnp.int32)
    c_hi = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    c_lo = jnp.asarray(rng.standard_normal((m, n)) * 1e-8, jnp.float32)
    scale = float(2.0 ** scale_pow)
    gh, gl = accum_scaled_dw(p, c_hi, c_lo, scale=scale, interpret=True)
    wh, wl = ref.accum_scaled_dw_ref(p, c_hi, c_lo, scale=scale)
    np.testing.assert_array_equal(np.asarray(gh), np.asarray(wh))
    np.testing.assert_array_equal(np.asarray(gl), np.asarray(wl))


@pytest.mark.parametrize("b,m,n,k", [
    (1, 8, 8, 8), (3, 16, 24, 32), (2, 100, 60, 130)])
def test_int8_gemm_batched_sweep(rng, b, m, n, k):
    a = jnp.asarray(rng.integers(-128, 128, (b, m, k)), jnp.int8)
    bt = jnp.asarray(rng.integers(-128, 128, (b, n, k)), jnp.int8)
    got = np.asarray(int8_matmul_nt_batched(a, bt, interpret=True))
    want = np.asarray(ref.int8_matmul_nt_batched_ref(a, bt))
    np.testing.assert_array_equal(got, want)


def test_int8_gemm_batched_matches_unbatched(rng):
    a = jnp.asarray(rng.integers(-128, 128, (4, 32, 64)), jnp.int8)
    bt = jnp.asarray(rng.integers(-128, 128, (4, 16, 64)), jnp.int8)
    got = np.asarray(int8_matmul_nt_batched(a, bt, interpret=True))
    for i in range(4):
        np.testing.assert_array_equal(
            got[i], np.asarray(int8_matmul_nt(a[i], bt[i], interpret=True)))


@pytest.mark.parametrize("m,n,scale_pow", [(16, 128, -14), (100, 200, -28)])
def test_accum_scaled_sw_sweep(rng, m, n, scale_pow):
    p = jnp.asarray(rng.integers(-2 ** 30, 2 ** 30, (m, n)), jnp.int32)
    c = jnp.asarray(rng.standard_normal((m, n)), jnp.float64)
    scale = float(2.0 ** scale_pow)
    got = accum_scaled_sw(p, c, scale=scale, interpret=True)
    want = ref.accum_scaled_sw_ref(p, c, scale=scale)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("m,k,s,w", [(8, 128, 9, 7), (33, 130, 13, 6)])
def test_fused_split_f64_zero_lo_equals_split_int(rng, m, k, s, w):
    """(f64, 0) through the dw kernel == Algorithm 4 on the f64 matrix."""
    x = jnp.asarray(rng.uniform(-0.5, 0.5, (m, k))
                    * np.exp(rng.standard_normal((m, k))))
    want = split_int(x, s, w)
    got = fused_split_dw(x, jnp.zeros_like(x), want.exp, num_splits=s, w=w,
                         interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want.slices))


@pytest.mark.parametrize("m,n,k,p_lo,t,npairs", [
    (8, 8, 8, 0, 0, 1), (16, 24, 32, 1, 3, 2), (33, 7, 129, 0, 2, 3),
    (100, 60, 130, 2, 4, 2)])
def test_int8_gemm_epilogue_sw_sweep(rng, m, n, k, p_lo, t, npairs):
    """Epilogue-fused GEMM (single-word C) == GEMM kernel + scaled add."""
    s = 5
    a_sl = jnp.asarray(rng.integers(-100, 101, (s, m, k)), jnp.int8)
    b_sl = jnp.asarray(rng.integers(-100, 101, (s, n, k)), jnp.int8)
    c = jnp.asarray(rng.standard_normal((m, n)))
    scale = 2.0 ** -21
    got = int8_matmul_nt_epilogue_sw(a_sl, b_sl, c, p_lo=p_lo, t=t,
                                     npairs=npairs, scale=scale,
                                     interpret=True)
    p_t = sum(np.asarray(int8_matmul_nt(a_sl[p_lo + i],
                                        b_sl[t - p_lo - i], interpret=True))
              for i in range(npairs))
    want = np.asarray(c) + p_t.astype(np.float64) * scale
    np.testing.assert_array_equal(np.asarray(got), want)


@pytest.mark.parametrize("m,n,k,npairs", [(16, 24, 32, 2), (33, 7, 129, 3)])
def test_int8_gemm_epilogue_dw_matches_accum_kernel(rng, m, n, k, npairs):
    """Epilogue df32 add == ``accum_scaled_dw`` on the summed product."""
    s, p_lo, t = 4, 0, npairs - 1
    a_sl = jnp.asarray(rng.integers(-100, 101, (s, m, k)), jnp.int8)
    b_sl = jnp.asarray(rng.integers(-100, 101, (s, n, k)), jnp.int8)
    c_hi = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    c_lo = jnp.asarray(rng.standard_normal((m, n)) * 1e-8, jnp.float32)
    scale = 2.0 ** -28
    gh, gl = int8_matmul_nt_epilogue_dw(a_sl, b_sl, c_hi, c_lo, p_lo=p_lo,
                                        t=t, npairs=npairs, scale=scale,
                                        interpret=True)
    p_t = sum(np.asarray(int8_matmul_nt(a_sl[p_lo + i],
                                        b_sl[t - p_lo - i], interpret=True),
                         np.int64)
              for i in range(npairs)).astype(np.int32)
    wh, wl = accum_scaled_dw(jnp.asarray(p_t), c_hi, c_lo, scale=scale,
                             interpret=True)
    np.testing.assert_array_equal(np.asarray(gh), np.asarray(wh))
    np.testing.assert_array_equal(np.asarray(gl), np.asarray(wl))


def test_int8_gemm_epilogue_block_shapes(rng):
    """Explicit (small) blocks cover the multi-block epilogue grid walk."""
    s, m, n, k = 3, 70, 40, 200
    a_sl = jnp.asarray(rng.integers(-100, 101, (s, m, k)), jnp.int8)
    b_sl = jnp.asarray(rng.integers(-100, 101, (s, n, k)), jnp.int8)
    c = jnp.asarray(rng.standard_normal((m, n)))
    scale = 2.0 ** -14
    got = int8_matmul_nt_epilogue_sw(a_sl, b_sl, c, p_lo=0, t=2, npairs=3,
                                     scale=scale, bm=32, bn=128, bk=128,
                                     interpret=True)
    p_t = sum(np.asarray(int8_matmul_nt(a_sl[i], b_sl[2 - i],
                                        interpret=True))
              for i in range(3))
    want = np.asarray(c) + p_t.astype(np.float64) * scale
    np.testing.assert_array_equal(np.asarray(got), want)


def test_int8_gemm_jit_composes(rng):
    """Kernels must be callable under an outer jit (pjit path)."""
    a = jnp.asarray(rng.integers(-128, 128, (64, 128)), jnp.int8)
    bt = jnp.asarray(rng.integers(-128, 128, (32, 128)), jnp.int8)

    @jax.jit
    def f(a, bt):
        return int8_matmul_nt(a, bt, interpret=True) + 1

    got = np.asarray(f(a, bt))
    np.testing.assert_array_equal(
        got, np.asarray(ref.int8_matmul_nt_ref(a, bt)) + 1)
