"""Per-arch smoke tests (reduced configs) + decode/train consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.models import (decode_step, forward_train, init_decode_state,
                          init_model, prefill)

KEY = jax.random.key(0)


def _batch(cfg, rng, b, s):
    if cfg.frontend == "audio":
        return {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s, cfg.num_codebooks)),
            jnp.int32)}
    if cfg.frontend == "vision":
        return {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s - cfg.num_patches)),
            jnp.int32),
            "patch_embeds": jnp.asarray(
                rng.standard_normal((b, cfg.num_patches, cfg.d_model)),
                jnp.float32)}
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                                  jnp.int32)}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_forward_smoke(arch, rng):
    cfg = get_config(arch).reduced()
    params, axes = init_model(cfg, KEY)
    assert jax.tree.structure(params) == jax.tree.structure(
        axes, is_leaf=lambda a: isinstance(a, tuple))
    b, s = 2, 32
    logits, aux = forward_train(cfg, params, _batch(cfg, rng, b, s))
    want = (b, s, cfg.num_codebooks, cfg.vocab_size) \
        if cfg.frontend == "audio" else (b, s, cfg.vocab_size)
    assert logits.shape == want
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_matches_teacher_forcing(arch, rng):
    cfg = get_config(arch).reduced()
    if cfg.moe is not None:    # dropless capacity: drop-pattern parity
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=8.0))
    params, _ = init_model(cfg, KEY)
    b, s, p = 2, 24, 16
    batch = _batch(cfg, rng, b, s)
    toks = batch["tokens"]
    full, _ = forward_train(cfg, params, batch)
    if cfg.frontend == "vision":
        full = full[:, -toks.shape[1]:]

    pre = dict(batch)
    pre["tokens"] = toks[:, :p]
    state = init_decode_state(cfg, b, s + cfg.num_patches
                              if cfg.frontend == "vision" else s,
                              dtype=jnp.float32)
    state, last = prefill(cfg, params, pre, state)
    errs = [float(jnp.max(jnp.abs(last - full[:, p - 1])))]
    for t in range(p, min(s, p + 4)):
        tok = toks[:, t:t + 1]
        dl, state = decode_step(cfg, params, state, tok)
        errs.append(float(jnp.max(jnp.abs(dl - full[:, t]))))
    assert max(errs) < 2e-4, errs


def test_gemma2_sliding_window_matters(rng):
    """A local layer must ignore keys beyond the window."""
    cfg = get_config("gemma2-9b").reduced()
    assert cfg.sliding_window
    params, _ = init_model(cfg, KEY)
    b, s = 1, 40
    t1 = rng.integers(0, cfg.vocab_size, (b, s)).astype(np.int32)
    t2 = t1.copy()
    t2[0, 0] = (t2[0, 0] + 1) % cfg.vocab_size   # perturb far-away token
    l1, _ = forward_train(cfg, params, {"tokens": jnp.asarray(t1)})
    l2, _ = forward_train(cfg, params, {"tokens": jnp.asarray(t2)})
    # both models see token 0 through GLOBAL layers -> logits differ at
    # the end; but a pure-local model would not. Here we just assert the
    # window machinery runs and the last position still changed (global
    # layers exist) while an early in-window position changed too.
    assert float(jnp.max(jnp.abs(l1[:, -1] - l2[:, -1]))) >= 0.0
    assert float(jnp.max(jnp.abs(l1[:, 1] - l2[:, 1]))) > 0.0


def test_ozaki_precision_policy_runs(rng):
    """The paper's policy as a drop-in matmul mode of the LM stack."""
    cfg = dataclasses.replace(get_config("llama3.2-3b").reduced(),
                              matmul_precision="ozaki_fp64",
                              ozaki_splits=7)
    params, _ = init_model(cfg, KEY)
    batch = _batch(cfg, rng, 1, 16)
    logits, _ = forward_train(cfg, params, batch)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # ozaki_fp64 must agree with the f32-compute reference closely
    cfg32 = dataclasses.replace(cfg, matmul_precision="bf16",
                                compute_dtype="float32")
    ref, _ = forward_train(cfg32, params, batch)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_int8_quant_policy_runs(rng):
    cfg = dataclasses.replace(get_config("llama3.2-3b").reduced(),
                              matmul_precision="int8_quant")
    params, _ = init_model(cfg, KEY)
    logits, _ = forward_train(cfg, params, _batch(cfg, rng, 1, 16))
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_fold_causal_attention_equivalent(rng):
    from repro.models.attention import chunked_attention
    b, s, h, d = 2, 64, 4, 16
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, 2, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, 2, d)), jnp.float32)
    base = chunked_attention(q, k, v, q_block=16, kv_block=16)
    fold = chunked_attention(q, k, v, q_block=16, kv_block=16,
                             fold_causal=True)
    np.testing.assert_allclose(np.asarray(fold), np.asarray(base),
                               rtol=1e-5, atol=1e-6)


def test_attention_window_and_softcap(rng):
    from repro.models.attention import chunked_attention
    b, s, h, d = 1, 32, 2, 8
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    # window=8: output at position t must not depend on keys < t-7
    w = chunked_attention(q, k, v, window=8, q_block=8, kv_block=8)
    k2 = k.at[:, 0].set(100.0)
    v2 = v.at[:, 0].set(-100.0)
    w2 = chunked_attention(q, k2, v2, window=8, q_block=8, kv_block=8)
    np.testing.assert_allclose(np.asarray(w[:, 16:]),
                               np.asarray(w2[:, 16:]), atol=1e-6)
    sc = chunked_attention(q, k, v, softcap=5.0, q_block=8, kv_block=8)
    assert bool(jnp.all(jnp.isfinite(sc)))
