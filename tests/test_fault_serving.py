"""Fault-tolerance runtime + serving engine behaviour."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_model
from repro.runtime.fault import (Heartbeat, SimulatedFailure, StepWatchdog,
                                 is_alive, restart_loop)
from repro.serving.engine import (Request, ServingEngine,
                                  generate_sequential)

KEY = jax.random.key(0)


# --------------------------------------------------------------------------
# runtime
# --------------------------------------------------------------------------

def test_heartbeat_lifecycle(tmp_path):
    path = str(tmp_path / "hb.json")
    hb = Heartbeat(path, interval_s=0.01).start()
    time.sleep(0.08)
    hb.step = 42
    time.sleep(0.05)
    assert is_alive(path, timeout_s=1.0)
    hb.stop()
    time.sleep(0.12)
    assert not is_alive(path, timeout_s=0.05)


def test_watchdog_flags_straggler():
    wd = StepWatchdog(factor=3.0, warmup=3)
    for _ in range(5):
        assert wd.observe(0.10) is None
    ev = wd.observe(0.50)
    assert ev is not None and ev.duration_s >= 0.5
    assert wd.observe(0.11) is None
    assert len(wd.events) == 1


def test_restart_loop_recovers():
    calls = []

    def run(resume):
        calls.append(resume)
        if len(calls) < 3:
            raise SimulatedFailure("boom")
        return 99

    assert restart_loop(run, max_restarts=5) == 99
    assert calls == [None, -1, -1]


def test_restart_loop_exhausts():
    def run(resume):
        raise SimulatedFailure("always")

    with pytest.raises(SimulatedFailure):
        restart_loop(run, max_restarts=1)


def test_train_restart_bitwise_identical(tmp_path):
    """Kill at step 3, restart, finish == uninterrupted run (bitwise)."""
    import argparse
    from repro.launch.train import train

    def args(ckpt, fail_at):
        return argparse.Namespace(
            arch="llama3.2-3b", full=False, precision=None, steps=6,
            batch=4, seq=32, grad_accum=1, model_parallel=1, lr=5e-3,
            warmup=2, seed=0, data_seed=1234, ckpt_dir=ckpt,
            ckpt_every=2, log_every=100, max_restarts=2,
            simulate_failure_at=fail_at)

    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    os.makedirs(d1), os.makedirs(d2)
    train(args(d1, -1))          # uninterrupted
    train(args(d2, 3))           # crash at step 3, auto-restart
    from repro.checkpoint import ckpt as ckpt_lib
    m1 = ckpt_lib.load_manifest(d1, 6)
    m2 = ckpt_lib.load_manifest(d2, 6)
    assert m1["keys"] == m2["keys"]
    for k in m1["keys"]:
        a = np.load(os.path.join(d1, "step_0000006", "arrays", k + ".npy"))
        b = np.load(os.path.join(d2, "step_0000006", "arrays", k + ".npy"))
        np.testing.assert_array_equal(a, b, err_msg=k)


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def llama_setup():
    cfg = get_config("llama3.2-3b").reduced()
    params, _ = init_model(cfg, KEY)
    return cfg, params


def test_engine_matches_sequential(llama_setup, rng):
    cfg, params = llama_setup
    engine = ServingEngine(cfg, params, num_slots=3, max_len=64)
    reqs = []
    for rid in range(5):
        plen = int(rng.integers(3, 10))
        prompt = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
        req = Request(rid, prompt, max_new_tokens=int(rng.integers(4, 9)))
        reqs.append(req)
        engine.submit(req)
    finished = engine.run_until_done()
    assert len(finished) == 5
    for req in reqs:
        ref = generate_sequential(cfg, params, req.prompt,
                                  req.max_new_tokens, max_len=64)
        assert req.generated == ref, req.rid


def test_engine_mid_flight_admission(llama_setup, rng):
    """A request submitted while others decode must join and match."""
    cfg, params = llama_setup
    engine = ServingEngine(cfg, params, num_slots=2, max_len=64)
    first = Request(0, rng.integers(0, cfg.vocab_size, 5).astype(np.int32),
                    max_new_tokens=8)
    engine.submit(first)
    for _ in range(3):
        engine.step()
    late = Request(1, rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
                   max_new_tokens=6)
    engine.submit(late)
    engine.run_until_done()
    for req in (first, late):
        ref = generate_sequential(cfg, params, req.prompt,
                                  req.max_new_tokens, max_len=64)
        assert req.generated == ref, req.rid


def test_engine_eos_stops(llama_setup, rng):
    cfg, params = llama_setup
    prompt = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    ref = generate_sequential(cfg, params, prompt, 16, max_len=64)
    eos = ref[2]
    engine = ServingEngine(cfg, params, num_slots=1, max_len=64)
    req = Request(0, prompt, max_new_tokens=16, eos_id=int(eos))
    engine.submit(req)
    engine.run_until_done()
    assert req.generated[-1] == eos
    assert len(req.generated) <= 16
    assert req.generated == ref[:len(req.generated)]


def test_engine_more_requests_than_slots(llama_setup, rng):
    cfg, params = llama_setup
    engine = ServingEngine(cfg, params, num_slots=2, max_len=64)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
                    max_new_tokens=4) for i in range(6)]
    for r in reqs:
        engine.submit(r)
    finished = engine.run_until_done()
    assert sorted(r.rid for r in finished) == list(range(6))
