"""SplitInt invariants (Algorithm 4) — hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis "
                           "(pip install -r requirements-test.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.splitting import (compute_alpha, reconstruct,
                                  row_exponents, slice_width, split_int,
                                  split_int_dw, split_tail)
from repro.core.xmath import DW, df32_from_f64


def _rand(rng, m, k, phi=1.0):
    return jnp.asarray(rng.uniform(-0.5, 0.5, (m, k))
                       * np.exp(phi * rng.standard_normal((m, k))))


@given(st.integers(1, 2 ** 22))
@settings(max_examples=200, deadline=None)
def test_alpha_never_overflows_int32(k):
    """k * 4^alpha <= 2^31 - 1: the exactness precondition of the scheme."""
    a = compute_alpha(k)
    assert a >= 0
    assert k * 4 ** a <= 2 ** 31 - 1
    if a > 0:
        assert k * 4 ** (a + 1) > 2 ** 31 - 1   # maximal


@given(st.integers(1, 2 ** 20), st.integers(1, 16))
@settings(max_examples=100, deadline=None)
def test_alpha_fuse_headroom(k, fuse):
    a = compute_alpha(k, fuse_terms=fuse)
    assert k * fuse * 4 ** a <= 2 ** 31 - 1


@pytest.mark.parametrize("phi", [0.1, 1.0, 4.0])
@pytest.mark.parametrize("s,w", [(9, 7), (13, 7), (4, 3)])
def test_split_int_invariants(rng, phi, s, w):
    m = _rand(rng, 5, 64, phi)
    res = split_int(m, s, w)
    sl = np.asarray(res.slices)
    # int8 bounds (magnitude < 2^w)
    assert sl.min() >= -(2 ** w) and sl.max() <= 2 ** w - 1
    # sign agreement: slice sign matches element sign (or zero)
    signs = np.sign(np.asarray(m))
    for p in range(s):
        nz = sl[p] != 0
        assert np.all(np.sign(sl[p])[nz] == signs[nz])
    # error-free truncation: |tail| < 2^(exp - s*w) per row
    tail = np.abs(np.asarray(split_tail(m, res)))
    bound = 2.0 ** (np.asarray(res.exp, np.float64) - s * w)
    assert np.all(tail <= bound[:, None])


def test_split_reconstruct_exact_when_enough_bits(rng):
    """Values with <= s*w mantissa bits below the row exponent are
    captured exactly."""
    exp = np.array([0, 3, -5], np.float64)
    quant = 2.0 ** (exp - 60)                     # 60 bits < 9*7
    m = jnp.asarray(np.round(rng.uniform(-0.4, 0.4, (3, 32))
                             * 2.0 ** exp[:, None] / quant[:, None])
                    * quant[:, None])
    res = split_int(m, 9, 7)
    back = reconstruct(res)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(m))


def test_row_exponents_strict(rng):
    m = _rand(rng, 7, 33)
    e = np.asarray(row_exponents(m), np.float64)
    amax = np.max(np.abs(np.asarray(m)), axis=1)
    assert np.all(2.0 ** e >= amax)
    assert np.all(2.0 ** (e - 1) < amax)


def test_split_int_dw_matches_f64_on_48bit_values(rng):
    x = np.asarray(_rand(rng, 4, 40))
    # truncate to 40 mantissa bits so df32 holds the value exactly
    mant, ex = np.frexp(x)
    x = np.ldexp(np.round(mant * 2 ** 40), ex - 40)
    xj = jnp.asarray(x)
    r64 = split_int(xj, 9, 7)
    rdw = split_int_dw(df32_from_f64(xj), 9, 7)
    np.testing.assert_array_equal(np.asarray(r64.exp), np.asarray(rdw.exp))
    np.testing.assert_array_equal(np.asarray(r64.slices),
                                  np.asarray(rdw.slices))


def test_precomputed_exponents_path(rng):
    """Distributed path: splitting k-chunks against the GLOBAL exponents
    must reproduce the slices of splitting the full matrix."""
    m = _rand(rng, 6, 64)
    full = split_int(m, 9, 7)
    left = split_int(m[:, :32], 9, 7, exp=full.exp)
    right = split_int(m[:, 32:], 9, 7, exp=full.exp)
    np.testing.assert_array_equal(
        np.asarray(full.slices),
        np.concatenate([np.asarray(left.slices),
                        np.asarray(right.slices)], axis=2))


def test_zero_rows(rng):
    m = jnp.zeros((3, 16), jnp.float64)
    res = split_int(m, 5, 7)
    assert np.all(np.asarray(res.slices) == 0)
    assert np.all(np.asarray(res.exp) == 0)


def test_slice_width_caps_at_ell_in():
    assert slice_width(4096) == 7          # INT8: alpha > 7 -> capped
    assert slice_width(2 ** 18) <= 7       # alpha shrinks at huge k
    # FP16-FP32 at k=4096: Eq.(4) floor says 6, but 4096*4^6 = 2^24
    # exactly OVERFLOWS the 2^24-1 budget -> the exact check yields 5
    # (the corner documented in splitting.py)
    assert slice_width(4096, ell_acc=24, ell_in=11) == 5
