"""Quickstart: FP64-accurate GEMM out of int8 matmuls, in five lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.ozaki import OzakiConfig, ozaki_matmul  # noqa: E402


def main():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.uniform(-0.5, 0.5, (512, 512)))
    b = jnp.asarray(rng.uniform(-0.5, 0.5, (512, 512)))

    # The paper: split into int8 slices, exact int32 GEMMs, one
    # high-precision accumulation (INT8x9 = 9 splits).
    c = ozaki_matmul(a, b, OzakiConfig(num_splits=9))

    ref = a @ b                                  # plain FP64 GEMM
    err = float(jnp.max(jnp.abs(c - ref)) / jnp.max(jnp.abs(ref)))
    print(f"ozaki INT8x9 vs FP64 DGEMM: max rel diff = {err:.2e}")
    assert err < 1e-14

    # Variable precision: fewer splits = faster + coarser (Sec. 2.3.3)
    for s in (4, 6, 9):
        c = ozaki_matmul(a, b, OzakiConfig(num_splits=s))
        err = float(jnp.max(jnp.abs(c - ref)) / jnp.max(jnp.abs(ref)))
        print(f"  INT8x{s}: {s * (s + 1) // 2:3d} int8 GEMMs, "
              f"rel err {err:.2e}")


if __name__ == "__main__":
    main()
