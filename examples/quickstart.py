"""Quickstart: FP64-accurate GEMM out of int8 matmuls, via the one
front door — ``repro.matmul`` + a ``MatmulPolicy`` precision spec.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

import repro  # noqa: E402


def main():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.uniform(-0.5, 0.5, (512, 512)))
    b = jnp.asarray(rng.uniform(-0.5, 0.5, (512, 512)))

    # The paper as a drop-in DGEMM: ask for FP64 accuracy, the scheme
    # decides splits and kernels (default policy = ozaki-fp64, auto s).
    c = repro.matmul(a, b)

    ref = a @ b                                  # plain FP64 GEMM
    err = float(jnp.max(jnp.abs(c - ref)) / jnp.max(jnp.abs(ref)))
    print(f"repro.matmul (ozaki-fp64, auto) vs FP64 DGEMM: "
          f"max rel diff = {err:.2e}")
    assert err < 1e-14

    # Variable precision: the spec string IS the dial (Sec. 2.3.3) —
    # fewer splits = faster + coarser. "ozaki-fp64x9" pins INT8x9.
    for s in (4, 6, 9):
        c = repro.matmul(a, b, precision=f"ozaki-fp64x{s}")
        err = float(jnp.max(jnp.abs(c - ref)) / jnp.max(jnp.abs(ref)))
        print(f"  ozaki-fp64x{s}: {s * (s + 1) // 2:3d} int8 GEMMs, "
              f"rel err {err:.2e}")

    # The same spec scopes ambiently (mirrors jax.default_matmul_precision)
    with repro.default_matmul_precision("ozaki-fp64x9/pallas_fused"
                                        "+epilogue"):
        c_fused = repro.matmul(a, b)
    c_ref = repro.matmul(a, b, precision="ozaki-fp64x9")
    assert bool(jnp.all(c_fused == c_ref))       # backends are bitwise-equal
    print("fused-kernel backend bitwise == xla reference ✓")


if __name__ == "__main__":
    main()
