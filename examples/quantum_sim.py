"""Paper Sec. 4.4: brickwork random-unitary circuit simulation with the
Ozaki scheme and automatic split selection (INT8-AUTO).

    PYTHONPATH=src python examples/quantum_sim.py --qubits 10 --layers 3
"""
import argparse

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from benchmarks.bench_quantum_sim import simulate  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--qubits", type=int, default=10)
    ap.add_argument("--gate-qubits", type=int, default=4)
    ap.add_argument("--layers", type=int, default=3)
    args = ap.parse_args()

    ref, t_ref, _ = simulate(args.qubits, args.gate_qubits, args.layers,
                             "zgemm")
    print(f"ZGEMM (complex128 reference): {t_ref:.2f}s")
    for t in (0.0, 1.0):
        st, dt, splits = simulate(args.qubits, args.gate_qubits,
                                  args.layers, "ozaki", threshold=t)
        err = abs(st[0].real - ref[0].real) / abs(ref[0].real)
        print(f"INT8-AUTO(T={t:.0f}): {dt:.2f}s  "
              f"speedup={t_ref / dt:.2f}x  modes=INT8x{splits[0]}.."
              f"{max(splits)}  |amp err|={err:.2e}  "
              f"norm={np.linalg.norm(st):.12f}")


if __name__ == "__main__":
    main()
