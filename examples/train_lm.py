"""End-to-end driver: train a ~100M-param llama-family model for a few
hundred steps on the synthetic pipeline, with checkpointing + restart.

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import make_data
from repro.models import init_model
from repro.train.optimizer import OptimizerConfig, adamw_init
from repro.train.trainer import train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    args = ap.parse_args()

    # ~100M params: 8 layers x d512 x ff2048, 32k vocab
    cfg = dataclasses.replace(
        get_config("llama3.2-3b"),
        num_layers=args.layers, d_model=args.d_model, num_heads=8,
        num_kv_heads=4, head_dim=64, d_ff=4 * args.d_model,
        vocab_size=32_768, compute_dtype="float32", remat=False,
        name="llama-100m")
    print(f"model: {cfg.param_count() / 1e6:.1f}M params")

    params, _ = init_model(cfg, jax.random.key(0))
    oc = OptimizerConfig(peak_lr=3e-3, warmup_steps=20,
                         total_steps=args.steps)
    opt = adamw_init(params)
    data = make_data(cfg, args.seq, args.batch)

    step_fn = jax.jit(lambda p, o, b: train_step(cfg, oc, p, o, b),
                      donate_argnums=(0, 1))
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        params, opt, metrics = step_fn(params, opt, batch)
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {float(metrics['loss']):.4f}  "
                  f"lr {float(metrics['lr']):.2e}", flush=True)
    print("done.")


if __name__ == "__main__":
    main()
