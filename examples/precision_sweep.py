"""The Ozaki scheme as a *variable-precision dial* (paper Sec. 2.3.3):
sweep the split count through ``repro.matmul`` policy specs and chart
accuracy vs. #int8-GEMMs, including the intermediate-precision regime
between FP32 and FP64 the paper highlights.

    PYTHONPATH=src python examples/precision_sweep.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

import repro  # noqa: E402
from repro.core.ozaki import gemm_fp32_pass  # noqa: E402
from repro.core.xmath import dd_matmul_np, rel_error_vs_dd  # noqa: E402


def main():
    rng = np.random.default_rng(0)
    n, k = 128, 256
    a = jnp.asarray(rng.uniform(-0.5, 0.5, (n, k))
                    * np.exp(rng.standard_normal((n, k))))
    b = jnp.asarray(rng.uniform(-0.5, 0.5, (k, n))
                    * np.exp(rng.standard_normal((k, n))))
    hi, lo = dd_matmul_np(np.asarray(a), np.asarray(b))

    def err(c):
        return float(np.max(rel_error_vs_dd(np.asarray(c), hi, lo)))

    print(f"{'policy':>14s} {'#int8 GEMMs':>12s} {'max rel err':>12s}")
    e32 = err(gemm_fp32_pass(a, b))
    print(f"{'FP32':>14s} {'-':>12s} {e32:12.2e}")
    for s in range(2, 14):
        spec = f"ozaki-fp64x{s}"
        cfg = repro.MatmulPolicy.parse(spec).ozaki_config(k)
        e = err(repro.matmul(a, b, precision=spec))
        marker = ""
        if e < e32 and s <= 5:
            marker = "   <- between FP32 and FP64"
        if e < 1e-15:
            marker = "   <- FP64-equivalent"
        print(f"{spec:>14s} {cfg.num_gemms:12d} {e:12.2e}{marker}")

    # Scheme II: the same dial, but #GEMMs grows LINEARLY in the
    # mantissa budget (one int8 GEMM per residue modulus, xL pins L)
    print()
    for ell in (10, 15, 20):
        spec = f"ozaki2-fp64x{ell}"
        point = repro.MatmulPolicy.parse(spec).modular_config().point(k)
        e = err(repro.matmul(a, b, precision=spec))
        marker = "   <- FP64-equivalent" if e < 1e-15 else ""
        print(f"{spec:>14s} {len(point.moduli):12d} {e:12.2e}{marker}")

    # and the cross-scheme cost model arbitrating at matched accuracy
    from repro.core.accuracy import resolve_accuracy
    for kk, tgt in ((k, 1e-2), (4096, 1e-20)):
        choice = resolve_accuracy(kk, 10, target_error=tgt,
                                  schemes=("ozaki_fp64", "ozaki2_fp64"),
                                  m=n, n=n)
        costs = "  ".join(f"{s}:{c:.1f}" for s, c in choice.costs)
        print(f"resolve_accuracy(k={kk}, @{tgt:g}) -> {choice.scheme}"
              f"   (modeled GEMMs  {costs})")


if __name__ == "__main__":
    main()
