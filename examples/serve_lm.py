"""Continuous-batching serving demo (vLLM-style slots, static shapes).

    PYTHONPATH=src python examples/serve_lm.py --requests 6 --slots 3
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main()
