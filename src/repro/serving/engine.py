"""Serving: batched prefill/decode with a continuous-batching scheduler.

Slot-based continuous batching (vLLM-style, adapted to static JAX
shapes): the engine owns ONE batched ``DecodeState`` with ``num_slots``
rows, each with an independent cursor (``DecodeState.pos`` is a (b,)
vector). Admission prefillis a request on a batch-1 state and inserts
its caches into a free slot; every engine tick decodes ALL slots in one
jitted step (idle slots compute masked garbage — the static-shape tax).
Finished rows free their slot immediately, so new requests join mid-
flight without draining the batch.

Matmul precision: the engine takes ONE ``policy`` per deployment — a
``repro.api.MatmulPolicy`` (or spec string like
``"ozaki-fp64@1e-25:fast/pallas_fused+epilogue"``) that overrides the
model config's matmul policy wholesale (e.g. serve an FP64-accurate
variant of a checkpoint without a new config). The pre-PR-5 per-knob
kwargs (``matmul_precision`` / ``ozaki_backend`` / ... ) still work for
legacy callers but cannot be mixed with ``policy``. With
``matmul_precision="ozaki_fp64"`` every dense projection in the batched
decode step is a ``(num_slots, 1, k) @ (k, n)`` matmul against shared
weights — exactly ``ozaki_matmul_batched``'s broadcast-weights case, so
the whole batch shares one set of slice GEMMs per projection
(``models.layers._matmul_ozaki`` routes 3-D activations there).
``ozaki_fuse_epilogue`` selects the epilogue-fused GEMM+accumulate
kernels; ``ozaki_shard_axis`` (+ a ``mesh``) wires k-sharding for the
Ozaki projections — the engine scopes its mesh into
``parallel.ozaki_shard``'s registry around every tick, so traced model
steps pick it up without leaking it to other engines. (On the pinned
jax version the in-model constraints engage only for 2-D projections —
see ``models.layers._matmul_ozaki`` for the XLA SPMD caveat; the
sharded batched GEMM itself is served by
``parallel.ozaki_shard.ozaki_matmul_kshard_auto``.)

Plan cache pre-warm: with a ``plan_cache`` (or ``cfg.ozaki_plan_cache``
path) and ``matmul_precision="ozaki_fp64"``, the engine resolves a
``PipelinePlan`` for every dense decode projection shape AT STARTUP —
measured on the live backend when ``autotune_plans`` /
``cfg.ozaki_autotune`` is set, analytic otherwise — and persists the
cache. The cache is then scoped around every tick
(``core.autotune.use_plan_cache``) exactly like the mesh, so the first
traced decode step picks the tuned launch parameters up from the cache:
steady-state serving never tunes (or even re-plans) on the request
path.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_decode_state, prefill
from repro.models.transformer import DecodeState


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def ozaki_projection_shapes(cfg) -> list[tuple[int, int]]:
    """Distinct (k, n) weight shapes of the dense decode projections.

    These are the ``(slots, 1, k) @ (k, n)`` broadcast-weights matmuls a
    decode tick issues through ``models.layers.policy_matmul``: the
    attention q/k/v/o projections, the fused gate+up and the down MLP
    matmuls, and the unembedding. MoE expert matmuls run per-expert with
    the same (d, 2*ff_e)/(ff_e, d) pattern when configured; SSM inner
    projections are left to miss into the analytic plan (cheap).
    """
    d = cfg.d_model
    shapes = set()
    if cfg.num_heads:
        h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        shapes |= {(d, h * hd), (d, kv * hd), (h * hd, d)}
    if cfg.d_ff:
        shapes |= {(d, 2 * cfg.d_ff), (cfg.d_ff, d)}
    if getattr(cfg, "moe", None) is not None:
        ffe = cfg.moe.d_ff_expert
        shapes |= {(d, 2 * ffe), (ffe, d)}
    shapes.add((d, cfg.vocab_size))          # unembed (tied: embed.T)
    return sorted(shapes)


def _insert_row(batched, single, row: int):
    """Write a batch-1 state pytree into slot ``row`` of the batched one.

    Every DecodeState leaf has batch at dim 1 (stacked (L, b, ...)),
    except ``pos`` (dim 0).
    """
    def ins(full, one):
        if full.ndim == 1:                       # pos vector (b,)
            return full.at[row].set(one[0] if one.ndim else one)
        return jax.lax.dynamic_update_slice(
            full, one.astype(full.dtype),
            (0, row) + (0,) * (full.ndim - 2))

    return jax.tree.map(ins, batched, single)


class ServingEngine:
    def __init__(self, cfg, params, *, num_slots: int = 4,
                 max_len: int = 256, cache_dtype=jnp.float32,
                 sample_fn: Callable = greedy_sample,
                 policy=None,
                 matmul_precision: Optional[str] = None,
                 ozaki_backend: Optional[str] = None,
                 ozaki_fuse_epilogue: Optional[bool] = None,
                 ozaki_shard_axis: Optional[str] = None,
                 ozaki_target_error: Optional[float] = None,
                 ozaki_fast_mode: Optional[bool] = None,
                 mesh=None, plan_cache=None,
                 autotune_plans: Optional[bool] = None):
        # ONE policy object/spec replaces the six per-knob overrides: it
        # becomes the config's matmul_policy (authoritative — ArchConfig
        # back-fills matmul_precision and the legacy ozaki_* fields from
        # it, so every downstream reader agrees). The per-knob kwargs
        # stay for legacy callers but cannot be mixed with `policy`.
        overrides = {}
        if matmul_precision is not None:
            overrides["matmul_precision"] = matmul_precision
        if ozaki_backend is not None:
            overrides["ozaki_backend"] = ozaki_backend
        if ozaki_fuse_epilogue is not None:
            overrides["ozaki_fuse_epilogue"] = ozaki_fuse_epilogue
        if ozaki_shard_axis is not None:
            overrides["ozaki_shard_axis"] = ozaki_shard_axis
        if ozaki_target_error is not None:
            overrides["ozaki_target_error"] = ozaki_target_error
        if ozaki_fast_mode is not None:
            overrides["ozaki_fast_mode"] = ozaki_fast_mode
        if policy is not None:
            if overrides:
                raise ValueError(
                    "pass either policy=... or the legacy ozaki_*/"
                    f"matmul_precision kwargs, not both: {sorted(overrides)}")
            from repro.api import MatmulPolicy
            cfg = dataclasses.replace(
                cfg, matmul_policy=MatmulPolicy.of(policy).spec())
        elif overrides:
            # legacy kwargs merge INTO the config's resolved policy (one
            # spec stays authoritative), so spec-only knobs the legacy
            # fields can't express — pair_policy, auto split count — are
            # not silently discarded by a per-knob override.
            from repro.api import merge_legacy_overrides
            cfg = dataclasses.replace(
                cfg,
                matmul_policy=merge_legacy_overrides(cfg, overrides).spec())
        self.mesh = mesh
        self.cfg = cfg
        # plan cache: a PlanCache, a path, or the config's path; pre-warm
        # resolves every decode projection shape before serving starts.
        if plan_cache is None:
            plan_cache = getattr(cfg, "ozaki_plan_cache", "") or None
        if isinstance(plan_cache, (str, bytes)) or hasattr(plan_cache,
                                                           "__fspath__"):
            from repro.core.autotune import PlanCache
            plan_cache = PlanCache.load(plan_cache)
        self.plan_cache = plan_cache
        self.autotune_plans = (getattr(cfg, "ozaki_autotune", False)
                               if autotune_plans is None else autotune_plans)
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.sample_fn = sample_fn
        self.state = init_decode_state(cfg, num_slots, max_len,
                                       dtype=cache_dtype, per_row_pos=True)
        self.slot_req: list[Optional[Request]] = [None] * num_slots
        self.next_token = np.zeros((num_slots, 1), np.int32)
        self.waiting: list[Request] = []
        self.finished: list[Request] = []
        self.cache_dtype = cache_dtype
        self._decode = jax.jit(functools.partial(decode_step, cfg))
        self._steps = 0
        if (self.plan_cache is not None and
                cfg.matmul_precision == "ozaki_fp64"):
            self._prewarm_plans()            # before any request is served

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.waiting.append(req)

    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self):
        for slot in self._free_slots():
            if not self.waiting:
                break
            req = self.waiting.pop(0)
            single = init_decode_state(self.cfg, 1, self.max_len,
                                       dtype=self.cache_dtype,
                                       per_row_pos=True)
            p = req.prompt[None, :]
            single = single._replace(pos=jnp.int32(0))
            single, last = prefill(self.cfg, self.params,
                                   {"tokens": jnp.asarray(p)}, single)
            single = single._replace(
                pos=jnp.full((1,), single.pos, jnp.int32))
            self.state = _insert_row(self.state, single, slot)
            first = np.asarray(self.sample_fn(last))[0]
            req.generated.append(int(first))
            self.next_token[slot, 0] = int(first)
            self.slot_req[slot] = req

    def _retire(self, slot: int):
        req = self.slot_req[slot]
        req.done = True
        self.finished.append(req)
        self.slot_req[slot] = None
        # neutralize the cursor so the idle row stays cheap/masked
        self.state = self.state._replace(
            pos=self.state.pos.at[slot].set(0))

    def _mesh_scope(self):
        """Scope this engine's mesh around traced model calls.

        The shard mesh is an ambient registry (``parallel.ozaki_shard``);
        scoping it per step — instead of registering it globally at
        construction — keeps two engines with different meshes (or a
        later mesh-less engine) from seeing each other's mesh. Without a
        mesh the ambient registration, if any, stays in effect.
        """
        if self.mesh is None:
            return contextlib.nullcontext()
        from repro.parallel.ozaki_shard import use_shard_mesh
        return use_shard_mesh(self.mesh)

    def _plan_scope(self):
        """Scope this engine's plan cache around traced model calls, the
        same way ``_mesh_scope`` scopes the mesh: the jitted decode step
        reads it at trace time (``models.layers`` consults the ambient
        cache), so cached launch plans apply without re-planning — let
        alone re-tuning — on the request path."""
        if self.plan_cache is None:
            return contextlib.nullcontext()
        from repro.core.autotune import use_plan_cache
        return use_plan_cache(self.plan_cache)

    def _prewarm_plans(self):
        """Resolve a PipelinePlan for every decode projection shape.

        Runs at construction, BEFORE any request: with
        ``autotune_plans`` each cache miss is measured on the live
        backend (warm-up + ``block_until_ready``); without it the
        analytic plan is stored. Either way every steady-state decode
        projection is a cache HIT afterwards, and the cache file (when
        backed by a path) holds the plans for the next process.
        """
        from repro.api import policy_of
        from repro.core.autotune import plan_cache_key
        from repro.core.tuning import select_pipeline_plan
        from repro.kernels.ops import INTERPRET
        cfg = self.cfg
        pol = policy_of(cfg)             # one object carries every knob
        for k, n in ozaki_projection_shapes(cfg):
            key = plan_cache_key(1, n, k, batch=self.num_slots,
                                 dtype="float32", backend=pol.backend)
            if key in self.plan_cache:
                self.plan_cache.get(key)         # count the hit
                continue
            plan = select_pipeline_plan(
                1, n, k, batch=self.num_slots, broadcast_weights=True,
                backend=pol.backend, accum="df32",
                num_splits=pol.num_splits,
                fuse_epilogue=pol.fuse_epilogue, interpret=INTERPRET,
                target_error=pol.target_error, fast_mode=pol.fast_mode,
                dtype="float32", cache=self.plan_cache,
                autotune=self.autotune_plans)
            if key not in self.plan_cache:       # analytic miss: store it
                self.plan_cache.put(key, plan)
        if self.plan_cache.path is not None:
            self.plan_cache.save()

    # ------------------------------------------------------------------
    def step(self):
        """One engine tick: admit, one batched decode, retire."""
        with self._mesh_scope(), self._plan_scope():
            self._admit()
            if all(r is None for r in self.slot_req):
                return
            logits, self.state = self._decode(
                self.params, self.state, jnp.asarray(self.next_token))
        toks = np.asarray(self.sample_fn(logits))
        self._steps += 1
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            if len(req.generated) >= req.max_new_tokens or \
                    (req.eos_id is not None and
                     req.generated[-1] == req.eos_id) or \
                    int(self.state.pos[slot]) >= self.max_len:
                self._retire(slot)
                continue
            req.generated.append(int(toks[slot]))
            self.next_token[slot, 0] = int(toks[slot])

    def run_until_done(self, max_ticks: int = 10_000):
        while (self.waiting or
               any(r is not None for r in self.slot_req)):
            self.step()
            max_ticks -= 1
            if max_ticks <= 0:
                raise TimeoutError("serving engine did not drain")
        return self.finished


def generate_sequential(cfg, params, prompt: np.ndarray,
                        max_new_tokens: int, *, max_len: int = 256,
                        cache_dtype=jnp.float32,
                        sample_fn: Callable = greedy_sample) -> list[int]:
    """Single-request reference generator (the engine must match this)."""
    state = init_decode_state(cfg, 1, max_len, dtype=cache_dtype)
    state, last = prefill(cfg, params, {"tokens": jnp.asarray(prompt[None])},
                          state)
    out = [int(np.asarray(sample_fn(last))[0])]
    tok = jnp.asarray([[out[-1]]], jnp.int32)
    for _ in range(max_new_tokens - 1):
        logits, state = decode_step(cfg, params, state, tok)
        nxt = int(np.asarray(sample_fn(logits))[0])
        out.append(nxt)
        tok = jnp.asarray([[nxt]], jnp.int32)
    return out
