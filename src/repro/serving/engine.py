"""Serving: batched prefill/decode with a continuous-batching scheduler.

Slot-based continuous batching (vLLM-style, adapted to static JAX
shapes): the engine owns ONE batched ``DecodeState`` with ``num_slots``
rows, each with an independent cursor (``DecodeState.pos`` is a (b,)
vector). Admission prefillis a request on a batch-1 state and inserts
its caches into a free slot; every engine tick decodes ALL slots in one
jitted step (idle slots compute masked garbage — the static-shape tax).
Finished rows free their slot immediately, so new requests join mid-
flight without draining the batch.

Matmul precision: the engine can override the model config's
``matmul_precision`` / ``ozaki_backend`` / ``ozaki_fuse_epilogue`` /
``ozaki_shard_axis`` per deployment (e.g. serve an FP64-accurate variant
of a checkpoint without a new config). With
``matmul_precision="ozaki_fp64"`` every dense projection in the batched
decode step is a ``(num_slots, 1, k) @ (k, n)`` matmul against shared
weights — exactly ``ozaki_matmul_batched``'s broadcast-weights case, so
the whole batch shares one set of slice GEMMs per projection
(``models.layers._matmul_ozaki`` routes 3-D activations there).
``ozaki_fuse_epilogue`` selects the epilogue-fused GEMM+accumulate
kernels; ``ozaki_shard_axis`` (+ a ``mesh``) wires k-sharding for the
Ozaki projections — the engine scopes its mesh into
``parallel.ozaki_shard``'s registry around every tick, so traced model
steps pick it up without leaking it to other engines. (On the pinned
jax version the in-model constraints engage only for 2-D projections —
see ``models.layers._matmul_ozaki`` for the XLA SPMD caveat; the
sharded batched GEMM itself is served by
``parallel.ozaki_shard.ozaki_matmul_kshard_auto``.)
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_decode_state, prefill
from repro.models.transformer import DecodeState


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def _insert_row(batched, single, row: int):
    """Write a batch-1 state pytree into slot ``row`` of the batched one.

    Every DecodeState leaf has batch at dim 1 (stacked (L, b, ...)),
    except ``pos`` (dim 0).
    """
    def ins(full, one):
        if full.ndim == 1:                       # pos vector (b,)
            return full.at[row].set(one[0] if one.ndim else one)
        return jax.lax.dynamic_update_slice(
            full, one.astype(full.dtype),
            (0, row) + (0,) * (full.ndim - 2))

    return jax.tree.map(ins, batched, single)


class ServingEngine:
    def __init__(self, cfg, params, *, num_slots: int = 4,
                 max_len: int = 256, cache_dtype=jnp.float32,
                 sample_fn: Callable = greedy_sample,
                 matmul_precision: Optional[str] = None,
                 ozaki_backend: Optional[str] = None,
                 ozaki_fuse_epilogue: Optional[bool] = None,
                 ozaki_shard_axis: Optional[str] = None,
                 mesh=None):
        overrides = {}
        if matmul_precision is not None:
            overrides["matmul_precision"] = matmul_precision
        if ozaki_backend is not None:
            overrides["ozaki_backend"] = ozaki_backend
        if ozaki_fuse_epilogue is not None:
            overrides["ozaki_fuse_epilogue"] = ozaki_fuse_epilogue
        if ozaki_shard_axis is not None:
            overrides["ozaki_shard_axis"] = ozaki_shard_axis
        if overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        self.mesh = mesh
        self.cfg = cfg
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.sample_fn = sample_fn
        self.state = init_decode_state(cfg, num_slots, max_len,
                                       dtype=cache_dtype, per_row_pos=True)
        self.slot_req: list[Optional[Request]] = [None] * num_slots
        self.next_token = np.zeros((num_slots, 1), np.int32)
        self.waiting: list[Request] = []
        self.finished: list[Request] = []
        self.cache_dtype = cache_dtype
        self._decode = jax.jit(functools.partial(decode_step, cfg))
        self._steps = 0

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.waiting.append(req)

    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self):
        for slot in self._free_slots():
            if not self.waiting:
                break
            req = self.waiting.pop(0)
            single = init_decode_state(self.cfg, 1, self.max_len,
                                       dtype=self.cache_dtype,
                                       per_row_pos=True)
            p = req.prompt[None, :]
            single = single._replace(pos=jnp.int32(0))
            single, last = prefill(self.cfg, self.params,
                                   {"tokens": jnp.asarray(p)}, single)
            single = single._replace(
                pos=jnp.full((1,), single.pos, jnp.int32))
            self.state = _insert_row(self.state, single, slot)
            first = np.asarray(self.sample_fn(last))[0]
            req.generated.append(int(first))
            self.next_token[slot, 0] = int(first)
            self.slot_req[slot] = req

    def _retire(self, slot: int):
        req = self.slot_req[slot]
        req.done = True
        self.finished.append(req)
        self.slot_req[slot] = None
        # neutralize the cursor so the idle row stays cheap/masked
        self.state = self.state._replace(
            pos=self.state.pos.at[slot].set(0))

    def _mesh_scope(self):
        """Scope this engine's mesh around traced model calls.

        The shard mesh is an ambient registry (``parallel.ozaki_shard``);
        scoping it per step — instead of registering it globally at
        construction — keeps two engines with different meshes (or a
        later mesh-less engine) from seeing each other's mesh. Without a
        mesh the ambient registration, if any, stays in effect.
        """
        if self.mesh is None:
            return contextlib.nullcontext()
        from repro.parallel.ozaki_shard import use_shard_mesh
        return use_shard_mesh(self.mesh)

    # ------------------------------------------------------------------
    def step(self):
        """One engine tick: admit, one batched decode, retire."""
        with self._mesh_scope():
            self._admit()
            if all(r is None for r in self.slot_req):
                return
            logits, self.state = self._decode(
                self.params, self.state, jnp.asarray(self.next_token))
        toks = np.asarray(self.sample_fn(logits))
        self._steps += 1
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            if len(req.generated) >= req.max_new_tokens or \
                    (req.eos_id is not None and
                     req.generated[-1] == req.eos_id) or \
                    int(self.state.pos[slot]) >= self.max_len:
                self._retire(slot)
                continue
            req.generated.append(int(toks[slot]))
            self.next_token[slot, 0] = int(toks[slot])

    def run_until_done(self, max_ticks: int = 10_000):
        while (self.waiting or
               any(r is not None for r in self.slot_req)):
            self.step()
            max_ticks -= 1
            if max_ticks <= 0:
                raise TimeoutError("serving engine did not drain")
        return self.finished


def generate_sequential(cfg, params, prompt: np.ndarray,
                        max_new_tokens: int, *, max_len: int = 256,
                        cache_dtype=jnp.float32,
                        sample_fn: Callable = greedy_sample) -> list[int]:
    """Single-request reference generator (the engine must match this)."""
    state = init_decode_state(cfg, 1, max_len, dtype=cache_dtype)
    state, last = prefill(cfg, params, {"tokens": jnp.asarray(prompt[None])},
                          state)
    out = [int(np.asarray(sample_fn(last))[0])]
    tok = jnp.asarray([[out[-1]]], jnp.int32)
    for _ in range(max_new_tokens - 1):
        logits, state = decode_step(cfg, params, state, tok)
        nxt = int(np.asarray(sample_fn(logits))[0])
        out.append(nxt)
        tok = jnp.asarray([[nxt]], jnp.int32)
    return out
