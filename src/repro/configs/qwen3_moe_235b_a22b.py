"""qwen3-moe-235b-a22b — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf].

94L d_model=4096 64H (GQA kv=4) d_ff=1536(expert) vocab=151936.
FSDP-style parameter sharding over the data axis is required to fit.
"""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4,
    head_dim=128, d_ff=0, vocab_size=151936,
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=1536),
    fsdp_params=True,
    param_dtype="bfloat16",   # pure-bf16 Adam: the only  layout that
    moment_dtype="bfloat16",  # fits 940GB of state on one 256-chip pod
    train_grad_accum=16,       # 1-row microbatches: remat saves 94x33MB
)
