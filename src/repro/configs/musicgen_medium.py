"""musicgen-medium — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

48L d_model=1536 24H (kv=24, MHA) d_ff=6144 vocab=2048.
The EnCodec frontend is a STUB: inputs are the discrete codebook token ids
(batch, seq, num_codebooks); the model sums one embedding per codebook
(MusicGen delay-pattern flattening assumed upstream).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium", family="audio",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
    head_dim=64, d_ff=6144, vocab_size=2048,
    frontend="audio", num_codebooks=4,
)
