"""gemma2-9b — local+global alternating attention, logit softcaps
[arXiv:2408.00118; hf].

42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000.
Every 2nd layer is global full attention; local layers use a 4096 sliding
window. Attention logits capped at 50, final logits at 30.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-9b", family="dense",
    num_layers=42, d_model=3584, num_heads=16, num_kv_heads=8,
    head_dim=256, d_ff=14336, vocab_size=256000,
    sliding_window=4096, local_global_period=2,
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    tie_embeddings=True,
    fsdp_params=True,
)
