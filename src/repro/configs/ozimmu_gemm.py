"""ozimmu — the paper-native workload: one FP64-accurate GEMM.

Not an assigned LM architecture; this config drives the paper's own
benchmarks (Fig. 5-9) and the paper-representative dry-run/hillclimb cell:
a distributed Ozaki DGEMM C = A.B with k sharded across the mesh.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class GemmConfig:
    name: str = "ozimmu-gemm"
    m: int = 16384
    n: int = 16384
    k: int = 16384
    num_splits: int = 9
    fuse_diagonals: bool = True
    concat_k: bool = False


CONFIG = GemmConfig()
