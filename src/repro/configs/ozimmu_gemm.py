"""ozimmu — the paper-native workload: one FP64-accurate GEMM.

Not an assigned LM architecture; this config drives the paper's own
benchmarks (Fig. 5-9) and the paper-representative dry-run/hillclimb cell:
a distributed Ozaki DGEMM C = A.B with k sharded across the mesh.

``backend`` selects the pipeline implementation (see ``core.ozaki``):
"xla" is the reference, "pallas_fused" the deployment path whose split
and accumulation stages run as one-pass fused kernels; ``autotune``
derives block shapes via ``core.tuning.select_plan``. Consumers:
``benchmarks/bench_fused_pipeline.py`` (backend/accum/autotune and the
``BATCHED_CONFIG`` serving shape, CPU-scaled) and the dry-run gemm cell
(``launch/dryrun.py``: num_splits / fuse_diagonals / accum defaults).
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class GemmConfig:
    name: str = "ozimmu-gemm"
    m: int = 16384
    n: int = 16384
    k: int = 16384
    num_splits: int = 9
    fuse_diagonals: bool = True
    concat_k: bool = False
    backend: str = "pallas_fused"   # xla | pallas | pallas_fused
    accum: str = "df32"             # deployable accumulation (TPU: no f64)
    autotune: bool = True           # derive blocks via core.tuning.select_plan


@dataclasses.dataclass(frozen=True)
class BatchedGemmConfig:
    """Serving case: (batch, m, k) @ (k, n) with broadcast weights."""

    name: str = "ozimmu-gemm-batched"
    batch: int = 32
    m: int = 128
    n: int = 4096
    k: int = 4096
    num_splits: int = 9
    backend: str = "pallas_fused"
    accum: str = "df32"


CONFIG = GemmConfig()
BATCHED_CONFIG = BatchedGemmConfig()
