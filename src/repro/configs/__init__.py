"""Config registry: ``--arch <id>`` resolves through ``get_config``."""
from __future__ import annotations

import importlib

from .base import (ArchConfig, MoEConfig, SSMConfig, ShapeConfig, SHAPES,
                   LONG_CONTEXT_ARCHS, cell_is_skipped)

# arch id (CLI) -> module name
ARCH_MODULES = {
    "llama3.2-3b": "llama3_2_3b",
    "minitron-8b": "minitron_8b",
    "gemma2-9b": "gemma2_9b",
    "chatglm3-6b": "chatglm3_6b",
    "internvl2-76b": "internvl2_76b",
    "zamba2-7b": "zamba2_7b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "musicgen-medium": "musicgen_medium",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "ozimmu-gemm": "ozimmu_gemm",
}

ALL_ARCHS = tuple(a for a in ARCH_MODULES if a != "ozimmu-gemm")


def get_config(arch: str, **overrides):
    """Load ``CONFIG`` for an arch id; ``overrides`` replace fields."""
    if arch not in ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCH_MODULES)}")
    mod = importlib.import_module(f".{ARCH_MODULES[arch]}", __name__)
    cfg = mod.CONFIG
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


__all__ = ["ArchConfig", "MoEConfig", "SSMConfig", "ShapeConfig", "SHAPES",
           "LONG_CONTEXT_ARCHS", "cell_is_skipped", "ARCH_MODULES",
           "ALL_ARCHS", "get_config"]
