"""Architecture / shape / run configuration system.

Every assigned architecture is a frozen ``ArchConfig`` in its own module
(``src/repro/configs/<id>.py``) selectable by ``--arch <id>`` in the
launchers.  ``reduced()`` derives the CPU smoke-test version of the same
family (small widths/depths, tiny vocab, few experts).

The paper's contribution enters through ``matmul_precision``:

  * ``"bf16"``       — plain MXU bf16 matmuls (the TPU-native baseline).
  * ``"int8_quant"`` — inference-style per-channel int8 quantization
                       (what the IMMUs were built for; lossy).
  * ``"ozaki_fp64"`` — the paper: FP64-accurate matmul from int8 MXU ops
                       (error-free Ozaki splitting, df32 accumulation).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.warn_once import WarnOnceLatch

# one-shot DeprecationWarning for legacy ozaki_* fields (resettable in
# tests via core.warn_once.reset_all_warn_latches — conftest does this)
_LEGACY_FIELD_LATCH = WarnOnceLatch("archconfig_legacy_ozaki_fields")


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int            # per-expert hidden width
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    load_balance_coef: float = 1e-2


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int                # N in Mamba papers
    d_conv: int = 4
    expand: int = 2
    variant: str = "mamba1"     # "mamba1" | "mamba2"
    headdim: int = 64           # mamba2 SSD head size
    chunk: int = 256            # mamba2 SSD chunk length


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int              # 0 => attention-free
    num_kv_heads: int
    d_ff: int                   # dense FFN hidden (0 for moe/ssm-only)
    vocab_size: int
    head_dim: int = 0           # 0 => d_model // num_heads

    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None

    # --- attention pattern ---------------------------------------------
    sliding_window: int = 0         # >0: width of local-attention layers
    local_global_period: int = 0    # gemma2: every p-th layer is global
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    rope_style: str = "standard"    # standard | partial2d (chatglm) | none
    rope_theta: float = 10000.0
    # hybrid (zamba2): a SHARED attention block applied every p mamba blocks
    hybrid_attn_period: int = 0

    # --- modality frontend stubs (per assignment spec) ------------------
    frontend: str = "none"          # none | vision | audio
    num_patches: int = 256          # vision stub: patch embeddings per image
    num_codebooks: int = 1          # audio stub: EnCodec codebooks summed

    # --- numerics / the paper's knob ------------------------------------
    # ONE policy spec is the supported surface (repro.api.MatmulPolicy):
    # e.g. "ozaki-fp64x9@1e-25:fast/pallas_fused+epilogue|shard=data".
    # When set it is authoritative — matmul_precision and every ozaki_*
    # field below are back-filled from it so legacy readers stay
    # consistent. When empty, the legacy fields below stand (deprecated:
    # any non-default ozaki_* value emits a one-shot DeprecationWarning).
    matmul_policy: str = ""
    matmul_precision: str = "bf16"  # bf16 | int8_quant | ozaki_fp64
    ozaki_splits: int = 9
    ozaki_backend: str = "xla"      # xla | pallas | pallas_fused
    ozaki_fuse_epilogue: bool = False   # pallas_fused: GEMM+accum in one
                                        # kernel (int32 stays in VMEM)
    ozaki_shard_axis: str = ""      # mesh axis to k-shard ozaki matmuls
                                    # over ("" = unsharded); needs a mesh
                                    # registered via parallel.ozaki_shard
    ozaki_plan_cache: str = ""      # path to a persistent PlanCache JSON
                                    # ("" = no cache); the serving engine
                                    # pre-warms it at startup
    ozaki_autotune: bool = False    # measure candidate plans on a cache
                                    # miss (deploy-time; needs plan_cache)
    ozaki_target_error: float = 0.0  # accuracy target on the scaled error
                                    # (core.accuracy); > 0 lets the driver
                                    # REDUCE ozaki_splits per GEMM shape
                                    # when the guaranteed bound allows
    ozaki_fast_mode: bool = False   # truncate slice pairs to the minimal
                                    # budget meeting the target (or drop
                                    # the last anti-diagonal w/o a target)
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    accum_dtype: str = "float32"    # matmul partial sums; bf16 halves the
                                    # TP all-reduce payload (§Perf cell C)
    moment_dtype: str = "float32"   # bf16 moments fit the 235B single-pod
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    # --- training / memory policy ----------------------------------------
    remat: bool = True              # activation checkpointing per block
    fsdp_params: bool = False       # additionally shard params over "data"
    scan_layers: bool = True
    train_grad_accum: int = 8       # microbatching (clamped to local batch)

    # ----------------------------------------------------------------------
    def __post_init__(self):
        if self.num_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.family in ("dense", "moe", "ssm", "hybrid", "vlm", "audio")
        self._sync_matmul_policy()
        assert self.matmul_precision in ("bf16", "int8_quant", "ozaki_fp64")
        assert self.ozaki_backend in ("xla", "pallas", "pallas_fused")
        assert self.ozaki_target_error >= 0.0

    def _sync_matmul_policy(self):
        """Keep ``matmul_policy`` and the legacy fields consistent.

        * ``matmul_policy`` set — it is authoritative: parse/validate it
          (canonicalizing the spec) and back-fill ``matmul_precision`` +
          every ``ozaki_*`` field so legacy readers agree with it.
        * ``matmul_policy`` empty — the legacy fields stand; any
          non-default ``ozaki_*`` value emits a one-shot
          DeprecationWarning pointing at the spec equivalent
          (``self.policy().spec()``). The spec is NOT stored back (so
          ``dataclasses.replace`` with legacy kwargs keeps working).
        """
        from repro.api import policy_from_legacy_fields
        if self.matmul_policy:
            from repro.api import MatmulPolicy
            pol = MatmulPolicy.parse(self.matmul_policy)
            set_ = object.__setattr__
            set_(self, "matmul_policy", pol.spec())
            set_(self, "matmul_precision", pol.scheme)
            if pol.scheme == "ozaki_fp64":
                set_(self, "ozaki_backend", pol.backend)
                if pol.num_splits is not None:
                    set_(self, "ozaki_splits", pol.num_splits)
                elif self.ozaki_splits != \
                        _legacy_ozaki_defaults()["ozaki_splits"]:
                    # the one legacy field an auto-split spec cannot
                    # back-fill: a pinned count alongside the spec would
                    # silently diverge from what actually runs
                    _LEGACY_FIELD_LATCH.warn(
                        "splits_vs_auto_spec",
                        f"ozaki_splits={self.ozaki_splits} is ignored: "
                        f"matmul_policy {pol.spec()!r} selects the split "
                        "count automatically (pin it with an 'xN' scheme "
                        "suffix instead)", stacklevel=6)
                set_(self, "ozaki_fuse_epilogue", pol.fuse_epilogue)
                set_(self, "ozaki_shard_axis", pol.shard_axis or "")
                set_(self, "ozaki_plan_cache", pol.plan_cache or "")
                set_(self, "ozaki_autotune", pol.autotune)
                set_(self, "ozaki_target_error", pol.target_error or 0.0)
                set_(self, "ozaki_fast_mode", pol.fast_mode)
            return
        stale = [f for f, dflt in _legacy_ozaki_defaults().items()
                 if getattr(self, f) != dflt]
        if stale:
            _LEGACY_FIELD_LATCH.warn(
                "ozaki_fields",
                f"ArchConfig ozaki_* fields ({', '.join(sorted(stale))}) "
                f"are deprecated; set matmul_policy="
                f"{policy_from_legacy_fields(self).spec()!r} instead "
                "(repro.api.MatmulPolicy)",
                category=DeprecationWarning, stacklevel=5)

    def policy(self):
        """The ``repro.api.MatmulPolicy`` this config resolves to."""
        from repro.api import policy_of
        return policy_of(self)

    @property
    def attention_free(self) -> bool:
        return self.num_heads == 0

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(1, self.num_kv_heads)

    def param_count(self) -> int:
        """Analytic total parameter count (for 6ND MODEL_FLOPS)."""
        c, d = self, self.d_model
        n = c.vocab_size * d * (1 if c.tie_embeddings else 2)
        per_layer = 0
        if c.family == "hybrid":
            # zamba2: mamba2 stack + ONE shared attention+mlp block
            per_layer = _mamba_params(c, variant="mamba2")
            n += c.num_layers * per_layer
            n += _attn_params(c) + 3 * d * c.d_ff          # shared block
            n += c.num_layers * 2 * d                      # norms
            return n
        if c.family == "ssm":
            per_layer = _mamba_params(c, variant=c.ssm.variant)
        else:
            per_layer = _attn_params(c)
            if c.moe is not None:
                per_layer += d * c.moe.num_experts           # router
                per_layer += c.moe.num_experts * 3 * d * c.moe.d_ff_expert
            else:
                per_layer += 3 * d * c.d_ff                  # gate/up/down
        per_layer += 2 * d                                   # 2 RMSNorms
        n += c.num_layers * per_layer + d                    # final norm
        return n

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k of num_experts)."""
        c, d = self, self.d_model
        if c.moe is None:
            return self.param_count()
        n = self.param_count()
        moe_all = c.num_layers * c.moe.num_experts * 3 * d * c.moe.d_ff_expert
        moe_act = c.num_layers * c.moe.top_k * 3 * d * c.moe.d_ff_expert
        return n - moe_all + moe_act

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dataclasses.asdict(self)
        if self.moe is not None:
            kw["moe"] = MoEConfig(num_experts=8, top_k=2, d_ff_expert=64)
        if self.ssm is not None:
            kw["ssm"] = SSMConfig(d_state=8, d_conv=4, expand=2,
                                  variant=self.ssm.variant, headdim=16,
                                  chunk=32)
        kw.update(
            name=self.name + "-reduced",
            num_layers=2 if self.family != "hybrid" else 4,
            d_model=64,
            num_heads=0 if self.attention_free else 4,
            num_kv_heads=0 if self.attention_free else 2,
            head_dim=0 if self.attention_free else 16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            sliding_window=16 if self.sliding_window else 0,
            hybrid_attn_period=2 if self.hybrid_attn_period else 0,
            num_patches=4,
            remat=False,
            # CPU backend cannot *execute* batched bf16->f32 dots (compile
            # is fine); smoke tests run f32, full configs stay bf16.
            compute_dtype="float32",
        )
        if isinstance(kw.get("moe"), dict):
            kw["moe"] = MoEConfig(**kw["moe"])
        if isinstance(kw.get("ssm"), dict):
            kw["ssm"] = SSMConfig(**kw["ssm"])
        return ArchConfig(**kw)


def _legacy_ozaki_defaults() -> dict:
    """The legacy ``ozaki_*`` fields and their dataclass defaults — a
    non-default value on a config WITHOUT a matmul_policy spec is the
    deprecated surface. Derived from the dataclass itself so a changed
    default cannot drift out of sync with the deprecation check."""
    return {f.name: f.default for f in dataclasses.fields(ArchConfig)
            if f.name.startswith("ozaki_")}


def _attn_params(c: ArchConfig) -> int:
    if c.attention_free:
        return 0
    d, hd = c.d_model, c.head_dim
    return (d * c.num_heads * hd          # q
            + 2 * d * c.num_kv_heads * hd  # k, v
            + c.num_heads * hd * d)        # o


def _mamba_params(c: ArchConfig, variant: str) -> int:
    d = c.d_model
    di = c.ssm.expand * d
    n = 2 * d * di                # in_proj (x, z)
    n += di * c.ssm.d_conv        # depthwise conv
    if variant == "mamba1":
        n += di * (c.ssm.d_state * 2 + 1)   # B, C, dt projections (x-dep)
        n += di * c.ssm.d_state             # A
        n += di * 2                          # dt bias, D
    else:                          # mamba2 (SSD): scalar A per head
        nh = di // c.ssm.headdim
        n += d * (2 * c.ssm.d_state + nh)   # B, C, dt
        n += nh * 2                          # A, D
    n += di * d                    # out_proj
    return n


# ----------------------------------------------------------------------------
# Input shapes (assigned): seq_len x global_batch, with the step they lower
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# Pure full-attention archs skip long_500k (quadratic attention; the skip is
# recorded in DESIGN.md §6 and EXPERIMENTS.md §Dry-run).
LONG_CONTEXT_ARCHS = ("zamba2-7b", "falcon-mamba-7b")


def cell_is_skipped(arch_name: str, shape_name: str) -> bool:
    return shape_name == "long_500k" and arch_name not in LONG_CONTEXT_ARCHS
