"""falcon-mamba-7b — attention-free Mamba1 [arXiv:2410.05355; unverified].

64L d_model=4096 d_ff=0 vocab=65024, ssm_state=16.
Runs long_500k (O(1) recurrent state). The Ozaki precision policy applies
to the in/out projections only — the selective scan is not a GEMM
(DESIGN.md SArch-applicability).
"""
from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    num_layers=64, d_model=4096, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=65024,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, variant="mamba1"),
    fsdp_params=True,
)
