"""zamba2-7b — Mamba2 backbone + SHARED attention block [arXiv:2411.15242].

81L d_model=3584 32H (kv=32, MHA) d_ff=14336 vocab=32000, ssm_state=64.
One shared attention+MLP block is applied every ``hybrid_attn_period``
Mamba2 blocks (weights shared across applications, distinct KV caches).
Runs long_500k: SSM state is O(1); the shared-attention KV at 500k is
sharded over the model axis.
"""
from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    head_dim=112, d_ff=14336, vocab_size=32000,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, variant="mamba2",
                  headdim=64, chunk=256),
    hybrid_attn_period=6,
    fsdp_params=True,
    train_grad_accum=16,
)
