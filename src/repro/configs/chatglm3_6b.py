"""chatglm3-6b — RoPE 2d (partial rotary), GQA [arXiv:2406.12793; hf].

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b", family="dense",
    num_layers=28, d_model=4096, num_heads=32, num_kv_heads=2,
    head_dim=128, d_ff=13696, vocab_size=65024,
    rope_style="partial2d",
    fsdp_params=True,
)
