"""internvl2-76b — InternViT + (Llama3-70B-class) LLM [arXiv:2404.16821].

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
The InternViT frontend is a STUB per the assignment: ``input_specs()``
provides precomputed patch embeddings (batch, num_patches, d_model) that
the backbone consumes alongside token embeddings.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    head_dim=128, d_ff=28672, vocab_size=128256,
    frontend="vision", num_patches=256,
    rope_theta=500000.0,
    fsdp_params=True,
    moment_dtype="bfloat16",   # dense 70B on one pod: halve Adam state
    train_grad_accum=16,       # 1-row microbatches (80x134MB saves -> 5.4GB)
)
