"""ozJAX — DGEMM on integer matrix multiplication units, in JAX/Pallas.

Package-wide numerics policy, applied before any RNG or kernel runs:

* partitionable threefry — sharded parameter init must draw the SAME
  numbers as single-device init. The non-partitionable generator (the
  default on older jax) re-derives bits from output positions per shard,
  so a (4, 2)-sharded weight would be initialized differently than the
  replicated reference. Setting it here (the package root) rather than
  in one module keeps the stream independent of import order: every
  ``repro.*`` import passes through this file first.
"""
import jax

jax.config.update("jax_threefry_partitionable", True)
