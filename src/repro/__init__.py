"""ozJAX — DGEMM on integer matrix multiplication units, in JAX/Pallas.

Public API (the package's front door — see ``repro.api``):

* ``matmul(a, b, precision=...)`` — one precision-policy entry point
  over every Ozaki pipeline (unbatched/batched/DW/complex).
* ``MatmulPolicy`` — the frozen precision spec (``"ozaki-fp64x9"``,
  ``"ozaki-fp64@1e-25:fast/pallas_fused+epilogue"``, ``"bf16"``, ...).
* ``default_matmul_precision(spec)`` — scope the ambient policy (and
  its plan cache), mirroring ``jax.default_matmul_precision``.
* ``OzakiConfig`` — the core-layer configuration object, for callers
  driving ``repro.core`` directly.

Package-wide numerics policy, applied before any RNG or kernel runs:

* partitionable threefry — sharded parameter init must draw the SAME
  numbers as single-device init. The non-partitionable generator (the
  default on older jax) re-derives bits from output positions per shard,
  so a (4, 2)-sharded weight would be initialized differently than the
  replicated reference. Setting it here (the package root) rather than
  in one module keeps the stream independent of import order: every
  ``repro.*`` import passes through this file first.
"""
import jax

jax.config.update("jax_threefry_partitionable", True)

from repro.api import (MatmulPolicy, default_matmul_precision,  # noqa: E402
                       matmul)
from repro.core.ozaki import OzakiConfig  # noqa: E402

__all__ = ["matmul", "MatmulPolicy", "default_matmul_precision",
           "OzakiConfig"]
