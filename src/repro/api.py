"""The package's front door: one matmul, one precision policy.

The paper's pitch is that the Ozaki scheme is a *drop-in* DGEMM: callers
ask for an accuracy and the scheme decides splits, kernels, and
truncation. Four PRs of growth left that decision spread over four entry
points (``ozaki_matmul``/``_batched``/``_dw``/``_complex``), eight
``ozaki_*`` ArchConfig fields, and six serving-engine kwargs. This
module collapses all of it into two objects:

* ``MatmulPolicy`` — a frozen, hashable bundle of every precision
  decision (scheme, backend, split count, fusion, accuracy target, fast
  mode, sharding, plan cache), with a compact string spec that parses,
  formats canonically, and JSON-round-trips::

      ozaki-fp64                      # the paper, auto split count
      ozaki-fp64x9                    # pinned INT8x9 operating point
      ozaki-fp64@1e-25:fast/pallas_fused+epilogue
      ozaki-fp64x9/pallas_fused+streaming   # slices never leave VMEM
      ozaki-fp64x7:budget:12/pallas|shard=data|cache=plans.json|autotune
      bf16                            # the TPU-native baseline
      int8-quant                      # lossy inference quantization

  Grammar (sections in fixed order, every one optional but the scheme)::

      SPEC    := SCHEME ["x" SPLITS] ["@" TARGET] [":" MODES]
                 ["/" BACKEND ["+epilogue" | "+streaming"]] ("|" OPTION)*
      MODES   := MODE ("," MODE)*   MODE := "fast" | "full" | "diagonal"
                                          | "budget:" N
      OPTION  := "shard=" AXIS | "comm=" ("f64" | "int8")
                 | "cache=" PATH | "autotune"

* ``matmul(a, b, precision=...)`` — one entry point dispatching on
  rank/dtype/DW-ness to the existing pipelines (which stay the
  bitwise-verified implementation layer): 2-D f64 -> the paper path,
  2-D f32 -> the TPU-native df32 path, 3-D -> the batched pipeline
  (stacked or broadcast weights), ``DW`` operands -> the double-float32
  entry, complex -> the 4-mul complex pipeline.

``default_matmul_precision(spec)`` mirrors ``jax.default_matmul_precision``:
a context manager scoping the ambient policy — and, when the policy
names a plan cache, the ambient ``core.autotune`` plan-cache registry —
around a region of code, so libraries can call ``repro.matmul`` without
threading a policy argument.

Validation that used to live in ``OzakiConfig.__post_init__``,
``ArchConfig``'s asserts, and ``launch/serve.py`` flag handling is
centralized in ``MatmulPolicy.__post_init__``: unknown schemes/backends,
malformed pair policies, non-positive targets, and ozaki-only knobs on
non-ozaki schemes are all rejected at policy construction, before any
array exists.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import functools
import os
import re
import threading
from typing import Optional

SCHEMES = ("bf16", "int8_quant", "ozaki_fp64", "ozaki2_fp64")

_SCHEME_RE = re.compile(r"^(?P<scheme>[a-z0-9_\-]+?)(?:x(?P<splits>\d+))?$")


def _canon_scheme(s: str) -> str:
    return s.replace("-", "_")


def _canon_backend(s: str) -> str:
    return s.replace("-", "_")


@dataclasses.dataclass(frozen=True)
class MatmulPolicy:
    """One precision decision for every matmul it governs (hashable).

    scheme:        "bf16" | "int8_quant" | "ozaki_fp64" — what the matmul
                   computes (baseline, lossy quantization, or the paper's
                   FP64-accurate int8 scheme).
    backend:       "xla" | "pallas" | "pallas_fused" — executor family
                   (ozaki only; see ``core.tuning.BACKENDS``).
    num_splits:    s in INT8xs, or None for the shape-derived paper
                   operating point (``core.tuning.select_num_splits``).
    fuse_epilogue: pallas_fused: GEMM + scaled accumulation in one kernel
                   (int32 slice products never reach HBM).
    streaming:     pallas_fused: slice EXTRACTION fused into the epilogue
                   GEMM grid too — int8 slices live only in VMEM, never
                   written to or re-read from HBM (``fusion="streaming"``;
                   spec suffix ``+streaming``). Mutually exclusive with
                   ``fuse_epilogue`` (it subsumes it).
    target_error:  accuracy target on the scaled error (``core.accuracy``)
                   — lets the planner REDUCE the split count per shape.
    fast_mode:     truncate slice pairs to the minimal budget meeting
                   ``target_error`` (or drop the last anti-diagonal).
    pair_policy:   "full" | "diagonal" | "budget:N" explicit truncation.
    shard_axis:    mesh axis to k-shard over (``parallel.ozaki_shard``).
    comm:          "f64" | "int8" — what sharded calls move over the
                   interconnect: f64 operand words (GSPMD baseline) or
                   the packed int8-slice representation + exact int32
                   partials (``|comm=int8``; ~8x fewer bytes on k-shard
                   layouts, bitwise-identical results).
    plan_cache:    path of a persistent ``core.autotune.PlanCache`` —
                   tuned launch plans (result-invariant fields only) are
                   applied to matching shapes.
    autotune:      measure candidate plans on cache misses (consumed by
                   the serving pre-warm and the benchmark machinery; the
                   ``matmul`` hot path itself only ever *reads* a cache).
    """

    scheme: str = "ozaki_fp64"
    backend: str = "xla"
    num_splits: Optional[int] = None
    fuse_epilogue: bool = False
    streaming: bool = False
    target_error: Optional[float] = None
    fast_mode: bool = False
    pair_policy: str = "full"
    shard_axis: Optional[str] = None
    comm: str = "f64"
    plan_cache: Optional[str] = None
    autotune: bool = False

    def __post_init__(self):
        from repro.core.tuning import BACKENDS, COMM_MODES
        if self.scheme not in SCHEMES:
            raise ValueError(f"unknown scheme {self.scheme!r}; expected "
                             f"one of {SCHEMES}")
        if self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; expected "
                             f"one of {BACKENDS}")
        if self.comm not in COMM_MODES:
            raise ValueError(f"unknown comm {self.comm!r}; expected one "
                             f"of {COMM_MODES}")
        if self.num_splits is not None and self.num_splits < 1:
            raise ValueError(f"num_splits must be >= 1, got "
                             f"{self.num_splits}")
        if self.target_error is not None and not self.target_error > 0.0:
            raise ValueError(f"target_error must be > 0, got "
                             f"{self.target_error}")
        if self.streaming and self.fuse_epilogue:
            raise ValueError(
                "streaming and fuse_epilogue are mutually exclusive: "
                "streaming subsumes the epilogue fusion (pick one of "
                "'+streaming' / '+epilogue')")
        _validate_pair_policy(self.pair_policy)
        if self.scheme == "ozaki2_fp64":
            # Scheme II shares the backend/fusion/transport/accuracy/
            # cache knobs ('+epilogue' is the fused-CRT kernel, |shard=/
            # |comm=int8 the residue-wire transport); what it rejects is
            # the Scheme I pair machinery (no pair schedule to truncate —
            # accuracy scales via the mantissa budget) and streaming.
            # ``num_splits`` IS meaningful: it pins the residue modulus
            # count (the ``ozaki2-fp64xL`` accuracy dial).
            for field, why in _OZAKI2_REJECTED.items():
                if getattr(self, field) != _ozaki_only_fields()[field]:
                    raise ValueError(
                        f"{field}={getattr(self, field)!r} does not apply "
                        f"to scheme 'ozaki2-fp64': {why}")
        elif self.scheme != "ozaki_fp64":
            for field, default in _ozaki_only_fields().items():
                if getattr(self, field) != default:
                    raise ValueError(
                        f"{field}={getattr(self, field)!r} only applies to "
                        f"scheme 'ozaki-fp64', not {self.spec()!r}")

    # ---- string spec ---------------------------------------------------
    def spec(self) -> str:
        """Canonical compact spec; ``parse(p.spec()) == p`` always."""
        s = self.scheme.replace("_", "-")
        if self.num_splits is not None:
            s += f"x{self.num_splits}"
        if self.target_error is not None:
            s += f"@{self.target_error!r}"
        modes = (["fast"] if self.fast_mode else []) + \
            ([self.pair_policy] if self.pair_policy != "full" else [])
        if modes:
            s += ":" + ",".join(modes)
        if self.backend != "xla" or self.fuse_epilogue or self.streaming:
            s += "/" + self.backend + \
                ("+epilogue" if self.fuse_epilogue else "") + \
                ("+streaming" if self.streaming else "")
        if self.shard_axis:
            s += f"|shard={self.shard_axis}"
        if self.comm != "f64":
            s += f"|comm={self.comm}"
        if self.plan_cache:
            s += f"|cache={self.plan_cache}"
        if self.autotune:
            s += "|autotune"
        return s

    def __str__(self) -> str:
        return self.spec()

    @classmethod
    def parse(cls, spec: str) -> "MatmulPolicy":
        return _parse_spec(spec)

    @classmethod
    def of(cls, value) -> "MatmulPolicy":
        """Coerce a policy, a spec string, or None (-> ambient/default)."""
        if value is None:
            return default_policy()
        if isinstance(value, cls):
            return value
        if isinstance(value, str):
            return cls.parse(value)
        raise TypeError(f"expected MatmulPolicy, spec str, or None; got "
                        f"{type(value).__name__}")

    # ---- JSON ----------------------------------------------------------
    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "MatmulPolicy":
        return cls(**d)

    # ---- interop -------------------------------------------------------
    def resolve_num_splits(self, k: int) -> int:
        """The split count this policy runs at for reduction extent k."""
        if self.num_splits is not None:
            return self.num_splits
        from repro.core.tuning import select_num_splits
        return select_num_splits(k)

    def ozaki_config(self, k: int, *, accum: str = "f64",
                     interpret: Optional[bool] = None):
        """The ``core.ozaki.OzakiConfig`` this policy resolves to.

        Shape-only (k sizes the auto split count), so the result is
        trace-stable. ``interpret`` defaults from the host
        (``kernels.ops.INTERPRET``: interpret-mode Pallas on CPU
        validation hosts, Mosaic lowering on TPU).
        """
        if self.scheme != "ozaki_fp64":
            raise ValueError(f"scheme {self.scheme!r} has no OzakiConfig")
        from repro.core.ozaki import OzakiConfig
        if interpret is None:
            from repro.kernels.ops import INTERPRET
            interpret = INTERPRET
        return OzakiConfig(
            num_splits=self.resolve_num_splits(k), accum=accum,
            backend=self.backend, fuse_epilogue=self.fuse_epilogue,
            streaming=self.streaming,
            pair_policy=self.pair_policy, target_error=self.target_error,
            fast_mode=self.fast_mode, shard_axis=self.shard_axis,
            comm=self.comm, fuse_diagonals=True, interpret=interpret)

    def modular_config(self, *, interpret: Optional[bool] = None):
        """The ``core.modular.ModularConfig`` this policy resolves to
        (Scheme II). ``num_splits`` maps onto the residue modulus count
        (the ``ozaki2-fp64xL`` spec dial); ``target_error`` sizes the
        mantissa budget via the guaranteed bound."""
        if self.scheme != "ozaki2_fp64":
            raise ValueError(f"scheme {self.scheme!r} has no ModularConfig")
        from repro.core.modular import ModularConfig
        if interpret is None:
            from repro.kernels.ops import INTERPRET
            interpret = INTERPRET
        return ModularConfig(num_moduli=self.num_splits,
                             target_error=self.target_error,
                             backend=self.backend,
                             fuse_epilogue=self.fuse_epilogue,
                             interpret=interpret)


# MatmulPolicy fields Scheme II rejects, with the reason (the rest —
# backend, fuse_epilogue (the fused-CRT kernel), shard_axis/comm (the
# residue-wire transport), num_splits, target_error, plan_cache,
# autotune — carry over).
_OZAKI2_REJECTED = {
    "streaming": "no residue streaming kernel (the fused-CRT '+epilogue' "
                 "route is the Scheme II fusion)",
    "fast_mode": "no pair schedule to truncate (use target_error or a "
                 "pinned modulus count xL instead)",
    "pair_policy": "no pair schedule to truncate (use target_error or a "
                   "pinned modulus count xL instead)",
}


@functools.lru_cache(maxsize=1)
def _ozaki_only_fields() -> dict:
    """Every MatmulPolicy field but ``scheme`` is ozaki-only, with its
    dataclass default as the neutral value a non-ozaki scheme must keep.
    Derived from the dataclass itself so a future field cannot be
    silently forgotten here."""
    return {f.name: f.default for f in dataclasses.fields(MatmulPolicy)
            if f.name != "scheme"}


def _validate_pair_policy(policy: str) -> None:
    """Syntactic pair-policy check (the schedule-level semantic check
    lives in ``core.tuning.parse_pair_policy``, which needs a split
    count)."""
    if policy in ("full", "diagonal"):
        return
    if policy.startswith("budget:"):
        tail = policy[len("budget:"):]
        if tail.isdigit() and int(tail) >= 1:
            return
        raise ValueError(f"pair budget must be a positive int, got "
                         f"{policy!r}")
    raise ValueError(f"unknown pair_policy {policy!r}; expected 'full', "
                     f"'diagonal', or 'budget:N'")


@functools.lru_cache(maxsize=256)
def _parse_spec(spec: str) -> MatmulPolicy:
    if not isinstance(spec, str) or not spec.strip():
        raise ValueError(f"empty policy spec {spec!r}")
    parts = spec.strip().split("|")
    core, opts = parts[0], parts[1:]

    kw: dict = {}
    for opt in opts:
        if opt == "autotune":
            kw["autotune"] = True
        elif opt.startswith("shard="):
            kw["shard_axis"] = opt[len("shard="):] or None
        elif opt.startswith("comm="):
            kw["comm"] = opt[len("comm="):]
        elif opt.startswith("cache="):
            kw["plan_cache"] = opt[len("cache="):] or None
        else:
            raise ValueError(f"unknown policy option {opt!r} in {spec!r}; "
                             f"expected shard=AXIS, comm=MODE, cache=PATH, "
                             f"autotune")

    if "/" in core:
        core, backend = core.split("/", 1)
        if backend.endswith("+streaming"):
            kw["streaming"] = True
            backend = backend[: -len("+streaming")]
        if backend.endswith("+epilogue"):
            kw["fuse_epilogue"] = True
            backend = backend[: -len("+epilogue")]
        kw["backend"] = _canon_backend(backend)
    if ":" in core:
        core, modes = core.split(":", 1)
        for mode in modes.split(","):
            if mode == "fast":
                kw["fast_mode"] = True
            elif mode in ("full", "diagonal") or mode.startswith("budget:"):
                if "pair_policy" in kw and mode != kw["pair_policy"]:
                    raise ValueError(f"conflicting pair policies in "
                                     f"{spec!r}")
                kw["pair_policy"] = mode
            else:
                raise ValueError(f"unknown mode {mode!r} in {spec!r}; "
                                 f"expected fast, full, diagonal, budget:N")
    if "@" in core:
        core, target = core.split("@", 1)
        try:
            kw["target_error"] = float(target)
        except ValueError:
            raise ValueError(f"malformed target_error {target!r} in "
                             f"{spec!r}") from None
    m = _SCHEME_RE.match(core)
    if not m:
        raise ValueError(f"malformed scheme {core!r} in {spec!r}")
    kw["scheme"] = _canon_scheme(m.group("scheme"))
    if m.group("splits") is not None:
        kw["num_splits"] = int(m.group("splits"))
    return MatmulPolicy(**kw)          # __post_init__ validates the rest


# ----------------------------------------------------------------------------
# Ambient default policy (mirrors jax.default_matmul_precision)
# ----------------------------------------------------------------------------

# thread-local like jax.default_matmul_precision: a scope entered on one
# thread must not leak into another thread's unscoped matmul calls
_DEFAULT_POLICY = threading.local()
_PACKAGE_DEFAULT = "ozaki_fp64"


def default_policy() -> MatmulPolicy:
    """The policy ``matmul`` runs under when none is passed: the innermost
    ``default_matmul_precision`` scope (on this thread), else the package
    default (the paper's FP64-accurate scheme, auto operating point)."""
    pol = getattr(_DEFAULT_POLICY, "value", None)
    if pol is not None:
        return pol
    return MatmulPolicy(scheme=_PACKAGE_DEFAULT)


@contextlib.contextmanager
def default_matmul_precision(precision):
    """Scope the ambient matmul policy (and its plan cache) — the repro
    counterpart of ``jax.default_matmul_precision``::

        with repro.default_matmul_precision("ozaki-fp64@1e-25:fast"):
            c = repro.matmul(a, b)          # runs under the scoped policy

    When the policy names a plan cache (``|cache=PATH``), the cache is
    loaded (memoized per path, reloaded on file change) and registered
    as the ambient ``core.autotune`` plan cache for the scope —
    subsuming a manual ``use_plan_cache`` — so both ``repro.matmul`` and
    traced model steps pick tuned launch plans up without any extra
    plumbing.

    The POLICY scope is thread-local (like
    ``jax.default_matmul_precision``); the plan-cache registry it feeds
    is the pre-existing process-global ``core.autotune`` slot, shared
    with the serving engine's tick scope. Cached plans are
    result-invariant by contract, so a cross-thread cache sighting can
    only change launch parameters, never results.
    """
    pol = MatmulPolicy.of(precision)
    cache_ctx = contextlib.nullcontext()
    if pol.plan_cache is not None:
        from repro.core.autotune import use_plan_cache
        cache_ctx = use_plan_cache(_load_plan_cache(pol.plan_cache))
    prev = getattr(_DEFAULT_POLICY, "value", None)
    _DEFAULT_POLICY.value = pol
    try:
        with cache_ctx:
            yield pol
    finally:
        _DEFAULT_POLICY.value = prev


# path -> (mtime, PlanCache), LRU-bounded: a serving process cycling
# through many per-model cache paths must not grow this without limit,
# and concurrent matmul callers (the engine is threaded) must not race
# the check-then-insert. Mutated only under _PLAN_CACHE_LOCK.
_PLAN_CACHE_MEMO: collections.OrderedDict = collections.OrderedDict()
_PLAN_CACHE_MEMO_MAX = 16
_PLAN_CACHE_LOCK = threading.Lock()


def _load_plan_cache(path: str):
    """The persistent PlanCache a policy names, memoized per path but
    re-loaded whenever the backing file changes on disk — an engine
    pre-warm or ``--autotune`` run persisting new plans mid-process must
    not leave later ``matmul`` calls reading a stale snapshot."""
    from repro.core.autotune import PlanCache
    try:
        mtime = os.stat(path).st_mtime_ns
    except OSError:
        mtime = None
    with _PLAN_CACHE_LOCK:
        hit = _PLAN_CACHE_MEMO.get(path)
        if hit is not None and hit[0] == mtime:
            _PLAN_CACHE_MEMO.move_to_end(path)
            return hit[1]
    # load outside the lock: file I/O + JSON parse must not serialize
    # every other thread's memo hits behind it
    cache = PlanCache.load(path)
    with _PLAN_CACHE_LOCK:
        _PLAN_CACHE_MEMO[path] = (mtime, cache)
        _PLAN_CACHE_MEMO.move_to_end(path)
        while len(_PLAN_CACHE_MEMO) > _PLAN_CACHE_MEMO_MAX:
            _PLAN_CACHE_MEMO.popitem(last=False)
    return cache


def _active_plan_cache(pol: MatmulPolicy):
    """The cache ``matmul`` reads tuned plans from: the ambient registry
    first (an engine tick / default_matmul_precision scope), else the
    policy's own cache path."""
    from repro.core.autotune import active_plan_cache
    cache = active_plan_cache()
    if cache is None and pol.plan_cache is not None:
        cache = _load_plan_cache(pol.plan_cache)
    return cache


def _apply_tuned_plan(cfg, cache, *, m: int, n: int, k: int, batch: int):
    """Fold a cached tuned plan into an OzakiConfig — RESULT-INVARIANT
    fields only (tile shapes + the stages/epilogue/streaming fusion flip,
    all bitwise-neutral per the backend-parity suite), so a cached plan
    can never change what ``matmul`` returns, only how fast it runs."""
    if cache is None:
        return cfg
    from repro.core.autotune import plan_cache_key
    dtype = "float64" if cfg.accum == "f64" else "float32"
    plan = cache.get(plan_cache_key(m, n, k, batch=batch, dtype=dtype,
                                    backend=cfg.backend))
    if plan is None:
        return cfg
    return dataclasses.replace(cfg, tile=plan.tile,
                               fuse_epilogue=(plan.fusion == "epilogue"),
                               streaming=(plan.fusion == "streaming"))


# ----------------------------------------------------------------------------
# The front door
# ----------------------------------------------------------------------------

def matmul(a, b, precision=None):
    """``a @ b`` under a precision policy — the package's one entry point.

    precision: a ``MatmulPolicy``, a spec string (``"ozaki-fp64x9"``,
    ``"bf16"``, ...), or None for the ambient default
    (``default_matmul_precision`` scope, else the paper scheme at the
    auto operating point).

    Dispatch (ozaki scheme) on rank/dtype/DW-ness, to the
    bitwise-verified pipelines:

    * ``DW`` operands          -> the TPU-native df32 entry (df32 out).
    * complex 2-D              -> the 4-mul complex pipeline.
    * 3-D ``a``                -> the batched pipeline; ``b`` may be 3-D
                                  (stacked weights, batch-grid kernel) or
                                  2-D (broadcast weights, rows fold).
    * 2-D f64                  -> the paper path (f64 out).
    * 2-D f32                  -> the df32 pipeline (f32 out) — runs
                                  entirely in {int8, int32, f32}.

    ``b`` is always taken in natural ``(..., k, n)`` orientation — the
    front door transposes for the entries that want ``B^T`` (exact).
    """
    pol = MatmulPolicy.of(precision)
    if pol.scheme == "bf16":
        return _matmul_bf16(a, b)
    if pol.scheme == "int8_quant":
        return _matmul_int8_quant(a, b)
    if pol.scheme == "ozaki2_fp64":
        return _matmul_ozaki2(a, b, pol)
    return _matmul_ozaki_dispatch(a, b, pol)


def _matmul_bf16(a, b):
    """The TPU-native baseline: bf16 operands, f32 accumulation. 2-D
    weights share ``models.layers``' definition of the baseline (one
    source of truth); a stacked 3-D ``b`` needs batched-matmul
    semantics, which the layers projection never has."""
    import jax.numpy as jnp
    if getattr(b, "ndim", 2) == 2:
        from repro.models.layers import _matmul_bf16 as impl
        return impl(a, b, jnp.bfloat16)
    return jnp.matmul(a.astype(jnp.bfloat16), b.astype(jnp.bfloat16),
                      preferred_element_type=jnp.float32)


def _matmul_int8_quant(a, b):
    """Lossy per-channel int8 quantization (what IMMUs were built for)."""
    if getattr(b, "ndim", 2) != 2:
        raise ValueError("int8-quant expects 2-D weights (k, n); got "
                         f"{getattr(b, 'shape', None)}")
    from repro.models.layers import _matmul_int8_quant as impl
    import jax.numpy as jnp
    return impl(a.astype(jnp.float32), b.astype(jnp.float32))


def _apply_tuned_modular_plan(cfg, cache, *, m: int, n: int, k: int,
                              batch: int):
    """Fold a cached Scheme II tuned plan into a ModularConfig — tile
    shapes and the stages<->epilogue fusion flip only (result-invariant:
    the residue GEMMs are exact integer arithmetic under any tiling and
    the fused-CRT epilogue replays the reference rounding sequence)."""
    if cache is None:
        return cfg
    from repro.core.autotune import plan_cache_key
    plan = cache.get(plan_cache_key(m, n, k, batch=batch, dtype="float64",
                                    accum="f64", backend=cfg.backend,
                                    scheme="ozaki2_fp64"))
    if plan is None or getattr(plan, "scheme", "ozaki_fp64") != \
            "ozaki2_fp64":
        return cfg
    return dataclasses.replace(cfg, tile=plan.tile,
                               fuse_epilogue=(plan.fusion == "epilogue"))


def _matmul_ozaki2(a, b, pol: MatmulPolicy):
    """Scheme II dispatch: residue-system int8 GEMMs + CRT.

    float64 is the native route; complex128 decomposes into three or
    four real residue GEMMs (``ozaki2_matmul_complex``) and float32
    reconstructs through the double-float32 CRT target
    (``ozaki2_matmul_df32``). DW operands raise — the Scheme I DW
    pipeline is a different algorithm than the policy named.
    """
    import jax.numpy as jnp

    from repro.core.modular import (ozaki2_matmul, ozaki2_matmul_batched,
                                    ozaki2_matmul_complex,
                                    ozaki2_matmul_df32)
    from repro.core.xmath import DW

    if isinstance(a, DW) or isinstance(b, DW):
        raise TypeError("ozaki2-fp64 has no DW path (the CRT "
                        "reconstruction is FP64); use scheme 'ozaki-fp64'")
    if jnp.issubdtype(a.dtype, jnp.complexfloating) or \
            jnp.issubdtype(b.dtype, jnp.complexfloating):
        if a.ndim != 2 or b.ndim != 2:
            raise ValueError(f"complex operands must be 2-D, got "
                             f"{a.shape} @ {b.shape}")
        return ozaki2_matmul_complex(a, b, pol.modular_config())
    if a.dtype != b.dtype:
        raise TypeError(f"dtype mismatch: {a.dtype} @ {b.dtype}")
    if a.dtype == jnp.float32:
        if a.ndim != 2 or b.ndim != 2:
            raise ValueError(f"float32 Scheme II operands must be 2-D, "
                             f"got {a.shape} @ {b.shape}")
        return ozaki2_matmul_df32(a, b, pol.modular_config())
    if a.dtype != jnp.float64:
        raise TypeError(f"ozaki2-fp64 runs on float64/float32/complex128 "
                        f"operands, got {a.dtype}")
    cfg = pol.modular_config()
    cache = _active_plan_cache(pol)
    if a.ndim == 3:
        bsz, m, k = a.shape
        cfg = _apply_tuned_modular_plan(cfg, cache, m=m, n=b.shape[-1],
                                        k=k, batch=bsz)
        return ozaki2_matmul_batched(a, b, cfg)
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError(f"matmul expects 2-D or 3-D operands, got "
                         f"{a.shape} @ {b.shape}")
    m, k = a.shape
    if pol.shard_axis:
        from repro.parallel.ozaki_shard import (active_shard_mesh,
                                                constrain_batched_kshard,
                                                distributed_ozaki2_matmul)
        mesh = active_shard_mesh()
        if pol.comm == "int8" and mesh is not None and \
                pol.shard_axis in mesh.axis_names and \
                k % mesh.shape[pol.shard_axis] == 0:
            # |comm=int8: the residue-wire collective schedule — exact
            # int32 psum/reduce-scatter of the per-modulus residue
            # partials, bitwise-identical to the unsharded reference
            # for any mesh shape.
            return distributed_ozaki2_matmul(a, b, mesh, cfg,
                                             axis=pol.shard_axis)
        # mirror Scheme I's composition point: pin the reduction dim to
        # the registered shard mesh; silently a no-op without a mesh.
        a, b = constrain_batched_kshard(a, b, pol.shard_axis)
    cfg = _apply_tuned_modular_plan(cfg, cache, m=m, n=b.shape[1], k=k,
                                    batch=1)
    return ozaki2_matmul(a, b, cfg)


def _matmul_ozaki_dispatch(a, b, pol: MatmulPolicy):
    import jax.numpy as jnp

    from repro.core.ozaki import (ozaki_matmul, ozaki_matmul_batched,
                                  ozaki_matmul_complex, ozaki_matmul_dw)
    from repro.core.xmath import DW

    if isinstance(a, DW) or isinstance(b, DW):
        if not (isinstance(a, DW) and isinstance(b, DW)):
            raise TypeError("DW matmul needs both operands as DW")
        k = a.hi.shape[-1]
        cfg = pol.ozaki_config(k, accum="df32")
        b_t = DW(b.hi.T, b.lo.T)               # exact: a permutation
        cfg = _apply_tuned_plan(cfg, _active_plan_cache(pol),
                                m=a.hi.shape[0], n=b.hi.shape[1], k=k,
                                batch=1)
        return ozaki_matmul_dw(a, b_t, cfg)

    if jnp.issubdtype(a.dtype, jnp.complexfloating):
        if a.ndim != 2 or b.ndim != 2:
            raise ValueError(f"complex operands must be 2-D, got "
                             f"{a.shape} @ {b.shape}")
        cfg = pol.ozaki_config(a.shape[1], accum="f64")
        return ozaki_matmul_complex(a, b, cfg)

    # the front door validates what the internal entry points assumed:
    # matching float operands (accuracy silently degrading to the f32
    # pipeline because ONE operand was f32 is exactly the surprise a
    # precision-policy API exists to prevent)
    if a.dtype != b.dtype:
        raise TypeError(f"dtype mismatch: {a.dtype} @ {b.dtype}")
    if a.dtype not in (jnp.float32, jnp.float64):
        raise TypeError(f"matmul supports float32/float64/complex128/DW "
                        f"operands, got {a.dtype}")

    if a.ndim == 3:
        # shard_axis on the batched path: structural no-op, exactly like
        # models/layers (in-scan 3-D constraints trip an XLA SPMD bug on
        # the pinned jax — see ROADMAP; sharded batched GEMMs are served
        # by parallel.ozaki_shard.ozaki_matmul_kshard_auto).
        bsz, m, k = a.shape
        accum = "f64" if a.dtype == jnp.float64 else "df32"
        cfg = pol.ozaki_config(k, accum=accum)
        cfg = _apply_tuned_plan(cfg, _active_plan_cache(pol),
                                m=m, n=b.shape[-1], k=k, batch=bsz)
        return ozaki_matmul_batched(a, b, cfg)

    if a.ndim != 2 or b.ndim != 2:
        raise ValueError(f"matmul expects 2-D or 3-D operands, got "
                         f"{a.shape} @ {b.shape}")
    m, k = a.shape
    n = b.shape[-1]
    if pol.shard_axis:
        from repro.parallel.ozaki_shard import (active_shard_mesh,
                                                constrain_batched_kshard,
                                                distributed_ozaki_matmul)
        mesh = active_shard_mesh()
        if pol.comm == "int8" and mesh is not None and \
                pol.shard_axis in mesh.axis_names and \
                a.dtype == jnp.float64 and \
                k % mesh.shape[pol.shard_axis] == 0:
            # |comm=int8: run the explicit int8-slice collective
            # schedule instead of GSPMD f64-operand sharding — exact
            # int32 psum of the pair partials, bitwise-identical to the
            # unsharded reference for any mesh shape.
            cfg = pol.ozaki_config(k, accum="f64")
            return distributed_ozaki_matmul(a, b, mesh, cfg,
                                            axis=pol.shard_axis)
        # same composition point as models/layers: pin the reduction dim
        # to the registered shard mesh on plain 2-D calls (the path
        # verified bitwise-safe); silently a no-op without a mesh.
        a, b = constrain_batched_kshard(a, b, pol.shard_axis)
    cache = _active_plan_cache(pol)
    if a.dtype == jnp.float64:
        cfg = _apply_tuned_plan(pol.ozaki_config(k, accum="f64"), cache,
                                m=m, n=n, k=k, batch=1)
        return ozaki_matmul(a, b, cfg)
    # f32: the TPU-native df32 pipeline ({int8, int32, f32} only)
    from repro.core.xmath import dw_to_single
    cfg = _apply_tuned_plan(pol.ozaki_config(k, accum="df32"), cache,
                            m=m, n=n, k=k, batch=1)
    out = ozaki_matmul_dw(DW(a, jnp.zeros_like(a)),
                          DW(b.T, jnp.zeros_like(b.T)), cfg)
    return dw_to_single(out)


# ----------------------------------------------------------------------------
# Legacy-config interop (ArchConfig's ozaki_* fields, engine kwargs)
# ----------------------------------------------------------------------------

def policy_from_legacy_fields(cfg, scheme: Optional[str] = None
                              ) -> MatmulPolicy:
    """Derive a MatmulPolicy from legacy ``ozaki_*``-style fields
    (duck-typed: missing fields take their legacy defaults). Non-ozaki
    schemes drop the ozaki knobs — they configure nothing there.
    ``scheme`` overrides ``cfg.matmul_precision`` (the legacy engine
    kwarg semantics: switching scheme keeps the config's ozaki knobs)."""
    if scheme is None:
        scheme = getattr(cfg, "matmul_precision", "ozaki_fp64")
    if scheme != "ozaki_fp64":
        return MatmulPolicy(scheme=scheme)
    return MatmulPolicy(
        scheme="ozaki_fp64",
        backend=getattr(cfg, "ozaki_backend", "xla"),
        num_splits=getattr(cfg, "ozaki_splits", 9),
        fuse_epilogue=getattr(cfg, "ozaki_fuse_epilogue", False),
        target_error=getattr(cfg, "ozaki_target_error", 0.0) or None,
        fast_mode=getattr(cfg, "ozaki_fast_mode", False),
        shard_axis=getattr(cfg, "ozaki_shard_axis", "") or None,
        plan_cache=getattr(cfg, "ozaki_plan_cache", "") or None,
        autotune=getattr(cfg, "ozaki_autotune", False))


def policy_of(cfg) -> MatmulPolicy:
    """The MatmulPolicy a config-like object resolves to: its
    ``matmul_policy`` spec when set, else the legacy-field derivation."""
    spec = getattr(cfg, "matmul_policy", "")
    if spec:
        return MatmulPolicy.parse(spec)
    return policy_from_legacy_fields(cfg)


# names the legacy serving-engine kwargs carry -> policy fields ("" and
# 0.0 are the legacy "unset" spellings for shard_axis / target_error)
_LEGACY_OVERRIDE_FIELDS = {
    "ozaki_backend": ("backend", lambda v: v),
    "ozaki_fuse_epilogue": ("fuse_epilogue", lambda v: v),
    "ozaki_shard_axis": ("shard_axis", lambda v: v or None),
    "ozaki_target_error": ("target_error", lambda v: v or None),
    "ozaki_fast_mode": ("fast_mode", lambda v: v),
}


def merge_legacy_overrides(cfg, overrides: dict) -> MatmulPolicy:
    """Apply legacy per-knob override kwargs on top of a config's
    resolved policy, as ONE merged policy.

    This preserves spec-only knobs the legacy fields cannot express
    (``pair_policy``, an auto split count, a plan-cache path carried in
    the spec): ``ServingEngine(cfg_with_policy, ozaki_fast_mode=True)``
    keeps the config's policy and flips only ``fast_mode``, instead of
    discarding the spec. A ``matmul_precision`` override switches the
    scheme; switching ONTO ozaki seeds the ozaki knobs from the config's
    legacy fields (the pre-policy engine semantics)."""
    pol = policy_of(cfg)
    scheme = overrides.get("matmul_precision", pol.scheme)
    if scheme != "ozaki_fp64":
        return MatmulPolicy(scheme=scheme)
    if pol.scheme != "ozaki_fp64":
        pol = policy_from_legacy_fields(cfg, scheme="ozaki_fp64")
    kw = {field: conv(overrides[name])
          for name, (field, conv) in _LEGACY_OVERRIDE_FIELDS.items()
          if name in overrides}
    return dataclasses.replace(pol, **kw) if kw else pol
