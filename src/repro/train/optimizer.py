"""AdamW + schedules + gradient clipping (pytree-native, no optax).

Optimizer state is a pytree congruent with the params, so the same
sharding specs apply (moments shard exactly like their parameter).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # scalar int32
    mu: Any                  # first moment, like params
    nu: Any                  # second moment, like params


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0


def adamw_init(params: Any, moment_dtype=None) -> AdamWState:
    """``moment_dtype``: e.g. bf16 moments to fit HBM at the 235B scale."""
    def z(p):
        return jnp.zeros(p.shape, moment_dtype or jnp.float32)
    return AdamWState(jnp.int32(0), jax.tree.map(z, params),
                      jax.tree.map(z, params))


def cosine_lr(oc: OptimizerConfig, step: jax.Array) -> jax.Array:
    warm = step.astype(jnp.float32) / max(1, oc.warmup_steps)
    prog = (step - oc.warmup_steps).astype(jnp.float32) / max(
        1, oc.total_steps - oc.warmup_steps)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    decayed = oc.min_lr_ratio + (1 - oc.min_lr_ratio) * cos
    return oc.peak_lr * jnp.where(step < oc.warmup_steps,
                                  jnp.clip(warm, 0.0, 1.0), decayed)


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads: Any, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(grads: Any, state: AdamWState, params: Any,
                 oc: OptimizerConfig):
    """-> (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, oc.grad_clip_norm)
    step = state.step + 1
    lr = jnp.asarray(cosine_lr(oc, step), jnp.float32)
    b1t = 1.0 - oc.b1 ** step.astype(jnp.float32)
    b2t = 1.0 - oc.b2 ** step.astype(jnp.float32)
    b1t = jnp.asarray(b1t, jnp.float32)
    b2t = jnp.asarray(b2t, jnp.float32)

    def upd(p, g, m, v):
        # Update math runs in the MOMENT dtype: f32 normally; fully-bf16
        # when the config chose bf16 moments (the 235B single-pod fit) —
        # f32 math there would materialize f32 copies of every parameter
        # leaf (observed +7 GiB/chip on the dry-run).
        wdt = m.dtype
        g = g.astype(wdt)
        m = (oc.b1 * m + (1 - oc.b1) * g).astype(wdt)
        v = (oc.b2 * v + (1 - oc.b2) * jnp.square(g)).astype(wdt)
        mh = m / b1t.astype(wdt)
        vh = v / b2t.astype(wdt)
        delta = mh / (jnp.sqrt(vh) + jnp.asarray(oc.eps, wdt)) + \
            jnp.asarray(oc.weight_decay, wdt) * p.astype(wdt)
        return ((p - (lr.astype(wdt) * delta).astype(p.dtype)),
                m, v)

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda o: isinstance(o, tuple))
    new_mu = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda o: isinstance(o, tuple))
    new_nu = jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda o: isinstance(o, tuple))
    return new_params, AdamWState(step, new_mu, new_nu), \
        {"lr": lr, "grad_norm": gnorm}
