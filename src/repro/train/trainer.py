"""Training step: loss, grads, microbatch accumulation, jit + sharding.

``make_train_step`` builds the jitted (params, opt_state, batch) ->
(params, opt_state, metrics) function with in/out shardings from the
``ShardingPlan`` and donated argnums for in-place buffer reuse.

Loss: next-token cross-entropy (in f32) + z-loss + any model aux losses
(MoE load-balance / router-z). Gradient accumulation scans over
microbatches so the activation peak is one microbatch.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import forward_train
from repro.train.optimizer import (AdamWState, OptimizerConfig, adamw_init,
                                   adamw_update)

Z_LOSS_COEF = 1e-4


def _shift_labels(tokens: jax.Array) -> tuple[jax.Array, jax.Array]:
    """labels[t] = tokens[t+1]; mask out the last position."""
    labels = jnp.roll(tokens, -1, axis=1)
    mask = jnp.ones(tokens.shape[:2], jnp.float32).at[:, -1].set(0.0)
    return labels, mask


def loss_fn(cfg, params, batch) -> tuple[jax.Array, dict]:
    logits, aux = forward_train(cfg, params, batch)
    tokens = batch["tokens"]
    if cfg.frontend == "vision":
        # loss on the text positions only (patches occupy the prefix)
        logits = logits[:, -tokens.shape[1]:]
    labels, mask = _shift_labels(tokens)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    if cfg.frontend == "audio":
        ll = jnp.take_along_axis(logits, labels[..., None],
                                 axis=-1)[..., 0]           # (b, s, nq)
        nll = (logz - ll).mean(axis=-1)
        zsq = jnp.square(logz).mean(axis=-1)
        mask = mask[..., 0] if mask.ndim == 3 else mask
    else:
        ll = jnp.take_along_axis(logits, labels[..., None],
                                 axis=-1)[..., 0]           # (b, s)
        nll = logz - ll
        zsq = jnp.square(logz)
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = (nll * mask).sum() / denom
    z_loss = Z_LOSS_COEF * (zsq * mask).sum() / denom
    total = ce + z_loss + aux
    return total, {"ce": ce, "z_loss": z_loss, "aux": aux}


def split_microbatches(batch: dict, n: int) -> dict:
    """HOST-side reshape to the (grad_accum, batch/ga, ...) layout.

    The leading accumulation dim must exist *before* jit so the
    microbatch dim keeps its data-axis sharding — reshaping a sharded
    batch inside jit lets GSPMD replicate it (observed 16x FLOP blowup).
    """
    return {k: v.reshape((n, v.shape[0] // n) + v.shape[1:])
            for k, v in batch.items()}


def train_step(cfg, oc: OptimizerConfig, params, opt_state: AdamWState,
               batch: dict, grad_accum: int = 1):
    """One optimizer step (pure; jit-wrapped by ``make_train_step``).

    With grad_accum > 1 the batch leaves must already carry the leading
    accumulation dim (see ``split_microbatches``).
    """
    grad_of = jax.value_and_grad(
        lambda p, b: loss_fn(cfg, p, b), has_aux=True)

    if grad_accum == 1:
        (loss, metrics), grads = grad_of(params, batch)
    else:
        def body(carry, mb):
            acc, _ = carry
            (l, m), g = grad_of(params, mb)
            acc = jax.tree.map(jnp.add, acc, g)
            return (acc, l), m

        # accumulate in f32 for f32 params; bf16-param configs (the 235B
        # single-pod layout) accumulate in bf16 to avoid carrying an
        # extra full-f32 parameter-sized buffer through the loop
        def acc_dtype(p):
            return p.dtype if p.dtype == jnp.bfloat16 else jnp.float32

        zero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, acc_dtype(p)), params)
        (grads, loss), ms = jax.lax.scan(body, (zero, jnp.float32(0)),
                                         batch)
        grads = jax.tree.map(lambda g: g / grad_accum, grads)
        metrics = jax.tree.map(lambda m: m[-1], ms)

    new_params, new_opt, opt_metrics = adamw_update(grads, opt_state,
                                                    params, oc)
    metrics = dict(metrics) | opt_metrics | {"loss": loss}
    return new_params, new_opt, metrics


def _param_shardings(plan):
    from jax.sharding import NamedSharding, PartitionSpec
    ns = lambda spec: NamedSharding(plan.mesh, spec)
    return jax.tree.map(ns, plan.param_specs,
                        is_leaf=lambda s: isinstance(s, PartitionSpec))


def make_train_step(cfg, oc: OptimizerConfig, plan, grad_accum: int = 1):
    """jit with shardings from the plan; params/opt donated.

    With grad_accum > 1, feed batches through ``split_microbatches``.
    """
    from jax.sharding import NamedSharding, PartitionSpec
    from repro.parallel.sharding import wrap_with_sharding

    p_sh = _param_shardings(plan)
    rep = NamedSharding(plan.mesh, PartitionSpec())
    opt_sh = AdamWState(rep, p_sh, p_sh)

    def bspec(spec):
        if grad_accum > 1:
            spec = PartitionSpec(None, *spec)
        return NamedSharding(plan.mesh, spec)

    b_sh = {k: bspec(v) for k, v in plan.batch_specs.items()}

    fn = wrap_with_sharding(
        functools.partial(train_step, cfg, oc, grad_accum=grad_accum),
        plan.mesh, plan.rules)
    return jax.jit(fn,
                   in_shardings=(p_sh, opt_sh, b_sh),
                   out_shardings=(p_sh, opt_sh, None),
                   donate_argnums=(0, 1))


def init_training(cfg, key, plan=None):
    """(params, axes, opt_state) — sharded when a plan is given."""
    from repro.models import init_model
    if plan is None:
        params, axes = init_model(cfg, key)
        return params, axes, adamw_init(params)
    from jax.sharding import NamedSharding, PartitionSpec
    p_sh = _param_shardings(plan)
    axes_box = {}

    def params_only(k):
        p, a = init_model(cfg, k)
        axes_box["axes"] = a
        return p

    init_fn = jax.jit(params_only, out_shardings=p_sh)
    params = init_fn(key)
    rep = NamedSharding(plan.mesh, PartitionSpec())
    opt = jax.jit(adamw_init, out_shardings=AdamWState(
        rep, p_sh, p_sh))(params)
    return params, axes_box["axes"], opt
