"""Sharded checkpointing: manifest + per-leaf npy, async save, elastic
restore.

Layout of one checkpoint::

    <dir>/step_0000042/
        MANIFEST.json       # tree paths, shapes, dtypes, specs, cursor,
                            # mesh shape, integrity sizes
        arrays/<flat-key>.npy

Writes are atomic (tmp dir + rename); ``save`` can run asynchronously on
a writer thread after the arrays are fetched to host. ``restore`` puts
each leaf back with the *target* sharding — the manifest stores logical
PartitionSpecs, but the caller decides the mesh, so a job restarted on a
different topology (elastic re-scale) restores transparently.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Callable, Optional

import jax
import numpy as np

_SEP = "."


def _flatten(tree: Any) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def _to_storable(arr: np.ndarray) -> np.ndarray:
    """numpy cannot round-trip ml_dtypes (bf16 etc.) through .npy —
    store them as a same-width unsigned view; restore() views back using
    the manifest dtype."""
    if arr.dtype.kind == "V" or arr.dtype.name in ("bfloat16", "float8_e4m3",
                                                   "float8_e5m2"):
        return arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
    return arr


def save(ckpt_dir: str, step: int, tree: Any, *, meta: Optional[dict] = None,
         async_write: bool = False,
         keep_last: int = 3) -> "threading.Thread | None":
    """Write a checkpoint. Returns the writer thread if async."""
    flat = _flatten(tree)
    host = {k: np.asarray(v) for k, v in flat.items()}   # fetch (sync point)
    manifest = {
        "step": int(step),
        "keys": sorted(host),
        "shapes": {k: list(v.shape) for k, v in host.items()},
        "dtypes": {k: str(v.dtype) for k, v in host.items()},
        "nbytes": {k: int(v.nbytes) for k, v in host.items()},
        "meta": meta or {},
    }

    def _write():
        final = os.path.join(ckpt_dir, f"step_{step:07d}")
        tmp = final + ".tmp"
        os.makedirs(os.path.join(tmp, "arrays"), exist_ok=True)
        for k, v in host.items():
            np.save(os.path.join(tmp, "arrays", k + ".npy"),
                    _to_storable(v))
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.isdir(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(ckpt_dir, keep_last)

    if async_write:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _gc(ckpt_dir: str, keep_last: int) -> None:
    steps = all_steps(ckpt_dir)
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:07d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name,
                                             "MANIFEST.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def load_manifest(ckpt_dir: str, step: int) -> dict:
    with open(os.path.join(ckpt_dir, f"step_{step:07d}",
                           "MANIFEST.json")) as f:
        return json.load(f)


def restore(ckpt_dir: str, step: int, target_tree: Any,
            sharding_fn: Optional[Callable[[str], Any]] = None) -> Any:
    """Rebuild ``target_tree``'s structure from disk.

    ``target_tree``: pytree of arrays or ShapeDtypeStructs (structure +
    dtypes must match the save). ``sharding_fn(flat_key)`` -> Sharding for
    elastic placement; None keeps default device placement.
    """
    base = os.path.join(ckpt_dir, f"step_{step:07d}")
    manifest = load_manifest(ckpt_dir, step)
    flat_target = _flatten(target_tree)
    missing = set(flat_target) - set(manifest["keys"])
    if missing:
        raise ValueError(f"checkpoint lacks keys: {sorted(missing)[:5]}...")
    out = {}
    for k, tgt in flat_target.items():
        arr = np.load(os.path.join(base, "arrays", k + ".npy"))
        want = manifest["dtypes"][k]
        if arr.dtype.name != want:          # bf16 etc. stored as uint view
            import ml_dtypes
            arr = arr.view(np.dtype(want))
        if list(arr.shape) != list(tgt.shape):
            raise ValueError(f"shape mismatch for {k}: "
                             f"{arr.shape} vs {tgt.shape}")
        if sharding_fn is not None:
            out[k] = jax.device_put(arr, sharding_fn(k))
        else:
            out[k] = jax.device_put(arr.astype(tgt.dtype))
    # unflatten back into the target structure
    leaves_order = [out[k] for k in
                    (_SEP.join(_path_str(p) for p in path)
                     for path, _ in
                     jax.tree_util.tree_flatten_with_path(target_tree)[0])]
    treedef = jax.tree_util.tree_structure(target_tree)
    return jax.tree_util.tree_unflatten(treedef, leaves_order)


def verify(ckpt_dir: str, step: int) -> bool:
    """Integrity check: every manifest key exists with the right size."""
    base = os.path.join(ckpt_dir, f"step_{step:07d}")
    manifest = load_manifest(ckpt_dir, step)
    for k in manifest["keys"]:
        p = os.path.join(base, "arrays", k + ".npy")
        if not os.path.exists(p):
            return False
        arr = np.load(p, mmap_mode="r")
        if int(arr.nbytes) != manifest["nbytes"][k]:
            return False
    return True
