"""Deterministic data pipeline: synthetic LM tokens + memmap corpus.

Determinism contract (used by the fault-tolerance tests): batch contents
are a pure function of (seed, step, arch shape) — a restarted job that
resumes from step N sees byte-identical batches from step N on, for any
host count. Per-host sharding slices the global batch by process index.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    num_codebooks: int = 0       # audio archs: tokens (b, s, nq)
    num_patches: int = 0         # vlm archs: extra patch embeddings
    d_model: int = 0             # for patch embedding stub width
    memmap_path: Optional[str] = None


class TokenSource:
    """Synthetic Zipf-ish token stream, or a memmapped corpus window."""

    def __init__(self, dc: DataConfig):
        self.dc = dc
        self._mm = None
        if dc.memmap_path:
            self._mm = np.memmap(dc.memmap_path, dtype=np.int32, mode="r")

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.dc.seed, step]))

    def batch_at(self, step: int, *, host_index: int = 0,
                 host_count: int = 1) -> dict:
        dc = self.dc
        assert dc.global_batch % host_count == 0
        local_b = dc.global_batch // host_count
        rng = self._rng(step)
        shape = (dc.global_batch, dc.seq_len)
        if dc.num_codebooks:
            shape = shape + (dc.num_codebooks,)
        if self._mm is not None:
            max_start = len(self._mm) - dc.seq_len - 1
            starts = rng.integers(0, max_start, size=dc.global_batch)
            tokens = np.stack([
                np.asarray(self._mm[s:s + dc.seq_len]) for s in starts])
            tokens = tokens % dc.vocab_size
            if dc.num_codebooks:
                tokens = np.repeat(tokens[..., None], dc.num_codebooks, -1)
        else:
            # Zipf-distributed ids (realistic logit scale), deterministic
            z = rng.zipf(1.3, size=shape).astype(np.int64)
            tokens = (z % dc.vocab_size).astype(np.int32)
        lo = host_index * local_b
        batch = {"tokens": tokens[lo:lo + local_b].astype(np.int32)}
        if dc.num_patches:
            emb = rng.standard_normal(
                (dc.global_batch, dc.num_patches, dc.d_model),
                dtype=np.float32)
            batch["patch_embeds"] = emb[lo:lo + local_b]
            batch["tokens"] = batch["tokens"][:, :dc.seq_len - dc.num_patches]
        return batch

    def iterate(self, start_step: int = 0, *, host_index: int = 0,
                host_count: int = 1) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch_at(step, host_index=host_index,
                                host_count=host_count)
            step += 1


def make_data(cfg, seq_len: int, global_batch: int, seed: int = 1234,
              memmap_path: Optional[str] = None) -> TokenSource:
    """TokenSource matching an ArchConfig's input contract."""
    return TokenSource(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=seq_len,
        global_batch=global_batch, seed=seed,
        num_codebooks=cfg.num_codebooks if cfg.frontend == "audio" else 0,
        num_patches=cfg.num_patches if cfg.frontend == "vision" else 0,
        d_model=cfg.d_model, memmap_path=memmap_path))


def write_corpus(path: str, num_tokens: int, vocab: int,
                 seed: int = 7) -> None:
    """Materialize a synthetic corpus for the memmap loader."""
    rng = np.random.default_rng(seed)
    arr = (rng.zipf(1.3, size=num_tokens) % vocab).astype(np.int32)
    arr.tofile(path)
