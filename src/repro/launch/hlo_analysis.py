"""Post-optimization HLO analyzer: FLOPs, HBM traffic, collective bytes.

Why not ``compiled.cost_analysis()``: XLA's cost analysis visits a while
body ONCE — a 94-layer scanned model under-reports by 94x (verified
empirically; see EXPERIMENTS.md §Dry-run notes). This parser walks the
call graph from ENTRY, multiplies through ``while`` trip counts (scan
loops carry ``compare(iter, constant), direction=LT`` conditions), and
accumulates:

  * dot FLOPs, split by operand dtype (bf16/f32 vs int8 — they hit
    different peak numbers on the MXU);
  * a fusion-level HBM traffic model: every top-level op moves
    (operand bytes + result bytes), matching XLA's "one read per input,
    one write per output" fusion contract (fusion *bodies* are walked
    for FLOPs/collectives but add no extra traffic);
  * per-kind collective bytes and ring-model link bytes per chip.

Optimized HLO operands are *names only* (``dot(%a, %b)``), so each
computation keeps a symbol table name -> result type built from the
defining lines (parameters included).

The compiled module is already SPMD-partitioned, so every shape is
per-device — exactly what the roofline terms need.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 0.5, "u4": 0.5,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
_OPCODE_RE = re.compile(r"\b([a-z][a-z0-9\-]*)\(")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_CALLED_LIST_RE = re.compile(
    r"(calls|to_apply|body|condition|branch_computations)="
    r"(\{[^}]*\}|%?[\w.\-]+)")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_CONST_RE = re.compile(r"=\s*s(?:32|64)\[\]\s*constant\((\d+)\)")
_NAME_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_NO_TRAFFIC = {"parameter", "get-tuple-element", "tuple", "constant",
               "bitcast", "copy-done", "after-all", "partition-id",
               "replica-id", "iota", "reshape"}

# Ops XLA:TPU fuses into element-per-element kernels. CPU-compiled HLO
# keeps softmax-style chains as MANY small fusions; counting each would
# model a 5-10x HBM pessimism the TPU backend doesn't have, so chains of
# single-consumer fusable ops are merged into "super fusions" and charged
# only at their boundaries (one read per external input, one write per
# externally-used output).
_FUSABLE = {"add", "subtract", "multiply", "divide", "maximum", "minimum",
            "exponential", "exponential-minus-one", "tanh", "negate",
            "abs", "power", "rsqrt", "sqrt", "log", "log-plus-one",
            "select", "compare", "and", "or", "not", "xor", "convert",
            "broadcast", "clamp", "floor", "ceil", "round-nearest-even",
            "sign", "reduce", "transpose", "slice", "pad", "copy",
            "reverse", "rem", "shift-right-logical", "shift-left",
            "shift-right-arithmetic", "is-finite", "atan2", "expm1",
            "log1p", "cosine", "sine", "reduce-window"}


def _shape_bytes(text: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems_dt(text: str) -> Optional[tuple[str, list[int]]]:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    result: str       # result type text (before the opcode token)
    args: str         # inside the call parens (operand names)
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    ops: list
    constants: dict   # op name -> int (s32 scalar constants)
    types: dict       # op name -> result type text
    root_opcode: str = ""


def parse_computations(hlo: str) -> tuple[dict, Optional[str]]:
    comps: dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        m = _COMP_RE.match(line)
        if m:
            cur = Computation(m.group(2), bool(m.group(1)), [], {}, {})
            comps[cur.name] = cur
            if cur.is_entry:
                entry = cur.name
            continue
        if cur is None:
            continue
        if line.strip().startswith("}"):
            cur = None
            continue
        s = line.strip()
        if not s.startswith(("%", "ROOT")):
            continue
        eq = s.find(" = ")
        if eq < 0:
            continue
        is_root = s.startswith("ROOT")
        name = s[:eq].replace("ROOT", "").strip().lstrip("%")
        rest = s[eq + 3:]
        om = _OPCODE_RE.search(rest)
        if not om:
            continue
        opcode = om.group(1)
        result = rest[:om.start()]
        depth = 0
        args_end = om.end() - 1
        for i in range(om.end() - 1, len(rest)):
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
                if depth == 0:
                    args_end = i
                    break
        args = rest[om.end():args_end]
        cur.ops.append(Op(name, opcode, result, args, s))
        cur.types[name] = result
        if is_root:
            cur.root_opcode = opcode
        cm = _CONST_RE.search(s)
        if cm:
            cur.constants[name] = int(cm.group(1))
    return comps, entry


def _called_comps(line: str) -> list[tuple[str, str]]:
    out = []
    for m in _CALLED_LIST_RE.finditer(line):
        kind, val = m.group(1), m.group(2)
        if val.startswith("{"):
            for name in val.strip("{}").split(","):
                out.append((kind, name.strip().lstrip("%")))
        else:
            out.append((kind, val.lstrip("%")))
    return out


def _while_trip_count(cond: Computation) -> int:
    for op in cond.ops:
        if op.opcode == "compare" and "direction=LT" in op.line:
            for a in _NAME_RE.findall(op.args):
                if a in cond.constants:
                    return cond.constants[a]
    if cond.constants:
        return max(cond.constants.values())
    return 1


def _operand_types(op: Op, comp: Computation) -> list[str]:
    return [comp.types.get(a, "") for a in _NAME_RE.findall(op.args)]


def _dot_flops(op: Op, comp: Computation) -> tuple[float, str]:
    res = _shape_elems_dt(op.result)
    operands = _operand_types(op, comp)
    lhs = _shape_elems_dt(operands[0]) if operands else None
    if res is None or lhs is None:
        return 0.0, "f32"
    lhs_dt, lhs_dims = lhs
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    k = 1
    if cm and cm.group(1):
        for idx in cm.group(1).split(","):
            k *= lhs_dims[int(idx)]
    n_out = 1
    for d in res[1]:
        n_out *= d
    return 2.0 * n_out * k, lhs_dt


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m2 = _GROUPS_LIST_RE.search(line)
    return len(m2.group(1).split(",")) if m2 else 1


def _collective_stats(op: Op) -> tuple[str, float, int]:
    """(kind, payload bytes = FULL reduced/gathered tensor, group size)."""
    kind = op.opcode[:-6] if op.opcode.endswith("-start") else op.opcode
    gsize = _group_size(op.line)
    res = _shape_bytes(op.result)
    if kind == "reduce-scatter":
        payload = res * gsize            # operand = result x group
    else:
        payload = res                    # AR/AG/A2A/CP: result-sized
    return kind, payload, gsize


def _link_bytes(kind: str, payload: float, gsize: int) -> float:
    """Ring-model per-chip link traffic for one collective."""
    if gsize <= 1:
        return 0.0
    f = (gsize - 1) / gsize
    if kind == "all-reduce":
        return 2.0 * f * payload
    if kind in ("all-gather", "reduce-scatter", "all-to-all"):
        return f * payload
    if kind == "collective-permute":
        return payload
    return 0.0


@dataclasses.dataclass
class HLOStats:
    dot_flops: dict                  # operand dtype -> flops (per chip)
    hbm_bytes: float                 # traffic model (per chip)
    collective_bytes: dict           # kind -> payload bytes
    collective_link_bytes: float     # ring-model per-chip link bytes
    collective_counts: dict          # kind -> dynamic op count

    @property
    def total_flops(self) -> float:
        return float(sum(self.dot_flops.values()))

    @property
    def int_flops(self) -> float:
        # any signed/unsigned integer operand dtype counts as IMMU work —
        # including s32: XLA:CPU lowers an int8 dot as convert + s32 dot.
        return float(sum(v for k, v in self.dot_flops.items()
                         if k.startswith(("s", "u"))))


def analyze(hlo: str) -> HLOStats:
    comps, entry = parse_computations(hlo)
    if entry is None:
        entry = next((n for n in comps if "main" in n), None) or \
            next(iter(comps))

    flops = defaultdict(float)
    coll_bytes = defaultdict(float)
    coll_counts = defaultdict(float)
    totals = {"hbm": 0.0, "link": 0.0}
    stack: list[str] = []

    def _is_fusable(op: Op) -> bool:
        if op.opcode in _FUSABLE:
            return True
        if op.opcode == "fusion":
            called = dict(_called_comps(op.line))
            body = comps.get(called.get("calls", ""))
            has_dus = body is not None and any(
                o.opcode in ("dynamic-update-slice", "scatter")
                for o in body.ops)
            return not has_dus
        if op.opcode == "call":
            # XLA:CPU wraps fused elementwise expressions in `call`s to
            # parallel_* computations (thread-level parallelism). Treat a
            # call whose body is elementwise/reduction-only as a fusion so
            # softmax-style chains merge across the calls; calls hiding
            # in-place updates or contractions keep their own traffic
            # (including DUS/scatter nested inside a fusion in the body —
            # e.g. a KV-cache update — which must keep the 2x-slice model).
            called = dict(_called_comps(op.line))
            body = comps.get(called.get("to_apply", ""))
            if body is None:
                return False
            forbidden = ("dynamic-update-slice", "scatter", "dot",
                         "convolution")
            for o in body.ops:
                if o.opcode in forbidden + ("while", "call"):
                    return False
                if o.opcode == "fusion":
                    fused = comps.get(
                        dict(_called_comps(o.line)).get("calls", ""))
                    if fused is not None and any(
                            oo.opcode in forbidden for oo in fused.ops):
                        return False
            return True
        return False

    def _comp_traffic(comp: Computation) -> float:
        """HBM bytes per execution of one computation's top-level ops.

        Single-consumer chains of fusable ops are merged (union-find)
        and charged at the super-fusion boundary only. In-place patterns
        (DUS/gather/scatter/dynamic-slice and DUS fusions) move only the
        touched slice.
        """
        ops_by_name = {o.name: o for o in comp.ops}
        consumers: dict = defaultdict(list)
        for op in comp.ops:
            if op.opcode in ("parameter", "constant"):
                continue
            for a in _NAME_RE.findall(op.args):
                if a in ops_by_name:
                    consumers[a].append(op.name)

        parent = {o.name: o.name for o in comp.ops}

        def find(x):
            while parent[x] != x:
                parent[x] = parent[parent[x]]
                x = parent[x]
            return x

        def union(a, b):
            parent[find(a)] = find(b)

        transparent = {"get-tuple-element", "bitcast", "reshape", "tuple"}
        for op in comp.ops:
            if not _is_fusable(op):
                continue
            for a in _NAME_RE.findall(op.args):
                prod = ops_by_name.get(a)
                if prod is None:
                    continue
                if not (_is_fusable(prod) or prod.opcode in transparent):
                    continue
                # single consumer: classic fusion. multiple consumers:
                # the TPU backend duplicates the producer into each
                # fusable consumer, so the value never hits HBM as long
                # as EVERY consumer is fusable.
                if len(consumers[a]) == 1 or all(
                        _is_fusable(ops_by_name[c]) or
                        ops_by_name[c].opcode in transparent
                        for c in consumers[a]):
                    union(op.name, a)

        total = 0.0
        for op in comp.ops:
            oc = op.opcode
            if oc in _NO_TRAFFIC or oc.endswith("-done") or \
                    oc in ("while", "conditional"):
                continue
            if oc == "call" and not _is_fusable(op):
                continue        # body walked with traffic accounting
            operands = [_shape_bytes(t) for t in _operand_types(op, comp)]
            res = _shape_bytes(op.result)
            g = find(op.name)
            # reads: operands produced OUTSIDE this op's group
            ext_read = 0.0
            for a, ob in zip(_NAME_RE.findall(op.args), operands):
                prod = ops_by_name.get(a)
                if prod is not None and prod.opcode not in (
                        "parameter", "constant") and find(a) == g:
                    continue                      # fused internal edge
                ext_read += min(ob, res) if _is_fusable(op) or \
                    oc == "fusion" else ob
            # writes: results consumed outside the group (or root)
            used_outside = (not consumers[op.name]) or any(
                find(cname) != g for cname in consumers[op.name])
            ext_write = res if used_outside else 0.0

            if oc == "dynamic-update-slice":
                upd = operands[1] if len(operands) > 1 else 0.0
                total += 2.0 * upd
                continue
            if oc in ("dynamic-slice", "gather"):
                total += (res if used_outside else 0.0) + res
                continue
            if oc == "scatter":
                upd = operands[2] if len(operands) > 2 else res
                total += 3.0 * upd
                continue
            if oc == "fusion":
                called = dict(_called_comps(op.line))
                body = comps.get(called.get("calls", ""))
                has_dus = body is not None and any(
                    o.opcode in ("dynamic-update-slice", "scatter")
                    for o in body.ops)
                if has_dus:
                    smaller = [o for o in operands if o < res]
                    total += 2.0 * (max(smaller) if smaller else 0.0)
                    continue
            total += ext_read + ext_write
        return total

    comp_traffic_cache: dict = {}

    def walk(comp_name: str, mult: float, traffic: bool):
        comp = comps.get(comp_name)
        if comp is None or comp_name in stack:
            return
        stack.append(comp_name)
        if traffic:
            if comp_name not in comp_traffic_cache:
                comp_traffic_cache[comp_name] = _comp_traffic(comp)
            totals["hbm"] += mult * comp_traffic_cache[comp_name]
        for op in comp.ops:
            oc = op.opcode
            if oc == "while":
                called = dict(_called_comps(op.line))
                cond = called.get("condition")
                body = called.get("body")
                trips = _while_trip_count(comps[cond]) \
                    if cond in comps else 1
                if body in comps:
                    walk(body, mult * trips, traffic)
                continue
            for attr, cn in _called_comps(op.line):
                if cn not in comps:
                    continue
                if attr == "calls":                 # fusion body
                    walk(cn, mult, False)
                elif attr == "to_apply" and oc == "call" and _is_fusable(op):
                    walk(cn, mult, False)           # charged at the call site
                elif attr in ("branch_computations", "to_apply"):
                    walk(cn, mult, traffic)
            if oc == "dot":
                fl, dt = _dot_flops(op, comp)
                flops[dt] += mult * fl
            base = oc[:-6] if oc.endswith("-start") else oc
            if base in COLLECTIVE_KINDS and not oc.endswith("-done"):
                kind, payload, gsize = _collective_stats(op)
                coll_bytes[kind] += mult * payload
                coll_counts[kind] += mult
                totals["link"] += mult * _link_bytes(kind, payload, gsize)
        stack.pop()

    walk(entry, 1.0, True)
    return HLOStats(dict(flops), totals["hbm"], dict(coll_bytes),
                    totals["link"], dict(coll_counts))
