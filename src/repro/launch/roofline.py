"""Roofline table emitter: reads experiments/dryrun/*.json, writes the
§Dry-run and §Roofline markdown tables for EXPERIMENTS.md.

    PYTHONPATH=src python -m repro.launch.roofline [--out experiments]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import ALL_ARCHS, SHAPES, cell_is_skipped

NOTES = {
    "compute": "compute-bound: raise useful-FLOPs ratio (causal folding, "
               "less padding/remat waste)",
    "memory": "memory-bound: cut activation/cache materializations "
              "(bf16 end-to-end, fused attention, fewer saves)",
    "collective": "collective-bound: re-balance sharding rules (less TP, "
                  "more DP/FSDP; overlap or compress collectives)",
}


def load_cells(dry_dir: str) -> dict:
    cells = {}
    for path in glob.glob(os.path.join(dry_dir, "*.json")):
        with open(path) as f:
            r = json.load(f)
        key = (r["arch"], r["shape"], r.get("n_chips", 256),
               r.get("tag", ""))
        cells[key] = r
    return cells


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def dryrun_table(cells: dict, tag: str = "") -> str:
    rows = ["| arch | shape | mesh | compile | peak HBM/chip | fits 16GiB "
            "| collective schedule (ring bytes/chip) |",
            "|---|---|---|---|---|---|---|"]
    order = list(ALL_ARCHS) + ["ozimmu-gemm"]
    for arch in order:
        for shape in (list(SHAPES) if arch != "ozimmu-gemm" else
                      ["gemm_8k", "gemm_16k", "gemm_32k"]):
            if arch != "ozimmu-gemm" and cell_is_skipped(arch, shape):
                rows.append(f"| {arch} | {shape} | — | — | — | — | "
                            f"SKIPPED: quadratic full attention at 500k "
                            f"(DESIGN.md §6) |")
                continue
            for chips in (256, 512):
                r = cells.get((arch, shape, chips, tag))
                if r is None:
                    continue
                if not r.get("ok"):
                    rows.append(f"| {arch} | {shape} | {r.get('mesh')} | "
                                f"FAILED | — | — | {r.get('error', '')[:60]} |")
                    continue
                m = r["memory"]
                rf = r["roofline"]
                colls = ", ".join(
                    f"{k.replace('collective-', 'c')}:"
                    f"{v / 1e9:.1f}GB(x{int(r['roofline']['collective_counts'].get(k, 0))})"
                    for k, v in sorted(rf["collective_bytes"].items())
                    if v > 1e6) or "none"
                rows.append(
                    f"| {arch} | {shape} | {r['mesh']} | "
                    f"{r['compile_s']:.0f}s | "
                    f"{m['peak_bytes_per_chip'] / 2**30:.1f}GiB | "
                    f"{'yes' if m['fits_16GiB'] else 'NO*'} | {colls} |")
    return "\n".join(rows)


def roofline_table(cells: dict, tag: str = "") -> str:
    rows = ["| arch | shape | t_compute | t_memory | t_collective | "
            "dominant | MODEL/HLO flops | roofline frac | next move |",
            "|---|---|---|---|---|---|---|---|---|"]
    order = list(ALL_ARCHS) + ["ozimmu-gemm"]
    for arch in order:
        for shape in (list(SHAPES) if arch != "ozimmu-gemm" else
                      ["gemm_8k", "gemm_16k", "gemm_32k"]):
            r = cells.get((arch, shape, 256, tag))
            if r is None or not r.get("ok"):
                continue
            rf = r["roofline"]
            rows.append(
                f"| {arch} | {shape} | {fmt_s(rf['t_compute_s'])} | "
                f"{fmt_s(rf['t_memory_s'])} | "
                f"{fmt_s(rf['t_collective_s'])} | {rf['dominant']} | "
                f"{rf['useful_flops_ratio']:.3f} | "
                f"{rf['roofline_fraction']:.3f} | "
                f"{NOTES[rf['dominant']]} |")
    return "\n".join(rows)


def pick_hillclimb(cells: dict) -> list:
    """worst roofline fraction / most collective-bound / paper-native."""
    lm = [(k, r) for k, r in cells.items()
          if r.get("ok") and k[2] == 256 and k[0] != "ozimmu-gemm"
          and not k[3]]
    worst = min(lm, key=lambda kr: kr[1]["roofline"]["roofline_fraction"])
    coll = max(lm, key=lambda kr: kr[1]["roofline"]["t_collective_s"] /
               max(kr[1]["roofline"]["step_time_bound_s"], 1e-12))
    return [worst[0], coll[0], ("ozimmu-gemm", "gemm_16k", 256, "")]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "experiments",
        "dryrun"))
    args = ap.parse_args()
    cells = load_cells(args.dry_dir)
    print("## §Dry-run\n")
    print(dryrun_table(cells))
    print("\n## §Roofline (single-pod 16x16)\n")
    print(roofline_table(cells))
    print("\n## hillclimb candidates\n")
    for c in pick_hillclimb(cells):
        r = cells[c]["roofline"]
        print(f"- {c[0]} {c[1]}: dominant={r['dominant']} "
              f"frac={r['roofline_fraction']:.3f}")


if __name__ == "__main__":
    main()
