"""Mesh construction (functions, not module constants — importing this
module never touches jax device state).

Production target: TPU v5e pods, 256 chips each, 16x16 ICI torus.
Single-pod mesh (16, 16) = ("data", "model"); multi-pod adds a leading
"pod" axis over DCN: (2, 16, 16) = ("pod", "data", "model").
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def _auto(n: int):
    return (AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_local_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over however many (CPU) devices the test session has."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"),
                             axis_types=_auto(3))
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=_auto(2))


# TPU v5e hardware constants (per chip) — the roofline denominators.
PEAK_BF16_FLOPS = 197e12        # bf16 MXU
PEAK_INT8_OPS = 394e12          # int8 MXU (the paper's 2x IMMU advantage)
HBM_BW = 819e9                  # bytes/s
ICI_LINK_BW = 50e9              # bytes/s per link
HBM_PER_CHIP = 16 * 2**30       # 16 GiB
