"""Mesh construction (functions, not module constants — importing this
module never touches jax device state).

Production target: TPU v5e pods, 256 chips each, 16x16 ICI torus.
Single-pod mesh (16, 16) = ("data", "model"); multi-pod adds a leading
"pod" axis over DCN: (2, 16, 16) = ("pod", "data", "model").
"""
from __future__ import annotations

import jax

try:                                    # jax >= 0.5 has explicit axis types
    from jax.sharding import AxisType
except ImportError:                     # jax 0.4.x: meshes are Auto already
    AxisType = None


def _axis_kwargs(n: int) -> dict:
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n}


def make_mesh_compat(shape, axes):
    """``jax.make_mesh`` with Auto axis types where the API supports them.

    The single place the repo constructs meshes, so the jax-version
    dance happens once (``axis_types`` only exists in newer jax).
    """
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_kwargs(len(tuple(axes))))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over however many (CPU) devices the test session has."""
    if pod:
        return make_mesh_compat((pod, data, model), ("pod", "data", "model"))
    return make_mesh_compat((data, model), ("data", "model"))


# TPU v5e hardware constants (per chip) — the roofline denominators.
PEAK_BF16_FLOPS = 197e12        # bf16 MXU
PEAK_INT8_OPS = 394e12          # int8 MXU (the paper's 2x IMMU advantage)
HBM_BW = 819e9                  # bytes/s
ICI_LINK_BW = 50e9              # bytes/s per link
HBM_PER_CHIP = 16 * 2**30       # 16 GiB
