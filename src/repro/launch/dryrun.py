import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay the first statements of this module — jax
locks the device count at first initialization, and the production meshes
need 512 placeholder host devices (2 pods x 16 x 16).

Per cell this driver:
  1. builds the full ArchConfig and the production mesh;
  2. constructs abstract (ShapeDtypeStruct) params / optimizer / batch /
     decode-state trees with NamedShardings from the ShardingPlan;
  3. ``jit(step).lower(...).compile()`` — success proves the sharding
     config is coherent (no mismatched specs, no unsupported collective);
  4. records ``memory_analysis()`` (fits-in-HBM proof),
     ``cost_analysis()`` (XLA's one-pass numbers), and the while-aware
     HLO analysis (FLOPs / HBM traffic / collective bytes — the roofline
     terms) into ``experiments/dryrun/<cell>.json``.

CLI::

    python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
    python -m repro.launch.dryrun --all          # every cell, both meshes
    python -m repro.launch.dryrun --arch ozimmu-gemm --shape gemm_16k
"""
import argparse
import dataclasses
import functools
import json
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ALL_ARCHS, SHAPES, cell_is_skipped, get_config)
from repro.launch import hlo_analysis
from repro.launch.mesh import (HBM_PER_CHIP, HBM_BW, ICI_LINK_BW,
                               PEAK_BF16_FLOPS, PEAK_INT8_OPS,
                               make_production_mesh)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

GEMM_SHAPES = {"gemm_8k": 8192, "gemm_16k": 16384, "gemm_32k": 32768}


# ----------------------------------------------------------------------------
# abstract trees
# ----------------------------------------------------------------------------

def _fit_sharding(shape, ns):
    """Drop spec entries whose mesh-axis product doesn't divide the dim
    (e.g. a batch of 1 under a 16-way data axis in the long_500k cells) —
    jit rejects such explicit out_shardings."""
    spec = list(ns.spec) + [None] * (len(shape) - len(ns.spec))
    changed = False
    for i, (dim, entry) in enumerate(zip(shape, spec)):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        shards = 1
        for a in axes:
            shards *= ns.mesh.shape[a]
        if dim % shards:
            spec[i] = None
            changed = True
    if not changed:
        return ns
    return NamedSharding(ns.mesh, P(*spec))


def _sds(tree, shardings):
    return jax.tree.map(
        lambda t, s: jax.ShapeDtypeStruct(
            t.shape, t.dtype, sharding=_fit_sharding(t.shape, s)),
        tree, shardings)


def abstract_cell(cfg, shape_name: str, mesh, rules_overrides=None,
                  grad_accum: int = 8):
    """(step_fn, abstract_args, donate) for one cell."""
    from repro.models import init_model
    from repro.models.transformer import (decode_step, forward_train,
                                          init_decode_state, prefill)
    from repro.parallel.sharding import make_plan, wrap_with_sharding
    from repro.train.optimizer import AdamWState, OptimizerConfig, adamw_init
    from repro.train.trainer import train_step

    shape = SHAPES[shape_name]
    kind = shape.kind
    axes_box = {}

    def params_only(k):
        p, a = init_model(cfg, k)
        axes_box["axes"] = a
        return p

    p_shapes = jax.eval_shape(params_only, jax.random.key(0))
    plan = make_plan(cfg, axes_box["axes"], mesh, kind=kind,
                     overrides=rules_overrides)
    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), plan.param_specs,
                        is_leaf=lambda s: isinstance(s, P))
    params = _sds(p_shapes, p_sh)

    b, s = shape.global_batch, shape.seq_len
    rep = NamedSharding(mesh, P())
    bspec = plan.batch_specs
    wrap = functools.partial(wrap_with_sharding, mesh=mesh,
                             rules=plan.rules)

    def tok_sds(bb, ss, lead_accum=0):
        shp = (bb, ss, cfg.num_codebooks) if cfg.frontend == "audio" \
            else (bb, ss)
        spec = bspec["tokens"]
        if lead_accum:
            shp = (lead_accum,) + shp
            spec = P(None, *spec)
        return jax.ShapeDtypeStruct(
            shp, jnp.int32,
            sharding=_fit_sharding(shp, NamedSharding(mesh, spec)))

    if kind == "train":
        batch_shards = 1
        for ax in plan.rules.get("batch", ()):
            batch_shards *= mesh.shape[ax]
        local_b = max(1, b // batch_shards)
        ga = max(1, min(grad_accum or cfg.train_grad_accum, local_b))
        lead = ga if ga > 1 else 0
        text_len = s - cfg.num_patches if cfg.frontend == "vision" else s
        batch = {"tokens": tok_sds(b // ga if lead else b, text_len, lead)}
        if cfg.frontend == "vision":
            pe_shape = (b // ga if lead else b, cfg.num_patches,
                        cfg.d_model)
            pe_spec = bspec["patch_embeds"]
            if lead:
                pe_shape = (ga,) + pe_shape
                pe_spec = P(None, *pe_spec)
            batch["patch_embeds"] = jax.ShapeDtypeStruct(
                pe_shape, jnp.float32,
                sharding=NamedSharding(mesh, pe_spec))
        opt_shapes = jax.eval_shape(
            functools.partial(adamw_init,
                              moment_dtype=jnp.dtype(cfg.moment_dtype)),
            p_shapes)
        opt = AdamWState(
            jax.ShapeDtypeStruct((), jnp.int32, sharding=rep),
            _sds(opt_shapes.mu, p_sh), _sds(opt_shapes.nu, p_sh))
        oc = OptimizerConfig()
        fn = wrap(functools.partial(train_step, cfg, oc, grad_accum=ga))
        out_sh = (jax.tree.map(lambda x: x.sharding, params),
                  jax.tree.map(lambda x: x.sharding, opt), None)
        return fn, (params, opt, batch), (0, 1), out_sh

    # inference state
    state_shapes = jax.eval_shape(
        functools.partial(init_decode_state, cfg, b, s), )
    st_sh = jax.tree.map(
        lambda spec: NamedSharding(mesh, spec), plan.state_specs,
        is_leaf=lambda x: isinstance(x, P))
    state = _sds(state_shapes, st_sh)

    state_sh = jax.tree.map(lambda x: x.sharding, state)
    if kind == "prefill":
        text_len = s - cfg.num_patches if cfg.frontend == "vision" else s
        batch = {"tokens": tok_sds(b, text_len)}
        if cfg.frontend == "vision":
            batch["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.num_patches, cfg.d_model), jnp.float32,
                sharding=NamedSharding(mesh, bspec["patch_embeds"]))
        fn = wrap(functools.partial(prefill, cfg))
        return fn, (params, batch, state), (2,), (state_sh, None)

    # decode: one new token against a seq_len cache
    tok = tok_sds(b, 1)
    fn = wrap(functools.partial(decode_step, cfg))
    return fn, (params, state, tok), (1,), (None, state_sh)


def abstract_gemm_cell(shape_name: str, mesh, num_splits: int = 9,
                       schedule: str = "psum", fuse: bool = True):
    """The paper-native cell: distributed Ozaki DGEMM, df32 TPU path.

    2D distribution: m sharded over "data", k over "model" (the paper's
    single-GPU GEMM scaled onto the pod grid). ``schedule`` / ``fuse`` /
    ``num_splits`` are the §Perf hillclimb knobs.
    """
    from repro.configs.ozimmu_gemm import CONFIG as GEMM_CONFIG
    from repro.core.ozaki import OzakiConfig
    from repro.core.xmath import DW
    from repro.parallel.ozaki_shard import distributed_ozaki_matmul
    n = GEMM_SHAPES[shape_name]
    cfg = OzakiConfig(num_splits=num_splits, accum=GEMM_CONFIG.accum,
                      fuse_diagonals=fuse)
    fn = functools.partial(distributed_ozaki_matmul, mesh=mesh, cfg=cfg,
                           axis="model", m_axis="data", schedule=schedule)
    a = jax.ShapeDtypeStruct((n, n), jnp.float32, sharding=NamedSharding(
        mesh, P("data", "model")))
    b = jax.ShapeDtypeStruct((n, n), jnp.float32, sharding=NamedSharding(
        mesh, P("model", None)))
    col = "model" if schedule in ("reduce_scatter", "rs_stream") else None
    ns = NamedSharding(mesh, P("data", col))
    return fn, (a, b), (), DW(ns, ns)


# ----------------------------------------------------------------------------
# roofline terms
# ----------------------------------------------------------------------------

def roofline_record(stats: hlo_analysis.HLOStats, *, n_chips: int,
                    model_flops_global: float,
                    ideal_bytes_per_chip: float = 0.0) -> dict:
    """The three roofline terms + how close the step is to ITS OWN bound.

    ``roofline_fraction`` = (the step's unavoidable time: useful-FLOPs
    at peak vs minimal data movement at full HBM bw, whichever is larger)
    / (the modeled step time = max of the three terms). 1.0 means the
    compiled program moves/computes nothing it doesn't have to.
    """
    int_fl = stats.int_flops
    float_fl = stats.total_flops - int_fl
    t_compute = float_fl / PEAK_BF16_FLOPS + int_fl / PEAK_INT8_OPS
    t_memory = stats.hbm_bytes / HBM_BW
    t_collective = stats.collective_link_bytes / ICI_LINK_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_collective}
    dominant = max(terms, key=terms.get)
    model_flops_chip = model_flops_global / n_chips
    t_ideal = max(model_flops_chip / PEAK_BF16_FLOPS,
                  ideal_bytes_per_chip / HBM_BW)
    bound = max(terms.values())
    return {
        "hlo_flops_per_chip": stats.total_flops,
        "hlo_int_flops_per_chip": int_fl,
        "hbm_bytes_per_chip": stats.hbm_bytes,
        "ideal_bytes_per_chip": ideal_bytes_per_chip,
        "collective_bytes": stats.collective_bytes,
        "collective_counts": stats.collective_counts,
        "collective_link_bytes_per_chip": stats.collective_link_bytes,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dominant,
        "model_flops_global": model_flops_global,
        "model_flops_per_chip": model_flops_chip,
        "useful_flops_ratio": (model_flops_chip / stats.total_flops
                               if stats.total_flops else 0.0),
        "bytes_efficiency": (ideal_bytes_per_chip / stats.hbm_bytes
                             if stats.hbm_bytes else 0.0),
        "roofline_fraction": (t_ideal / bound) if bound else 0.0,
        "step_time_bound_s": bound,
    }


def _tree_bytes(tree) -> float:
    """Global bytes across a tree of arrays/ShapeDtypeStructs."""
    leaves = [l for l in jax.tree.leaves(tree)
              if hasattr(l, "shape") and hasattr(l, "dtype")]
    total = 0.0
    for l in leaves:
        n = 1
        for d in l.shape:
            n *= d
        total += n * jnp.dtype(l.dtype).itemsize
    return total


def _tree_bytes_per_chip(tree) -> float:
    """PER-CHIP bytes honoring each leaf's actual sharding: a leaf only
    sharded over "model" (16-way) costs each chip 16x more than naive
    global/256 — the minimal-traffic model must reflect that."""
    total = 0.0
    for l in jax.tree.leaves(tree):
        if not (hasattr(l, "shape") and hasattr(l, "dtype")):
            continue
        n = 1
        for d in l.shape:
            n *= d
        bytes_ = n * jnp.dtype(l.dtype).itemsize
        sh = getattr(l, "sharding", None)
        shards = 1
        if sh is not None and hasattr(sh, "spec"):
            for dim, entry in enumerate(list(sh.spec)):
                if entry is None:
                    continue
                names = entry if isinstance(entry, tuple) else (entry,)
                k = 1
                for nm in names:
                    k *= sh.mesh.shape[nm]
                if l.shape[dim] % k == 0:
                    shards *= k
        total += bytes_ / shards
    return total


def model_flops(cfg, shape_name: str) -> float:
    """6·N_active·D (train) / 2·N_active·D (inference forward)."""
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens


# ----------------------------------------------------------------------------
# one cell
# ----------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             precision: str | None = None, rules: dict | None = None,
             grad_accum: int = 8, tag: str = "", out_dir: str = OUT_DIR,
             fold_causal: bool = False,
             param_dtype: str | None = None,
             accum_dtype: str | None = None) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    t0 = time.time()
    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "mesh_axes": list(mesh.axis_names),
        "n_chips": n_chips, "tag": tag,
        "precision": precision or "bf16",
        "rules_overrides": rules or {},
        "grad_accum": grad_accum,
    }
    if arch == "ozimmu-gemm":
        from repro.configs.ozimmu_gemm import CONFIG as GEMM_CONFIG
        gemm_opts = rules or {}
        s = int(gemm_opts.get("splits", GEMM_CONFIG.num_splits))
        fn, args, donate, out_sh = abstract_gemm_cell(
            shape_name, mesh, num_splits=s,
            schedule=gemm_opts.get("schedule", "psum"),
            fuse=bool(gemm_opts.get("fuse", GEMM_CONFIG.fuse_diagonals)))
        n = GEMM_SHAPES[shape_name]
        mf = 2.0 * n * n * n       # the FP64 GEMM being emulated
        record["model_flops_note"] = "2mnk of the emulated DGEMM"
        record["gemm_opts"] = dict(gemm_opts) | {"splits": s}
        # minimal movement: read both inputs, write C (+ int8 slices once)
        ideal_bytes = (_tree_bytes(args) + 2 * s * n * n) / n_chips
    else:
        overrides = {k: tuple(v) for k, v in (rules or {}).items()}
        if shape_name == "long_500k" and "kv_heads" not in overrides:
            # batch=1 leaves the data axis idle; park the KV heads there
            # (2D-sharded cache: heads x sequence)
            overrides["kv_heads"] = ("data",)
            overrides["batch"] = ()
        cfg = get_config(arch)
        if precision:
            cfg = dataclasses.replace(cfg, matmul_precision=precision)
        if param_dtype:
            cfg = dataclasses.replace(cfg, param_dtype=param_dtype)
            record["param_dtype"] = param_dtype
        if accum_dtype:
            cfg = dataclasses.replace(cfg, accum_dtype=accum_dtype)
            record["accum_dtype"] = accum_dtype
        if fold_causal:
            record["fold_causal"] = True
            import repro.models.attention as attn_mod
            import repro.models.transformer as tr_mod
            _orig = attn_mod.chunked_attention
            patched = functools.partial(_orig, fold_causal=True)
            attn_mod.chunked_attention = patched
            tr_mod.chunked_attention = patched   # transformer's binding
        ga = grad_accum if SHAPES[shape_name].kind == "train" else 1
        record["grad_accum"] = ga
        fn, args, donate, out_sh = abstract_cell(
            cfg, shape_name, mesh, rules_overrides=overrides or None,
            grad_accum=ga)
        mf = model_flops(cfg, shape_name)
        # minimal data movement: every jit argument once (params, opt
        # state, batch, caches) + grads written once for train steps —
        # per chip, honoring each leaf's real sharding
        ideal_bytes = _tree_bytes_per_chip(args)
        if SHAPES[shape_name].kind == "train":
            ideal_bytes += _tree_bytes_per_chip(args[0])   # grad write

    lowered = jax.jit(fn, donate_argnums=donate,
                      out_shardings=out_sh).lower(*args)
    record["lower_s"] = round(time.time() - t0, 1)
    t1 = time.time()
    compiled = lowered.compile()
    record["compile_s"] = round(time.time() - t1, 1)

    ma = compiled.memory_analysis()
    mem = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "generated_code_bytes": int(ma.generated_code_size_in_bytes),
    }
    peak = mem["argument_bytes"] + mem["temp_bytes"] + \
        mem["output_bytes"] - mem["alias_bytes"]
    mem["peak_bytes_per_chip"] = peak
    mem["fits_16GiB"] = bool(peak <= HBM_PER_CHIP)
    record["memory"] = mem

    ca = compiled.cost_analysis() or {}
    record["xla_cost_analysis"] = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
    }

    stats = hlo_analysis.analyze(compiled.as_text())
    record["roofline"] = roofline_record(stats, n_chips=n_chips,
                                         model_flops_global=mf,
                                         ideal_bytes_per_chip=ideal_bytes)
    record["dot_flops_by_dtype"] = stats.dot_flops
    record["ok"] = True
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, cell_name(record)), "w") as f:
        json.dump(record, f, indent=1)
    return record


def cell_name(record: dict) -> str:
    tag = f"_{record['tag']}" if record.get("tag") else ""
    pods = "2pod" if record["n_chips"] == 512 else "1pod"
    return f"{record['arch']}_{record['shape']}_{pods}{tag}.json"


# ----------------------------------------------------------------------------
# sweep driver (subprocess per cell: isolates OOM/hangs)
# ----------------------------------------------------------------------------

def all_cells(include_gemm: bool = True):
    cells = []
    for mp in (False, True):        # all single-pod first: roofline table
        for arch in ALL_ARCHS:
            for shape in SHAPES:
                if cell_is_skipped(arch, shape):
                    continue
                cells.append((arch, shape, mp))
        if include_gemm:
            for shape in GEMM_SHAPES:
                cells.append(("ozimmu-gemm", shape, mp))
    return cells


def sweep(args):
    cells = all_cells()
    done = failed = 0
    for arch, shape, mp in cells:
        rec = {"arch": arch, "shape": shape, "tag": args.tag,
               "n_chips": 512 if mp else 256}
        path = os.path.join(args.out, cell_name(rec))
        if os.path.exists(path) and not args.force:
            with open(path) as f:
                if json.load(f).get("ok"):
                    done += 1
                    continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--out", args.out,
               "--grad-accum", str(args.grad_accum)]
        if mp:
            cmd.append("--multi-pod")
        if args.tag:
            cmd += ["--tag", args.tag]
        print(f"[dryrun] {arch} {shape} {'2pod' if mp else '1pod'} ...",
              flush=True)
        try:
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout)
            if r.returncode == 0:
                done += 1
                print("  ok", flush=True)
            else:
                failed += 1
                err = (r.stderr or r.stdout).strip().splitlines()
                tail = "\n".join(err[-15:])
                with open(path, "w") as f:
                    json.dump({"arch": arch, "shape": shape, "ok": False,
                               "tag": args.tag,
                               "n_chips": 512 if mp else 256,
                               "error": tail}, f, indent=1)
                print(f"  FAILED:\n{tail}\n", flush=True)
        except subprocess.TimeoutExpired:
            failed += 1
            with open(path, "w") as f:
                json.dump({"arch": arch, "shape": shape, "ok": False,
                           "tag": args.tag, "n_chips": 512 if mp else 256,
                           "error": "timeout"}, f, indent=1)
            print("  TIMEOUT", flush=True)
    print(f"[dryrun] complete: {done} ok, {failed} failed "
          f"of {len(cells)}")
    return failed


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--precision", default=None,
                    choices=[None, "bf16", "int8_quant", "ozaki_fp64"])
    ap.add_argument("--rules", default=None,
                    help='JSON dict: logical axis -> [mesh axes]')
    ap.add_argument("--grad-accum", type=int, default=0,
                    help="0: use the arch config's train_grad_accum")
    ap.add_argument("--fold-causal", action="store_true")
    ap.add_argument("--param-dtype", default=None,
                    choices=[None, "float32", "bfloat16"])
    ap.add_argument("--accum-dtype", default=None,
                    choices=[None, "float32", "bfloat16"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args()

    if args.all:
        sys.exit(1 if sweep(args) else 0)

    rules = json.loads(args.rules) if args.rules else None
    try:
        rec = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                       precision=args.precision, rules=rules,
                       grad_accum=args.grad_accum, tag=args.tag,
                       out_dir=args.out, fold_causal=args.fold_causal,
                       param_dtype=args.param_dtype,
                       accum_dtype=args.accum_dtype)
    except Exception:
        traceback.print_exc()
        sys.exit(1)
    r = rec["roofline"]
    print(json.dumps({
        "cell": cell_name(rec),
        "compile_s": rec["compile_s"],
        "peak_GiB": round(rec["memory"]["peak_bytes_per_chip"] / 2**30, 2),
        "fits": rec["memory"]["fits_16GiB"],
        "t_compute_ms": round(r["t_compute_s"] * 1e3, 3),
        "t_memory_ms": round(r["t_memory_s"] * 1e3, 3),
        "t_collective_ms": round(r["t_collective_s"] * 1e3, 3),
        "dominant": r["dominant"],
        "useful_ratio": round(r["useful_flops_ratio"], 3),
        "roofline_fraction": round(r["roofline_fraction"], 3),
    }, indent=1))


if __name__ == "__main__":
    main()
