"""Training launcher: end-to-end driver with checkpoint/restart.

On this CPU container it trains *reduced* configs for real (the
``--full`` flag selects the production config for use on an actual pod).
Fault tolerance is wired in: heartbeat thread, step watchdog (straggler
log), periodic async checkpoints, and crash-restart through
``runtime.fault.restart_loop`` (``--simulate-failure-at N`` injects one).

Example::

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
        --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt as ckpt_lib
from repro.configs import get_config
from repro.data.pipeline import make_data
from repro.launch.mesh import make_local_mesh
from repro.parallel.sharding import make_plan
from repro.runtime.fault import (Heartbeat, SimulatedFailure, StepWatchdog,
                                 restart_loop)
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import (init_training, make_train_step,
                                 split_microbatches)


def train(args) -> int:
    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    if args.precision:
        cfg = dataclasses.replace(cfg, matmul_precision=args.precision)

    n_dev = len(jax.devices())
    model_par = min(args.model_parallel, n_dev)
    mesh = make_local_mesh(data=n_dev // model_par, model=model_par)
    oc = OptimizerConfig(peak_lr=args.lr, warmup_steps=args.warmup,
                         total_steps=args.steps)
    data = make_data(cfg, args.seq, args.batch, seed=args.data_seed)

    def run(resume) -> int:
        params, axes, opt_state = init_training(cfg, jax.random.key(args.seed))
        plan = make_plan(cfg, axes, mesh, kind="train")
        step_fn = make_train_step(cfg, oc, plan, grad_accum=args.grad_accum)

        start = 0
        if resume is not None:
            latest = ckpt_lib.latest_step(args.ckpt_dir)
            if latest is not None:
                tree = {"params": params, "opt": opt_state}
                tree = ckpt_lib.restore(args.ckpt_dir, latest, tree)
                params, opt_state = tree["params"], tree["opt"]
                start = ckpt_lib.load_manifest(
                    args.ckpt_dir, latest)["meta"]["data_cursor"]
                print(f"[train] restored step {latest}, "
                      f"data cursor {start}", flush=True)

        hb = Heartbeat(os.path.join(args.ckpt_dir, "heartbeat.json")).start()
        wd = StepWatchdog()
        pending = None
        try:
            for step in range(start, args.steps):
                if args.simulate_failure_at == step and resume is None:
                    raise SimulatedFailure(f"injected at step {step}")
                wd.start_step(step)
                batch = data.batch_at(step)
                if args.grad_accum > 1:
                    batch = split_microbatches(batch, args.grad_accum)
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                params, opt_state, metrics = step_fn(params, opt_state,
                                                     batch)
                hb.step = step
                ev = wd.end_step()
                if ev:
                    print(f"[watchdog] straggler step {ev.step}: "
                          f"{ev.duration_s:.2f}s vs median "
                          f"{ev.median_s:.2f}s", flush=True)
                if step % args.log_every == 0 or step == args.steps - 1:
                    loss = float(metrics["loss"])
                    print(f"step {step:5d} loss {loss:.4f} "
                          f"lr {float(metrics['lr']):.2e} "
                          f"gnorm {float(metrics['grad_norm']):.2f}",
                          flush=True)
                if args.ckpt_every and (step + 1) % args.ckpt_every == 0:
                    if pending is not None:
                        pending.join()
                    pending = ckpt_lib.save(
                        args.ckpt_dir, step + 1,
                        {"params": params, "opt": opt_state},
                        meta={"data_cursor": step + 1,
                              "arch": cfg.name},
                        async_write=True)
            if pending is not None:
                pending.join()
            ckpt_lib.save(args.ckpt_dir, args.steps,
                          {"params": params, "opt": opt_state},
                          meta={"data_cursor": args.steps,
                                "arch": cfg.name})
            return args.steps
        finally:
            hb.stop()

    final = restart_loop(run, max_restarts=args.max_restarts,
                         on_restart=lambda i, e: print(
                             f"[restart {i}] {e}", flush=True))
    print(f"[train] done at step {final}")
    return final


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--full", action="store_true",
                    help="production config (pods); default: reduced")
    ap.add_argument("--precision", default=None,
                    choices=["bf16", "int8_quant", "ozaki_fp64"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data-seed", type=int, default=1234)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--max-restarts", type=int, default=2)
    ap.add_argument("--simulate-failure-at", type=int, default=-1)
    train(ap.parse_args())


if __name__ == "__main__":
    main()
