"""Serving launcher: continuous-batching engine demo on a reduced config.

Submits a stream of randomized requests, drains the engine, and verifies
one request against the sequential reference generator.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b \
        --requests 6 --slots 3
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_model
from repro.serving.engine import (Request, ServingEngine,
                                  generate_sequential)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--policy", metavar="SPEC", default=None,
                    help="matmul policy spec for this engine (the one "
                         "front door; repro.api.MatmulPolicy), e.g. "
                         "'ozaki-fp64@1e-25:fast/pallas_fused+epilogue"
                         "|cache=plans.json|autotune'; add "
                         "'|shard=model|comm=int8' for the int8-slice "
                         "collective transport on a mesh. Subsumes (and "
                         "cannot be combined with) --precision/"
                         "--target-error/--fast-mode; --plan-cache/"
                         "--autotune stay combinable and override the "
                         "spec's |cache=/|autotune sections")
    ap.add_argument("--precision", default=None,
                    choices=["bf16", "int8_quant", "ozaki_fp64"],
                    help="legacy: override cfg.matmul_precision only "
                         "(prefer --policy)")
    ap.add_argument("--plan-cache", metavar="PATH", default=None,
                    help="persistent PlanCache JSON the engine pre-warms "
                         "at startup (ozaki_fp64 only)")
    ap.add_argument("--autotune", action="store_true",
                    help="measure candidate plans for cache misses during "
                         "the startup pre-warm")
    ap.add_argument("--target-error", type=float, default=None,
                    help="accuracy target on the scaled error "
                         "(core.accuracy); lets the driver reduce the "
                         "split count per projection shape")
    ap.add_argument("--fast-mode", action="store_true",
                    help="truncate slice pairs to the minimal budget "
                         "meeting --target-error (or drop the last "
                         "anti-diagonal without one)")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if cfg.frontend == "audio":
        raise SystemExit("serve demo targets text archs; see tests for "
                         "audio decode coverage")
    params, _ = init_model(cfg, jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)

    if args.policy is not None and (args.precision or args.target_error
                                    or args.fast_mode):
        raise SystemExit("--policy subsumes --precision/--target-error/"
                         "--fast-mode; pass one or the other")
    if args.policy is not None:
        from repro.api import MatmulPolicy
        pol = MatmulPolicy.parse(args.policy)
        print(f"[serve] matmul policy: {pol.spec()}")
        engine = ServingEngine(cfg, params, num_slots=args.slots,
                               max_len=args.max_len, policy=pol,
                               plan_cache=args.plan_cache,
                               autotune_plans=args.autotune or None)
    else:
        engine = ServingEngine(cfg, params, num_slots=args.slots,
                               max_len=args.max_len,
                               matmul_precision=args.precision,
                               ozaki_target_error=args.target_error,
                               ozaki_fast_mode=args.fast_mode or None,
                               plan_cache=args.plan_cache,
                               autotune_plans=args.autotune or None)
    if engine.plan_cache is not None:
        print(f"[serve] plan cache pre-warmed: {len(engine.plan_cache)} "
              f"plans ({engine.plan_cache.path})")
    reqs = []
    for rid in range(args.requests):
        plen = int(rng.integers(4, 12))
        prompt = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
        req = Request(rid, prompt, max_new_tokens=args.max_new)
        reqs.append(req)
        engine.submit(req)

    t0 = time.time()
    finished = engine.run_until_done()
    dt = time.time() - t0
    total_tokens = sum(len(r.generated) for r in finished)
    print(f"[serve] {len(finished)} requests, {total_tokens} tokens in "
          f"{dt:.2f}s ({total_tokens / dt:.1f} tok/s) with "
          f"{engine._steps} batched decode ticks")

    ref = generate_sequential(engine.cfg, params, reqs[0].prompt,
                              reqs[0].max_new_tokens,
                              max_len=args.max_len)
    got = next(r for r in finished if r.rid == 0).generated
    assert got == ref, (got, ref)
    print("[serve] continuous-batching output == sequential reference ✓")


if __name__ == "__main__":
    main()
