"""Ozaki-scheme GEMM on integer matrix units — Algorithm 3 of the paper.

``ozaki_matmul`` computes an FP64-accurate ``C = A @ B`` using only int8
matrix multiplications with int32 accumulation (the TPU MXU int8 path) plus
a high-precision scaled accumulation of the slice products.

This module is the thin *driver* of a planner/executor architecture:

  * ``core.tuning.PipelinePlan`` — the execution strategy for one shape
    (tiles, split count, fusion mode, batch layout, shard axis), built
    once per shape by ``plan_for`` (reflecting the ``OzakiConfig``) or
    ``select_pipeline_plan`` (from shapes alone).
  * ``core.executors`` — one executor class per strategy; the driver
    normalizes operands (transpose, batch folding), computes the deferred
    exponent base, and hands the three-stage pipeline (split, slice
    GEMMs, accumulate) to ``get_executor(plan)``.

Backends (``OzakiConfig.backend`` — executor families):

  * ``xla``          — every stage as composite XLA ops. The reference.
  * ``pallas``       — the int8 GEMMs run on the Pallas MXU kernel; split
    and accumulation stay XLA ops.
  * ``pallas_fused`` — the deployment path. With ``fuse_epilogue=False``
    (fusion mode "stages"): one-pass SplitInt kernel, Pallas MXU GEMMs,
    fused scaled-accumulation kernels. With ``fuse_epilogue=True``
    (fusion mode "epilogue"): GEMM and accumulation run in ONE kernel per
    anti-diagonal group — the int32 slice products accumulate in a VMEM
    scratch block and never round-trip to HBM (the remaining accumulation
    traffic ``core.tuning.hbm_pass_model`` charges the "stages" mode).
    Both modes are bitwise identical to ``xla`` for both accumulation
    modes (the kernels run the same rounding sequences).

Accumulation modes:
  * ``accum="f64"``  — the paper's mode (CPU validation; x64 required).
  * ``accum="df32"`` — double-float32 accumulation, deployable on TPU
    (no FP64 hardware exists there); carries 48 mantissa bits.

Scheduling modes (see DESIGN.md §4):
  * paper-faithful: each slice pair (i, j) with i + j <= s + 1 is a
    separate int8 GEMM followed by a scaled high-precision accumulation.
  * ``fuse_diagonals`` (O1): pairs on an anti-diagonal share their scale,
    so their int32 products are summed exactly in int32 first. Requires
    slack bits in alpha (``compute_alpha(..., fuse_terms=...)``).
  * ``concat_k`` (O2): realizes each anti-diagonal sum as ONE int8 GEMM
    over a k-concatenated operand pair (the epilogue-fused executor gets
    the same exact sum from its pair grid dimension instead).

Batched entry point: ``ozaki_matmul_batched`` handles ``(B, m, k) @
(B, k, n)`` stacks and the serving case ``(B, m, k) @ (k, n)`` (broadcast
weights). Broadcast weights collapse the batch into rows — one big GEMM,
bitwise identical to a Python loop over ``ozaki_matmul``. Fully-batched
operands run the SAME pipeline with an explicit batch dimension: the
split stage folds the stack into rows (row-independent, exact), the
GEMMs run the explicit batch-grid kernel (one launch per group, batch
outermost in the grid — no vmap), and the accumulation broadcasts the
per-(batch, row, col) exponent base. Gradients are defined via
``jax.custom_jvp`` with the exact-product rule ``dC = dA·B + A·dB``.

Sharding: ``OzakiConfig.shard_axis`` names a mesh axis the k (reduction)
dimension is sharded over; ``parallel.ozaki_shard`` composes the batched
API with that axis (the plan carries it; GSPMD inserts the collectives).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from .executors import StreamingSplit, get_executor, int32_to_dw
from .splitting import SplitResult, slice_width
from .tuning import (BACKENDS, PipelinePlan, TilePlan, diagonal_groups,
                     parse_pair_policy, plan_for)
from .xmath import DW, dw_to_single

@dataclasses.dataclass(frozen=True)
class OzakiConfig:
    """Configuration for one Ozaki GEMM.

    num_splits: s in the paper (INT8x{s}).
    accum: "f64" | "df32".
    backend: "xla" (lax ops) | "pallas" (MXU GEMM kernel only) |
        "pallas_fused" (fused split/GEMM/accumulate kernel pipeline).
    fuse_epilogue: with ``backend="pallas_fused"``, run GEMM + scaled
        accumulation in one kernel per group (int32 products stay in
        VMEM). Ignored by other backends. Stacked-weights batches run
        the batch-grid epilogue kernel (set the
        ``REPRO_OZAKI_BATCHED_EPILOGUE=0`` env knob to fall back to the
        stage-fused pipeline on batched calls; the fallback warns once).
    streaming: with ``backend="pallas_fused"``, fuse the SPLIT into the
        GEMM grid as well (``fusion="streaming"``): each (k-panel, pair)
        grid step extracts the int8 slices of its operand tiles in VMEM,
        so the slice stacks never materialize in HBM (see
        ``tuning.hbm_pass_model``'s "slices" item). Wins over
        ``fuse_epilogue`` when both are set; ignored by other backends;
        gated by the same env knob as the epilogue kernels on stacked
        batches.
    fuse_diagonals: O1 — exact int32 pre-accumulation per anti-diagonal.
    concat_k: O2 — one GEMM per anti-diagonal via k-concatenation.
    full_pairs: compute all s*s pairs (paper computes i+j <= s+1 only).
    pair_policy: "full" | "diagonal" | "budget:N" — fast-mode pair
        truncation: compute only the highest-significance slice pairs
        (``core.accuracy`` bounds the error; the truncated pair list is
        threaded into the executors' grids, never applied as a mask).
    target_error: accuracy target on the scaled error
        ``max |C - C_hat| / 2^{ea+eb}`` (see ``core.accuracy``). When
        set, the driver REDUCES num_splits to the smallest count whose
        guaranteed bound meets it (never raises it), per GEMM shape.
    fast_mode: truncate slice pairs to the minimal budget meeting
        ``target_error`` (or drop the last anti-diagonal when no target
        is set). An explicit non-"full" ``pair_policy`` wins over it.
    shard_axis: mesh axis name to shard the reduction (k) dim over, or
        None. Consumed by ``parallel.ozaki_shard`` / the serving layer.
    comm: "f64" (GSPMD moves f64 operand words around the sharded GEMM)
        | "int8" (ship the packed int8-slice representation / exact
        int32 partials instead — ``parallel.ozaki_shard`` explicit
        collective schedules). Result-invariant; ignored unless a shard
        axis and mesh are in play.
    ell_acc / ell_in: accumulator / input mantissa widths (Table 2).
    interpret: run Pallas kernels in interpret mode (CPU validation).
    tile: optional TilePlan with per-stage block shapes (core.tuning).
    """

    num_splits: int = 9
    accum: str = "f64"
    backend: str = "xla"
    fuse_epilogue: bool = False
    streaming: bool = False
    fuse_diagonals: bool = True
    concat_k: bool = False
    full_pairs: bool = False
    pair_policy: str = "full"
    target_error: Optional[float] = None
    fast_mode: bool = False
    shard_axis: Optional[str] = None
    comm: str = "f64"
    ell_acc: int = 31
    ell_in: int = 7
    interpret: bool = True
    tile: Optional[TilePlan] = None

    def width_for(self, k: int) -> int:
        fuse_terms = self.max_fuse_terms if (self.fuse_diagonals or
                                             self.concat_k) else 1
        return slice_width(k, ell_acc=self.ell_acc, ell_in=self.ell_in,
                           fuse_terms=fuse_terms)

    @property
    def max_fuse_terms(self) -> int:
        # longest anti-diagonal: i+j = s+1 has s pairs (full: s as well)
        return self.num_splits

    def diagonals(self) -> Sequence[tuple[int, Sequence[tuple[int, int]]]]:
        """0-based (t, [(p, q)...]) groups with t = p + q ascending."""
        return diagonal_groups(
            self.num_splits, self.full_pairs,
            pair_budget=parse_pair_policy(self.pair_policy, self.num_splits,
                                          self.full_pairs))

    @property
    def num_gemms(self) -> int:
        return sum(len(p) for _, p in self.diagonals())

    def plan(self, batch_layout: str = "none") -> PipelinePlan:
        """The PipelinePlan this config resolves to (see ``tuning``)."""
        return plan_for(self, batch_layout=batch_layout)


# ----------------------------------------------------------------------------
# Driver helpers
# ----------------------------------------------------------------------------

def resolve_accuracy_config(cfg: OzakiConfig, k: int) -> OzakiConfig:
    """Resolve ``target_error``/``fast_mode`` into static schedule knobs.

    Shape-only (uses k, never the operand values), so the result is
    trace-stable: the drivers call it once per GEMM shape before sizing
    the split width. ``num_splits`` is only ever REDUCED (the configured
    count is the quality ceiling); the resolved ``pair_policy`` replaces
    a "full" policy when fast mode asks for truncation. No-op when
    neither knob is set.
    """
    if cfg.target_error is None and not cfg.fast_mode:
        return cfg
    from .accuracy import resolve_accuracy         # lazy: keeps core light
    s, policy = resolve_accuracy(
        k, cfg.num_splits, target_error=cfg.target_error,
        fast_mode=cfg.fast_mode, pair_policy=cfg.pair_policy,
        ell_acc=cfg.ell_acc, ell_in=cfg.ell_in,
        fuse=cfg.fuse_diagonals or cfg.concat_k, full_pairs=cfg.full_pairs)
    if s == cfg.num_splits and policy == cfg.pair_policy:
        return cfg
    return dataclasses.replace(cfg, num_splits=s, pair_policy=policy)


def _e_base(ea: jax.Array, eb: jax.Array) -> jax.Array:
    """Deferred per-element exponent: broadcast outer sum (int32).

    ea: (..., m) row exponents of A; eb: (..., n) row exponents of B^T.
    """
    return (ea[..., :, None].astype(jnp.int32) +
            eb[..., None, :].astype(jnp.int32))


def _from_dw(out, cfg: OzakiConfig):
    """df32 accumulator -> the f64 the paper-mode entry points return."""
    if cfg.accum == "f64":
        return out
    return out.hi.astype(jnp.float64) + out.lo.astype(jnp.float64)


def _check_dw_schedule(cfg: OzakiConfig, w: int) -> None:
    if (cfg.num_splits + 1) * w > 120:
        raise ValueError("split schedule underflows f32 scale range")


def _fold_rows(split_fn, x3, w: int) -> SplitResult:
    """Split a (B, r, k) stack by folding the batch into rows (exact:
    exponents, slices and accumulation are all row-independent)."""
    if isinstance(x3, DW):
        bsz, r, k = x3.hi.shape
        res = split_fn(DW(x3.hi.reshape(bsz * r, k),
                          x3.lo.reshape(bsz * r, k)), w)
    else:
        bsz, r, k = x3.shape
        res = split_fn(x3.reshape(bsz * r, k), w)
    if isinstance(res, StreamingSplit):
        # nothing was split: un-fold the carried operand words so the
        # batch-grid streaming kernels see (B, r, k) / (B, r) blocks
        return StreamingSplit(res.hi.reshape(bsz, r, k),
                              res.lo.reshape(bsz, r, k),
                              res.exp.reshape(bsz, r), res.w)
    s = res.slices.shape[0]
    return SplitResult(res.slices.reshape(s, bsz, r, k),
                       res.exp.reshape(bsz, r), res.w)


# ----------------------------------------------------------------------------
# Core drivers
# ----------------------------------------------------------------------------

def ozaki_matmul(a: jax.Array, b: jax.Array,
                 cfg: OzakiConfig = OzakiConfig()) -> jax.Array:
    """FP64-accurate C = A @ B via int8 GEMMs. A: (m, k) f64, B: (k, n) f64."""
    if a.dtype != jnp.float64:
        raise TypeError("ozaki_matmul takes float64; use ozaki_matmul_dw for "
                        "the TPU df32 path")
    k = a.shape[1]
    cfg = resolve_accuracy_config(cfg, k)
    w = cfg.width_for(k)
    ex = get_executor(cfg.plan())
    sa = ex.split(a, w)
    sb = ex.split(b.T, w)
    out = ex.contract(sa, sb, w, _e_base(sa.exp, sb.exp),
                      (a.shape[0], b.shape[1]))
    return _from_dw(out, cfg)


def ozaki_matmul_dw(a: DW, b_t: DW, cfg: OzakiConfig = OzakiConfig()) -> DW:
    """TPU-native path: df32 in, df32 out. ``b_t`` is B TRANSPOSED (n, k).

    Runs entirely in {int8, int32, f32}: deployable on hardware with no
    FP64 units. The number of splits should satisfy
    (num_splits + 1) * w <= 120 so all scales stay in f32 normal range.
    """
    if cfg.accum != "df32":
        cfg = dataclasses.replace(cfg, accum="df32")   # dw path IS df32
    k = a.shape[1]
    cfg = resolve_accuracy_config(cfg, k)
    w = cfg.width_for(k)
    _check_dw_schedule(cfg, w)
    ex = get_executor(cfg.plan())
    sa = ex.split_dw(a, w)
    sb = ex.split_dw(b_t, w)
    return ex.contract(sa, sb, w, _e_base(sa.exp, sb.exp),
                       (a.shape[0], b_t.shape[0]))


# ----------------------------------------------------------------------------
# Batched API: (B, m, k) @ (B, k, n), or (B, m, k) @ (k, n) broadcast weights
# ----------------------------------------------------------------------------

def _matmul_any(a: jax.Array, b: jax.Array, cfg: OzakiConfig) -> jax.Array:
    """Unbatched dispatch on input dtype: f64 paper path or f32 dw path."""
    if a.dtype == jnp.float64:
        return ozaki_matmul(a, b, cfg)
    out = ozaki_matmul_dw(DW(a, jnp.zeros_like(a)),
                          DW(b.T, jnp.zeros_like(b.T)), cfg)
    return dw_to_single(out)


def _batched_grid(a: jax.Array, b: jax.Array, cfg: OzakiConfig) -> jax.Array:
    """Fully-batched pipeline with an explicit batch dimension.

    Split folds the stack into rows, the GEMMs run the batch-grid kernel
    (Pallas backends) or a batch-dim dot_general (xla), accumulation
    broadcasts the (B, m, n) exponent base — bitwise identical to a
    Python loop over the unbatched pipeline.
    """
    f64 = a.dtype == jnp.float64
    if not f64 and cfg.accum != "df32":
        cfg = dataclasses.replace(cfg, accum="df32")
    bsz, m, k = a.shape
    n = b.shape[-1]
    cfg = resolve_accuracy_config(cfg, k)
    w = cfg.width_for(k)
    if not f64:
        _check_dw_schedule(cfg, w)
    ex = get_executor(cfg.plan(batch_layout="grid"))
    b_t = jnp.swapaxes(b, 1, 2)                        # (B, n, k)
    if f64:
        sa = _fold_rows(ex.split, a, w)
        sb = _fold_rows(ex.split, b_t, w)
    else:
        sa = _fold_rows(ex.split_dw, DW(a, jnp.zeros_like(a)), w)
        sb = _fold_rows(ex.split_dw, DW(b_t, jnp.zeros_like(b_t)), w)
    out = ex.contract(sa, sb, w, _e_base(sa.exp, sb.exp), (bsz, m, n))
    if f64:
        return _from_dw(out, cfg)
    return dw_to_single(out)


@functools.partial(jax.custom_jvp, nondiff_argnums=(2,))
def _batched_core(a: jax.Array, b: jax.Array, cfg: OzakiConfig) -> jax.Array:
    if b.ndim == 2:
        # Broadcast weights: fold the batch into rows. Exact — exponents,
        # slices and accumulation are all row-independent, so this equals
        # a loop over ``ozaki_matmul`` bitwise (and is one big MXU GEMM).
        bsz, m, k = a.shape
        out = _matmul_any(a.reshape(bsz * m, k), b, cfg)
        return out.reshape(bsz, m, b.shape[1])
    return _batched_grid(a, b, cfg)


@_batched_core.defjvp
def _batched_core_jvp(cfg, primals, tangents):
    a, b = primals
    da, db = tangents
    primal = _batched_core(a, b, cfg)
    # The scheme reproduces the exact product, so the product rule applies
    # verbatim. Tangents run on the plain matmul (they need only the
    # working precision of the inputs, not the emulated one).
    tangent = (jnp.matmul(da, b, preferred_element_type=a.dtype) +
               jnp.matmul(a, db, preferred_element_type=a.dtype))
    return primal, tangent.astype(primal.dtype)


def ozaki_matmul_batched(a: jax.Array, b: jax.Array,
                         cfg: OzakiConfig = OzakiConfig()) -> jax.Array:
    """Batched Ozaki GEMM: ``C[i] = A[i] @ B[i]`` (or shared ``B``).

    a: (B, m, k); b: (B, k, n), or (k, n) to broadcast one weight matrix
    over the batch (the serving case). f64 inputs follow ``cfg.accum``;
    f32 inputs run the TPU-native df32 pipeline and return f32. The
    result is differentiable (exact-product JVP) and jit-stable — pass
    ``cfg`` statically when jitting.
    """
    if a.ndim != 3:
        raise ValueError(f"a must be (batch, m, k), got {a.shape}")
    if b.ndim not in (2, 3):
        raise ValueError(f"b must be (k, n) or (batch, k, n), got {b.shape}")
    if b.ndim == 3 and a.shape[0] != b.shape[0]:
        raise ValueError(f"batch mismatch: {a.shape} vs {b.shape}")
    if a.shape[-1] != b.shape[-2]:
        raise ValueError(f"contraction mismatch: {a.shape} vs {b.shape}")
    if a.dtype != b.dtype:
        raise TypeError(f"dtype mismatch: {a.dtype} vs {b.dtype}")
    return _batched_core(a, b, cfg)


# ----------------------------------------------------------------------------
# Complex GEMM (quantum-circuit simulation support, Sec. 4.4)
# ----------------------------------------------------------------------------

def ozaki_matmul_complex(a: jax.Array, b: jax.Array,
                         cfg: OzakiConfig = OzakiConfig(),
                         algo: str = "4mul") -> jax.Array:
    """complex128 C = A @ B with real/imag separated at split time.

    ``algo="4mul"``: Cr = ArBr - AiBi, Ci = ArBi + AiBr (paper's approach —
    each of the 4 real matrices is split exactly once, products reused).
    ``algo="3mul"``: Karatsuba, one fewer real GEMM group at slightly wider
    exponent range (beyond-paper option).
    """
    ar, ai = jnp.real(a), jnp.imag(a)
    br, bi = jnp.real(b), jnp.imag(b)
    k = a.shape[1]
    cfg = resolve_accuracy_config(cfg, k)
    w = cfg.width_for(k)
    ex = get_executor(cfg.plan())

    def real_mm(x_split, y_split, shape):
        out = ex.contract(x_split, y_split, w,
                          _e_base(x_split.exp, y_split.exp), shape)
        return _from_dw(out, cfg)

    def split(x):
        return ex.split(x, w)

    shape = (a.shape[0], b.shape[1])
    if algo == "3mul":
        s_ar = split(ar)
        s_ai = split(ai)
        s_as = split(ar + ai)
        s_br = split(br.T)
        s_bi = split(bi.T)
        s_bs = split((br + bi).T)
        p1 = real_mm(s_ar, s_br, shape)
        p2 = real_mm(s_ai, s_bi, shape)
        p3 = real_mm(s_as, s_bs, shape)
        return jax.lax.complex(p1 - p2, p3 - p1 - p2)

    s_ar = split(ar)
    s_ai = split(ai)
    s_br = split(br.T)
    s_bi = split(bi.T)
    c_r = real_mm(s_ar, s_br, shape) - real_mm(s_ai, s_bi, shape)
    c_i = real_mm(s_ar, s_bi, shape) + real_mm(s_ai, s_br, shape)
    return jax.lax.complex(c_r, c_i)


# ----------------------------------------------------------------------------
# Reference paths for comparison (the paper's baselines)
# ----------------------------------------------------------------------------

def dgemm_f64(a: jax.Array, b: jax.Array) -> jax.Array:
    """Plain FP64 GEMM (cuBLAS-DGEMM stand-in on CPU)."""
    return jnp.dot(a, b, preferred_element_type=jnp.float64)


def gemm_fp32_pass(a: jax.Array, b: jax.Array) -> jax.Array:
    """Naive single-f32 GEMM of f64 data — the accuracy anti-baseline."""
    return jnp.dot(a.astype(jnp.float32),
                   b.astype(jnp.float32)).astype(jnp.float64)
