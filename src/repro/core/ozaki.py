"""Ozaki-scheme GEMM on integer matrix units — Algorithm 3 of the paper.

``ozaki_matmul`` computes an FP64-accurate ``C = A @ B`` using only int8
matrix multiplications with int32 accumulation (the TPU MXU int8 path) plus
a high-precision scaled accumulation of the slice products.

Accumulation modes:
  * ``accum="f64"``  — the paper's mode (CPU validation; x64 required).
  * ``accum="df32"`` — double-float32 accumulation, deployable on TPU
    (no FP64 hardware exists there); carries 48 mantissa bits.

Scheduling modes (see DESIGN.md §4):
  * paper-faithful: each slice pair (i, j) with i + j <= s + 1 is a
    separate int8 GEMM followed by a scaled high-precision accumulation —
    s(s+1)/2 GEMMs and as many accumulations (Alg. 3 verbatim).
  * ``fuse_diagonals`` (O1): pairs on an anti-diagonal share their scale,
    so their int32 products are summed exactly in int32 first; the number
    of high-precision accumulations drops to s. Requires slack bits in
    alpha (handled by ``compute_alpha(..., fuse_terms=...)``).
  * ``concat_k`` (O2): realizes each anti-diagonal sum as ONE int8 GEMM
    over a k-concatenated operand pair — fewer, larger MXU launches.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from .splitting import (SplitResult, compute_alpha, slice_width, split_int,
                        split_int_dw)
from .xmath import DW, dw_add, dw_normalize, fast_two_sum


@dataclasses.dataclass(frozen=True)
class OzakiConfig:
    """Configuration for one Ozaki GEMM.

    num_splits: s in the paper (INT8x{s}).
    accum: "f64" | "df32".
    backend: "xla" (lax.dot_general) | "pallas" (MXU kernel).
    fuse_diagonals: O1 — exact int32 pre-accumulation per anti-diagonal.
    concat_k: O2 — one GEMM per anti-diagonal via k-concatenation.
    full_pairs: compute all s*s pairs (paper computes i+j <= s+1 only).
    ell_acc / ell_in: accumulator / input mantissa widths (Table 2).
    interpret: run Pallas kernels in interpret mode (CPU validation).
    """

    num_splits: int = 9
    accum: str = "f64"
    backend: str = "xla"
    fuse_diagonals: bool = True
    concat_k: bool = False
    full_pairs: bool = False
    ell_acc: int = 31
    ell_in: int = 7
    interpret: bool = True

    def width_for(self, k: int) -> int:
        fuse_terms = self.max_fuse_terms if (self.fuse_diagonals or
                                             self.concat_k) else 1
        return slice_width(k, ell_acc=self.ell_acc, ell_in=self.ell_in,
                           fuse_terms=fuse_terms)

    @property
    def max_fuse_terms(self) -> int:
        # longest anti-diagonal: i+j = s+1 has s pairs (full: s as well)
        return self.num_splits

    def diagonals(self) -> Sequence[tuple[int, Sequence[tuple[int, int]]]]:
        """0-based (t, [(p, q)...]) groups with t = p + q ascending."""
        s = self.num_splits
        t_max = 2 * s - 2 if self.full_pairs else s - 1
        out = []
        for t in range(t_max + 1):
            pairs = [(p, t - p) for p in range(max(0, t - s + 1),
                                               min(s - 1, t) + 1)]
            out.append((t, pairs))
        return out

    @property
    def num_gemms(self) -> int:
        return sum(len(p) for _, p in self.diagonals())


# ----------------------------------------------------------------------------
# int8 GEMM backends: (m,k) int8 x (n,k) int8 -> (m,n) int32, contract on k
# ----------------------------------------------------------------------------

def _gemm_xla(a8: jax.Array, bt8: jax.Array) -> jax.Array:
    return jax.lax.dot_general(
        a8, bt8, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)


def _get_gemm(cfg: OzakiConfig) -> Callable[[jax.Array, jax.Array], jax.Array]:
    if cfg.backend == "pallas":
        from repro.kernels import int8_gemm
        return functools.partial(int8_gemm.int8_matmul_nt,
                                 interpret=cfg.interpret)
    return _gemm_xla


# ----------------------------------------------------------------------------
# int32 -> df32 exact conversion (no int64 anywhere: TPU/x32 safe)
# ----------------------------------------------------------------------------

def int32_to_dw(p: jax.Array) -> DW:
    low = jnp.bitwise_and(p, jnp.int32(0xFFFF))        # [0, 65535]
    high = p - low                                      # multiple of 2^16
    hi_f = high.astype(jnp.float32)                     # <= 15 sig bits: exact
    lo_f = low.astype(jnp.float32)                      # <= 16 sig bits: exact
    return dw_normalize(hi_f, lo_f)


# ----------------------------------------------------------------------------
# Core driver
# ----------------------------------------------------------------------------

def _pair_products(sa: SplitResult, sb: SplitResult, cfg: OzakiConfig,
                   gemm) -> list[tuple[int, jax.Array]]:
    """Return [(t, P_t int32)] per anti-diagonal, smallest scale first."""
    out = []
    for t, pairs in cfg.diagonals():
        if cfg.concat_k:
            a_cat = jnp.concatenate([sa.slices[p] for p, _ in pairs], axis=1)
            b_cat = jnp.concatenate([sb.slices[q] for _, q in pairs], axis=1)
            p_t = gemm(a_cat, b_cat)
        elif cfg.fuse_diagonals:
            p_t = gemm(sa.slices[pairs[0][0]], sb.slices[pairs[0][1]])
            for p, q in pairs[1:]:
                p_t = p_t + gemm(sa.slices[p], sb.slices[q])
        else:
            # paper-faithful: keep pair products separate (caller scales each)
            for p, q in pairs:
                out.append((t, gemm(sa.slices[p], sb.slices[q])))
            continue
        out.append((t, p_t))
    return out


def _accum_f64(products, sa, sb, w, shape):
    c = jnp.zeros(shape, jnp.float64)
    e_base = sa.exp[:, None].astype(jnp.int32) + sb.exp[None, :].astype(jnp.int32)
    for t, p_t in sorted(products, key=lambda tp: -tp[0]):  # small terms first
        c = c + jnp.ldexp(p_t.astype(jnp.float64), e_base - (t + 2) * w)
    return c


def _accum_df32(products, sa, sb, w, shape) -> DW:
    acc = DW(jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32))
    for t, p_t in sorted(products, key=lambda tp: -tp[0]):
        scale = jnp.float32(2.0 ** (-(t + 2) * w))      # exact power of two
        term = int32_to_dw(p_t)
        acc = dw_add(acc, DW(term.hi * scale, term.lo * scale))
    e_base = sa.exp[:, None] + sb.exp[None, :]
    hi = jnp.ldexp(acc.hi, e_base)
    lo = jnp.ldexp(acc.lo, e_base)
    return DW(hi, lo)


def ozaki_matmul(a: jax.Array, b: jax.Array,
                 cfg: OzakiConfig = OzakiConfig()) -> jax.Array:
    """FP64-accurate C = A @ B via int8 GEMMs. A: (m, k) f64, B: (k, n) f64."""
    if a.dtype != jnp.float64:
        raise TypeError("ozaki_matmul takes float64; use ozaki_matmul_dw for "
                        "the TPU df32 path")
    k = a.shape[1]
    w = cfg.width_for(k)
    sa = split_int(a, cfg.num_splits, w)
    sb = split_int(b.T, cfg.num_splits, w)
    gemm = _get_gemm(cfg)
    products = _pair_products(sa, sb, cfg, gemm)
    if cfg.accum == "f64":
        return _accum_f64(products, sa, sb, w, (a.shape[0], b.shape[1]))
    dw = _accum_df32(products, sa, sb, w, (a.shape[0], b.shape[1]))
    return dw.hi.astype(jnp.float64) + dw.lo.astype(jnp.float64)


def ozaki_matmul_dw(a: DW, b_t: DW, cfg: OzakiConfig = OzakiConfig()) -> DW:
    """TPU-native path: df32 in, df32 out. ``b_t`` is B TRANSPOSED (n, k).

    Runs entirely in {int8, int32, f32}: deployable on hardware with no
    FP64 units. The number of splits should satisfy
    (num_splits + 1) * w <= 120 so all scales stay in f32 normal range.
    """
    k = a.shape[1]
    w = cfg.width_for(k)
    if (cfg.num_splits + 1) * w > 120:
        raise ValueError("split schedule underflows f32 scale range")
    sa = split_int_dw(a, cfg.num_splits, w)
    sb = split_int_dw(b_t, cfg.num_splits, w)
    gemm = _get_gemm(cfg)
    products = _pair_products(sa, sb, cfg, gemm)
    return _accum_df32(products, sa, sb, w, (a.shape[0], b_t.shape[0]))


# ----------------------------------------------------------------------------
# Complex GEMM (quantum-circuit simulation support, Sec. 4.4)
# ----------------------------------------------------------------------------

def ozaki_matmul_complex(a: jax.Array, b: jax.Array,
                         cfg: OzakiConfig = OzakiConfig(),
                         algo: str = "4mul") -> jax.Array:
    """complex128 C = A @ B with real/imag separated at split time.

    ``algo="4mul"``: Cr = ArBr - AiBi, Ci = ArBi + AiBr (paper's approach —
    each of the 4 real matrices is split exactly once, products reused).
    ``algo="3mul"``: Karatsuba, one fewer real GEMM group at slightly wider
    exponent range (beyond-paper option).
    """
    ar, ai = jnp.real(a), jnp.imag(a)
    br, bi = jnp.real(b), jnp.imag(b)
    k = a.shape[1]
    w = cfg.width_for(k)
    gemm = _get_gemm(cfg)

    def real_mm(x_split, y_split, shape):
        products = _pair_products(x_split, y_split, cfg, gemm)
        if cfg.accum == "f64":
            return _accum_f64(products, x_split, y_split, w, shape)
        dw = _accum_df32(products, x_split, y_split, w, shape)
        return dw.hi.astype(jnp.float64) + dw.lo.astype(jnp.float64)

    shape = (a.shape[0], b.shape[1])
    if algo == "3mul":
        s_ar = split_int(ar, cfg.num_splits, w)
        s_ai = split_int(ai, cfg.num_splits, w)
        s_as = split_int(ar + ai, cfg.num_splits, w)
        s_br = split_int(br.T, cfg.num_splits, w)
        s_bi = split_int(bi.T, cfg.num_splits, w)
        s_bs = split_int((br + bi).T, cfg.num_splits, w)
        p1 = real_mm(s_ar, s_br, shape)
        p2 = real_mm(s_ai, s_bi, shape)
        p3 = real_mm(s_as, s_bs, shape)
        return jax.lax.complex(p1 - p2, p3 - p1 - p2)

    s_ar = split_int(ar, cfg.num_splits, w)
    s_ai = split_int(ai, cfg.num_splits, w)
    s_br = split_int(br.T, cfg.num_splits, w)
    s_bi = split_int(bi.T, cfg.num_splits, w)
    c_r = real_mm(s_ar, s_br, shape) - real_mm(s_ai, s_bi, shape)
    c_i = real_mm(s_ar, s_bi, shape) + real_mm(s_ai, s_br, shape)
    return jax.lax.complex(c_r, c_i)


# ----------------------------------------------------------------------------
# Reference paths for comparison (the paper's baselines)
# ----------------------------------------------------------------------------

def dgemm_f64(a: jax.Array, b: jax.Array) -> jax.Array:
    """Plain FP64 GEMM (cuBLAS-DGEMM stand-in on CPU)."""
    return jnp.dot(a, b, preferred_element_type=jnp.float64)


def gemm_fp32_pass(a: jax.Array, b: jax.Array) -> jax.Array:
    """Naive single-f32 GEMM of f64 data — the accuracy anti-baseline."""
    return jnp.dot(a.astype(jnp.float32),
                   b.astype(jnp.float32)).astype(jnp.float64)
