"""Ozaki-scheme GEMM on integer matrix units — Algorithm 3 of the paper.

``ozaki_matmul`` computes an FP64-accurate ``C = A @ B`` using only int8
matrix multiplications with int32 accumulation (the TPU MXU int8 path) plus
a high-precision scaled accumulation of the slice products.

The driver is a three-stage pipeline — split, slice GEMMs, accumulate —
and each stage dispatches on ``OzakiConfig.backend``:

  * ``xla``          — every stage as composite XLA ops (lax primitives).
    The reference path: s-pass splitting, dot_general GEMMs, multi-op
    accumulation.
  * ``pallas``       — the int8 GEMMs run on the Pallas MXU kernel; split
    and accumulation stay XLA ops.
  * ``pallas_fused`` — the full fused pipeline: one-pass SplitInt kernel
    (all s slices per HBM read), Pallas MXU GEMMs, and the fused scaled
    accumulation kernel (int32→float convert + scale + compensated add in
    one VMEM pass). This is the deployment path; the memory-bound split
    and accumulate stages the paper's Fig. 9 profiles drop from s-pass /
    5-pass to 1-pass / 3-pass (see ``core.tuning.hbm_pass_model``).
    Results are bitwise identical to ``xla`` for both accumulation modes
    (the kernels run the same rounding sequences).

Accumulation modes:
  * ``accum="f64"``  — the paper's mode (CPU validation; x64 required).
  * ``accum="df32"`` — double-float32 accumulation, deployable on TPU
    (no FP64 hardware exists there); carries 48 mantissa bits.

Scheduling modes (see DESIGN.md §4):
  * paper-faithful: each slice pair (i, j) with i + j <= s + 1 is a
    separate int8 GEMM followed by a scaled high-precision accumulation —
    s(s+1)/2 GEMMs and as many accumulations (Alg. 3 verbatim).
  * ``fuse_diagonals`` (O1): pairs on an anti-diagonal share their scale,
    so their int32 products are summed exactly in int32 first; the number
    of high-precision accumulations drops to s. Requires slack bits in
    alpha (handled by ``compute_alpha(..., fuse_terms=...)``).
  * ``concat_k`` (O2): realizes each anti-diagonal sum as ONE int8 GEMM
    over a k-concatenated operand pair — fewer, larger MXU launches.

Batched entry point: ``ozaki_matmul_batched`` handles ``(B, m, k) @
(B, k, n)`` stacks and the serving case ``(B, m, k) @ (k, n)`` (broadcast
weights). Broadcast weights collapse the batch into rows — one big GEMM,
bitwise identical to a Python loop over ``ozaki_matmul`` because every
per-row quantity (exponent, slices, accumulation) is row-independent.
Fully-batched operands go through ``jax.vmap`` over the pipeline (all
three Pallas kernels are vmap-compatible; the batch becomes a leading
grid dimension). Gradients are defined via ``jax.custom_jvp`` with the
exact-product rule ``dC = dA·B + A·dB`` — correct because the scheme is
an error-free rewrite of the true product, not a lossy quantizer.

Block shapes and split counts for the Pallas paths come from
``OzakiConfig.tile`` (a ``core.tuning.TilePlan``); ``tile=None`` uses the
kernels' MXU-aligned defaults.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from .splitting import (SplitResult, row_exponents, slice_width, split_int,
                        split_int_dw)
from .tuning import TilePlan
from .xmath import DW, dw_add, dw_normalize, dw_to_single

BACKENDS = ("xla", "pallas", "pallas_fused")


@dataclasses.dataclass(frozen=True)
class OzakiConfig:
    """Configuration for one Ozaki GEMM.

    num_splits: s in the paper (INT8x{s}).
    accum: "f64" | "df32".
    backend: "xla" (lax ops) | "pallas" (MXU GEMM kernel only) |
        "pallas_fused" (full split/GEMM/accumulate kernel pipeline).
    fuse_diagonals: O1 — exact int32 pre-accumulation per anti-diagonal.
    concat_k: O2 — one GEMM per anti-diagonal via k-concatenation.
    full_pairs: compute all s*s pairs (paper computes i+j <= s+1 only).
    ell_acc / ell_in: accumulator / input mantissa widths (Table 2).
    interpret: run Pallas kernels in interpret mode (CPU validation).
    tile: optional TilePlan with per-stage block shapes (core.tuning).
    """

    num_splits: int = 9
    accum: str = "f64"
    backend: str = "xla"
    fuse_diagonals: bool = True
    concat_k: bool = False
    full_pairs: bool = False
    ell_acc: int = 31
    ell_in: int = 7
    interpret: bool = True
    tile: Optional[TilePlan] = None

    def width_for(self, k: int) -> int:
        fuse_terms = self.max_fuse_terms if (self.fuse_diagonals or
                                             self.concat_k) else 1
        return slice_width(k, ell_acc=self.ell_acc, ell_in=self.ell_in,
                           fuse_terms=fuse_terms)

    @property
    def max_fuse_terms(self) -> int:
        # longest anti-diagonal: i+j = s+1 has s pairs (full: s as well)
        return self.num_splits

    def diagonals(self) -> Sequence[tuple[int, Sequence[tuple[int, int]]]]:
        """0-based (t, [(p, q)...]) groups with t = p + q ascending."""
        s = self.num_splits
        t_max = 2 * s - 2 if self.full_pairs else s - 1
        out = []
        for t in range(t_max + 1):
            pairs = [(p, t - p) for p in range(max(0, t - s + 1),
                                               min(s - 1, t) + 1)]
            out.append((t, pairs))
        return out

    @property
    def num_gemms(self) -> int:
        return sum(len(p) for _, p in self.diagonals())


# ----------------------------------------------------------------------------
# Stage 1 — split: f64/df32 matrix -> (s, m, k) int8 slices + row exponents
# ----------------------------------------------------------------------------

def _split_stage(m: jax.Array, cfg: OzakiConfig, w: int) -> SplitResult:
    """Split a single-word float matrix (rows share the exponent)."""
    if cfg.backend != "pallas_fused":
        return split_int(m, cfg.num_splits, w)
    from repro.kernels import fused_split_dw
    exp = row_exponents(m)
    kw = {} if cfg.tile is None else {"bm": cfg.tile.split_bm,
                                      "bk": cfg.tile.split_bk}
    slices = fused_split_dw(m, jnp.zeros_like(m), exp,
                            num_splits=cfg.num_splits, w=w,
                            interpret=cfg.interpret, **kw)
    return SplitResult(slices, exp, w)


def _split_stage_dw(m: DW, cfg: OzakiConfig, w: int) -> SplitResult:
    """Split a double-word (df32) matrix."""
    if cfg.backend != "pallas_fused":
        return split_int_dw(m, cfg.num_splits, w)
    from repro.kernels import fused_split_dw
    exp = row_exponents(m.hi)
    kw = {} if cfg.tile is None else {"bm": cfg.tile.split_bm,
                                      "bk": cfg.tile.split_bk}
    slices = fused_split_dw(m.hi, m.lo, exp, num_splits=cfg.num_splits,
                            w=w, interpret=cfg.interpret, **kw)
    return SplitResult(slices, exp, w)


# ----------------------------------------------------------------------------
# Stage 2 — int8 GEMMs: (m,k) int8 x (n,k) int8 -> (m,n) int32, contract on k
# ----------------------------------------------------------------------------

def _gemm_xla(a8: jax.Array, bt8: jax.Array) -> jax.Array:
    return jax.lax.dot_general(
        a8, bt8, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)


def _get_gemm(cfg: OzakiConfig) -> Callable[[jax.Array, jax.Array], jax.Array]:
    if cfg.backend in ("pallas", "pallas_fused"):
        from repro.kernels import int8_gemm
        kw = {"interpret": cfg.interpret}
        if cfg.tile is not None:
            kw.update(bm=cfg.tile.bm, bn=cfg.tile.bn, bk=cfg.tile.bk)
        return functools.partial(int8_gemm.int8_matmul_nt, **kw)
    if cfg.backend != "xla":
        raise ValueError(f"unknown backend {cfg.backend!r}; "
                         f"expected one of {BACKENDS}")
    return _gemm_xla


def _pair_products(sa: SplitResult, sb: SplitResult, cfg: OzakiConfig,
                   gemm) -> list[tuple[int, jax.Array]]:
    """Return [(t, P_t int32)] per anti-diagonal, smallest scale first."""
    out = []
    for t, pairs in cfg.diagonals():
        if cfg.concat_k:
            a_cat = jnp.concatenate([sa.slices[p] for p, _ in pairs], axis=1)
            b_cat = jnp.concatenate([sb.slices[q] for _, q in pairs], axis=1)
            p_t = gemm(a_cat, b_cat)
        elif cfg.fuse_diagonals:
            p_t = gemm(sa.slices[pairs[0][0]], sb.slices[pairs[0][1]])
            for p, q in pairs[1:]:
                p_t = p_t + gemm(sa.slices[p], sb.slices[q])
        else:
            # paper-faithful: keep pair products separate (caller scales each)
            for p, q in pairs:
                out.append((t, gemm(sa.slices[p], sb.slices[q])))
            continue
        out.append((t, p_t))
    return out


# ----------------------------------------------------------------------------
# int32 -> df32 exact conversion (no int64 anywhere: TPU/x32 safe)
# ----------------------------------------------------------------------------

def int32_to_dw(p: jax.Array) -> DW:
    low = jnp.bitwise_and(p, jnp.int32(0xFFFF))        # [0, 65535]
    high = p - low                                      # multiple of 2^16
    hi_f = high.astype(jnp.float32)                     # <= 15 sig bits: exact
    lo_f = low.astype(jnp.float32)                      # <= 16 sig bits: exact
    return dw_normalize(hi_f, lo_f)


# ----------------------------------------------------------------------------
# Stage 3 — high-precision scaled accumulation (line 7 of Alg. 3)
# ----------------------------------------------------------------------------

def _ordered(products):
    return sorted(products, key=lambda tp: -tp[0])      # small terms first


def _accum_f64(products, sa, sb, w, shape):
    c = jnp.zeros(shape, jnp.float64)
    e_base = sa.exp[:, None].astype(jnp.int32) + sb.exp[None, :].astype(jnp.int32)
    for t, p_t in _ordered(products):
        c = c + jnp.ldexp(p_t.astype(jnp.float64), e_base - (t + 2) * w)
    return c


def _accum_df32(products, sa, sb, w, shape) -> DW:
    acc = DW(jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32))
    for t, p_t in _ordered(products):
        scale = jnp.float32(2.0 ** (-(t + 2) * w))      # exact power of two
        term = int32_to_dw(p_t)
        acc = dw_add(acc, DW(term.hi * scale, term.lo * scale))
    e_base = sa.exp[:, None] + sb.exp[None, :]
    hi = jnp.ldexp(acc.hi, e_base)
    lo = jnp.ldexp(acc.lo, e_base)
    return DW(hi, lo)


def _accum_fused_f64(products, sa, sb, w, shape, cfg):
    """Fused-kernel f64 accumulation — bitwise equal to ``_accum_f64``.

    The deferred per-element exponent is exact (power-of-two scaling
    commutes with rounding), so accumulating against the scalar
    ``2^{-(t+2)w}`` and applying ``ldexp(·, e_A + e_B)`` once reproduces
    the reference sum bit for bit.
    """
    from repro.kernels import accum_scaled_sw
    kw = {"interpret": cfg.interpret}
    if cfg.tile is not None:
        kw.update(bm=cfg.tile.accum_bm, bn=cfg.tile.accum_bn)
    c = jnp.zeros(shape, jnp.float64)
    for t, p_t in _ordered(products):
        c = accum_scaled_sw(p_t, c, scale=2.0 ** (-(t + 2) * w), **kw)
    e_base = sa.exp[:, None].astype(jnp.int32) + sb.exp[None, :].astype(jnp.int32)
    return jnp.ldexp(c, e_base)


def _accum_fused_df32(products, sa, sb, w, shape, cfg) -> DW:
    """Fused-kernel df32 accumulation — bitwise equal to ``_accum_df32``."""
    from repro.kernels import accum_scaled_dw
    kw = {"interpret": cfg.interpret}
    if cfg.tile is not None:
        kw.update(bm=cfg.tile.accum_bm, bn=cfg.tile.accum_bn)
    c_hi = jnp.zeros(shape, jnp.float32)
    c_lo = jnp.zeros(shape, jnp.float32)
    for t, p_t in _ordered(products):
        c_hi, c_lo = accum_scaled_dw(p_t, c_hi, c_lo,
                                     scale=2.0 ** (-(t + 2) * w), **kw)
    e_base = sa.exp[:, None] + sb.exp[None, :]
    return DW(jnp.ldexp(c_hi, e_base), jnp.ldexp(c_lo, e_base))


def _accum_stage(products, sa, sb, w, shape, cfg: OzakiConfig):
    """Dispatch the accumulation stage; returns f64 array or DW."""
    fused = cfg.backend == "pallas_fused"
    if cfg.accum == "f64":
        if fused:
            return _accum_fused_f64(products, sa, sb, w, shape, cfg)
        return _accum_f64(products, sa, sb, w, shape)
    if fused:
        return _accum_fused_df32(products, sa, sb, w, shape, cfg)
    return _accum_df32(products, sa, sb, w, shape)


# ----------------------------------------------------------------------------
# Core drivers
# ----------------------------------------------------------------------------

def ozaki_matmul(a: jax.Array, b: jax.Array,
                 cfg: OzakiConfig = OzakiConfig()) -> jax.Array:
    """FP64-accurate C = A @ B via int8 GEMMs. A: (m, k) f64, B: (k, n) f64."""
    if a.dtype != jnp.float64:
        raise TypeError("ozaki_matmul takes float64; use ozaki_matmul_dw for "
                        "the TPU df32 path")
    k = a.shape[1]
    w = cfg.width_for(k)
    sa = _split_stage(a, cfg, w)
    sb = _split_stage(b.T, cfg, w)
    gemm = _get_gemm(cfg)
    products = _pair_products(sa, sb, cfg, gemm)
    out = _accum_stage(products, sa, sb, w, (a.shape[0], b.shape[1]), cfg)
    if cfg.accum == "f64":
        return out
    return out.hi.astype(jnp.float64) + out.lo.astype(jnp.float64)


def ozaki_matmul_dw(a: DW, b_t: DW, cfg: OzakiConfig = OzakiConfig()) -> DW:
    """TPU-native path: df32 in, df32 out. ``b_t`` is B TRANSPOSED (n, k).

    Runs entirely in {int8, int32, f32}: deployable on hardware with no
    FP64 units. The number of splits should satisfy
    (num_splits + 1) * w <= 120 so all scales stay in f32 normal range.
    """
    if cfg.accum != "df32":
        cfg = dataclasses.replace(cfg, accum="df32")   # dw path IS df32
    k = a.shape[1]
    w = cfg.width_for(k)
    if (cfg.num_splits + 1) * w > 120:
        raise ValueError("split schedule underflows f32 scale range")
    sa = _split_stage_dw(a, cfg, w)
    sb = _split_stage_dw(b_t, cfg, w)
    gemm = _get_gemm(cfg)
    products = _pair_products(sa, sb, cfg, gemm)
    return _accum_stage(products, sa, sb, w, (a.shape[0], b_t.shape[0]), cfg)


# ----------------------------------------------------------------------------
# Batched API: (B, m, k) @ (B, k, n), or (B, m, k) @ (k, n) broadcast weights
# ----------------------------------------------------------------------------

def _matmul_any(a: jax.Array, b: jax.Array, cfg: OzakiConfig) -> jax.Array:
    """Unbatched dispatch on input dtype: f64 paper path or f32 dw path."""
    if a.dtype == jnp.float64:
        return ozaki_matmul(a, b, cfg)
    out = ozaki_matmul_dw(DW(a, jnp.zeros_like(a)),
                          DW(b.T, jnp.zeros_like(b.T)), cfg)
    return dw_to_single(out)


@functools.partial(jax.custom_jvp, nondiff_argnums=(2,))
def _batched_core(a: jax.Array, b: jax.Array, cfg: OzakiConfig) -> jax.Array:
    if b.ndim == 2:
        # Broadcast weights: fold the batch into rows. Exact — exponents,
        # slices and accumulation are all row-independent, so this equals
        # a loop over ``ozaki_matmul`` bitwise (and is one big MXU GEMM).
        bsz, m, k = a.shape
        out = _matmul_any(a.reshape(bsz * m, k), b, cfg)
        return out.reshape(bsz, m, b.shape[1])
    return jax.vmap(lambda x, y: _matmul_any(x, y, cfg))(a, b)


@_batched_core.defjvp
def _batched_core_jvp(cfg, primals, tangents):
    a, b = primals
    da, db = tangents
    primal = _batched_core(a, b, cfg)
    # The scheme reproduces the exact product, so the product rule applies
    # verbatim. Tangents run on the plain matmul (they need only the
    # working precision of the inputs, not the emulated one).
    tangent = (jnp.matmul(da, b, preferred_element_type=a.dtype) +
               jnp.matmul(a, db, preferred_element_type=a.dtype))
    return primal, tangent.astype(primal.dtype)


def ozaki_matmul_batched(a: jax.Array, b: jax.Array,
                         cfg: OzakiConfig = OzakiConfig()) -> jax.Array:
    """Batched Ozaki GEMM: ``C[i] = A[i] @ B[i]`` (or shared ``B``).

    a: (B, m, k); b: (B, k, n), or (k, n) to broadcast one weight matrix
    over the batch (the serving case). f64 inputs follow ``cfg.accum``;
    f32 inputs run the TPU-native df32 pipeline and return f32. The
    result is differentiable (exact-product JVP) and jit-stable — pass
    ``cfg`` statically when jitting.
    """
    if a.ndim != 3:
        raise ValueError(f"a must be (batch, m, k), got {a.shape}")
    if b.ndim not in (2, 3):
        raise ValueError(f"b must be (k, n) or (batch, k, n), got {b.shape}")
    if b.ndim == 3 and a.shape[0] != b.shape[0]:
        raise ValueError(f"batch mismatch: {a.shape} vs {b.shape}")
    if a.shape[-1] != b.shape[-2]:
        raise ValueError(f"contraction mismatch: {a.shape} vs {b.shape}")
    if a.dtype != b.dtype:
        raise TypeError(f"dtype mismatch: {a.dtype} vs {b.dtype}")
    return _batched_core(a, b, cfg)


# ----------------------------------------------------------------------------
# Complex GEMM (quantum-circuit simulation support, Sec. 4.4)
# ----------------------------------------------------------------------------

def ozaki_matmul_complex(a: jax.Array, b: jax.Array,
                         cfg: OzakiConfig = OzakiConfig(),
                         algo: str = "4mul") -> jax.Array:
    """complex128 C = A @ B with real/imag separated at split time.

    ``algo="4mul"``: Cr = ArBr - AiBi, Ci = ArBi + AiBr (paper's approach —
    each of the 4 real matrices is split exactly once, products reused).
    ``algo="3mul"``: Karatsuba, one fewer real GEMM group at slightly wider
    exponent range (beyond-paper option).
    """
    ar, ai = jnp.real(a), jnp.imag(a)
    br, bi = jnp.real(b), jnp.imag(b)
    k = a.shape[1]
    w = cfg.width_for(k)
    gemm = _get_gemm(cfg)

    def real_mm(x_split, y_split, shape):
        products = _pair_products(x_split, y_split, cfg, gemm)
        out = _accum_stage(products, x_split, y_split, w, shape, cfg)
        if cfg.accum == "f64":
            return out
        return out.hi.astype(jnp.float64) + out.lo.astype(jnp.float64)

    def split(x):
        return _split_stage(x, cfg, w)

    shape = (a.shape[0], b.shape[1])
    if algo == "3mul":
        s_ar = split(ar)
        s_ai = split(ai)
        s_as = split(ar + ai)
        s_br = split(br.T)
        s_bi = split(bi.T)
        s_bs = split((br + bi).T)
        p1 = real_mm(s_ar, s_br, shape)
        p2 = real_mm(s_ai, s_bi, shape)
        p3 = real_mm(s_as, s_bs, shape)
        return jax.lax.complex(p1 - p2, p3 - p1 - p2)

    s_ar = split(ar)
    s_ai = split(ai)
    s_br = split(br.T)
    s_bi = split(bi.T)
    c_r = real_mm(s_ar, s_br, shape) - real_mm(s_ai, s_bi, shape)
    c_i = real_mm(s_ar, s_bi, shape) + real_mm(s_ai, s_br, shape)
    return jax.lax.complex(c_r, c_i)


# ----------------------------------------------------------------------------
# Reference paths for comparison (the paper's baselines)
# ----------------------------------------------------------------------------

def dgemm_f64(a: jax.Array, b: jax.Array) -> jax.Array:
    """Plain FP64 GEMM (cuBLAS-DGEMM stand-in on CPU)."""
    return jnp.dot(a, b, preferred_element_type=jnp.float64)


def gemm_fp32_pass(a: jax.Array, b: jax.Array) -> jax.Array:
    """Naive single-f32 GEMM of f64 data — the accuracy anti-baseline."""
    return jnp.dot(a.astype(jnp.float32),
                   b.astype(jnp.float32)).astype(jnp.float64)
