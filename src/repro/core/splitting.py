"""SplitInt — Algorithm 4 of the paper, adapted for exact signed extraction.

Splits an ``m x k`` matrix row-wise into ``s`` int8 mantissa slices relative
to a shared per-row power-of-two exponent (block-float). The extraction is
*error-free*: with ``w`` bits per slice,

    M[i, j]  ==  2**exp[i] * sum_p slice[p, i, j] * 2**(-(p+1) * w)  +  tail

where ``tail`` is the (truncated) residual below the kept mantissa space.
Every slice value lies in ``[-2**w, 2**w - 1] ⊆ [-128, 127]``.

Implementation notes (documented in DESIGN.md):

* Extraction is sign-magnitude, exactly as the paper presents Alg. 4:
  the residual is kept nonnegative so ``t - floor(t)`` is exact in
  floating point (for a *negative* residual that subtraction needs one
  extra mantissa bit and silently rounds — a bug this module originally
  had, caught by the exact-reconstruction property test).
* The shared exponent is strictly greater than the row max
  (``2**(floor(log2 max) + 1)``), so the scaled residual is in [0, 1)
  and a slice magnitude never exceeds 2**w - 1 <= 127.
* ``alpha`` uses an exact integer overflow check ``k_terms * 2**(wa+wb)
  <= 2**31 - 1`` instead of Eq. (4)'s floor, which admits a one-off
  overflow corner at exact powers of two.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .xmath import DW, fast_two_sum, two_sum

INT8_MIN, INT8_MAX = -128, 127

# Shared exponent assigned to all-zero rows. Any finite value yields the
# correct all-zero slices (ldexp(0, -e) == 0); what matters is that the
# sentinel IS finite: a log2-style exponent of an empty row is -inf, and
# -inf reaching the 2**exp scales turns the whole pipeline into NaNs on
# the pinned jax (whose exp2 is additionally inexact at extreme
# arguments — ldexp with finite int32 exponents sidesteps both hazards).
# Zero-cancellation workloads (paper Fig. 7) and padded/sparse serving
# batches hit this case routinely.
ZERO_ROW_EXP = 0


class SplitResult(NamedTuple):
    """Result of SplitInt for one matrix (row-wise sharing).

    slices: (s, m, k) int8 mantissa slices, most significant first.
    exp:    (m,) int32 shared per-row exponents (value scale = 2**exp).
    w:      python int, bits kept per slice (BPS).
    """

    slices: jax.Array
    exp: jax.Array
    w: int


def compute_alpha(k: int, *, ell_acc: int = 31, fuse_terms: int = 1) -> int:
    """Max slice bit width avoiding accumulator overflow — Eq. (3)/(4).

    ``fuse_terms`` > 1 reserves headroom for summing that many slice-GEMM
    products exactly in the integer accumulator (diagonal fusion, O1).
    """
    if k <= 0:
        raise ValueError("k must be positive")
    limit = 2 ** ell_acc - 1
    alpha = (ell_acc - max(0, (k * fuse_terms - 1)).bit_length()) // 2
    # exact check (covers the power-of-two equality corner)
    while alpha > 0 and k * fuse_terms * 4 ** alpha > limit:
        alpha -= 1
    while k * fuse_terms * 4 ** (alpha + 1) <= limit:
        alpha += 1
    return alpha


def slice_width(k: int, *, ell_acc: int = 31, ell_in: int = 7,
                fuse_terms: int = 1) -> int:
    """BPS = min(alpha, ell_in) — Eq. (5)."""
    return max(1, min(compute_alpha(k, ell_acc=ell_acc, fuse_terms=fuse_terms),
                      ell_in))


def row_exponents(m: jax.Array) -> jax.Array:
    """Strict power-of-two row exponents: 2**exp > max_j |M_ij| (int32).

    All-zero rows are clamped to the finite ``ZERO_ROW_EXP`` sentinel —
    never a ``-inf``-style "empty max" exponent, which would propagate
    NaN/overflow through the power-of-two scales downstream (the split's
    ``ldexp``, the deferred ``e_base`` application, and the exponent
    statistics in ``core.accuracy``).
    """
    amax = jnp.max(jnp.abs(m), axis=-1)
    # frexp: x = mant * 2**e with mant in [0.5, 1)  ->  2**e >= |x|, strict
    # unless mant == 0.5 exactly (x a power of two), where 2**e == 2*x > x.
    _, e = jnp.frexp(amax)
    return jnp.where(amax > 0, e, ZERO_ROW_EXP).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("num_splits", "w"))
def split_int(m: jax.Array, num_splits: int, w: int,
              exp: jax.Array | None = None) -> SplitResult:
    """SplitInt on a float matrix (f64 on CPU, f32 acceptable for tests).

    Rows share the exponent; to split ``B`` column-wise pass ``B.T``.
    ``exp``: precomputed per-row exponents — the distributed path passes
    the global (all-reduced max) exponents so every k-shard splits against
    the same mantissa space.
    """
    if exp is None:
        exp = row_exponents(m)
    sign = jnp.where(m < 0, -1, 1).astype(jnp.int8)
    r = jnp.ldexp(jnp.abs(m), -exp[:, None]).astype(m.dtype)  # exact, [0, 1)
    scale = jnp.asarray(2.0 ** w, m.dtype)

    def body(r, _):
        t = r * scale                      # exact (power-of-two scale)
        y = jnp.floor(t)                   # in [0, 2**w - 1]
        r = t - y                          # exact: nonneg fraction suffix
        return r, (sign * y.astype(jnp.int8))

    _, slices = jax.lax.scan(body, r, None, length=num_splits)
    return SplitResult(slices, exp, w)


@functools.partial(jax.jit, static_argnames=("num_splits", "w"))
def split_int_dw(m: DW, num_splits: int, w: int) -> SplitResult:
    """SplitInt on a double-float32 matrix (the TPU-native input format).

    The residual is carried as an f32 pair; two_sum keeps the value exact
    and the signed floor self-corrects hi/lo boundary off-by-ones (the
    clip pushes any ±1 overflow back into the residual, also exactly).
    """
    exp = row_exponents(m.hi)  # |lo| <= ulp(hi)/2 cannot change the row max bit
    # sign of the pair == sign of hi (lo only refines hi's last bit),
    # except hi == 0 where lo is the value.
    neg = (m.hi < 0) | ((m.hi == 0) & (m.lo < 0))
    sign = jnp.where(neg, -1, 1).astype(jnp.int8)
    a_hi = jnp.where(neg, -m.hi, m.hi)
    a_lo = jnp.where(neg, -m.lo, m.lo)
    r_hi = jnp.ldexp(a_hi, -exp[:, None]).astype(jnp.float32)
    r_lo = jnp.ldexp(a_lo, -exp[:, None]).astype(jnp.float32)
    scale = jnp.float32(2.0 ** w)

    def body(carry, _):
        r_hi, r_lo = carry
        t = r_hi * scale                   # exact
        u = r_lo * scale                   # exact
        s, e = two_sum(t, u)               # exact: s + e == t + u
        # value (s + e) >= 0 but s alone may round a hair negative; a -1
        # slice self-corrects on the next step. Clip guards the +128 edge.
        y = jnp.clip(jnp.floor(s), INT8_MIN, INT8_MAX)
        f_hi, f_e = two_sum(s, -y)         # exact for any sign/magnitude
        n_hi, t1 = two_sum(f_hi, e)
        n_lo = t1 + f_e                    # rounds at ~2^-49 of the residual
        return (n_hi, n_lo), (sign * y.astype(jnp.int8))

    _, slices = jax.lax.scan(body, (r_hi, r_lo), None, length=num_splits)
    return SplitResult(slices, exp, w)


def reconstruct(res: SplitResult, dtype=jnp.float64) -> jax.Array:
    """Sum the slices back: the kept (truncated) part of the input."""
    s = res.slices.shape[0]
    out = jnp.zeros(res.slices.shape[1:], dtype)
    for p in range(s - 1, -1, -1):
        term = jnp.ldexp(res.slices[p].astype(dtype),
                         res.exp[:, None] - (p + 1) * res.w)
        out = out + term
    return out


def split_tail(m: jax.Array, res: SplitResult) -> jax.Array:
    """Residual left uncaptured by the slices (for AUTO loss estimation)."""
    return m - reconstruct(res, m.dtype)
