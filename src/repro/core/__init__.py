"""ozJAX core — the Ozaki scheme on integer matrix multiplication units."""
from .accuracy import (MAX_SPLITS, SchemeChoice, accum_floor, error_bound,
                       exponent_spread, input_truncation_eta, kept_pairs,
                       min_splits_for, pair_budget_for, required_splits,
                       resolve_accuracy, scaled_error, scheme_costs,
                       truncation_eta)
from .analytic import (ALL_MMUS, DGEMM_MANTISSA_SPACE, FP16_FP32, INT4_INT32,
                       INT8_INT32, INT12_INT32, MMUSpec, ozaki_flops,
                       ozaki_hp_accum_ops)
from .auto_split import auto_num_splits, auto_num_splits_complex
from .autotune import (AutotuneReport, PlanCache, PlanKey, autotune_plan,
                       candidate_plans, measure_plan, plan_cache_key,
                       use_plan_cache)
from .executors import (EpilogueExecutor, FusedExecutor,
                        ModularFusedExecutor, ModularPallasExecutor,
                        ModularXlaExecutor, PallasExecutor, XlaExecutor,
                        get_executor)
from .modular import (MAX_BETA, ModularConfig, ModularPoint, min_beta_for,
                      modular_error_bound, modular_eta, modular_plan,
                      ozaki2_matmul, ozaki2_matmul_batched, resolve_modular,
                      select_moduli, usable_moduli)
from .ozaki import (BACKENDS, OzakiConfig, dgemm_f64, gemm_fp32_pass,
                    int32_to_dw, ozaki_matmul, ozaki_matmul_batched,
                    ozaki_matmul_complex, ozaki_matmul_dw,
                    resolve_accuracy_config)
from .splitting import (SplitResult, compute_alpha, reconstruct, row_exponents,
                        slice_width, split_int, split_int_dw, split_tail)
from .tuning import (BATCH_LAYOUTS, FUSION_MODES, PAIR_POLICIES,
                     PLAN_SCHEMES, PipelinePlan,
                     TilePlan, apply_pipeline_plan, apply_plan,
                     diagonal_groups, hbm_pass_model, parse_pair_policy,
                     plan_for, plan_schedule_ok, reset_downgrade_warnings,
                     select_num_splits, select_plan, select_pipeline_plan)
from .xmath import (DW, dd_matmul_f64, dd_matmul_np, df32_from_f64,
                    df32_to_f64, dw_add, dw_add_single, dw_mul, dw_mul_single,
                    dw_normalize, dw_sub, dw_to_single, dw_zeros,
                    fast_two_sum, rel_error_vs_dd, two_prod, two_sum)

__all__ = [
    "ALL_MMUS", "AutotuneReport", "BACKENDS", "BATCH_LAYOUTS",
    "DGEMM_MANTISSA_SPACE", "DW", "MAX_BETA", "MAX_SPLITS",
    "ModularConfig", "ModularFusedExecutor", "ModularPallasExecutor",
    "ModularPoint", "ModularXlaExecutor", "PLAN_SCHEMES", "PAIR_POLICIES",
    "SchemeChoice", "min_beta_for", "modular_error_bound", "modular_eta",
    "modular_plan", "ozaki2_matmul", "ozaki2_matmul_batched",
    "resolve_modular", "scheme_costs", "select_moduli", "usable_moduli",
    "accum_floor", "error_bound", "exponent_spread", "input_truncation_eta",
    "kept_pairs", "min_splits_for", "pair_budget_for", "parse_pair_policy",
    "plan_schedule_ok", "required_splits", "reset_downgrade_warnings",
    "resolve_accuracy", "resolve_accuracy_config", "scaled_error",
    "truncation_eta",
    "EpilogueExecutor", "FP16_FP32", "FUSION_MODES", "FusedExecutor",
    "INT12_INT32", "INT4_INT32", "INT8_INT32", "MMUSpec", "OzakiConfig",
    "PallasExecutor", "PipelinePlan", "PlanCache", "PlanKey", "SplitResult",
    "TilePlan",
    "XlaExecutor", "apply_pipeline_plan", "apply_plan", "auto_num_splits",
    "auto_num_splits_complex", "autotune_plan", "candidate_plans",
    "compute_alpha", "dd_matmul_f64", "measure_plan", "plan_cache_key",
    "use_plan_cache",
    "dd_matmul_np", "df32_from_f64", "df32_to_f64", "dgemm_f64",
    "diagonal_groups", "dw_add", "dw_add_single", "dw_mul", "dw_mul_single",
    "dw_normalize", "dw_sub", "dw_to_single", "dw_zeros", "fast_two_sum",
    "gemm_fp32_pass", "get_executor", "hbm_pass_model", "int32_to_dw",
    "ozaki_flops", "ozaki_hp_accum_ops", "ozaki_matmul",
    "ozaki_matmul_batched", "ozaki_matmul_complex", "ozaki_matmul_dw",
    "plan_for", "reconstruct", "rel_error_vs_dd", "row_exponents",
    "select_num_splits", "select_pipeline_plan", "select_plan",
    "slice_width", "split_int", "split_int_dw", "split_tail", "two_prod",
    "two_sum",
]
