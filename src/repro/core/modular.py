"""Ozaki Scheme II — modular-arithmetic GEMM emulation (arXiv:2504.08009).

Scheme I (``core.ozaki``) splits each operand into ``s`` int8 mantissa
slices and pays up to ``s(s+1)/2`` slice-pair int8 GEMMs. Scheme II
rounds each operand to ``beta`` mantissa bits relative to its row
exponent — so the scaled operands are *integers* bounded by ``2^beta`` —
and computes the exact integer product ``C_int = A_int @ B_int^T`` in a
**residue number system**: one int8 GEMM per modulus, ``ell`` moduli
total, with ``ell`` growing *linearly* in the mantissa budget where
Scheme I's pair count grows quadratically.

The pipeline (every integer stage exact by construction):

1. **Integerize** — reuse ``splitting.split_int``: ``s`` slices of ``w``
   bits each represent ``A_int = sum_p slices[p] * 2^{(s-1-p)w}``
   exactly, with ``A_kept = 2^{ea - beta} * A_int`` and ``beta = s*w``.
   Truncation toward zero gives ``|A - A_kept| <= 2^{ea - beta}``.
2. **Residues** (``residues_from_slices``) — per modulus ``m_j`` (odd
   primes <= 251), the centered residue ``A_int mod m_j`` is computed
   from the slices with host-precomputed weights ``2^{(s-1-p)w} mod m_j``
   — an int32 tensordot (max partial ``s * 127 * 250 < 2^21``) followed
   by one mod: never a float remainder, so exactness is structural.
   Centered residues lie in ``[-(m_j-1)/2, (m_j-1)/2] ⊆ [-125, 125]``:
   int8 operands for the MXU.
3. **Residue GEMMs** — ``ell`` int8 NT GEMMs with int32 accumulation,
   batched along the modulus axis (the existing batch-grid Pallas kernel
   ``kernels.int8_matmul_nt_batched`` runs all ``ell`` in ONE launch).
   ``usable_moduli`` guarantees ``k * ((m-1)/2)^2 <= 2^31 - 1``: no
   accumulator overflow for any modulus kept.
4. **CRT reconstruction** (``crt_digits`` / ``crt_value``) — Garner's
   mixed-radix algorithm with *balanced* digits: odd moduli make the
   balanced representation unique over ``(-M/2, M/2)``, and
   ``select_moduli`` guarantees ``M > 2k * 4^beta > 2 |C_int|``, so the
   digits reconstruct ``C_int`` exactly (an O(ell^2) elementwise int32
   pass — every intermediate bounded well below 2^31). The FP64 result
   is ``ldexp(sum_j v_j * float(Q_j) * 2^{-2 beta}, ea_i + eb_j)``,
   accumulated smallest radix first.

The guaranteed error bound mirrors ``core.accuracy.error_bound``:
``k * modular_eta(beta)`` covers the operand truncation and
``modular_accum_floor`` covers the float reconstruction rounding —
``modular_error_bound`` is the sum, on the same ``2^{ea_i + eb_j}``
normalization ``accuracy.scaled_error`` measures.

Cost crossover (the reason this module exists): meeting Scheme I's
``s``-split accuracy needs ``beta ~ s*w`` bits, i.e. ``ell ~
(2 s w + log2 k) / 8`` moduli, versus ``s(s+1)/2`` slice pairs — at
``s = 7, k = 4096`` that is 15 residue GEMMs against 28 pair GEMMs, and
the gap widens with ``s``. ``core.accuracy.resolve_accuracy`` arbitrates
per ``(shape, target)`` using exactly these counts.

This module is import-cycle-free with the executor layer:
``core.executors`` imports it at module top (for the ``ModularExecutor``
family); the drivers here import ``get_executor`` lazily.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .analytic import DGEMM_MANTISSA_SPACE
from .splitting import SplitResult, split_int
from .tuning import PipelinePlan, TilePlan

__all__ = ["MAX_BETA", "ModularConfig", "ModularPoint", "center_mod",
           "crt_digits", "crt_value", "crt_value_dw", "garner_constants",
           "min_beta_for", "modular_accum_floor",
           "modular_error_bound", "modular_eta", "modular_plan",
           "ozaki2_matmul", "ozaki2_matmul_batched",
           "ozaki2_matmul_complex", "ozaki2_matmul_df32",
           "residues_from_slices",
           "resolve_modular", "select_moduli", "usable_moduli"]

# Past 2 * 53 bits even a double-double reference is matched; the cap
# bounds the moduli pool the same way accuracy.MAX_SPLITS bounds s.
MAX_BETA = 112

_INT32_MAX = 2 ** 31 - 1


def _odd_primes_desc(limit: int = 251) -> tuple[int, ...]:
    sieve = np.ones(limit + 1, dtype=bool)
    sieve[:2] = False
    for p in range(2, int(limit ** 0.5) + 1):
        if sieve[p]:
            sieve[p * p::p] = False
    return tuple(int(p) for p in np.flatnonzero(sieve)[::-1] if p % 2 == 1)


# Odd primes <= 251 descending: the int8 residue alphabet. 2 is excluded
# not for range but for uniqueness — balanced digits are unique only for
# odd moduli (an even modulus has two centered representatives of m/2).
MODULI_POOL = _odd_primes_desc()


@functools.lru_cache(maxsize=256)
def usable_moduli(k: int) -> tuple[int, ...]:
    """The moduli whose residue GEMM cannot overflow int32 at this k:
    ``k * ((m-1)/2)^2 <= 2^31 - 1`` (centered residues bound each
    product by ``((m-1)/2)^2``; the exact analogue of Eq. (3)/(4))."""
    if k <= 0:
        raise ValueError("k must be positive")
    return tuple(m for m in MODULI_POOL
                 if k * ((m - 1) // 2) ** 2 <= _INT32_MAX)


def select_moduli(k: int, beta: int) -> tuple[int, ...]:
    """Minimal descending-prime prefix with ``prod > 2 * k * 4^beta``.

    The CRT range requirement: ``|C_int| <= k * (2^beta - 1)^2``, and the
    balanced representation is unique over ``(-M/2, M/2)``, so ``M >
    2 k 4^beta`` guarantees exact reconstruction. Raises when the pool
    cannot cover the range (beta too large for this k).
    """
    need = 2 * k * 4 ** beta
    chosen: list[int] = []
    prod = 1
    for m in usable_moduli(k):
        if prod > need:
            break
        chosen.append(m)
        prod *= m
    if prod <= need:
        raise ValueError(
            f"moduli pool exhausted: k={k}, beta={beta} needs product > "
            f"2*k*4^beta (~2^{need.bit_length()}) but the usable odd primes "
            f"<= 251 reach only ~2^{prod.bit_length()}")
    return tuple(chosen)


# ----------------------------------------------------------------------------
# Guaranteed bounds (mirror core.accuracy.error_bound's structure)
# ----------------------------------------------------------------------------

def modular_eta(beta: int) -> float:
    """eta: ``|C - C_hat| <= k * eta * 2^{ea_i + eb_j}``, guaranteed.

    Truncation toward zero keeps ``|A - A_kept| <= 2^{ea - beta}`` with
    ``|A| < 2^{ea}``, so each k-term errs by at most
    ``(2 * 2^{-beta} + 4^{-beta}) * 2^{ea + eb}``.
    """
    if beta < 1:
        raise ValueError(f"beta must be >= 1, got {beta}")
    return 2.0 ** (1 - beta) + 4.0 ** (-beta)


def modular_accum_floor(beta: int, moduli: Sequence[int]) -> float:
    """Rounding floor of the FP64 CRT reconstruction (relative to
    ``2^{ea_i + eb_j}``) — the Scheme II ``accum_floor``.

    Every reconstruction term is bounded by ``M/2 * 4^{-beta}`` (so are
    all partial sums: balanced mixed-radix prefixes telescope), and each
    term costs <= 3 roundings at 2^-53 (``float(Q_j)``, the multiply,
    the add); +2 covers the final ldexp pair conservatively.
    """
    m_prod = 1
    for m in moduli:
        m_prod *= m
    term_cap = math.ldexp(float(m_prod), -(2 * beta + 1))
    return (3 * len(moduli) + 2) * 2.0 ** -53 * term_cap


def modular_error_bound(beta: int, k: int,
                        moduli: Optional[Sequence[int]] = None) -> float:
    """Total guaranteed ``max_ij |C - C_hat| / 2^{ea_i + eb_j}``."""
    if moduli is None:
        moduli = select_moduli(k, beta)
    return k * modular_eta(beta) + modular_accum_floor(beta, moduli)


def min_beta_for(target_error: float, k: int, *,
                 max_beta: int = MAX_BETA) -> int:
    """Smallest beta with ``k * modular_eta(beta) <= target_error``
    (clamped at ``max_beta``, mirroring ``accuracy.min_splits_for``)."""
    if target_error <= 0:
        raise ValueError(f"target_error must be > 0, got {target_error}")
    for beta in range(1, max_beta + 1):
        if k * modular_eta(beta) <= target_error:
            return beta
    return max_beta


# ----------------------------------------------------------------------------
# Operating point
# ----------------------------------------------------------------------------

class ModularPoint(NamedTuple):
    """One Scheme II operating point: mantissa bits, the split count that
    realizes them (``beta = num_splits * w``), and the residue moduli."""

    beta: int
    num_splits: int
    moduli: tuple[int, ...]


def resolve_modular(k: int, *, beta: Optional[int] = None,
                    target_error: Optional[float] = None,
                    num_moduli: Optional[int] = None, w: int = 7,
                    mantissa_space: int = DGEMM_MANTISSA_SPACE
                    ) -> ModularPoint:
    """Resolve the Scheme II accuracy knobs into a concrete point.

    Priority mirrors Scheme I's ``resolve_accuracy``:

    * explicit ``beta`` wins (rounded up to a slice multiple ``s * w`` —
      the integerization is slice-built, so only multiples of w exist);
    * else ``target_error`` sizes beta via the guaranteed bound;
    * else the paper's DGEMM mantissa space (70 bits — the same default
      Scheme I's ``select_num_splits`` targets).

    ``num_moduli`` pins the GEMM count (the ``ozaki2-fp64xL`` spec dial):
    with no beta/target it sizes beta UP to the largest count those L
    primes can reconstruct (the accuracy dial, mirroring pinned s); with
    a beta/target it must still cover the range — fewer moduli than the
    CRT needs is not graceful degradation but wraparound garbage, so
    that is a ``ValueError``, never a silent fallback.
    """
    pool = usable_moduli(k)
    if num_moduli is not None:
        if num_moduli < 1:
            raise ValueError(f"num_moduli must be >= 1, got {num_moduli}")
        if num_moduli > len(pool):
            raise ValueError(
                f"num_moduli={num_moduli} exceeds the {len(pool)} usable "
                f"odd-prime moduli at k={k}")
    if beta is None:
        if target_error is not None:
            beta = min_beta_for(target_error, k)
        elif num_moduli is not None:
            # pinned GEMM count: the largest beta those primes reconstruct
            moduli = pool[:num_moduli]
            cap = 1
            for m in moduli:
                cap *= m
            s = 0
            while (s + 1) * w <= MAX_BETA and cap > 2 * k * 4 ** ((s + 1) * w):
                s += 1
            if s < 1:
                raise ValueError(
                    f"num_moduli={num_moduli} covers no mantissa bits at "
                    f"k={k}: product ~2^{cap.bit_length()} <= 2*k*4^{w}")
            return ModularPoint(s * w, s, tuple(moduli))
        else:
            beta = mantissa_space
    s = -(-beta // w)
    beta = s * w
    if beta > MAX_BETA:
        raise ValueError(f"beta={beta} exceeds MAX_BETA={MAX_BETA}")
    minimal = select_moduli(k, beta)
    if num_moduli is None:
        moduli = minimal
    else:
        if num_moduli < len(minimal):
            raise ValueError(
                f"num_moduli={num_moduli} cannot reconstruct beta={beta} "
                f"at k={k}: the CRT needs >= {len(minimal)} moduli "
                f"(fewer is wraparound, not graceful degradation)")
        moduli = pool[:num_moduli]
    if target_error is not None and \
            k * modular_eta(beta) > target_error:
        raise ValueError(
            f"target_error={target_error} unreachable at beta={beta} "
            f"(k * eta = {k * modular_eta(beta):.3g})")
    return ModularPoint(beta, s, moduli)


# ----------------------------------------------------------------------------
# Residue arithmetic (device-side, every integer stage exact)
# ----------------------------------------------------------------------------

def _mods_array(moduli: Sequence[int], ndim: int) -> jnp.ndarray:
    """int32 moduli broadcast against an (ell, ...) residue stack."""
    m = jnp.asarray(np.asarray(moduli, np.int32))
    return m.reshape((len(moduli),) + (1,) * (ndim - 1))


def center_mod(x: jax.Array, moduli: Sequence[int]) -> jax.Array:
    """Centered residues of an (ell, ...) int32 stack: x[j] mod m_j in
    ``[-(m_j-1)/2, (m_j-1)/2]`` (floor-mod then fold the upper half)."""
    mods = _mods_array(moduli, x.ndim)
    r = jnp.mod(x, mods)
    return r - jnp.where(r > (mods - 1) // 2, mods, 0)


def residues_from_slices(slices: jax.Array, w: int,
                         moduli: Sequence[int]) -> jax.Array:
    """int8 centered residues of the integerized operand, per modulus.

    slices: (s, ..., k) int8 from ``split_int`` (most significant first),
    representing ``A_int = sum_p slices[p] * 2^{(s-1-p)w}``. The weights
    ``2^{(s-1-p)w} mod m_j`` are host-side pow-mod (exact python ints);
    the device does one int32 tensordot (bounded by ``s * 127 * 250``)
    and one centered mod. Returns (ell, ..., k) int8.
    """
    s = slices.shape[0]
    wts = np.array([[pow(2, (s - 1 - p) * w, m) for p in range(s)]
                    for m in moduli], np.int32)
    x = jnp.tensordot(jnp.asarray(wts), slices.astype(jnp.int32),
                      axes=[[1], [0]])
    return center_mod(x, moduli).astype(jnp.int8)


def _garner_tables(moduli: Sequence[int]):
    """Host-side Garner constants: prefix products Q_j (python ints),
    ``Q_j^{-1} mod m_j``, and ``Q_i mod m_j`` for i < j."""
    ell = len(moduli)
    prefix = [1]
    for m in moduli[:-1]:
        prefix.append(prefix[-1] * m)
    inv = [pow(prefix[j] % moduli[j], -1, moduli[j]) for j in range(ell)]
    qmod = [[prefix[i] % moduli[j] for j in range(ell)] for i in range(ell)]
    return prefix, inv, qmod


def crt_digits(cres: jax.Array, moduli: Sequence[int]) -> list[jax.Array]:
    """Balanced mixed-radix digits of the value behind the residues.

    cres: (ell, ...) int32 centered residues of one integer X with
    ``|X| < M/2``. Garner's recurrence, digits centered per modulus:
    ``X = sum_j v_j * Q_j`` with ``|v_j| <= (m_j-1)/2`` — unique for odd
    moduli, so the digits ARE X's balanced representation (exactness is
    an identity, not an approximation). All int32: the inner sum is
    bounded by ``125 + ell * 125 * 250 < 2^21``.
    """
    _, inv, qmod = _garner_tables(moduli)
    digits: list[jax.Array] = []
    for j, mj in enumerate(moduli):
        acc = cres[j]
        for i in range(j):
            acc = acc - digits[i] * jnp.int32(qmod[i][j])
        d = jnp.mod(acc, jnp.int32(mj))
        v = jnp.mod(d * jnp.int32(inv[j]), jnp.int32(mj))
        digits.append(v - jnp.where(v > (mj - 1) // 2, jnp.int32(mj), 0))
    return digits


def _split26(x: float) -> tuple[float, float]:
    """Veltkamp split of a host f64 into an exact (hi, lo) pair with
    <= 26 / 27 significant bits each (``x == hi + lo`` exactly)."""
    c = x * (2.0 ** 27 + 1.0)
    hi = c - (c - x)
    return hi, x - hi


def crt_value(digits: Sequence[jax.Array], moduli: Sequence[int], beta: int,
              e_base: jax.Array) -> jax.Array:
    """FP64 reconstruction: ``ldexp(sum_j v_j * float(Q_j) * 4^{-beta},
    ea + eb)``, summed smallest radix first (ascending j) so rounding
    stays within ``modular_accum_floor``. ``float(Q_j)`` rounds at
    2^-53 relative — covered by the floor, like every term op.

    Each scale is Veltkamp-split host-side into an exact (hi, lo) pair
    of <= 27-bit halves, so both ``v * lo`` and ``v * hi`` products are
    EXACT in f64 (|v| <= 125 adds 7 bits: 34 < 53) and only the running
    adds round. That makes the sum FMA-contraction-proof — fusing an
    exact mul into the following add cannot move a bit — so jit, eager,
    and the fused-CRT kernel epilogue produce the identical bit pattern
    (the same trick the Scheme I epilogue gets for free from its
    power-of-two scale)."""
    prefix, _, _ = _garner_tables(moduli)
    c = None
    for j, v in enumerate(digits):
        hi, lo = _split26(math.ldexp(float(prefix[j]), -2 * beta))
        vf = v.astype(jnp.float64)
        t_lo = vf * lo                       # exact: 7 + 27 bits
        c = t_lo if c is None else c + t_lo  # smallest piece first
        c = c + vf * hi                      # exact: 7 + 26 bits
    return jnp.ldexp(c, e_base)


def garner_constants(moduli: Sequence[int], beta: int):
    """Static Garner constants for the fused-CRT epilogue kernel: the
    moduli, ``Q_i mod m_j`` rows, ``Q_j^{-1} mod m_j``, and the per-digit
    FP64 scales ``float(Q_j) * 4^{-beta}`` as Veltkamp (hi, lo) pairs
    (``crt_value``'s exact-product form) — every value a hashable python
    scalar, so the kernel wrapper can take them as jit statics and
    replay ``crt_digits``/``crt_value``'s exact arithmetic in VMEM."""
    prefix, inv, qmod = _garner_tables(moduli)
    scales = tuple(_split26(math.ldexp(float(prefix[j]), -2 * beta))
                   for j in range(len(moduli)))
    return (tuple(moduli), tuple(tuple(row) for row in qmod),
            tuple(inv), scales)


def crt_value_dw(digits: Sequence[jax.Array], moduli: Sequence[int],
                 beta: int, e_base: jax.Array):
    """df32 reconstruction target: the CRT sum accumulated in double-
    float32 (DW) arithmetic — no FP64 hardware needed past the exact
    integer stages.

    Each scale ``Q_j * 4^{-beta}`` is decomposed host-side into an exact
    (f32 hi, f32 lo) pair; the digit (|v| <= 125, exact in f32)
    multiplies it via the Dekker-based ``dw_mul_single`` and the terms
    accumulate ascending-radix through ``dw_add``. Low-radix scales
    below f32's subnormal floor round to zero — they sit ~2^-90 under
    the df32 accumulation floor, so the guaranteed df32-level bound is
    unaffected. Returns a ``DW`` pair scaled by ``2^{e_base}``.
    """
    from .xmath import DW, dw_add, dw_mul_single
    prefix, _, _ = _garner_tables(moduli)
    c = None
    for j, v in enumerate(digits):
        scale = math.ldexp(float(prefix[j]), -2 * beta)
        hi = np.float32(scale)
        lo = np.float32(scale - float(hi))
        scale_dw = DW(jnp.float32(hi), jnp.float32(lo))
        term = dw_mul_single(scale_dw, v.astype(jnp.float32))
        c = term if c is None else dw_add(c, term)
    return DW(jnp.ldexp(c.hi, e_base), jnp.ldexp(c.lo, e_base))


# ----------------------------------------------------------------------------
# Config + plan reflection
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModularConfig:
    """Configuration for one Scheme II GEMM (the ``OzakiConfig`` sibling).

    beta:         mantissa bits kept per operand (rounded up to ``s*w``),
                  or None to derive from ``target_error`` / the 70-bit
                  DGEMM default.
    target_error: accuracy target on the scaled error (same contract as
                  Scheme I's) — sizes beta via the guaranteed bound.
    num_moduli:   pinned residue-GEMM count L (``ozaki2-fp64xL``): with
                  no beta/target it is the accuracy dial (largest beta L
                  primes reconstruct); with one it must cover the range.
    w:            bits per integerization slice (int8 keeps 7).
    backend:      "xla" | "pallas" | "pallas_fused" — residue GEMMs as a
                  batched dot_general or the batch-grid Pallas kernel
                  (pallas_fused additionally splits with the one-pass
                  kernel).
    fuse_epilogue: with ``pallas_fused``: run the balanced-Garner CRT
                  reconstruction inside the residue GEMM grid's epilogue
                  (VMEM scratch over the modulus axis) — int32 residue
                  products never round-trip through HBM.
    interpret:    Pallas interpret mode (CPU validation hosts).
    tile:         optional TilePlan for the kernel launches.
    """

    beta: Optional[int] = None
    target_error: Optional[float] = None
    num_moduli: Optional[int] = None
    w: int = 7
    backend: str = "xla"
    fuse_epilogue: bool = False
    interpret: bool = True
    tile: Optional[TilePlan] = None

    def point(self, k: int) -> ModularPoint:
        return resolve_modular(k, beta=self.beta,
                               target_error=self.target_error,
                               num_moduli=self.num_moduli, w=self.w)

    def plan(self, k: int, *, batch_layout: str = "none") -> PipelinePlan:
        return modular_plan(k, point=self.point(k), backend=self.backend,
                            fuse_epilogue=self.fuse_epilogue,
                            interpret=self.interpret, tile=self.tile,
                            batch_layout=batch_layout)


def modular_plan(k: int, *, point: Optional[ModularPoint] = None,
                 backend: str = "xla", fuse_epilogue: bool = False,
                 interpret: bool = True,
                 tile: Optional[TilePlan] = None,
                 batch_layout: str = "none",
                 target_error: Optional[float] = None,
                 num_moduli: Optional[int] = None) -> PipelinePlan:
    """The ``PipelinePlan`` one Scheme II operating point executes as.

    The plan records the point (``beta``, ``num_moduli``, and
    ``num_splits`` = the integerization slice count) next to the launch
    knobs, so the plan cache round-trips everything the executor needs:
    the moduli themselves are re-derived deterministically
    (``usable_moduli(k)[:num_moduli]`` — always a pool prefix).
    """
    if point is None:
        point = resolve_modular(k, target_error=target_error,
                                num_moduli=num_moduli)
    if tile is None:
        tile = TilePlan(num_splits=point.num_splits, concat_k=False)
    if fuse_epilogue and backend != "pallas_fused":
        raise ValueError(
            f"fuse_epilogue (fused-CRT reconstruction) needs the "
            f"pallas_fused backend, got backend={backend!r}")
    if backend == "pallas_fused":
        fusion = "epilogue" if fuse_epilogue else "stages"
    else:
        fusion = "none"
    return PipelinePlan(
        scheme="ozaki2_fp64", num_splits=point.num_splits,
        beta=point.beta, num_moduli=len(point.moduli), tile=tile,
        backend=backend, fusion=fusion,
        batch_layout=batch_layout, pair_policy="full", fuse_diagonals=True,
        concat_k=False, full_pairs=False, accum="f64", interpret=interpret)


# ----------------------------------------------------------------------------
# Drivers (mirror core.ozaki's thin-driver role)
# ----------------------------------------------------------------------------

def _e_base(ea: jax.Array, eb: jax.Array) -> jax.Array:
    """Deferred per-element exponent: broadcast outer sum (int32)."""
    return (ea[..., :, None].astype(jnp.int32) +
            eb[..., None, :].astype(jnp.int32))


def _check_f64(a, b, name: str) -> None:
    if a.dtype != jnp.float64 or b.dtype != jnp.float64:
        raise TypeError(
            f"{name} takes float64 operands (complex128 routes through "
            f"ozaki2_matmul_complex, float32 through ozaki2_matmul_df32), "
            f"got {a.dtype} @ {b.dtype}")


def ozaki2_matmul(a: jax.Array, b: jax.Array,
                  cfg: ModularConfig = ModularConfig()) -> jax.Array:
    """FP64-accurate ``C = A @ B`` via residue-system int8 GEMMs.

    A: (m, k) f64, B: (k, n) f64 — the Scheme II sibling of
    ``ozaki_matmul``, with ``len(point.moduli)`` int8 GEMMs instead of
    ``s(s+1)/2`` slice pairs.
    """
    _check_f64(a, b, "ozaki2_matmul")
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError(f"ozaki2_matmul expects 2-D operands, got "
                         f"{a.shape} @ {b.shape}")
    if a.shape[1] != b.shape[0]:
        raise ValueError(f"contraction mismatch: {a.shape} vs {b.shape}")
    k = a.shape[1]
    plan = cfg.plan(k)
    from .executors import get_executor          # lazy: executors import us
    ex = get_executor(plan)
    w = cfg.w
    sa = ex.split(a, w)
    sb = ex.split(b.T, w)
    return ex.contract(sa, sb, w, _e_base(sa.exp, sb.exp),
                       (a.shape[0], b.shape[1]))


def _fold_rows2(split_fn, x3: jax.Array, w: int) -> SplitResult:
    """Split a (B, r, k) stack by folding the batch into rows (exact:
    exponents and slices are row-independent)."""
    bsz, r, k = x3.shape
    res = split_fn(x3.reshape(bsz * r, k), w)
    s = res.slices.shape[0]
    return SplitResult(res.slices.reshape(s, bsz, r, k),
                       res.exp.reshape(bsz, r), res.w)


@functools.partial(jax.custom_jvp, nondiff_argnums=(2,))
def _batched_core2(a: jax.Array, b: jax.Array,
                   cfg: ModularConfig) -> jax.Array:
    if b.ndim == 2:
        # broadcast weights: fold the batch into rows (row-independent
        # split/exponents — equals a loop over ozaki2_matmul bitwise)
        bsz, m, k = a.shape
        out = ozaki2_matmul(a.reshape(bsz * m, k), b, cfg)
        return out.reshape(bsz, m, b.shape[1])
    bsz, m, k = a.shape
    n = b.shape[-1]
    plan = cfg.plan(k, batch_layout="grid")
    from .executors import get_executor          # lazy: executors import us
    ex = get_executor(plan)
    w = cfg.w
    sa = _fold_rows2(ex.split, a, w)
    sb = _fold_rows2(ex.split, jnp.swapaxes(b, 1, 2), w)
    return ex.contract(sa, sb, w, _e_base(sa.exp, sb.exp), (bsz, m, n))


@_batched_core2.defjvp
def _batched_core2_jvp(cfg, primals, tangents):
    a, b = primals
    da, db = tangents
    primal = _batched_core2(a, b, cfg)
    # exact-product rule, same rationale as the Scheme I batched JVP
    tangent = (jnp.matmul(da, b, preferred_element_type=a.dtype) +
               jnp.matmul(a, db, preferred_element_type=a.dtype))
    return primal, tangent.astype(primal.dtype)


def ozaki2_matmul_batched(a: jax.Array, b: jax.Array,
                          cfg: ModularConfig = ModularConfig()) -> jax.Array:
    """Batched Scheme II GEMM: ``C[i] = A[i] @ B[i]`` (or shared ``B``).

    a: (B, m, k) f64; b: (B, k, n) stacked or (k, n) broadcast. Stacked
    batches fold the (modulus, batch) product onto the batch-grid GEMM
    kernel's leading dimension — one launch for all ``ell * B`` residue
    GEMMs. Differentiable via the exact-product JVP.
    """
    if a.ndim != 3:
        raise ValueError(f"a must be (batch, m, k), got {a.shape}")
    if b.ndim not in (2, 3):
        raise ValueError(f"b must be (k, n) or (batch, k, n), got {b.shape}")
    if b.ndim == 3 and a.shape[0] != b.shape[0]:
        raise ValueError(f"batch mismatch: {a.shape} vs {b.shape}")
    if a.shape[-1] != b.shape[-2]:
        raise ValueError(f"contraction mismatch: {a.shape} vs {b.shape}")
    _check_f64(a, b, "ozaki2_matmul_batched")
    return _batched_core2(a, b, cfg)


# ----------------------------------------------------------------------------
# Complex + df32 routes (the Scheme I parity surfaces, PR 9)
# ----------------------------------------------------------------------------

def ozaki2_matmul_complex(a: jax.Array, b: jax.Array,
                          cfg: ModularConfig = ModularConfig(),
                          algo: str = "4mul") -> jax.Array:
    """complex128 ``C = A @ B`` through real Scheme II GEMMs.

    The same decomposition ``ozaki_matmul_complex`` uses — the scheme
    only changes what a *real* GEMM costs, not the complex algebra:

    ``algo="4mul"``: Cr = ArBr - AiBi, Ci = ArBi + AiBr (each of the 4
    real matrices integerized exactly once, residue stacks reused).
    ``algo="3mul"``: Karatsuba, one fewer residue-GEMM group at one
    extra magnitude bit on the summed operands (covered by beta).
    """
    if a.dtype != jnp.complex128 or b.dtype != jnp.complex128:
        raise TypeError(f"ozaki2_matmul_complex takes complex128 operands, "
                        f"got {a.dtype} @ {b.dtype}")
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError(f"complex operands must be 2-D, got "
                         f"{a.shape} @ {b.shape}")
    if a.shape[1] != b.shape[0]:
        raise ValueError(f"contraction mismatch: {a.shape} vs {b.shape}")
    ar, ai = jnp.real(a), jnp.imag(a)
    br, bi = jnp.real(b), jnp.imag(b)
    k = a.shape[1]
    plan = cfg.plan(k)
    from .executors import get_executor          # lazy: executors import us
    ex = get_executor(plan)
    w = cfg.w

    def real_mm(xs, ys, shape):
        return ex.contract(xs, ys, w, _e_base(xs.exp, ys.exp), shape)

    shape = (a.shape[0], b.shape[1])
    if algo == "3mul":
        s_ar = ex.split(ar, w)
        s_ai = ex.split(ai, w)
        s_as = ex.split(ar + ai, w)
        s_br = ex.split(br.T, w)
        s_bi = ex.split(bi.T, w)
        s_bs = ex.split((br + bi).T, w)
        p1 = real_mm(s_ar, s_br, shape)
        p2 = real_mm(s_ai, s_bi, shape)
        p3 = real_mm(s_as, s_bs, shape)
        return jax.lax.complex(p1 - p2, p3 - p1 - p2)
    if algo != "4mul":
        raise ValueError(f"algo must be '4mul' or '3mul', got {algo!r}")
    s_ar = ex.split(ar, w)
    s_ai = ex.split(ai, w)
    s_br = ex.split(br.T, w)
    s_bi = ex.split(bi.T, w)
    c_r = real_mm(s_ar, s_br, shape) - real_mm(s_ai, s_bi, shape)
    c_i = real_mm(s_ar, s_bi, shape) + real_mm(s_ai, s_br, shape)
    return jax.lax.complex(c_r, c_i)


def ozaki2_matmul_df32(a: jax.Array, b: jax.Array,
                       cfg: ModularConfig = ModularConfig()) -> jax.Array:
    """f32-in/f32-out Scheme II GEMM with a df32 reconstruction target.

    Every stage up to the CRT digits is exact integer arithmetic on the
    *widened* operands (f32 -> f64 is exact), identical to
    ``ozaki2_matmul``'s stages; the reconstruction then runs
    ``crt_value_dw`` — the CRT sum in double-float32 — instead of the
    FP64 sum, so past the integer stages the route needs no FP64
    hardware. Returns ``dw_to_single`` of the DW result (f32).
    """
    if a.dtype != jnp.float32 or b.dtype != jnp.float32:
        raise TypeError(f"ozaki2_matmul_df32 takes float32 operands, got "
                        f"{a.dtype} @ {b.dtype}")
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError(f"ozaki2_matmul_df32 expects 2-D operands, got "
                         f"{a.shape} @ {b.shape}")
    if a.shape[1] != b.shape[0]:
        raise ValueError(f"contraction mismatch: {a.shape} vs {b.shape}")
    k = a.shape[1]
    plan = cfg.plan(k)
    from .executors import get_executor          # lazy: executors import us
    from .xmath import dw_to_single
    ex = get_executor(plan)
    w = cfg.w
    sa = ex.split(a.astype(jnp.float64), w)      # exact widening
    sb = ex.split(b.T.astype(jnp.float64), w)
    moduli = usable_moduli(k)[:plan.num_moduli]
    ra = residues_from_slices(sa.slices, w, moduli)
    rb = residues_from_slices(sb.slices, w, moduli)
    p = ex.gemm(ra, rb)
    digits = crt_digits(center_mod(p, moduli), moduli)
    out = crt_value_dw(digits, moduli, plan.beta,
                       _e_base(sa.exp, sb.exp))
    return dw_to_single(out)
