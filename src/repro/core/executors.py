"""Pipeline executors — one class per backend/fusion strategy.

``core.ozaki`` is the thin driver: it normalizes operands (transposes B,
folds batches into rows for the "rows"/"grid" layouts), builds a
``PipelinePlan`` (``core.tuning.plan_for``), and calls the executor the
plan selects. Executors own the three pipeline stages:

  * ``split``/``split_dw`` — stage 1, always on a 2-D matrix (the driver
    folds a batch into rows first; splitting is row-independent, so the
    fold is exact).
  * ``gemm``/``products`` — stage 2, the slice GEMMs per anti-diagonal
    group. Operands may be 2-D ``(m, k) x (n, k)`` or 3-D batched
    ``(B, m, k) x (B, n, k)``; the 3-D case runs the explicit batch-grid
    kernel (``int8_matmul_nt_batched``) on the Pallas executors and a
    batch-dimension ``dot_general`` on XLA — never ``vmap``. The pair
    schedule comes from ``plan.diagonals()``, which already reflects the
    plan's fast-mode ``pair_policy``: truncated diagonals mean fewer
    GEMMs here and a shorter pair-grid dimension in the epilogue kernels
    (``npairs`` below) — truncation is threaded into the launch grids,
    never applied as a post-hoc mask.
  * ``accumulate`` — stage 3, the high-precision scaled accumulation,
    ordered smallest terms first; the deferred per-element exponent
    ``e_base`` is applied once at the end (exact power-of-two scaling).
  * ``contract`` — stages 2+3. The epilogue executor overrides this
    whole stage pair: GEMM and accumulation run in one kernel per group
    and the int32 group products never materialize to HBM.

Every executor is bitwise-compatible with ``XlaExecutor`` for both
accumulation modes: integer stages are exact, and the float stages run
identical rounding sequences (enforced by ``tests/test_backend_parity``).

Kernel imports stay lazy (inside methods) to keep ``repro.core``
importable without ``repro.kernels`` and cycle-free.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

# core.modular imports core.tuning only; its drivers import us lazily,
# so this top-level import is cycle-free.
from .modular import (center_mod, crt_digits, crt_value, garner_constants,
                      residues_from_slices, usable_moduli)
from .splitting import SplitResult, row_exponents, split_int, split_int_dw
from .tuning import BACKENDS, PipelinePlan
from .xmath import DW, dw_add, dw_normalize

__all__ = ["BACKENDS", "XlaExecutor", "PallasExecutor", "FusedExecutor",
           "EpilogueExecutor", "StreamingExecutor", "StreamingSplit",
           "ModularXlaExecutor", "ModularPallasExecutor",
           "ModularFusedExecutor", "ModularEpilogueExecutor",
           "get_executor", "gemm_xla", "int32_to_dw"]


def gemm_xla(a8: jax.Array, bt8: jax.Array) -> jax.Array:
    """int8 NT GEMM as one XLA op; 3-D operands contract batched."""
    if a8.ndim == 3:
        return jax.lax.dot_general(
            a8, bt8, dimension_numbers=(((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.int32)
    return jax.lax.dot_general(
        a8, bt8, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)


def int32_to_dw(p: jax.Array) -> DW:
    """Exact int32 -> df32 conversion (no int64 anywhere: TPU/x32 safe)."""
    low = jnp.bitwise_and(p, jnp.int32(0xFFFF))        # [0, 65535]
    high = p - low                                      # multiple of 2^16
    hi_f = high.astype(jnp.float32)                     # <= 15 sig bits: exact
    lo_f = low.astype(jnp.float32)                      # <= 16 sig bits: exact
    return dw_normalize(hi_f, lo_f)


def _ordered(products):
    return sorted(products, key=lambda tp: -tp[0])      # small terms first


class XlaExecutor:
    """Reference executor: every stage as composite XLA ops."""

    def __init__(self, plan: PipelinePlan):
        self.plan = plan

    # ---- stage 1: split -------------------------------------------------
    def split(self, x: jax.Array, w: int) -> SplitResult:
        return split_int(x, self.plan.num_splits, w)

    def split_dw(self, x: DW, w: int) -> SplitResult:
        return split_int_dw(x, self.plan.num_splits, w)

    # ---- stage 2: slice GEMMs ------------------------------------------
    def gemm(self, a8: jax.Array, bt8: jax.Array) -> jax.Array:
        return gemm_xla(a8, bt8)

    def products(self, sa: SplitResult,
                 sb: SplitResult) -> list[tuple[int, jax.Array]]:
        """[(t, P_t int32)] per anti-diagonal group."""
        plan = self.plan
        out = []
        for t, pairs in plan.diagonals():
            if plan.concat_k:
                a_cat = jnp.concatenate([sa.slices[p] for p, _ in pairs],
                                        axis=-1)
                b_cat = jnp.concatenate([sb.slices[q] for _, q in pairs],
                                        axis=-1)
                out.append((t, self.gemm(a_cat, b_cat)))
            elif plan.fuse_diagonals:
                p_t = self.gemm(sa.slices[pairs[0][0]], sb.slices[pairs[0][1]])
                for p, q in pairs[1:]:
                    p_t = p_t + self.gemm(sa.slices[p], sb.slices[q])
                out.append((t, p_t))
            else:
                # paper-faithful: pair products stay separate
                out.extend((t, self.gemm(sa.slices[p], sb.slices[q]))
                           for p, q in pairs)
        return out

    # ---- stage 3: high-precision scaled accumulation -------------------
    def accumulate(self, products, e_base: jax.Array, w: int, shape):
        if self.plan.accum == "f64":
            c = jnp.zeros(shape, jnp.float64)
            for t, p_t in _ordered(products):
                c = c + jnp.ldexp(p_t.astype(jnp.float64),
                                  e_base - (t + 2) * w)
            return c
        acc = DW(jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32))
        for t, p_t in _ordered(products):
            scale = jnp.float32(2.0 ** (-(t + 2) * w))  # exact power of two
            term = int32_to_dw(p_t)
            acc = dw_add(acc, DW(term.hi * scale, term.lo * scale))
        return DW(jnp.ldexp(acc.hi, e_base), jnp.ldexp(acc.lo, e_base))

    # ---- stages 2+3 -----------------------------------------------------
    def contract(self, sa: SplitResult, sb: SplitResult, w: int,
                 e_base: jax.Array, shape):
        return self.accumulate(self.products(sa, sb), e_base, w, shape)


class PallasExecutor(XlaExecutor):
    """Slice GEMMs on the Pallas MXU kernels; split/accumulate stay XLA.

    3-D operands run the explicit batch-grid GEMM (the batch is the
    outermost grid dimension of ONE kernel launch — no vmap wrapper).
    """

    def gemm(self, a8: jax.Array, bt8: jax.Array) -> jax.Array:
        from repro.kernels import int8_matmul_nt, int8_matmul_nt_batched
        tile = self.plan.tile
        kw = dict(bm=tile.bm, bn=tile.bn, bk=tile.bk,
                  interpret=self.plan.interpret)
        if a8.ndim == 3:
            return int8_matmul_nt_batched(a8, bt8, **kw)
        return int8_matmul_nt(a8, bt8, **kw)


class FusedExecutor(PallasExecutor):
    """The PR 1 ``pallas_fused`` pipeline (``fusion="stages"``): one-pass
    SplitInt kernel, Pallas GEMMs, fused scaled-accumulation kernels.
    Batched accumulation folds ``(B, m, n)`` onto ``(B*m, n)`` — the
    kernels are elementwise, so the fold is exact.
    """

    def split(self, x: jax.Array, w: int) -> SplitResult:
        from repro.kernels import fused_split_dw
        exp = row_exponents(x)
        tile = self.plan.tile
        slices = fused_split_dw(x, jnp.zeros_like(x), exp,
                                num_splits=self.plan.num_splits, w=w,
                                bm=tile.split_bm, bk=tile.split_bk,
                                interpret=self.plan.interpret)
        return SplitResult(slices, exp, w)

    def split_dw(self, x: DW, w: int) -> SplitResult:
        from repro.kernels import fused_split_dw
        exp = row_exponents(x.hi)
        tile = self.plan.tile
        slices = fused_split_dw(x.hi, x.lo, exp,
                                num_splits=self.plan.num_splits, w=w,
                                bm=tile.split_bm, bk=tile.split_bk,
                                interpret=self.plan.interpret)
        return SplitResult(slices, exp, w)

    def accumulate(self, products, e_base: jax.Array, w: int, shape):
        from repro.kernels import accum_scaled_dw, accum_scaled_sw
        tile = self.plan.tile
        kw = dict(bm=tile.accum_bm, bn=tile.accum_bn,
                  interpret=self.plan.interpret)
        fold = len(shape) > 2
        flat = (-1, shape[-1])

        def fold2d(x):
            return x.reshape(flat) if fold else x

        if self.plan.accum == "f64":
            c = fold2d(jnp.zeros(shape, jnp.float64))
            for t, p_t in _ordered(products):
                c = accum_scaled_sw(fold2d(p_t), c,
                                    scale=2.0 ** (-(t + 2) * w), **kw)
            return jnp.ldexp(c.reshape(shape), e_base)
        c_hi = fold2d(jnp.zeros(shape, jnp.float32))
        c_lo = fold2d(jnp.zeros(shape, jnp.float32))
        for t, p_t in _ordered(products):
            c_hi, c_lo = accum_scaled_dw(fold2d(p_t), c_hi, c_lo,
                                         scale=2.0 ** (-(t + 2) * w), **kw)
        return DW(jnp.ldexp(c_hi.reshape(shape), e_base),
                  jnp.ldexp(c_lo.reshape(shape), e_base))


class EpilogueExecutor(FusedExecutor):
    """``fusion="epilogue"``: GEMM + scaled accumulation in one kernel.

    One launch per anti-diagonal group; the group's int32 product lives
    only in a VMEM scratch block (``tuning.hbm_pass_model`` drops the
    per-group P read). ``concat_k`` needs no concatenated operands here —
    the pair grid dimension accumulates the same exact int32 sum. A 3-D
    output shape runs the batch-grid epilogue kernels ((s, B, m, k)
    slice stacks, batch outermost in the grid): stacked-weights batches
    keep epilogue fusion instead of downgrading to the stage-fused
    pipeline.
    """

    def _groups(self):
        """(t, p_lo, npairs) in accumulation order: t descending, and for
        the unfused schedule pairs in ``diagonals()`` order (matching the
        stable ``_ordered`` sort of the reference products list).
        ``npairs`` reflects the plan's ``pair_policy``: a truncated
        diagonal launches a shorter pair-grid dimension (the kept pairs
        are the prefix from ``p_lo``, which the kernels' affine slice
        indexing covers unchanged)."""
        plan = self.plan
        groups = []
        for t, pairs in plan.diagonals():
            if plan.fuse_diagonals or plan.concat_k:
                groups.append((t, pairs[0][0], len(pairs)))
            else:
                groups.extend((t, p, 1) for p, _ in pairs)
        return sorted(groups, key=lambda g: -g[0])

    def contract(self, sa: SplitResult, sb: SplitResult, w: int,
                 e_base: jax.Array, shape):
        from repro.kernels import (int8_matmul_nt_epilogue_dw,
                                   int8_matmul_nt_epilogue_sw)
        assert len(shape) in (2, 3), shape    # 3-D: batch-grid kernels
        tile = self.plan.tile
        kw = dict(bm=tile.bm, bn=tile.bn, bk=tile.bk,
                  interpret=self.plan.interpret)
        if self.plan.accum == "f64":
            c = jnp.zeros(shape, jnp.float64)
            for t, p_lo, npairs in self._groups():
                c = int8_matmul_nt_epilogue_sw(
                    sa.slices, sb.slices, c, p_lo=p_lo, t=t, npairs=npairs,
                    scale=2.0 ** (-(t + 2) * w), **kw)
            return jnp.ldexp(c, e_base)
        c_hi = jnp.zeros(shape, jnp.float32)
        c_lo = jnp.zeros(shape, jnp.float32)
        for t, p_lo, npairs in self._groups():
            c_hi, c_lo = int8_matmul_nt_epilogue_dw(
                sa.slices, sb.slices, c_hi, c_lo, p_lo=p_lo, t=t,
                npairs=npairs, scale=2.0 ** (-(t + 2) * w), **kw)
        return DW(jnp.ldexp(c_hi, e_base), jnp.ldexp(c_lo, e_base))


class StreamingSplit(NamedTuple):
    """Stage-1 "result" of the streaming pipeline: nothing is split yet.

    ``split`` only computes the per-row exponents; the (hi, lo) operand
    words ride forward so the streaming GEMM kernels can extract the int8
    slices tile-wise in VMEM — the slice stacks never exist in HBM.
    Duck-types the ``SplitResult`` fields the driver reads (exp, w).
    """

    hi: jax.Array
    lo: jax.Array
    exp: jax.Array
    w: int


class StreamingExecutor(EpilogueExecutor):
    """``fusion="streaming"``: split + GEMM + accumulation in one kernel.

    The anti-diagonal group schedule, rounding sequences and accumulation
    order are exactly the epilogue executor's; the difference is purely
    where the slices live. ``split``/``split_dw`` are no-ops that carry
    the operand words plus precomputed row exponents forward (the
    exponents are full-row reductions, so they must be computed before
    tiling), and each group's kernel extracts the slice prefix it needs
    into VMEM scratch. Extraction is elementwise per (row, col) given the
    row exponent, so the tile-wise in-kernel split is bitwise identical
    to the materialized stacks — the parity matrix enforces it.
    """

    def split(self, x: jax.Array, w: int) -> StreamingSplit:
        return StreamingSplit(x, jnp.zeros_like(x), row_exponents(x), w)

    def split_dw(self, x: DW, w: int) -> StreamingSplit:
        return StreamingSplit(x.hi, x.lo, row_exponents(x.hi), w)

    def contract(self, sa: StreamingSplit, sb: StreamingSplit, w: int,
                 e_base: jax.Array, shape):
        from repro.kernels import (int8_matmul_nt_streaming_dw,
                                   int8_matmul_nt_streaming_sw)
        assert len(shape) in (2, 3), shape    # 3-D: batch-grid kernels
        plan = self.plan
        tile = plan.tile
        kw = dict(num_splits=plan.num_splits, w=w, bm=tile.bm, bn=tile.bn,
                  bk=tile.bk, interpret=plan.interpret)
        a_ops = (sa.hi, sa.lo, sa.exp)
        b_ops = (sb.hi, sb.lo, sb.exp)
        if plan.accum == "f64":
            c = jnp.zeros(shape, jnp.float64)
            for t, p_lo, npairs in self._groups():
                c = int8_matmul_nt_streaming_sw(
                    *a_ops, *b_ops, c, p_lo=p_lo, t=t, npairs=npairs,
                    scale=2.0 ** (-(t + 2) * w), **kw)
            return jnp.ldexp(c, e_base)
        c_hi = jnp.zeros(shape, jnp.float32)
        c_lo = jnp.zeros(shape, jnp.float32)
        for t, p_lo, npairs in self._groups():
            c_hi, c_lo = int8_matmul_nt_streaming_dw(
                *a_ops, *b_ops, c_hi, c_lo, p_lo=p_lo, t=t, npairs=npairs,
                scale=2.0 ** (-(t + 2) * w), **kw)
        return DW(jnp.ldexp(c_hi, e_base), jnp.ldexp(c_lo, e_base))


class ModularXlaExecutor:
    """Ozaki Scheme II reference executor (``plan.scheme="ozaki2_fp64"``).

    Stage 1 reuses ``split_int`` — the ``num_splits`` slices ARE the
    integerization (``A_int = sum_p slices[p] * 2^{(s-1-p)w}``, beta =
    s*w bits kept). Stage 2 maps the slices to centered int8 residues
    per modulus and runs ONE int8 NT GEMM per modulus, with the modulus
    axis as the leading batch dimension (a batched operand folds the
    (modulus, batch) product onto that same axis — still one launch).
    Stage 3 is the exact CRT reconstruction (``core.modular``): Garner
    digits in int32, FP64 sum smallest radix first, deferred ``e_base``
    applied once at the end — the same rounding-sequence discipline the
    Scheme I executors keep, so the guaranteed bound
    (``modular.modular_error_bound``) is the whole error story.

    The moduli re-derive from the plan deterministically:
    ``usable_moduli(k)[:plan.num_moduli]`` — selection always takes a
    prefix of the usable pool, so the plan's count is the full identity.
    """

    def __init__(self, plan: PipelinePlan):
        self.plan = plan

    # ---- stage 1: integerize (slice-built) -----------------------------
    def split(self, x: jax.Array, w: int) -> SplitResult:
        return split_int(x, self.plan.num_splits, w)

    # ---- stage 2: residue GEMMs ----------------------------------------
    def gemm(self, a8: jax.Array, bt8: jax.Array) -> jax.Array:
        return gemm_xla(a8, bt8)

    # ---- stages 2+3 -----------------------------------------------------
    def contract(self, sa: SplitResult, sb: SplitResult, w: int,
                 e_base: jax.Array, shape):
        k = sa.slices.shape[-1]
        moduli = usable_moduli(k)[:self.plan.num_moduli]
        ra = residues_from_slices(sa.slices, w, moduli)
        rb = residues_from_slices(sb.slices, w, moduli)
        if ra.ndim == 4:                 # batched: (ell, B, rows, k)
            ell, bsz = ra.shape[0], ra.shape[1]
            p = self.gemm(ra.reshape(ell * bsz, ra.shape[2], k),
                          rb.reshape(ell * bsz, rb.shape[2], k))
            p = p.reshape((ell,) + shape)
        else:                            # 2-D: modulus axis is the batch
            p = self.gemm(ra, rb)
        digits = crt_digits(center_mod(p, moduli), moduli)
        return crt_value(digits, moduli, self.plan.beta, e_base)


class ModularPallasExecutor(ModularXlaExecutor):
    """Residue GEMMs on the batch-grid Pallas MXU kernel: the modulus
    (or modulus x batch) axis is the outermost grid dimension of ONE
    ``int8_matmul_nt_batched`` launch — the operands are always 3-D
    here, so the batched kernel is the only entry needed."""

    def gemm(self, a8: jax.Array, bt8: jax.Array) -> jax.Array:
        from repro.kernels import int8_matmul_nt_batched
        tile = self.plan.tile
        return int8_matmul_nt_batched(a8, bt8, bm=tile.bm, bn=tile.bn,
                                      bk=tile.bk,
                                      interpret=self.plan.interpret)


class ModularFusedExecutor(ModularPallasExecutor):
    """``pallas_fused`` Scheme II: integerize with the one-pass SplitInt
    kernel (stage-1 fusion — the residue GEMM stage is already a single
    batched launch, and CRT is elementwise XLA)."""

    def split(self, x: jax.Array, w: int) -> SplitResult:
        return FusedExecutor.split(self, x, w)


class ModularEpilogueExecutor(ModularFusedExecutor):
    """``fusion="epilogue"`` Scheme II: residue GEMMs + balanced-Garner
    CRT reconstruction in ONE kernel launch.

    The per-modulus int32 product planes accumulate in a (ell, bm, bn)
    VMEM scratch stack over the (modulus, k) grid walk and the CRT
    epilogue reconstructs the f64 value at the last grid step — they
    never round-trip through HBM (``tuning.hbm_pass_model`` drops the
    2*ell accumulation passes). The kernel replays
    ``crt_digits``/``crt_value``'s exact integer recurrence and f64
    rounding sequence with host-baked Garner constants
    (``modular.garner_constants``), so the fused route stays bitwise
    identical to the unfused XLA reference.
    """

    def contract(self, sa: SplitResult, sb: SplitResult, w: int,
                 e_base: jax.Array, shape):
        from repro.kernels import int8_matmul_nt_crt
        k = sa.slices.shape[-1]
        moduli = usable_moduli(k)[:self.plan.num_moduli]
        ra = residues_from_slices(sa.slices, w, moduli)
        rb = residues_from_slices(sb.slices, w, moduli)
        mods, qmod, inv, scales = garner_constants(moduli, self.plan.beta)
        tile = self.plan.tile
        out = int8_matmul_nt_crt(ra, rb, moduli=mods, qmod=qmod, inv=inv,
                                 scales=scales, bm=tile.bm, bn=tile.bn,
                                 bk=tile.bk, interpret=self.plan.interpret)
        return jnp.ldexp(out, e_base)


def get_executor(plan: PipelinePlan) -> XlaExecutor:
    if getattr(plan, "scheme", "ozaki_fp64") == "ozaki2_fp64":
        if plan.backend == "xla":
            return ModularXlaExecutor(plan)
        if plan.backend == "pallas":
            return ModularPallasExecutor(plan)
        if plan.backend == "pallas_fused":
            if plan.fusion == "epilogue":
                return ModularEpilogueExecutor(plan)
            return ModularFusedExecutor(plan)
        raise ValueError(f"unknown backend {plan.backend!r}; "
                         f"expected one of {BACKENDS}")
    if plan.backend == "xla":
        return XlaExecutor(plan)
    if plan.backend == "pallas":
        return PallasExecutor(plan)
    if plan.backend == "pallas_fused":
        if plan.fusion == "streaming":
            return StreamingExecutor(plan)
        if plan.fusion == "epilogue":
            return EpilogueExecutor(plan)
        return FusedExecutor(plan)
    raise ValueError(f"unknown backend {plan.backend!r}; "
                     f"expected one of {BACKENDS}")
