"""Tile/schedule selection for the Ozaki pipeline (fused-backend planner).

Given operand shapes, this module picks (a) the number of splits from the
analytic model in ``core.analytic`` and (b) Pallas block shapes for the
three pipeline stages, so callers never hand-tune kernel launches.

Heuristics (kept deliberately closed-form — no autotuning searches):

* **num_splits** — the smallest ``s`` with ``s * BPS(k) >= mantissa_space``
  (Eq. 5 / Table 2): the paper's INT8xs operating point for a target
  mantissa-space length (70 bits for the DGEMM-replacement mode). Callers
  wanting data-dependent selection use ``core.auto_split`` instead; this
  planner is shape-only so it can run before the operands exist.
* **GEMM blocks (bm, bn, bk)** — largest power-of-two, MXU-aligned tiles
  whose working set ``bm*bk + bn*bk (int8) + 4*bm*bn (int32)`` fits the
  VMEM budget (default: half of 16 MiB, leaving room for double
  buffering). Under pressure the reduction slab ``bk`` halves first (it
  shrinks BOTH int8 operand tiles at once and only lengthens the inner
  k loop), then ``bm``, then ``bn`` down to their alignment floors.
* **split blocks** — the split kernel's output block is ``num_splits``
  times its input tile, so the input tile is sized from
  ``(num_splits + 8) * split_bm * split_bk <= budget`` (8 ~= two float32
  input blocks at 4 bytes each per int8 output element).
* **accum blocks** — elementwise kernel; the largest aligned tile for the
  (m, n) output with 4 arrays resident (p, c_hi, c_lo, + headroom).
* **schedule** — ``fuse_diagonals`` always (the int32 pre-accumulation is
  exact, strictly fewer high-precision accumulations);``concat_k`` when
  the per-GEMM reduction is short (k <= CONCAT_K_MAX) so that one big
  MXU launch amortizes what would otherwise be launch-bound slice GEMMs.

``apply_plan`` folds a plan back into an ``OzakiConfig`` without importing
it (plain ``dataclasses.replace``), keeping this module import-cycle-free.
"""
from __future__ import annotations

import dataclasses
import math

# alignment vocabulary is owned by the kernels' shared launch layer, so
# the planner's choices match shrink_block's exactly (repro.core imports
# repro.kernels.launch only — the kernels themselves import repro.core
# lazily, so there is no cycle).
from repro.kernels.launch import (LANE, SUBLANE_F32 as SUBLANE, SUBLANE_I8,
                                  align_up as _align_up)

from .analytic import DGEMM_MANTISSA_SPACE, INT8_INT32, MMUSpec

VMEM_BYTES = 16 * 2 ** 20
VMEM_BUDGET = VMEM_BYTES // 2      # leave half for double buffering
CONCAT_K_MAX = 2048                 # below this, slice GEMMs are launch-bound


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """Block shapes + schedule for one fused-pipeline launch (hashable)."""

    bm: int = 256                   # int8 GEMM output rows per block
    bn: int = 256                   # int8 GEMM output cols per block
    bk: int = 512                   # int8 GEMM reduction slab
    split_bm: int = 256             # split kernel input tile rows
    split_bk: int = 256             # split kernel input tile cols
    accum_bm: int = 256             # accumulation tile rows
    accum_bn: int = 256             # accumulation tile cols
    num_splits: int = 9
    fuse_diagonals: bool = True
    concat_k: bool = False


def _pow2_at_most(x: int, lo: int) -> int:
    """Largest power of two <= x, floored at ``lo``."""
    if x <= lo:
        return lo
    return 2 ** int(math.floor(math.log2(x)))


def select_num_splits(k: int, *, mantissa_space: int = DGEMM_MANTISSA_SPACE,
                      mmu: MMUSpec = INT8_INT32) -> int:
    """Paper operating point: ceil(mantissa_space / BPS(k))."""
    return mmu.num_splits(k, mantissa_space)


def select_plan(m: int, n: int, k: int, *, batch: int = 1,
                num_splits: int | None = None,
                mantissa_space: int = DGEMM_MANTISSA_SPACE,
                mmu: MMUSpec = INT8_INT32,
                vmem_budget: int = VMEM_BUDGET) -> TilePlan:
    """Pick block shapes and split count from operand shapes alone.

    ``batch`` scales nothing directly (the batch is a grid dimension, not
    a VMEM resident), but a multi-row batch disables ``concat_k`` — the
    concatenated operands would be materialized once per batch row.
    """
    if num_splits is None:
        num_splits = select_num_splits(k, mantissa_space=mantissa_space,
                                       mmu=mmu)

    # --- GEMM blocks: shrink from the 256x256x512 MXU sweet spot.
    # bm is an int8 A-tile sublane dim (32-aligned); bn doubles as the
    # int32 C-tile lane dim, so the stricter 128 alignment applies.
    bm = min(256, _pow2_at_most(_align_up(m, SUBLANE_I8), SUBLANE_I8))
    bn = min(256, _pow2_at_most(_align_up(n, LANE), LANE))
    bk = min(512, _pow2_at_most(_align_up(k, LANE), LANE))
    while bm * bk + bn * bk + 4 * bm * bn > vmem_budget:
        if bk > LANE:
            bk //= 2
        elif bm > SUBLANE_I8:
            bm //= 2
        elif bn > LANE:
            bn //= 2
        else:
            break

    # --- split blocks: output is num_splits x the (int8) input tile.
    split_bm = min(256, _pow2_at_most(_align_up(m, SUBLANE_I8), SUBLANE_I8))
    split_bk = min(256, _pow2_at_most(_align_up(k, LANE), LANE))
    while (num_splits + 8) * split_bm * split_bk > vmem_budget and \
            split_bk > LANE:
        split_bk //= 2

    # --- accum blocks: 4 f32/int32 arrays resident per tile.
    accum_bm = min(256, _pow2_at_most(_align_up(m, SUBLANE), SUBLANE))
    accum_bn = min(256, _pow2_at_most(_align_up(n, LANE), LANE))
    while 16 * accum_bm * accum_bn > vmem_budget and accum_bn > LANE:
        accum_bn //= 2

    return TilePlan(bm=bm, bn=bn, bk=bk, split_bm=split_bm,
                    split_bk=split_bk, accum_bm=accum_bm, accum_bn=accum_bn,
                    num_splits=num_splits, fuse_diagonals=True,
                    concat_k=(k <= CONCAT_K_MAX and batch == 1))


def apply_plan(cfg, plan: TilePlan):
    """Fold a TilePlan into an OzakiConfig (any dataclass with the fields)."""
    return dataclasses.replace(cfg, num_splits=plan.num_splits,
                               fuse_diagonals=plan.fuse_diagonals,
                               concat_k=plan.concat_k, tile=plan)


def hbm_pass_model(num_splits: int, *, fused: bool,
                   fuse_diagonals: bool = True) -> dict:
    """Modeled HBM round-trips per stage for one operand/output matrix.

    Counts *array passes* (each read or write of a full matrix-sized
    buffer), the quantity the paper's Fig. 9 shows dominating the split
    and accumulation stages:

    * split — Algorithm 4 re-reads the residual every iteration
      (``s`` passes) while the one-pass kernel reads the input once.
    * accum — the unfused path materializes the int32->float conversion
      and the scaled term before the compensated add (2 extra passes per
      accumulation group); the fused kernel does conversion + scale +
      add in registers within one VMEM pass.
    """
    s = num_splits
    groups = s if fuse_diagonals else s * (s + 1) // 2
    split_passes = 1 if fused else s
    # per group: read P + read/write C(hi,lo); unfused adds temp traffic
    accum_passes = groups * (3 if fused else 5)
    return {"split": split_passes, "accum": accum_passes,
            "total": split_passes + accum_passes}
