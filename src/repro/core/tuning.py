"""Planner for the Ozaki pipeline: tiles, schedule, and execution strategy.

Two layers of planning live here:

* ``TilePlan`` / ``select_plan`` — block shapes and split count from
  operand shapes (the PR 1 tile planner, unchanged contract).
* ``PipelinePlan`` / ``plan_for`` / ``select_pipeline_plan`` — the full
  execution strategy for one GEMM shape: which executor runs the pipeline
  (``backend``), how stages are fused (``fusion``: separate kernels,
  stage-fused kernels, or the epilogue-fused GEMM that never materializes
  int32 products), how a batch is laid out (``batch_layout``: folded into
  rows, an explicit batch grid dimension, or absent), and which mesh axis
  the reduction is sharded over (``shard_axis``). ``core.ozaki`` is a
  thin driver: it builds (or receives) a ``PipelinePlan`` once per shape
  and hands execution to the executor the plan selects
  (``core.executors.get_executor``).

Given operand shapes, this module picks (a) the number of splits from the
analytic model in ``core.analytic`` and (b) Pallas block shapes for the
three pipeline stages, so callers never hand-tune kernel launches.

The analytic planner is the fallback and the seed of the search space;
the *measured* layer lives in ``core.autotune``: ``select_pipeline_plan``
consults a persistent ``PlanCache`` when given one (hit returns without
re-tuning) and can hand a miss to the measurement-driven autotuner
(``autotune=True``), which times candidate plans on the live backend.

Heuristics of the analytic layer (closed-form, shape-only):

* **num_splits** — the smallest ``s`` with ``s * BPS(k) >= mantissa_space``
  (Eq. 5 / Table 2): the paper's INT8xs operating point for a target
  mantissa-space length (70 bits for the DGEMM-replacement mode). Callers
  wanting data-dependent selection use ``core.auto_split`` instead; this
  planner is shape-only so it can run before the operands exist.
* **GEMM blocks (bm, bn, bk)** — largest power-of-two, MXU-aligned tiles
  whose working set ``bm*bk + bn*bk (int8) + 4*bm*bn (int32)`` fits the
  VMEM budget (default: half of 16 MiB, leaving room for double
  buffering). Under pressure the reduction slab ``bk`` halves first (it
  shrinks BOTH int8 operand tiles at once and only lengthens the inner
  k loop), then ``bm``, then ``bn`` down to their alignment floors.
* **split blocks** — the split kernel's output block is ``num_splits``
  times its input tile, so the input tile is sized from
  ``(num_splits + 8) * split_bm * split_bk <= budget`` (8 ~= two float32
  input blocks at 4 bytes each per int8 output element).
* **accum blocks** — elementwise kernel; the largest aligned tile for the
  (m, n) output with 4 arrays resident (p, c_hi, c_lo, + headroom).
* **schedule** — ``fuse_diagonals`` always (the int32 pre-accumulation is
  exact, strictly fewer high-precision accumulations);``concat_k`` when
  the per-GEMM reduction is short (k <= CONCAT_K_MAX) so that one big
  MXU launch amortizes what would otherwise be launch-bound slice GEMMs.

``apply_plan`` folds a plan back into an ``OzakiConfig`` without importing
it (plain ``dataclasses.replace``), keeping this module import-cycle-free.
"""
from __future__ import annotations

import dataclasses
import math
import os
from typing import Optional, Sequence

# alignment vocabulary is owned by the kernels' shared launch layer, so
# the planner's choices match shrink_block's exactly (repro.core imports
# repro.kernels.launch only — the kernels themselves import repro.core
# lazily, so there is no cycle).
from repro.kernels.launch import (LANE, SUBLANE_F32 as SUBLANE, SUBLANE_I8,
                                  VMEM_BYTES, align_up as _align_up)

from .analytic import DGEMM_MANTISSA_SPACE, INT8_INT32, MMUSpec
from .splitting import slice_width
from .warn_once import WarnOnceLatch

VMEM_BUDGET = VMEM_BYTES // 2      # leave half for double buffering
CONCAT_K_MAX = 2048                 # below this, slice GEMMs are launch-bound

BACKENDS = ("xla", "pallas", "pallas_fused")
FUSION_MODES = ("none", "stages", "epilogue", "streaming")
BATCH_LAYOUTS = ("none", "rows", "grid")
# Which emulation algorithm a plan executes: Scheme I ("ozaki_fp64",
# slice-pair GEMMs — everything above) or Scheme II ("ozaki2_fp64",
# residue-system GEMMs + CRT — ``core.modular``). The scheme is part of
# the plan because the executor family, the GEMM count, and the accuracy
# bound all pivot on it; ``core.accuracy.resolve_accuracy`` arbitrates
# between the two per (shape, target).
PLAN_SCHEMES = ("ozaki_fp64", "ozaki2_fp64")
# What crosses the interconnect when the GEMM is sharded: "f64" moves
# f64 operand words (the GSPMD auto-sharding baseline gathers operands
# around the opaque kernels), "int8" ships the quantized Ozaki
# representation itself — packed int8 slice stacks + int32 exponent
# vectors for gathers, exact int32 pair partials for reductions
# (parallel.compression.SliceWire / parallel.ozaki_shard schedules).
# Result-invariant: every transport is bitwise-identical to the
# single-device reference (integer collectives are associative).
COMM_MODES = ("f64", "int8")
# Fast-mode pair truncation (see core.accuracy): "full" keeps the whole
# schedule; "diagonal" drops the last (least-significant) anti-diagonal
# group; "budget:N" keeps only the N highest-significance pairs. The
# policy is part of the plan, so executors thread it into the kernels'
# grid construction (fewer pair steps launched) — never a post-hoc mask.
PAIR_POLICIES = ("full", "diagonal", "budget:N")

# The batch-grid epilogue kernels ship with this PR; the env knob exists
# for deployments that need to fall back to the stage-fused pipeline on
# batched calls (e.g. a backend where the 5-D epilogue grid is not yet
# validated). The fallback warns once per reason instead of silently
# switching fusion mode. The latch is a shared ``WarnOnceLatch`` so the
# conftest-wide ``reset_all_warn_latches`` covers it.
BATCHED_EPILOGUE_ENV = "REPRO_OZAKI_BATCHED_EPILOGUE"
_DOWNGRADE_LATCH = WarnOnceLatch("fuse_epilogue_downgrade")


def batched_epilogue_enabled() -> bool:
    return os.environ.get(BATCHED_EPILOGUE_ENV, "1") != "0"


def _warn_downgrade_once(reason: str) -> None:
    _DOWNGRADE_LATCH.warn(
        reason, f"fuse_epilogue downgraded to fusion='stages': {reason}",
        stacklevel=4)


def reset_downgrade_warnings() -> None:
    """Reset the warn-once latch to fresh-process state.

    The latch is module-level state, so without a reset only the FIRST
    plan built after the env knob flips would warn — a second test (or a
    re-configured long-lived process) would see silence. Test fixtures
    (``tests/conftest.py``) reset every registered latch around every
    test (``core.warn_once.reset_all_warn_latches``); deployments that
    re-read the env knob at runtime should call this when they do.
    """
    _DOWNGRADE_LATCH.reset()


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """Block shapes + schedule for one fused-pipeline launch (hashable)."""

    bm: int = 256                   # int8 GEMM output rows per block
    bn: int = 256                   # int8 GEMM output cols per block
    bk: int = 512                   # int8 GEMM reduction slab
    split_bm: int = 256             # split kernel input tile rows
    split_bk: int = 256             # split kernel input tile cols
    accum_bm: int = 256             # accumulation tile rows
    accum_bn: int = 256             # accumulation tile cols
    num_splits: int = 9
    fuse_diagonals: bool = True
    concat_k: bool = False


def _pow2_at_most(x: int, lo: int) -> int:
    """Largest power of two <= x, floored at ``lo``."""
    if x <= lo:
        return lo
    return 2 ** int(math.floor(math.log2(x)))


def select_num_splits(k: int, *, mantissa_space: int = DGEMM_MANTISSA_SPACE,
                      mmu: MMUSpec = INT8_INT32) -> int:
    """Paper operating point: ceil(mantissa_space / BPS(k))."""
    return mmu.num_splits(k, mantissa_space)


def select_plan(m: int, n: int, k: int, *, batch: int = 1,
                num_splits: int | None = None,
                mantissa_space: int = DGEMM_MANTISSA_SPACE,
                mmu: MMUSpec = INT8_INT32,
                vmem_budget: int = VMEM_BUDGET) -> TilePlan:
    """Pick block shapes and split count from operand shapes alone.

    ``batch`` scales nothing directly (the batch is a grid dimension, not
    a VMEM resident), but a multi-row batch disables ``concat_k`` — the
    concatenated operands would be materialized once per batch row.
    """
    if num_splits is None:
        num_splits = select_num_splits(k, mantissa_space=mantissa_space,
                                       mmu=mmu)

    # --- GEMM blocks: shrink from the 256x256x512 MXU sweet spot.
    # bm is an int8 A-tile sublane dim (32-aligned); bn doubles as the
    # int32 C-tile lane dim, so the stricter 128 alignment applies.
    bm = min(256, _pow2_at_most(_align_up(m, SUBLANE_I8), SUBLANE_I8))
    bn = min(256, _pow2_at_most(_align_up(n, LANE), LANE))
    bk = min(512, _pow2_at_most(_align_up(k, LANE), LANE))
    while bm * bk + bn * bk + 4 * bm * bn > vmem_budget:
        if bk > LANE:
            bk //= 2
        elif bm > SUBLANE_I8:
            bm //= 2
        elif bn > LANE:
            bn //= 2
        else:
            break

    # --- split blocks: output is num_splits x the (int8) input tile.
    split_bm = min(256, _pow2_at_most(_align_up(m, SUBLANE_I8), SUBLANE_I8))
    split_bk = min(256, _pow2_at_most(_align_up(k, LANE), LANE))
    while (num_splits + 8) * split_bm * split_bk > vmem_budget and \
            split_bk > LANE:
        split_bk //= 2

    # --- accum blocks: 4 f32/int32 arrays resident per tile.
    accum_bm = min(256, _pow2_at_most(_align_up(m, SUBLANE), SUBLANE))
    accum_bn = min(256, _pow2_at_most(_align_up(n, LANE), LANE))
    while 16 * accum_bm * accum_bn > vmem_budget and accum_bn > LANE:
        accum_bn //= 2

    return TilePlan(bm=bm, bn=bn, bk=bk, split_bm=split_bm,
                    split_bk=split_bk, accum_bm=accum_bm, accum_bn=accum_bn,
                    num_splits=num_splits, fuse_diagonals=True,
                    concat_k=(k <= CONCAT_K_MAX and batch == 1))


def apply_plan(cfg, plan: TilePlan):
    """Fold a TilePlan into an OzakiConfig (any dataclass with the fields)."""
    return dataclasses.replace(cfg, num_splits=plan.num_splits,
                               fuse_diagonals=plan.fuse_diagonals,
                               concat_k=plan.concat_k, tile=plan)


# ----------------------------------------------------------------------------
# Pipeline planning: the full execution strategy for one GEMM shape
# ----------------------------------------------------------------------------

def diagonal_groups(num_splits: int,
                    full_pairs: bool = False,
                    pair_budget: Optional[int] = None
                    ) -> Sequence[tuple[int, Sequence[tuple[int, int]]]]:
    """0-based (t, [(p, q)...]) anti-diagonal groups with t = p + q.

    The schedule vocabulary shared by ``OzakiConfig`` and ``PipelinePlan``:
    the paper computes pairs with i + j <= s + 1 (``t <= s - 1`` 0-based);
    ``full_pairs`` keeps all 2s - 1 diagonals. ``pair_budget`` (from
    ``parse_pair_policy``) keeps only the first N pairs in significance
    order — diagonals ascending, the last kept diagonal possibly partial
    (its pairs share one scale, so which prefix survives is
    accuracy-neutral within the diagonal).
    """
    s = num_splits
    t_max = 2 * s - 2 if full_pairs else s - 1
    out = []
    remaining = pair_budget
    for t in range(t_max + 1):
        pairs = [(p, t - p) for p in range(max(0, t - s + 1),
                                           min(s - 1, t) + 1)]
        if remaining is not None:
            if remaining <= 0:
                break
            pairs = pairs[:remaining]
            remaining -= len(pairs)
        out.append((t, pairs))
    return out


def parse_pair_policy(policy: str, num_splits: int,
                      full_pairs: bool = False) -> Optional[int]:
    """Pair budget (kept-pair count) encoded by a policy string.

    ``None`` means "no truncation" (the full schedule); budgets are
    clamped to ``[1, total]`` — a plan always computes at least the
    leading (0, 0) pair. Raises ``ValueError`` on malformed policies, so
    ``PipelinePlan.__post_init__`` can validate by parsing.
    """
    groups = diagonal_groups(num_splits, full_pairs)
    total = sum(len(p) for _, p in groups)
    if policy == "full":
        return None
    if policy == "diagonal":
        return max(1, total - len(groups[-1][1]))
    if policy.startswith("budget:"):
        try:
            n = int(policy[len("budget:"):])
        except ValueError:
            n = 0
        if n < 1:
            raise ValueError(f"pair budget must be a positive int, "
                             f"got {policy!r}")
        return min(n, total)
    raise ValueError(f"unknown pair_policy {policy!r}; expected one of "
                     f"{PAIR_POLICIES}")


def plan_schedule_ok(plan: "PipelinePlan", k: int, *, ell_acc: int = 31,
                     ell_in: int = 7) -> bool:
    """True when the plan's split schedule is executable on the df32 path.

    ``ozaki_matmul_dw`` requires ``(num_splits + 1) * w <= 120`` so every
    accumulation scale stays in f32 normal range; a candidate enumerated
    above that (e.g. ``search_num_splits`` widening s) would crash
    mid-measurement. f64 accumulation has no such ceiling.
    """
    if plan.accum != "df32":
        return True
    fuse_terms = (plan.num_splits
                  if (plan.fuse_diagonals or plan.concat_k) else 1)
    w = slice_width(k, ell_acc=ell_acc, ell_in=ell_in,
                    fuse_terms=fuse_terms)
    return (plan.num_splits + 1) * w <= 120


@dataclasses.dataclass(frozen=True)
class PipelinePlan:
    """Execution strategy for one Ozaki GEMM shape (hashable, serializable).

    Built once per shape — by ``plan_for`` (reflecting an ``OzakiConfig``)
    or ``select_pipeline_plan`` (from shapes alone) — and consumed by
    ``core.executors``:

    num_splits:   s (INT8xs operating point).
    tile:         block shapes for every kernel launch (``TilePlan``; its
                  own num_splits/schedule fields are advisory — the plan's
                  top-level fields below are authoritative).
    backend:      "xla" | "pallas" | "pallas_fused" — executor family.
    fusion:       "none"      — every stage a separate op/kernel;
                  "stages"    — one-pass split + fused accumulation kernels
                                (the PR 1 ``pallas_fused`` pipeline);
                  "epilogue"  — GEMM and scaled accumulation in ONE kernel:
                                int32 group products never reach HBM;
                  "streaming" — split + GEMM + accumulation in ONE kernel:
                                the int8 slice stacks are extracted
                                tile-wise in VMEM and never reach HBM
                                either (only the operand words and the
                                carried C cross the HBM boundary).
    batch_layout: "none" — unbatched (m, k) x (k, n);
                  "rows" — broadcast weights, batch folded into rows;
                  "grid" — explicit batch grid dimension on every stage.
    shard_axis:   mesh axis name the k (reduction) dim is sharded over, or
                  None. Consumed by ``parallel.ozaki_shard`` composition
                  and the model/serving layers; the executors themselves
                  stay single-device (GSPMD inserts the collectives).
    comm:         "f64" — sharded calls move f64 operand words (GSPMD
                  baseline); "int8" — ship the packed int8-slice
                  representation / exact int32 partials instead
                  (``parallel.ozaki_shard`` explicit schedules;
                  ``comm_bytes_model`` prices both). Result-invariant:
                  a no-op without a shard axis + registered mesh.
    pair_policy:  "full" | "diagonal" | "budget:N" — fast-mode pair
                  truncation (``core.accuracy`` bounds the error). The
                  policy shapes ``diagonals()``, so every executor and
                  the Pallas pair-grid dimensions shrink with it.
    fuse_diagonals / concat_k / full_pairs / accum / interpret: the
    schedule and numeric knobs, verbatim from the config.

    scheme / beta / num_moduli: the emulation algorithm. Scheme I
    (``"ozaki_fp64"``) ignores beta/num_moduli (0 sentinels); Scheme II
    (``"ozaki2_fp64"``) records its operating point — ``beta`` mantissa
    bits (= ``num_splits * 7``, the integerization slice count) and the
    residue-GEMM count ``num_moduli`` (the moduli themselves re-derive
    deterministically as ``modular.usable_moduli(k)[:num_moduli]``).
    Scheme II constraints: f64 accumulation only (the CRT reconstruction
    is an FP64 sum), "full" pair policy (there is no pair schedule to
    truncate — accuracy scales via beta), and fusion "none"/"stages"/
    "epilogue" — "epilogue" is the fused-CRT kernel (balanced-Garner
    reconstruction in VMEM scratch over the modulus grid axis; the int32
    residue products never round-trip through HBM). There is no Scheme II
    streaming kernel.
    """

    num_splits: int = 9
    tile: TilePlan = TilePlan()
    backend: str = "xla"
    fusion: str = "none"
    batch_layout: str = "none"
    shard_axis: Optional[str] = None
    comm: str = "f64"
    pair_policy: str = "full"
    fuse_diagonals: bool = True
    concat_k: bool = False
    full_pairs: bool = False
    accum: str = "f64"
    interpret: bool = True
    scheme: str = "ozaki_fp64"
    beta: int = 0
    num_moduli: int = 0

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"expected one of {BACKENDS}")
        if self.fusion not in FUSION_MODES:
            raise ValueError(f"unknown fusion {self.fusion!r}; "
                             f"expected one of {FUSION_MODES}")
        if self.batch_layout not in BATCH_LAYOUTS:
            raise ValueError(f"unknown batch_layout {self.batch_layout!r}; "
                             f"expected one of {BATCH_LAYOUTS}")
        if self.accum not in ("f64", "df32"):
            raise ValueError(f"unknown accum {self.accum!r}")
        if self.comm not in COMM_MODES:
            raise ValueError(f"unknown comm {self.comm!r}; "
                             f"expected one of {COMM_MODES}")
        if self.scheme not in PLAN_SCHEMES:
            raise ValueError(f"unknown scheme {self.scheme!r}; "
                             f"expected one of {PLAN_SCHEMES}")
        if self.scheme == "ozaki2_fp64":
            if self.beta < 1 or self.num_moduli < 1:
                raise ValueError(
                    f"ozaki2_fp64 plans need beta >= 1 and num_moduli >= 1, "
                    f"got beta={self.beta}, num_moduli={self.num_moduli}")
            if self.accum != "f64":
                raise ValueError("ozaki2_fp64 accumulates in f64 only "
                                 f"(CRT reconstruction), got {self.accum!r}")
            if self.fusion not in ("none", "stages", "epilogue"):
                raise ValueError(
                    f"ozaki2_fp64 supports fusion 'none'/'stages'/"
                    f"'epilogue' (fused-CRT reconstruction; no residue "
                    f"streaming kernel), got {self.fusion!r}")
            if self.pair_policy != "full":
                raise ValueError(
                    "ozaki2_fp64 has no pair schedule to truncate "
                    f"(accuracy scales via beta), got pair_policy="
                    f"{self.pair_policy!r}")
        parse_pair_policy(self.pair_policy, self.num_splits,
                          self.full_pairs)       # raises on malformed

    def diagonals(self):
        return diagonal_groups(
            self.num_splits, self.full_pairs,
            pair_budget=parse_pair_policy(self.pair_policy, self.num_splits,
                                          self.full_pairs))

    @property
    def num_gemms(self) -> int:
        if self.scheme == "ozaki2_fp64":
            return self.num_moduli          # one residue GEMM per modulus
        return sum(len(p) for _, p in self.diagonals())

    # --- serialization (deployment caches / cross-process handoff) -----
    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "PipelinePlan":
        d = dict(d)
        tile = d.get("tile")
        if isinstance(tile, dict):
            d["tile"] = TilePlan(**tile)
        return cls(**d)


def _fusion_for(backend: str, fuse_epilogue: bool, batch_layout: str,
                streaming: bool = False) -> str:
    if backend != "pallas_fused":
        return "none"
    if streaming:
        if batch_layout == "grid" and not batched_epilogue_enabled():
            # streaming reuses the batch-grid epilogue machinery, so the
            # same env knob gates it (and the same warn-once fires).
            _warn_downgrade_once(
                f"stacked-weights batch with {BATCHED_EPILOGUE_ENV}=0 — "
                "the batch-grid streaming kernel is disabled, falling "
                "back to the stage-fused pipeline (batched GEMM + fused "
                "accumulation)")
            return "stages"
        return "streaming"
    if not fuse_epilogue:
        return "stages"
    if batch_layout == "grid" and not batched_epilogue_enabled():
        _warn_downgrade_once(
            f"stacked-weights batch with {BATCHED_EPILOGUE_ENV}=0 — the "
            "batch-grid epilogue kernel is disabled, falling back to the "
            "stage-fused pipeline (batched GEMM + fused accumulation)")
        return "stages"
    return "epilogue"


def plan_for(cfg, *, batch_layout: str = "none") -> PipelinePlan:
    """Reflect an ``OzakiConfig`` (duck-typed) into a ``PipelinePlan``.

    ``cfg.tile=None`` keeps the kernels' MXU-aligned default blocks
    (``TilePlan()`` matches the kernel defaults exactly); schedule flags
    come from the config, never from the tile.
    """
    tile = cfg.tile if cfg.tile is not None else TilePlan(
        num_splits=cfg.num_splits, fuse_diagonals=cfg.fuse_diagonals,
        concat_k=cfg.concat_k)
    return PipelinePlan(
        num_splits=cfg.num_splits, tile=tile, backend=cfg.backend,
        fusion=_fusion_for(cfg.backend, getattr(cfg, "fuse_epilogue", False),
                           batch_layout,
                           streaming=getattr(cfg, "streaming", False)),
        batch_layout=batch_layout,
        shard_axis=getattr(cfg, "shard_axis", None),
        comm=getattr(cfg, "comm", "f64"),
        pair_policy=getattr(cfg, "pair_policy", "full"),
        fuse_diagonals=cfg.fuse_diagonals, concat_k=cfg.concat_k,
        full_pairs=cfg.full_pairs, accum=cfg.accum, interpret=cfg.interpret)


def _cached_hit_acceptable(hit: PipelinePlan, k: int, *, num_splits,
                           target_error, accuracy_pinned: bool,
                           policy: str, scheme: str = "ozaki_fp64",
                           num_moduli=None) -> bool:
    """Shared cache-hit validation for ``select_pipeline_plan`` and
    ``autotune_plan`` (see the comment at the call site).

    Under a pinned ``target_error`` the TARGET is the contract, so a hit
    from EITHER scheme family is accepted when its guaranteed bound
    meets it — a measured cross-scheme winner must not force eternal
    re-tuning. Without a target the requested scheme must match exactly
    (and Scheme II hits must match the resolved modulus count, the
    result-affecting knob of that family).
    """
    hit_scheme = getattr(hit, "scheme", "ozaki_fp64")
    if target_error is not None:
        from .accuracy import plan_meets_target      # lazy: no cycle
        return plan_meets_target(hit, k, target_error)
    if scheme == "ozaki2_fp64":
        return hit_scheme == "ozaki2_fp64" and \
            (num_moduli is None or hit.num_moduli == num_moduli)
    if hit_scheme != "ozaki_fp64":
        return False
    if accuracy_pinned:
        return hit.num_splits == num_splits and hit.pair_policy == policy
    return (num_splits is None or hit.num_splits == num_splits) and \
        hit.pair_policy == "full"


def select_pipeline_plan(m: int, n: int, k: int, *, batch: int = 1,
                         broadcast_weights: bool = False,
                         backend: str = "pallas_fused", accum: str = "df32",
                         num_splits: int | None = None,
                         fuse_epilogue: bool = True,
                         streaming: bool = False,
                         shard_axis: Optional[str] = None,
                         comm: str = "f64",
                         interpret: bool = True,
                         target_error: Optional[float] = None,
                         fast_mode: bool = False,
                         pair_policy: Optional[str] = None,
                         mantissa_space: int = DGEMM_MANTISSA_SPACE,
                         mmu: MMUSpec = INT8_INT32,
                         vmem_budget: int = VMEM_BUDGET,
                         cache=None, autotune: bool = False,
                         dtype: Optional[str] = None,
                         device_kind: Optional[str] = None,
                         scheme: str = "ozaki_fp64",
                         num_moduli: Optional[int] = None) -> PipelinePlan:
    """Build the full execution strategy from shapes alone.

    ``batch``/``broadcast_weights`` describe the batched API's operands:
    broadcast weights fold the batch into rows (tiles are sized for the
    folded ``batch * m`` row extent — one big GEMM), a stacked-weights
    batch becomes an explicit grid dimension (and disables ``concat_k``,
    whose concatenated operands would be materialized per batch row).

    ``target_error`` / ``fast_mode`` / ``pair_policy`` pin an accuracy
    operating point (``core.accuracy.resolve_accuracy``): the target can
    REDUCE the split count below the ``mantissa_space`` default, fast
    mode truncates slice pairs to the minimal budget meeting the target
    (or drops the last diagonal when no target is set). When any of the
    three is given, a cached plan must match the resolved
    ``(num_splits, pair_policy)`` to be accepted — both are
    result-affecting.

    ``cache`` (a ``core.autotune.PlanCache``) short-circuits planning: a
    hit for ``(m, n, k, batch, dtype, backend, device_kind)`` returns
    the cached plan without re-tuning. On a miss the analytic plan above
    is returned — unless ``autotune=True``, in which case the measured
    autotuner (``core.autotune.autotune_plan``) times the candidate
    plans on the live backend, stores the winner in the cache, and
    returns it. ``dtype`` defaults from ``accum`` ("f64" -> float64,
    else float32 — the operand dtype the pipeline runs on).

    ``scheme="ozaki2_fp64"`` plans the residue-system path instead:
    ``target_error`` / ``num_moduli`` resolve the Scheme II operating
    point (``core.modular.resolve_modular``), the plan cache is keyed
    with the scheme, and fast-mode/pair-policy knobs are rejected (the
    residue path has no pair schedule).
    """
    if batch <= 1 and not broadcast_weights:
        layout = "none"
    elif broadcast_weights:
        layout = "rows"
    else:
        layout = "grid"
    if scheme not in PLAN_SCHEMES:
        raise ValueError(f"unknown scheme {scheme!r}; "
                         f"expected one of {PLAN_SCHEMES}")
    if scheme == "ozaki2_fp64":
        if fast_mode or pair_policy is not None:
            raise ValueError(
                "ozaki2_fp64 has no pair schedule: fast_mode/pair_policy "
                "do not apply (set target_error or num_moduli instead)")
        if streaming:
            raise ValueError(
                "ozaki2_fp64 has no streaming kernel: the residue stacks "
                "are built by residues_from_slices (set fuse_epilogue for "
                "the fused-CRT reconstruction instead)")
        # lazy: core.modular imports this module at top
        from .modular import modular_plan, resolve_modular
        point = resolve_modular(k, target_error=target_error,
                                num_moduli=num_moduli,
                                mantissa_space=mantissa_space)
        if cache is not None or autotune:
            from .autotune import (autotune_plan, plan_cache_key,
                                   warn_if_interpret_ranked)
            key = plan_cache_key(m, n, k, batch=batch, dtype=dtype,
                                 accum="f64", backend=backend,
                                 device_kind=device_kind,
                                 scheme="ozaki2_fp64")
            if cache is not None:
                hit = cache.get(key)
                if hit is not None and _cached_hit_acceptable(
                        hit, k, num_splits=None, target_error=target_error,
                        accuracy_pinned=target_error is not None,
                        policy="full", scheme="ozaki2_fp64",
                        num_moduli=len(point.moduli)):
                    warn_if_interpret_ranked(cache, key, interpret)
                    return hit
            if autotune:
                return autotune_plan(
                    m, n, k, batch=batch,
                    broadcast_weights=broadcast_weights, backend=backend,
                    accum="f64", interpret=interpret,
                    target_error=target_error, dtype=dtype,
                    device_kind=device_kind, mantissa_space=mantissa_space,
                    mmu=mmu, vmem_budget=vmem_budget, cache=cache,
                    scheme="ozaki2_fp64",
                    num_moduli=len(point.moduli)).best
        m_eff = m * batch if layout == "rows" else m
        tile = select_plan(m_eff, n, k,
                           batch=batch if layout == "grid" else 1,
                           num_splits=point.num_splits,
                           mantissa_space=mantissa_space, mmu=mmu,
                           vmem_budget=vmem_budget)
        return modular_plan(k, point=point, backend=backend,
                            fuse_epilogue=(fuse_epilogue and
                                           backend == "pallas_fused"),
                            interpret=interpret, tile=tile,
                            batch_layout=layout)
    accuracy_pinned = (target_error is not None or fast_mode or
                      pair_policy is not None)
    policy = pair_policy if pair_policy is not None else "full"
    if accuracy_pinned:
        from .accuracy import resolve_accuracy            # lazy: no cycle
        base_s = (num_splits if num_splits is not None else
                  select_num_splits(k, mantissa_space=mantissa_space,
                                    mmu=mmu))
        num_splits, policy = resolve_accuracy(
            k, base_s, target_error=target_error, fast_mode=fast_mode,
            pair_policy=policy)
    if cache is not None or autotune:
        from .autotune import (autotune_plan, plan_cache_key,   # lazy: no cycle
                               warn_if_interpret_ranked)
        key = plan_cache_key(m, n, k, batch=batch, dtype=dtype, accum=accum,
                             backend=backend, device_kind=device_kind)
        if cache is not None:
            hit = cache.get(key)
            # The key is deliberately coarser than the accuracy operating
            # point, so the hit path validates it:
            #  * target_error pinned — the TARGET is the contract: any
            #    cached point whose guaranteed bound meets it is accepted
            #    (a measured winner with more pairs/splits than the
            #    minimal resolution must not force eternal re-tuning);
            #  * fast_mode / explicit pair_policy without a target — the
            #    resolved (s, policy) point must match exactly;
            #  * no accuracy knobs — an explicit num_splits must match
            #    (PR 3 rule), and a fast-mode-truncated cached plan must
            #    NEVER be served silently: full schedules only.
            if hit is not None and _cached_hit_acceptable(
                    hit, k, num_splits=num_splits,
                    target_error=target_error,
                    accuracy_pinned=accuracy_pinned, policy=policy):
                warn_if_interpret_ranked(cache, key, interpret)
                return hit
        if autotune:
            return autotune_plan(
                m, n, k, batch=batch, broadcast_weights=broadcast_weights,
                backend=backend, accum=accum, num_splits=num_splits,
                fuse_epilogue=fuse_epilogue, streaming=streaming,
                shard_axis=shard_axis, comm=comm,
                interpret=interpret, target_error=target_error,
                pair_policy=policy if accuracy_pinned else None,
                dtype=dtype, device_kind=device_kind,
                mantissa_space=mantissa_space, mmu=mmu,
                vmem_budget=vmem_budget, cache=cache).best
    m_eff = m * batch if layout == "rows" else m
    tile = select_plan(m_eff, n, k, batch=batch if layout == "grid" else 1,
                       num_splits=num_splits, mantissa_space=mantissa_space,
                       mmu=mmu, vmem_budget=vmem_budget)
    return PipelinePlan(
        num_splits=tile.num_splits, tile=tile, backend=backend,
        fusion=_fusion_for(backend, fuse_epilogue, layout,
                           streaming=streaming),
        batch_layout=layout, shard_axis=shard_axis, comm=comm,
        pair_policy=policy,
        fuse_diagonals=tile.fuse_diagonals, concat_k=tile.concat_k,
        accum=accum, interpret=interpret)


def apply_pipeline_plan(cfg, plan: PipelinePlan):
    """Fold a PipelinePlan back into an OzakiConfig-shaped dataclass."""
    return dataclasses.replace(
        cfg, num_splits=plan.num_splits, backend=plan.backend,
        fuse_diagonals=plan.fuse_diagonals, concat_k=plan.concat_k,
        full_pairs=plan.full_pairs, accum=plan.accum, tile=plan.tile,
        fuse_epilogue=(plan.fusion == "epilogue"),
        streaming=(plan.fusion == "streaming"),
        pair_policy=plan.pair_policy,
        shard_axis=plan.shard_axis, comm=plan.comm,
        interpret=plan.interpret)


def hbm_pass_model(num_splits: int, *, fused: bool = False,
                   fuse_diagonals: bool = True,
                   fuse_epilogue: bool = False,
                   fusion: Optional[str] = None,
                   batch: int = 1, batch_layout: str = "none",
                   pair_policy: str = "full",
                   scheme: str = "ozaki_fp64",
                   num_moduli: int = 0) -> dict:
    """Modeled HBM round-trips per stage for one operand/output matrix.

    Counts *array passes* (each read or write of a full matrix-sized
    buffer), the quantity the paper's Fig. 9 shows dominating the split
    and accumulation stages:

    * split — Algorithm 4 re-reads the residual every iteration
      (``s`` passes) while the one-pass kernel reads the input once.
      Streaming mode has no standalone split pass; instead each group's
      kernel re-reads the operand words (``groups`` input passes).
    * slices — every non-streaming mode materializes the (s, m, k) int8
      slice stack between split and GEMM: ``s`` write passes at the end
      of split plus one read pass per kept slice pair in the GEMM stage.
      Streaming extracts slices tile-wise in VMEM, so this item is 0 —
      the O(s·m·k) traffic the mode exists to remove (and which this
      model previously omitted entirely, hiding the win).
    * accum — the unfused path materializes the int32->float conversion
      and the scaled term before the compensated add (2 extra passes per
      accumulation group); the stage-fused kernel does conversion + scale
      + add in registers within one VMEM pass but still reads the int32
      group product the GEMM materialized; the epilogue-fused and
      streaming GEMMs accumulate inside the GEMM grid so the int32
      product never round-trips at all — only the carried C read/write
      remains.

    Per-operand passes at s=9, full schedule (45 pairs, 9 groups):

    ====================  =====  ======  =====  =====
    fusion                split  slices  accum  total
    ====================  =====  ======  =====  =====
    "none"                    9      54     45    108
    "stages"                  1      54     27     82
    "epilogue"                1      54     18     73
    "streaming"               9       0     18     27
    ====================  =====  ======  =====  =====

    Streaming is strictly below epilogue for every schedule: the saved
    slice traffic ``s + kept`` always exceeds the extra operand re-reads
    ``groups - 1`` (``kept >= groups``).

    ``fusion`` names the plan's mode directly (``PipelinePlan.fusion``)
    and overrides the legacy ``fused``/``fuse_epilogue`` flags, which
    remain for callers modeling the pre-streaming pipelines.

    ``batch``/``batch_layout`` model the batched pipeline: every layout
    runs the identical per-element pipeline (the "rows" layout folds the
    batch into rows of ONE matrix; the "grid" layout walks the same
    blocks per batch row, including the batch-grid epilogue kernel), so
    passes scale linearly with the batch size. Until the batch-grid
    epilogue kernel existed, a "grid" batch downgraded epilogue plans to
    the stage-fused pipeline — that legacy state is modeled by calling
    with ``fuse_epilogue=False`` — so the kernel removes one modeled
    pass per accumulation group (3 -> 2) on the batched path.

    ``scheme="ozaki2_fp64"`` prices the residue-system pipeline instead
    (``num_moduli`` = ``ell``, the CRT modulus count). Its stages:

    * split — identical to Scheme I (s residual passes unfused, one
      input read for the one-pass kernel).
    * slices — the (s, m, k) int8 stack is written once by split and
      read ONCE by the residue extraction (``residues_from_slices``
      contracts the whole slice axis per modulus in one tensordot pass),
      so ``2 * s`` — not the per-pair re-reads Scheme I pays.
    * residues — the (ell, m, k) int8 residue stacks: ell write passes
      by the extraction plus ell read passes by the batched GEMM. This
      is the line item the model previously had no vocabulary for
      (mirroring the slice-stack fix: Scheme I plans carry
      ``residues = 0``).
    * accum — unfused/stage-fused: the (ell, m, n) int32 residue
      products round-trip through HBM between the GEMM and the Garner
      reconstruction (``2 * ell``) plus the f64 output write; the
      fused-CRT epilogue (``fusion="epilogue"``) reconstructs in VMEM
      scratch over the modulus grid axis, so only the output write
      remains — strictly ``2 * ell`` passes fewer.

    Scheme II at s=9, ell=15: "none" 9+18+30+31=88, "stages"
    1+18+30+31=80, "epilogue" 1+18+30+1=50. There is no Scheme II
    streaming mode.
    """
    if batch_layout not in BATCH_LAYOUTS:
        raise ValueError(f"unknown batch_layout {batch_layout!r}; "
                         f"expected one of {BATCH_LAYOUTS}")
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    if batch > 1 and batch_layout == "none":
        raise ValueError("batch > 1 requires batch_layout 'rows' or 'grid'")
    if fusion is not None:
        if fusion not in FUSION_MODES:
            raise ValueError(f"unknown fusion {fusion!r}; "
                             f"expected one of {FUSION_MODES}")
        fused = fusion in ("stages", "epilogue", "streaming")
        fuse_epilogue = fusion == "epilogue"
    streaming = fusion == "streaming"
    fused = fused or fuse_epilogue      # epilogue fusion implies fused
    s = num_splits
    if scheme not in PLAN_SCHEMES:
        raise ValueError(f"unknown scheme {scheme!r}; "
                         f"expected one of {PLAN_SCHEMES}")
    if scheme == "ozaki2_fp64":
        if num_moduli < 1:
            raise ValueError("ozaki2_fp64 pass model needs num_moduli >= 1 "
                             f"(the CRT modulus count), got {num_moduli}")
        if streaming:
            raise ValueError("ozaki2_fp64 has no streaming kernel")
        if pair_policy != "full":
            raise ValueError("ozaki2_fp64 has no pair schedule to "
                             f"truncate, got pair_policy={pair_policy!r}")
        ell = num_moduli
        split_passes = (1 if fused else s) * batch
        slices_passes = 2 * s * batch       # stack written s + read s
        residues_passes = 2 * ell * batch   # planes written ell + read ell
        accum_passes = (1 if fuse_epilogue else 2 * ell + 1) * batch
        return {"split": split_passes, "slices": slices_passes,
                "residues": residues_passes, "accum": accum_passes,
                "total": split_passes + slices_passes + residues_passes +
                accum_passes}
    # pair truncation drops whole accumulation groups (fuse_diagonals)
    # or individual pair products (paper-faithful schedule)
    gl = diagonal_groups(s, False,
                         pair_budget=parse_pair_policy(pair_policy, s))
    kept = sum(len(p) for _, p in gl)
    groups = len(gl) if fuse_diagonals else kept
    if streaming:
        # one operand-word read per group kernel; no slice stack at all
        split_passes = groups
        slices_passes = 0
        accum_passes = groups * 2        # read C + write C, nothing else
    else:
        split_passes = 1 if fused else s
        # the materialized (s, m, k) stack: written once by split, then
        # one slice plane read per kept pair by the GEMM stage
        slices_passes = s + kept
        if fuse_epilogue:
            accum_passes = groups * 2    # read C + write C, nothing else
        else:
            # per group: read P + read/write C(hi,lo); unfused adds
            # temp traffic
            accum_passes = groups * (3 if fused else 5)
    split_passes *= batch
    slices_passes *= batch
    accum_passes *= batch
    return {"split": split_passes, "slices": slices_passes,
            "residues": 0, "accum": accum_passes,
            "total": split_passes + slices_passes + accum_passes}


def comm_bytes_model(m: int, n: int, k: int, *, num_splits: int,
                     world: int, layout: str = "kshard",
                     comm: str = "f64", schedule: str = "psum",
                     batch: int = 1, fuse_diagonals: bool = True,
                     full_pairs: bool = False,
                     pair_policy: str = "full",
                     scheme: str = "ozaki_fp64",
                     num_moduli: int = 0) -> dict:
    """Modeled per-device interconnect bytes for one sharded GEMM — the
    ``hbm_pass_model`` companion for the transport layer.

    Counts the bytes ONE device sends over the links (ring-schedule
    accounting: an all-gather/reduce-scatter of a V-byte global buffer
    moves ``(P-1)/P * V`` bytes per device; an all-reduce moves twice
    that — reduce-scatter + all-gather). ``batch`` scales the
    activation-side items linearly (broadcast weights cross once).

    Layouts and what each transport moves:

    * ``layout="kshard"`` — the reduction dim is sharded.

      - ``comm="f64"`` (the GSPMD auto-sharding baseline): the Pallas
        kernel calls are opaque to the SPMD partitioner, so the jitted
        pipeline all-gathers BOTH f64 operands before computing —
        ``(P-1)/P * 8 * (m*k + k*n)`` bytes. This is exactly what
        ``ozaki_matmul_kshard_auto`` pays today.
      - ``comm="int8"``: slices stay device-local (each device splits
        only its k-chunk); what crosses the mesh is the exact int32
        anti-diagonal partials (4 bytes x ``groups`` x ``m*n``) plus
        two int32 exponent pmaxes. ``schedule="psum"``/``"overlap"``
        all-reduce the partials (2x factor); ``"reduce_scatter"``/
        ``"rs_stream"`` halve that by leaving C column-sharded.

    * ``layout="mnshard"`` — A row-sharded, B column-sharded; full k
      local. B's representation is all-gathered so every device can
      compute its row block against all columns:

      - ``comm="f64"``: gather B operand words, ``(P-1)/P * 8 * k*n``.
      - ``comm="int8"``: gather the packed ``SliceWire`` — int8 slice
        stack + int32 exponents, ``(P-1)/P * (s * k*n + 4*n)``.

      The model is honest about where int8 loses: the slice stack costs
      ``s`` bytes per element vs f64's 8, so the m/n-shard gather only
      wins for ``s < 8`` (e.g. ``target_error``-reduced split counts) —
      the headline >= 6x win is the k-shard layout's, where the int8
      path moves NO operand words at all and tall-k shapes amortize the
      ``m*n`` partials against the ``(m + n) * k`` operand gather.

    ``scheme="ozaki2_fp64"`` prices the residue-system transport instead
    (``num_moduli`` = ``ell``). k-shard int8 ships the exact int32
    residue partial stack — ``ell`` planes of ``4 * m*n`` bytes (the
    per-modulus products are exact int32 sums over the sharded k axis,
    so the reduction commutes with the CRT reconstruction) — plus the
    same two exponent pmaxes. m/n-shard int8 gathers the packed
    ``ResidueWire`` (int8 residue stack + exponents): ``ell`` bytes per
    element of B vs f64's 8, so the gather wins exactly when
    ``ell < 8`` — the same honesty note as Scheme I's ``s < 8``.

    Returns per-item bytes: ``operands`` (f64 words), ``slices`` (int8
    stacks — slice planes for Scheme I, residue planes for Scheme II),
    ``exponents`` (int32 vectors), ``partials`` (int32 group / residue
    products), and ``total``.
    """
    if layout not in ("kshard", "mnshard"):
        raise ValueError(f"unknown layout {layout!r}; expected 'kshard' "
                         f"or 'mnshard'")
    if comm not in COMM_MODES:
        raise ValueError(f"unknown comm {comm!r}; expected one of "
                         f"{COMM_MODES}")
    if schedule not in ("psum", "overlap", "reduce_scatter", "rs_stream",
                        "allgather"):
        raise ValueError(f"unknown schedule {schedule!r}")
    if world < 1:
        raise ValueError(f"world must be >= 1, got {world}")
    if scheme not in PLAN_SCHEMES:
        raise ValueError(f"unknown scheme {scheme!r}; "
                         f"expected one of {PLAN_SCHEMES}")
    ring = (world - 1) / world           # per-device ring fraction
    s = num_splits
    if scheme == "ozaki2_fp64":
        if num_moduli < 1:
            raise ValueError("ozaki2_fp64 comm model needs num_moduli >= 1 "
                             f"(the CRT modulus count), got {num_moduli}")
        ell = num_moduli
        operands = slices = exponents = partials = 0.0
        if layout == "kshard":
            if comm == "f64":
                operands = ring * 8 * (batch * m * k + k * n)
            else:
                exponents = 2 * ring * 4 * (batch * m + n)
                # exact int32 residue partials: one (m, n) plane per
                # modulus; all-reduce costs 2x a reduce-scatter
                factor = 2 if schedule in ("psum", "overlap") else 1
                partials = factor * ring * 4 * ell * batch * m * n
        else:                            # mnshard: gather B's residues
            if comm == "f64":
                operands = ring * 8 * k * n
            else:
                slices = ring * ell * k * n      # packed ResidueWire
                exponents = ring * 4 * n
        total = operands + slices + exponents + partials
        return {"operands": operands, "slices": slices,
                "exponents": exponents, "partials": partials,
                "total": total}
    gl = diagonal_groups(s, full_pairs,
                         pair_budget=parse_pair_policy(pair_policy, s,
                                                       full_pairs))
    groups = len(gl) if fuse_diagonals else sum(len(p) for _, p in gl)
    operands = slices = exponents = partials = 0.0
    if layout == "kshard":
        if comm == "f64":
            # GSPMD gathers both operands around the opaque kernels
            operands = ring * 8 * (batch * m * k + k * n)
        else:
            # int32 exponent pmax (all-reduce) over both row vectors
            exponents = 2 * ring * 4 * (batch * m + n)
            # exact int32 anti-diagonal partials; all-reduce costs 2x a
            # reduce-scatter (reduce-scatter + all-gather phases)
            factor = 2 if schedule in ("psum", "overlap") else 1
            partials = factor * ring * 4 * groups * batch * m * n
    else:                                # mnshard: gather B's columns
        if comm == "f64":
            operands = ring * 8 * k * n
        else:
            slices = ring * s * k * n
            exponents = ring * 4 * n
    total = operands + slices + exponents + partials
    return {"operands": operands, "slices": slices,
            "exponents": exponents, "partials": partials, "total": total}
