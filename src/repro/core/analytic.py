"""Closed-form cost model of the Ozaki scheme — paper Fig. 4 / Table 2.

Four quantities as functions of the reduction size k and the MMU type:
  * alpha / BPS        (Eq. 4, 5)
  * number of splits to keep a target mantissa-space length
  * working-memory bytes per input element for the slices
  * number of slice GEMMs (s(s+1)/2)

These are used by ``benchmarks/bench_fig4_analytic.py`` and by the
framework's own planner (choosing s and the MMU-type knobs).
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class MMUSpec:
    """{input}-{accumulator} matrix multiplication unit (paper Table 2)."""

    name: str
    ell_in: int          # input mantissa bits (excl. sign)
    ell_acc: int         # accumulator mantissa bits
    in_bytes: float      # storage bytes per input element
    is_integer: bool

    def alpha(self, k: int) -> int:
        a = int(math.floor((self.ell_acc - math.log2(k)) / 2))
        return max(a, 0)

    def bps(self, k: int) -> int:
        """Bits-per-slice actually carried (Eq. 5)."""
        return max(1, min(self.alpha(k), self.ell_in))

    def num_splits(self, k: int, mantissa_space: int) -> int:
        """Splits needed so num_splits * BPS >= mantissa_space."""
        return math.ceil(mantissa_space / self.bps(k))

    def slice_bytes_per_element(self, k: int, mantissa_space: int) -> float:
        """Working memory for the slices, per input element.

        Integer units store one shared exponent per row *per matrix* —
        amortized to ~0 per element; float units re-store an exponent in
        every element of every slice (that is the paper's 50-75% saving).
        """
        return self.num_splits(k, mantissa_space) * self.in_bytes

    def num_gemms(self, k: int, mantissa_space: int) -> int:
        s = self.num_splits(k, mantissa_space)
        return s * (s + 1) // 2

    def waste_bits(self, k: int) -> int:
        """Mantissa bits of a slice that carry no information (Sec. 3.2.1)."""
        return max(0, self.ell_in - self.alpha(k))


FP16_FP32 = MMUSpec("FP16-FP32", ell_in=11, ell_acc=24, in_bytes=2.0,
                    is_integer=False)
INT4_INT32 = MMUSpec("INT4-INT32", ell_in=3, ell_acc=31, in_bytes=0.5,
                     is_integer=True)
INT8_INT32 = MMUSpec("INT8-INT32", ell_in=7, ell_acc=31, in_bytes=1.0,
                     is_integer=True)
INT12_INT32 = MMUSpec("INT12-INT32", ell_in=11, ell_acc=31, in_bytes=1.5,
                      is_integer=True)

ALL_MMUS = (FP16_FP32, INT4_INT32, INT8_INT32, INT12_INT32)

# FP64 mantissa space the paper's DGEMM-replacement mode must carry.
DGEMM_MANTISSA_SPACE = 70


def ozaki_flops(m: int, n: int, k: int, s: int) -> float:
    """Integer MAC ops in the slice GEMMs (2mnk per GEMM equivalents)."""
    return 2.0 * m * n * k * (s * (s + 1) // 2)


def ozaki_hp_accum_ops(m: int, n: int, s: int, fused_diagonals: bool) -> float:
    """High-precision accumulation element-ops (line 7 of Alg. 3)."""
    groups = s if fused_diagonals else s * (s + 1) // 2
    return float(m * n * groups)
