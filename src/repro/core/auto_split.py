"""INT8-AUTO — automatic split-count selection (paper Sec. 4.4).

Before a GEMM, inspect the exponent distribution of both operands and pick
the smallest number of splits whose *average mantissa loss* per element is
<= a threshold ``T`` bits. ``T = 0`` keeps every input mantissa bit;
``T = 1`` admits one lost bit on average (the paper's fast mode, which
auto-selected INT8x8/9 instead of INT8x12/13 for 4.33x speedup).

The statistics pass is jitted; the split-count decision itself happens on
the host (it changes trace shapes), mirroring the paper's implementation
which inspects the matrices before dispatching the GEMM kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .splitting import row_exponents


@functools.partial(jax.jit, static_argnames=("w", "mantissa_bits", "max_splits"))
def _loss_curve(m: jax.Array, w: int, mantissa_bits: int,
                max_splits: int) -> jax.Array:
    """Mean lost mantissa bits per element for s = 1..max_splits.

    An element with exponent e under a row exponent E keeps bits down to
    E - s*w; its own mantissa reaches e - mantissa_bits. Loss is the gap,
    clipped to [0, mantissa_bits]. Zeros lose nothing.
    """
    row_e = row_exponents(m)[:, None]
    _, elem_e = jnp.frexp(m)
    nonzero = m != 0
    losses = []
    for s in range(1, max_splits + 1):
        floor_bit = row_e - s * w
        lowest_bit = elem_e - mantissa_bits
        loss = jnp.clip(floor_bit - lowest_bit, 0, mantissa_bits)
        loss = jnp.where(nonzero, loss, 0)
        losses.append(jnp.mean(loss.astype(jnp.float32)))
    return jnp.stack(losses)


def auto_num_splits(a: jax.Array, b: jax.Array, w: int, *,
                    threshold_bits: float = 0.0, mantissa_bits: int = 53,
                    max_splits: int = 26) -> int:
    """Smallest s with mean mantissa loss <= threshold for BOTH operands."""
    curve_a = np.asarray(_loss_curve(a, w, mantissa_bits, max_splits))
    curve_b = np.asarray(_loss_curve(b.T, w, mantissa_bits, max_splits))
    curve = np.maximum(curve_a, curve_b)
    ok = np.nonzero(curve <= threshold_bits)[0]
    if ok.size == 0:
        return max_splits
    return int(ok[0]) + 1


def auto_num_splits_complex(a: jax.Array, b: jax.Array, w: int, *,
                            threshold_bits: float = 0.0,
                            mantissa_bits: int = 53,
                            max_splits: int = 26) -> int:
    """AUTO over the 4 real component matrices of a complex GEMM."""
    s = 1
    for x, transpose in ((jnp.real(a), False), (jnp.imag(a), False),
                         (jnp.real(b), True), (jnp.imag(b), True)):
        xm = x.T if transpose else x
        curve = np.asarray(_loss_curve(xm, w, mantissa_bits, max_splits))
        ok = np.nonzero(curve <= threshold_bits)[0]
        s = max(s, (int(ok[0]) + 1) if ok.size else max_splits)
    return s
