"""Error-free floating point transforms and compensated ("double-word") arithmetic.

Two users inside the framework:

* ``df32`` — a pair of float32 arrays ``(hi, lo)`` with ``hi = RN(hi+lo)``.
  This is the accumulation type the Ozaki scheme uses on TPU, where no
  float64 hardware exists. It carries 2x24 = 48 mantissa bits.
* ``dd64`` — double-double on float64. CPU-only oracle used by tests and
  benchmarks as the high-precision reference (the paper's ``C^DD``).

All transforms are branch-free and jit-safe. ``two_prod`` uses Dekker's
split (no FMA requirement — XLA:CPU does not guarantee fused multiply-add).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class DW(NamedTuple):
    """A double-word value: ``value = hi + lo`` exactly, |lo| <= ulp(hi)/2."""

    hi: jax.Array
    lo: jax.Array

    @property
    def dtype(self):
        return self.hi.dtype

    @property
    def shape(self):
        return self.hi.shape


# ----------------------------------------------------------------------------
# Error-free transforms (dtype generic: f32 or f64)
# ----------------------------------------------------------------------------

def two_sum(a, b):
    """Knuth's TwoSum: s + e == a + b exactly."""
    s = a + b
    bb = s - a
    e = (a - (s - bb)) + (b - bb)
    return s, e


def fast_two_sum(a, b):
    """Dekker's FastTwoSum. Requires |a| >= |b| (or a == 0)."""
    s = a + b
    e = b - (s - a)
    return s, e


def _split_const(dtype) -> float:
    # Dekker splitting constant 2^ceil(p/2) + 1 where p = mantissa bits.
    if dtype == jnp.float32:
        return float(2 ** 12 + 1)
    if dtype == jnp.float64:
        return float(2 ** 27 + 1)
    raise ValueError(f"unsupported dtype for Dekker split: {dtype}")


def veltkamp_split(a):
    """Split a into hi + lo with non-overlapping half-width mantissas."""
    c = jnp.asarray(_split_const(a.dtype), a.dtype) * a
    hi = c - (c - a)
    lo = a - hi
    return hi, lo


def two_prod(a, b):
    """Dekker's TwoProd: p + e == a * b exactly (no FMA needed)."""
    p = a * b
    a_hi, a_lo = veltkamp_split(a)
    b_hi, b_lo = veltkamp_split(b)
    e = ((a_hi * b_hi - p) + a_hi * b_lo + a_lo * b_hi) + a_lo * b_lo
    return p, e


# ----------------------------------------------------------------------------
# Double-word arithmetic (works for df32 and dd64 alike)
# ----------------------------------------------------------------------------

def dw_zeros(shape, dtype=jnp.float32) -> DW:
    z = jnp.zeros(shape, dtype)
    return DW(z, z)


def dw_from_single(x) -> DW:
    return DW(x, jnp.zeros_like(x))


def dw_normalize(hi, lo) -> DW:
    s, e = fast_two_sum(hi, lo)
    return DW(s, e)


def dw_add(x: DW, y: DW) -> DW:
    """Accurate double-word + double-word (AccurateDWPlusDW, 2 two_sums)."""
    s_hi, e_hi = two_sum(x.hi, y.hi)
    s_lo, e_lo = two_sum(x.lo, y.lo)
    c = e_hi + s_lo
    v_hi, v_lo = fast_two_sum(s_hi, c)
    w = e_lo + v_lo
    return dw_normalize(v_hi, w)


def dw_add_single(x: DW, y) -> DW:
    """Double-word + single word."""
    s_hi, e = two_sum(x.hi, y)
    v = x.lo + e
    return dw_normalize(s_hi, v)


def dw_mul_single(x: DW, y) -> DW:
    """Double-word * single word (DWTimesFP, Dekker-based)."""
    p_hi, p_lo = two_prod(x.hi, y)
    p_lo = p_lo + x.lo * y
    return dw_normalize(p_hi, p_lo)


def dw_mul(x: DW, y: DW) -> DW:
    p_hi, p_lo = two_prod(x.hi, y.hi)
    p_lo = p_lo + (x.hi * y.lo + x.lo * y.hi)
    return dw_normalize(p_hi, p_lo)


def dw_neg(x: DW) -> DW:
    return DW(-x.hi, -x.lo)


def dw_sub(x: DW, y: DW) -> DW:
    return dw_add(x, dw_neg(y))


def dw_to_single(x: DW):
    return x.hi + x.lo


# ----------------------------------------------------------------------------
# df32 <-> f64 conversion (CPU-side bridging; f64 requires x64 mode)
# ----------------------------------------------------------------------------

def df32_from_f64(x) -> DW:
    """Exactly decompose float64 into (f32 hi, f32 lo) pairs.

    Exact whenever x's mantissa fits 48 bits and its exponent is in f32
    range; otherwise lo absorbs the nearest representable remainder.
    """
    hi = x.astype(jnp.float32)
    lo = (x - hi.astype(x.dtype)).astype(jnp.float32)
    return DW(hi, lo)


def df32_to_f64(x: DW):
    return x.hi.astype(jnp.float64) + x.lo.astype(jnp.float64)


# ----------------------------------------------------------------------------
# dd64 oracle matmul (the paper's double-double reference C^DD)
# ----------------------------------------------------------------------------

def dd_matmul_f64(a: jax.Array, b: jax.Array) -> DW:
    """Double-double accurate C = A @ B on float64 inputs (CPU oracle).

    Sequential compensated accumulation over k; vectorized over (m, n).
    Cost ~20x a plain f64 matmul of the same shape — use moderate sizes.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2

    def body(carry, idx):
        c_hi, c_lo = carry
        # outer product column step: a[:, idx] (m,) x b[idx, :] (n,)
        p, pe = two_prod(a[:, idx][:, None], b[idx, :][None, :])
        s, e = two_sum(c_hi, p)
        c_lo = c_lo + (e + pe)
        c_hi, c_lo = fast_two_sum(s, c_lo)
        return (c_hi, c_lo), None

    init = (jnp.zeros((m, n), a.dtype), jnp.zeros((m, n), a.dtype))
    (c_hi, c_lo), _ = jax.lax.scan(body, init, jnp.arange(k))
    return DW(c_hi, c_lo)


def dd_matmul_np(a: np.ndarray, b: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """NumPy fallback double-double matmul (no jax tracing, pure f64)."""
    m, k = a.shape
    _, n = b.shape
    c_hi = np.zeros((m, n))
    c_lo = np.zeros((m, n))
    split = 2.0 ** 27 + 1

    for t in range(k):
        x = a[:, t][:, None]
        y = b[t, :][None, :]
        p = x * y
        cx = split * x
        x_hi = cx - (cx - x)
        x_lo = x - x_hi
        cy = split * y
        y_hi = cy - (cy - y)
        y_lo = y - y_hi
        pe = ((x_hi * y_hi - p) + x_hi * y_lo + x_lo * y_hi) + x_lo * y_lo
        s = c_hi + p
        bb = s - c_hi
        e = (c_hi - (s - bb)) + (p - bb)
        c_lo = c_lo + (e + pe)
        c_hi = s + c_lo
        c_lo = c_lo - (c_hi - s)
    return c_hi, c_lo


def rel_error_vs_dd(c: np.ndarray, dd_hi: np.ndarray, dd_lo: np.ndarray) -> np.ndarray:
    """Paper Eq. (7): |C - C_dd| / |C_dd| elementwise (safe at 0)."""
    ref = dd_hi + dd_lo
    denom = np.where(ref == 0.0, 1.0, np.abs(ref))
    num = np.abs((c - dd_hi) - dd_lo)
    return num / denom
