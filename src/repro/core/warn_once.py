"""Resettable warn-once latches, shared by every module that must warn
exactly once per process *and* stay testable.

PR 4 grew the first instance of this pattern for the
``REPRO_OZAKI_BATCHED_EPILOGUE`` downgrade warning: module-level
warn-once state leaks across tests (the first test that trips the
warning latches it and every later test sees silence), so the latch
needs a public reset the test fixtures can call. PR 5 adds a second
consumer (the ``ozaki_*`` ArchConfig deprecation warning), so the
pattern moves here:

* ``WarnOnceLatch(name)`` — one latch per warning family. ``warn(key,
  message)`` emits ``message`` the first time ``key`` is seen and stays
  silent afterwards; ``reset()`` restores fresh-process state.
* Every latch registers itself in a module-level registry;
  ``reset_all_warn_latches()`` resets them all. ``tests/conftest.py``
  calls it around every test, so any future warn-once consumer is
  covered without touching the fixture again.
"""
from __future__ import annotations

import warnings
from typing import Type

_LATCHES: list["WarnOnceLatch"] = []


class WarnOnceLatch:
    """A named warn-once latch: one warning per key until ``reset()``."""

    def __init__(self, name: str):
        self.name = name
        self._seen: set[str] = set()
        _LATCHES.append(self)

    def warn(self, key: str, message: str, *,
             category: Type[Warning] = UserWarning,
             stacklevel: int = 3) -> bool:
        """Emit ``message`` once per ``key``; True when it fired."""
        if key in self._seen:
            return False
        self._seen.add(key)
        warnings.warn(message, category, stacklevel=stacklevel)
        return True

    def seen(self, key: str) -> bool:
        return key in self._seen

    def reset(self) -> None:
        """Restore fresh-process state (the next ``warn`` fires again)."""
        self._seen.clear()


def reset_all_warn_latches() -> None:
    """Reset every registered latch — the one call test fixtures need."""
    for latch in _LATCHES:
        latch.reset()
