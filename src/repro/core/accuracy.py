"""Accuracy-adaptive planning: error bounds for split counts and pair
truncation (the "fast mode" of the follow-up literature).

The paper's pipeline pays for a fixed ``num_splits`` s regardless of the
input, yet its own accuracy experiments (Fig. 6, Fig. 7) show the
required s varies sharply with the data. Two follow-ups close that gap:
Uchino, Ozaki & Imamura (arXiv:2409.13313) *reduce* the split count per
input with an accuracy guarantee and add a *fast mode* that skips
low-order slice-pair products; Abdelfattah et al. (arXiv:2506.11277)
supply the error bounds that make the truncation principled. This module
implements both bound families; ``core.tuning`` / ``core.ozaki`` consume
them to resolve ``target_error`` / ``fast_mode`` / ``pair_policy`` knobs
into a concrete ``(num_splits, pair_policy)`` operating point.

The error model
---------------

Slice ``p`` of A is bounded by ``|A_p slice value| < 2^{ea_i - p*w}``
(the shared row exponent ``2^{ea}`` strictly dominates the row, and each
slice keeps ``w`` bits). Hence the slice-pair product (p, q), summed
over the reduction dim k, contributes at most

    |sum_k A_p B_q|  <  k * 2^{ea_i + eb_j} * 2^{-(p+q) * w}.

Every error source of the scheme — the split tails (slices p >= s), the
schedule's dropped diagonals (the paper computes pairs with
``p + q <= s - 1`` only), and fast-mode pair truncation — is exactly "a
set of (p, q) pairs not computed", so the guaranteed bound is a single
geometric sum over the *complement* of the kept pair set:

    |C - C_hat|_ij  <=  k * eta * 2^{ea_i + eb_j},
    eta = sum_{(p, q) not kept} 2^{-(p+q) * w}          (truncation_eta)

plus a small accumulation-rounding floor (``accum_floor``) that no split
count can remove. ``scaled_error`` measures exactly the left-hand side
normalization, so benchmarks and tests can *prove* the bound holds.

The data-dependent refinement (``required_splits``): an element with
exponent ``e`` under row exponent ``ea`` carries no mantissa bits below
``e - mantissa_bits``, so slices with ``p * w >= spread + mantissa_bits``
are identically zero — pairs touching them contribute nothing. Narrow
row/column exponent spreads therefore shrink the effective pair grid and
admit *fewer* splits at the same guaranteed error (the follow-up's
"accuracy-guaranteed split reduction"). All-zero rows/columns are
clamped to spread 0 (finite sentinel — see ``splitting.row_exponents``),
so zero-cancellation inputs never produce ``-inf``/NaN statistics.

Everything here is host-side, closed-form float arithmetic over static
shapes: resolution happens once per GEMM shape (trace-safe), never on
the device.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

# core.modular imports tuning/splitting only (never this module), so the
# top-level import is cycle-free; it powers the cross-scheme cost model.
from .modular import modular_eta, resolve_modular
from .splitting import row_exponents, slice_width
from .tuning import diagonal_groups, hbm_pass_model, parse_pair_policy

__all__ = ["MAX_SPLITS", "SchemeChoice", "kept_pairs", "truncation_eta",
           "input_truncation_eta", "accum_floor", "error_bound",
           "min_splits_for", "pair_budget_for", "plan_meets_target",
           "resolve_accuracy", "scheme_costs", "exponent_spread",
           "required_splits", "scaled_error"]

MAX_SPLITS = 26     # ceil(2 * 53 / 4): past this even INT4 covers dd64

# Accumulation-rounding floor per accumulation group, relative to the
# k * 2^{ea+eb} normalizer: f64 rounds at 2^-53 per add; the compensated
# df32 pair carries ~48 bits — 2^-44 is a deliberately generous cover.
_ACCUM_UNIT = {"f64": 2.0 ** -52, "df32": 2.0 ** -44}


# ----------------------------------------------------------------------------
# Guaranteed (shape-only) bounds
# ----------------------------------------------------------------------------

def kept_pairs(num_splits: int, *, pair_policy: str = "full",
               full_pairs: bool = False) -> list[tuple[int, int]]:
    """The (p, q) slice pairs a schedule actually computes."""
    budget = parse_pair_policy(pair_policy, num_splits, full_pairs)
    return [(p, q)
            for _, pairs in diagonal_groups(num_splits, full_pairs,
                                            pair_budget=budget)
            for p, q in pairs]


def truncation_eta(num_splits: int, w: int, *, pair_policy: str = "full",
                   full_pairs: bool = False) -> float:
    """eta: |C - C_hat| <= k * eta * 2^{ea_i + eb_j}, guaranteed.

    The sum over ALL dropped pairs — split tails (p >= s or q >= s),
    schedule-dropped diagonals, and fast-mode truncation. Summed over
    the *dropped* set directly (per-diagonal deficits plus the closed-
    form geometric tail), never as total-minus-kept: that subtraction
    cancels ~7 decimal digits and would corrupt tight targets.
    """
    r = 2.0 ** (-w)
    kept = kept_pairs(num_splits, pair_policy=pair_policy,
                      full_pairs=full_pairs)
    kept_per_t: dict[int, int] = {}
    for p, q in kept:
        kept_per_t[p + q] = kept_per_t.get(p + q, 0) + 1
    t_cut = max(kept_per_t) + 1
    # diagonal t holds t + 1 pairs over the full (infinite-slice) grid
    head = math.fsum(((t + 1) - kept_per_t.get(t, 0)) * r ** t
                     for t in range(t_cut))
    tail = r ** t_cut * (t_cut * (1.0 - r) + 1.0) / (1.0 - r) ** 2
    return head + tail


def input_truncation_eta(num_splits: int, w: int, sa_eff: int, sb_eff: int,
                         *, pair_policy: str = "full",
                         full_pairs: bool = False) -> float:
    """Per-input eta: slices beyond the operands' information content are
    identically zero, so only dropped pairs with ``p < sa_eff`` and
    ``q < sb_eff`` contribute (``sa_eff/sb_eff`` from exponent spreads).
    """
    r = 2.0 ** (-w)
    kept = set(kept_pairs(num_splits, pair_policy=pair_policy,
                          full_pairs=full_pairs))
    return math.fsum(r ** (p + q)
                     for p in range(sa_eff) for q in range(sb_eff)
                     if (p, q) not in kept)


def accum_floor(num_splits: int, k: int, *, accum: str = "f64",
                fuse_diagonals: bool = True, pair_policy: str = "full",
                full_pairs: bool = False) -> float:
    """Rounding floor of the high-precision accumulation stage (relative
    to ``2^{ea_i + eb_j}``): no split count or pair budget removes it."""
    budget = parse_pair_policy(pair_policy, num_splits, full_pairs)
    groups = diagonal_groups(num_splits, full_pairs, pair_budget=budget)
    g = len(groups) if fuse_diagonals else sum(len(p) for _, p in groups)
    return (g + 2) * _ACCUM_UNIT[accum] * k


def error_bound(num_splits: int, w: int, k: int, *,
                pair_policy: str = "full", full_pairs: bool = False,
                accum: str = "f64", fuse_diagonals: bool = True) -> float:
    """Total guaranteed bound on ``max_ij |C - C_hat| / 2^{ea_i+eb_j}``."""
    return (k * truncation_eta(num_splits, w, pair_policy=pair_policy,
                               full_pairs=full_pairs)
            + accum_floor(num_splits, k, accum=accum,
                          fuse_diagonals=fuse_diagonals,
                          pair_policy=pair_policy, full_pairs=full_pairs))


# ----------------------------------------------------------------------------
# Operating-point selection (shape-only: trace-safe)
# ----------------------------------------------------------------------------

def min_splits_for(target_error: float, k: int, *, ell_acc: int = 31,
                   ell_in: int = 7, fuse: bool = True,
                   full_pairs: bool = False,
                   max_splits: int = MAX_SPLITS) -> int:
    """Smallest s whose guaranteed truncation error meets the target.

    ``target_error`` bounds ``k * truncation_eta`` (the part s controls;
    the accumulation floor is reported separately by ``error_bound``).
    The slice width is re-derived per candidate s — fewer splits reserve
    less diagonal-fusion headroom, so w can widen as s shrinks.
    """
    if target_error <= 0:
        raise ValueError(f"target_error must be > 0, got {target_error}")
    for s in range(1, max_splits + 1):
        w = slice_width(k, ell_acc=ell_acc, ell_in=ell_in,
                        fuse_terms=s if fuse else 1)
        if k * truncation_eta(s, w, full_pairs=full_pairs) <= target_error:
            return s
    return max_splits


def pair_budget_for(target_error: float, num_splits: int, w: int, k: int,
                    *, full_pairs: bool = False) -> str:
    """Smallest pair budget still meeting the target at this s.

    Returns ``"budget:N"`` with minimal N, or ``"full"`` when no pair can
    be dropped without crossing the target (no truncation headroom).
    """
    if target_error <= 0:
        raise ValueError(f"target_error must be > 0, got {target_error}")
    total = len(kept_pairs(num_splits, full_pairs=full_pairs))
    for n in range(1, total):
        eta = truncation_eta(num_splits, w, pair_policy=f"budget:{n}",
                             full_pairs=full_pairs)
        if k * eta <= target_error:
            return f"budget:{n}"
    return "full"


def plan_meets_target(plan, k: int, target_error: float, *,
                      ell_acc: int = 31, ell_in: int = 7) -> bool:
    """Does a ``PipelinePlan``'s operating point guarantee the target?

    The acceptance rule for cached plans under a pinned ``target_error``:
    the target is the contract, not one specific ``(s, policy)`` string —
    a measured winner with MORE pairs or splits than the minimal resolved
    point still satisfies it (and must be accepted, or every cache hit
    would re-tune forever). Scheme II plans are judged on THEIR
    guaranteed bound (``k * modular_eta(beta)``) — under a target the
    two families are interchangeable contracts.
    """
    if getattr(plan, "scheme", "ozaki_fp64") == "ozaki2_fp64":
        return k * modular_eta(plan.beta) <= target_error
    fuse = plan.fuse_diagonals or plan.concat_k
    w = slice_width(k, ell_acc=ell_acc, ell_in=ell_in,
                    fuse_terms=plan.num_splits if fuse else 1)
    eta = truncation_eta(plan.num_splits, w, pair_policy=plan.pair_policy,
                         full_pairs=plan.full_pairs)
    return k * eta <= target_error


@dataclasses.dataclass(frozen=True)
class SchemeChoice:
    """One arbitrated cross-scheme operating point (hashable).

    ``scheme`` names the winning family; the family's knobs follow
    (Scheme I: ``num_splits``/``pair_policy``; Scheme II: ``beta``/
    ``num_moduli``, with ``num_splits`` the integerization slice count).
    ``gemms`` is the winner's modeled int8-GEMM-equivalent cost and
    ``costs`` records every candidate's, so callers (and tests) can see
    WHY the arbitration went the way it did. ``traffic`` records each
    candidate's modeled HBM passes (``tuning.hbm_pass_model`` at the
    family's best fused route) — the secondary axis: GEMM-equivalents
    rank first, traffic breaks cost ties before the incumbent rule does.
    """

    scheme: str
    num_splits: int
    pair_policy: str = "full"
    beta: int = 0
    num_moduli: int = 0
    gemms: float = 0.0
    costs: tuple = ()        # ((scheme, modeled cost), ...)
    traffic: tuple = ()      # ((scheme, modeled HBM passes), ...)


def _scheme2_cost(num_moduli: int, num_splits: int, k: int,
                  m: Optional[int], n: Optional[int]) -> float:
    """Scheme II's modeled cost in int8-GEMM equivalents (2mnk ops each).

    The residue GEMMs are the linear term (``ell`` launches); the CRT
    reconstruction is an O(ell^2) elementwise pass over the (m, n)
    output (``ell^2 / 2k`` GEMM-equivalents) and, when the output shape
    is known, the residue extraction tensordots add
    ``ell * s * (m + n) / 2mn`` — both vanish for tall-k shapes, which
    is exactly where Scheme II's linear GEMM count wins.
    """
    cost = float(num_moduli) + num_moduli ** 2 / (2.0 * k)
    if m is not None and n is not None:
        cost += num_moduli * num_splits * (m + n) / (2.0 * m * n)
    return cost


def scheme_costs(k: int, num_splits: int, *, target_error: Optional[float],
                 pair_policy: str = "full", full_pairs: bool = False,
                 m: Optional[int] = None,
                 n: Optional[int] = None) -> tuple:
    """Both families' modeled costs at MATCHED accuracy.

    Scheme I at the resolved ``(s, policy)`` costs its kept-pair count.
    Scheme II is sized for the same contract — the explicit
    ``target_error`` when one is set, else Scheme I's own guaranteed
    truncation bound (so a no-target comparison is still
    accuracy-matched, not apples-to-oranges). An infeasible Scheme II
    point (moduli pool exhausted) costs ``inf``.
    """
    cost_1 = float(len(kept_pairs(num_splits, pair_policy=pair_policy,
                                  full_pairs=full_pairs)))
    if target_error is None:
        w = slice_width(k, fuse_terms=num_splits)
        target_error = k * truncation_eta(num_splits, w,
                                          pair_policy=pair_policy,
                                          full_pairs=full_pairs)
    try:
        point = resolve_modular(k, target_error=target_error)
    except ValueError:
        return (("ozaki_fp64", cost_1), ("ozaki2_fp64", math.inf))
    cost_2 = _scheme2_cost(len(point.moduli), point.num_splits, k, m, n)
    return (("ozaki_fp64", cost_1), ("ozaki2_fp64", cost_2))


def resolve_accuracy(k: int, num_splits: int, *,
                     target_error: Optional[float] = None,
                     fast_mode: bool = False, pair_policy: str = "full",
                     ell_acc: int = 31, ell_in: int = 7, fuse: bool = True,
                     full_pairs: bool = False,
                     schemes: Optional[Sequence[str]] = None,
                     m: Optional[int] = None, n: Optional[int] = None):
    """Resolve the accuracy knobs into a concrete ``(s, pair_policy)``.

    * ``target_error`` REDUCES s below the configured operating point
      when the bound allows (never raises it — the configured s is the
      quality ceiling the caller asked for).
    * ``fast_mode`` truncates pairs: to the minimal budget meeting
      ``target_error`` when one is set, else to ``"diagonal"`` (drop the
      schedule's last, least-significant anti-diagonal — the follow-up
      paper's fast mode).
    * An explicit non-"full" ``pair_policy`` always wins over fast_mode.

    Idempotent: resolving an already-resolved point returns it unchanged.

    ``schemes`` turns the resolver into the CROSS-SCHEME cost model:
    pass the candidate families (e.g. ``("ozaki_fp64", "ozaki2_fp64")``)
    and the return type becomes a ``SchemeChoice`` — both families are
    sized for the same accuracy contract and the one with the fewer
    modeled int8-GEMM equivalents wins (``m``/``n`` refine Scheme II's
    elementwise overhead terms when the output shape is known). A cost
    tie falls through to modeled HBM traffic (``hbm_pass_model`` at each
    family's best fused route: Scheme I streaming vs the Scheme II
    fused-CRT epilogue); only a tie on BOTH axes goes to Scheme I, the
    bitwise-validated incumbent. Without ``schemes`` the legacy
    ``(s, policy)`` tuple contract is unchanged.
    """
    s = num_splits
    if target_error is not None:
        s = max(1, min(s, min_splits_for(target_error, k, ell_acc=ell_acc,
                                         ell_in=ell_in, fuse=fuse,
                                         full_pairs=full_pairs)))
    policy = pair_policy
    if policy == "full" and fast_mode:
        if target_error is not None:
            w = slice_width(k, ell_acc=ell_acc, ell_in=ell_in,
                            fuse_terms=s if fuse else 1)
            policy = pair_budget_for(target_error, s, w, k,
                                     full_pairs=full_pairs)
        else:
            policy = "diagonal"
    if schemes is None:
        return s, policy
    for name in schemes:
        if name not in ("ozaki_fp64", "ozaki2_fp64"):
            raise ValueError(f"unknown scheme {name!r} in schemes")
    costs = dict(scheme_costs(k, s, target_error=target_error,
                              pair_policy=policy, full_pairs=full_pairs,
                              m=m, n=n))
    # Secondary axis: modeled HBM passes at each family's best fused
    # route (Scheme I streaming vs the Scheme II fused-CRT epilogue) —
    # breaks GEMM-cost ties before the incumbent rule.
    traffic = {"ozaki_fp64": float(hbm_pass_model(
        s, fusion="streaming", pair_policy=policy)["total"])}
    point2 = None
    if math.isfinite(costs["ozaki2_fp64"]):
        if target_error is not None:
            point2 = resolve_modular(k, target_error=target_error)
        else:
            w = slice_width(k, ell_acc=ell_acc, ell_in=ell_in,
                            fuse_terms=s if fuse else 1)
            point2 = resolve_modular(
                k, target_error=k * truncation_eta(
                    s, w, pair_policy=policy, full_pairs=full_pairs))
        traffic["ozaki2_fp64"] = float(hbm_pass_model(
            point2.num_splits, fusion="epilogue", scheme="ozaki2_fp64",
            num_moduli=len(point2.moduli))["total"])
    else:
        traffic["ozaki2_fp64"] = math.inf
    ranked = sorted((name for name in schemes),
                    key=lambda name: (costs[name], traffic[name],
                                      name != "ozaki_fp64"))
    winner = ranked[0]
    all_costs = tuple((name, costs[name]) for name in schemes)
    all_traffic = tuple((name, traffic[name]) for name in schemes)
    if winner == "ozaki2_fp64" and math.isfinite(costs[winner]):
        return SchemeChoice(scheme="ozaki2_fp64",
                            num_splits=point2.num_splits, beta=point2.beta,
                            num_moduli=len(point2.moduli),
                            gemms=costs[winner], costs=all_costs,
                            traffic=all_traffic)
    return SchemeChoice(scheme="ozaki_fp64", num_splits=s,
                        pair_policy=policy, gemms=costs["ozaki_fp64"],
                        costs=all_costs, traffic=all_traffic)


# ----------------------------------------------------------------------------
# Data-dependent refinement (host-side, like core.auto_split)
# ----------------------------------------------------------------------------

def exponent_spread(m) -> jnp.ndarray:
    """Per-row exponent spread: row exponent minus the smallest *nonzero*
    element exponent, as int32 ``(rows,)``.

    Zero elements are clamped to the row exponent (no spread
    contribution) and all-zero rows — whose ``row_exponents`` sentinel is
    already finite — report spread 0, so zero-cancellation inputs never
    leak ``-inf`` into the exp2/ldexp scales downstream.
    """
    m = jnp.asarray(m)
    row_e = row_exponents(m)
    _, e = jnp.frexp(m)
    e = jnp.where(m != 0, e.astype(jnp.int32), row_e[:, None])
    return row_e - jnp.min(e, axis=-1).astype(jnp.int32)


def required_splits(a, b, *, target_error: Optional[float] = None,
                    mantissa_bits: int = 53, ell_acc: int = 31,
                    ell_in: int = 7, fuse: bool = True,
                    full_pairs: bool = False, pair_policy: str = "full",
                    max_splits: int = MAX_SPLITS) -> int:
    """Minimal s meeting ``target_error`` for THESE operands.

    ``a: (m, k)``, ``b: (k, n)`` — the spread statistics run on device
    (jitted ``frexp``/reductions), the decision on the host (it changes
    trace shapes, exactly like ``core.auto_split``). ``target_error=None``
    asks for input-exactness: the smallest s whose kept pairs cover every
    pair of informative slices.
    """
    k = a.shape[-1]
    sa = int(np.max(np.asarray(exponent_spread(a))))
    sb = int(np.max(np.asarray(exponent_spread(jnp.asarray(b).T))))
    tgt = 0.0 if target_error is None else float(target_error)
    for s in range(1, max_splits + 1):
        w = slice_width(k, ell_acc=ell_acc, ell_in=ell_in,
                        fuse_terms=s if fuse else 1)
        sa_eff = -(-(sa + mantissa_bits) // w)
        sb_eff = -(-(sb + mantissa_bits) // w)
        eta = input_truncation_eta(s, w, sa_eff, sb_eff,
                                   pair_policy=pair_policy,
                                   full_pairs=full_pairs)
        if k * eta <= tgt:
            return s
    return max_splits


def scaled_error(c, ref_hi, a, b, ref_lo=None) -> float:
    """Measured ``max_ij |c - ref| / 2^{ea_i + eb_j}`` — the exact
    normalization ``error_bound`` guarantees, so ``scaled_error <= bound``
    is a *provable* (and CSV-checkable) statement. ``ref_lo`` carries the
    low word of a double-double reference for sub-ulp resolution."""
    ea = np.asarray(row_exponents(jnp.asarray(a)))
    eb = np.asarray(row_exponents(jnp.asarray(b).T))
    diff = np.asarray(c) - np.asarray(ref_hi)
    if ref_lo is not None:
        diff = diff - np.asarray(ref_lo)
    return float(np.max(np.abs(diff) / np.exp2(ea[:, None] + eb[None, :])))
