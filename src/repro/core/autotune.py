"""Measurement-driven autotuning for the Ozaki pipeline, with a
persistent plan cache.

The analytic planner (``core.tuning.select_pipeline_plan``) is a
VMEM-budget model: good enough to never launch an illegal kernel, blind
to everything the follow-up literature (arXiv:2409.13313,
arXiv:2508.03984) shows actually separates implementations — measured
launch overheads, the fusion-mode crossover, concat-k amortization. This
module closes that gap in three pieces:

* ``candidate_plans`` — enumerate ``PipelinePlan`` candidates around the
  analytic seed: tile shapes (halved GEMM blocks down to their alignment
  floors), fusion mode (epilogue- vs stage-fused), and the ``concat_k``
  schedule. By default every candidate is **result-invariant**: tiles
  and fusion modes are bitwise-neutral (enforced by the backend-parity
  suite) and ``concat_k`` regroups exact int32 sums, so a tuned plan's
  results are bitwise-equal to the analytic plan's. ``search_num_splits``
  widens the space to split counts *above* the accuracy target's minimum
  (never below — the paper's operating point is a floor); those
  candidates trade bitwise reproducibility for generality and are off by
  default.
* ``measure_plan`` / ``autotune_plan`` — time each candidate on the live
  backend with warm-up (covers jit compile) and ``block_until_ready``,
  then pick the measured best. The analytic plan is always candidate #0,
  so the tuned result is never worse than analytic modulo timer noise.
* ``PlanCache`` — a versioned JSON file mapping
  ``(m, n, k, batch, dtype, backend, device_kind)`` to the measured-best
  ``PipelinePlan`` (reusing ``PipelinePlan.to_dict/from_dict``).
  ``select_pipeline_plan`` consults it (hit returns without re-tuning;
  miss falls back to the analytic plan unless ``autotune=True``), and
  ``serving.engine`` pre-warms it at startup so steady-state serving
  never tunes on the request path. Version mismatches and corrupted
  files degrade to an empty cache (analytic planning), never an error.

An ambient-cache registry (``use_plan_cache``) mirrors
``parallel.ozaki_shard``'s mesh registry: the serving engine scopes its
cache around each tick, and ``models.layers`` picks cached plans up at
trace time without threading the cache through every call site.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import tempfile
import time
import warnings
from typing import Callable, Optional, Sequence

import numpy as np

from repro.kernels.launch import LANE, SUBLANE_I8

from .analytic import DGEMM_MANTISSA_SPACE, INT8_INT32
from .splitting import slice_width
from .tuning import (CONCAT_K_MAX, PipelinePlan, _cached_hit_acceptable,
                     diagonal_groups, plan_schedule_ok, select_num_splits,
                     select_pipeline_plan)
from .warn_once import WarnOnceLatch

__all__ = ["PLAN_CACHE_VERSION", "PlanKey", "PlanCache", "plan_cache_key",
           "candidate_plans", "measure_plan", "autotune_plan",
           "AutotuneReport", "use_plan_cache", "active_plan_cache",
           "set_plan_cache", "warn_if_interpret_ranked"]

# v3: keys carry the emulation ``scheme`` (Scheme I slice pairs vs
# Scheme II residue GEMMs — ``tuning.PLAN_SCHEMES``), so tuned winners
# from the two families never collide under one key. v2 entries predate
# the scheme field and load as empty (the standard fallback-to-empty
# path — analytic plans until re-tuned).
# v2: entries carry a ``meta`` dict recording the measurement mode
# (``{"interpret": bool | None}``). v1 files load as empty — the old
# entries were indistinguishable from hardware-measured plans, which is
# exactly the bug that bump fixed.
PLAN_CACHE_VERSION = 3

# Warns (once per cache key) when a compiled run is served a plan whose
# measurement ranking ran in Pallas interpret mode: interpret timings
# order candidates but are not Mosaic timings, so the ranking deserves a
# re-tune on hardware. Registered in ``warn_once`` so the test suite's
# ``reset_all_warn_latches`` covers it.
_INTERPRET_LATCH = WarnOnceLatch("interpret_ranked_plans")


def default_device_kind() -> str:
    """The accelerator identity plans are tuned for (cache key part)."""
    try:
        import jax
        return jax.devices()[0].device_kind
    except Exception:                                   # pragma: no cover
        return "unknown"


def _dtype_name(dtype) -> str:
    """Canonical dtype name: ``jnp.float64`` objects, ``np.dtype``s and
    ``"float64"`` strings all map to the same key string."""
    try:
        return np.dtype(dtype).name
    except TypeError:                       # e.g. bfloat16 via ml_dtypes
        import jax.numpy as jnp
        return jnp.dtype(dtype).name


def _canon_dtype(dtype, accum: str) -> str:
    """Normalize the operand dtype key; default it from the accum mode."""
    if dtype is None:
        return "float64" if accum == "f64" else "float32"
    return _dtype_name(dtype)


@dataclasses.dataclass(frozen=True)
class PlanKey:
    """Cache identity of one tuned GEMM: shape, operand dtype, backend,
    and the device kind the measurement ran on (hashable).

    ``dtype`` is canonicalized at construction (``jnp.dtype(...).name``
    semantics): a key built from the ``jnp.float64`` *object* and one
    built from the ``"float64"`` *string* are the same key — before the
    canonicalization they hashed differently and silently missed the
    cache (and broke JSON serialization of ``to_dict``).
    """

    m: int
    n: int
    k: int
    batch: int = 1
    dtype: str = "float64"
    backend: str = "pallas_fused"
    device_kind: str = "cpu"
    scheme: str = "ozaki_fp64"

    def __post_init__(self):
        if not isinstance(self.dtype, str) or self.dtype != \
                _dtype_name(self.dtype):
            object.__setattr__(self, "dtype", _dtype_name(self.dtype))

    def encode(self) -> str:
        return (f"m={self.m};n={self.n};k={self.k};batch={self.batch};"
                f"dtype={self.dtype};backend={self.backend};"
                f"device={self.device_kind};scheme={self.scheme}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "PlanKey":
        return cls(**d)


def plan_cache_key(m: int, n: int, k: int, *, batch: int = 1,
                   dtype=None, accum: str = "df32",
                   backend: str = "pallas_fused",
                   device_kind: Optional[str] = None,
                   scheme: str = "ozaki_fp64") -> PlanKey:
    """The key ``select_pipeline_plan`` and the engine pre-warm agree on."""
    return PlanKey(m=m, n=n, k=k, batch=batch,
                   dtype=_canon_dtype(dtype, accum), backend=backend,
                   device_kind=device_kind or default_device_kind(),
                   scheme=scheme)


class PlanCache:
    """Persistent measured-plan store: one JSON file per deployment.

    File format (``version`` guards schema drift — a mismatch or a
    corrupted file loads as an EMPTY cache with a warning, so planning
    falls back to the analytic model instead of failing)::

        {"version": 2,
         "plans": {"m=..;n=..;..": {"key": {...PlanKey...},
                                    "plan": {...PipelinePlan.to_dict...},
                                    "us": 123.4,
                                    "meta": {"interpret": true}}}}

    Entries are decoded from the structured ``key`` dict (the string key
    is display/dedup only). ``meta`` records how the entry's measurement
    ran — today ``interpret`` (Pallas interpret mode vs compiled Mosaic;
    None for entries stored without measurement) — so consumers can tell
    a CPU-interpret ranking from a hardware one
    (``warn_if_interpret_ranked``). ``hits``/``misses`` count ``get``
    outcomes for the pre-warm/steady-state tests and ops introspection.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = os.fspath(path) if path is not None else None
        self._plans: dict[PlanKey, PipelinePlan] = {}
        self._us: dict[PlanKey, Optional[float]] = {}
        self._meta: dict[PlanKey, dict] = {}
        self.hits = 0
        self.misses = 0

    # ---- persistence ---------------------------------------------------
    @classmethod
    def load(cls, path) -> "PlanCache":
        """Load a cache file; missing/corrupted/wrong-version -> empty."""
        cache = cls(path)
        if not os.path.exists(cache.path):
            return cache
        try:
            with open(cache.path) as f:
                data = json.load(f)
            version = data.get("version")
            if version != PLAN_CACHE_VERSION:
                warnings.warn(
                    f"plan cache {cache.path}: version {version!r} != "
                    f"{PLAN_CACHE_VERSION}; starting from an empty cache "
                    "(analytic plans until re-tuned)")
                return cache
            for entry in data.get("plans", {}).values():
                key = PlanKey.from_dict(entry["key"])
                cache._plans[key] = PipelinePlan.from_dict(entry["plan"])
                cache._us[key] = entry.get("us")
                cache._meta[key] = dict(entry.get("meta") or {})
        except (OSError, ValueError, KeyError, TypeError) as e:
            warnings.warn(f"plan cache {cache.path}: unreadable "
                          f"({type(e).__name__}: {e}); starting from an "
                          "empty cache (analytic plans until re-tuned)")
            cache._plans.clear()
            cache._us.clear()
            cache._meta.clear()
        return cache

    def save(self, path: Optional[str] = None) -> Optional[str]:
        """Atomically write the cache (tmp file + rename); no-op without
        a path. Returns the path written."""
        path = os.fspath(path) if path is not None else self.path
        if path is None:
            return None
        data = {"version": PLAN_CACHE_VERSION, "plans": {
            key.encode(): {"key": key.to_dict(),
                           "plan": self._plans[key].to_dict(),
                           "us": self._us.get(key),
                           "meta": self._meta.get(key, {})}
            for key in sorted(self._plans, key=lambda kk: kk.encode())}}
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(data, f, indent=1, sort_keys=True)
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        self.path = path
        return path

    # ---- store ---------------------------------------------------------
    def get(self, key: PlanKey) -> Optional[PipelinePlan]:
        plan = self._plans.get(key)
        if plan is None:
            self.misses += 1
        else:
            self.hits += 1
        return plan

    def put(self, key: PlanKey, plan: PipelinePlan,
            measured_us: Optional[float] = None,
            interpret: Optional[bool] = None) -> None:
        self._plans[key] = plan
        self._us[key] = measured_us
        self._meta[key] = {"interpret": interpret}

    def measured_us(self, key: PlanKey) -> Optional[float]:
        return self._us.get(key)

    def meta(self, key: PlanKey) -> dict:
        """Measurement metadata of one entry ({} when unknown)."""
        return self._meta.get(key, {})

    def __len__(self) -> int:
        return len(self._plans)

    def __contains__(self, key: PlanKey) -> bool:
        return key in self._plans

    def keys(self):
        return self._plans.keys()


def warn_if_interpret_ranked(cache: PlanCache, key: PlanKey,
                             interpret: bool) -> None:
    """Warn once per key when a compiled consumer gets an interpret-ranked
    plan.

    Called on every cache-hit path (``select_pipeline_plan`` and
    ``autotune_plan``) with the CONSUMER's interpret mode: a compiled run
    (``interpret=False``) served a plan whose candidate ranking was timed
    in Pallas interpret mode is running an ordering CPU emulation picked —
    bitwise-correct, but not a Mosaic timing. Entries with unknown
    provenance (``meta`` absent: stored without measurement, or loaded
    from a pre-v2 cache) stay silent.
    """
    if interpret:
        return
    if cache.meta(key).get("interpret"):
        _INTERPRET_LATCH.warn(
            key.encode(),
            f"plan cache {cache.path or '<memory>'}: plan for "
            f"[{key.encode()}] was ranked in Pallas interpret mode but is "
            "being consumed by a compiled run — re-tune on hardware "
            "(autotune with interpret=False) for a trustworthy ranking")


# ----------------------------------------------------------------------------
# Ambient cache registry (mirrors parallel.ozaki_shard's mesh registry)
# ----------------------------------------------------------------------------

_PLAN_CACHE: list = [None]


def set_plan_cache(cache: Optional[PlanCache]) -> None:
    """Register (or clear, with None) the ambient plan cache.

    Trace-time semantics, exactly like the shard-mesh registry: jitted
    model steps read the registry while TRACING, so the cache must be
    registered before the first call of any step that should honor it.
    The serving engine scopes its cache around every tick
    (``use_plan_cache``), which covers the first trace by construction.
    """
    _PLAN_CACHE[0] = cache


def active_plan_cache() -> Optional[PlanCache]:
    return _PLAN_CACHE[0]


@contextlib.contextmanager
def use_plan_cache(cache: Optional[PlanCache]):
    prev = _PLAN_CACHE[0]
    _PLAN_CACHE[0] = cache
    try:
        yield cache
    finally:
        _PLAN_CACHE[0] = prev


# ----------------------------------------------------------------------------
# Candidate enumeration
# ----------------------------------------------------------------------------

def _tile_variants(tile):
    """Halved-block launch variants of one TilePlan (result-invariant)."""
    out = []
    if tile.bk > LANE:
        out.append(dataclasses.replace(tile, bk=tile.bk // 2))
    if tile.bm > SUBLANE_I8:
        out.append(dataclasses.replace(tile, bm=tile.bm // 2))
    if tile.bn > LANE:
        out.append(dataclasses.replace(tile, bn=tile.bn // 2))
    return out


def candidate_plans(m: int, n: int, k: int, *, batch: int = 1,
                    broadcast_weights: bool = False,
                    backend: str = "pallas_fused", accum: str = "df32",
                    num_splits: Optional[int] = None,
                    fuse_epilogue: bool = True,
                    streaming: bool = False,
                    shard_axis: Optional[str] = None,
                    comm: str = "f64",
                    interpret: bool = True,
                    search_num_splits: int = 0,
                    target_error: Optional[float] = None,
                    fast_mode: bool = False,
                    pair_policy: Optional[str] = None,
                    max_candidates: Optional[int] = None,
                    scheme: str = "ozaki_fp64",
                    num_moduli: Optional[int] = None,
                    cross_scheme: bool = True,
                    **analytic_kwargs) -> list[PipelinePlan]:
    """Enumerate candidate plans around the analytic seed.

    The analytic plan is always first. Default candidates vary only
    launch-level knobs — GEMM tile shapes, fusion mode (epilogue vs
    stages), ``concat_k`` — all of which leave results bitwise unchanged
    (exact int32 regrouping / parity-tested kernel fusions), so any
    cached winner reproduces the analytic plan's output bit for bit.
    ``search_num_splits=j`` additionally tries ``s_min+1 .. s_min+j``
    splits (still within the accuracy target: more slices is strictly
    more mantissa space); those change the rounding stream and are off
    by default. With ``target_error`` set, pair-budget variants are also
    enumerated — every one checked against the guaranteed error bound
    (``core.accuracy.truncation_eta``), so no candidate the measurement
    ranks can violate the configured target. Every non-seed candidate is
    filtered through ``tuning.plan_schedule_ok``: a df32 plan violating
    ``(num_splits + 1) * w <= 120`` would crash mid-measurement, so it
    never enters the search space. ``max_candidates`` truncates AFTER
    dedup, keeping the analytic seed. ``analytic_kwargs``
    (``mantissa_space``/``mmu``/``vmem_budget``) reach the analytic seed
    planner unchanged.

    Cross-scheme search: when ``target_error`` pins an accuracy contract
    and ``cross_scheme`` is on, the OTHER scheme family's analytic seed
    joins the candidate list — a Scheme I search (f64 accumulation only;
    the residue path reconstructs through FP64 CRT) enumerates the
    matching Scheme II operating point and vice versa, so the
    measurement arbitrates between the families for real instead of
    trusting the GEMM-count model. Both seeds guarantee the same target,
    so any winner honors the contract.
    """
    base = select_pipeline_plan(
        m, n, k, batch=batch, broadcast_weights=broadcast_weights,
        backend=backend, accum=accum, num_splits=num_splits,
        fuse_epilogue=fuse_epilogue, streaming=streaming,
        shard_axis=shard_axis, comm=comm,
        interpret=interpret, target_error=target_error,
        fast_mode=fast_mode, pair_policy=pair_policy, scheme=scheme,
        num_moduli=num_moduli, **analytic_kwargs)
    cands = [base]

    def add(plan: PipelinePlan):
        if plan not in cands and plan_schedule_ok(plan, k):
            cands.append(plan)

    if scheme == "ozaki2_fp64":
        # the residue path has no pair schedule; the launch-level space
        # is the stages <-> epilogue fusion flip (both bitwise-equal:
        # the fused-CRT kernel replays the reference Garner digits and
        # ascending-radix f64 sum) and the GEMM tile shapes, plus (under
        # a target) the Scheme I seed for cross-family arbitration
        if base.fusion in ("stages", "epilogue"):
            add(dataclasses.replace(
                base, fusion=("epilogue" if base.fusion == "stages"
                              else "stages")))
        for seed in list(cands):
            for tile in _tile_variants(seed.tile):
                add(dataclasses.replace(seed, tile=tile))
        if target_error is not None and cross_scheme and \
                shard_axis is None:
            add(select_pipeline_plan(
                m, n, k, batch=batch, broadcast_weights=broadcast_weights,
                backend=backend, accum="f64",
                fuse_epilogue=fuse_epilogue, streaming=streaming,
                interpret=interpret, target_error=target_error,
                **analytic_kwargs))
        if max_candidates is not None and len(cands) > max_candidates:
            cands = cands[:max_candidates]
        return cands

    if target_error is not None and cross_scheme and accum == "f64" and \
            shard_axis is None:
        try:
            add(select_pipeline_plan(
                m, n, k, batch=batch, broadcast_weights=broadcast_weights,
                backend=backend, interpret=interpret,
                target_error=target_error, scheme="ozaki2_fp64",
                **analytic_kwargs))
        except ValueError:
            pass            # moduli pool exhausted: no Scheme II point

    # fusion-mode flips (pallas_fused only; all modes bitwise-equal —
    # streaming included, so the measurement decides whether eliminating
    # the HBM slice stacks beats re-reading the operand words per group)
    if base.fusion in ("stages", "epilogue", "streaming"):
        for flip in ("stages", "epilogue", "streaming"):
            if flip != base.fusion:
                add(dataclasses.replace(base, fusion=flip))

    # comm-transport flip (sharded shapes only): both transports are
    # bitwise-equal to the single-device reference (integer collectives
    # are associative), so the measurement is free to pick either — on
    # a single-device measurement host the flip is a no-op to execute
    # but the cached winner carries the transport for the deployment
    if base.shard_axis is not None:
        for flip in ("f64", "int8"):
            if flip != base.comm:
                add(dataclasses.replace(base, comm=flip))

    # concat_k flip: exact int32 regrouping; never for a stacked batch
    # (the concatenated operands would materialize once per batch row)
    if base.fuse_diagonals and k <= CONCAT_K_MAX and \
            base.batch_layout != "grid":
        add(dataclasses.replace(base, concat_k=not base.concat_k))

    # halved GEMM tiles, crossed with every schedule/fusion seed so far
    for seed in list(cands):
        for tile in _tile_variants(seed.tile):
            add(dataclasses.replace(seed, tile=tile))

    # pair-budget variants (accuracy-checked ONLY: each must meet the
    # target's guaranteed bound) — whole-diagonal budgets between the
    # seed's resolved policy and the full schedule, so the measurement
    # can trade kept pairs for time without ever crossing the target
    if target_error is not None:
        from .accuracy import kept_pairs, truncation_eta   # lazy: no cycle
        s = base.num_splits
        fuse = base.fuse_diagonals or base.concat_k
        w = slice_width(k, fuse_terms=s if fuse else 1)
        groups_seen = 0
        for _, pairs in diagonal_groups(s, base.full_pairs):
            groups_seen += len(pairs)
            policy = f"budget:{groups_seen}"
            eta = truncation_eta(s, w, pair_policy=policy,
                                 full_pairs=base.full_pairs)
            if k * eta <= target_error:
                total = len(kept_pairs(s, full_pairs=base.full_pairs))
                if groups_seen < total:
                    add(dataclasses.replace(base, pair_policy=policy))
        if base.pair_policy != "full":
            # the untruncated schedule is always at least as accurate as
            # the resolved budget: let the measurement decline truncation
            add(dataclasses.replace(base, pair_policy="full"))

    # wider split counts stay within the accuracy target (s >= s_min)
    for extra in range(1, search_num_splits + 1):
        add(dataclasses.replace(base, num_splits=base.num_splits + extra))

    if max_candidates is not None and len(cands) > max_candidates:
        cands = cands[:max_candidates]
    return cands


# ----------------------------------------------------------------------------
# Measurement
# ----------------------------------------------------------------------------

def _make_operands(m: int, n: int, k: int, *, batch: int,
                   broadcast_weights: bool, dtype: str, seed: int = 0):
    """Representative operands (paper Eq. 6-style spread, phi=1)."""
    rng = np.random.default_rng(seed)

    def mat(r, c):
        x = (rng.uniform(-0.5, 0.5, (r, c))
             * np.exp(rng.standard_normal((r, c))))
        return x.astype(dtype)

    if batch <= 1 and not broadcast_weights:
        return mat(m, k), mat(k, n)
    a = np.stack([mat(m, k) for _ in range(batch)])
    if broadcast_weights:
        return a, mat(k, n)
    return a, np.stack([mat(k, n) for _ in range(batch)])


def _plan_runner(plan: PipelinePlan, a, b) -> Callable[[], object]:
    """A zero-arg callable running one GEMM under ``plan``.

    Applies the plan through the public driver (``apply_pipeline_plan``
    -> ``OzakiConfig``), so the measurement exercises exactly the code
    path a deployment with the cached plan runs.
    """
    import jax.numpy as jnp

    from .ozaki import OzakiConfig, ozaki_matmul, ozaki_matmul_batched
    from .tuning import apply_pipeline_plan

    a = jnp.asarray(a)
    b = jnp.asarray(b)
    if getattr(plan, "scheme", "ozaki_fp64") == "ozaki2_fp64":
        from .modular import (ModularConfig, ozaki2_matmul,
                              ozaki2_matmul_batched)
        mcfg = ModularConfig(beta=plan.beta, num_moduli=plan.num_moduli,
                             backend=plan.backend,
                             fuse_epilogue=(plan.fusion == "epilogue"),
                             interpret=plan.interpret, tile=plan.tile)
        if a.ndim == 3:
            return lambda: ozaki2_matmul_batched(a, b, mcfg)
        return lambda: ozaki2_matmul(a, b, mcfg)
    cfg = apply_pipeline_plan(OzakiConfig(), plan)
    if a.ndim == 3:
        return lambda: ozaki_matmul_batched(a, b, cfg)
    if str(a.dtype) == "float64":
        return lambda: ozaki_matmul(a, b, cfg)
    # f32 operands: the TPU-native path via the batched API's rows fold
    return lambda: ozaki_matmul_batched(a[None], b, cfg)[0]


def measure_plan(plan: PipelinePlan, m: int, n: int, k: int, *,
                 batch: int = 1, broadcast_weights: bool = False,
                 dtype: Optional[str] = None, warmup: int = 1,
                 iters: int = 3, seed: int = 0,
                 operands=None) -> float:
    """Median wall-time (us) of one GEMM under ``plan`` on the live
    backend. Warm-up runs (jit compile included) and every timed run
    ``block_until_ready`` so device work is fully counted."""
    import jax

    dtype = _canon_dtype(dtype, plan.accum)
    if operands is None:
        operands = _make_operands(m, n, k, batch=batch,
                                  broadcast_weights=broadcast_weights,
                                  dtype=dtype, seed=seed)
    fn = _plan_runner(plan, *operands)
    for _ in range(max(1, warmup)):
        jax.block_until_ready(fn())
    times = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


@dataclasses.dataclass(frozen=True)
class AutotuneReport:
    """Outcome of one autotune run: the winner plus every measurement."""

    key: PlanKey
    best: PipelinePlan
    best_us: float
    measurements: tuple          # ((plan, us), ...) in candidate order

    @property
    def analytic_us(self) -> float:
        return self.measurements[0][1]       # candidate #0 is analytic


def autotune_plan(m: int, n: int, k: int, *, batch: int = 1,
                  broadcast_weights: bool = False,
                  backend: str = "pallas_fused", accum: str = "df32",
                  num_splits: Optional[int] = None,
                  fuse_epilogue: bool = True,
                  streaming: bool = False,
                  shard_axis: Optional[str] = None,
                  comm: str = "f64", interpret: bool = True,
                  target_error: Optional[float] = None,
                  fast_mode: bool = False,
                  pair_policy: Optional[str] = None,
                  dtype: Optional[str] = None,
                  device_kind: Optional[str] = None,
                  cache: Optional[PlanCache] = None,
                  candidates: Optional[Sequence[PipelinePlan]] = None,
                  max_candidates: Optional[int] = 8, warmup: int = 1,
                  iters: int = 3, save: bool = True,
                  scheme: str = "ozaki_fp64",
                  num_moduli: Optional[int] = None,
                  **analytic_kwargs) -> AutotuneReport:
    """Measure candidate plans and return the best (stored in ``cache``).

    The cache is consulted first (a hit at the SAME accuracy operating
    point — explicit ``num_splits`` must match the cached plan's, and
    when ``target_error``/``fast_mode``/``pair_policy`` pin a pair
    policy, the cached plan's policy must match the resolved one — skips
    measurement entirely); the winner is ``put`` under the shared key
    and — when the cache has a backing path and ``save`` — persisted
    immediately, so a crash after tuning N of M shapes keeps the N
    measured plans.
    """
    accuracy_pinned = (target_error is not None or fast_mode or
                       pair_policy is not None)
    if scheme == "ozaki2_fp64":
        accum = "f64"
        if num_moduli is None:
            from .modular import resolve_modular    # lazy: no cycle
            num_moduli = len(resolve_modular(
                k, target_error=target_error,
                mantissa_space=analytic_kwargs.get(
                    "mantissa_space", DGEMM_MANTISSA_SPACE)).moduli)
    elif accuracy_pinned:
        from .accuracy import resolve_accuracy      # lazy: no cycle
        base_s = (num_splits if num_splits is not None else
                  select_num_splits(
                      k,
                      mantissa_space=analytic_kwargs.get(
                          "mantissa_space", DGEMM_MANTISSA_SPACE),
                      mmu=analytic_kwargs.get("mmu", INT8_INT32)))
        num_splits, pair_policy = resolve_accuracy(
            k, base_s, target_error=target_error, fast_mode=fast_mode,
            pair_policy=pair_policy if pair_policy is not None else "full")
    dtype = _canon_dtype(dtype, accum)
    key = plan_cache_key(m, n, k, batch=batch, dtype=dtype, accum=accum,
                         backend=backend, device_kind=device_kind,
                         scheme=scheme)
    if cache is not None:
        hit = cache.get(key)
        # same acceptance rule as select_pipeline_plan: under a pinned
        # target ANY cached point meeting the bound hits (the measured
        # winner may carry more pairs/splits than the minimal resolved
        # point — rejecting it would re-measure on every call)
        if hit is not None and _cached_hit_acceptable(
                hit, k, num_splits=num_splits, target_error=target_error,
                accuracy_pinned=accuracy_pinned,
                policy=pair_policy if pair_policy is not None else "full",
                scheme=scheme, num_moduli=num_moduli):
            warn_if_interpret_ranked(cache, key, interpret)
            return AutotuneReport(key=key, best=hit,
                                  best_us=cache.measured_us(key) or 0.0,
                                  measurements=((hit, 0.0),))
    if candidates is None:
        candidates = candidate_plans(
            m, n, k, batch=batch, broadcast_weights=broadcast_weights,
            backend=backend, accum=accum, num_splits=num_splits,
            fuse_epilogue=fuse_epilogue, streaming=streaming,
            shard_axis=shard_axis, comm=comm,
            interpret=interpret, target_error=target_error,
            pair_policy=pair_policy, max_candidates=max_candidates,
            scheme=scheme, num_moduli=num_moduli, **analytic_kwargs)
    operands = _make_operands(m, n, k, batch=batch,
                              broadcast_weights=broadcast_weights,
                              dtype=dtype)
    measurements = []
    for plan in candidates:
        us = measure_plan(plan, m, n, k, batch=batch,
                          broadcast_weights=broadcast_weights, dtype=dtype,
                          warmup=warmup, iters=iters, operands=operands)
        measurements.append((plan, us))
    best, best_us = min(measurements, key=lambda pu: pu[1])
    if cache is not None:
        cache.put(key, best, measured_us=best_us, interpret=interpret)
        if save and cache.path is not None:
            cache.save()
    return AutotuneReport(key=key, best=best, best_us=best_us,
                          measurements=tuple(measurements))
