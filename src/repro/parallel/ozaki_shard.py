"""Distributed Ozaki GEMM — the paper's DGEMM scaled onto the mesh (O4).

The reduction (k) dimension is sharded across a mesh axis. Each device:

  1. contributes its local row/col maxima to a *global* ``pmax`` so all
     shards split against the same shared exponents (the Ozaki invariant:
     slices of one row live in one mantissa space);
  2. extracts int8 slices of its local k-chunk and runs the local slice
     GEMMs (int8 x int8 -> int32, exact);
  3. reduces each anti-diagonal's int32 partial product with an integer
     ``psum`` — integer addition is associative, so the distributed sum
     is **bitwise reproducible** for any mesh shape or reduction order
     (the elasticity invariant used by the checkpoint/restart tests);
  4. performs the high-precision scaled accumulation once, on the reduced
     products.

Exactness requires accumulator headroom for ``k_global`` terms (not just
the local chunk) plus diagonal-fusion slack — ``alpha`` is computed from
the GLOBAL k, mirroring Eq. (3) of the paper.

Three collective schedules:
  * ``schedule="psum"``      — stacked psum of all anti-diagonals at the
    end; result replicated over the k-axis (paper-faithful layout).
  * ``schedule="overlap"``   — psum of diagonal d is issued while diagonal
    d+1's GEMMs run (compute/comm overlap; beyond-paper O4b).
  * ``schedule="reduce_scatter"`` — int32 reduce-scatter over the OUTPUT
    COLUMNS instead of an all-reduce: 2x less link traffic, and the
    high-precision accumulation runs on 1/P of the columns per chip.
    C comes out sharded (m@m_axis, n@axis) — the natural layout for a
    GEMM feeding the next sharded operator (beyond-paper O4c; §Perf).

Batched composition: ``ozaki_matmul_kshard_auto`` accepts the batched
API's operand ranks ((B, m, k) activations with stacked or broadcast
weights) and records the axis on the config so the ``PipelinePlan``
carries it; ``constrain_batched_kshard`` + the ``set_shard_mesh`` /
``use_shard_mesh`` registry are the in-trace composition points the
model/serving layers use for ``ArchConfig.ozaki_shard_axis``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.executors import gemm_xla, int32_to_dw
from repro.core.ozaki import OzakiConfig
from repro.core.splitting import row_exponents, slice_width, split_int
from repro.core.xmath import DW, dw_add


def _local_diag_products(sa, sb, cfg: OzakiConfig):
    """[(t, int32 product)] per anti-diagonal from local slices."""
    out = []
    for t, pairs in cfg.diagonals():
        p_t = gemm_xla(sa.slices[pairs[0][0]], sb.slices[pairs[0][1]])
        for pth, qth in pairs[1:]:
            p_t = p_t + gemm_xla(sa.slices[pth], sb.slices[qth])
        out.append((t, p_t))
    return out


def distributed_ozaki_matmul(a: jax.Array, b: jax.Array, mesh: Mesh,
                             cfg: OzakiConfig = OzakiConfig(),
                             axis: str = "model",
                             schedule: str = "psum",
                             m_axis: str | None = None) -> jax.Array:
    """FP64-accurate C = A @ B with k sharded over ``mesh[axis]``.

    a: (m, k) f64, b: (k, n) f64 (global shapes). Result is replicated
    over ``axis`` and bitwise identical for every device count.
    ``cfg.accum`` selects f64 (CPU oracle) or df32 (TPU-deployable:
    everything below stays in {int8, int32, f32}).
    ``m_axis``: additionally shard the m (row) dim — the 2D production
    layout; rows are independent in the Ozaki scheme (per-row exponents),
    so this composes with the k-shard reduction untouched.
    """
    n_shards = mesh.shape[axis]
    k_global = a.shape[1]
    # Headroom: k_global terms per diagonal-fused GEMM group. The int32
    # psum adds no extra constraint beyond k_global (the global count
    # already includes every shard's terms).
    fuse = cfg.max_fuse_terms if (cfg.fuse_diagonals or cfg.concat_k) else 1
    w = slice_width(k_global, ell_acc=cfg.ell_acc, ell_in=cfg.ell_in,
                    fuse_terms=fuse)

    def local(a_blk, b_blk):
        # 1. global shared exponents (pmax over the k-shards)
        ea = row_exponents(a_blk)
        eb = row_exponents(b_blk.T)
        ea = jax.lax.pmax(ea, axis)
        eb = jax.lax.pmax(eb, axis)
        # 2. local slices against the global exponents
        sa = split_int(a_blk, cfg.num_splits, w, exp=ea)
        sb = split_int(b_blk.T, cfg.num_splits, w, exp=eb)
        prods = _local_diag_products(sa, sb, cfg)
        # 3. exact integer reduction per anti-diagonal
        if schedule == "overlap":
            # issue psum(d) early so it overlaps the next diagonal's GEMMs
            reduced = []
            for t, p_t in prods:
                reduced.append((t, jax.lax.psum(p_t, axis)))
            prods = reduced
        elif schedule == "reduce_scatter":
            # int32 reduce-scatter over output columns: each chip keeps
            # its n/P column block, exactly reduced (still associative
            # -> bitwise reproducible). eb must be sliced to the block.
            ts = [t for t, _ in prods]
            stacked = jnp.stack([p for _, p in prods])
            stacked = jax.lax.psum_scatter(stacked, axis,
                                           scatter_dimension=2, tiled=True)
            prods = list(zip(ts, stacked))
            nloc = stacked.shape[2]
            idx = jax.lax.axis_index(axis)
            eb = jax.lax.dynamic_slice_in_dim(eb, idx * nloc, nloc)
        elif schedule == "rs_stream":
            # per-diagonal reduce-scatter, issued as each diagonal's
            # GEMMs finish: no s-deep int32 stack is materialized and
            # diagonal d's collective overlaps diagonal d+1's compute
            prods = [(t, jax.lax.psum_scatter(p, axis,
                                              scatter_dimension=1,
                                              tiled=True))
                     for t, p in prods]
            nloc = prods[0][1].shape[1]
            idx = jax.lax.axis_index(axis)
            eb = jax.lax.dynamic_slice_in_dim(eb, idx * nloc, nloc)
        else:
            ts = [t for t, _ in prods]
            stacked = jnp.stack([p for _, p in prods])
            stacked = jax.lax.psum(stacked, axis)
            prods = list(zip(ts, stacked))
        # 4. high-precision accumulation (shape follows the — possibly
        # scattered — reduced products)
        shape = prods[0][1].shape
        e_base = ea[:, None].astype(jnp.int32) + eb[None, :].astype(jnp.int32)
        if cfg.accum == "df32":
            # TPU path: compensated f32 pair, no f64 anywhere
            acc = DW(jnp.zeros(shape, jnp.float32),
                     jnp.zeros(shape, jnp.float32))
            for t, p_t in sorted(prods, key=lambda tp: -tp[0]):
                scale = jnp.float32(2.0 ** (-(t + 2) * w))
                term = int32_to_dw(p_t)
                acc = dw_add(acc, DW(term.hi * scale, term.lo * scale))
            hi = jnp.ldexp(acc.hi, e_base)
            lo = jnp.ldexp(acc.lo, e_base)
            return hi, lo             # df32 pair (48 mantissa bits)
        c = jnp.zeros(shape, jnp.float64)
        for t, p_t in sorted(prods, key=lambda tp: -tp[0]):
            c = c + jnp.ldexp(p_t.astype(jnp.float64), e_base - (t + 2) * w)
        return c

    row = m_axis if m_axis else None
    col = axis if schedule in ("reduce_scatter", "rs_stream") else None
    c_spec = P(row, col)
    out_specs = (c_spec, c_spec) if cfg.accum == "df32" else c_spec
    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(row, axis), P(axis, None)),
                   out_specs=out_specs)
    out = fn(a, b)
    return DW(*out) if cfg.accum == "df32" else out


def kshard_specs(a_ndim: int, b_ndim: int, axis: str) -> tuple[P, P]:
    """PartitionSpecs placing the contraction (k) dim on ``axis``.

    Handles every operand rank of the (batched) Ozaki API: a is
    (m, k) or (B, m, k); b is (k, n) or (B, k, n).
    """
    a_spec = P(None, None, axis) if a_ndim == 3 else P(None, axis)
    b_spec = P(None, axis, None) if b_ndim == 3 else P(axis, None)
    return a_spec, b_spec


def ozaki_matmul_kshard_auto(a: jax.Array, b: jax.Array, mesh: Mesh,
                             cfg: OzakiConfig = OzakiConfig(),
                             axis: Optional[str] = None) -> jax.Array:
    """Paper-faithful distributed baseline: the (batched) Ozaki pipeline
    under jit with k-sharded inputs — GSPMD inserts the collectives.
    Reproducible only per mesh shape.

    3-D ``a`` routes through ``ozaki_matmul_batched`` (stacked or
    broadcast ``b``), composing the batched API with k-sharding: the
    executor pipeline is unchanged, only the operand layout differs. The
    resolved axis is recorded on the config (``shard_axis``), so the
    ``PipelinePlan`` built inside the jitted computation carries it.
    """
    from repro.core.ozaki import ozaki_matmul, ozaki_matmul_batched
    axis = axis or cfg.shard_axis or "model"
    cfg = dataclasses.replace(cfg, shard_axis=axis)
    impl = ozaki_matmul_batched if a.ndim == 3 else ozaki_matmul
    a_spec, b_spec = kshard_specs(a.ndim, b.ndim, axis)
    out_spec = P(*([None] * a.ndim))
    fn = jax.jit(functools.partial(impl, cfg=cfg),
                 in_shardings=(NamedSharding(mesh, a_spec),
                               NamedSharding(mesh, b_spec)),
                 out_shardings=NamedSharding(mesh, out_spec))
    return fn(a, b)


# ----------------------------------------------------------------------------
# Deployment wiring: an ambient shard mesh + in-trace sharding hints, so the
# model/serving layers can honor ``ozaki_shard_axis`` without threading a
# Mesh through every projection call.
# ----------------------------------------------------------------------------

_SHARD_MESH: list = [None]


def set_shard_mesh(mesh: Optional[Mesh]) -> None:
    """Register (or clear, with None) the deployment's shard mesh.

    Trace-time semantics: the registry is read while a jitted function
    TRACES, not when it runs — register the mesh before the first call
    of any jitted step that should honor it (a cached executable traced
    without a mesh stays unsharded until a shape change retraces it).
    The serving engine scopes its mesh around every tick
    (``use_shard_mesh``), which covers the first trace by construction.
    """
    _SHARD_MESH[0] = mesh


def active_shard_mesh() -> Optional[Mesh]:
    return _SHARD_MESH[0]


@contextlib.contextmanager
def use_shard_mesh(mesh: Optional[Mesh]):
    prev = _SHARD_MESH[0]
    _SHARD_MESH[0] = mesh
    try:
        yield mesh
    finally:
        _SHARD_MESH[0] = prev


def _constrain(x: jax.Array, sharding: NamedSharding) -> jax.Array:
    if isinstance(x, jax.core.Tracer):          # inside jit: GSPMD hint
        return jax.lax.with_sharding_constraint(x, sharding)
    return jax.device_put(x, sharding)          # eager: reshard now


def constrain_batched_kshard(a: jax.Array, b: jax.Array, axis: str,
                             mesh: Optional[Mesh] = None
                             ) -> tuple[jax.Array, jax.Array]:
    """Pin the k dim of a (batched) matmul's operands to ``mesh[axis]``.

    The in-trace composition point for ``OzakiConfig.shard_axis`` /
    ``ArchConfig.ozaki_shard_axis``: unlike ``ozaki_matmul_kshard_auto``
    (which owns its jit), this works inside an already-traced model step.
    No-op when no mesh is registered or the axis is absent from it.
    """
    mesh = mesh if mesh is not None else active_shard_mesh()
    if mesh is None or axis not in mesh.axis_names:
        return a, b
    a_spec, b_spec = kshard_specs(a.ndim, b.ndim, axis)
    return (_constrain(a, NamedSharding(mesh, a_spec)),
            _constrain(b, NamedSharding(mesh, b_spec)))
