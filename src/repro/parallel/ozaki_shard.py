"""Distributed Ozaki GEMM — the paper's DGEMM scaled onto the mesh (O4).

Two sharded layouts, one invariant: every schedule below is **bitwise
identical** to the single-device reference — integer collectives are
associative, so the distributed sums reproduce the single-device rounding
stream exactly, for any mesh shape (the elasticity invariant used by the
checkpoint/restart tests).

**k-sharded** (``distributed_ozaki_matmul``): the reduction dimension is
sharded. Each device:

  1. contributes its local row/col maxima to a *global* ``pmax`` so all
     shards split against the same shared exponents (the Ozaki invariant:
     slices of one row live in one mantissa space);
  2. extracts int8 slices of its local k-chunk and runs the local slice
     GEMMs (int8 x int8 -> int32, exact);
  3. reduces each anti-diagonal's int32 partial product with an integer
     collective (``parallel.collectives``) — NO f64 operand ever crosses
     a link (the int8-slice transport, ``comm="int8"`` in the policy
     spec; ``core.tuning.comm_bytes_model`` prices it against the GSPMD
     f64-operand baseline);
  4. performs the high-precision scaled accumulation once, on the reduced
     products.

Exactness requires accumulator headroom for ``k_global`` terms (not just
the local chunk) plus diagonal-fusion slack — ``alpha`` is computed from
the GLOBAL k, mirroring Eq. (3) of the paper.

k-shard collective schedules:
  * ``schedule="psum"``      — stacked psum of all anti-diagonals at the
    end; result replicated over the k-axis (paper-faithful layout).
  * ``schedule="overlap"``   — diagonal d's psum is issued BEFORE diagonal
    d+1's GEMMs are built, so the int32 all-reduce rides the links while
    the next diagonal computes (compute/comm overlap; beyond-paper O4b).
  * ``schedule="reduce_scatter"`` — int32 reduce-scatter over the OUTPUT
    COLUMNS instead of an all-reduce: 2x less link traffic, and the
    high-precision accumulation runs on 1/P of the columns per chip.
    C comes out sharded (m@m_axis, n@axis) — the natural layout for a
    GEMM feeding the next sharded operator (beyond-paper O4c; §Perf).
  * ``schedule="rs_stream"`` — per-diagonal reduce-scatter issued as each
    diagonal's GEMMs finish (overlap + scatter combined).

**m/n-sharded** (``ozaki_matmul_mnshard``): A row-sharded, B
column-sharded, full k local. Instead of all-gathering B's f64 words,
each device splits its column block locally and all-gathers the packed
``SliceWire`` (int8 slice stack + int32 exponents,
``parallel.compression``) over a ``ring_all_gather`` — ``s`` bytes per
element instead of 8. The gathered representation feeds the plan's OWN
executor (``core.executors.get_executor``), so the result is
bitwise-identical to the unsharded pipeline for every backend by
construction. ``schedule="overlap"`` gathers B's slice planes one ring
hop chain per plane, issued just before the first anti-diagonal needing
the plane — plane q+1's hops overlap diagonal q's GEMMs.

**2-D (k x batch)** (``distributed_ozaki_matmul_batched``): the serving
layout from the SNIPPETS host-platform recipe — batch rows spread over
one mesh axis, the reduction over another; the batch folds into rows
locally (row-independent, exact) and the k-shard machinery above runs
unchanged.

**Scheme II** (``distributed_ozaki2_matmul`` / ``ozaki2_matmul_mnshard``):
the residue pipeline rides the same two layouts. k-shard: the residue
map is per-element in k, so each device's ``(ell, m, n)`` int32 residue
partials reduce with ONE stacked integer collective (``psum`` /
``reduce_scatter``) and the balanced-Garner CRT runs once on the reduced
stack — ``ell`` modulus planes cross the wire instead of Scheme I's
``s`` anti-diagonals. m/n-shard: the packed ``ResidueWire`` (int8
centered residues + int32 exponents, ``parallel.compression``) is
ring-all-gathered — ``ell`` bytes per element, beating the SliceWire's
``s`` exactly when ``ell < s``. Both are bitwise identical to the
single-device reference (the policy spec's
``ozaki2-fp64|shard=AXIS|comm=int8`` route).

Batched GSPMD composition: ``ozaki_matmul_kshard_auto`` accepts the
batched API's operand ranks ((B, m, k) activations with stacked or
broadcast weights) and records the axis on the config so the
``PipelinePlan`` carries it; ``cfg.comm="int8"`` re-routes it onto the
explicit int8-slice schedules above. ``constrain_batched_kshard`` + the
``set_shard_mesh`` / ``use_shard_mesh`` registry are the in-trace
composition points the model/serving layers use for
``ArchConfig.ozaki_shard_axis``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.executors import gemm_xla, get_executor, int32_to_dw
from repro.core.modular import (ModularConfig, center_mod, crt_digits,
                                crt_value, garner_constants,
                                residues_from_slices, usable_moduli)
from repro.core.ozaki import OzakiConfig, resolve_accuracy_config
from repro.core.splitting import SplitResult, row_exponents, split_int
from repro.core.xmath import DW, dw_add
from repro.parallel.collectives import (psum_exact_int32, reduce_scatter_sum,
                                        ring_all_gather)
from repro.parallel.compression import (ResidueWire, SliceWire, pack_residues,
                                        pack_slices, unpack_residues)

KSHARD_SCHEDULES = ("psum", "overlap", "reduce_scatter", "rs_stream")
MNSHARD_SCHEDULES = ("allgather", "overlap")
OZAKI2_KSHARD_SCHEDULES = ("psum", "reduce_scatter")


def _diag_gemms(sa, sb, pairs) -> jax.Array:
    """One anti-diagonal's exact int32 partial from local slices —
    pair order matches ``core.executors.XlaExecutor.products`` exactly
    (the bitwise-parity contract)."""
    p_t = gemm_xla(sa.slices[pairs[0][0]], sb.slices[pairs[0][1]])
    for pth, qth in pairs[1:]:
        p_t = p_t + gemm_xla(sa.slices[pth], sb.slices[qth])
    return p_t


def _local_diag_products(sa, sb, cfg: OzakiConfig):
    """[(t, int32 product)] per anti-diagonal from local slices."""
    return [(t, _diag_gemms(sa, sb, pairs)) for t, pairs in cfg.diagonals()]


def _accumulate(prods, ea, eb, cfg: OzakiConfig, w: int):
    """High-precision scaled accumulation on the reduced products —
    the identical op sequence to ``XlaExecutor.accumulate`` (ordered
    smallest terms first, one deferred ldexp), so the sharded result is
    bitwise equal to the single-device pipeline."""
    shape = prods[0][1].shape
    e_base = ea[:, None].astype(jnp.int32) + eb[None, :].astype(jnp.int32)
    if cfg.accum == "df32":
        # TPU path: compensated f32 pair, no f64 anywhere
        acc = DW(jnp.zeros(shape, jnp.float32),
                 jnp.zeros(shape, jnp.float32))
        for t, p_t in sorted(prods, key=lambda tp: -tp[0]):
            scale = jnp.float32(2.0 ** (-(t + 2) * w))
            term = int32_to_dw(p_t)
            acc = dw_add(acc, DW(term.hi * scale, term.lo * scale))
        hi = jnp.ldexp(acc.hi, e_base)
        lo = jnp.ldexp(acc.lo, e_base)
        return hi, lo                     # df32 pair (48 mantissa bits)
    c = jnp.zeros(shape, jnp.float64)
    for t, p_t in sorted(prods, key=lambda tp: -tp[0]):
        c = c + jnp.ldexp(p_t.astype(jnp.float64), e_base - (t + 2) * w)
    return c


def _kshard_local(a_blk, b_blk, cfg: OzakiConfig, axis: str, schedule: str,
                  w: int):
    """The per-device k-shard pipeline (runs inside shard_map).

    a_blk: (r, k_local) f64/f32, b_blk: (k_local, n). Returns the full
    (r, n) block (psum/overlap) or the (r, n/P) column block
    (reduce_scatter/rs_stream); df32 returns an (hi, lo) pair.
    """
    # 1. global shared exponents (pmax over the k-shards)
    ea = row_exponents(a_blk)
    eb = row_exponents(b_blk.T)
    ea = jax.lax.pmax(ea, axis)
    eb = jax.lax.pmax(eb, axis)
    # 2. local slices against the global exponents
    sa = split_int(a_blk, cfg.num_splits, w, exp=ea)
    sb = split_int(b_blk.T, cfg.num_splits, w, exp=eb)
    # 3. exact integer reduction per anti-diagonal — only int32 partials
    # (and the int32 exponent pmaxes above) ever cross a link: the f64
    # operands and the int8 slice stacks stay device-local
    if schedule == "overlap":
        # diagonal t's all-reduce is issued BEFORE diagonal t+1's GEMMs
        # are built — the independent int32 psum rides the links while
        # the next diagonal's MXU work runs (compute/comm overlap)
        prods = []
        for t, pairs in cfg.diagonals():
            prods.append((t, psum_exact_int32(
                _diag_gemms(sa, sb, pairs), axis)))
    elif schedule == "rs_stream":
        # per-diagonal reduce-scatter, issued as each diagonal's GEMMs
        # finish: no s-deep int32 stack is materialized and diagonal
        # d's collective overlaps diagonal d+1's compute
        prods = []
        for t, pairs in cfg.diagonals():
            prods.append((t, reduce_scatter_sum(
                _diag_gemms(sa, sb, pairs), axis, scatter_dim=1)))
        nloc = prods[0][1].shape[1]
        idx = jax.lax.axis_index(axis)
        eb = jax.lax.dynamic_slice_in_dim(eb, idx * nloc, nloc)
    elif schedule == "reduce_scatter":
        # int32 reduce-scatter over output columns: each chip keeps
        # its n/P column block, exactly reduced (still associative
        # -> bitwise reproducible). eb must be sliced to the block.
        prods = _local_diag_products(sa, sb, cfg)
        ts = [t for t, _ in prods]
        stacked = reduce_scatter_sum(jnp.stack([p for _, p in prods]),
                                     axis, scatter_dim=2)
        prods = list(zip(ts, stacked))
        nloc = stacked.shape[2]
        idx = jax.lax.axis_index(axis)
        eb = jax.lax.dynamic_slice_in_dim(eb, idx * nloc, nloc)
    else:
        prods = _local_diag_products(sa, sb, cfg)
        ts = [t for t, _ in prods]
        stacked = psum_exact_int32(jnp.stack([p for _, p in prods]), axis)
        prods = list(zip(ts, stacked))
    # 4. high-precision accumulation (shape follows the — possibly
    # scattered — reduced products)
    return _accumulate(prods, ea, eb, cfg, w)


def distributed_ozaki_matmul(a: jax.Array, b: jax.Array, mesh: Mesh,
                             cfg: OzakiConfig = OzakiConfig(),
                             axis: str = "model",
                             schedule: str = "psum",
                             m_axis: str | None = None) -> jax.Array:
    """FP64-accurate C = A @ B with k sharded over ``mesh[axis]``.

    a: (m, k) f64, b: (k, n) f64 (global shapes). Result is replicated
    over ``axis`` and bitwise identical for every device count.
    ``cfg.accum`` selects f64 (CPU oracle) or df32 (TPU-deployable:
    everything below stays in {int8, int32, f32}).
    ``m_axis``: additionally shard the m (row) dim — the 2D production
    layout; rows are independent in the Ozaki scheme (per-row exponents),
    so this composes with the k-shard reduction untouched.
    """
    if schedule not in KSHARD_SCHEDULES:
        raise ValueError(f"unknown schedule {schedule!r}; expected one of "
                         f"{KSHARD_SCHEDULES}")
    k_global = a.shape[1]
    # fast-mode/target resolution BEFORE sizing the width, exactly like
    # the single-device drivers — required for bitwise parity on the
    # truncated-pair rows of the parity matrix
    cfg = resolve_accuracy_config(cfg, k_global)
    # Headroom: k_global terms per diagonal-fused GEMM group. The int32
    # psum adds no extra constraint beyond k_global (the global count
    # already includes every shard's terms).
    w = cfg.width_for(k_global)

    def local(a_blk, b_blk):
        return _kshard_local(a_blk, b_blk, cfg, axis, schedule, w)

    row = m_axis if m_axis else None
    col = axis if schedule in ("reduce_scatter", "rs_stream") else None
    c_spec = P(row, col)
    out_specs = (c_spec, c_spec) if cfg.accum == "df32" else c_spec
    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(row, axis), P(axis, None)),
                   out_specs=out_specs, check_rep=False)
    out = fn(a, b)
    return DW(*out) if cfg.accum == "df32" else out


def distributed_ozaki_matmul_batched(a: jax.Array, b: jax.Array, mesh: Mesh,
                                     cfg: OzakiConfig = OzakiConfig(),
                                     axis: str = "model",
                                     batch_axis: str | None = "data",
                                     schedule: str = "psum") -> jax.Array:
    """2-D (k x batch) mesh composition: ``(B, m, k) @ (k, n)``.

    The serving layout on the host-platform recipe: the batch dim is
    sharded over ``batch_axis`` (or fully replicated with ``None``), the
    reduction over ``axis`` — broadcast weights cross the k-axis only.
    Locally the batch folds into rows (row-independent, exact — the same
    fold the unbatched serving path uses), so the k-shard schedules above
    run unchanged and the result is bitwise identical to the unsharded
    ``ozaki_matmul_batched`` for every mesh shape and schedule.
    """
    if a.ndim != 3 or b.ndim != 2:
        raise ValueError(f"expected (B, m, k) @ (k, n) broadcast weights, "
                         f"got {a.shape} @ {b.shape}")
    if schedule not in KSHARD_SCHEDULES:
        raise ValueError(f"unknown schedule {schedule!r}; expected one of "
                         f"{KSHARD_SCHEDULES}")
    _, m, k_global = a.shape
    cfg = resolve_accuracy_config(cfg, k_global)
    w = cfg.width_for(k_global)

    def local(a_blk, b_blk):
        bloc = a_blk.shape[0]
        folded = a_blk.reshape(bloc * m, a_blk.shape[-1])
        out = _kshard_local(folded, b_blk, cfg, axis, schedule, w)
        if cfg.accum == "df32":
            hi, lo = out
            return (hi.reshape(bloc, m, -1), lo.reshape(bloc, m, -1))
        return out.reshape(bloc, m, -1)

    row = batch_axis if batch_axis else None
    col = axis if schedule in ("reduce_scatter", "rs_stream") else None
    c_spec = P(row, None, col)
    out_specs = (c_spec, c_spec) if cfg.accum == "df32" else c_spec
    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(row, None, axis), P(axis, None)),
                   out_specs=out_specs, check_rep=False)
    out = fn(a, b)
    return DW(*out) if cfg.accum == "df32" else out


def ozaki_matmul_mnshard(a: jax.Array, b: jax.Array, mesh: Mesh,
                         cfg: OzakiConfig = OzakiConfig(),
                         axis: str = "model",
                         schedule: str = "allgather") -> jax.Array:
    """C = A @ B with A row-sharded and B column-sharded over ``axis``.

    Full k is local, so each device splits its operand blocks against
    purely LOCAL per-row exponents (no pmax needed) and what crosses the
    mesh is the packed int8 ``SliceWire`` of B's column block — ``s``
    bytes per element + an int32 exponent vector instead of 8-byte f64
    words (``comm_bytes_model(layout="mnshard")`` prices both).

    ``schedule="allgather"``: one ring all-gather of the packed wire,
    then the plan's own executor contracts locally — bitwise-identical
    to the unsharded pipeline for EVERY backend by construction (the
    gathered representation is the exact split the reference computes,
    and rows of A are independent).

    ``schedule="overlap"``: B's slice planes are gathered one ring-hop
    chain per plane, each issued just before the first anti-diagonal
    that needs it — plane q+1's hops overlap diagonal q's GEMMs. The
    products/accumulation replicate ``XlaExecutor``'s op sequence, which
    every backend is bitwise-equal to.

    f64 operands/accumulation only (the CPU-oracle layout; the k-shard
    path owns the df32 story).
    """
    if schedule not in MNSHARD_SCHEDULES:
        raise ValueError(f"unknown schedule {schedule!r}; expected one of "
                         f"{MNSHARD_SCHEDULES}")
    if cfg.accum != "f64":
        raise ValueError("ozaki_matmul_mnshard is the f64 layout; use the "
                         "k-shard schedules for df32")
    world = mesh.shape[axis]
    m, k = a.shape
    n = b.shape[1]
    cfg = resolve_accuracy_config(cfg, k)
    w = cfg.width_for(k)
    plan = cfg.plan()
    if plan.fusion == "streaming":
        raise ValueError(
            "streaming fusion keeps slices in VMEM scratch — there is no "
            "materialized slice stack to put on the wire; use "
            "fuse_epilogue (or a non-streaming plan) with mnshard")

    def local(a_blk, b_blk):
        ex = get_executor(plan)
        sa = ex.split(a_blk, w)                    # local rows of A
        sb_loc = ex.split(b_blk.T, w)              # local cols of B (rows of B^T)
        wire = pack_slices(sb_loc)                 # (n_loc, s, k) int8 + (n_loc,)
        exp = ring_all_gather(wire.exp, axis, world)            # (n,)
        if schedule == "overlap":
            # gather plane q right before its first use; diagonals
            # ascending need planes q <= t, so plane t+1's ring hops are
            # independent of (and overlap) diagonal t's GEMMs
            planes = {}

            def plane(q):
                if q not in planes:
                    planes[q] = ring_all_gather(wire.slices[:, q, :],
                                                axis, world)    # (n, k)
                return planes[q]

            prods = []
            for t, pairs in cfg.diagonals():
                p_t = gemm_xla(sa.slices[pairs[0][0]], plane(pairs[0][1]))
                for pth, qth in pairs[1:]:
                    p_t = p_t + gemm_xla(sa.slices[pth], plane(qth))
                prods.append((t, p_t))
            return _accumulate(prods, sa.exp, exp, cfg, w)
        gathered = ring_all_gather(wire.slices, axis, world)    # (n, s, k)
        sb = SplitResult(jnp.swapaxes(gathered, 0, 1), exp, w)
        e_base = (sa.exp[:, None].astype(jnp.int32) +
                  exp[None, :].astype(jnp.int32))
        return ex.contract(sa, sb, w, e_base, (a_blk.shape[0], n))

    # check_rep=False: Pallas kernels have no shard_map replication rule
    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(axis, None), P(None, axis)),
                   out_specs=P(axis, None), check_rep=False)
    return fn(a, b)


def _kshard_local2(a_blk, b_blk, plan, moduli, w: int, axis: str,
                   schedule: str):
    """The per-device Scheme II k-shard pipeline (runs inside shard_map).

    The residue map is per-element in k, so local residue partial
    products sum EXACTLY (int32 collectives are associative) to the
    product of the global residue operands — the reference's single
    batched GEMM. Centering, Garner digits, and the f64 CRT sum run
    once, on the reduced stack, replaying the reference's exact op
    sequence: bitwise identity for any device count. int32 headroom is
    the ``usable_moduli(k_global)`` guarantee — the global bound already
    covers every shard-partial and every psum intermediate (each is a
    partial sum of the same <= k_global bounded terms).
    """
    # 1. global shared exponents, 2. local slices against them — the
    # Scheme I k-shard discipline, unchanged
    ea = jax.lax.pmax(row_exponents(a_blk), axis)
    eb = jax.lax.pmax(row_exponents(b_blk.T), axis)
    sa = split_int(a_blk, plan.num_splits, w, exp=ea)
    sb = split_int(b_blk.T, plan.num_splits, w, exp=eb)
    # 3. local centered residues + ONE batched int8 GEMM over the
    # modulus axis: only the (ell, m, n) int32 residue partials (and the
    # int32 exponent pmaxes) ever cross a link
    ra = residues_from_slices(sa.slices, w, moduli)
    rb = residues_from_slices(sb.slices, w, moduli)
    p = gemm_xla(ra, rb)
    if schedule == "reduce_scatter":
        # scatter over output columns: each chip keeps n/P columns of
        # every modulus plane, exactly reduced; CRT runs on 1/P of the
        # output per chip. eb must follow the column block.
        p = reduce_scatter_sum(p, axis, scatter_dim=2)
        nloc = p.shape[2]
        idx = jax.lax.axis_index(axis)
        eb = jax.lax.dynamic_slice_in_dim(eb, idx * nloc, nloc)
    else:
        p = psum_exact_int32(p, axis)
    # 4. CRT reconstruction on the reduced products
    digits = crt_digits(center_mod(p, moduli), moduli)
    e_base = ea[:, None].astype(jnp.int32) + eb[None, :].astype(jnp.int32)
    return crt_value(digits, moduli, plan.beta, e_base)


def distributed_ozaki2_matmul(a: jax.Array, b: jax.Array, mesh: Mesh,
                              cfg: ModularConfig = ModularConfig(),
                              axis: str = "model",
                              schedule: str = "psum") -> jax.Array:
    """Scheme II C = A @ B with k sharded over ``mesh[axis]``.

    The residue-system sibling of ``distributed_ozaki_matmul``: global
    pmax exponents, local integerization, local centered residues, one
    local batched int8 GEMM — then ONE int32 collective over the
    stacked ``(ell, m, n)`` residue partials (``schedule="psum"``
    replicates C; ``schedule="reduce_scatter"`` leaves C column-sharded
    with half the link traffic). NO f64 operand crosses a link
    (``comm="int8"`` in the policy spec; ``comm_bytes_model`` with
    ``scheme="ozaki2_fp64"`` prices it), and the result is bitwise
    identical to the single-device reference for any mesh shape.
    """
    if schedule not in OZAKI2_KSHARD_SCHEDULES:
        raise ValueError(f"unknown schedule {schedule!r}; expected one of "
                         f"{OZAKI2_KSHARD_SCHEDULES}")
    if a.dtype != jnp.float64 or b.dtype != jnp.float64:
        raise TypeError(f"distributed_ozaki2_matmul takes f64 operands, "
                        f"got {a.dtype} @ {b.dtype}")
    k_global = a.shape[1]
    plan = cfg.plan(k_global)
    moduli = usable_moduli(k_global)[:plan.num_moduli]
    w = cfg.w

    def local(a_blk, b_blk):
        return _kshard_local2(a_blk, b_blk, plan, moduli, w, axis, schedule)

    col = axis if schedule == "reduce_scatter" else None
    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(None, axis), P(axis, None)),
                   out_specs=P(None, col), check_rep=False)
    return fn(a, b)


def ozaki2_matmul_mnshard(a: jax.Array, b: jax.Array, mesh: Mesh,
                          cfg: ModularConfig = ModularConfig(),
                          axis: str = "model") -> jax.Array:
    """Scheme II C = A @ B with A row-sharded, B column-sharded.

    Full k is local, so operands split against purely local exponents;
    what crosses the mesh is the packed ``ResidueWire`` of B's column
    block — ``ell`` bytes per element + an int32 exponent vector instead
    of 8-byte f64 words (vs the SliceWire's ``s``: the residue wire wins
    exactly when ``ell < s``, the same arbitration
    ``comm_bytes_model(scheme="ozaki2_fp64", layout="mnshard")``
    encodes). The gathered stack IS the residue operand the reference
    executor computes, so every backend — including the fused-CRT
    epilogue kernel — contracts it to the bitwise-identical result.
    """
    world = mesh.shape[axis]
    k = a.shape[1]
    plan = cfg.plan(k)
    moduli = usable_moduli(k)[:plan.num_moduli]
    w = cfg.w

    def local(a_blk, b_blk):
        ex = get_executor(plan)
        sa = ex.split(a_blk, w)                     # local rows of A
        sb_loc = ex.split(b_blk.T, w)               # local cols of B
        rb_loc = residues_from_slices(sb_loc.slices, w, moduli)
        wire = pack_residues(rb_loc, sb_loc.exp, moduli)  # (n_loc, ell, k)
        gathered = ResidueWire(
            ring_all_gather(wire.residues, axis, world),   # (n, ell, k)
            ring_all_gather(wire.exp, axis, world),        # (n,)
            wire.moduli)
        rb, exp = unpack_residues(gathered)                # (ell, n, k)
        ra = residues_from_slices(sa.slices, w, moduli)
        e_base = (sa.exp[:, None].astype(jnp.int32) +
                  exp[None, :].astype(jnp.int32))
        if plan.fusion == "epilogue":
            from repro.kernels import int8_matmul_nt_crt
            mods, qmod, inv, scales = garner_constants(moduli, plan.beta)
            tile = plan.tile
            out = int8_matmul_nt_crt(ra, rb, moduli=mods, qmod=qmod,
                                     inv=inv, scales=scales, bm=tile.bm,
                                     bn=tile.bn, bk=tile.bk,
                                     interpret=plan.interpret)
            return jnp.ldexp(out, e_base)
        p = ex.gemm(ra, rb)
        digits = crt_digits(center_mod(p, moduli), moduli)
        return crt_value(digits, moduli, plan.beta, e_base)

    # check_rep=False: Pallas kernels have no shard_map replication rule
    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(axis, None), P(None, axis)),
                   out_specs=P(axis, None), check_rep=False)
    return fn(a, b)


def kshard_specs(a_ndim: int, b_ndim: int, axis: str) -> tuple[P, P]:
    """PartitionSpecs placing the contraction (k) dim on ``axis``.

    Handles every operand rank of the (batched) Ozaki API: a is
    (m, k) or (B, m, k); b is (k, n) or (B, k, n).
    """
    a_spec = P(None, None, axis) if a_ndim == 3 else P(None, axis)
    b_spec = P(None, axis, None) if b_ndim == 3 else P(axis, None)
    return a_spec, b_spec


def ozaki_matmul_kshard_auto(a: jax.Array, b: jax.Array, mesh: Mesh,
                             cfg: OzakiConfig = OzakiConfig(),
                             axis: Optional[str] = None) -> jax.Array:
    """Paper-faithful distributed baseline: the (batched) Ozaki pipeline
    under jit with k-sharded inputs — GSPMD inserts the collectives
    (f64 operand words move around the opaque kernels; reproducible only
    per mesh shape).

    ``cfg.comm="int8"`` re-routes onto the explicit int8-slice collective
    schedules (``distributed_ozaki_matmul``/``_batched``): NO f64 operand
    crosses a link, only exact int32 pair partials + exponent pmaxes —
    and the result upgrades from per-mesh-shape reproducible to bitwise
    identical to the single-device reference for ANY mesh shape.
    Covered routes: f64 2-D, and f64 3-D with broadcast (2-D) weights —
    stacked 3-D weights and df32 stay on the GSPMD path.

    3-D ``a`` routes through ``ozaki_matmul_batched`` (stacked or
    broadcast ``b``), composing the batched API with k-sharding: the
    executor pipeline is unchanged, only the operand layout differs. The
    resolved axis is recorded on the config (``shard_axis``), so the
    ``PipelinePlan`` built inside the jitted computation carries it.
    """
    from repro.core.ozaki import ozaki_matmul, ozaki_matmul_batched
    axis = axis or cfg.shard_axis or "model"
    cfg = dataclasses.replace(cfg, shard_axis=axis)
    if getattr(cfg, "comm", "f64") == "int8" and cfg.accum == "f64" and \
            a.dtype == jnp.float64:
        if a.ndim == 2:
            return distributed_ozaki_matmul(a, b, mesh, cfg, axis=axis)
        if a.ndim == 3 and b.ndim == 2:
            return distributed_ozaki_matmul_batched(
                a, b, mesh, cfg, axis=axis, batch_axis=None)
    impl = ozaki_matmul_batched if a.ndim == 3 else ozaki_matmul
    a_spec, b_spec = kshard_specs(a.ndim, b.ndim, axis)
    out_spec = P(*([None] * a.ndim))
    fn = jax.jit(functools.partial(impl, cfg=cfg),
                 in_shardings=(NamedSharding(mesh, a_spec),
                               NamedSharding(mesh, b_spec)),
                 out_shardings=NamedSharding(mesh, out_spec))
    return fn(a, b)


# ----------------------------------------------------------------------------
# Deployment wiring: an ambient shard mesh + in-trace sharding hints, so the
# model/serving layers can honor ``ozaki_shard_axis`` without threading a
# Mesh through every projection call.
# ----------------------------------------------------------------------------

_SHARD_MESH: list = [None]


def set_shard_mesh(mesh: Optional[Mesh]) -> None:
    """Register (or clear, with None) the deployment's shard mesh.

    Trace-time semantics: the registry is read while a jitted function
    TRACES, not when it runs — register the mesh before the first call
    of any jitted step that should honor it (a cached executable traced
    without a mesh stays unsharded until a shape change retraces it).
    The serving engine scopes its mesh around every tick
    (``use_shard_mesh``), which covers the first trace by construction.
    """
    _SHARD_MESH[0] = mesh


def active_shard_mesh() -> Optional[Mesh]:
    return _SHARD_MESH[0]


@contextlib.contextmanager
def use_shard_mesh(mesh: Optional[Mesh]):
    prev = _SHARD_MESH[0]
    _SHARD_MESH[0] = mesh
    try:
        yield mesh
    finally:
        _SHARD_MESH[0] = prev


def _constrain(x: jax.Array, sharding: NamedSharding) -> jax.Array:
    if isinstance(x, jax.core.Tracer):          # inside jit: GSPMD hint
        return jax.lax.with_sharding_constraint(x, sharding)
    return jax.device_put(x, sharding)          # eager: reshard now


def constrain_batched_kshard(a: jax.Array, b: jax.Array, axis: str,
                             mesh: Optional[Mesh] = None
                             ) -> tuple[jax.Array, jax.Array]:
    """Pin the k dim of a (batched) matmul's operands to ``mesh[axis]``.

    The in-trace composition point for ``OzakiConfig.shard_axis`` /
    ``ArchConfig.ozaki_shard_axis``: unlike ``ozaki_matmul_kshard_auto``
    (which owns its jit), this works inside an already-traced model step.
    No-op when no mesh is registered or the axis is absent from it.
    """
    mesh = mesh if mesh is not None else active_shard_mesh()
    if mesh is None or axis not in mesh.axis_names:
        return a, b
    a_spec, b_spec = kshard_specs(a.ndim, b.ndim, axis)
    return (_constrain(a, NamedSharding(mesh, a_spec)),
            _constrain(b, NamedSharding(mesh, b_spec)))
