"""Distribution layer: sharding rules, collectives, distributed Ozaki."""
from .sharding import (ShardingPlan, batch_axes, decode_state_axes,
                       make_plan, make_rules, pspec, tree_pspecs,
                       tree_shardings)

__all__ = ["ShardingPlan", "batch_axes", "decode_state_axes", "make_plan",
           "make_rules", "pspec", "tree_pspecs", "tree_shardings"]
