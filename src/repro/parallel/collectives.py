"""Collective helpers: exact integer reductions, ring primitives, and the
compute/comm-overlap chunked matmul used by the §Perf experiments.

``psum`` of int32 is associative -> bitwise reproducible for any mesh
shape/reduction order. That exactness is what upgrades the Ozaki scheme's
reproducibility story to an *elasticity invariant* (DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def psum_exact_int32(x: jax.Array, axis: str) -> jax.Array:
    """Integer all-reduce; order-independent by associativity."""
    assert jnp.issubdtype(x.dtype, jnp.integer), x.dtype
    return jax.lax.psum(x, axis)


def ring_all_gather(x: jax.Array, axis: str, axis_size: int) -> jax.Array:
    """All-gather along ``axis`` built from collective_permutes (one hop
    per step) — the schedule that overlaps with per-step compute on TPU
    ICI rings. x: (chunk, ...) -> (axis_size * chunk, ...).
    """
    idx = jax.lax.axis_index(axis)
    perm = [(i, (i + 1) % axis_size) for i in range(axis_size)]

    def body(carry, _):
        block = carry
        block = jax.lax.ppermute(block, axis, perm)
        return block, block

    _, blocks = jax.lax.scan(body, x, None, length=axis_size - 1)
    all_blocks = jnp.concatenate([x[None], blocks], axis=0)
    # blocks arrive in source order idx-1, idx-2, ...; restore global order
    src = (idx - jnp.arange(axis_size)) % axis_size
    order = jnp.argsort(src)
    all_blocks = jnp.take(all_blocks, order, axis=0)
    return all_blocks.reshape((-1,) + x.shape[1:])


def chunked_matmul_psum(x: jax.Array, w: jax.Array, axis: str,
                        num_chunks: int) -> jax.Array:
    """k-sharded matmul with the reduction interleaved over n-chunks.

    Inside shard_map: x (m, k_local), w (k_local, n). Splitting n into
    chunks and issuing one psum per chunk lets chunk i's all-reduce
    overlap chunk i+1's matmul (XLA schedules the independent collective
    concurrently). Beyond-paper trick recorded in §Perf.
    """
    n = w.shape[1]
    chunk = n // num_chunks
    outs = []
    for i in range(num_chunks):
        part = x @ w[:, i * chunk:(i + 1) * chunk]
        outs.append(jax.lax.psum(part, axis))
    rest = n - chunk * num_chunks
    if rest:
        outs.append(jax.lax.psum(x @ w[:, n - rest:], axis))
    return jnp.concatenate(outs, axis=1)


def reduce_scatter_sum(x: jax.Array, axis: str, axis_size: int,
                       scatter_dim: int = 0) -> jax.Array:
    """psum_scatter wrapper (tiled=True keeps the dim, divided)."""
    return jax.lax.psum_scatter(x, axis, scatter_dimension=scatter_dim,
                                tiled=True)
