"""Collective helpers: exact integer reductions, ring primitives, and the
compute/comm-overlap chunked matmul used by the §Perf experiments.

``psum`` of int32 is associative -> bitwise reproducible for any mesh
shape/reduction order. That exactness is what upgrades the Ozaki scheme's
reproducibility story to an *elasticity invariant* (DESIGN.md §2).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def psum_exact_int32(x: jax.Array, axis: str) -> jax.Array:
    """Integer all-reduce; order-independent by associativity."""
    assert jnp.issubdtype(x.dtype, jnp.integer), x.dtype
    return jax.lax.psum(x, axis)


def ring_all_gather(x: jax.Array, axis: str, axis_size: int,
                    hop: int = 1) -> jax.Array:
    """All-gather along ``axis`` built from collective_permutes (one hop
    per step) — the schedule that overlaps with per-step compute on TPU
    ICI rings. x: (chunk, ...) -> (axis_size * chunk, ...).

    ``hop`` is the ring stride: step j forwards every block one more
    ``hop`` around the axis, so after j steps device ``i`` holds the
    block that originated at ``(i - j * hop) % axis_size``. A
    non-contiguous ring (``hop > 1`` — e.g. skipping over devices that
    share a host link) visits every device iff
    ``gcd(hop, axis_size) == 1``. The source-order restore below indexes
    by the ACTUAL per-step source, not by position — the hop-1 shortcut
    ``src = idx - arange`` silently shuffled blocks for any other
    permutation.
    """
    if axis_size > 1 and math.gcd(hop % axis_size, axis_size) != 1:
        raise ValueError(
            f"hop={hop} does not generate the ring for axis_size="
            f"{axis_size} (gcd != 1): some source blocks would never "
            f"arrive")
    idx = jax.lax.axis_index(axis)
    perm = [(i, (i + hop) % axis_size) for i in range(axis_size)]

    def body(carry, _):
        block = carry
        block = jax.lax.ppermute(block, axis, perm)
        return block, block

    _, blocks = jax.lax.scan(body, x, None, length=axis_size - 1)
    all_blocks = jnp.concatenate([x[None], blocks], axis=0)
    # position j holds the block from source (idx - j*hop) % axis_size;
    # argsort over the true source ids restores global order for ANY hop
    src = (idx - hop * jnp.arange(axis_size)) % axis_size
    order = jnp.argsort(src)
    all_blocks = jnp.take(all_blocks, order, axis=0)
    return all_blocks.reshape((-1,) + x.shape[1:])


def chunked_matmul_psum(x: jax.Array, w: jax.Array, axis: str,
                        num_chunks: int) -> jax.Array:
    """k-sharded matmul with the reduction interleaved over n-chunks.

    Inside shard_map: x (m, k_local), w (k_local, n). Splitting n into
    chunks and issuing one psum per chunk lets chunk i's all-reduce
    overlap chunk i+1's matmul (XLA schedules the independent collective
    concurrently). Beyond-paper trick recorded in §Perf.
    """
    n = w.shape[1]
    chunk = n // num_chunks
    outs = []
    for i in range(num_chunks):
        part = x @ w[:, i * chunk:(i + 1) * chunk]
        outs.append(jax.lax.psum(part, axis))
    rest = n - chunk * num_chunks
    if rest:
        outs.append(jax.lax.psum(x @ w[:, n - rest:], axis))
    return jnp.concatenate(outs, axis=1)


def reduce_scatter_sum(x: jax.Array, axis: str, axis_size: int = None,
                       scatter_dim: int = 0) -> jax.Array:
    """psum_scatter wrapper (tiled=True keeps the dim, divided).

    Exact for integer ``x`` (associative adds), so the Ozaki k-shard
    schedules reduce their int32 pair partials through this — half the
    link bytes of an all-reduce, bitwise reproducible either way.
    ``axis_size`` is advisory (the sharded dim must divide by it).
    """
    return jax.lax.psum_scatter(x, axis, scatter_dimension=scatter_dim,
                                tiled=True)
