"""Logical-axis sharding rules -> PartitionSpec (MaxText-style).

Every parameter/state leaf in the framework carries a tuple of *logical*
axis names (assigned at init by ``ParamBuilder``); a ``Rules`` table maps
each logical name to zero or more *mesh* axes. Changing the table is the
main §Perf lever — the hillclimb log edits rules, not model code.

Mesh axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
multi-pod. ``pod`` composes with ``data`` for batch/FSDP sharding.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


Rules = Mapping[str, tuple[str, ...]]

# ----------------------------------------------------------------------------
# Activation-constraint context: models call ``constrain(x, axes)`` at key
# points (attention heads, FFN hidden, MoE buffers, logits); inside a
# ``use_sharding(mesh, rules)`` scope that pins the GSPMD propagation —
# without it GSPMD is free to replicate scanned/microbatched activations
# (observed: 16x FLOP blowup on the first train_4k dry-run).
# ----------------------------------------------------------------------------

_ACTIVE: list = []


class use_sharding:
    def __init__(self, mesh: Mesh, rules: Rules):
        self.mesh = mesh
        self.rules = rules

    def __enter__(self):
        _ACTIVE.append((self.mesh, self.rules))
        return self

    def __exit__(self, *exc):
        _ACTIVE.pop()
        return False


def constrain(x, axes: Sequence[Optional[str]]):
    """with_sharding_constraint by logical axes; no-op outside the ctx.

    Entries are dropped when the dim is SMALLER than the shard count —
    constraining a size-1 batch over a 16-way data axis makes GSPMD PAD
    the tensor 16x (observed 98 GiB cache ghosts on the long_500k
    cells). Merely non-divisible dims (24 heads over 16) keep the
    constraint: the <2x padding beats full replication (dropping the
    24-head constraint cost 4x FLOPs on the llama/musicgen cells).
    """
    if not _ACTIVE:
        return x
    mesh, rules = _ACTIVE[-1]
    spec = list(pspec(axes, rules)) if pspec(axes, rules) else []
    spec = spec + [None] * (x.ndim - len(spec))
    used: set = set()
    for i, entry in enumerate(spec):
        if entry is None:
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        shards = 1
        for nm in names:
            shards *= mesh.shape[nm]
        # drop tiny dims (padding blowup) and duplicate mesh axes (a
        # later logical axis yields to the earlier one, e.g. seq vs
        # vocab both -> model under the SP override)
        if x.shape[i] < shards or used & set(names):
            spec[i] = None
            continue
        used |= set(names)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def wrap_with_sharding(fn, mesh: Mesh, rules: Rules):
    """Make ``fn`` trace under the activation-constraint context."""
    import functools

    @functools.wraps(fn)
    def wrapped(*args, **kwargs):
        with use_sharding(mesh, rules):
            return fn(*args, **kwargs)

    return wrapped

# --- default logical -> mesh rules ------------------------------------------
# Parameters
_PARAM_RULES = {
    "vocab": ("model",),
    "embed": (),                 # ("data",)+ under FSDP
    "mlp": ("model",),
    "mlp2": ("model",),
    "heads_flat": ("model",),    # q heads x head_dim, fused
    "kv_flat": (),               # few KV heads; replicated
    "experts": ("model",),       # expert parallelism
    "expert_mlp": (),
    "inner": ("model",),         # SSM d_inner
    "heads": ("model",),         # mamba2 heads
    "state": (),
    "conv": (),
    "ssm_misc": (),
    "codebooks": (),
    "layers": (),                # scanned; never sharded
}
# Activations / batch / caches
_DATA_RULES = {
    "batch": ("data",),
    "seq": (),
    "kv_seq": ("model",),        # decode caches: flash-decoding style
    "kv_heads": (),
    "head_dim": (),
    "act_embed": (),
}


def make_rules(*, multi_pod: bool = False, fsdp: bool = False,
               overrides: Optional[Mapping[str, tuple[str, ...]]] = None
               ) -> dict[str, tuple[str, ...]]:
    rules = dict(_PARAM_RULES) | dict(_DATA_RULES)
    if multi_pod:
        rules["batch"] = ("pod", "data")
    if fsdp:
        # ZeRO-3-style: the embed dim of (almost) every param shards over
        # the data axis (and pod, when present).
        rules["embed"] = ("pod", "data") if multi_pod else ("data",)
    if overrides:
        rules.update(overrides)
    return rules


def pspec(axes: Sequence[Optional[str]], rules: Rules) -> P:
    """Logical axes tuple -> PartitionSpec. ``None`` axis -> unsharded."""
    out = []
    for a in axes:
        if a is None:
            out.append(None)
            continue
        mesh_axes = rules.get(a, ())
        if not mesh_axes:
            out.append(None)
        elif len(mesh_axes) == 1:
            out.append(mesh_axes[0])
        else:
            out.append(tuple(mesh_axes))
    # trailing Nones are implicit
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def tree_pspecs(axes_tree: Any, rules: Rules) -> Any:
    return jax.tree.map(lambda a: pspec(a, rules), axes_tree,
                        is_leaf=lambda a: isinstance(a, tuple) and
                        all(isinstance(x, (str, type(None))) for x in a))


def tree_shardings(axes_tree: Any, mesh: Mesh, rules: Rules) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        tree_pspecs(axes_tree, rules),
                        is_leaf=lambda s: isinstance(s, P))


# --- batch / state logical axes ---------------------------------------------

def batch_axes(cfg, kind: str) -> dict[str, tuple]:
    """Logical axes for the input batch dict of one step."""
    if cfg.frontend == "audio":
        tok = ("batch", "seq", None)
    else:
        tok = ("batch", "seq")
    out = {"tokens": tok}
    if cfg.frontend == "vision" and kind in ("train", "prefill"):
        out["patch_embeds"] = ("batch", "seq", "act_embed")
    return out


def decode_state_axes(cfg) -> Any:
    """Logical axes matching models.transformer.DecodeState (isomorphic
    pytree: same NamedTuple nodes, axis-name tuples as leaves)."""
    from repro.models.attention import KVCache
    from repro.models.ssm import SSMState
    from repro.models.transformer import DecodeState
    kv = ssm = hyb = None
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        kv = _kv_axes(KVCache)
    elif cfg.family == "ssm":
        ssm = _ssm_axes(SSMState, variant="mamba1")
    elif cfg.family == "hybrid":
        ssm = _ssm_axes(SSMState, variant="mamba2")
        hyb = _kv_axes(KVCache)
    return DecodeState((), kv, ssm, hyb)   # pos scalar: P() -> replicated


def _kv_axes(KVCache):
    a = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    return KVCache(a, a)


def _ssm_axes(SSMState, variant: str):
    conv = ("layers", "batch", None, "inner")
    if variant == "mamba1":
        h = ("layers", "batch", "inner", "state")
    else:
        h = ("layers", "batch", "heads", None, "state")
    return SSMState(conv, h)


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    """Everything jit needs for one (arch, shape) cell."""

    mesh: Mesh
    rules: dict
    param_specs: Any
    batch_specs: Any
    state_specs: Any = None

    def param_shardings(self):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.param_specs,
                            is_leaf=lambda s: isinstance(s, P))


def make_plan(cfg, axes_tree, mesh: Mesh, kind: str = "train",
              overrides=None) -> ShardingPlan:
    multi_pod = "pod" in mesh.axis_names
    rules = make_rules(multi_pod=multi_pod, fsdp=cfg.fsdp_params,
                       overrides=overrides)
    pspecs = tree_pspecs(axes_tree, rules)
    b_axes = batch_axes(cfg, kind)
    b_specs = {k: pspec(v, rules) for k, v in b_axes.items()}
    s_specs = None
    if kind in ("prefill", "decode"):
        s_axes = decode_state_axes(cfg)
        s_specs = jax.tree.map(
            lambda a: pspec(a, rules), s_axes,
            is_leaf=lambda a: isinstance(a, tuple) and
            all(isinstance(x, (str, type(None))) for x in a))
    return ShardingPlan(mesh, rules, pspecs, b_specs, s_specs)
