"""int8 wire formats for the mesh: the Ozaki slice/residue transports and
the EF-SGD gradient compressor.

Three distinct kinds of "int8 on the wire" live here:

* ``SliceWire`` — **lossless**. The Ozaki operands already *are* exact
  int8 mantissa slices + per-row power-of-two exponents, so shipping
  the packed representation across the mesh moves ``s`` bytes per
  element instead of the 8 an f64 operand costs — with zero rounding
  anywhere (pack/unpack are pure transposes). ``parallel.ozaki_shard``
  all-gathers ``SliceWire`` stacks for m/n-sharded layouts; the
  byte accounting feeds ``core.tuning.comm_bytes_model``.
* ``ResidueWire`` — **lossless**, the Scheme II sibling. The residue
  pipeline's operand representation is the centered int8 residue stack
  (one plane per CRT modulus, ``core.modular.residues_from_slices``)
  plus the same per-row exponents — ``ell`` bytes per element on the
  wire. Both wires share the pack/unpack shape discipline (sharded dim
  leading) and the ``wire_nbytes`` accounting.
* ``compress_psum`` — **lossy** (EF-SGD). The gradient all-reduce is
  replaced by: quantize local grad to int8 against a global per-tensor
  scale (pmax), *exact* int32 psum of the quantized values (associative
  -> reproducible), dequantize. The quantization residual is fed back
  into the next step's gradient (error feedback), so the compression
  error stays O(1) over training instead of accumulating — the standard
  EF-SGD guarantee. Off by default; enabled per-run
  (``--grad-compression int8``). The Ozaki exactness paths never enable
  it (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.splitting import SplitResult


class SliceWire(NamedTuple):
    """The packed int8-slice transport format (lossless, gather-ready).

    A ``SplitResult`` holds slices as ``(s, r, k)`` — slice index
    leading, the natural layout for the GEMM stage. On the wire the
    SHARDED dimension must lead so a ``ring_all_gather`` /
    ``jax.lax.all_gather`` over dim 0 concatenates row blocks from
    different devices into the global matrix:

    slices: int8 ``(r, s, k)`` — row-major slice stack.
    exp:    int32 ``(r,)``     — per-row shared power-of-two exponents.
    w:      static slice width (split metadata; never crosses the wire
            as an array — it is shape-derived and identical on every
            device by construction).
    """

    slices: jax.Array
    exp: jax.Array
    w: int


def pack_slices(sr: SplitResult) -> SliceWire:
    """SplitResult -> wire layout. Exact: a transpose, no arithmetic."""
    return SliceWire(jnp.swapaxes(sr.slices, 0, 1), sr.exp, sr.w)


def unpack_slices(wire: SliceWire) -> SplitResult:
    """Wire layout -> SplitResult. Exact inverse of ``pack_slices``."""
    return SplitResult(jnp.swapaxes(wire.slices, 0, 1), wire.exp, wire.w)


def slice_wire_bytes(rows: int, k: int, num_splits: int) -> int:
    """Bytes one device contributes to a SliceWire gather: the int8
    slice stack plus the int32 exponent vector (``w`` is static)."""
    return rows * num_splits * k + 4 * rows


class ResidueWire(NamedTuple):
    """The packed int8-residue transport format (lossless, gather-ready).

    Scheme II stores residues as ``(ell, r, k)`` — modulus index
    leading, the batched-GEMM layout. On the wire the SHARDED dimension
    leads (the same discipline as ``SliceWire``), so a gather over dim 0
    concatenates row blocks into the global residue stack:

    residues: int8 ``(r, ell, k)`` — row-major centered residue stack,
              one plane per CRT modulus (|value| <= (m_j - 1) / 2).
    exp:      int32 ``(r,)`` — per-row shared power-of-two exponents.
    moduli:   static tuple of the CRT moduli (shape-derived metadata,
              identical on every device by construction — like
              ``SliceWire.w`` it never crosses the wire as an array).
    """

    residues: jax.Array
    exp: jax.Array
    moduli: tuple


def pack_residues(residues: jax.Array, exp: jax.Array,
                  moduli) -> ResidueWire:
    """(ell, r, k) residue stack -> wire layout. Exact: a transpose."""
    return ResidueWire(jnp.swapaxes(residues, 0, 1), exp, tuple(moduli))


def unpack_residues(wire: ResidueWire) -> tuple[jax.Array, jax.Array]:
    """Wire layout -> ((ell, r, k) residues, exp). Exact inverse of
    ``pack_residues``."""
    return jnp.swapaxes(wire.residues, 0, 1), wire.exp


def residue_wire_bytes(rows: int, k: int, num_moduli: int) -> int:
    """Bytes one device contributes to a ResidueWire gather: the int8
    residue stack plus the int32 exponent vector (``moduli`` static)."""
    return rows * num_moduli * k + 4 * rows


def wire_nbytes(wire) -> int:
    """Actual byte count of a wire's arrays (must match the models) —
    the shared protocol over both wire formats: every non-scalar array
    field is payload, static metadata (``w`` / ``moduli``) costs
    nothing — even when a tracer has turned it into a 0-d array."""
    return sum(int(v.size) * v.dtype.itemsize for v in wire
               if hasattr(v, "dtype") and getattr(v, "ndim", 0) > 0)


class EFState(NamedTuple):
    residual: Any          # pytree like grads


def init_ef_state(grads_like: Any) -> EFState:
    return EFState(jax.tree.map(jnp.zeros_like, grads_like))


def compress_psum(grads: Any, ef: EFState, axis: str) -> tuple[Any, EFState]:
    """All-reduce-mean ``grads`` over ``axis`` in int8 with error feedback.

    Returns (averaged grads, new EF state). Must be called inside
    shard_map/pmap context where ``axis`` is bound.
    """
    n = jax.lax.psum(1, axis)

    def one(g, r):
        g_ef = g + r
        scale = jax.lax.pmax(jnp.max(jnp.abs(g_ef)), axis) / 127.0 + 1e-30
        q = jnp.clip(jnp.round(g_ef / scale), -127, 127).astype(jnp.int8)
        new_r = g_ef - q.astype(g.dtype) * scale      # local residual
        total = jax.lax.psum(q.astype(jnp.int32), axis)
        return total.astype(g.dtype) * scale / n, new_r

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(ef.residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    avg = tdef.unflatten([o[0] for o in out])
    res = tdef.unflatten([o[1] for o in out])
    return avg, EFState(res)


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantization (checkpoint compression)."""
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return q.astype(dtype) * scale
