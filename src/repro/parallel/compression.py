"""int8 gradient compression with error feedback (distributed-opt trick).

The gradient all-reduce is replaced by: quantize local grad to int8
against a global per-tensor scale (pmax), *exact* int32 psum of the
quantized values (associative -> reproducible), dequantize. The
quantization residual is fed back into the next step's gradient (error
feedback), so the compression error stays O(1) over training instead of
accumulating — the standard EF-SGD guarantee.

Off by default; enabled per-run (``--grad-compression int8``). The Ozaki
exactness paths never enable it (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any          # pytree like grads


def init_ef_state(grads_like: Any) -> EFState:
    return EFState(jax.tree.map(jnp.zeros_like, grads_like))


def compress_psum(grads: Any, ef: EFState, axis: str) -> tuple[Any, EFState]:
    """All-reduce-mean ``grads`` over ``axis`` in int8 with error feedback.

    Returns (averaged grads, new EF state). Must be called inside
    shard_map/pmap context where ``axis`` is bound.
    """
    n = jax.lax.psum(1, axis)

    def one(g, r):
        g_ef = g + r
        scale = jax.lax.pmax(jnp.max(jnp.abs(g_ef)), axis) / 127.0 + 1e-30
        q = jnp.clip(jnp.round(g_ef / scale), -127, 127).astype(jnp.int8)
        new_r = g_ef - q.astype(g.dtype) * scale      # local residual
        total = jax.lax.psum(q.astype(jnp.int32), axis)
        return total.astype(g.dtype) * scale / n, new_r

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(ef.residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    avg = tdef.unflatten([o[0] for o in out])
    res = tdef.unflatten([o[1] for o in out])
    return avg, EFState(res)


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantization (checkpoint compression)."""
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return q.astype(dtype) * scale
