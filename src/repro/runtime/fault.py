"""Fault tolerance runtime: heartbeat, straggler watchdog, restart driver.

On a real cluster each host runs a ``Heartbeat`` thread and the
coordinator inspects the files; missing beats mark a dead host and the
job restarts from the latest checkpoint onto the surviving topology
(elastic restore — see ``checkpoint.restore``'s sharding_fn). Here the
same machinery is exercised in-process: ``restart_loop`` catches
(simulated or real) failures, restores, and continues — the integration
test asserts bit-identical results vs an uninterrupted run.
"""
from __future__ import annotations

import dataclasses
import json
import os
import statistics
import threading
import time
from typing import Callable, Optional


class SimulatedFailure(RuntimeError):
    """Injected node failure (tests / chaos drills)."""


class Heartbeat:
    """Background thread writing {host, step, t} beats to a JSON file."""

    def __init__(self, path: str, host: str = "host0",
                 interval_s: float = 0.05):
        self.path = path
        self.host = host
        self.interval_s = interval_s
        self.step = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def start(self):
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        return self

    def _run(self):
        while not self._stop.is_set():
            beat = {"host": self.host, "step": self.step, "t": time.time()}
            tmp = self.path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(beat, f)
            os.replace(tmp, self.path)
            self._stop.wait(self.interval_s)

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2)


def is_alive(path: str, timeout_s: float) -> bool:
    try:
        with open(path) as f:
            beat = json.load(f)
        return (time.time() - beat["t"]) < timeout_s
    except (OSError, ValueError):
        return False


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration_s: float
    median_s: float


class StepWatchdog:
    """Flags steps slower than ``factor`` x running median (stragglers).

    On TPU pods a straggling host stalls the whole program at the next
    collective; the watchdog turns that stall into a logged, attributable
    event so the scheduler can evict/replace the host.
    """

    def __init__(self, factor: float = 3.0, window: int = 50,
                 warmup: int = 3):
        self.factor = factor
        self.window = window
        self.warmup = warmup
        self.durations: list[float] = []
        self.events: list[StragglerEvent] = []
        self._t0: Optional[float] = None
        self._step = 0

    def start_step(self, step: int):
        self._step = step
        self._t0 = time.monotonic()

    def end_step(self) -> Optional[StragglerEvent]:
        if self._t0 is None:
            return None
        dur = time.monotonic() - self._t0
        ev = None
        if len(self.durations) >= self.warmup:
            med = statistics.median(self.durations[-self.window:])
            if dur > self.factor * med:
                ev = StragglerEvent(self._step, dur, med)
                self.events.append(ev)
        self.durations.append(dur)
        return ev

    def observe(self, duration_s: float, step: int = -1):
        """Record an externally-measured duration (tests)."""
        self.start_step(step)
        self._t0 = time.monotonic() - duration_s
        return self.end_step()


def restart_loop(run_fn: Callable[[Optional[int]], int], *,
                 max_restarts: int = 3,
                 on_restart: Optional[Callable[[int, BaseException], None]]
                 = None) -> int:
    """Run ``run_fn(resume_step)`` to completion with crash recovery.

    ``run_fn`` returns the final step on success and raises on failure;
    it must itself restore state from the latest checkpoint when
    ``resume_step`` is not None. Returns the final step.
    """
    resume: Optional[int] = None
    for attempt in range(max_restarts + 1):
        try:
            return run_fn(resume)
        except (SimulatedFailure, RuntimeError) as e:  # noqa: PERF203
            if attempt == max_restarts:
                raise
            if on_restart:
                on_restart(attempt, e)
            resume = -1   # sentinel: "restore from latest"
    raise AssertionError("unreachable")
