"""LM substrate: layers, attention, MoE, SSM, and model assembly."""
from .attention import KVCache, chunked_attention, decode_attention
from .layers import ParamBuilder, policy_matmul, rms_norm
from .transformer import (DecodeState, decode_step, forward_train,
                          init_decode_state, init_model, prefill)

__all__ = ["KVCache", "chunked_attention", "decode_attention",
           "ParamBuilder", "policy_matmul", "rms_norm", "DecodeState",
           "decode_step", "forward_train", "init_decode_state",
           "init_model", "prefill"]
