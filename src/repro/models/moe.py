"""Mixture-of-Experts FFN (qwen3-style: top-k of E experts, gated SiLU).

Dispatch is GShard-style with a *per-batch-row group*: token positions are
assigned a slot inside their expert's capacity buffer by a cumulative sum
over the row, then scattered into an ``(B, E, C, d)`` buffer. Expert
einsums contract over the buffer; with ``experts -> model`` sharding the
scatter/gather lower to the expert-parallel all-to-alls.

Overflowing tokens (position >= capacity) are dropped — their combine
weight is zero — which keeps every shape static. Router runs in fp32
(precision-critical softmax; the Ozaki policy covers it when enabled).

Aux losses (load-balance + router-z) are returned for the trainer.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain

from .layers import ParamBuilder, policy_matmul


class MoEOut(NamedTuple):
    y: jax.Array
    load_balance_loss: jax.Array
    router_z_loss: jax.Array


def init_moe(pb: ParamBuilder, d_model: int, num_experts: int,
             d_ff_expert: int) -> None:
    pb.dense("router", (d_model, num_experts), ("embed", "experts"))
    pb.dense("wi", (num_experts, d_model, 2 * d_ff_expert),
             ("experts", "embed", "expert_mlp"))
    pb.dense("wo", (num_experts, d_ff_expert, d_model),
             ("experts", "expert_mlp", "embed"))


def capacity_of(tokens_per_group: int, num_experts: int, top_k: int,
                capacity_factor: float) -> int:
    c = math.ceil(tokens_per_group * top_k / num_experts * capacity_factor)
    return max(c, 1)


def moe_ffn(cfg, params, x: jax.Array) -> MoEOut:
    """x: (batch, seq, d_model) -> MoEOut with y the same shape."""
    mc = cfg.moe
    b, s, d = x.shape
    e, k = mc.num_experts, mc.top_k
    cap = capacity_of(s, e, k, mc.capacity_factor)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, k)             # (b, s, k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)

    # --- slot assignment within each batch-row group ---------------------
    flat_idx = idx.reshape(b, s * k)                   # priority: seq-major
    onehot = jax.nn.one_hot(flat_idx, e, dtype=jnp.int32)      # (b, s*k, e)
    pos = jnp.cumsum(onehot, axis=1) - 1               # slot per assignment
    pos = jnp.sum(pos * onehot, axis=-1)               # (b, s*k)
    keep = pos < cap
    pos3 = pos.reshape(b, s, k)
    keep3 = keep.reshape(b, s, k)

    # --- dispatch: scatter tokens into (b, e, cap, d) ---------------------
    # one scatter per top-k slot: materializing the k-fold token repeat
    # costs 8x the hidden state at 32k prefill (observed 2.1 GB/buffer)
    def scatter_row(xr, er, pr, kr):
        buf = jnp.zeros((e, cap, d), x.dtype)
        for j in range(k):
            slot = jnp.where(kr[:, j], pr[:, j], cap)  # dropped -> OOB
            buf = buf.at[er[:, j], slot].add(xr, mode="drop")
        return buf

    buf = jax.vmap(scatter_row)(x, idx, pos3, keep3)
    buf = constrain(buf, ("batch", "experts", None, None))

    # --- expert FFN (gated SiLU), experts sharded over "model" -----------
    cdt = jnp.dtype(cfg.compute_dtype)
    adt = jnp.dtype(getattr(cfg, "accum_dtype", "float32"))
    h = jnp.einsum("becd,edf->becf", buf.astype(cdt),
                   params["wi"].astype(cdt),
                   preferred_element_type=adt)
    h = constrain(h, ("batch", "experts", None, None))
    gate, up = jnp.split(h, 2, axis=-1)
    h = (jax.nn.silu(gate) * up).astype(cdt)
    out = jnp.einsum("becf,efd->becd", h, params["wo"].astype(cdt),
                     preferred_element_type=adt).astype(x.dtype)
    out = constrain(out, ("batch", "experts", None, None))

    # --- combine: gather slots back, weighted sum over k ------------------
    def gather_row(br, er, pr, wr, kr):
        acc = jnp.zeros((s, d), x.dtype)
        for j in range(k):
            yj = br[er[:, j], jnp.minimum(pr[:, j], cap - 1)]
            acc = acc + yj * (wr[:, j] * kr[:, j])[:, None].astype(x.dtype)
        return acc

    y = jax.vmap(gather_row)(out, idx, pos3, weights,
                             keep3.astype(jnp.float32))

    # --- aux losses --------------------------------------------------------
    frac_tokens = jnp.mean(
        jax.nn.one_hot(idx, e, dtype=jnp.float32), axis=(0, 1, 2))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    lb = e * jnp.sum(frac_tokens * frac_probs) * mc.load_balance_coef
    zl = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * mc.router_z_coef
    return MoEOut(y, lb, zl)
