"""Model assembly for every assigned architecture family.

One shared decoder skeleton covers dense / moe / vlm / audio; the ssm
family is a Mamba1 stack; hybrid (zamba2) is a Mamba2 stack with ONE
shared attention(+MLP) block applied every ``hybrid_attn_period`` layers.

Layers are *stacked* (leading dim = num_layers) and executed with
``lax.scan`` (+ optional ``jax.checkpoint`` per block), which keeps
lowering/compile time flat in depth — required for the 80-94-layer
dry-run cells.

Entry points (all pure functions of (cfg, params, ...)):
  init_model         -> (params, axes)           axes = logical names
  forward_train      -> (logits, aux_loss)
  init_decode_state  -> DecodeState (cache pytree; abstract-eval friendly)
  prefill            -> (state, last_logits)
  decode_step        -> (logits, state)
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain

from . import ssm as ssm_mod
from .attention import (KVCache, cache_update, chunked_attention,
                        decode_attention, init_cache)
from .layers import (ParamBuilder, apply_rope, embed_lookup, policy_matmul,
                     rms_norm, rope_frequencies, softcap)
from .moe import init_moe, moe_ffn


class DecodeState(NamedTuple):
    pos: jax.Array                 # scalar int32: tokens already in cache
    kv: Optional[KVCache]          # stacked (L, b, max_len, hkv, hd)
    ssm: Optional[ssm_mod.SSMState]  # stacked (L, ...)
    hybrid_kv: Optional[KVCache]   # (n_apps, b, max_len, hkv, hd)


# ----------------------------------------------------------------------------
# init
# ----------------------------------------------------------------------------

def _init_attn(pb: ParamBuilder, cfg) -> None:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    pb.dense("wq", (d, h * hd), ("embed", "heads_flat"))
    pb.dense("wk", (d, kv * hd), ("embed", "kv_flat"))
    pb.dense("wv", (d, kv * hd), ("embed", "kv_flat"))
    pb.dense("wo", (h * hd, d), ("heads_flat", "embed"))


def _init_mlp(pb: ParamBuilder, d: int, ff: int) -> None:
    pb.dense("wi", (d, 2 * ff), ("embed", "mlp2"))
    pb.dense("wo", (ff, d), ("mlp", "embed"))


def _init_block(cfg, key) -> tuple[Any, Any]:
    pb = ParamBuilder(key, jnp.dtype(cfg.param_dtype))
    pb.ones("ln1", (cfg.d_model,), ("embed",))
    if cfg.family == "ssm":
        sub = pb.child("mamba")
        ssm_mod.init_mamba1(sub, cfg.d_model, cfg.ssm.d_state,
                            cfg.ssm.d_conv, cfg.ssm.expand)
        return pb.build()
    if cfg.family == "hybrid":
        sub = pb.child("mamba")
        ssm_mod.init_mamba2(sub, cfg.d_model, cfg.ssm.d_state,
                            cfg.ssm.d_conv, cfg.ssm.expand, cfg.ssm.headdim)
        return pb.build()
    _init_attn(pb.child("attn"), cfg)
    pb.ones("ln2", (cfg.d_model,), ("embed",))
    if cfg.moe is not None:
        init_moe(pb.child("moe"), cfg.d_model, cfg.moe.num_experts,
                 cfg.moe.d_ff_expert)
    else:
        _init_mlp(pb.child("mlp"), cfg.d_model, cfg.d_ff)
    return pb.build()


def init_model(cfg, key) -> tuple[Any, Any]:
    pb = ParamBuilder(key, jnp.dtype(cfg.param_dtype))
    if cfg.frontend == "audio":
        pb.dense("embed", (cfg.num_codebooks, cfg.vocab_size, cfg.d_model),
                 ("codebooks", "vocab", "embed"), scale=0.02)
    else:
        pb.dense("embed", (cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                 scale=0.02)

    block_keys = jax.random.split(pb._next_key(), cfg.num_layers)
    axes_box = {}

    def _params_only(k):
        p, a = _init_block(cfg, k)
        axes_box["axes"] = a
        return p

    jax.eval_shape(_params_only, block_keys[0])   # captures axes, no alloc
    sample_axes = axes_box["axes"]
    blocks = jax.vmap(_params_only)(block_keys)
    pb.params["blocks"] = blocks
    pb.axes["blocks"] = jax.tree.map(
        lambda a: ("layers",) + a, sample_axes,
        is_leaf=lambda a: isinstance(a, tuple))

    if cfg.family == "hybrid":
        shared = pb.child("shared_attn")
        shared.ones("ln1", (cfg.d_model,), ("embed",))
        _init_attn(shared.child("attn"), cfg)
        shared.ones("ln2", (cfg.d_model,), ("embed",))
        _init_mlp(shared.child("mlp"), cfg.d_model, cfg.d_ff)

    pb.ones("final_norm", (cfg.d_model,), ("embed",))
    if not cfg.tie_embeddings:
        if cfg.frontend == "audio":
            pb.dense("unembed", (cfg.num_codebooks, cfg.d_model,
                                 cfg.vocab_size),
                     ("codebooks", "embed", "vocab"))
        else:
            pb.dense("unembed", (cfg.d_model, cfg.vocab_size),
                     ("embed", "vocab"))
    return pb.build()


# ----------------------------------------------------------------------------
# per-layer pieces
# ----------------------------------------------------------------------------

def _attention(cfg, p, x, positions, *, is_local, cache=None,
               write_slice=None):
    """Attention sub-block (pre-norm + residual).

    is_local: traced bool (or python bool) — sliding window active.
    cache: KVCache for decode; write_slice: (cache, start) for prefill.
    """
    b, s, d = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    y = rms_norm(x, p["ln1"], cfg.norm_eps)
    q = policy_matmul(cfg, y, p["wq"]).reshape(b, s, h, hd)
    k = policy_matmul(cfg, y, p["wk"]).reshape(b, s, kvh, hd)
    v = policy_matmul(cfg, y, p["wv"]).reshape(b, s, kvh, hd)
    if s > 1:  # decode (s == 1) replicates q: see decode_attention
        q = constrain(q, ("batch", None, "heads", None))
        k = constrain(k, ("batch", None, None, None))
        v = constrain(v, ("batch", None, None, None))

    if cfg.rope_style != "none":
        rd = hd // 2 if cfg.rope_style == "partial2d" else hd
        cos, sin = rope_frequencies(hd, cfg.rope_theta, positions,
                                    rotary_dim=rd)
        q = apply_rope(q, cos, sin, cfg.rope_style)
        k = apply_rope(k, cos, sin, cfg.rope_style)

    window = jnp.where(is_local, cfg.sliding_window, 0) \
        if cfg.sliding_window else 0

    new_cache = cache
    if cache is not None and s == 1:                      # decode
        pos = positions[:, 0] if positions.shape[0] > 1 \
            else jnp.reshape(positions, (-1,))[0]
        new_cache = cache_update(cache, k, v, pos)
        out = decode_attention(q, new_cache, pos + 1, window=window,
                               softcap=cfg.attn_logit_softcap)
    else:                                                 # train / prefill
        out = chunked_attention(q, k, v, causal=True, window=window,
                                softcap=cfg.attn_logit_softcap)
        if write_slice is not None:
            cache, start = write_slice
            kc = jax.lax.dynamic_update_slice(
                cache.k, k.astype(cache.k.dtype), (0, start, 0, 0))
            vc = jax.lax.dynamic_update_slice(
                cache.v, v.astype(cache.v.dtype), (0, start, 0, 0))
            new_cache = KVCache(kc, vc)
    proj = policy_matmul(cfg, out.reshape(b, s, h * hd), p["wo"])
    proj = constrain(proj, ("batch", "seq", None))
    return x + proj.astype(x.dtype), new_cache


def _mlp(cfg, p, x):
    y = rms_norm(x, p["ln2"] if "ln2" in p else p["ln1"], cfg.norm_eps)
    gate_up = constrain(policy_matmul(cfg, y, p["mlp"]["wi"]),
                        ("batch", "seq", "mlp2"))
    gate, up = jnp.split(gate_up, 2, axis=-1)
    out = policy_matmul(cfg, (jax.nn.silu(gate) * up).astype(x.dtype),
                        p["mlp"]["wo"])
    out = constrain(out, ("batch", "seq", None))
    return x + out.astype(x.dtype)


def _ffn(cfg, p, x):
    """MLP or MoE, returns (x, aux_loss)."""
    if cfg.moe is not None:
        y = rms_norm(x, p["ln2"], cfg.norm_eps)
        out = moe_ffn(cfg, p["moe"], y)
        return x + out.y, out.load_balance_loss + out.router_z_loss
    return _mlp(cfg, p, x), jnp.float32(0.0)


# ----------------------------------------------------------------------------
# backbone scans (one per family group)
# ----------------------------------------------------------------------------

def _layer_flags(cfg) -> jax.Array:
    """is_local per layer (gemma2: even layers local, odd global)."""
    idx = jnp.arange(cfg.num_layers)
    if cfg.sliding_window and cfg.local_global_period:
        return (idx % cfg.local_global_period) == 0
    return jnp.zeros((cfg.num_layers,), bool) | bool(cfg.sliding_window)


def _scan_decoder(cfg, params, x, positions, kv_stack, write_start):
    """Standard decoder stack. kv_stack None (train) or stacked caches.

    Caches ride in the scan CARRY with in-place indexed updates — as
    xs/ys XLA double-buffers the full stack (2x cache HBM, observed on
    the decode_32k dry-runs); as a donated carry it updates in place.
    """
    flags = _layer_flags(cfg)

    if kv_stack is None:
        def body(carry, xs):
            p, is_local = xs
            hx, _ = _attention(cfg, p["attn"] | {"ln1": p["ln1"]}, carry,
                               positions, is_local=is_local)
            hx, aux = _ffn(cfg, p, hx)
            return hx, aux

        fn = jax.checkpoint(body) if cfg.remat else body
        x, aux = jax.lax.scan(fn, x, (params["blocks"], flags))
        return x, jnp.sum(aux), None

    def body(carry, xs):
        hx, kv = carry
        p, is_local, li = xs
        cache = KVCache(jax.lax.dynamic_index_in_dim(kv.k, li, 0, False),
                        jax.lax.dynamic_index_in_dim(kv.v, li, 0, False))
        if write_start is None:
            hx, nc = _attention(cfg, p["attn"] | {"ln1": p["ln1"]}, hx,
                                positions, is_local=is_local, cache=cache)
        else:
            hx, nc = _attention(cfg, p["attn"] | {"ln1": p["ln1"]}, hx,
                                positions, is_local=is_local,
                                write_slice=(cache, write_start))
        kv = KVCache(
            jax.lax.dynamic_update_index_in_dim(kv.k, nc.k, li, 0),
            jax.lax.dynamic_update_index_in_dim(kv.v, nc.v, li, 0))
        hx, aux = _ffn(cfg, p, hx)
        return (hx, kv), aux

    (x, new_kv), aux = jax.lax.scan(
        body, (x, kv_stack),
        (params["blocks"], flags, jnp.arange(cfg.num_layers)))
    return x, jnp.sum(aux), new_kv


def _scan_ssm(cfg, params, x, ssm_stack):
    def body(carry, xs):
        hx = carry
        if ssm_stack is None:
            p = xs
            y = rms_norm(hx, p["ln1"], cfg.norm_eps)
            out, _ = ssm_mod.mamba1_block(cfg, p["mamba"], y)
            return hx + out, None
        p, st = xs
        y = rms_norm(hx, p["ln1"], cfg.norm_eps)
        out, new_st = ssm_mod.mamba1_block(cfg, p["mamba"], y, st)
        return hx + out, new_st

    fn = jax.checkpoint(body) if cfg.remat else body
    xs = params["blocks"] if ssm_stack is None else \
        (params["blocks"], ssm_stack)
    x, new_states = jax.lax.scan(fn, x, xs)
    return x, jnp.float32(0.0), new_states


def _scan_hybrid(cfg, params, x, positions, ssm_stack, kv_apps,
                 write_start):
    """zamba2: mamba2 stack; the SHARED attention block fires before
    layers 0, p, 2p, ... (p = hybrid_attn_period).

    Structure: a python loop over the attention applications (static
    cache indices -> clean in-place updates; a traced ``lax.cond`` here
    copies the full KV stack per layer — observed 4x cache memory on the
    long_500k dry-run), with a ``lax.scan`` over each mamba2 segment
    between applications.
    """
    period = cfg.hybrid_attn_period
    n_layers = cfg.num_layers
    shared = params["shared_attn"]
    sp = shared["attn"] | {"ln1": shared["ln1"]}

    def segment(lo, hi, x, states_seg):
        seg_params = jax.tree.map(lambda t: t[lo:hi], params["blocks"])

        def body(carry, xs):
            hx = carry
            if states_seg is None:
                p = xs
                st = None
            else:
                p, st = xs
            y = rms_norm(hx, p["ln1"], cfg.norm_eps)
            out, new_st = ssm_mod.mamba2_block(cfg, p["mamba"], y, st)
            return hx + out, new_st

        if cfg.remat and states_seg is None:
            # hierarchical remat: checkpoint the WHOLE segment (saves one
            # residual per segment, not per layer) + per-layer checkpoint
            # inside — 6x fewer saved activations for ~1 extra forward
            fn = jax.checkpoint(body)

            def run(x):
                return jax.lax.scan(fn, x, seg_params)

            return jax.checkpoint(run)(x)
        xs = seg_params if states_seg is None else (seg_params, states_seg)
        return jax.lax.scan(body, x, xs)

    def shared_train_block(x):
        hx, _ = _attention(cfg, sp, x, positions, is_local=False)
        return _mlp(cfg, shared, hx)

    if cfg.remat:
        shared_train_block = jax.checkpoint(shared_train_block)

    new_state_segs = []
    for a, lo in enumerate(range(0, n_layers, period)):
        hi = min(lo + period, n_layers)
        # shared attention application #a (static cache row)
        if kv_apps is None:
            x = shared_train_block(x)
        else:
            cache = KVCache(kv_apps.k[a], kv_apps.v[a])
            if write_start is None:
                x, nc = _attention(cfg, sp, x, positions, is_local=False,
                                   cache=cache)
            else:
                x, nc = _attention(cfg, sp, x, positions, is_local=False,
                                   write_slice=(cache, write_start))
            kv_apps = KVCache(kv_apps.k.at[a].set(nc.k),
                              kv_apps.v.at[a].set(nc.v))
            x = _mlp(cfg, shared, x)
        seg_states = None if ssm_stack is None else \
            jax.tree.map(lambda t: t[lo:hi], ssm_stack)
        x, new_seg = segment(lo, hi, x, seg_states)
        new_state_segs.append(new_seg)

    new_states = None
    if ssm_stack is not None:
        new_states = jax.tree.map(
            lambda *segs: jnp.concatenate(segs, axis=0), *new_state_segs)
    return x, kv_apps, new_states


def _hybrid_apps(cfg) -> int:
    return -(-cfg.num_layers // cfg.hybrid_attn_period)


# ----------------------------------------------------------------------------
# embedding / logits
# ----------------------------------------------------------------------------

def _embed(cfg, params, batch) -> jax.Array:
    cdt = jnp.dtype(cfg.compute_dtype)
    tokens = batch["tokens"]
    if cfg.frontend == "audio":
        # tokens: (b, s, nq); sum one embedding per codebook
        parts = [embed_lookup(params["embed"][i], tokens[..., i], cdt)
                 for i in range(cfg.num_codebooks)]
        return functools.reduce(jnp.add, parts)
    x = embed_lookup(params["embed"], tokens, cdt)
    if cfg.frontend == "vision" and "patch_embeds" in batch:
        x = jnp.concatenate(
            [batch["patch_embeds"].astype(cdt), x], axis=1)
    return constrain(x, ("batch", "seq", None))


def _logits(cfg, params, x) -> jax.Array:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.frontend == "audio":
        w = params["unembed"]                   # (nq, d, V)
        out = jnp.stack([policy_matmul(cfg, x, w[i])
                         for i in range(cfg.num_codebooks)], axis=-2)
        out = constrain(out, ("batch", None, None, "vocab"))
    elif cfg.tie_embeddings:
        out = constrain(policy_matmul(cfg, x, params["embed"].T),
                        ("batch", None, "vocab"))
    else:
        out = constrain(policy_matmul(cfg, x, params["unembed"]),
                        ("batch", None, "vocab"))
    return softcap(out.astype(jnp.float32), cfg.final_logit_softcap)


# ----------------------------------------------------------------------------
# entry points
# ----------------------------------------------------------------------------

def forward_train(cfg, params, batch):
    """-> (logits f32, aux_loss). Logits cover the full (padded) sequence."""
    x = _embed(cfg, params, batch)
    positions = jnp.arange(x.shape[1])[None, :]
    aux = jnp.float32(0.0)
    if cfg.family == "ssm":
        x, aux, _ = _scan_ssm(cfg, params, x, None)
    elif cfg.family == "hybrid":
        x, _, _ = _scan_hybrid(cfg, params, x, positions, None, None, None)
    else:
        x, aux, _ = _scan_decoder(cfg, params, x, positions, None, None)
    return _logits(cfg, params, x), aux


def init_decode_state(cfg, batch: int, max_len: int,
                      dtype=jnp.bfloat16,
                      per_row_pos: bool = False) -> DecodeState:
    kv = ssm_st = hyb = None
    pos0 = jnp.zeros((batch,), jnp.int32) if per_row_pos else jnp.int32(0)
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        kv = jax.vmap(lambda _: init_cache(
            batch, max_len, cfg.num_kv_heads, cfg.head_dim, dtype))(
            jnp.arange(cfg.num_layers))
    elif cfg.family == "ssm":
        ssm_st = jax.vmap(lambda _: ssm_mod.init_ssm_state(
            cfg, batch, cfg.ssm.variant))(jnp.arange(cfg.num_layers))
    elif cfg.family == "hybrid":
        ssm_st = jax.vmap(lambda _: ssm_mod.init_ssm_state(
            cfg, batch, "mamba2"))(jnp.arange(cfg.num_layers))
        hyb = jax.vmap(lambda _: init_cache(
            batch, max_len, cfg.num_kv_heads, cfg.head_dim, dtype))(
            jnp.arange(_hybrid_apps(cfg)))
    return DecodeState(pos0, kv, ssm_st, hyb)


def prefill(cfg, params, batch, state: DecodeState):
    """Run the prompt, fill caches, return (state, last-position logits)."""
    x = _embed(cfg, params, batch)
    s = x.shape[1]
    positions = jnp.arange(s)[None, :]
    if cfg.family == "ssm":
        x, _, new_ssm = _scan_ssm(cfg, params, x, state.ssm)
        state = state._replace(ssm=new_ssm, pos=jnp.int32(s))
    elif cfg.family == "hybrid":
        x, new_kv, new_ssm = _scan_hybrid(cfg, params, x, positions,
                                          state.ssm, state.hybrid_kv, 0)
        state = state._replace(ssm=new_ssm, hybrid_kv=new_kv,
                               pos=jnp.int32(s))
    else:
        x, _, new_kv = _scan_decoder(cfg, params, x, positions, state.kv, 0)
        state = state._replace(kv=new_kv, pos=jnp.int32(s))
    return state, _logits(cfg, params, x[:, -1:, :])[:, 0]


def decode_step(cfg, params, state: DecodeState, tokens):
    """One token for every sequence. tokens: (b, 1) (audio: (b, 1, nq)).

    ``state.pos`` may be a scalar (uniform batch) or a (b,) vector of
    per-slot cursors (continuous batching).
    """
    x = _embed(cfg, params, {"tokens": tokens})
    positions = jnp.reshape(jnp.asarray(state.pos), (-1, 1)).astype(jnp.int32)
    if cfg.family == "ssm":
        x, _, new_ssm = _scan_ssm(cfg, params, x, state.ssm)
        state = state._replace(ssm=new_ssm, pos=state.pos + 1)
    elif cfg.family == "hybrid":
        x, new_kv, new_ssm = _scan_hybrid(cfg, params, x, positions,
                                          state.ssm, state.hybrid_kv, None)
        state = state._replace(ssm=new_ssm, hybrid_kv=new_kv,
                               pos=state.pos + 1)
    else:
        x, _, new_kv = _scan_decoder(cfg, params, x, positions, state.kv,
                                     None)
        state = state._replace(kv=new_kv, pos=state.pos + 1)
    return _logits(cfg, params, x)[:, 0], state
