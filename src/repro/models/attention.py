"""Attention: chunked (flash-style) training/prefill path + decode path.

Layout choice (DESIGN.md §7): everything runs in the *query-head* layout
(b, s, h, d) with KV broadcast to query heads by a static gather
(``kv_index = arange(h) // group``). Tensor parallelism then shards the
``h`` dim over the "model" mesh axis — under that sharding the gather
reads only the local heads' KV, logits/softmax/AV stay local, and no
attention collective is emitted. Archs whose 24 heads don't divide the
16-way axis compile with GSPMD padding (25% attention-only overhead,
recorded in the roofline table; the grouped-KV alternative pads 2-8x).

The chunked path tiles BOTH query and key/value: an outer ``lax.map``
over query blocks, an inner ``lax.scan`` over KV blocks with an online
softmax. Per-layer live memory is O(q_block x kv_block) logits — the
32k-prefill fit depends on this. ``window`` may be a *traced* scalar so
gemma2's local/global alternation works inside a layer scan.

Baseline computes the full rectangular block grid with masking (2x the
causal-optimal FLOPs at long seq); ``fold_causal=True`` recovers the
triangle: query blocks are processed in pairs (i, n-1-i), every pair
visiting exactly n+1 KV blocks — uniform static work per scan step,
triangle FLOPs total (§Perf optimization O6).

Decode path: one query position against a full KV cache, which is
sequence-sharded over "model" (flash-decoding): each chip scores its
cache shard, and softmax over the sharded axis lowers to two small
all-reduces (max + denominator).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain

NEG_INF = -1e30


class KVCache(NamedTuple):
    """Decode cache for one attention block application.

    k, v: (batch, max_len, kv_heads, head_dim)
    """

    k: jax.Array
    v: jax.Array


def broadcast_kv(k: jax.Array, num_heads: int) -> jax.Array:
    """(b, s, hkv, d) -> (b, s, h, d) by the static head map."""
    hkv = k.shape[2]
    if hkv == num_heads:
        return k
    idx = jnp.arange(num_heads) // (num_heads // hkv)
    return jnp.take(k, idx, axis=2)


def _bias_block(q_pos, k_pos, *, causal: bool, window, valid_len):
    """(q_blk, k_blk) additive f32 bias from absolute positions.

    window / valid_len may be traced scalars (0 / huge => inactive).
    """
    rel = q_pos[:, None] - k_pos[None, :]
    ok = k_pos[None, :] < valid_len
    if causal:
        ok &= rel >= 0
    win = jnp.asarray(window)
    ok &= (win <= 0) | (rel < win)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window=0,
                      softcap: float = 0.0, q_block: int = 1024,
                      kv_block: int = 512,
                      fold_causal: bool = False) -> jax.Array:
    """q: (b, sq, h, d); k, v: (b, skv, hkv, d) -> (b, sq, h, d)."""
    h = q.shape[2]
    k = constrain(broadcast_kv(k, h), ("batch", None, "heads", None))
    v = constrain(broadcast_kv(v, h), ("batch", None, "heads", None))
    if fold_causal and causal:
        return _folded_causal_attention(q, k, v, window=window,
                                        softcap=softcap, q_block=q_block,
                                        kv_block=kv_block)
    b, sq, h, d = q.shape
    _, skv, _, _ = k.shape
    qb = min(q_block, sq)
    kb = min(kv_block, skv)
    nqb, nkb = -(-sq // qb), -(-skv // kb)
    qpad, kpad = nqb * qb - sq, nkb * kb - skv
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0)))
    if kpad:
        k = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0)))
    scale = d ** -0.5
    qs = (q.astype(jnp.float32) * scale).reshape(b, nqb, qb, h, d)
    qs = qs.swapaxes(0, 1)                        # (nqb, b, qb, h, d)
    ks = k.reshape(b, nkb, kb, h, d).swapaxes(0, 1).astype(jnp.float32)
    vs = v.reshape(b, nkb, kb, h, d).swapaxes(0, 1).astype(jnp.float32)

    def one_q_block(inp):
        qi, qf = inp                              # scalar idx, (b,qb,h,d)
        q_pos = qi * qb + jnp.arange(qb)

        def body(carry, kin):
            acc, m, l = carry
            kb_arr, vb_arr, ki = kin
            k_pos = ki * kb + jnp.arange(kb)
            s = jnp.einsum("bqhd,bkhd->bhqk", qf, kb_arr)
            s = constrain(s, ("batch", "heads", None, None))
            if softcap:
                s = jnp.tanh(s / softcap) * softcap
            bias = _bias_block(q_pos, k_pos, causal=causal, window=window,
                               valid_len=skv)
            s = s + bias[None, None]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vb_arr)
            return (acc, m_new, l_new), None

        acc0 = jnp.zeros((b, h, qb, d), jnp.float32)
        m0 = jnp.full((b, h, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, qb), jnp.float32)
        (acc, _, l), _ = jax.lax.scan(
            body, (acc0, m0, l0), (ks, vs, jnp.arange(nkb)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.swapaxes(1, 2).astype(q.dtype)  # (b, qb, h, d)

    outs = jax.lax.map(one_q_block, (jnp.arange(nqb), qs))
    out = outs.swapaxes(0, 1).reshape(b, nqb * qb, h, d)
    return out[:, :sq]


def _folded_causal_attention(q, k, v, *, window, softcap, q_block,
                             kv_block):
    """Causal attention at triangle FLOPs with static shapes (§Perf O6).

    Query blocks are paired (i, n-1-i). A pair needs KV blocks
    [0..i] + [0..n-1-i] — exactly n+1 block visits for every pair, so an
    inner scan of fixed length n+1 does uniform work with no masking
    waste beyond the diagonal blocks. k/v arrive pre-broadcast to query
    heads.
    """
    b, sq, h, d = q.shape
    _, skv, _, _ = k.shape
    blk = min(q_block, kv_block, sq, skv)
    n = -(-sq // blk)
    pad = n * blk - sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    if n % 2:                                     # odd: plain path
        return chunked_attention(q[:, :sq], k[:, :skv], v[:, :skv],
                                 causal=True, window=window,
                                 softcap=softcap, q_block=blk,
                                 kv_block=blk, fold_causal=False)
    scale = d ** -0.5
    qs = (q.astype(jnp.float32) * scale).reshape(b, n, blk, h, d)
    qs = qs.swapaxes(0, 1)
    ks = k.reshape(b, n, blk, h, d).swapaxes(0, 1).astype(jnp.float32)
    vs = v.reshape(b, n, blk, h, d).swapaxes(0, 1).astype(jnp.float32)

    def one_pair(pair_idx):
        i = pair_idx                              # first member
        j = n - 1 - pair_idx                      # second member
        qa = jax.lax.dynamic_index_in_dim(qs, i, 0, False)
        qb_ = jax.lax.dynamic_index_in_dim(qs, j, 0, False)

        def body(carry, t):
            (acc_a, m_a, l_a), (acc_b, m_b, l_b) = carry
            serve_a = t <= i
            kv_idx = jnp.where(serve_a, t, t - i - 1)
            kb_arr = jax.lax.dynamic_index_in_dim(ks, kv_idx, 0, False)
            vb_arr = jax.lax.dynamic_index_in_dim(vs, kv_idx, 0, False)
            qf = jnp.where(serve_a, qa, qb_)
            q_idx = jnp.where(serve_a, i, j)
            q_pos = q_idx * blk + jnp.arange(blk)
            k_pos = kv_idx * blk + jnp.arange(blk)
            s = jnp.einsum("bqhd,bkhd->bhqk", qf, kb_arr)
            s = constrain(s, ("batch", "heads", None, None))
            if softcap:
                s = jnp.tanh(s / softcap) * softcap
            s = s + _bias_block(q_pos, k_pos, causal=True, window=window,
                                valid_len=skv)[None, None]
            m, l, acc = (jnp.where(serve_a, m_a, m_b),
                         jnp.where(serve_a, l_a, l_b),
                         jnp.where(serve_a, acc_a, acc_b))
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vb_arr)
            a_state = (jnp.where(serve_a, acc_new, acc_a),
                       jnp.where(serve_a, m_new, m_a),
                       jnp.where(serve_a, l_new, l_a))
            b_state = (jnp.where(serve_a, acc_b, acc_new),
                       jnp.where(serve_a, m_b, m_new),
                       jnp.where(serve_a, l_b, l_new))
            return (a_state, b_state), None

        z = lambda: (jnp.zeros((b, h, blk, d), jnp.float32),
                     jnp.full((b, h, blk), NEG_INF, jnp.float32),
                     jnp.zeros((b, h, blk), jnp.float32))
        ((acc_a, _, l_a), (acc_b, _, l_b)), _ = jax.lax.scan(
            body, (z(), z()), jnp.arange(n + 1))
        oa = (acc_a / jnp.maximum(l_a[..., None], 1e-30)).swapaxes(1, 2)
        ob = (acc_b / jnp.maximum(l_b[..., None], 1e-30)).swapaxes(1, 2)
        return oa.astype(q.dtype), ob.astype(q.dtype)

    outs_a, outs_b = jax.lax.map(one_pair, jnp.arange(n // 2))
    out = jnp.concatenate([outs_a, outs_b[::-1]], axis=0)  # (n, b, blk,..)
    out = out.swapaxes(0, 1).reshape(b, n * blk, h, d)
    return out[:, :sq]


def decode_attention(q: jax.Array, cache: KVCache, kv_len, *,
                     window=0, softcap: float = 0.0) -> jax.Array:
    """One-token attention. q: (b, 1, h, d); cache holds (b, L, hkv, d).

    Flash-decoding under GSPMD: the cache is sequence-sharded, logits are
    constrained to the same sharding, and the softmax max/denominator
    reduce over the shard axis as two tiny all-reduces.

    ``kv_len``: valid cache length; scalar or (b,) per-slot cursors.
    The einsum keeps the cache dtype (bf16) with f32 accumulation — no
    f32 materialization of the cache.
    """
    b, _, h, d = q.shape
    _, max_len, hkv, _ = cache.k.shape
    g = h // hkv
    qf = (q.astype(jnp.float32) * d ** -0.5).reshape(b, hkv, g, d)
    # q must NOT stay head-sharded here: with the cache sequence-sharded,
    # a head-sharded q forces a cache-sized all-to-all (observed 195 GB
    # on the 500k cells). Replicate the tiny q instead.
    qf = constrain(qf, ("batch", None, None, None))
    s = jnp.einsum("bhgd,bkhd->bhgk", qf.astype(cache.k.dtype), cache.k,
                   preferred_element_type=jnp.float32)
    s = constrain(s, ("batch", "kv_heads", None, "kv_seq"))
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    kv_len = jnp.reshape(jnp.broadcast_to(jnp.asarray(kv_len), (b,)), (b, 1))
    pos = jnp.arange(max_len)[None, :]
    ok = pos < kv_len                                # (b, L)
    win = jnp.asarray(window)
    ok &= (win <= 0) | (pos >= kv_len - win)
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(cache.v.dtype), cache.v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, d).astype(q.dtype)


def cache_update(cache: KVCache, k_new: jax.Array, v_new: jax.Array,
                 pos) -> KVCache:
    """Write one position (b, 1, hkv, d) at ``pos`` (scalar or (b,)).

    One-hot select, not dynamic-update-slice: the cache is SHARDED along
    the sequence dim, and a DUS at a traced index there makes GSPMD
    all-gather the whole cache (observed 131 GiB peak on the 500k
    cells). The select is elementwise -> fully sharded; XLA fuses it
    into an in-place masked write of the donated buffer.
    """
    b, max_len = cache.k.shape[:2]
    pos = jnp.broadcast_to(jnp.asarray(pos), (b,))
    oh = (jnp.arange(max_len)[None, :] == pos[:, None])[..., None, None]
    k = jnp.where(oh, k_new.astype(cache.k.dtype), cache.k)
    v = jnp.where(oh, v_new.astype(cache.v.dtype), cache.v)
    return KVCache(k, v)


def init_cache(batch: int, max_len: int, kv_heads: int, head_dim: int,
               dtype=jnp.bfloat16) -> KVCache:
    z = jnp.zeros((batch, max_len, kv_heads, head_dim), dtype)
    return KVCache(z, z)
