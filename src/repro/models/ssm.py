"""State-space model blocks: Mamba1 (falcon-mamba) and Mamba2 SSD (zamba2).

TPU adaptation (DESIGN.md §2): the CUDA selective-scan kernel is replaced
by a *chunked* scan — a sequential ``lax.scan`` over sequence chunks whose
inner step is dense tensor algebra (VPU/MXU friendly), carrying the
(d_inner, d_state) recurrent state between chunks. The inner dimension is
sharded over the "model" mesh axis; the recurrence is elementwise in
d_inner, so the scan introduces no collectives.

Mamba2 uses the SSD chunked form: intra-chunk quadratic attention-like
term + inter-chunk state passing — the chunk matmuls are MXU-shaped,
which is the TPU-native formulation of the paper['s] SSD algorithm.

Decode: O(1) recurrent update per token, with a conv-tail cache.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain

from .layers import ParamBuilder


class SSMState(NamedTuple):
    """Decode-time cache for one SSM layer (leading dim = layers)."""

    conv: jax.Array   # (b, d_conv - 1, d_inner) trailing inputs
    h: jax.Array      # mamba1: (b, d_inner, N); mamba2: (b, nh, hd, N)


# ----------------------------------------------------------------------------
# shared pieces
# ----------------------------------------------------------------------------

def _causal_conv(x: jax.Array, w: jax.Array, tail: jax.Array | None = None):
    """Depthwise causal conv. x: (b, s, d), w: (dc, d). Returns (y, new_tail).

    ``tail``: (b, dc-1, d) inputs preceding x (decode carries this).
    """
    dc = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], dc - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(dc))
    return y, xp[:, -(dc - 1):, :]


def _softplus(x):
    return jax.nn.softplus(x)


# ----------------------------------------------------------------------------
# Mamba1 (falcon-mamba-7b)
# ----------------------------------------------------------------------------

def init_mamba1(pb: ParamBuilder, d_model: int, d_state: int, d_conv: int,
                expand: int, dt_rank: int | None = None) -> None:
    di = expand * d_model
    dtr = dt_rank or max(1, d_model // 16)
    pb.dense("in_proj", (d_model, 2 * di), ("embed", "inner"))
    pb.dense("conv_w", (d_conv, di), ("conv", "inner"), scale=0.5)
    pb.dense("x_proj", (di, dtr + 2 * d_state), ("inner", "ssm_misc"))
    pb.dense("dt_proj", (dtr, di), ("ssm_misc", "inner"))
    pb.zeros("dt_bias", (di,), ("inner",))
    pb.value("a_log", jnp.log(jnp.tile(
        jnp.arange(1, d_state + 1, dtype=jnp.float32), (di, 1))),
        ("inner", "state"))
    pb.ones("d_skip", (di,), ("inner",))
    pb.dense("out_proj", (di, d_model), ("inner", "embed"))


def _mamba1_core(params, xi, dt_rank: int, chunk: int):
    """Selective scan. xi: (b, s, di) post-conv. Returns (b, s, di)."""
    b, s, di = xi.shape
    n = params["a_log"].shape[1]
    proj = jnp.einsum("bsd,dm->bsm", xi, params["x_proj"])
    dt, bmat, cmat = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    dt = _softplus(jnp.einsum("bsr,rd->bsd", dt, params["dt_proj"])
                   + params["dt_bias"])                  # (b, s, di)
    a = -jnp.exp(params["a_log"])                        # (di, n)

    s_pad = -(-s // chunk) * chunk
    if s_pad != s:
        pad = ((0, 0), (0, s_pad - s), (0, 0))
        xi, dt, bmat, cmat = (jnp.pad(t, pad) for t in (xi, dt, bmat, cmat))
    nc = s_pad // chunk

    def to_chunks(t):
        return t.reshape(b, nc, chunk, -1).swapaxes(0, 1)

    xc, dtc, bc, cc = map(to_chunks, (xi, dt, bmat, cmat))

    def body(h, inp):
        xq, dq, bq, cq = inp                              # (b, Q, ...)
        da = jnp.exp(dq[..., None] * a[None, None])       # (b, Q, di, n)
        dbx = (dq * xq)[..., None] * bq[:, :, None, :]    # (b, Q, di, n)

        def step(hc, t):
            hc = da[:, t] * hc + dbx[:, t]
            return hc, jnp.einsum("bdn,bn->bd", hc, cq[:, t])

        h, ys = jax.lax.scan(step, h, jnp.arange(chunk))
        return h, ys.swapaxes(0, 1)                      # (b, Q, di)

    h0 = jnp.zeros((b, di, n), jnp.float32)
    h, ys = jax.lax.scan(body, h0, (xc.astype(jnp.float32),
                                    dtc.astype(jnp.float32),
                                    bc.astype(jnp.float32),
                                    cc.astype(jnp.float32)))
    y = ys.swapaxes(0, 1).reshape(b, s_pad, di)[:, :s]
    y = y.astype(xi.dtype) + xi[:, :s] * params["d_skip"].astype(xi.dtype)
    return y, h


def mamba1_block(cfg, params, x: jax.Array,
                 state: SSMState | None = None):
    """Full Mamba1 block. x: (b, s, d_model). Returns (y, new_state)."""
    sc = cfg.ssm
    dtr = max(1, cfg.d_model // 16)
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(x.dtype))
    xz = constrain(xz, ("batch", None, "inner"))
    xi, z = jnp.split(xz, 2, axis=-1)
    tail = state.conv if state is not None else None
    xi, new_tail = _causal_conv(xi, params["conv_w"].astype(x.dtype), tail)
    xi = jax.nn.silu(xi)
    if state is None or x.shape[1] > 1:
        y, new_h = _mamba1_core(params, xi, dtr, chunk=min(64, x.shape[1]))
    else:
        y, new_h = _mamba1_step(params, xi[:, 0], state.h, dtr)
        y = y[:, None]
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsd,de->bse", y, params["out_proj"].astype(x.dtype))
    if state is None:
        return out, None
    return out, SSMState(new_tail, new_h)


def _mamba1_step(params, xi, h, dt_rank: int):
    """One-token recurrence. xi: (b, di); h: (b, di, n)."""
    n = params["a_log"].shape[1]
    proj = xi.astype(jnp.float32) @ params["x_proj"]
    dt, bvec, cvec = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    dt = _softplus(dt @ params["dt_proj"] + params["dt_bias"])  # (b, di)
    a = -jnp.exp(params["a_log"])
    da = jnp.exp(dt[..., None] * a[None])                       # (b, di, n)
    h = da * h + (dt * xi)[..., None] * bvec[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, cvec) + \
        xi.astype(jnp.float32) * params["d_skip"]
    return y.astype(xi.dtype), h


# ----------------------------------------------------------------------------
# Mamba2 / SSD (zamba2-7b)
# ----------------------------------------------------------------------------

def init_mamba2(pb: ParamBuilder, d_model: int, d_state: int, d_conv: int,
                expand: int, headdim: int) -> None:
    di = expand * d_model
    nh = di // headdim
    pb.dense("in_proj", (d_model, 2 * di), ("embed", "inner"))
    pb.dense("conv_w", (d_conv, di), ("conv", "inner"), scale=0.5)
    pb.dense("bc_proj", (d_model, 2 * d_state), ("embed", "state"))
    pb.dense("dt_proj", (d_model, nh), ("embed", "heads"))
    pb.zeros("dt_bias", (nh,), ("heads",))
    pb.value("a_log", jnp.zeros((nh,), jnp.float32), ("heads",))
    pb.ones("d_skip", (nh,), ("heads",))
    pb.dense("out_proj", (di, d_model), ("inner", "embed"))


def _segsum_exp(da: jax.Array) -> jax.Array:
    """L[t, u] = prod_{u < r <= t} da_r for t >= u else 0.

    da: (..., Q). Returns (..., Q, Q) lower-triangular (inclusive diag).
    """
    q = da.shape[-1]
    logs = jnp.log(jnp.maximum(da, 1e-30))
    cs = jnp.cumsum(logs, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]           # sum_(u, t]
    tri = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(tri, jnp.exp(diff), 0.0)


def mamba2_core(params, xi: jax.Array, bmat, cmat, dt, headdim: int,
                chunk: int, h0=None):
    """SSD chunked scan. xi: (b, s, di); bmat/cmat: (b, s, n); dt: (b, s, nh).

    Returns (y (b, s, di), h_final (b, nh, hd, n)).
    """
    b, s, di = xi.shape
    nh = di // headdim
    n = bmat.shape[-1]
    a = -jnp.exp(params["a_log"])                        # (nh,)

    s_pad = -(-s // chunk) * chunk
    if s_pad != s:
        pad3 = ((0, 0), (0, s_pad - s), (0, 0))
        xi, bmat, cmat, dt = (jnp.pad(t, pad3) for t in (xi, bmat, cmat, dt))
    nc = s_pad // chunk
    xh = xi.reshape(b, nc, chunk, nh, headdim).swapaxes(0, 1)
    bq = bmat.reshape(b, nc, chunk, n).swapaxes(0, 1)
    cq = cmat.reshape(b, nc, chunk, n).swapaxes(0, 1)
    dq = dt.reshape(b, nc, chunk, nh).swapaxes(0, 1)

    def body(h, inp):
        xq, bqq, cqq, dqq = inp                          # per-chunk tensors
        da = jnp.exp(dqq * a[None, None])                # (b, Q, nh)
        # intra-chunk: Y = (L ⊙ (C B^T)) (dt X)
        l = _segsum_exp(da.swapaxes(1, 2))               # (b, nh, Q, Q)
        cb = jnp.einsum("bqn,bkn->bqk", cqq, bqq)        # (b, Q, Q)
        w = cb[:, None] * l                              # (b, nh, Q, Q)
        dx = dqq[..., None] * xq                         # (b, Q, nh, hd)
        y = jnp.einsum("bhqk,bkhd->bqhd", w, dx)
        # contribution of the carried state: C_t (prod da) h0
        dec = jnp.cumprod(da, axis=1)                    # (b, Q, nh)
        y = y + jnp.einsum("bqn,bhdn,bqh->bqhd", cqq, h, dec)
        # inter-chunk state update
        tot = dec[:, -1]                                 # (b, nh)
        rem = tot[:, None] / jnp.maximum(dec, 1e-30)     # prod_(t, Q]
        h = h * tot[..., None, None] + jnp.einsum(
            "bqn,bqhd,bqh->bhdn", bqq, dx, rem)
        return h, y

    if h0 is None:
        h0 = jnp.zeros((b, nh, headdim, n), jnp.float32)
    h, ys = jax.lax.scan(body, h0, (xh.astype(jnp.float32),
                                    bq.astype(jnp.float32),
                                    cq.astype(jnp.float32),
                                    dq.astype(jnp.float32)))
    y = ys.swapaxes(0, 1).reshape(b, s_pad, di)[:, :s]
    return y, h


def mamba2_block(cfg, params, x: jax.Array, state: SSMState | None = None):
    """Full Mamba2 block. x: (b, s, d_model). Returns (y, new_state)."""
    sc = cfg.ssm
    di = sc.expand * cfg.d_model
    nh = di // sc.headdim
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(x.dtype))
    xz = constrain(xz, ("batch", None, "inner"))
    xi, z = jnp.split(xz, 2, axis=-1)
    tail = state.conv if state is not None else None
    xi, new_tail = _causal_conv(xi, params["conv_w"].astype(x.dtype), tail)
    xi = jax.nn.silu(xi)
    bc = jnp.einsum("bsd,dn->bsn", x.astype(jnp.float32), params["bc_proj"])
    bmat, cmat = jnp.split(bc, 2, axis=-1)
    dt = _softplus(jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32),
                              params["dt_proj"]) + params["dt_bias"])
    if state is None or x.shape[1] > 1:
        y, h = mamba2_core(params, xi, bmat, cmat, dt, sc.headdim,
                           min(sc.chunk, x.shape[1]),
                           h0=None if state is None else state.h)
        new_h = h
    else:
        y, new_h = _mamba2_step(params, xi[:, 0], bmat[:, 0], cmat[:, 0],
                                dt[:, 0], state.h, sc.headdim)
        y = y[:, None]
    skip = jnp.repeat(params["d_skip"], sc.headdim)      # (di,)
    y = (y.astype(x.dtype) + xi * skip.astype(x.dtype)) * jax.nn.silu(z)
    out = jnp.einsum("bsd,de->bse", y, params["out_proj"].astype(x.dtype))
    if state is None:
        return out, None
    return out, SSMState(new_tail, new_h)


def _mamba2_step(params, xi, bvec, cvec, dt, h, headdim: int):
    """One-token SSD recurrence. xi: (b, di); h: (b, nh, hd, n)."""
    b, di = xi.shape
    nh = di // headdim
    a = -jnp.exp(params["a_log"])
    da = jnp.exp(dt * a[None])                           # (b, nh)
    xh = xi.reshape(b, nh, headdim).astype(jnp.float32)
    h = (h * da[..., None, None]
         + jnp.einsum("bn,bhd,bh->bhdn", bvec, xh, dt))
    y = jnp.einsum("bhdn,bn->bhd", h, cvec).reshape(b, di)
    return y.astype(xi.dtype), h


def init_ssm_state(cfg, batch: int, variant: str, dtype=None):
    """Conv tail lives in the compute dtype (a f32 tail would promote the
    whole post-conv stream and break bf16 scan carries); the recurrent
    state h stays f32 (precision of the recurrence)."""
    sc = cfg.ssm
    di = sc.expand * cfg.d_model
    conv = jnp.zeros((batch, sc.d_conv - 1, di),
                     dtype or jnp.dtype(cfg.compute_dtype))
    if variant == "mamba1":
        h = jnp.zeros((batch, di, sc.d_state), jnp.float32)
    else:
        h = jnp.zeros((batch, di // sc.headdim, sc.headdim, sc.d_state),
                      jnp.float32)
    return SSMState(conv, h)
