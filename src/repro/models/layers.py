"""Shared model layers with the Ozaki matmul-precision policy.

Every dense projection in the LM substrate goes through ``policy_matmul``,
which dispatches on ``ArchConfig.matmul_precision``:

  * ``bf16``       — cast to bf16, MXU matmul, f32 accumulation
                     (``preferred_element_type``): the TPU-native baseline.
  * ``int8_quant`` — per-channel symmetric int8 quantization of x and w,
                     int8 x int8 -> int32 MXU matmul, rescale. Lossy; this
                     is the inference mode the IMMUs were built for.
  * ``ozaki_fp64`` — the paper: error-free Ozaki splitting into int8
                     slices, exact int32 slice GEMMs, df32 accumulation.
                     FP64-accurate on hardware with no FP64 unit.

Parameters are created together with their *logical axis names*; the
parallel layer maps those to mesh axes (``repro.parallel.sharding``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

Params = Any        # nested dict of jnp arrays
Axes = Any          # matching nested dict of tuples of logical axis names


# ----------------------------------------------------------------------------
# Parameter creation with logical axes
# ----------------------------------------------------------------------------

@dataclasses.dataclass
class ParamBuilder:
    """Collects (params, axes) trees; init functions thread one through."""

    key: jax.Array
    dtype: Any = jnp.float32
    params: dict = dataclasses.field(default_factory=dict)
    axes: dict = dataclasses.field(default_factory=dict)

    def _next_key(self) -> jax.Array:
        self.key, sub = jax.random.split(self.key)
        return sub

    def dense(self, name: str, shape: tuple[int, ...], axes: tuple[str, ...],
              scale: Optional[float] = None):
        fan_in = shape[0]
        scale = (1.0 / fan_in) ** 0.5 if scale is None else scale
        self.params[name] = (jax.random.normal(self._next_key(), shape,
                                               self.dtype) * scale)
        self.axes[name] = axes

    def zeros(self, name: str, shape: tuple[int, ...], axes: tuple[str, ...]):
        self.params[name] = jnp.zeros(shape, self.dtype)
        self.axes[name] = axes

    def ones(self, name: str, shape: tuple[int, ...], axes: tuple[str, ...]):
        self.params[name] = jnp.ones(shape, self.dtype)
        self.axes[name] = axes

    def value(self, name: str, arr: jax.Array, axes: tuple[str, ...]):
        self.params[name] = arr.astype(self.dtype)
        self.axes[name] = axes

    def child(self, name: str) -> "ParamBuilder":
        sub = ParamBuilder(self._next_key(), self.dtype)
        self.params[name] = sub.params
        self.axes[name] = sub.axes
        return sub

    def build(self):
        return self.params, self.axes


# ----------------------------------------------------------------------------
# Precision-policy matmul
# ----------------------------------------------------------------------------

def _matmul_bf16(x, w, compute_dtype, accum_dtype=jnp.float32):
    return jax.lax.dot_general(
        x.astype(compute_dtype), w.astype(compute_dtype),
        dimension_numbers=(((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=accum_dtype)


def _matmul_int8_quant(x, w):
    """Per-channel symmetric int8 quantization, int32 MXU accumulation."""
    xs = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0 + 1e-30
    ws = jnp.max(jnp.abs(w), axis=0, keepdims=True) / 127.0 + 1e-30
    xq = jnp.clip(jnp.round(x / xs), -127, 127).astype(jnp.int8)
    wq = jnp.clip(jnp.round(w / ws), -127, 127).astype(jnp.int8)
    acc = jax.lax.dot_general(
        xq, wq, dimension_numbers=(((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * xs * ws


def _apply_cached_plan(cfg, x, w):
    """Fold the ambient plan cache's tuned plan into an OzakiConfig.

    Trace-time lookup (shapes are static under jit) against the cache
    the serving engine pre-warmed and scoped around the tick
    (``core.autotune.use_plan_cache``) — a miss, or no ambient cache,
    leaves the config untouched. The application rule is SHARED with
    ``repro.matmul`` (``api._apply_tuned_plan``): only the
    RESULT-INVARIANT plan fields apply (tile shapes and the
    stage/epilogue fusion flip, both bitwise-neutral per the
    backend-parity suite); num_splits and the accumulation schedule stay
    the model config's, so serving results are bit-identical with and
    without a cache.
    """
    from repro.api import _apply_tuned_plan
    from repro.core.autotune import active_plan_cache

    batch, m = (x.shape[0], x.shape[1]) if x.ndim == 3 else (1, x.shape[0])
    return _apply_tuned_plan(cfg, active_plan_cache(), m=m, n=w.shape[1],
                             k=w.shape[0], batch=batch)


def _matmul_ozaki(x, w, policy):
    """The paper's path: FP64-accurate x @ w out of int8 MXU GEMMs.

    ``policy`` is the ``repro.api.MatmulPolicy`` carrying every precision
    decision (backend, split count, fusion, accuracy target, fast mode,
    shard axis) — the one object that replaced the six per-knob kwargs
    this function used to take.

    x: (..., k) f32, w: (k, n) f32, deployable on TPU ({int8, int32, f32}
    only), f32 result rounded from df32. 3-D activations — the serving
    engine's (slots, seq, k) decode/prefill shape — go through
    ``ozaki_matmul_batched``'s broadcast-weights route (the batch folds
    into rows: ONE slice GEMM per anti-diagonal for the whole batch);
    other ranks flatten leading dims onto the df32 matmul directly.
    ``policy.shard_axis`` k-shards the contraction over the registered
    shard mesh (``parallel.ozaki_shard``) — a no-op when no mesh is
    active. ``policy.target_error`` / ``policy.fast_mode`` opt into
    accuracy-adaptive planning (``core.accuracy``): the driver resolves
    them into a reduced split count / truncated pair schedule per GEMM
    shape at trace time (shape-only, so the jitted step stays
    trace-stable).

    Sharding hints are applied ONLY to plain 2-D matmul calls, the path
    verified bitwise-safe under the constraints. Projections inside the
    transformer stack (3-D prefill AND decode shapes) run unsharded for
    now: sharding constraints inside the model's layer/attention scans
    produce wrong logits on the pinned jax version (an XLA SPMD
    numerical bug, reproduced with pure-XLA backends too — see
    ROADMAP.md). Pod-scale sharded serving of the GEMM itself goes
    through ``parallel.ozaki_shard.ozaki_matmul_kshard_auto``, which
    owns its jit and is bitwise-verified on the mesh.
    """
    from repro.core.ozaki import ozaki_matmul_batched, ozaki_matmul_dw
    from repro.core.xmath import DW, dw_to_single

    x = x.astype(jnp.float32)
    w = w.astype(jnp.float32)
    # INTERPRET follows the backend (policy.ozaki_config default):
    # interpret-mode on CPU validation hosts, Mosaic lowering on TPU.
    cfg = policy.ozaki_config(x.shape[-1], accum="df32")
    cfg = _apply_cached_plan(cfg, x, w)
    if x.ndim == 3:
        return ozaki_matmul_batched(x, w, cfg)
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    if policy.shard_axis and x.ndim == 2:      # plain 2-D matmuls only
        from repro.parallel.ozaki_shard import constrain_batched_kshard
        x2, w = constrain_batched_kshard(x2, w, policy.shard_axis)
    out = ozaki_matmul_dw(DW(x2, jnp.zeros_like(x2)),
                          DW(w.T, jnp.zeros_like(w.T)), cfg)
    return dw_to_single(out).reshape(*lead, w.shape[1])


def policy_matmul(cfg, x: jax.Array, w: jax.Array) -> jax.Array:
    """cfg is an ArchConfig (or anything resolvable to a MatmulPolicy:
    a ``matmul_policy`` spec, or the legacy precision fields)."""
    from repro.api import policy_of

    pol = policy_of(cfg)
    if pol.scheme == "bf16":
        return _matmul_bf16(x, w, jnp.dtype(getattr(cfg, "compute_dtype",
                                                    "bfloat16")),
                            jnp.dtype(getattr(cfg, "accum_dtype",
                                              "float32")))
    if pol.scheme == "int8_quant":
        return _matmul_int8_quant(x.astype(jnp.float32),
                                  w.astype(jnp.float32))
    return _matmul_ozaki(x, w, pol)


# ----------------------------------------------------------------------------
# Norms / embeddings / softcap
# ----------------------------------------------------------------------------

def rms_norm(x: jax.Array, gamma: jax.Array, eps: float) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32)
            ).astype(x.dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


def embed_lookup(table: jax.Array, ids: jax.Array,
                 compute_dtype) -> jax.Array:
    return table.astype(compute_dtype)[ids]


# ----------------------------------------------------------------------------
# Rotary position embeddings
# ----------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float, positions: jax.Array,
                     rotary_dim: Optional[int] = None):
    """cos/sin tables: (..., seq, rotary_dim // 2)."""
    rd = rotary_dim or head_dim
    inv = 1.0 / (theta ** (jnp.arange(0, rd, 2, dtype=jnp.float32) / rd))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array,
               style: str = "standard") -> jax.Array:
    """x: (batch, seq, heads, head_dim); cos/sin: (batch?, seq, rd//2).

    ``standard``  — rotate the full head_dim (llama-style half-split).
    ``partial2d`` — chatglm: rotate only the first half of head_dim
                    (interleaved pairs), pass the rest through. The second
                    positional channel of GLM's 2D RoPE is the identity for
                    causal LM inference (block position = 0), so only the
                    sequence channel rotates — noted in DESIGN.md.
    """
    orig_dtype = x.dtype
    x = x.astype(jnp.float32)
    c = cos[:, :, None, :] if cos.ndim == 3 else cos[None, :, None, :]
    s = sin[:, :, None, :] if sin.ndim == 3 else sin[None, :, None, :]
    if style == "standard":
        half = x.shape[-1] // 2
        x1, x2 = x[..., :half], x[..., half:]
        out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
        return out.astype(orig_dtype)
    if style == "partial2d":
        rd = x.shape[-1] // 2
        xr, xp = x[..., :rd], x[..., rd:]
        xe, xo = xr[..., 0::2], xr[..., 1::2]
        re = xe * c - xo * s
        ro = xo * c + xe * s
        rot = jnp.stack([re, ro], axis=-1).reshape(xr.shape)
        return jnp.concatenate([rot, xp], axis=-1).astype(orig_dtype)
    if style == "none":
        return x.astype(orig_dtype)
    raise ValueError(f"unknown rope style {style!r}")
