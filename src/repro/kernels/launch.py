"""Shared launch-configuration layer for the Ozaki Pallas kernels.

All three kernels (``int8_gemm``, ``ozaki_split``, ``ozaki_accum``) follow
the same launch recipe: shrink the requested block to the (aligned) array
extent, zero-pad the operands up to a whole number of blocks, launch a
dense grid, and slice the padding back off. This module centralizes that
recipe so the kernels agree on alignment rules; the tuning layer
(``repro.core.tuning``) selects the block shapes themselves
(``TilePlan``) that flow into these helpers.

TPU tiling constraints (see the Pallas guide): the last dimension of a
block should be a multiple of 128 lanes; the second-to-last a multiple of
the dtype's sublane count (8 for f32, 32 for int8). In interpret mode any
shape works, but keeping the compiled-mode constraints here means the same
launch parameters lower to Mosaic unchanged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

LANE = 128          # last-dim tile multiple (all dtypes)
SUBLANE_F32 = 8     # second-to-last multiple, 4-byte dtypes
SUBLANE_I8 = 32     # second-to-last multiple, 1-byte dtypes

VMEM_BYTES = 16 * 2 ** 20   # per-core VMEM (v4/v5 class)


def align_up(x: int, align: int) -> int:
    """Smallest multiple of ``align`` >= x."""
    return -(-x // align) * align


def shrink_block(requested: int, extent: int, align: int) -> int:
    """Block actually launched: the request, capped at the aligned extent.

    Tiny inputs get a single just-big-enough block instead of a padded
    256-wide one (interpret-mode tests sweep shapes down to 7).
    """
    return min(requested, align_up(extent, align))


def pad_tail(x: jax.Array, mults: tuple[int, ...]) -> jax.Array:
    """Zero-pad the trailing ``len(mults)`` dims up to whole blocks."""
    nd = len(mults)
    pads = [(0, 0)] * (x.ndim - nd) + [
        (0, (-d) % m) for d, m in zip(x.shape[-nd:], mults)]
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


def grid_for(shape: tuple[int, ...], blocks: tuple[int, ...]) -> tuple[int, ...]:
    """Dense grid over padded ``shape`` (must divide exactly)."""
    assert all(d % b == 0 for d, b in zip(shape, blocks)), (shape, blocks)
    return tuple(d // b for d, b in zip(shape, blocks))


# ----------------------------------------------------------------------------
# Named block recipes — one per kernel family, so every launch site agrees
# on the alignment rules (the planner in ``repro.core.tuning`` mirrors them).
# ----------------------------------------------------------------------------

def gemm_blocks(m: int, n: int, k: int, bm: int, bn: int,
                bk: int) -> tuple[int, int, int]:
    """Blocks for the int8 NT GEMM family: (m, k) x (n, k) -> (m, n).

    bm/bn are sublane dims of int8 operand tiles; bn doubles as the lane
    dim of the int32 (or float, for the epilogue-fused variants) output
    tile, so the stricter 128 alignment applies to it and to bk.
    """
    return (shrink_block(bm, m, SUBLANE_I8), shrink_block(bn, n, LANE),
            shrink_block(bk, k, LANE))


def int8_tile_blocks(m: int, k: int, bm: int, bk: int) -> tuple[int, int]:
    """Blocks for kernels tiled over an (m, k) int8-output matrix (split)."""
    return shrink_block(bm, m, SUBLANE_I8), shrink_block(bk, k, LANE)


def elementwise_blocks(m: int, n: int, bm: int, bn: int) -> tuple[int, int]:
    """Blocks for elementwise (m, n) kernels over 4-byte dtypes (accum)."""
    return shrink_block(bm, m, SUBLANE_F32), shrink_block(bn, n, LANE)


def _streaming_working_set(bm: int, bn: int, bk: int, *, num_splits_a: int,
                           num_splits_b: int, el_bytes: int) -> int:
    """VMEM bytes resident per streaming-GEMM grid step.

    Operand tiles arrive as (hi, lo) word pairs plus per-row exponent
    vectors; the in-kernel split lands ``num_splits_a`` / ``num_splits_b``
    int8 slice planes in persistent scratch next to the int32 product
    accumulator and up to two carried float accumulator planes.
    """
    operands = 2 * el_bytes * (bm * bk + bn * bk) + 4 * (bm + bn)
    slices = num_splits_a * bm * bk + num_splits_b * bn * bk
    accum = 4 * bm * bn + 2 * 2 * el_bytes * bm * bn   # int32 + in/out C
    return operands + slices + accum


def _crt_working_set(bm: int, bn: int, bk: int, *, ell: int) -> int:
    """VMEM bytes resident per fused-CRT GEMM grid step.

    int8 operand tiles plus the persistent (ell, bm, bn) int32 residue
    accumulator stack — the whole modulus axis must stay resident for the
    Garner epilogue — and the f64 output tile the epilogue writes.
    """
    operands = bm * bk + bn * bk
    accum = 4 * ell * bm * bn
    out = 8 * bm * bn
    return operands + accum + out


def crt_blocks(m: int, n: int, k: int, bm: int, bn: int, bk: int, *,
               ell: int, vmem_budget: int = VMEM_BYTES // 2
               ) -> tuple[int, int, int]:
    """Blocks for the fused-CRT residue GEMM: validated against the VMEM
    budget including the (ell, bm, bn) int32 accumulator stack.

    Starts from the standard GEMM shrink, then halves bm -> bn -> bk (to
    their alignment floors) until the working set fits — the accumulator
    stack scales with bm*bn, so the output tile shrinks first. Raises
    ``ValueError`` if even the floor tile exceeds the budget: the CRT
    epilogue needs every modulus plane resident, so there is no smaller
    launch.
    """
    bm_, bn_, bk_ = gemm_blocks(m, n, k, bm, bn, bk)
    ws = functools.partial(_crt_working_set, ell=ell)
    while ws(bm_, bn_, bk_) > vmem_budget:
        if bm_ > SUBLANE_I8:
            bm_ //= 2
        elif bn_ > LANE:
            bn_ //= 2
        elif bk_ > LANE:
            bk_ //= 2
        else:
            raise ValueError(
                "fused-CRT epilogue cannot fit VMEM: floor tile "
                f"({bm_}, {bn_}, {bk_}) with {ell} modulus planes needs "
                f"{ws(bm_, bn_, bk_)} bytes > budget {vmem_budget}")
    return bm_, bn_, bk_


def streaming_blocks(m: int, n: int, k: int, bm: int, bn: int, bk: int, *,
                     num_splits_a: int, num_splits_b: int, el_bytes: int,
                     vmem_budget: int = VMEM_BYTES // 2
                     ) -> tuple[int, int, int]:
    """Blocks for the streaming-split GEMM: validated against the VMEM
    budget including the (s, bm, bk) / (s, bn, bk) slice scratches.

    Starts from the standard GEMM shrink, then halves bk -> bm -> bn (to
    their alignment floors) until the streaming working set fits. Raises
    ``ValueError`` if even the floor tile exceeds the budget — streaming
    needs the whole slice chain resident, so there is no smaller launch.
    """
    bm_, bn_, bk_ = gemm_blocks(m, n, k, bm, bn, bk)
    ws = functools.partial(_streaming_working_set, num_splits_a=num_splits_a,
                           num_splits_b=num_splits_b, el_bytes=el_bytes)
    while ws(bm_, bn_, bk_) > vmem_budget:
        if bk_ > LANE:
            bk_ //= 2
        elif bm_ > SUBLANE_I8:
            bm_ //= 2
        elif bn_ > LANE:
            bn_ //= 2
        else:
            raise ValueError(
                "streaming split cannot fit VMEM: floor tile "
                f"({bm_}, {bn_}, {bk_}) with {num_splits_a}+{num_splits_b} "
                f"slice planes needs {ws(bm_, bn_, bk_)} bytes "
                f"> budget {vmem_budget}")
    return bm_, bn_, bk_
