"""Jit'd public wrappers over the Pallas kernels.

``interpret`` defaults to True (CPU validation). On a real TPU deployment
set ``repro.kernels.ops.INTERPRET = False`` (or pass interpret=False) so
``pl.pallas_call`` lowers to Mosaic.
"""
from __future__ import annotations

import jax

from .int8_gemm import (int8_matmul_nt, int8_matmul_nt_batched,
                        int8_matmul_nt_crt,
                        int8_matmul_nt_epilogue_dw,
                        int8_matmul_nt_epilogue_sw,
                        int8_matmul_nt_streaming_dw,
                        int8_matmul_nt_streaming_sw)
from .ozaki_accum import accum_scaled_dw, accum_scaled_sw
from .ozaki_split import fused_split_dw

INTERPRET = jax.default_backend() != "tpu"

__all__ = ["int8_matmul_nt", "int8_matmul_nt_batched",
           "int8_matmul_nt_crt",
           "int8_matmul_nt_epilogue_dw", "int8_matmul_nt_epilogue_sw",
           "int8_matmul_nt_streaming_dw", "int8_matmul_nt_streaming_sw",
           "fused_split_dw", "accum_scaled_dw", "accum_scaled_sw",
           "INTERPRET"]
