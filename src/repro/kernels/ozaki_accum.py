"""Pallas TPU kernels: fused scaled accumulation of int32 slice products.

Line 7 of Algorithm 3: ``C += C_tmp ⊙ (2^{-(i+j)α} · e_A · e_B^T)``. Fusing
the int32→float conversion, the power-of-two scaling, and the add into one
VMEM pass halves the HBM traffic of the accumulation stage — which the
paper's Fig. 9 identifies as the second-largest cost of the whole scheme.

Two accumulator widths:

  * ``accum_scaled_dw``  — C in double-float32 with a compensated add
    (the TPU has no FP64 unit). 48 mantissa bits.
  * ``accum_scaled_sw``  — C in one plain word (f64 on CPU validation
    hosts). The add sequence is a single rounding, so the fused pipeline
    stays bitwise identical to the XLA ``_accum_f64`` reference path
    (power-of-two scaling commutes with rounding).

The exponent application is deferred in both: products are accumulated
against the scalar ``2^{-(t+2)w}`` only; the per-element ``e_A + e_B`` is
applied once by the caller at the end (see ``core.ozaki``). This keeps the
kernel's scale a compile-time scalar.

In/out aliasing: the C operand(s) are donated and updated in place.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.xmath import two_sum

from .launch import elementwise_blocks, grid_for, pad_tail


def dw_accum_step(p, c_hi, c_lo, scale: float):
    """One fused df32 accumulation: (c_hi, c_lo) += df32(p) * scale.

    The exact rounding sequence shared by ``accum_scaled_dw`` and the
    epilogue-fused GEMM (``int8_gemm.int8_matmul_nt_epilogue_dw``) — both
    paths MUST stay bitwise identical to the XLA reference accumulation,
    so the sequence lives in exactly one place.

    Steps: exact int32 -> df32 (16-bit split; no int64 anywhere), then
    normalize (fast_two_sum) so |lo| <= ulp(hi)/2 before the compensated
    add — skipping the normalize costs ~3 decimal digits over a scheme.
    """
    low = jnp.bitwise_and(p, jnp.int32(0xFFFF))
    high = p - low
    hi_f = high.astype(jnp.float32)
    lo_f = low.astype(jnp.float32)
    n_s = hi_f + lo_f
    n_e = lo_f - (n_s - hi_f)
    t_hi = n_s * jnp.float32(scale)
    t_lo = n_e * jnp.float32(scale)
    # compensated (c_hi, c_lo) += (t_hi, t_lo)
    s_hi, e_hi = two_sum(c_hi, t_hi)
    s_lo, e_lo = two_sum(c_lo, t_lo)
    c = e_hi + s_lo
    v_hi = s_hi + c
    v_lo = c - (v_hi - s_hi)
    w = e_lo + v_lo
    n_hi = v_hi + w
    n_lo = w - (n_hi - v_hi)
    return n_hi, n_lo


def _accum_kernel(scale: float, p_ref, chi_ref, clo_ref, ohi_ref, olo_ref):
    n_hi, n_lo = dw_accum_step(p_ref[...], chi_ref[...], clo_ref[...], scale)
    ohi_ref[...] = n_hi
    olo_ref[...] = n_lo


@functools.partial(jax.jit, static_argnames=("scale", "bm", "bn", "interpret"))
def accum_scaled_dw(p: jax.Array, c_hi: jax.Array, c_lo: jax.Array, *,
                    scale: float, bm: int = 256, bn: int = 256,
                    interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """(c_hi, c_lo) += df32(p) * scale, elementwise, fused in VMEM."""
    m, n = p.shape
    bm_, bn_ = elementwise_blocks(m, n, bm, bn)
    p = pad_tail(p, (bm_, bn_))
    c_hi = pad_tail(c_hi, (bm_, bn_))
    c_lo = pad_tail(c_lo, (bm_, bn_))
    mp, np_ = p.shape
    spec = pl.BlockSpec((bm_, bn_), lambda i, j: (i, j))
    o_hi, o_lo = pl.pallas_call(
        functools.partial(_accum_kernel, scale),
        grid=grid_for((mp, np_), (bm_, bn_)),
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((mp, np_), jnp.float32),
                   jax.ShapeDtypeStruct((mp, np_), jnp.float32)],
        input_output_aliases={1: 0, 2: 1},
        interpret=interpret,
    )(p, c_hi, c_lo)
    return o_hi[:m, :n], o_lo[:m, :n]


def _accum_sw_kernel(scale: float, p_ref, c_ref, o_ref):
    c = c_ref[...]
    # int32 -> f64 is exact; scale is an exact power of two: ONE rounding.
    o_ref[...] = c + p_ref[...].astype(c.dtype) * jnp.asarray(scale, c.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "bm", "bn", "interpret"))
def accum_scaled_sw(p: jax.Array, c: jax.Array, *, scale: float,
                    bm: int = 256, bn: int = 256,
                    interpret: bool = True) -> jax.Array:
    """c += p * scale in c's (single-word) dtype, fused in VMEM.

    Used by the ``pallas_fused`` pipeline when ``accum="f64"``: the single
    rounded add per element matches the XLA reference accumulation
    bitwise, because the deferred ``ldexp(·, e_A + e_B)`` is exact.
    """
    m, n = p.shape
    bm_, bn_ = elementwise_blocks(m, n, bm, bn)
    p = pad_tail(p, (bm_, bn_))
    c = pad_tail(c, (bm_, bn_))
    mp, np_ = p.shape
    spec = pl.BlockSpec((bm_, bn_), lambda i, j: (i, j))
    out = pl.pallas_call(
        functools.partial(_accum_sw_kernel, scale),
        grid=grid_for((mp, np_), (bm_, bn_)),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((mp, np_), c.dtype),
        input_output_aliases={1: 0},
        interpret=interpret,
    )(p, c)
    return out[:m, :n]
