"""Pallas TPU kernel: fused scaled accumulation of int32 slice products.

Line 7 of Algorithm 3: ``C += C_tmp ⊙ (2^{-(i+j)α} · e_A · e_B^T)`` with C
held in double-float32 (the TPU has no FP64 unit). Fusing the int32→df32
conversion, the power-of-two scaling, and the compensated add into one
VMEM pass halves the HBM traffic of the accumulation stage — which the
paper's Fig. 9 identifies as the second-largest cost of the whole scheme.

The exponent application is deferred: products are accumulated against the
scalar ``2^{-(t+2)w}`` only; the per-element ``e_A + e_B`` is applied once
by the caller at the end (see ``core.ozaki._accum_df32``). This keeps the
kernel's scale a compile-time scalar.

In/out aliasing: C_hi / C_lo are donated and updated in place.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.xmath import two_sum


def _accum_kernel(scale: float, p_ref, chi_ref, clo_ref, ohi_ref, olo_ref):
    p = p_ref[...]
    # exact int32 -> df32 (16-bit split; no int64 anywhere)
    low = jnp.bitwise_and(p, jnp.int32(0xFFFF))
    high = p - low
    t_hi = high.astype(jnp.float32) * jnp.float32(scale)
    t_lo = low.astype(jnp.float32) * jnp.float32(scale)
    # compensated (c_hi, c_lo) += (t_hi, t_lo)
    c_hi = chi_ref[...]
    c_lo = clo_ref[...]
    s_hi, e_hi = two_sum(c_hi, t_hi)
    s_lo, e_lo = two_sum(c_lo, t_lo)
    c = e_hi + s_lo
    v_hi = s_hi + c
    v_lo = c - (v_hi - s_hi)
    w = e_lo + v_lo
    n_hi = v_hi + w
    n_lo = w - (n_hi - v_hi)
    ohi_ref[...] = n_hi
    olo_ref[...] = n_lo


@functools.partial(jax.jit, static_argnames=("scale", "bm", "bn", "interpret"))
def accum_scaled_dw(p: jax.Array, c_hi: jax.Array, c_lo: jax.Array, *,
                    scale: float, bm: int = 256, bn: int = 256,
                    interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """(c_hi, c_lo) += df32(p) * scale, elementwise, fused in VMEM."""
    m, n = p.shape
    bm_ = min(bm, -(-m // 8) * 8)
    bn_ = min(bn, -(-n // 128) * 128)
    pm, pn = (-m) % bm_, (-n) % bn_
    if pm or pn:
        p = jnp.pad(p, ((0, pm), (0, pn)))
        c_hi = jnp.pad(c_hi, ((0, pm), (0, pn)))
        c_lo = jnp.pad(c_lo, ((0, pm), (0, pn)))
    mp, np_ = p.shape
    spec = pl.BlockSpec((bm_, bn_), lambda i, j: (i, j))
    o_hi, o_lo = pl.pallas_call(
        functools.partial(_accum_kernel, scale),
        grid=(mp // bm_, np_ // bn_),
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((mp, np_), jnp.float32),
                   jax.ShapeDtypeStruct((mp, np_), jnp.float32)],
        input_output_aliases={1: 0, 2: 1},
        interpret=interpret,
    )(p, c_hi, c_lo)
    return o_hi[:m, :n], o_lo[:m, :n]
