"""Pure-jnp oracles for every Pallas kernel in this package.

Each function mirrors its kernel's contract exactly; kernel tests sweep
shapes/dtypes and assert_allclose (exact equality for the integer paths)
against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.splitting import split_int_dw
from repro.core.xmath import DW, dw_add, dw_normalize


def int8_matmul_nt_ref(a: jax.Array, b_t: jax.Array) -> jax.Array:
    """C[m,n] = sum_k A[m,k] * B_t[n,k], exact int32."""
    return jax.lax.dot_general(
        a, b_t, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)


def int8_matmul_nt_batched_ref(a: jax.Array, b_t: jax.Array) -> jax.Array:
    """C[b,m,n] = sum_k A[b,m,k] * B_t[b,n,k], exact int32."""
    return jax.lax.dot_general(
        a, b_t, dimension_numbers=(((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.int32)


def fused_split_dw_ref(hi: jax.Array, lo: jax.Array, exp: jax.Array, *,
                       num_splits: int, w: int) -> jax.Array:
    """Slices via the sequential core implementation (same exponents)."""
    res = split_int_dw(DW(hi, lo), num_splits, w)
    # core recomputes exponents; caller passes the same row_exponents(hi)
    del exp
    return res.slices


def accum_scaled_dw_ref(p: jax.Array, c_hi: jax.Array, c_lo: jax.Array, *,
                        scale: float) -> tuple[jax.Array, jax.Array]:
    low = jnp.bitwise_and(p, jnp.int32(0xFFFF))
    high = p - low
    term = dw_normalize(high.astype(jnp.float32), low.astype(jnp.float32))
    out = dw_add(DW(c_hi, c_lo),
                 DW(term.hi * jnp.float32(scale),
                    term.lo * jnp.float32(scale)))
    return out.hi, out.lo


def accum_scaled_sw_ref(p: jax.Array, c: jax.Array, *,
                        scale: float) -> jax.Array:
    return c + p.astype(c.dtype) * jnp.asarray(scale, c.dtype)
