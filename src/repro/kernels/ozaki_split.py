"""Pallas TPU kernel: fused one-pass SplitInt (beyond-paper optimization O3).

Algorithm 4 as literally written re-reads the residual matrix once per
split — ``s`` HBM round-trips. This kernel reads each input tile ONCE into
VMEM and emits all ``s`` int8 slices from registers, turning the split
stage from ``s``-pass to 1-pass (the split stage is memory-bound; see the
paper's Fig. 9 breakdown).

Input is a double-word pair (hi, lo) plus the precomputed per-row exponent
vector. The arithmetic is dtype-generic: the TPU deployment feeds the
native df32 pair, while the FP64 entry point (``core.ozaki`` with
``backend="pallas_fused"`` and f64 operands) passes ``(a, 0.0)`` — with a
zero low word the two_sum chain degenerates to exactly Algorithm 4's
sign-magnitude extraction, so the slices are bitwise identical to
``core.splitting.split_int``. Output block is (s, bm, bk) int8 — for
s = 13, bm = bk = 256 that is 852 KiB VMEM, well inside budget.

Validated on CPU in interpret mode against ``repro.core.splitting``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.xmath import two_sum

from .launch import grid_for, int8_tile_blocks, pad_tail


def split_tile(out_ref, hi, lo, exp, num_splits: int, w: int):
    """Emit ``num_splits`` int8 slices of a (bm, bk) tile into ``out_ref``.

    The extraction is elementwise per (row, col) given the (full-row)
    exponent, so any tiling of the operand produces bitwise-identical
    slices — the streaming GEMM kernels call this on VMEM scratch refs
    with the same guarantee as the standalone split pass. The slice
    chain is prefix-stable: the first p slices do not depend on how many
    more will be extracted, so callers may size ``num_splits`` down to
    just the prefix they consume.
    """
    neg = (hi < 0) | ((hi == 0) & (lo < 0))
    sign = jnp.where(neg, -1, 1).astype(jnp.int8)
    a_hi = jnp.where(neg, -hi, hi)
    a_lo = jnp.where(neg, -lo, lo)
    # ldexp is exact (XLA's exp2 is not, even at integer arguments); the
    # scaled residual lands in [0, 1) like Algorithm 4 requires.
    r_hi = jnp.ldexp(a_hi, -exp[:, None])
    r_lo = jnp.ldexp(a_lo, -exp[:, None])
    scale = jnp.asarray(2.0 ** w, hi.dtype)

    for p in range(num_splits):
        t = r_hi * scale
        u = r_lo * scale
        s, e = two_sum(t, u)
        y = jnp.clip(jnp.floor(s), -128, 127)
        f_hi, f_e = two_sum(s, -y)
        r_hi, t1 = two_sum(f_hi, e)
        r_lo = t1 + f_e
        out_ref[p, :, :] = sign * y.astype(jnp.int8)


def _split_kernel(num_splits: int, w: int, hi_ref, lo_ref, exp_ref, out_ref):
    split_tile(out_ref, hi_ref[...], lo_ref[...], exp_ref[...],
               num_splits, w)


@functools.partial(jax.jit,
                   static_argnames=("num_splits", "w", "bm", "bk", "interpret"))
def fused_split_dw(hi: jax.Array, lo: jax.Array, exp: jax.Array, *,
                   num_splits: int, w: int, bm: int = 256, bk: int = 256,
                   interpret: bool = True) -> jax.Array:
    """All-slices-in-one-pass SplitInt. Returns (s, m, k) int8."""
    m, k = hi.shape
    # bm is the second-to-last dim of the int8 OUTPUT block: 32-sublane.
    bm_, bk_ = int8_tile_blocks(m, k, bm, bk)
    hi = pad_tail(hi, (bm_, bk_))
    lo = pad_tail(lo, (bm_, bk_))
    exp = pad_tail(exp, (bm_,))
    mp, kp = hi.shape
    out = pl.pallas_call(
        functools.partial(_split_kernel, num_splits, w),
        grid=grid_for((mp, kp), (bm_, bk_)),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j: (i, j)),
            pl.BlockSpec((bm_, bk_), lambda i, j: (i, j)),
            pl.BlockSpec((bm_,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((num_splits, bm_, bk_), lambda i, j: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((num_splits, mp, kp), jnp.int8),
        interpret=interpret,
    )(hi, lo, exp)
    return out[:, :m, :k]
