"""Pallas TPU kernels for the Ozaki scheme's compute hot-spots.

The paper's hot path is (a) the int8 slice GEMMs (its cuBLAS GemmEx call)
and (b) the high-precision accumulation + the splitting stage it profiles
in Fig. 9. One kernel each:

  int8_gemm.py    — MXU int8xint8->int32 tiled GEMM (NT layout), plus a
                    batch-grid variant for the batched Ozaki API, the
                    epilogue-fused GEMM+accumulate variants (int32 group
                    products never leave VMEM) and the streaming-split
                    variants (slices extracted in VMEM — the int8 stacks
                    never touch HBM either)
  ozaki_split.py  — fused one-pass SplitInt (s slices per HBM read)
  ozaki_accum.py  — fused int32->float scaled accumulation (df32
                    compensated, or single-word for the f64 oracle path)

launch.py holds the shared launch-config layer (block alignment, padding,
grid construction) all kernels go through; ops.py re-exports jit'd
wrappers; ref.py holds the pure-jnp oracles.
"""
from . import int8_gemm, launch, ozaki_accum, ozaki_split, ref
from .ops import (accum_scaled_dw, accum_scaled_sw, fused_split_dw,
                  int8_matmul_nt, int8_matmul_nt_batched,
                  int8_matmul_nt_crt,
                  int8_matmul_nt_epilogue_dw, int8_matmul_nt_epilogue_sw,
                  int8_matmul_nt_streaming_dw, int8_matmul_nt_streaming_sw)

__all__ = ["int8_gemm", "launch", "ozaki_accum", "ozaki_split", "ref",
           "accum_scaled_dw", "accum_scaled_sw", "fused_split_dw",
           "int8_matmul_nt", "int8_matmul_nt_batched",
           "int8_matmul_nt_crt",
           "int8_matmul_nt_epilogue_dw", "int8_matmul_nt_epilogue_sw",
           "int8_matmul_nt_streaming_dw", "int8_matmul_nt_streaming_sw"]
