"""Pallas TPU kernels for the Ozaki scheme's compute hot-spots.

The paper's hot path is (a) the int8 slice GEMMs (its cuBLAS GemmEx call)
and (b) the high-precision accumulation + the splitting stage it profiles
in Fig. 9. One kernel each:

  int8_gemm.py    — MXU int8xint8->int32 tiled GEMM (NT layout)
  ozaki_split.py  — fused one-pass SplitInt (s slices per HBM read)
  ozaki_accum.py  — fused int32->df32 scaled compensated accumulation

ops.py re-exports jit'd wrappers; ref.py holds the pure-jnp oracles.
"""
from . import int8_gemm, ozaki_accum, ozaki_split, ref
from .ops import accum_scaled_dw, fused_split_dw, int8_matmul_nt

__all__ = ["int8_gemm", "ozaki_accum", "ozaki_split", "ref",
           "accum_scaled_dw", "fused_split_dw", "int8_matmul_nt"]
