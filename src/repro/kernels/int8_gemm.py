"""Pallas TPU kernel: int8 x int8 -> int32 GEMM on the MXU (NT layout).

Computes ``C[m, n] = sum_k A[m, k] * B[n, k]`` — both operands contract on
their last axis, which is exactly how the Ozaki scheme stores B slices
(column-split of B == row-split of B^T), and is the MXU-friendly layout:
no transposition between HBM and VMEM.

Tiling: grid (m/bm, n/bn, k/bk), k innermost so each output block stays
resident in VMEM while the k loop streams A/B tiles through the MXU,
accumulating in int32. Block shapes default to MXU-aligned 256x256x512:
  A tile 256x512 int8 = 128 KiB, B tile 256x512 int8 = 128 KiB,
  C tile 256x256 int32 = 256 KiB  ->  ~0.5 MiB VMEM of ~16 MiB.

``int8_matmul_nt_batched`` adds a leading batch grid dimension — one
kernel launch for a whole ``(B, m, k) x (B, n, k)`` stack (the batched
Ozaki API's fully-batched case); the per-(batch, m, n) k-loop is
unchanged. Launch bookkeeping (block shrink, padding, grid) comes from
the shared ``launch`` layer.

Validated on CPU in interpret mode against ``ref.int8_matmul_nt_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .launch import LANE, SUBLANE_I8, grid_for, pad_tail, shrink_block


def _kernel(a_ref, b_ref, o_ref):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    prod = jax.lax.dot_general(
        a_ref[...], b_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)
    o_ref[...] += prod


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def int8_matmul_nt(a: jax.Array, b_t: jax.Array, *, bm: int = 256,
                   bn: int = 256, bk: int = 512,
                   interpret: bool = True) -> jax.Array:
    """C = A @ B_t.T with int32 accumulation. a: (m, k) int8, b_t: (n, k)."""
    assert a.dtype == jnp.int8 and b_t.dtype == jnp.int8
    m, k = a.shape
    n, k2 = b_t.shape
    assert k == k2, (a.shape, b_t.shape)
    # bm: sublane of the int8 A tile (32); bn: sublane of the int8 B tile
    # AND lane dim of the int32 C tile, so the stricter 128 applies.
    bm_ = shrink_block(bm, m, SUBLANE_I8)
    bn_ = shrink_block(bn, n, LANE)
    bk_ = shrink_block(bk, k, LANE)
    a_p = pad_tail(a, (bm_, bk_))
    b_p = pad_tail(b_t, (bn_, bk_))
    mp, kp = a_p.shape
    np_, _ = b_p.shape
    grid = grid_for((mp, np_, kp), (bm_, bn_, bk_))
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn_, bk_), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=interpret,
    )(a_p, b_p)
    return out[:m, :n]


def _kernel_batched(a_ref, b_ref, o_ref):
    k_idx = pl.program_id(3)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    prod = jax.lax.dot_general(
        a_ref[0], b_ref[0],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)
    o_ref[...] += prod[None]


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def int8_matmul_nt_batched(a: jax.Array, b_t: jax.Array, *, bm: int = 256,
                           bn: int = 256, bk: int = 512,
                           interpret: bool = True) -> jax.Array:
    """C[b] = A[b] @ B_t[b].T for every batch row, one kernel launch.

    a: (B, m, k) int8, b_t: (B, n, k) int8 -> (B, m, n) int32. The batch
    is the outermost grid dimension, so consecutive program instances
    reuse the same (i, j, k) walk per batch row.
    """
    assert a.dtype == jnp.int8 and b_t.dtype == jnp.int8
    B, m, k = a.shape
    B2, n, k2 = b_t.shape
    assert B == B2 and k == k2, (a.shape, b_t.shape)
    bm_ = shrink_block(bm, m, SUBLANE_I8)
    bn_ = shrink_block(bn, n, LANE)
    bk_ = shrink_block(bk, k, LANE)
    a_p = pad_tail(a, (bm_, bk_))
    b_p = pad_tail(b_t, (bn_, bk_))
    _, mp, kp = a_p.shape
    _, np_, _ = b_p.shape
    grid = (B,) + grid_for((mp, np_, kp), (bm_, bn_, bk_))
    out = pl.pallas_call(
        _kernel_batched,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm_, bk_), lambda b, i, j, kk: (b, i, kk)),
            pl.BlockSpec((1, bn_, bk_), lambda b, i, j, kk: (b, j, kk)),
        ],
        out_specs=pl.BlockSpec((1, bm_, bn_), lambda b, i, j, kk: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((B, mp, np_), jnp.int32),
        interpret=interpret,
    )(a_p, b_p)
    return out[:, :m, :n]
