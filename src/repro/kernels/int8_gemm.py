"""Pallas TPU kernel: int8 x int8 -> int32 GEMM on the MXU (NT layout).

Computes ``C[m, n] = sum_k A[m, k] * B[n, k]`` — both operands contract on
their last axis, which is exactly how the Ozaki scheme stores B slices
(column-split of B == row-split of B^T), and is the MXU-friendly layout:
no transposition between HBM and VMEM.

Tiling: grid (m/bm, n/bn, k/bk), k innermost so each output block stays
resident in VMEM while the k loop streams A/B tiles through the MXU,
accumulating in int32. Block shapes default to MXU-aligned 256x256x512:
  A tile 256x512 int8 = 128 KiB, B tile 256x512 int8 = 128 KiB,
  C tile 256x256 int32 = 256 KiB  ->  ~0.5 MiB VMEM of ~16 MiB.

Validated on CPU in interpret mode against ``ref.int8_matmul_nt_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, o_ref):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    prod = jax.lax.dot_general(
        a_ref[...], b_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)
    o_ref[...] += prod


def _pad_to(x: jax.Array, mult: tuple[int, int]) -> jax.Array:
    pm = (-x.shape[0]) % mult[0]
    pk = (-x.shape[1]) % mult[1]
    if pm == 0 and pk == 0:
        return x
    return jnp.pad(x, ((0, pm), (0, pk)))


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def int8_matmul_nt(a: jax.Array, b_t: jax.Array, *, bm: int = 256,
                   bn: int = 256, bk: int = 512,
                   interpret: bool = True) -> jax.Array:
    """C = A @ B_t.T with int32 accumulation. a: (m, k) int8, b_t: (n, k)."""
    assert a.dtype == jnp.int8 and b_t.dtype == jnp.int8
    m, k = a.shape
    n, k2 = b_t.shape
    assert k == k2, (a.shape, b_t.shape)
    bm_, bn_, bk_ = min(bm, _ceil_align(m)), min(bn, _ceil_align(n)), \
        min(bk, _ceil_align(k, 128))
    a_p = _pad_to(a, (bm_, bk_))
    b_p = _pad_to(b_t, (bn_, bk_))
    mp, kp = a_p.shape
    np_, _ = b_p.shape
    grid = (mp // bm_, np_ // bn_, kp // bk_)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn_, bk_), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=interpret,
    )(a_p, b_p)
    return out[:m, :n]


def _ceil_align(x: int, align: int = 8) -> int:
    """Smallest multiple of ``align`` >= x (shrinks blocks for tiny inputs)."""
    return -(-x // align) * align
