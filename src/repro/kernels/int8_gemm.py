"""Pallas TPU kernel: int8 x int8 -> int32 GEMM on the MXU (NT layout).

Computes ``C[m, n] = sum_k A[m, k] * B[n, k]`` — both operands contract on
their last axis, which is exactly how the Ozaki scheme stores B slices
(column-split of B == row-split of B^T), and is the MXU-friendly layout:
no transposition between HBM and VMEM.

Tiling: grid (m/bm, n/bn, k/bk), k innermost so each output block stays
resident in VMEM while the k loop streams A/B tiles through the MXU,
accumulating in int32. Block shapes default to MXU-aligned 256x256x512:
  A tile 256x512 int8 = 128 KiB, B tile 256x512 int8 = 128 KiB,
  C tile 256x256 int32 = 256 KiB  ->  ~0.5 MiB VMEM of ~16 MiB.

``int8_matmul_nt_batched`` adds a leading batch grid dimension — one
kernel launch for a whole ``(B, m, k) x (B, n, k)`` stack (the batched
Ozaki API's fully-batched case); the per-(batch, m, n) k-loop is
unchanged. Launch bookkeeping (block shrink, padding, grid) comes from
the shared ``launch`` layer.

``int8_matmul_nt_epilogue_{sw,dw}`` are the epilogue-fused variants used
by the ``fusion="epilogue"`` executor: the int32 slice products of one
anti-diagonal group accumulate in a VMEM scratch block across a
(pairs, k) grid walk and are folded into the carried high-precision
accumulator C inside the GEMM grid's epilogue — the int32 products never
round-trip to HBM (see ``core.tuning.hbm_pass_model``). The epilogue
runs the exact rounding sequence of the standalone accumulation kernels
(``ozaki_accum.dw_accum_step`` / the single rounded f64 add), so results
stay bitwise identical to the ``xla`` reference pipeline. Both epilogue
variants also take batch-grid operands — ``(s, B, m, k)`` slice stacks
with ``(B, m, n)`` carried accumulators and the batch as the outermost
grid dimension — so stacked-weights batches keep epilogue fusion.

Validated on CPU in interpret mode against ``ref.int8_matmul_nt_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .launch import gemm_blocks, grid_for, pad_tail
from .ozaki_accum import dw_accum_step


def _kernel(a_ref, b_ref, o_ref):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    prod = jax.lax.dot_general(
        a_ref[...], b_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)
    o_ref[...] += prod


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def int8_matmul_nt(a: jax.Array, b_t: jax.Array, *, bm: int = 256,
                   bn: int = 256, bk: int = 512,
                   interpret: bool = True) -> jax.Array:
    """C = A @ B_t.T with int32 accumulation. a: (m, k) int8, b_t: (n, k)."""
    assert a.dtype == jnp.int8 and b_t.dtype == jnp.int8
    m, k = a.shape
    n, k2 = b_t.shape
    assert k == k2, (a.shape, b_t.shape)
    bm_, bn_, bk_ = gemm_blocks(m, n, k, bm, bn, bk)
    a_p = pad_tail(a, (bm_, bk_))
    b_p = pad_tail(b_t, (bn_, bk_))
    mp, kp = a_p.shape
    np_, _ = b_p.shape
    grid = grid_for((mp, np_, kp), (bm_, bn_, bk_))
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn_, bk_), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=interpret,
    )(a_p, b_p)
    return out[:m, :n]


def _kernel_batched(a_ref, b_ref, o_ref):
    k_idx = pl.program_id(3)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    prod = jax.lax.dot_general(
        a_ref[0], b_ref[0],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)
    o_ref[...] += prod[None]


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def int8_matmul_nt_batched(a: jax.Array, b_t: jax.Array, *, bm: int = 256,
                           bn: int = 256, bk: int = 512,
                           interpret: bool = True) -> jax.Array:
    """C[b] = A[b] @ B_t[b].T for every batch row, one kernel launch.

    a: (B, m, k) int8, b_t: (B, n, k) int8 -> (B, m, n) int32. The batch
    is the outermost grid dimension, so consecutive program instances
    reuse the same (i, j, k) walk per batch row.
    """
    assert a.dtype == jnp.int8 and b_t.dtype == jnp.int8
    B, m, k = a.shape
    B2, n, k2 = b_t.shape
    assert B == B2 and k == k2, (a.shape, b_t.shape)
    bm_, bn_, bk_ = gemm_blocks(m, n, k, bm, bn, bk)
    a_p = pad_tail(a, (bm_, bk_))
    b_p = pad_tail(b_t, (bn_, bk_))
    _, mp, kp = a_p.shape
    _, np_, _ = b_p.shape
    grid = (B,) + grid_for((mp, np_, kp), (bm_, bn_, bk_))
    out = pl.pallas_call(
        _kernel_batched,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm_, bk_), lambda b, i, j, kk: (b, i, kk)),
            pl.BlockSpec((1, bn_, bk_), lambda b, i, j, kk: (b, j, kk)),
        ],
        out_specs=pl.BlockSpec((1, bm_, bn_), lambda b, i, j, kk: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((B, mp, np_), jnp.int32),
        interpret=interpret,
    )(a_p, b_p)
    return out[:, :m, :n]


# ----------------------------------------------------------------------------
# Epilogue-fused variants: GEMM + scaled high-precision accumulation in one
# launch. One call per anti-diagonal group; the int32 group product lives
# only in a VMEM scratch block.
# ----------------------------------------------------------------------------
#
# Grid is (m/bm, n/bn, npairs, k/bk) with the C block index a function of
# (i, j) only, so for each output block the whole (pairs, k) walk happens
# while C stays resident. Slice operands are indexed affinely in the pair
# dimension: A uses slice ``p_lo + pp``, B uses ``t - p_lo - pp`` — exactly
# the anti-diagonal's (p, q = t - p) pairs. The int32 scratch accumulator
# is exact (alpha reserves diagonal-fusion headroom), so the epilogue sees
# the same group product P_t the unfused pipeline materializes to HBM.
#
# The batch-grid variants take (s, B, m, k) x (s, B, n, k) slice stacks
# and prepend the batch as the OUTERMOST grid dimension:
# (B, m/bm, n/bn, npairs, k/bk). The inner (pairs, k) walk per C block is
# unchanged — the scratch accumulator carries across grid steps exactly
# as in the 2-D kernel because (pp, kk) remain the fastest-varying dims —
# so a stacked-weights batch keeps ``fuse_epilogue=True`` instead of
# falling back to the stage-fused pipeline (the PR 2 limitation).


def _epilogue_kernel_sw(scale, npairs, nk, a_ref, b_ref, c_ref, o_ref,
                        acc_ref):
    pp = pl.program_id(2)
    kk = pl.program_id(3)

    @pl.when((pp == 0) & (kk == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[0], b_ref[0],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when((pp == npairs - 1) & (kk == nk - 1))
    def _epilogue():
        c = c_ref[...]
        # int32 -> f64 exact; scale an exact power of two: ONE rounding,
        # matching ``_accum_f64`` / ``accum_scaled_sw`` bitwise.
        o_ref[...] = c + acc_ref[...].astype(c.dtype) * jnp.asarray(
            scale, c.dtype)


def _epilogue_kernel_dw(scale, npairs, nk, a_ref, b_ref, chi_ref, clo_ref,
                        ohi_ref, olo_ref, acc_ref):
    pp = pl.program_id(2)
    kk = pl.program_id(3)

    @pl.when((pp == 0) & (kk == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[0], b_ref[0],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when((pp == npairs - 1) & (kk == nk - 1))
    def _epilogue():
        n_hi, n_lo = dw_accum_step(acc_ref[...], chi_ref[...], clo_ref[...],
                                   scale)
        ohi_ref[...] = n_hi
        olo_ref[...] = n_lo


def _epilogue_kernel_batched_sw(scale, npairs, nk, a_ref, b_ref, c_ref,
                                o_ref, acc_ref):
    pp = pl.program_id(3)
    kk = pl.program_id(4)

    @pl.when((pp == 0) & (kk == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[0, 0], b_ref[0, 0],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when((pp == npairs - 1) & (kk == nk - 1))
    def _epilogue():
        c = c_ref[0]
        o_ref[...] = (c + acc_ref[...].astype(c.dtype) * jnp.asarray(
            scale, c.dtype))[None]


def _epilogue_kernel_batched_dw(scale, npairs, nk, a_ref, b_ref, chi_ref,
                                clo_ref, ohi_ref, olo_ref, acc_ref):
    pp = pl.program_id(3)
    kk = pl.program_id(4)

    @pl.when((pp == 0) & (kk == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[0, 0], b_ref[0, 0],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when((pp == npairs - 1) & (kk == nk - 1))
    def _epilogue():
        n_hi, n_lo = dw_accum_step(acc_ref[...], chi_ref[0], clo_ref[0],
                                   scale)
        ohi_ref[...] = n_hi[None]
        olo_ref[...] = n_lo[None]


_EPILOGUE_BATCHED = {_epilogue_kernel_sw: _epilogue_kernel_batched_sw,
                     _epilogue_kernel_dw: _epilogue_kernel_batched_dw}


def _epilogue_launch(a_slices, b_slices, c_arrays, kernel, *, p_lo, t,
                     npairs, scale, bm, bn, bk, interpret):
    """Shared launch recipe for both epilogue variants, 2-D and batched.

    c_arrays: list of (m, n) — or (B, m, n) for (s, B, m, k) slice
    stacks — accumulator planes (1 for sw, 2 for dw), donated and
    carried through ``input_output_aliases``.
    """
    if a_slices.ndim == 4:
        return _epilogue_launch_batched(
            a_slices, b_slices, c_arrays, _EPILOGUE_BATCHED[kernel],
            p_lo=p_lo, t=t, npairs=npairs, scale=scale, bm=bm, bn=bn,
            bk=bk, interpret=interpret)
    s, m, k = a_slices.shape
    s2, n, k2 = b_slices.shape
    assert k == k2, (a_slices.shape, b_slices.shape)
    assert 0 <= p_lo and p_lo + npairs <= s, (p_lo, npairs, s)
    assert 0 <= t - p_lo - (npairs - 1) and t - p_lo < s2, (p_lo, t, npairs)
    bm_, bn_, bk_ = gemm_blocks(m, n, k, bm, bn, bk)
    a_p = pad_tail(a_slices, (bm_, bk_))
    b_p = pad_tail(b_slices, (bn_, bk_))
    c_p = [pad_tail(c, (bm_, bn_)) for c in c_arrays]
    _, mp, kp = a_p.shape
    _, np_, _ = b_p.shape
    gm, gn, gk = grid_for((mp, np_, kp), (bm_, bn_, bk_))
    nc = len(c_p)
    c_spec = pl.BlockSpec((bm_, bn_), lambda i, j, pp, kk: (i, j))
    outs = pl.pallas_call(
        functools.partial(kernel, scale, npairs, gk),
        grid=(gm, gn, npairs, gk),
        in_specs=[
            pl.BlockSpec((1, bm_, bk_),
                         lambda i, j, pp, kk: (p_lo + pp, i, kk)),
            pl.BlockSpec((1, bn_, bk_),
                         lambda i, j, pp, kk: (t - p_lo - pp, j, kk)),
        ] + [c_spec] * nc,
        out_specs=[c_spec] * nc,
        out_shape=[jax.ShapeDtypeStruct((mp, np_), c.dtype) for c in c_p],
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.int32)],
        input_output_aliases={2 + i: i for i in range(nc)},
        interpret=interpret,
    )(a_p, b_p, *c_p)
    return [o[:m, :n] for o in outs]


def _epilogue_launch_batched(a_slices, b_slices, c_arrays, kernel, *, p_lo,
                             t, npairs, scale, bm, bn, bk, interpret):
    """Batch-grid epilogue launch: (s, B, m, k) x (s, B, n, k) slices,
    (B, m, n) carried accumulators, batch outermost in the grid."""
    s, B, m, k = a_slices.shape
    s2, B2, n, k2 = b_slices.shape
    assert k == k2 and B == B2, (a_slices.shape, b_slices.shape)
    assert 0 <= p_lo and p_lo + npairs <= s, (p_lo, npairs, s)
    assert 0 <= t - p_lo - (npairs - 1) and t - p_lo < s2, (p_lo, t, npairs)
    bm_, bn_, bk_ = gemm_blocks(m, n, k, bm, bn, bk)
    a_p = pad_tail(a_slices, (bm_, bk_))
    b_p = pad_tail(b_slices, (bn_, bk_))
    c_p = [pad_tail(c, (bm_, bn_)) for c in c_arrays]
    _, _, mp, kp = a_p.shape
    _, _, np_, _ = b_p.shape
    gm, gn, gk = grid_for((mp, np_, kp), (bm_, bn_, bk_))
    nc = len(c_p)
    c_spec = pl.BlockSpec((1, bm_, bn_), lambda b, i, j, pp, kk: (b, i, j))
    outs = pl.pallas_call(
        functools.partial(kernel, scale, npairs, gk),
        grid=(B, gm, gn, npairs, gk),
        in_specs=[
            pl.BlockSpec((1, 1, bm_, bk_),
                         lambda b, i, j, pp, kk: (p_lo + pp, b, i, kk)),
            pl.BlockSpec((1, 1, bn_, bk_),
                         lambda b, i, j, pp, kk: (t - p_lo - pp, b, j, kk)),
        ] + [c_spec] * nc,
        out_specs=[c_spec] * nc,
        out_shape=[jax.ShapeDtypeStruct((B, mp, np_), c.dtype) for c in c_p],
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.int32)],
        input_output_aliases={2 + i: i for i in range(nc)},
        interpret=interpret,
    )(a_p, b_p, *c_p)
    return [o[:, :m, :n] for o in outs]


@functools.partial(jax.jit, static_argnames=("p_lo", "t", "npairs", "scale",
                                             "bm", "bn", "bk", "interpret"))
def int8_matmul_nt_epilogue_sw(a_slices: jax.Array, b_slices: jax.Array,
                               c: jax.Array, *, p_lo: int, t: int,
                               npairs: int, scale: float, bm: int = 256,
                               bn: int = 256, bk: int = 512,
                               interpret: bool = True) -> jax.Array:
    """c += (sum_pp A[p_lo+pp] @ B[t-p_lo-pp].T) * scale, epilogue-fused.

    a_slices: (s, m, k) int8; b_slices: (s, n, k) int8; c: (m, n) float
    (f64 on CPU oracle hosts). One launch covers one anti-diagonal group.
    Batch-grid form: (s, B, m, k) x (s, B, n, k) slices with a (B, m, n)
    accumulator — the batch rides as the outermost grid dimension.
    """
    assert a_slices.dtype == jnp.int8 and b_slices.dtype == jnp.int8
    (out,) = _epilogue_launch(a_slices, b_slices, [c], _epilogue_kernel_sw,
                              p_lo=p_lo, t=t, npairs=npairs, scale=scale,
                              bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out


@functools.partial(jax.jit, static_argnames=("p_lo", "t", "npairs", "scale",
                                             "bm", "bn", "bk", "interpret"))
def int8_matmul_nt_epilogue_dw(a_slices: jax.Array, b_slices: jax.Array,
                               c_hi: jax.Array, c_lo: jax.Array, *,
                               p_lo: int, t: int, npairs: int, scale: float,
                               bm: int = 256, bn: int = 256, bk: int = 512,
                               interpret: bool = True
                               ) -> tuple[jax.Array, jax.Array]:
    """(c_hi, c_lo) += df32(group product) * scale, epilogue-fused.

    The compensated df32 add is ``ozaki_accum.dw_accum_step`` — the same
    rounding sequence as the standalone fused accumulation kernel, so the
    epilogue pipeline stays bitwise identical to the XLA reference.
    Accepts the batch-grid form exactly like the sw variant: (s, B, m, k)
    slices with (B, m, n) accumulator planes.
    """
    assert a_slices.dtype == jnp.int8 and b_slices.dtype == jnp.int8
    o_hi, o_lo = _epilogue_launch(a_slices, b_slices, [c_hi, c_lo],
                                  _epilogue_kernel_dw, p_lo=p_lo, t=t,
                                  npairs=npairs, scale=scale, bm=bm, bn=bn,
                                  bk=bk, interpret=interpret)
    return o_hi, o_lo
