"""Pallas TPU kernel: int8 x int8 -> int32 GEMM on the MXU (NT layout).

Computes ``C[m, n] = sum_k A[m, k] * B[n, k]`` — both operands contract on
their last axis, which is exactly how the Ozaki scheme stores B slices
(column-split of B == row-split of B^T), and is the MXU-friendly layout:
no transposition between HBM and VMEM.

Tiling: grid (m/bm, n/bn, k/bk), k innermost so each output block stays
resident in VMEM while the k loop streams A/B tiles through the MXU,
accumulating in int32. Block shapes default to MXU-aligned 256x256x512:
  A tile 256x512 int8 = 128 KiB, B tile 256x512 int8 = 128 KiB,
  C tile 256x256 int32 = 256 KiB  ->  ~0.5 MiB VMEM of ~16 MiB.

``int8_matmul_nt_batched`` adds a leading batch grid dimension — one
kernel launch for a whole ``(B, m, k) x (B, n, k)`` stack (the batched
Ozaki API's fully-batched case); the per-(batch, m, n) k-loop is
unchanged. Launch bookkeeping (block shrink, padding, grid) comes from
the shared ``launch`` layer.

``int8_matmul_nt_epilogue_{sw,dw}`` are the epilogue-fused variants used
by the ``fusion="epilogue"`` executor: the int32 slice products of one
anti-diagonal group accumulate in a VMEM scratch block across a
(pairs, k) grid walk and are folded into the carried high-precision
accumulator C inside the GEMM grid's epilogue — the int32 products never
round-trip to HBM (see ``core.tuning.hbm_pass_model``). The epilogue
runs the exact rounding sequence of the standalone accumulation kernels
(``ozaki_accum.dw_accum_step`` / the single rounded f64 add), so results
stay bitwise identical to the ``xla`` reference pipeline. Both epilogue
variants also take batch-grid operands — ``(s, B, m, k)`` slice stacks
with ``(B, m, n)`` carried accumulators and the batch as the outermost
grid dimension — so stacked-weights batches keep epilogue fusion.

Validated on CPU in interpret mode against ``ref.int8_matmul_nt_ref``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .launch import (crt_blocks, gemm_blocks, grid_for, pad_tail,
                     streaming_blocks)
from .ozaki_accum import dw_accum_step
from .ozaki_split import split_tile


def _kernel(a_ref, b_ref, o_ref):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    prod = jax.lax.dot_general(
        a_ref[...], b_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)
    o_ref[...] += prod


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def int8_matmul_nt(a: jax.Array, b_t: jax.Array, *, bm: int = 256,
                   bn: int = 256, bk: int = 512,
                   interpret: bool = True) -> jax.Array:
    """C = A @ B_t.T with int32 accumulation. a: (m, k) int8, b_t: (n, k)."""
    assert a.dtype == jnp.int8 and b_t.dtype == jnp.int8
    m, k = a.shape
    n, k2 = b_t.shape
    assert k == k2, (a.shape, b_t.shape)
    bm_, bn_, bk_ = gemm_blocks(m, n, k, bm, bn, bk)
    a_p = pad_tail(a, (bm_, bk_))
    b_p = pad_tail(b_t, (bn_, bk_))
    mp, kp = a_p.shape
    np_, _ = b_p.shape
    grid = grid_for((mp, np_, kp), (bm_, bn_, bk_))
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn_, bk_), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=interpret,
    )(a_p, b_p)
    return out[:m, :n]


def _kernel_batched(a_ref, b_ref, o_ref):
    k_idx = pl.program_id(3)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    prod = jax.lax.dot_general(
        a_ref[0], b_ref[0],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)
    o_ref[...] += prod[None]


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def int8_matmul_nt_batched(a: jax.Array, b_t: jax.Array, *, bm: int = 256,
                           bn: int = 256, bk: int = 512,
                           interpret: bool = True) -> jax.Array:
    """C[b] = A[b] @ B_t[b].T for every batch row, one kernel launch.

    a: (B, m, k) int8, b_t: (B, n, k) int8 -> (B, m, n) int32. The batch
    is the outermost grid dimension, so consecutive program instances
    reuse the same (i, j, k) walk per batch row.
    """
    assert a.dtype == jnp.int8 and b_t.dtype == jnp.int8
    B, m, k = a.shape
    B2, n, k2 = b_t.shape
    assert B == B2 and k == k2, (a.shape, b_t.shape)
    bm_, bn_, bk_ = gemm_blocks(m, n, k, bm, bn, bk)
    a_p = pad_tail(a, (bm_, bk_))
    b_p = pad_tail(b_t, (bn_, bk_))
    _, mp, kp = a_p.shape
    _, np_, _ = b_p.shape
    grid = (B,) + grid_for((mp, np_, kp), (bm_, bn_, bk_))
    out = pl.pallas_call(
        _kernel_batched,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm_, bk_), lambda b, i, j, kk: (b, i, kk)),
            pl.BlockSpec((1, bn_, bk_), lambda b, i, j, kk: (b, j, kk)),
        ],
        out_specs=pl.BlockSpec((1, bm_, bn_), lambda b, i, j, kk: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((B, mp, np_), jnp.int32),
        interpret=interpret,
    )(a_p, b_p)
    return out[:, :m, :n]


# ----------------------------------------------------------------------------
# Epilogue-fused variants: GEMM + scaled high-precision accumulation in one
# launch. One call per anti-diagonal group; the int32 group product lives
# only in a VMEM scratch block.
# ----------------------------------------------------------------------------
#
# Grid is (m/bm, n/bn, npairs, k/bk) with the C block index a function of
# (i, j) only, so for each output block the whole (pairs, k) walk happens
# while C stays resident. Slice operands are indexed affinely in the pair
# dimension: A uses slice ``p_lo + pp``, B uses ``t - p_lo - pp`` — exactly
# the anti-diagonal's (p, q = t - p) pairs. The int32 scratch accumulator
# is exact (alpha reserves diagonal-fusion headroom), so the epilogue sees
# the same group product P_t the unfused pipeline materializes to HBM.
#
# The batch-grid variants take (s, B, m, k) x (s, B, n, k) slice stacks
# and prepend the batch as the OUTERMOST grid dimension:
# (B, m/bm, n/bn, npairs, k/bk). The inner (pairs, k) walk per C block is
# unchanged — the scratch accumulator carries across grid steps exactly
# as in the 2-D kernel because (pp, kk) remain the fastest-varying dims —
# so a stacked-weights batch keeps ``fuse_epilogue=True`` instead of
# falling back to the stage-fused pipeline (the PR 2 limitation).


def _epilogue_kernel_sw(scale, npairs, nk, a_ref, b_ref, c_ref, o_ref,
                        acc_ref):
    pp = pl.program_id(2)
    kk = pl.program_id(3)

    @pl.when((pp == 0) & (kk == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[0], b_ref[0],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when((pp == npairs - 1) & (kk == nk - 1))
    def _epilogue():
        c = c_ref[...]
        # int32 -> f64 exact; scale an exact power of two: ONE rounding,
        # matching ``_accum_f64`` / ``accum_scaled_sw`` bitwise.
        o_ref[...] = c + acc_ref[...].astype(c.dtype) * jnp.asarray(
            scale, c.dtype)


def _epilogue_kernel_dw(scale, npairs, nk, a_ref, b_ref, chi_ref, clo_ref,
                        ohi_ref, olo_ref, acc_ref):
    pp = pl.program_id(2)
    kk = pl.program_id(3)

    @pl.when((pp == 0) & (kk == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[0], b_ref[0],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when((pp == npairs - 1) & (kk == nk - 1))
    def _epilogue():
        n_hi, n_lo = dw_accum_step(acc_ref[...], chi_ref[...], clo_ref[...],
                                   scale)
        ohi_ref[...] = n_hi
        olo_ref[...] = n_lo


def _epilogue_kernel_batched_sw(scale, npairs, nk, a_ref, b_ref, c_ref,
                                o_ref, acc_ref):
    pp = pl.program_id(3)
    kk = pl.program_id(4)

    @pl.when((pp == 0) & (kk == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[0, 0], b_ref[0, 0],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when((pp == npairs - 1) & (kk == nk - 1))
    def _epilogue():
        c = c_ref[0]
        o_ref[...] = (c + acc_ref[...].astype(c.dtype) * jnp.asarray(
            scale, c.dtype))[None]


def _epilogue_kernel_batched_dw(scale, npairs, nk, a_ref, b_ref, chi_ref,
                                clo_ref, ohi_ref, olo_ref, acc_ref):
    pp = pl.program_id(3)
    kk = pl.program_id(4)

    @pl.when((pp == 0) & (kk == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[0, 0], b_ref[0, 0],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when((pp == npairs - 1) & (kk == nk - 1))
    def _epilogue():
        n_hi, n_lo = dw_accum_step(acc_ref[...], chi_ref[0], clo_ref[0],
                                   scale)
        ohi_ref[...] = n_hi[None]
        olo_ref[...] = n_lo[None]


_EPILOGUE_BATCHED = {_epilogue_kernel_sw: _epilogue_kernel_batched_sw,
                     _epilogue_kernel_dw: _epilogue_kernel_batched_dw}


def _epilogue_launch(a_slices, b_slices, c_arrays, kernel, *, p_lo, t,
                     npairs, scale, bm, bn, bk, interpret):
    """Shared launch recipe for both epilogue variants, 2-D and batched.

    c_arrays: list of (m, n) — or (B, m, n) for (s, B, m, k) slice
    stacks — accumulator planes (1 for sw, 2 for dw), donated and
    carried through ``input_output_aliases``.
    """
    if a_slices.ndim == 4:
        return _epilogue_launch_batched(
            a_slices, b_slices, c_arrays, _EPILOGUE_BATCHED[kernel],
            p_lo=p_lo, t=t, npairs=npairs, scale=scale, bm=bm, bn=bn,
            bk=bk, interpret=interpret)
    s, m, k = a_slices.shape
    s2, n, k2 = b_slices.shape
    assert k == k2, (a_slices.shape, b_slices.shape)
    assert 0 <= p_lo and p_lo + npairs <= s, (p_lo, npairs, s)
    assert 0 <= t - p_lo - (npairs - 1) and t - p_lo < s2, (p_lo, t, npairs)
    bm_, bn_, bk_ = gemm_blocks(m, n, k, bm, bn, bk)
    a_p = pad_tail(a_slices, (bm_, bk_))
    b_p = pad_tail(b_slices, (bn_, bk_))
    c_p = [pad_tail(c, (bm_, bn_)) for c in c_arrays]
    _, mp, kp = a_p.shape
    _, np_, _ = b_p.shape
    gm, gn, gk = grid_for((mp, np_, kp), (bm_, bn_, bk_))
    nc = len(c_p)
    c_spec = pl.BlockSpec((bm_, bn_), lambda i, j, pp, kk: (i, j))
    outs = pl.pallas_call(
        functools.partial(kernel, scale, npairs, gk),
        grid=(gm, gn, npairs, gk),
        in_specs=[
            pl.BlockSpec((1, bm_, bk_),
                         lambda i, j, pp, kk: (p_lo + pp, i, kk)),
            pl.BlockSpec((1, bn_, bk_),
                         lambda i, j, pp, kk: (t - p_lo - pp, j, kk)),
        ] + [c_spec] * nc,
        out_specs=[c_spec] * nc,
        out_shape=[jax.ShapeDtypeStruct((mp, np_), c.dtype) for c in c_p],
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.int32)],
        input_output_aliases={2 + i: i for i in range(nc)},
        interpret=interpret,
    )(a_p, b_p, *c_p)
    return [o[:m, :n] for o in outs]


def _epilogue_launch_batched(a_slices, b_slices, c_arrays, kernel, *, p_lo,
                             t, npairs, scale, bm, bn, bk, interpret):
    """Batch-grid epilogue launch: (s, B, m, k) x (s, B, n, k) slices,
    (B, m, n) carried accumulators, batch outermost in the grid."""
    s, B, m, k = a_slices.shape
    s2, B2, n, k2 = b_slices.shape
    assert k == k2 and B == B2, (a_slices.shape, b_slices.shape)
    assert 0 <= p_lo and p_lo + npairs <= s, (p_lo, npairs, s)
    assert 0 <= t - p_lo - (npairs - 1) and t - p_lo < s2, (p_lo, t, npairs)
    bm_, bn_, bk_ = gemm_blocks(m, n, k, bm, bn, bk)
    a_p = pad_tail(a_slices, (bm_, bk_))
    b_p = pad_tail(b_slices, (bn_, bk_))
    c_p = [pad_tail(c, (bm_, bn_)) for c in c_arrays]
    _, _, mp, kp = a_p.shape
    _, _, np_, _ = b_p.shape
    gm, gn, gk = grid_for((mp, np_, kp), (bm_, bn_, bk_))
    nc = len(c_p)
    c_spec = pl.BlockSpec((1, bm_, bn_), lambda b, i, j, pp, kk: (b, i, j))
    outs = pl.pallas_call(
        functools.partial(kernel, scale, npairs, gk),
        grid=(B, gm, gn, npairs, gk),
        in_specs=[
            pl.BlockSpec((1, 1, bm_, bk_),
                         lambda b, i, j, pp, kk: (p_lo + pp, b, i, kk)),
            pl.BlockSpec((1, 1, bn_, bk_),
                         lambda b, i, j, pp, kk: (t - p_lo - pp, b, j, kk)),
        ] + [c_spec] * nc,
        out_specs=[c_spec] * nc,
        out_shape=[jax.ShapeDtypeStruct((B, mp, np_), c.dtype) for c in c_p],
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.int32)],
        input_output_aliases={2 + i: i for i in range(nc)},
        interpret=interpret,
    )(a_p, b_p, *c_p)
    return [o[:, :m, :n] for o in outs]


@functools.partial(jax.jit, static_argnames=("p_lo", "t", "npairs", "scale",
                                             "bm", "bn", "bk", "interpret"))
def int8_matmul_nt_epilogue_sw(a_slices: jax.Array, b_slices: jax.Array,
                               c: jax.Array, *, p_lo: int, t: int,
                               npairs: int, scale: float, bm: int = 256,
                               bn: int = 256, bk: int = 512,
                               interpret: bool = True) -> jax.Array:
    """c += (sum_pp A[p_lo+pp] @ B[t-p_lo-pp].T) * scale, epilogue-fused.

    a_slices: (s, m, k) int8; b_slices: (s, n, k) int8; c: (m, n) float
    (f64 on CPU oracle hosts). One launch covers one anti-diagonal group.
    Batch-grid form: (s, B, m, k) x (s, B, n, k) slices with a (B, m, n)
    accumulator — the batch rides as the outermost grid dimension.
    """
    assert a_slices.dtype == jnp.int8 and b_slices.dtype == jnp.int8
    (out,) = _epilogue_launch(a_slices, b_slices, [c], _epilogue_kernel_sw,
                              p_lo=p_lo, t=t, npairs=npairs, scale=scale,
                              bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out


@functools.partial(jax.jit, static_argnames=("p_lo", "t", "npairs", "scale",
                                             "bm", "bn", "bk", "interpret"))
def int8_matmul_nt_epilogue_dw(a_slices: jax.Array, b_slices: jax.Array,
                               c_hi: jax.Array, c_lo: jax.Array, *,
                               p_lo: int, t: int, npairs: int, scale: float,
                               bm: int = 256, bn: int = 256, bk: int = 512,
                               interpret: bool = True
                               ) -> tuple[jax.Array, jax.Array]:
    """(c_hi, c_lo) += df32(group product) * scale, epilogue-fused.

    The compensated df32 add is ``ozaki_accum.dw_accum_step`` — the same
    rounding sequence as the standalone fused accumulation kernel, so the
    epilogue pipeline stays bitwise identical to the XLA reference.
    Accepts the batch-grid form exactly like the sw variant: (s, B, m, k)
    slices with (B, m, n) accumulator planes.
    """
    assert a_slices.dtype == jnp.int8 and b_slices.dtype == jnp.int8
    o_hi, o_lo = _epilogue_launch(a_slices, b_slices, [c_hi, c_lo],
                                  _epilogue_kernel_dw, p_lo=p_lo, t=t,
                                  npairs=npairs, scale=scale, bm=bm, bn=bn,
                                  bk=bk, interpret=interpret)
    return o_hi, o_lo


# ----------------------------------------------------------------------------
# Fused-CRT variants (Ozaki Scheme II): residue GEMMs + balanced-Garner
# reconstruction in one launch. The int32 residue products accumulate in a
# (ell, bm, bn) VMEM scratch stack across a (modulus, k) grid walk and the
# CRT epilogue reconstructs the f64 value in-register at the last grid
# step — the per-modulus int32 product planes never round-trip to HBM.
# ----------------------------------------------------------------------------
#
# Grid is (m/bm, n/bn, ell, k/bk) with the C block index a function of
# (i, j) only, so for each output block the whole (modulus, k) walk
# happens while the accumulator stack stays resident. The epilogue replays
# ``core.modular.crt_digits``/``crt_value`` exactly: centered residues per
# modulus, Garner's int32 recurrence with host-baked constants (every
# intermediate bounded by ~125 + ell*125*250 < 2^21 — the centering step
# is what makes that bound hold in here too), then the f64 sum smallest
# radix first with the same python-float scales. Integer stages are exact
# and the float stage runs the identical rounding sequence, so the fused
# route is bitwise identical to the unfused XLA reference (the executor
# applies the same final ``jnp.ldexp(out, e_base)``).
#
# The batch-grid variant prepends the batch as the OUTERMOST grid
# dimension — (B, m/bm, n/bn, ell, k/bk) — like the epilogue family; the
# residue stacks arrive as (ell, B, m, k) x (ell, B, n, k).


def _fmod(x, m: int):
    """Floor mod by a positive int32 constant (== jnp.mod bitwise: exact
    integer arithmetic, spelled with lax.rem for Mosaic)."""
    r = jax.lax.rem(x, jnp.int32(m))
    return r + jnp.where(r < 0, jnp.int32(m), jnp.int32(0))


def _crt_epilogue(acc_ref, moduli, qmod, inv, scales):
    """Balanced-Garner digits + ascending-radix f64 sum of the resident
    (ell, bm, bn) int32 residue-product stack."""
    digits = []
    c = None
    for j, mj in enumerate(moduli):
        half = (mj - 1) // 2
        r = _fmod(acc_ref[pl.ds(j, 1)][0], mj)
        acc = r - jnp.where(r > half, jnp.int32(mj), jnp.int32(0))
        for i in range(j):
            acc = acc - digits[i] * jnp.int32(qmod[i][j])
        d = _fmod(acc, mj)
        v = _fmod(d * jnp.int32(inv[j]), mj)
        digits.append(v - jnp.where(v > half, jnp.int32(mj), jnp.int32(0)))
        # mirror ``crt_value``'s FMA-proof term: the scale arrives as a
        # Veltkamp (hi, lo) pair, so both digit products are EXACT f64
        # (7 + 27 bits) and only the running adds round — contracting an
        # exact mul into the add cannot move a bit, keeping the kernel
        # sum bitwise identical to the eager reference.
        hi, lo = scales[j]
        vf = digits[j].astype(jnp.float64)
        t_lo = vf * lo
        c = t_lo if c is None else c + t_lo
        c = c + vf * hi
    return c


def _crt_kernel(moduli, qmod, inv, scales, nk, a_ref, b_ref, o_ref, acc_ref):
    jj = pl.program_id(2)
    kk = pl.program_id(3)
    ell = len(moduli)

    @pl.when((jj == 0) & (kk == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[pl.ds(jj, 1)] += jax.lax.dot_general(
        a_ref[0], b_ref[0],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)[None]

    @pl.when((jj == ell - 1) & (kk == nk - 1))
    def _epilogue():
        o_ref[...] = _crt_epilogue(acc_ref, moduli, qmod, inv, scales)


def _crt_kernel_batched(moduli, qmod, inv, scales, nk, a_ref, b_ref, o_ref,
                        acc_ref):
    jj = pl.program_id(3)
    kk = pl.program_id(4)
    ell = len(moduli)

    @pl.when((jj == 0) & (kk == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[pl.ds(jj, 1)] += jax.lax.dot_general(
        a_ref[0, 0], b_ref[0, 0],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)[None]

    @pl.when((jj == ell - 1) & (kk == nk - 1))
    def _epilogue():
        o_ref[...] = _crt_epilogue(acc_ref, moduli, qmod, inv, scales)[None]


@functools.partial(jax.jit, static_argnames=("moduli", "qmod", "inv",
                                             "scales", "bm", "bn", "bk",
                                             "interpret"))
def int8_matmul_nt_crt(ra: jax.Array, rb: jax.Array, *, moduli, qmod, inv,
                       scales, bm: int = 256, bn: int = 256, bk: int = 512,
                       interpret: bool = True) -> jax.Array:
    """Fused residue GEMMs + balanced-Garner CRT reconstruction.

    ra: (ell, m, k) int8 centered residue stack of A_int; rb: (ell, n, k)
    of B_int^T. Returns the (m, n) f64 CRT value PRE-ldexp — the caller
    applies ``jnp.ldexp(out, e_base)``, exactly as after ``crt_value``.
    The Garner constants come from ``core.modular.garner_constants`` as
    hashable static tuples (moduli, Q_i-mod-m_j rows, inverses, f64
    scales). Batch-grid form: (ell, B, m, k) x (ell, B, n, k) residue
    stacks -> (B, m, n).

    Zero-padding is exact end to end: padded k columns contribute zero
    residue products, and all-zero accumulator planes reconstruct to 0.0
    in the padded m/n fringe (sliced off).
    """
    assert ra.dtype == jnp.int8 and rb.dtype == jnp.int8
    assert len(moduli) == ra.shape[0] == rb.shape[0], \
        (len(moduli), ra.shape, rb.shape)
    if ra.ndim == 4:
        return _crt_launch_batched(ra, rb, moduli=moduli, qmod=qmod,
                                   inv=inv, scales=scales, bm=bm, bn=bn,
                                   bk=bk, interpret=interpret)
    ell, m, k = ra.shape
    _, n, k2 = rb.shape
    assert k == k2, (ra.shape, rb.shape)
    bm_, bn_, bk_ = crt_blocks(m, n, k, bm, bn, bk, ell=ell)
    a_p = pad_tail(ra, (bm_, bk_))
    b_p = pad_tail(rb, (bn_, bk_))
    _, mp, kp = a_p.shape
    _, np_, _ = b_p.shape
    gm, gn, gk = grid_for((mp, np_, kp), (bm_, bn_, bk_))
    out = pl.pallas_call(
        functools.partial(_crt_kernel, moduli, qmod, inv, scales, gk),
        grid=(gm, gn, ell, gk),
        in_specs=[
            pl.BlockSpec((1, bm_, bk_), lambda i, j, jj, kk: (jj, i, kk)),
            pl.BlockSpec((1, bn_, bk_), lambda i, j, jj, kk: (jj, j, kk)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, jj, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float64),
        scratch_shapes=[pltpu.VMEM((ell, bm_, bn_), jnp.int32)],
        interpret=interpret,
    )(a_p, b_p)
    return out[:m, :n]


def _crt_launch_batched(ra, rb, *, moduli, qmod, inv, scales, bm, bn, bk,
                        interpret):
    """Batch-grid fused-CRT launch: (ell, B, m, k) x (ell, B, n, k)
    residue stacks, batch outermost in the grid."""
    ell, B, m, k = ra.shape
    _, B2, n, k2 = rb.shape
    assert k == k2 and B == B2, (ra.shape, rb.shape)
    bm_, bn_, bk_ = crt_blocks(m, n, k, bm, bn, bk, ell=ell)
    a_p = pad_tail(ra, (bm_, bk_))
    b_p = pad_tail(rb, (bn_, bk_))
    _, _, mp, kp = a_p.shape
    _, _, np_, _ = b_p.shape
    gm, gn, gk = grid_for((mp, np_, kp), (bm_, bn_, bk_))
    out = pl.pallas_call(
        functools.partial(_crt_kernel_batched, moduli, qmod, inv, scales,
                          gk),
        grid=(B, gm, gn, ell, gk),
        in_specs=[
            pl.BlockSpec((1, 1, bm_, bk_),
                         lambda b, i, j, jj, kk: (jj, b, i, kk)),
            pl.BlockSpec((1, 1, bn_, bk_),
                         lambda b, i, j, jj, kk: (jj, b, j, kk)),
        ],
        out_specs=pl.BlockSpec((1, bm_, bn_),
                               lambda b, i, j, jj, kk: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((B, mp, np_), jnp.float64),
        scratch_shapes=[pltpu.VMEM((ell, bm_, bn_), jnp.int32)],
        interpret=interpret,
    )(a_p, b_p)
    return out[:, :m, :n]


# ----------------------------------------------------------------------------
# Streaming-split variants: split + GEMM + scaled accumulation in one
# launch. Operands arrive as (hi, lo) word pairs plus per-row exponents;
# the int8 slices are extracted in VMEM at the head of each k-panel and
# never materialize to HBM.
# ----------------------------------------------------------------------------
#
# Grid is (m/bm, n/bn, k/bk, npairs) with the PAIR dimension innermost —
# the opposite nesting of the epilogue kernels — so each (i, j, kk)
# operand-tile load is split exactly once (at pp == 0) into persistent
# int8 VMEM scratches, then all of the group's pairs consume the resident
# slice planes. The slice chain is prefix-stable, so the scratches hold
# only the prefix the group touches: A needs slices [0, p_lo + npairs),
# B needs [0, t - p_lo + 1). The (kk, pp) walk sums the same int32
# products as the epilogue kernels' (pp, kk) walk — int32 accumulation is
# exact, hence order-independent — and the float epilogue runs the
# identical rounding sequence at the last grid step, so streaming stays
# bitwise identical to every other executor. Padded rows/cols carry
# hi = lo = 0 with exponent 0 and split to all-zero slices, matching the
# zero-padded materialized stacks.
#
# The batch-grid variants prepend the batch as the OUTERMOST grid
# dimension, exactly like the epilogue family.


def _streaming_kernel_sw(w, scale, p_lo, t, npairs, nk, ns_a, ns_b,
                         ahi_ref, alo_ref, aexp_ref, bhi_ref, blo_ref,
                         bexp_ref, c_ref, o_ref, asl_ref, bsl_ref, acc_ref):
    kk = pl.program_id(2)
    pp = pl.program_id(3)

    @pl.when(pp == 0)
    def _split():
        split_tile(asl_ref, ahi_ref[...], alo_ref[...], aexp_ref[...],
                   ns_a, w)
        split_tile(bsl_ref, bhi_ref[...], blo_ref[...], bexp_ref[...],
                   ns_b, w)

    @pl.when((kk == 0) & (pp == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        asl_ref[pl.ds(p_lo + pp, 1)][0], bsl_ref[pl.ds(t - p_lo - pp, 1)][0],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when((kk == nk - 1) & (pp == npairs - 1))
    def _epilogue():
        c = c_ref[...]
        o_ref[...] = c + acc_ref[...].astype(c.dtype) * jnp.asarray(
            scale, c.dtype)


def _streaming_kernel_dw(w, scale, p_lo, t, npairs, nk, ns_a, ns_b,
                         ahi_ref, alo_ref, aexp_ref, bhi_ref, blo_ref,
                         bexp_ref, chi_ref, clo_ref, ohi_ref, olo_ref,
                         asl_ref, bsl_ref, acc_ref):
    kk = pl.program_id(2)
    pp = pl.program_id(3)

    @pl.when(pp == 0)
    def _split():
        split_tile(asl_ref, ahi_ref[...], alo_ref[...], aexp_ref[...],
                   ns_a, w)
        split_tile(bsl_ref, bhi_ref[...], blo_ref[...], bexp_ref[...],
                   ns_b, w)

    @pl.when((kk == 0) & (pp == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        asl_ref[pl.ds(p_lo + pp, 1)][0], bsl_ref[pl.ds(t - p_lo - pp, 1)][0],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when((kk == nk - 1) & (pp == npairs - 1))
    def _epilogue():
        n_hi, n_lo = dw_accum_step(acc_ref[...], chi_ref[...], clo_ref[...],
                                   scale)
        ohi_ref[...] = n_hi
        olo_ref[...] = n_lo


def _streaming_kernel_batched_sw(w, scale, p_lo, t, npairs, nk, ns_a, ns_b,
                                 ahi_ref, alo_ref, aexp_ref, bhi_ref,
                                 blo_ref, bexp_ref, c_ref, o_ref, asl_ref,
                                 bsl_ref, acc_ref):
    kk = pl.program_id(3)
    pp = pl.program_id(4)

    @pl.when(pp == 0)
    def _split():
        split_tile(asl_ref, ahi_ref[0], alo_ref[0], aexp_ref[0], ns_a, w)
        split_tile(bsl_ref, bhi_ref[0], blo_ref[0], bexp_ref[0], ns_b, w)

    @pl.when((kk == 0) & (pp == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        asl_ref[pl.ds(p_lo + pp, 1)][0], bsl_ref[pl.ds(t - p_lo - pp, 1)][0],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when((kk == nk - 1) & (pp == npairs - 1))
    def _epilogue():
        c = c_ref[0]
        o_ref[...] = (c + acc_ref[...].astype(c.dtype) * jnp.asarray(
            scale, c.dtype))[None]


def _streaming_kernel_batched_dw(w, scale, p_lo, t, npairs, nk, ns_a, ns_b,
                                 ahi_ref, alo_ref, aexp_ref, bhi_ref,
                                 blo_ref, bexp_ref, chi_ref, clo_ref,
                                 ohi_ref, olo_ref, asl_ref, bsl_ref,
                                 acc_ref):
    kk = pl.program_id(3)
    pp = pl.program_id(4)

    @pl.when(pp == 0)
    def _split():
        split_tile(asl_ref, ahi_ref[0], alo_ref[0], aexp_ref[0], ns_a, w)
        split_tile(bsl_ref, bhi_ref[0], blo_ref[0], bexp_ref[0], ns_b, w)

    @pl.when((kk == 0) & (pp == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        asl_ref[pl.ds(p_lo + pp, 1)][0], bsl_ref[pl.ds(t - p_lo - pp, 1)][0],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when((kk == nk - 1) & (pp == npairs - 1))
    def _epilogue():
        n_hi, n_lo = dw_accum_step(acc_ref[...], chi_ref[0], clo_ref[0],
                                   scale)
        ohi_ref[...] = n_hi[None]
        olo_ref[...] = n_lo[None]


_STREAMING_BATCHED = {_streaming_kernel_sw: _streaming_kernel_batched_sw,
                      _streaming_kernel_dw: _streaming_kernel_batched_dw}


def _streaming_launch(a_ops, b_ops, c_arrays, kernel, *, num_splits, p_lo,
                      t, npairs, w, scale, bm, bn, bk, interpret):
    """Shared launch recipe for both streaming variants, 2-D and batched.

    a_ops/b_ops: (hi, lo, exp) operand triples — (m, k)/(m, k)/(m,) for
    the 2-D form, (B, m, k)/(B, m, k)/(B, m) for the batch grid.
    c_arrays: accumulator planes (1 for sw, 2 for dw), carried through
    ``input_output_aliases``.
    """
    ns_a = p_lo + npairs
    ns_b = t - p_lo + 1
    assert 0 <= p_lo and ns_a <= num_splits, (p_lo, npairs, num_splits)
    assert 0 <= t - p_lo - (npairs - 1) and ns_b <= num_splits, \
        (p_lo, t, npairs, num_splits)
    a_hi, a_lo, a_exp = a_ops
    b_hi, b_lo, b_exp = b_ops
    if a_hi.ndim == 3:
        return _streaming_launch_batched(
            a_ops, b_ops, c_arrays, _STREAMING_BATCHED[kernel],
            ns_a=ns_a, ns_b=ns_b, p_lo=p_lo, t=t, npairs=npairs, w=w,
            scale=scale, bm=bm, bn=bn, bk=bk, interpret=interpret)
    m, k = a_hi.shape
    n, k2 = b_hi.shape
    assert k == k2, (a_hi.shape, b_hi.shape)
    bm_, bn_, bk_ = streaming_blocks(m, n, k, bm, bn, bk, num_splits_a=ns_a,
                                     num_splits_b=ns_b,
                                     el_bytes=a_hi.dtype.itemsize)
    a_p = [pad_tail(a_hi, (bm_, bk_)), pad_tail(a_lo, (bm_, bk_)),
           pad_tail(a_exp, (bm_,))]
    b_p = [pad_tail(b_hi, (bn_, bk_)), pad_tail(b_lo, (bn_, bk_)),
           pad_tail(b_exp, (bn_,))]
    c_p = [pad_tail(c, (bm_, bn_)) for c in c_arrays]
    mp, kp = a_p[0].shape
    np_, _ = b_p[0].shape
    gm, gn, gk = grid_for((mp, np_, kp), (bm_, bn_, bk_))
    nc = len(c_p)
    c_spec = pl.BlockSpec((bm_, bn_), lambda i, j, kk, pp: (i, j))
    outs = pl.pallas_call(
        functools.partial(kernel, w, scale, p_lo, t, npairs, gk, ns_a, ns_b),
        grid=(gm, gn, gk, npairs),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk, pp: (i, kk)),
            pl.BlockSpec((bm_, bk_), lambda i, j, kk, pp: (i, kk)),
            pl.BlockSpec((bm_,), lambda i, j, kk, pp: (i,)),
            pl.BlockSpec((bn_, bk_), lambda i, j, kk, pp: (j, kk)),
            pl.BlockSpec((bn_, bk_), lambda i, j, kk, pp: (j, kk)),
            pl.BlockSpec((bn_,), lambda i, j, kk, pp: (j,)),
        ] + [c_spec] * nc,
        out_specs=[c_spec] * nc,
        out_shape=[jax.ShapeDtypeStruct((mp, np_), c.dtype) for c in c_p],
        scratch_shapes=[pltpu.VMEM((ns_a, bm_, bk_), jnp.int8),
                        pltpu.VMEM((ns_b, bn_, bk_), jnp.int8),
                        pltpu.VMEM((bm_, bn_), jnp.int32)],
        input_output_aliases={6 + i: i for i in range(nc)},
        interpret=interpret,
    )(*a_p, *b_p, *c_p)
    return [o[:m, :n] for o in outs]


def _streaming_launch_batched(a_ops, b_ops, c_arrays, kernel, *, ns_a, ns_b,
                              p_lo, t, npairs, w, scale, bm, bn, bk,
                              interpret):
    """Batch-grid streaming launch: (B, m, k) operand words, (B, m) row
    exponents, (B, m, n) carried accumulators, batch outermost."""
    a_hi, a_lo, a_exp = a_ops
    b_hi, b_lo, b_exp = b_ops
    B, m, k = a_hi.shape
    B2, n, k2 = b_hi.shape
    assert k == k2 and B == B2, (a_hi.shape, b_hi.shape)
    bm_, bn_, bk_ = streaming_blocks(m, n, k, bm, bn, bk, num_splits_a=ns_a,
                                     num_splits_b=ns_b,
                                     el_bytes=a_hi.dtype.itemsize)
    a_p = [pad_tail(a_hi, (bm_, bk_)), pad_tail(a_lo, (bm_, bk_)),
           pad_tail(a_exp, (bm_,))]
    b_p = [pad_tail(b_hi, (bn_, bk_)), pad_tail(b_lo, (bn_, bk_)),
           pad_tail(b_exp, (bn_,))]
    c_p = [pad_tail(c, (bm_, bn_)) for c in c_arrays]
    _, mp, kp = a_p[0].shape
    _, np_, _ = b_p[0].shape
    gm, gn, gk = grid_for((mp, np_, kp), (bm_, bn_, bk_))
    nc = len(c_p)
    c_spec = pl.BlockSpec((1, bm_, bn_), lambda b, i, j, kk, pp: (b, i, j))
    outs = pl.pallas_call(
        functools.partial(kernel, w, scale, p_lo, t, npairs, gk, ns_a, ns_b),
        grid=(B, gm, gn, gk, npairs),
        in_specs=[
            pl.BlockSpec((1, bm_, bk_), lambda b, i, j, kk, pp: (b, i, kk)),
            pl.BlockSpec((1, bm_, bk_), lambda b, i, j, kk, pp: (b, i, kk)),
            pl.BlockSpec((1, bm_), lambda b, i, j, kk, pp: (b, i)),
            pl.BlockSpec((1, bn_, bk_), lambda b, i, j, kk, pp: (b, j, kk)),
            pl.BlockSpec((1, bn_, bk_), lambda b, i, j, kk, pp: (b, j, kk)),
            pl.BlockSpec((1, bn_), lambda b, i, j, kk, pp: (b, j)),
        ] + [c_spec] * nc,
        out_specs=[c_spec] * nc,
        out_shape=[jax.ShapeDtypeStruct((B, mp, np_), c.dtype) for c in c_p],
        scratch_shapes=[pltpu.VMEM((ns_a, bm_, bk_), jnp.int8),
                        pltpu.VMEM((ns_b, bn_, bk_), jnp.int8),
                        pltpu.VMEM((bm_, bn_), jnp.int32)],
        input_output_aliases={6 + i: i for i in range(nc)},
        interpret=interpret,
    )(*a_p, *b_p, *c_p)
    return [o[:, :m, :n] for o in outs]


@functools.partial(jax.jit, static_argnames=("num_splits", "p_lo", "t",
                                             "npairs", "w", "scale", "bm",
                                             "bn", "bk", "interpret"))
def int8_matmul_nt_streaming_sw(a_hi: jax.Array, a_lo: jax.Array,
                                a_exp: jax.Array, b_hi: jax.Array,
                                b_lo: jax.Array, b_exp: jax.Array,
                                c: jax.Array, *, num_splits: int, p_lo: int,
                                t: int, npairs: int, w: int, scale: float,
                                bm: int = 256, bn: int = 256, bk: int = 512,
                                interpret: bool = True) -> jax.Array:
    """c += (sum_pp A[p_lo+pp] @ B[t-p_lo-pp].T) * scale — with the int8
    slices extracted in VMEM from the (hi, lo, exp) operand words.

    One launch covers one anti-diagonal group, exactly like the epilogue
    variants, but no slice stack exists in HBM: (m, k)/(m,) operand
    arrays in, (m, n) accumulator through. Batch-grid form: (B, m, k)
    words with (B, m) exponents and a (B, m, n) accumulator.
    """
    (out,) = _streaming_launch((a_hi, a_lo, a_exp), (b_hi, b_lo, b_exp),
                               [c], _streaming_kernel_sw,
                               num_splits=num_splits, p_lo=p_lo, t=t,
                               npairs=npairs, w=w, scale=scale, bm=bm,
                               bn=bn, bk=bk, interpret=interpret)
    return out


@functools.partial(jax.jit, static_argnames=("num_splits", "p_lo", "t",
                                             "npairs", "w", "scale", "bm",
                                             "bn", "bk", "interpret"))
def int8_matmul_nt_streaming_dw(a_hi: jax.Array, a_lo: jax.Array,
                                a_exp: jax.Array, b_hi: jax.Array,
                                b_lo: jax.Array, b_exp: jax.Array,
                                c_hi: jax.Array, c_lo: jax.Array, *,
                                num_splits: int, p_lo: int, t: int,
                                npairs: int, w: int, scale: float,
                                bm: int = 256, bn: int = 256, bk: int = 512,
                                interpret: bool = True
                                ) -> tuple[jax.Array, jax.Array]:
    """(c_hi, c_lo) += df32(group product) * scale, streaming-split.

    The epilogue runs ``ozaki_accum.dw_accum_step`` — the identical
    rounding sequence of every other executor — so streaming stays
    bitwise identical to the XLA reference. Batch-grid form as in the sw
    variant.
    """
    o_hi, o_lo = _streaming_launch((a_hi, a_lo, a_exp), (b_hi, b_lo, b_exp),
                                   [c_hi, c_lo], _streaming_kernel_dw,
                                   num_splits=num_splits, p_lo=p_lo, t=t,
                                   npairs=npairs, w=w, scale=scale, bm=bm,
                                   bn=bn, bk=bk, interpret=interpret)
    return o_hi, o_lo
